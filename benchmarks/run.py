"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (stub contract) and writes the
machine-readable ``BENCH_auto_pipeline.json`` perf baseline (bubble
fraction, simulated makespan and HLO collective-permute bytes per config)
next to this file's repo root, so future PRs can diff against it.  Heavy
subprocess benchmarks (pipeline_cpu) and the dry-run-dependent roofline are
included when available / unless --fast.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_auto_pipeline.json")

# Lower-is-better metrics --compare checks (anything else is
# informational).  Each rule scopes a set of leaf keys to a tree-path
# prefix, with its own relative tolerance — a leaf is gated only when it
# sits under that subtree, so e.g. a model config named "bubble" or a
# future unrelated "float32" leaf elsewhere in the JSON can never be
# silently gated (the old flat key-set matched leaf names anywhere).
# Analytic/count metrics get the tight 5% band; measured wall-clock rows
# get a loose jitter-aware band (shared CI runners are noisy).
# A new value may exceed the baseline by the tolerance before it counts
# as a regression; metrics absent from the baseline are skipped, so
# adding new rows never fails an old baseline.
REGRESSION_RULES: tuple[tuple[str, frozenset, float], ...] = (
    # (path prefix, gated leaf keys under it, relative tolerance)
    ("hlo", frozenset({"bfloat16", "float32", "collective_permute_bytes"}),
     0.05),
    ("hlo_collective_permute_bytes", frozenset({""}), 0.05),  # top-level leaf
    ("interleave", frozenset({"bubble", "rx_buffer_bytes",
                              "skip_buffer_bytes", "rx_entries",
                              "skip_entries"}), 0.05),
    ("measured", frozenset({"overlap_on_us"}), 1.00),
    ("measured", frozenset({"overlap_ratio"}), 0.50),
    # analytic ZeRO hybrid rows: comm share of an iteration + sharded
    # param/grad/optimizer peak bytes per device at each zero_stage
    ("zero", frozenset({"comm_share_pct", "b1_comm_share_pct",
                        "b2_comm_share_pct", "b4_comm_share_pct",
                        "peak_gb_zero0", "peak_gb_zero1",
                        "peak_gb_zero2"}), 0.05),
    # supervisor recovery MTTR (detect -> relaunched generation live):
    # dominated by worker relaunch + jit compile wall-clock, so the band
    # is deliberately very loose — it only catches order-of-magnitude
    # recovery-path breakage, not runner jitter.
    ("recovery", frozenset({"mttr_s"}), 2.00),
)
REGRESSION_TOL = 0.05   # the tight band (kept for --help/callers)


def _rule_for(path: str) -> tuple[float, bool]:
    """(tolerance, gated?) for a tree path like 'interleave/hunyuan/bubble'."""
    head, _, rest = path.partition("/")
    leaf = path.rsplit("/", 1)[-1]
    for prefix, keys, tol in REGRESSION_RULES:
        if head != prefix:
            continue
        if (rest == "" and "" in keys) or leaf in keys:
            return tol, True
    return 0.0, False


def _missing_metrics(old, path) -> list[str]:
    """Gated metrics present in the baseline but absent from the new run
    count as regressions — otherwise a probe that starts failing (and so
    stops emitting e.g. the HLO wire-format bytes) would make the gate
    pass vacuously."""
    out: list[str] = []
    if isinstance(old, dict):
        for k, v in old.items():
            out += _missing_metrics(v, f"{path}/{k}" if path else k)
        return out
    if _rule_for(path)[1] and isinstance(old, (int, float)):
        out.append(f"{path}: metric missing from the new run "
                   f"(baseline {old:.6g})")
    return out


def compare_baseline(old, new, path="") -> list[str]:
    """Walk two bench JSON trees; report lower-is-better regressions."""
    regressions: list[str] = []
    if isinstance(old, dict) and isinstance(new, dict):
        for k, ov in old.items():
            sub = f"{path}/{k}" if path else k
            if k in new:
                regressions += compare_baseline(ov, new[k], sub)
            else:
                regressions += _missing_metrics(ov, sub)
        return regressions
    tol, gated = _rule_for(path)
    if gated and isinstance(old, (int, float)) \
            and isinstance(new, (int, float)):
        if new > old * (1.0 + tol) + 1e-12:
            regressions.append(
                f"{path}: {new:.6g} vs baseline {old:.6g} "
                f"(+{100 * (new / old - 1):.1f}% > {100 * tol:.0f}%"
                " tolerance)" if old else f"{path}: {new:.6g} vs baseline 0")
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip subprocess + ILP benchmarks")
    ap.add_argument("--json-out", default=BENCH_JSON,
                    help="where to write the auto-pipeline perf baseline")
    ap.add_argument("--compare", metavar="BASELINE_JSON", default=None,
                    help="diff the fresh run against a committed baseline "
                         "and exit nonzero on any lower-is-better metric "
                         "regressing beyond its rule's tolerance "
                         f"({100 * REGRESSION_TOL:.0f}%% analytic, looser "
                         "for measured wall-clock rows)")
    args = ap.parse_args()

    from benchmarks import (partition_balance, comm_volume, hybrid_ablation,
                            throughput_model, zero_breakdown, moe_dispatch,
                            auto_pipeline, recovery)
    # recovery spawns worker subprocesses but stays in the --fast set:
    # the nightly gate runs --fast --compare, and a gated metric that
    # vanished from the new run counts as a regression.
    modules = [partition_balance, comm_volume, hybrid_ablation,
               throughput_model, zero_breakdown, moe_dispatch,
               auto_pipeline, recovery]
    if not args.fast:
        from benchmarks import schedule_synthesis, pipeline_cpu
        modules += [schedule_synthesis, pipeline_cpu]
    try:
        from benchmarks import roofline
        modules.append(roofline)
    except Exception:
        pass

    print("name,us_per_call,derived")
    failures = 0
    auto_pipeline_json: dict = {}
    for mod in modules:
        try:
            if mod in (auto_pipeline, zero_breakdown, recovery):
                rows = mod.run(json_sink=auto_pipeline_json)
            else:
                rows = mod.run()
            for row in rows:
                print(row)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__}.ERROR,0,{type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    if auto_pipeline_json:
        with open(args.json_out, "w") as f:
            json.dump(auto_pipeline_json, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json_out}", file=sys.stderr)
    if args.compare:
        with open(args.compare) as f:
            baseline = json.load(f)
        regressions = compare_baseline(baseline, auto_pipeline_json)
        if regressions:
            print("PERF REGRESSIONS vs " + args.compare, file=sys.stderr)
            for r in regressions:
                print(f"  {r}", file=sys.stderr)
            raise SystemExit(2)
        print(f"no perf regressions vs {args.compare}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
