"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (stub contract) and writes the
machine-readable ``BENCH_auto_pipeline.json`` perf baseline (bubble
fraction, simulated makespan and HLO collective-permute bytes per config)
next to this file's repo root, so future PRs can diff against it.  Heavy
subprocess benchmarks (pipeline_cpu) and the dry-run-dependent roofline are
included when available / unless --fast.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_auto_pipeline.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip subprocess + ILP benchmarks")
    ap.add_argument("--json-out", default=BENCH_JSON,
                    help="where to write the auto-pipeline perf baseline")
    args = ap.parse_args()

    from benchmarks import (partition_balance, comm_volume, hybrid_ablation,
                            throughput_model, zero_breakdown, moe_dispatch,
                            auto_pipeline)
    modules = [partition_balance, comm_volume, hybrid_ablation,
               throughput_model, zero_breakdown, moe_dispatch,
               auto_pipeline]
    if not args.fast:
        from benchmarks import schedule_synthesis, pipeline_cpu
        modules += [schedule_synthesis, pipeline_cpu]
    try:
        from benchmarks import roofline
        modules.append(roofline)
    except Exception:
        pass

    print("name,us_per_call,derived")
    failures = 0
    auto_pipeline_json: dict = {}
    for mod in modules:
        try:
            if mod is auto_pipeline:
                rows = mod.run(json_sink=auto_pipeline_json)
            else:
                rows = mod.run()
            for row in rows:
                print(row)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__}.ERROR,0,{type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    if auto_pipeline_json:
        with open(args.json_out, "w") as f:
            json.dump(auto_pipeline_json, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json_out}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
