"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (stub contract).  Heavy subprocess
benchmarks (pipeline_cpu) and the dry-run-dependent roofline are included
when available / unless --fast.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip subprocess + ILP benchmarks")
    args = ap.parse_args()

    from benchmarks import (partition_balance, comm_volume, hybrid_ablation,
                            throughput_model, zero_breakdown, moe_dispatch,
                            auto_pipeline)
    modules = [partition_balance, comm_volume, hybrid_ablation,
               throughput_model, zero_breakdown, moe_dispatch,
               auto_pipeline]
    if not args.fast:
        from benchmarks import schedule_synthesis, pipeline_cpu
        modules += [schedule_synthesis, pipeline_cpu]
    try:
        from benchmarks import roofline
        modules.append(roofline)
    except Exception:
        pass

    print("name,us_per_call,derived")
    failures = 0
    for mod in modules:
        try:
            for row in mod.run():
                print(row)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__}.ERROR,0,{type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
