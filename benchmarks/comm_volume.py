"""Paper Fig. 3 + Table III: per-sample communication volume.

Analytic volumes from the exact partition comm model for PULSE / 1F1B
(block-wise sequential) / Hanayo (same layout) / ZeRO-2, per model, using
the paper's microbatch settings.  The HLO-measured cross-check lives in
tests/helpers/comm_volume_hlo.py (collective-permute bytes of the compiled
wave vs skip-carry executors).
"""
from __future__ import annotations

from repro.core.comm_model import (partition_comm_volume, zero_volume_per_iter)
from repro.core.partition import blockwise_partition, partition
from benchmarks.partition_balance import MODELS

MICROBATCH = 32
DEVICES = 8


def run() -> list[str]:
    rows = []
    for name, make in MODELS.items():
        g = make()
        pulse = partition(g, DEVICES)
        base = blockwise_partition(g, DEVICES)
        v_pulse = partition_comm_volume(g, pulse).train_total / MICROBATCH
        v_base = partition_comm_volume(g, base).train_total / MICROBATCH
        params = g.total_param_bytes()
        v_zero2 = zero_volume_per_iter(params, DEVICES, 2) / MICROBATCH
        red = 100.0 * (1 - v_pulse / max(v_base, 1))
        rows.append(f"comm_volume.{name}.pulse_MB_per_sample,"
                    f"{v_pulse/1e6:.2f},")
        rows.append(f"comm_volume.{name}.seq1f1b_MB_per_sample,"
                    f"{v_base/1e6:.2f},reduction={red:.1f}%")
        rows.append(f"comm_volume.{name}.zero2_MB_per_sample,"
                    f"{v_zero2/1e6:.2f},")
        skip_share = partition_comm_volume(g, base)
        share = 100.0 * skip_share.skip_bytes / max(skip_share.fwd_total, 1)
        rows.append(f"comm_volume.{name}.skip_share_pct,{share:.1f},"
                    f"paper: 85.5-90%")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
