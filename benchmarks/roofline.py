"""Roofline analysis per (arch x shape x mesh) from the dry-run artefacts.

Three terms per cell (seconds/step on TPU v5e constants):

    compute    = FLOPs_per_chip / 197 TFLOP/s
    memory     = HBM_bytes_per_chip / 819 GB/s
    collective = collective_bytes_per_chip / link_bw (ICI 50 GB/s,
                 DCN 25 GB/s for 'pod'-crossing collectives)

Methodology (documented in EXPERIMENTS.md §Roofline): XLA's
``cost_analysis()`` counts ``lax.scan`` bodies ONCE (verified empirically),
so raw compiled numbers undercount by the trip counts of the layer/tick
scans.  We therefore *reconstruct* per-step totals analytically from the
config + parallel plan (formulas below), and use the compiled HLO for what
it is authoritative about: compile success, per-device peak memory, the
collective *schedule* (op kinds/counts), and per-body byte cross-checks.

FLOP conventions:
    dense fwd          = 2 * N_active * tokens
    train              = 3x fwd (+1x fwd re-compute under remat)
    attention fwd      = 4 * B * S^2 * d_attn per layer  (dense-masked)
    decode attn        = 4 * B * S * d_attn per layer (one query token)
    MODEL_FLOPS        = 6 * N_active * tokens  (assignment convention)

HBM-traffic conventions (per chip):
    params  : train  (2 fwd reads + 1 bwd read) * bf16 + optimizer
              (fp32 m,v read+write = 16 B; int8 = 4 B) + param write
    acts    : ~18 bytes/token/layer/d_model equivalent reads+writes
              (remat-adjusted), activations in bf16.

Collective conventions (per chip, ring algorithms):
    FSDP    : 3 gathers + 1 reduce-scatter of the chip's param group
    TP      : 4 all-reduces/layer of (tokens_chip * d) bf16 (Megatron)
    EP      : 2 all-to-alls/MoE-layer fwd (x3 for train) of the chip's
              dispatched token slice
    PP      : 2(D-1)/D boundary hops/microbatch each way, fwd + bwd
    DP      : one grad all-reduce (2(G-1)/G) of the chip's grads
"""
from __future__ import annotations

import json
import os

from repro.configs import get_arch, ASSIGNED, PAPER_ARCHS
from repro.configs.base import SHAPES

PEAK = 197e12
HBM = 819e9
ICI = 50e9
DCN = 25e9
CHIPS = {"16x16": 256, "2x16x16": 512}
AXES = {"16x16": {"data": 16, "model": 16},
        "2x16x16": {"pod": 2, "data": 16, "model": 16}}


def _plan_axes(plan, axes):
    tp = axes.get("model", 1) if plan.get("tp") else 1
    fsdp = 1
    for a in plan.get("fsdp", []):
        fsdp *= axes.get(a, 1)
    dp = 1
    for a in plan.get("batch_axes", []):
        dp *= axes.get(a, 1)
    return tp, fsdp, max(dp, 1)


def _family_attn_dim(cfg) -> tuple[int, int]:
    """(layers_with_attn, d_attn = Hq*Dh per layer)."""
    if hasattr(cfg, "mla") and cfg.mla is not None:
        m = cfg.mla
        return cfg.n_layers, m.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
    if hasattr(cfg, "attn") and cfg.attn is not None:
        return cfg.n_layers, cfg.attn.n_heads * cfg.attn.head_dim
    if hasattr(cfg, "shared_attn"):        # zamba2: attn only at shared sites
        return len(cfg.shared_sites()), \
            cfg.shared_attn.n_heads * cfg.shared_attn.head_dim
    if hasattr(cfg, "n_enc_layers"):       # whisper
        return cfg.n_enc_layers + 2 * cfg.n_dec_layers, cfg.d_model
    if hasattr(cfg, "slstm_every"):        # xlstm quadratic mLSTM form
        return cfg.n_layers, cfg.d_inner
    if hasattr(cfg, "ch_mults"):           # SDv2 UNet: attn at 3 levels
        n_attn = sum(cfg.blocks_per_level * 2 for lvl in cfg.attn_levels) + 1
        return n_attn, cfg.base_ch * max(cfg.ch_mults)
    if hasattr(cfg, "n_layers") and hasattr(cfg, "n_heads"):  # uvit/hunyuan
        return cfg.n_layers, cfg.d_model
    return 0, 0


def _attn_window(cfg, S):
    if hasattr(cfg, "attn") and cfg.attn is not None and cfg.attn.window:
        return min(cfg.attn.window, S)
    return S


def cell_roofline(arch: str, shape_name: str, mesh_key: str, rec: dict) -> dict:
    bundle = get_arch(arch)
    cfg = bundle.cfg
    shape = SHAPES[shape_name]
    axes = AXES[mesh_key]
    chips = CHIPS[mesh_key]
    plan = rec["plan"]
    tp, fsdp, dp = _plan_axes(plan, axes)
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    N_act = bundle.active_param_count
    N_tot = bundle.param_count
    p_bytes_tot = N_tot * 2                      # bf16
    int8 = plan.get("int8_opt", False)
    is_pp = plan["strategy"].startswith("pp")

    tokens = B * S if kind in ("train", "prefill") else B
    L_attn, d_attn = _family_attn_dim(cfg)
    ctx = _attn_window(cfg, S) if kind != "decode" else min(S, 10**9)

    # ---------------- FLOPs ----------------
    dense_fwd = 2.0 * N_act * tokens
    if kind == "decode":
        attn_fwd = 4.0 * B * ctx * d_attn * L_attn
    else:
        attn_fwd = 4.0 * B * S * ctx * d_attn * L_attn
    fwd = dense_fwd + attn_fwd
    if kind == "train":
        # remat_recompute_factor: 1.0 = full per-layer remat (recompute the
        # whole fwd); ~0.1 under checkpoint_dots (matmul outputs saved).
        rf = rec.get("remat_recompute_factor", 1.0)
        flops_total = (3.0 + rf) * fwd
    else:
        flops_total = fwd
    model_flops = 6.0 * N_act * tokens if kind == "train" \
        else 2.0 * N_act * tokens
    f_chip = flops_total / chips

    # ---------------- HBM bytes ----------------
    p_chip = p_bytes_tot / (tp * fsdp) if not is_pp \
        else p_bytes_tot / (axes["model"] * 1)
    opt_bytes = (4 if int8 else 16 + 16)         # m,v r+w per param
    if kind == "train":
        par_traffic = p_chip * (3 + 1) + (N_tot / (tp * fsdp)) * opt_bytes
    else:
        par_traffic = p_chip
    d_model = getattr(cfg, "d_model", getattr(cfg, "base_ch", 512) * 4)
    L = getattr(cfg, "n_layers", L_attn) or L_attn
    tok_chip = tokens / (dp if not is_pp else dp)
    act_traffic = 18.0 * tok_chip * L * d_model * 2 / (tp if not is_pp else 1)
    if kind == "decode":
        # decode reads the whole KV cache once per step
        cache = rec.get("cache_bytes", 0) or _decode_cache_bytes(bundle, shape)
        act_traffic += cache / chips
    b_chip = par_traffic + act_traffic

    # ---------------- collective bytes ----------------
    coll_ici = 0.0
    coll_dcn = 0.0
    grads_chip = p_bytes_tot / (tp * fsdp) if kind == "train" else 0.0
    if is_pp:
        D = axes["model"]
        M = plan.get("microbatches", 16)
        payload = (tokens / dp / max(M, 1)) * d_model * 2   # per microbatch
        hops = 2 * (D - 1) / D * M * payload
        coll_ici += hops * (4 if plan["strategy"] == "pp_wave" else 2)
        if kind == "train":
            coll_ici += 2 * grads_chip * (dp - 1) / dp      # DP allreduce
    else:
        if fsdp > 1:
            gathers = 3 if kind == "train" else 1
            coll_ici += gathers * p_chip * (fsdp - 1)
            if kind == "train":
                coll_ici += p_chip * (fsdp - 1)             # reduce-scatter
        if tp > 1:
            tok_tp = tokens / dp
            ar = 2 * (tp - 1) / tp * tok_tp * d_model * 2
            passes = 3 if kind == "train" else 1
            sp = 0.5 if rec.get("sp_halves_tp") else 1.0
            coll_ici += 4 * L * ar * passes * sp
        if plan.get("ep"):
            moe = getattr(cfg, "moe", None)
            if moe:
                tok_tp = tokens / dp
                a2a = 2 * tok_tp * moe.top_k * d_model * 2 / tp
                coll_ici += a2a * (3 if kind == "train" else 1) * \
                    (cfg.n_layers - getattr(cfg, "n_dense_layers", 0))
        if kind == "train" and dp > 1:
            coll_ici += 2 * grads_chip * (dp - 1) / dp
    if "pod" in axes and kind == "train":
        # the pod axis carries DP/FSDP traffic over DCN
        coll_dcn += 2 * grads_chip * 0.5

    t_compute = f_chip / PEAK
    t_memory = b_chip / HBM
    t_coll = coll_ici / ICI + coll_dcn / DCN
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_key,
        "strategy": plan["strategy"] + ("/ep" if plan.get("ep") else "")
        + (f"/tp{tp}" if tp > 1 else "") + (f"/fsdp{fsdp}" if fsdp > 1 else ""),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dominant[0],
        "roofline_frac": t_compute / max(t_compute, t_memory, t_coll),
        "model_flops": model_flops,
        "hlo_flops_reconstructed": flops_total,
        "useful_ratio": model_flops / flops_total,
        "hlo_flops_raw_body": rec.get("cost", {}).get("flops", 0.0),
        "mem_per_chip_GB": (rec.get("memory", {}).get("temp_size_in_bytes")
                            or 0) / chips / 2**30,
        "collectives_hlo": rec.get("collectives", {}).get("bytes_by_kind", {}),
    }


def _decode_cache_bytes(bundle, shape) -> float:
    try:
        import jax
        struct = bundle.cache_struct(shape)
        return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(struct))
    except Exception:
        return 0.0


def analyze(path: str, mesh_key: str) -> list[dict]:
    with open(path) as f:
        data = json.load(f)
    out = []
    for key, rec in data.items():
        if rec.get("status") != "ok":
            continue
        arch, shape = key.split("|")
        out.append(cell_roofline(arch, shape, mesh_key, rec))
    return out


def _advice(row) -> str:
    b = row["bottleneck"]
    if b == "collective":
        return ("shard params less aggressively / overlap gathers with "
                "compute; for PP raise microbatch size to amortize hops")
    if b == "memory":
        return "raise arithmetic intensity: larger microbatch or fused kernels"
    return "compute-bound: good; chase useful-ratio toward 1.0"


def run() -> list[str]:
    rows = []
    for mesh_key, fname in (("16x16", "results/dryrun_16x16.json"),
                            ("2x16x16", "results/dryrun_2x16x16.json")):
        if not os.path.exists(fname):
            continue
        for r in analyze(fname, mesh_key):
            t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
            rows.append(
                f"roofline.{r['arch']}.{r['shape']}.{mesh_key},"
                f"{t*1e6:.0f},"
                f"bottleneck={r['bottleneck']} "
                f"frac={r['roofline_frac']:.2f} "
                f"useful={r['useful_ratio']:.2f}")
    return rows


def markdown_table(path: str, mesh_key: str) -> str:
    rows = analyze(path, mesh_key)
    lines = [
        "| arch | shape | strategy | compute s | memory s | collective s "
        "| bottleneck | roofline frac | useful ratio | mem/chip GB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['strategy']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['bottleneck']}** "
            f"| {r['roofline_frac']:.2f} | {r['useful_ratio']:.2f} "
            f"| {r['mem_per_chip_GB']:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print("\n".join(run()))
