"""Paper Figs. 10-12: modelled training throughput, PULSE vs baselines.

Uses the §VI hybrid tuner end-to-end: for each scheme the *memory-feasible*
(P, G, b) is selected under the cluster's per-device budget — this is the
paper's core dynamic (ZeRO-2 holds full params+grads per device, capping
its microbatch; PULSE shards stages so it runs bigger microbatches and
avoids the reduce-scatter over the scale-out network).

Schemes:
  pulse    — skip-aware partition + wave schedule (best feasible P*G=N)
  seq1f1b  — block-wise sequential partition; skip traffic priced onto the
             inter-node link (stacked/transferred/popped baseline)
  zero2    — DP-only; gradient+optimizer collectives over the scale-out net,
             microbatch capped by full-replica memory
"""
from __future__ import annotations

from repro.core.comm_model import partition_comm_volume, zero_volume_per_iter
from repro.core.hw import V100_CLUSTER, ASCEND_910A_CLUSTER
from repro.core.partition import blockwise_partition, partition
from repro.core.profiler import reprofile_graph
from repro.core.tuner import profile_partition, t_sched_paper, peak_memory
from benchmarks.partition_balance import MODELS

MFU = 0.35   # realistic achieved fraction of peak on the paper's clusters


def _derate(prof):
    return type(prof)(
        tuple(t / MFU for t in prof.fwd_time_per_sample),
        prof.param_bytes, prof.act_bytes_per_sample,
        prof.out_bytes_per_sample)


def zero2_throughput(g, hw, N) -> float:
    prof = _derate(profile_partition(g, blockwise_partition(g, 1,
                                                            folded=False)))
    p_bytes = g.total_param_bytes()
    best = 0.0
    b = 1
    while b <= 64:
        # ZeRO-2: full bf16 params + grads per device, sharded fp32 states
        mem = 2 * p_bytes + 12 * p_bytes / N \
            + b * prof.act_bytes_per_sample[0] * 0.25  # remat'd activations
        if mem >= hw.mem_limit:
            break
        t = (3 * prof.fwd_time_per_sample[0] * b
             + zero_volume_per_iter(p_bytes, N, 2) / hw.inter_bw + hw.t_lat)
        best = max(best, b * N / t)
        b *= 2
    return best


def pp_throughput(g, hw, N, scheme: str) -> float:
    best = 0.0
    for P in (2, 4, 8, 16):
        if P > N or 2 * P > g.n:
            continue
        G = N // P
        try:
            part = (partition(g, P) if scheme == "pulse"
                    else blockwise_partition(g, P, folded=False))
        except ValueError:
            continue
        prof = _derate(profile_partition(g, part))
        b = 1
        while b <= 64:
            mem = peak_memory(prof, P, b, wave=scheme == "pulse")
            if mem >= hw.mem_limit:
                break
            t = t_sched_paper(prof, P, b, G, hw)
            if scheme != "pulse":
                skip = partition_comm_volume(g, part).train_total * b * P
                t = t + skip / hw.inter_bw
            best = max(best, b * P * G / t)
            b *= 2
    return best


def run() -> list[str]:
    rows = []
    for cluster, N in ((V100_CLUSTER, 16), (ASCEND_910A_CLUSTER, 64)):
        for name, make in MODELS.items():
            g = reprofile_graph(make(), cluster)
            pulse = pp_throughput(g, cluster, N, "pulse")
            base = pp_throughput(g, cluster, N, "seq1f1b")
            zero = zero2_throughput(g, cluster, N)
            if min(pulse, base, zero) == 0.0:
                rows.append(f"throughput.{cluster.name}.{name}.pulse_sps,"
                            f"{pulse:.1f},baseline OOM")
                continue
            rows.append(
                f"throughput.{cluster.name}.{name}.pulse_sps,"
                f"{pulse:.1f},vs1F1B={pulse/base:.2f}x "
                f"vsZeRO2={pulse/zero:.2f}x(LB; analytic ZeRO=best-case)")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
