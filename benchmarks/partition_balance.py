"""Paper Figs. 6 & 7: per-block imbalance + skip-aware DP improvement.

Prints ``name,us_per_call,derived`` CSV rows:
  partition_balance.<model>.blockwise_max_us  (max stage fwd time, baseline)
  partition_balance.<model>.dp_max_us         (skip-aware DP)
  derived = improvement %.
"""
from __future__ import annotations

import time

from repro.core.partition import blockwise_partition, partition_bidirectional
from repro.models.diffusion import (UViTConfig, uvit_block_graph,
                                    HunyuanDiTConfig, hunyuan_block_graph,
                                    UNetConfig, unet_block_graph)

MODELS = {
    "sdv2": lambda: unet_block_graph(
        UNetConfig("sdv2", img_size=32, base_ch=448, ch_mults=(1, 2, 4, 4),
                   blocks_per_level=2, attn_levels=(1, 2, 3), ctx_dim=1024),
        batch=32),
    "uvit": lambda: uvit_block_graph(
        UViTConfig("uvit", img_size=32, d_model=2560, n_layers=32,
                   n_heads=20, d_ff=10240), batch=32),
    "hunyuan": lambda: hunyuan_block_graph(
        HunyuanDiTConfig("hy", img_size=64, d_model=2048, n_layers=32,
                         n_heads=16, d_ff=8192), batch=32),
}


def run() -> list[str]:
    rows = []
    for name, make in MODELS.items():
        g = make()
        times = [b.fwd_time for b in g.blocks]
        imbalance = max(times) / (sum(times) / len(times))
        t0 = time.perf_counter()
        dp = partition_bidirectional(g, 8, lam=0.0)
        solve_us = (time.perf_counter() - t0) * 1e6
        bw = blockwise_partition(g, 8, folded=True, lam=0.0)
        imp = 100.0 * (1 - dp.objective / bw.objective)
        rows.append(f"partition_balance.{name}.block_imbalance,"
                    f"{solve_us:.0f},max/mean={imbalance:.2f}x")
        rows.append(f"partition_balance.{name}.blockwise_max_us,"
                    f"{bw.objective*1e6:.1f},")
        rows.append(f"partition_balance.{name}.dp_max_us,"
                    f"{dp.objective*1e6:.1f},improvement={imp:.1f}%")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
