"""Beyond-paper §Perf artefact: one-hot vs scatter MoE dispatch cost.

Compares compiled-HLO FLOPs of one qwen3-style MoE layer under both
dispatch modes — the hillclimb evidence for choosing scatter at scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import MoEConfig, init_moe, apply_moe


def run() -> list[str]:
    cfg = MoEConfig(d_model=256, d_ff=96, n_experts=32, top_k=8,
                    capacity_factor=1.25)
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg)
    x = jax.random.normal(key, (2, 512, 256))
    rows = []
    flops = {}
    for mode in ("onehot", "scatter"):
        c = jax.jit(lambda p, x: apply_moe(p, x, cfg, dispatch=mode)[0]) \
            .lower(p, x).compile()
        ca = c.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        flops[mode] = float(ca.get("flops", 0))
        rows.append(f"moe_dispatch.{mode}.hlo_flops,{flops[mode]:.3e},")
    rows.append(f"moe_dispatch.ratio,{flops['onehot']/flops['scatter']:.2f},"
                f"onehot/scatter HLO-FLOP ratio")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
