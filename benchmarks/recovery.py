"""Recovery MTTR: how fast the training supervisor turns a fault into a
running shrunk cluster.

Two real supervised runs (2 simulated hosts, P=2 x dp=2, uvit-nano),
one per fault class:

- ``recovery.hostdown.mttr_s`` — host 1 hard-exits after a checkpoint
  commit; MTTR = hostdown-detected event -> the relaunched generation's
  ``gen-live`` event (all surviving hosts training again on the shrunk
  plan).  Includes teardown, rollback, re-tune, relaunch and the new
  plan's jit compile — the full pipeline a real recovery pays.
- ``recovery.hang.mttr_s`` — host 0 freezes with its process alive; the
  clock additionally starts only after the watchdog's progress deadline
  (``hang.detect_age_s``, informational) has flagged the root host.

Wall-clock rows on shared CI runners are noisy and compile-heavy, so the
``--compare`` gate carries a deliberately loose tolerance (see
``REGRESSION_RULES`` in benchmarks/run.py); both scenarios share one jit
compilation cache (the hang scenario runs second and mostly measures
the compile-warm path).
"""
from __future__ import annotations

import os
import shutil
import tempfile

STEPS = 10


def _mttr(events: list[dict], detect_kind: str) -> tuple[float, dict]:
    detect = next(e for e in events if e["kind"] == detect_kind)
    live = next(e for e in events
                if e["kind"] == "gen-live" and e["gen"] > detect["gen"])
    return live["t"] - detect["t"], detect


def _drill(name: str, faults: str, tmp: str):
    from repro.launch.supervisor import (Supervisor, SupervisorConfig,
                                         read_events)
    cfg = SupervisorConfig(
        run_dir=os.path.join(tmp, name), num_hosts=2, devices_per_host=2,
        steps=STEPS, global_batch=8, arch="uvit-nano", dp=2, pp=2,
        microbatches=4, wire_dtype="float32", lr=1e-3, ckpt_every=4,
        faults=faults, stall_timeout=12.0, miss_budget=2, poll=0.2,
        backoff_base=0.2, log_every=4)
    res = Supervisor(cfg).run()
    if not res.ok or res.restarts != 1:
        raise RuntimeError(f"recovery drill {name} did not recover "
                           f"cleanly: {res.outcome}/{res.restarts}")
    return read_events(res.events_path)


def run(json_sink: dict | None = None) -> list[str]:
    os.environ.setdefault("JAX_COMPILATION_CACHE_DIR",
                          tempfile.mkdtemp(prefix="repro_rec_cache_"))
    os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS",
                          "1")
    tmp = tempfile.mkdtemp(prefix="repro_rec_")
    rows = []
    sink = {} if json_sink is None else json_sink.setdefault("recovery", {})
    try:
        events = _drill("hostdown", "hostdown@8:1", tmp)
        mttr, _ = _mttr(events, "hostdown")
        rows.append(f"recovery.hostdown.mttr_s,{mttr:.1f},"
                    "exit-detected -> shrunk cluster training (cold jit)")
        sink["hostdown"] = {"mttr_s": round(mttr, 2)}

        events = _drill("hang", "hang@6", tmp)
        mttr, detect = _mttr(events, "hang")
        rows.append(f"recovery.hang.mttr_s,{mttr:.1f},"
                    "watchdog-flagged -> shrunk cluster training "
                    "(warm jit)")
        rows.append(f"recovery.hang.detect_age_s,{detect['age']:.1f},"
                    "stall age at detection (~stall_timeout*miss_budget)")
        sink["hang"] = {"mttr_s": round(mttr, 2),
                        "detect_age_s": round(detect["age"], 2)}
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows
