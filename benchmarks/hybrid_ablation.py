"""Paper Fig. 14: hybrid parallelism ablation, P in {2,4,8} on 8 devices.

Per model: modelled samples/s (Eq. 15-17) and p2p MB/sample for each P.
"""
from __future__ import annotations

import dataclasses

from repro.core.comm_model import partition_comm_volume
from repro.core.hw import V100_CLUSTER
from repro.core.partition import partition
from repro.core.tuner import profile_partition, t_sched_paper
from benchmarks.partition_balance import MODELS

N = 8


def run() -> list[str]:
    rows = []
    for name, make in MODELS.items():
        g = make()
        for P in (2, 4, 8):
            G = N // P
            try:
                part = partition(g, P)
            except ValueError:
                continue
            prof = profile_partition(g, part)
            b = 8
            t = t_sched_paper(prof, P, b, G, V100_CLUSTER)
            sps = b * P * G / t
            vol = partition_comm_volume(g, part).train_total / (b * P) / 1e6
            rows.append(f"hybrid.{name}.P{P}G{G}.samples_per_s,"
                        f"{sps:.1f},p2p={vol:.2f}MB/sample")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
