"""Paper Fig. 2: ZeRO time breakdown + hybrid sharded memory footprints.

Two row families, both analytic (deterministic, CI-gated at the tight 5%
band via the ``zero`` subtree of ``BENCH_auto_pipeline.json``):

- ``zero_breakdown.hunyuan.b{b}.comm_share_pct`` — the paper's Fig. 2
  motivation numbers (ZeRO-3 re-gather comm share on the 2-node V100
  cluster).
- ``zero_breakdown.<big config>.*`` — what the hybrid tuner actually
  charges: ZeRO all-gather/reduce-scatter comm share of an iteration
  (``core.tuner.t_grad_sync``) and the per-device param+grad+optimizer
  bytes at each zero_stage (``core.tuner.zero_param_state_breakdown``
  with the ISSUE's 12 B/param fp32 Adam state over bf16 params, so
  ``param_state_factor = 8``).
"""
from __future__ import annotations

from repro.core.comm_model import zero_volume_per_iter
from repro.core.hw import TPU_V5E, V100_CLUSTER
from repro.core.partition import blockwise_partition
from repro.core.tuner import (profile_partition, t_grad_sync,
                              zero_param_state_breakdown)
from benchmarks.partition_balance import MODELS


MFU = 0.35
DP = 8              # data-parallel degree the sharded footprints assume
TOKENS = 4096       # per-replica tokens/iter for the comm-share proxy
# bf16 params (2 B) + fp32 m/v/master (12 B) -> opt = 6x param bytes
PARAM_STATE_FACTOR = 8.0


def _big_configs():
    from repro.configs import deepseek_v3_671b, granite_34b
    return {"granite_34b": granite_34b.CFG,
            "deepseek_v3_671b": deepseek_v3_671b.CFG}


def run(json_sink: dict | None = None) -> list[str]:
    rows = []
    hw = V100_CLUSTER
    from repro.core.profiler import reprofile_graph
    g = reprofile_graph(MODELS["hunyuan"](), hw)
    prof = profile_partition(g, blockwise_partition(g, 1, folded=False))
    sink = {} if json_sink is None else json_sink.setdefault("zero", {})
    for b in (1, 2, 4):
        t_comp = 3 * sum(prof.fwd_time_per_sample) / MFU * b
        # ZeRO-3 re-gathers parameters in fwd AND bwd; on a 2-node cluster
        # half the ring crosses InfiniBand -> effective bw ~ inter_bw
        vol = zero_volume_per_iter(g.total_param_bytes(), 8, 3)
        t_comm = vol / hw.inter_bw
        share = 100 * t_comm / (t_comm + t_comp)
        rows.append(f"zero_breakdown.hunyuan.b{b}.comm_share_pct,"
                    f"{share:.1f},paper: ~30%")
        sink.setdefault("hunyuan", {})[f"b{b}_comm_share_pct"] = share

    hw = TPU_V5E
    for name, cfg in _big_configs().items():
        pb = cfg.param_count() * 2.0            # bf16 at-rest bytes
        t_comp = 6.0 * cfg.param_count() * TOKENS / (hw.peak_flops * MFU)
        t_comm = t_grad_sync(pb, DP, hw, 2)
        share = 100 * t_comm / (t_comm + t_comp)
        dst = sink.setdefault(name, {})
        dst["comm_share_pct"] = share
        rows.append(f"zero_breakdown.{name}.comm_share_pct,{share:.1f},"
                    f"dp={DP} all-gather+reduce-scatter vs {MFU:.0%} MFU")
        for z in (0, 1, 2):
            peak = sum(zero_param_state_breakdown(
                pb, dp=DP, zero_stage=z,
                param_state_factor=PARAM_STATE_FACTOR).values()) / 1e9
            dst[f"peak_gb_zero{z}"] = peak
            rows.append(f"zero_breakdown.{name}.peak_gb_zero{z},"
                        f"{peak:.1f},param+grad+opt GB/device at dp={DP}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
