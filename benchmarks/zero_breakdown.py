"""Paper Fig. 2: ZeRO-3 time breakdown (comm share of iteration time)."""
from __future__ import annotations

from repro.core.comm_model import zero_volume_per_iter
from repro.core.hw import V100_CLUSTER
from repro.core.partition import blockwise_partition
from repro.core.tuner import profile_partition
from benchmarks.partition_balance import MODELS


MFU = 0.35


def run() -> list[str]:
    rows = []
    hw = V100_CLUSTER
    from repro.core.profiler import reprofile_graph
    g = reprofile_graph(MODELS["hunyuan"](), hw)
    prof = profile_partition(g, blockwise_partition(g, 1, folded=False))
    for b in (1, 2, 4):
        t_comp = 3 * sum(prof.fwd_time_per_sample) / MFU * b
        # ZeRO-3 re-gathers parameters in fwd AND bwd; on a 2-node cluster
        # half the ring crosses InfiniBand -> effective bw ~ inter_bw
        vol = zero_volume_per_iter(g.total_param_bytes(), 8, 3)
        t_comm = vol / hw.inter_bw
        share = 100 * t_comm / (t_comm + t_comp)
        rows.append(f"zero_breakdown.hunyuan.b{b}.comm_share_pct,"
                    f"{share:.1f},paper: ~30%")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
