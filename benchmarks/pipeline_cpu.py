"""Measured (wall-clock) pipeline throughput on simulated devices.

Unlike the analytic models, this actually RUNS the wave executor and the
skip-carry baseline on 8 forced host devices and times steps — a measured
reproduction of the paper's headline direction (PULSE > baseline) at CPU
scale.  Runs in a subprocess to keep the parent single-device.
"""
from __future__ import annotations

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.runtime.compat import shard_map
from repro.models.diffusion import UViTConfig, init_uvit
from repro.runtime.pipeline import PipelineConfig
from repro.runtime.adapters import DiffusionPipelineAdapter, make_diffusion_microbatches

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = UViTConfig("b", img_size=16, in_ch=4, patch=2, d_model=128,
                 n_layers=8, n_heads=4, d_ff=256, n_classes=10)
key = jax.random.PRNGKey(0)
params = init_uvit(key, cfg)
B, M = 16, 4
batch = {"latents": jax.random.normal(key, (B, 16, 16, 4)),
         "labels": jax.random.randint(key, (B,), 0, 10)}
mb, aux = make_diffusion_microbatches(batch, key, M, cfg, "uvit")
pcfg = PipelineConfig(num_devices=4, num_microbatches=M,
                      data_axes=("data",), dp_size=2)
ad = DiffusionPipelineAdapter(cfg, pcfg, "uvit")

def bench(fn, stacks, edge):
    def loss(stacks, edge, mb, aux):
        return shard_map(fn, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P("model"), stacks[0]),
                      jax.tree.map(lambda _: P("model"), stacks[1]),
                      jax.tree.map(lambda _: P(), edge),
                      jax.tree.map(lambda _: P(None, "data"), mb),
                      jax.tree.map(lambda _: P(None, "data"), aux)),
            out_specs=P(), check_vma=False)(stacks[0], stacks[1], edge, mb, aux)
    g = jax.jit(jax.grad(loss, argnums=(0, 1)))
    out = g(stacks, edge, mb, aux)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(g(stacks, edge, mb, aux))
    return (time.perf_counter() - t0) / 3

stacks, edge = ad.split_params(params)
t_wave = bench(ad.build(), stacks, edge)
stacks_b, edge_b = ad.split_params_skip_carry(params)
t_base = bench(ad.build_skip_carry_baseline(), stacks_b, edge_b)
print(f"RESULT wave_us={t_wave*1e6:.0f} base_us={t_base*1e6:.0f} "
      f"speedup={t_base/t_wave:.2f}")
"""


def run() -> list[str]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    for line in res.stdout.splitlines():
        if line.startswith("RESULT"):
            kv = dict(p.split("=") for p in line.split()[1:])
            return [
                f"pipeline_cpu.uvit8L.wave_step_us,{kv['wave_us']},",
                f"pipeline_cpu.uvit8L.skipcarry_step_us,{kv['base_us']},"
                f"speedup={kv['speedup']}x",
            ]
    raise RuntimeError(f"bench failed: {res.stdout[-500:]} {res.stderr[-2000:]}")


if __name__ == "__main__":
    print("\n".join(run()))
