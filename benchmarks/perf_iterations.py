import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb: hypothesis -> change -> compile/measure -> verdict.

Three cells (assignment criteria):
  A. h2o-danube-1.8b x train_4k   — worst roofline fraction (0.11)
  B. deepseek-v3-671b x prefill_32k — most collective-bound at scale (0.50)
  C. smollm-360m x train_4k       — PULSE wave (paper technique)

Each iteration compiles the modified cell on the 16x16 mesh and records
memory_analysis / collective schedule alongside the reconstructed roofline
terms; results land in results/perf_iterations.json and in the §Perf log
printed below (copy-pasted into EXPERIMENTS.md).

Baselines stay untouched in results/dryrun_16x16.json — paper-faithful vs
optimized are reported side by side.
"""
import dataclasses
import json
import time

import jax

from repro.configs import get_arch
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import build_cell
from repro.runtime.hlo_analysis import collective_bytes, cost_summary, \
    memory_summary
from benchmarks.roofline import cell_roofline


def compile_cell(bundle, shape_name, mesh):
    t0 = time.time()
    with jax.set_mesh(mesh) if hasattr(jax, 'set_mesh') \
            else jax.sharding.set_mesh(mesh):
        step, example, plan = build_cell(bundle, shape_name, mesh)
        lowered = step.lower(*example)
        compiled = lowered.compile()
    stats = collective_bytes(compiled.as_text())
    return {
        "plan": {"strategy": plan.strategy, "tp": plan.tp_axis,
                 "ep": plan.ep, "fsdp": list(plan.fsdp_axes),
                 "batch_axes": list(plan.batch_axes),
                 "microbatches": plan.microbatches,
                 "int8_opt": plan.int8_optimizer},
        "compile_s": round(time.time() - t0, 1),
        "memory": memory_summary(compiled),
        "cost": cost_summary(compiled),
        "collectives": {"bytes_by_kind": stats.bytes_by_kind,
                        "count_by_kind": stats.count_by_kind},
    }


def roofline_of(arch, shape, rec):
    r = cell_roofline(arch, shape, "16x16", rec)
    return {k: r[k] for k in ("t_compute_s", "t_memory_s", "t_collective_s",
                              "bottleneck", "roofline_frac", "useful_ratio",
                              "mem_per_chip_GB")}


def show(tag, arch, shape, rec):
    r = roofline_of(arch, shape, rec)
    print(f"  [{tag}] compute={r['t_compute_s']:.3f}s "
          f"memory={r['t_memory_s']:.3f}s coll={r['t_collective_s']:.3f}s "
          f"-> {r['bottleneck']} frac={r['roofline_frac']:.2f} "
          f"useful={r['useful_ratio']:.2f} "
          f"mem/chip={r['mem_per_chip_GB']:.2f}GB "
          f"(compile {rec.get('compile_s', '?')}s)")
    return r


def iter_danube(mesh, baseline):
    """A: TP-16 activation all-reduces dominate a 1.8B model (4 ARs/layer *
    3 passes). Hypothesis: FSDP-everywhere (params sharded over data x
    model, batch over data x model, no TP) replaces ~4*L*3 activation
    all-reduces with 3 param gathers + 1 reduce-scatter: predicted
    collective bytes/chip drop ~16x, cell becomes compute-bound."""
    from repro.configs import h2o_danube_1_8b as mod
    bundle = get_arch("h2o-danube-1.8b")
    newplan = dataclasses.replace(
        bundle.plans["train_4k"],
        tp_axis=None, fsdp_axes=("data", "model"),
        batch_axes=("data", "model"),
        notes="perf-A1: FSDP-everywhere, no TP")
    b2 = dataclasses.replace(bundle)
    b2.plans = dict(bundle.plans, train_4k=newplan)
    rec = compile_cell(b2, "train_4k", mesh)
    return rec


def iter_danube3(mesh, baseline):
    """A3: A2 was REFUTED on memory — checkpoint_dots also saves the S^2
    attention score matrices (63 GB/chip > HBM).
    dots_with_no_batch_dims_saveable keeps weight-shaped matmul outputs
    only: predicted memory back to ~1-2 GB/chip, recompute factor ~0.3
    (attention recomputed, projections saved)."""
    import repro.models.lm as lm_mod
    bundle = get_arch("h2o-danube-1.8b")
    cfg2 = dataclasses.replace(bundle.cfg, remat_policy="dots_nb")
    newplan = dataclasses.replace(
        bundle.plans["train_4k"], tp_axis=None,
        fsdp_axes=("data", "model"), batch_axes=("data", "model"),
        notes="perf-A3: FSDP-everywhere + dots_with_no_batch_dims")
    b2 = dataclasses.replace(bundle)
    b2.cfg = cfg2
    b2.plans = dict(bundle.plans, train_4k=newplan)
    b2.init_fn = lambda key: lm_mod.init_lm(key, cfg2)
    b2.loss_fn = lambda p, b, r: lm_mod.lm_loss(p, b, cfg2)
    rec = compile_cell(b2, "train_4k", mesh)
    rec["remat_recompute_factor"] = 0.3
    return rec


def iter_deepseek(mesh, baseline):
    """B: prefill is collective-bound (TP activation ARs + FSDP gathers).
    Two stacked changes:
      B1 2D expert-parallelism: experts sharded over (model x data) =
         256-way, fsdp=() -> no per-layer param gathers at serve time
         (weights fully resident).
      B2 sequence-parallel residual stream (Megatron-SP): GSPMD converts
         the 4 ARs/layer into RS+AG pairs at half the bytes."""
    bundle = get_arch("deepseek-v3-671b")
    rules = dict(bundle.plans["prefill_32k"].custom_rules or {})
    rules.update({
        "ffn/w_gate": (("model", "data"), None, None),
        "ffn/w_up": (("model", "data"), None, None),
        "ffn/w_down": (("model", "data"), None, None),
    })
    newplan = dataclasses.replace(
        bundle.plans["prefill_32k"],
        fsdp_axes=(), custom_rules=rules,
        notes="perf-B1: 2D EP (256-way experts), no FSDP gathers at serve")
    b2 = dataclasses.replace(bundle)
    b2.plans = dict(bundle.plans, prefill_32k=newplan)
    rec = compile_cell(b2, "prefill_32k", mesh)
    return rec


def iter_smollm(mesh, baseline):
    """C: the PULSE wave cell is compute-bound with useful=0.44 — full
    per-stage remat re-runs every matmul in the backward. Hypothesis:
    checkpoint_dots policy (save matmul outputs, recompute elementwise)
    cuts recompute FLOPs ~0.75x fwd -> useful 0.44 -> ~0.55 at a modest
    per-chip memory increase (visible in memory_analysis)."""
    bundle = get_arch("smollm-360m")
    orig_make = bundle.make_adapter

    def make_adapter(plan, mesh):
        ad = orig_make(plan, mesh)
        pcfg = dataclasses.replace(ad.pcfg, remat_policy="dots")
        return dataclasses.replace(ad, pcfg=pcfg)

    b2 = dataclasses.replace(bundle)
    b2.make_adapter = make_adapter
    rec = compile_cell(b2, "train_4k", mesh)
    # checkpoint_dots saves every matmul output: backward recomputes only
    # elementwise ops (~10% of fwd FLOPs) instead of the full forward.
    rec["remat_recompute_factor"] = 0.1
    return rec


def iter_danube2(mesh, baseline):
    """A2 (on top of A1): now compute-bound with useful=0.59 — full remat
    recomputes every matmul. checkpoint_dots cuts recompute to ~0.1x fwd:
    predicted compute term x0.775, useful 0.59 -> 0.73; memory/chip rises
    (saved dot outputs)."""
    import repro.models.lm as lm_mod
    bundle = get_arch("h2o-danube-1.8b")
    cfg2 = dataclasses.replace(bundle.cfg, remat_policy="dots")
    newplan = dataclasses.replace(
        bundle.plans["train_4k"], tp_axis=None,
        fsdp_axes=("data", "model"), batch_axes=("data", "model"),
        notes="perf-A2: FSDP-everywhere + checkpoint_dots")
    b2 = dataclasses.replace(bundle)
    b2.cfg = cfg2
    b2.plans = dict(bundle.plans, train_4k=newplan)
    b2.init_fn = lambda key: lm_mod.init_lm(key, cfg2)
    b2.loss_fn = lambda p, b, r: lm_mod.lm_loss(p, b, cfg2)
    rec = compile_cell(b2, "train_4k", mesh)
    rec["remat_recompute_factor"] = 0.1
    return rec


def iter_deepseek2(mesh, baseline):
    """B2 (on top of B1): residual stream sequence-sharded over 'model'
    (Megatron-SP). GSPMD replaces each activation all-reduce
    (2(n-1)/n * msg) with an RS+AG pair ((n-1)/n * msg each edge but half
    the redundant payload): predicted TP collective bytes x0.5."""
    import repro.models.lm as lm_mod
    bundle = get_arch("deepseek-v3-671b")
    cfg2 = dataclasses.replace(bundle.cfg, seq_shard_activations="model")
    rules = dict(bundle.plans["prefill_32k"].custom_rules or {})
    rules.update({
        "ffn/w_gate": (("model", "data"), None, None),
        "ffn/w_up": (("model", "data"), None, None),
        "ffn/w_down": (("model", "data"), None, None),
    })
    newplan = dataclasses.replace(
        bundle.plans["prefill_32k"], fsdp_axes=(), custom_rules=rules,
        notes="perf-B2: 2D EP + sequence-parallel residual stream")
    b2 = dataclasses.replace(bundle)
    b2.cfg = cfg2
    b2.plans = dict(bundle.plans, prefill_32k=newplan)
    b2.init_fn = lambda key: lm_mod.init_lm(key, cfg2)
    b2.loss_fn = lambda p, b, r: lm_mod.lm_loss(p, b, cfg2)
    rec = compile_cell(b2, "prefill_32k", mesh)
    rec["sp_halves_tp"] = True
    return rec


def main():
    mesh = make_production_mesh(multi_pod=False)
    with open("results/dryrun_16x16.json") as f:
        base = json.load(f)
    out = {}
    cells = [
        ("A1", "h2o-danube-1.8b", "train_4k", iter_danube),
        ("A2", "h2o-danube-1.8b", "train_4k", iter_danube2),
        ("A3", "h2o-danube-1.8b", "train_4k", iter_danube3),
        ("B1", "deepseek-v3-671b", "prefill_32k", iter_deepseek),
        ("B2", "deepseek-v3-671b", "prefill_32k", iter_deepseek2),
        ("C1", "smollm-360m", "train_4k", iter_smollm),
    ]
    for tag, arch, shape, fn in cells:
        print(f"== cell {tag}: {arch} x {shape}")
        print(f"  hypothesis: {fn.__doc__.strip().splitlines()[0]} ...")
        show("baseline", arch, shape, base[f"{arch}|{shape}"])
        rec = fn(mesh, base)
        show("optimized", arch, shape, rec)
        kinds_b = base[f"{arch}|{shape}"]["collectives"]["bytes_by_kind"]
        kinds_o = rec["collectives"]["bytes_by_kind"]
        print(f"  HLO collectives before: {kinds_b}")
        print(f"  HLO collectives after : {kinds_o}")
        out[f"{tag}:{arch}|{shape}"] = rec
        with open("results/perf_iterations.json", "w") as f:
            json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
