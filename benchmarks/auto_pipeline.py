"""Auto-pipeline compile path: planning cost + plan quality benchmark.

Measures, across graph sizes and device counts, (a) wall-clock of the full
compile path — partition + schedule synthesis + validation + layout — and
(b) the quality gap between the DP partition and the blockwise baseline on
heterogeneous graphs, via the event-driven simulator (modelled makespan).

CSV rows: ``name,us_per_call,derived`` (harness contract; derived is the
baseline/pulse simulated-makespan ratio for the quality rows).
"""
from __future__ import annotations

import time


def run():
    from repro.core.graph import Block, BlockGraph, make_unet_like
    from repro.core.partition import blockwise_partition, partition
    from repro.core.schedule import schedule_for_partition, simulate
    from repro.core.tuner import profile_partition
    from repro.models.diffusion import UViTConfig, uvit_pipeline_graph
    from repro.models.lm import LMConfig, lm_pipeline_graph
    from repro.models.layers import AttnConfig
    from repro.runtime.adapters import diffusion_model_fns, lm_model_fns
    from repro.runtime.compile import auto_pipeline

    rows = []

    # ---- compile-path latency (plan + schedule + layout, no lowering) ---
    cases = []
    for n_pairs, D in [(8, 4), (16, 8), (32, 8)]:
        cfg = UViTConfig("b", img_size=8, in_ch=4, patch=2, d_model=32,
                         n_layers=2 * n_pairs, n_heads=4, d_ff=64,
                         n_classes=10)
        cases.append((f"auto_pipeline_plan_uvit{2*n_pairs}b_d{D}",
                      uvit_pipeline_graph(cfg),
                      diffusion_model_fns(cfg, "uvit"), D))
    lcfg = LMConfig(name="b", vocab=64, d_model=32, n_layers=32,
                    attn=AttnConfig(32, 4, 2, 8), d_ff=64)
    cases.append(("auto_pipeline_plan_lm32b_d8",
                  lm_pipeline_graph(lcfg), lm_model_fns(lcfg), 8))

    from repro.runtime.schedule_exec import StepTables

    for name, graph, fns, D in cases:
        t0 = time.perf_counter()
        iters = 5
        for _ in range(iters):
            cp = auto_pipeline(graph, fns, D, pipeline_devices=D,
                               microbatches=2 * D)
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append(f"{name},{us:.0f},makespan={cp.schedule.makespan}")
        # schedule -> step-table lowering cost (host-side, per compile)
        t0 = time.perf_counter()
        for _ in range(iters):
            tabs = StepTables.from_schedule(cp.schedule,
                                            folded=cp.folded)
            cp.schedule.device_programs()
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append(f"{name.replace('_plan_', '_lower_')},{us:.0f},"
                    f"steps={tabs.num_steps}")

    # ---- plan quality: DP partition vs blockwise on heterogeneous UNet --
    for n_pairs, D in [(8, 4), (24, 8)]:
        g0 = make_unet_like(n_pairs, 0)
        import random
        rnd = random.Random(0)
        g = BlockGraph(tuple(
            Block(b.name, rnd.uniform(0.2, 3.0), b.param_bytes, b.act_bytes,
                  b.skip_bytes) for b in g0.blocks), g0.skips)
        t0 = time.perf_counter()
        pulse = partition(g, D, lam=0.0)
        us = (time.perf_counter() - t0) * 1e6
        # same device count as the DP plan: 2D folded stages over D devices
        base = blockwise_partition(g, 2 * D, folded=True, lam=0.0)
        M = 2 * D
        mk_p, _ = simulate(schedule_for_partition(pulse, M),
                           profile_partition(g, pulse).fwd_time_per_sample)
        mk_b, _ = simulate(schedule_for_partition(base, M),
                           profile_partition(g, base).fwd_time_per_sample)
        rows.append(f"auto_pipeline_quality_k{2*n_pairs}_d{D},{us:.0f},"
                    f"sim_speedup={mk_b / mk_p:.3f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
