"""Auto-pipeline compile path: planning cost + plan quality benchmark.

Measures, across graph sizes and device counts, (a) wall-clock of the full
compile path — partition + schedule synthesis + validation + layout — and
(b) the quality gap between the DP partition and the blockwise baseline on
heterogeneous graphs, via the event-driven simulator (modelled makespan).

CSV rows: ``name,us_per_call,derived`` (harness contract; derived is the
baseline/pulse simulated-makespan ratio for the quality rows).
"""
from __future__ import annotations

import time

# HLO measurement for the asymmetric folds: compile the lowered table
# executor's grad on 4 forced host devices and sum collective-permute
# bytes, per graph and per wire format (bf16 default vs the fp32 escape
# hatch — the wire halves every boundary hop, fwd and transposed bwd).
# The spec (config + wire dtype) arrives as a JSON argv.
_HLO_SCRIPT = r"""
import json, os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
spec = json.loads(sys.argv[1])
import jax
from repro.models.diffusion import SkipViTConfig, skipvit_pipeline_graph
from repro.runtime.adapters import skipvit_model_fns, make_diffusion_microbatches
from repro.runtime.compile import auto_pipeline
from repro.runtime.hlo_analysis import collective_bytes

cfg = SkipViTConfig("b", n_enc=spec["n_enc"], n_mid=spec["n_mid"],
                    n_dec=spec["n_dec"],
                    skip_pairs=(tuple(map(tuple, spec["skip_pairs"]))
                                if spec["skip_pairs"] else None))
g = skipvit_pipeline_graph(cfg, fwd_times=spec["fwd_times"])
cp = auto_pipeline(g, skipvit_model_fns(cfg), 2, pipeline_devices=2,
                   microbatches=4, lam=0.0, dp_size=2,
                   wire_dtype=spec["wire"])
mesh = jax.make_mesh((2, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
params = cp.model_fns.init_fn(key)
state = cp.split_params(params)
B, M = 8, 4
batch = {"latents": jax.random.normal(key, (B, 8, 8, 4)),
         "labels": jax.random.randint(key, (B,), 0, 10)}
mb, aux = make_diffusion_microbatches(batch, key, M, cfg, "uvit")
loss = cp.bind(mesh)
# parse the LOWERED module: the CPU backend's float-normalization pass
# upcasts sub-fp32 collectives (a host-simulation artifact real TPU/GPU
# collectives do not pay), so compiled.as_text() hides the wire format
low = jax.jit(jax.grad(loss)).lower(state, mb, aux)
st = collective_bytes(low.as_text())
cpb = st.bytes_by_kind.get("collective-permute", 0)
tabs = cp.step_tables()
print("RESULT", json.dumps({
    "collective_permute_bytes": cpb,
    "W_down": tabs.W_down, "W_up": tabs.W_up,
    "W_turn": tabs.W_turn, "W_skip": tabs.W_skip,
    "live_hops": sum(tabs.live_hops), "dense_hops": tabs.dense_hops}))
"""


# Measured wall-clock makespan of the lowered table executor, overlap on
# vs off (PipelineConfig.overlap — double-buffered ring hops vs the
# synchronous reference lowering), on the same 4 forced host devices the
# HLO probe uses.  Both modes are timed in ONE subprocess so they share
# the process/jit environment, and the ``reps`` post-warmup steps
# alternate on/off so slow drift (allocator growth, thermal, background
# load) cancels instead of landing entirely on whichever mode ran first;
# the per-mode median plus the on/off ratio is reported (lower is better
# for all three, but wall clock on shared runners is noisy — the
# --compare gate gives these rows a loose jitter-aware tolerance).
_TIMING_SCRIPT = r"""
import json, os, sys, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
spec = json.loads(sys.argv[1])
import jax
from repro.models.diffusion import SkipViTConfig, skipvit_pipeline_graph
from repro.runtime.adapters import skipvit_model_fns, make_diffusion_microbatches
from repro.runtime.compile import auto_pipeline

cfg = SkipViTConfig("b", n_enc=spec["n_enc"], n_mid=spec["n_mid"],
                    n_dec=spec["n_dec"],
                    skip_pairs=(tuple(map(tuple, spec["skip_pairs"]))
                                if spec["skip_pairs"] else None))
g = skipvit_pipeline_graph(cfg, fwd_times=spec["fwd_times"])
mesh = jax.make_mesh((2, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
B, M = 8, 4
bench = {}
for mode in (True, False):
    cp = auto_pipeline(g, skipvit_model_fns(cfg), 2, pipeline_devices=2,
                       microbatches=M, lam=0.0, dp_size=2, overlap=mode)
    params = cp.model_fns.init_fn(key)
    state = cp.split_params(params)
    batch = {"latents": jax.random.normal(key, (B, 8, 8, 4)),
             "labels": jax.random.randint(key, (B,), 0, 10)}
    mb, aux = make_diffusion_microbatches(batch, key, M, cfg, "uvit")
    step = jax.jit(jax.value_and_grad(cp.bind(mesh)))
    jax.block_until_ready(step(state, mb, aux))   # compile + warm up
    bench[mode] = (step, state, mb, aux)
ts = {True: [], False: []}
for _ in range(spec["reps"]):
    for mode in (True, False):
        step, state, mb, aux = bench[mode]
        t0 = time.perf_counter()
        jax.block_until_ready(step(state, mb, aux))
        ts[mode].append(time.perf_counter() - t0)
out = {}
for mode in (True, False):
    v = sorted(ts[mode])
    out["overlap_on_us" if mode else "overlap_off_us"] = \
        round(v[len(v) // 2] * 1e6, 1)
out["overlap_ratio"] = round(
    out["overlap_on_us"] / max(out["overlap_off_us"], 1e-9), 4)
print("RESULT", json.dumps(out))
"""


def _measure_timing(scfg, times, reps=20):
    """Run _TIMING_SCRIPT in a subprocess (parent stays single-device)."""
    import json as _json
    import os as _os
    import subprocess
    import sys as _sys
    spec = {"n_enc": scfg.n_enc, "n_mid": scfg.n_mid, "n_dec": scfg.n_dec,
            "skip_pairs": ([list(p) for p in scfg.skip_pairs]
                           if scfg.skip_pairs else None),
            "fwd_times": times, "reps": reps}
    proc = subprocess.run(
        [_sys.executable, "-c", _TIMING_SCRIPT, _json.dumps(spec)],
        capture_output=True, text=True, timeout=600,
        env={**_os.environ,
             "PYTHONPATH": "src:" + _os.environ.get("PYTHONPATH", "")})
    if proc.returncode != 0:
        err = (proc.stderr.strip().splitlines() or ["unknown"])[-1][:100]
        raise RuntimeError(err)
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return _json.loads(line[len("RESULT "):])
    raise RuntimeError("no RESULT line in timing probe output")


def _measure_hlo(scfg, times, wire):
    """Run _HLO_SCRIPT in a subprocess (keeps the parent single-device)."""
    import json as _json
    import os as _os
    import subprocess
    import sys as _sys
    spec = {"n_enc": scfg.n_enc, "n_mid": scfg.n_mid, "n_dec": scfg.n_dec,
            "skip_pairs": ([list(p) for p in scfg.skip_pairs]
                           if scfg.skip_pairs else None),
            "fwd_times": times, "wire": wire}
    proc = subprocess.run(
        [_sys.executable, "-c", _HLO_SCRIPT, _json.dumps(spec)],
        capture_output=True, text=True, timeout=600,
        env={**_os.environ,
             "PYTHONPATH": "src:" + _os.environ.get("PYTHONPATH", "")})
    if proc.returncode != 0:
        err = (proc.stderr.strip().splitlines() or ["unknown"])[-1][:100]
        raise RuntimeError(err)
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return _json.loads(line[len("RESULT "):])
    raise RuntimeError("no RESULT line in HLO probe output")


def run(json_sink: dict | None = None):
    """CSV rows; ``json_sink`` (optional dict) additionally collects the
    machine-readable perf baseline ``benchmarks/run.py`` writes to
    ``BENCH_auto_pipeline.json`` (bubble fraction, simulated makespan and
    HLO collective-permute bytes per config) so future PRs can regress
    against it."""
    from repro.core.graph import Block, BlockGraph, make_unet_like
    from repro.core.partition import blockwise_partition, partition
    from repro.core.schedule import schedule_for_partition, simulate
    from repro.core.tuner import profile_partition
    from repro.models.diffusion import UViTConfig, uvit_pipeline_graph
    from repro.models.lm import LMConfig, lm_pipeline_graph
    from repro.models.layers import AttnConfig
    from repro.runtime.adapters import diffusion_model_fns, lm_model_fns
    from repro.runtime.compile import auto_pipeline

    rows = []
    if json_sink is None:
        json_sink = {}

    # ---- compile-path latency (plan + schedule + layout, no lowering) ---
    cases = []
    for n_pairs, D in [(8, 4), (16, 8), (32, 8)]:
        cfg = UViTConfig("b", img_size=8, in_ch=4, patch=2, d_model=32,
                         n_layers=2 * n_pairs, n_heads=4, d_ff=64,
                         n_classes=10)
        cases.append((f"auto_pipeline_plan_uvit{2*n_pairs}b_d{D}",
                      uvit_pipeline_graph(cfg),
                      diffusion_model_fns(cfg, "uvit"), D))
    lcfg = LMConfig(name="b", vocab=64, d_model=32, n_layers=32,
                    attn=AttnConfig(32, 4, 2, 8), d_ff=64)
    cases.append(("auto_pipeline_plan_lm32b_d8",
                  lm_pipeline_graph(lcfg), lm_model_fns(lcfg), 8))

    from repro.runtime.schedule_exec import StepTables

    for name, graph, fns, D in cases:
        t0 = time.perf_counter()
        iters = 5
        for _ in range(iters):
            cp = auto_pipeline(graph, fns, D, pipeline_devices=D,
                               microbatches=2 * D)
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append(f"{name},{us:.0f},makespan={cp.schedule.makespan}")
        # schedule -> step-table lowering cost (host-side, per compile)
        t0 = time.perf_counter()
        for _ in range(iters):
            tabs = StepTables.from_schedule(cp.schedule,
                                            folded=cp.folded)
            cp.schedule.device_programs()
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append(f"{name.replace('_plan_', '_lower_')},{us:.0f},"
                    f"steps={tabs.num_steps}")

    # ---- asymmetric folds: the shapes the layout used to reject ---------
    # partition objective + simulated makespan + compile latency vs the
    # blockwise folded baseline, plus HLO-measured collective-permute
    # bytes of the lowered executor (skip-communication-savings tracking)
    from repro.core.comm_model import partition_comm_volume
    from repro.models.diffusion import SkipViTConfig, skipvit_pipeline_graph
    from repro.runtime.adapters import skipvit_model_fns

    asym_cases = [
        ("asym_unet3x2_d2",
         SkipViTConfig("b", n_enc=3, n_mid=2, n_dec=3),
         [1, 1, 4, 0.5, 0.5, 0.5, 1, 1], 2),
        ("asym_sparse_d2",
         SkipViTConfig("b", n_enc=3, n_mid=2, n_dec=3,
                       skip_pairs=((0, 7), (2, 5))),
         [1, 1, 4, 0.5, 0.5, 0.5, 1, 1], 2),
        ("asym_unet6x3_d2",
         SkipViTConfig("b", n_enc=6, n_mid=3, n_dec=6),
         [1, 1, 1, 2, 2, 5, 0.5, 0.5, 0.5, 1, 1, 2, 2, 1, 1], 2),
    ]
    for name, scfg, times, D in asym_cases:
        g = skipvit_pipeline_graph(scfg, fwd_times=times)
        fns = skipvit_model_fns(scfg)
        t0 = time.perf_counter()
        cp = auto_pipeline(g, fns, D, pipeline_devices=D,
                           microbatches=2 * D, lam=0.0)
        us = (time.perf_counter() - t0) * 1e6
        part = cp.partition
        base = blockwise_partition(g, 2 * D, folded=True, lam=0.0)
        M = 2 * D
        mk_p, _ = simulate(cp.schedule,
                           profile_partition(g, part).fwd_time_per_sample)
        mk_b, _ = simulate(schedule_for_partition(base, M),
                           profile_partition(g, base).fwd_time_per_sample)
        rows.append(f"auto_pipeline_{name}_plan,{us:.0f},"
                    f"objective={part.objective:.3f}"
                    f"_vs_blockwise={base.objective:.3f}"
                    f"_sim_speedup={mk_b / mk_p:.3f}"
                    f"_mirror={int(part.mirror_symmetric())}")
        # comm volume vs the paper's *sequential* blockwise 1F1B baseline
        # (skips stacked into the boundary payload, relayed hop-by-hop) at
        # D=4, where the relaying actually crosses devices
        part4 = partition(g, 4, lam=0.0)
        base4 = blockwise_partition(g, 4, folded=False, lam=0.0)
        v_p = partition_comm_volume(g, part4)
        v_b = partition_comm_volume(g, base4)
        rows.append(
            f"auto_pipeline_{name}_comm_d4,{v_p.fwd_total:.0f},"
            f"seq1f1b={v_b.fwd_total:.0f}"
            f"_skip_share={100 * v_b.skip_bytes / max(v_b.fwd_total, 1):.0f}%")

    # HLO-measured collective-permute bytes per graph + wire format
    # (subprocess keeps the parent single-device; cf.
    # tests/helpers/comm_volume_hlo.py).  The first case is additionally
    # measured at the fp32-wire escape hatch — the committed regression
    # anchor for the wire-format saving.
    hlo_json: dict = {}
    for i, (name, scfg, times, D) in enumerate(asym_cases):
        wires = ("bfloat16", "float32") if i == 0 else ("bfloat16",)
        for wire in wires:
            try:
                res = _measure_hlo(scfg, times, wire)
            except Exception as e:  # noqa: BLE001
                rows.append(f"auto_pipeline_hlo_{name}_{wire},0,"
                            f"ERROR={str(e)[:80]}")
                continue
            cpb = res["collective_permute_bytes"]
            hlo_json.setdefault(name, {})[wire] = cpb
            rows.append(
                f"auto_pipeline_hlo_{name}_{wire},{cpb},"
                f"live_hops={res['live_hops']}/{res['dense_hops']}"
                f"_W=({res['W_down']},{res['W_up']},{res['W_turn']},"
                f"{res['W_skip']})")
    json_sink["hlo"] = hlo_json
    anchor = asym_cases[0][0]
    if anchor in hlo_json and "bfloat16" in hlo_json[anchor]:
        # legacy top-level key: the tier-1 wave differential config's
        # measured bytes (seed baseline 9216 at fp32 every-hop wire)
        json_sink["hlo_collective_permute_bytes"] = \
            hlo_json[anchor]["bfloat16"]

    # measured wall-clock makespan, overlap on vs off, for the tier-1
    # wave config (asym_unet3x2_d2) — the end-to-end number the overlap
    # lowering is supposed to move; on the host-CPU simulation backend
    # the hop latency is small so the ratio mostly documents "does not
    # regress" rather than the full TPU/GPU-wire win
    name, scfg, times, _D = asym_cases[0]
    measured: dict = {}
    try:
        res = _measure_timing(scfg, times)
    except Exception as e:  # noqa: BLE001
        rows.append(f"auto_pipeline_measured_{name},0,ERROR={str(e)[:80]}")
    else:
        measured[name] = res
        rows.append(
            f"auto_pipeline_measured_{name},{res['overlap_on_us']:.0f},"
            f"overlap_off_us={res['overlap_off_us']:.0f}"
            f"_ratio={res['overlap_ratio']:.3f}")
    json_sink["measured"] = measured

    # ---- interleaved (virtual-stage) schedules: V = 1 / 2 / 4 -----------
    # Bubble fraction + simulated makespan of the synthesized schedule on
    # the heterogeneous SDv2-UNet / SkipViT / Hunyuan-DiT graphs: the
    # interleaved region of the plan space the S == 2D layout gate used to
    # reject.  V=1 is the 2D fold baseline; the derived field records the
    # bubble shrink (or the honest granularity loss where S does not
    # divide the block count, e.g. the 29-block SDv2 graph at V=2).
    import random as _random
    from repro.configs import hunyuan_dit, sdv2_unet
    from repro.core.hw import TPU_V5E
    from repro.core.tuner import tune
    from repro.models.diffusion import (SkipViTConfig, skipvit_pipeline_graph,
                                        unet_block_graph)

    _rnd = _random.Random(0)
    il_cases = [
        ("sdv2unet29", unet_block_graph(sdv2_unet.CFG, batch=1), 4),
        ("skipvit26", skipvit_pipeline_graph(
            SkipViTConfig("b", n_enc=12, n_mid=2, n_dec=12),
            fwd_times=[_rnd.uniform(0.5, 3.0) for _ in range(26)]), 4),
        ("hunyuan32", hunyuan_dit.pipeline_graph(), 4),
    ]
    il_json: dict = {}
    for name, g, D in il_cases:
        M = 2 * D
        per_v: dict = {}
        for Vdeg in (1, 2, 4):
            if 2 * Vdeg * D > g.n:
                continue
            t0 = time.perf_counter()
            try:
                part = partition(g, D, lam=0.0, interleave=Vdeg)
                sched = schedule_for_partition(part, M)
            except ValueError:
                continue
            us = (time.perf_counter() - t0) * 1e6
            prof = profile_partition(g, part)
            mk, bub = simulate(sched, prof.fwd_time_per_sample,
                               bwd_ratio=2.0)
            per_v[f"v{Vdeg}"] = {"bubble": round(bub, 4),
                                 "sim_makespan": mk,
                                 "makespan_slots": sched.makespan}
            # schedule-proven buffer liveness: rotating rx / skip stashes
            # sized by the windows instead of [M] / [M, V] dense buffers
            # (rx entries ride the bf16 wire == the graph's act
            # denomination; the dense sizing was fp32)
            from repro.runtime.compile import StageLayout
            tabs = StepTables.from_schedule(sched, folded=part.folded,
                                            devices=part.devices)
            layout = StageLayout.from_partition(part, g)
            m_o = max(prof.out_bytes_per_sample)
            rx_entries = tabs.W_down + tabs.W_up
            per_v[f"v{Vdeg}"].update({
                "rx_entries": rx_entries,
                "skip_entries": tabs.W_skip,
                "rx_buffer_bytes": rx_entries * m_o,
                "dense_rx_buffer_bytes": 2 * M * m_o * 2,
                "skip_buffer_bytes": tabs.W_skip * layout.enc_pad * m_o,
                "dense_skip_buffer_bytes":
                    M * tabs.V * layout.enc_pad * m_o,
            })
            rows.append(
                f"auto_pipeline_interleave_{name}_d{D}_v{Vdeg},{us:.0f},"
                f"bubble={bub:.3f}_vs_fold="
                f"{per_v.get('v1', {}).get('bubble', bub):.3f}"
                f"_sim_makespan={mk:.4g}")
            rows.append(
                f"auto_pipeline_buffers_{name}_d{D}_v{Vdeg},"
                f"{rx_entries * m_o + tabs.W_skip * layout.enc_pad * m_o:.0f},"
                f"rx_W={rx_entries}_of_{2 * M}"
                f"_skip_W={tabs.W_skip}_of_{M * tabs.V}"
                f"_live_hops={sum(tabs.live_hops)}_of_{tabs.dense_hops}")
        il_json[name] = per_v
    json_sink["interleave"] = il_json

    # the hybrid tuner searches V as an axis (simulation scoring, default
    # TPU v5e memory budget): record the degree it picks for Hunyuan-DiT
    t0 = time.perf_counter()
    il_choices = tune(hunyuan_dit.pipeline_graph(), 4, hw=TPU_V5E,
                      use_simulation=True, interleave_options=(1, 2, 4))
    us = (time.perf_counter() - t0) * 1e6
    if il_choices:
        best = il_choices[0]
        rows.append(f"auto_pipeline_interleave_tuner_hunyuan32_n4,{us:.0f},"
                    f"chose_P={best.P}_V={best.V}_b={best.b}"
                    f"_t_sample={best.t_sample:.3e}")
        json_sink["tuner"] = {"graph": "hunyuan32", "N": 4, "P": best.P,
                              "V": best.V, "b": best.b,
                              "t_sample": best.t_sample}

    # ---- plan quality: DP partition vs blockwise on heterogeneous UNet --
    for n_pairs, D in [(8, 4), (24, 8)]:
        g0 = make_unet_like(n_pairs, 0)
        import random
        rnd = random.Random(0)
        g = BlockGraph(tuple(
            Block(b.name, rnd.uniform(0.2, 3.0), b.param_bytes, b.act_bytes,
                  b.skip_bytes) for b in g0.blocks), g0.skips)
        t0 = time.perf_counter()
        pulse = partition(g, D, lam=0.0)
        us = (time.perf_counter() - t0) * 1e6
        # same device count as the DP plan: 2D folded stages over D devices
        base = blockwise_partition(g, 2 * D, folded=True, lam=0.0)
        M = 2 * D
        mk_p, _ = simulate(schedule_for_partition(pulse, M),
                           profile_partition(g, pulse).fwd_time_per_sample)
        mk_b, _ = simulate(schedule_for_partition(base, M),
                           profile_partition(g, base).fwd_time_per_sample)
        rows.append(f"auto_pipeline_quality_k{2*n_pairs}_d{D},{us:.0f},"
                    f"sim_speedup={mk_b / mk_p:.3f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
