"""Auto-pipeline compile path: planning cost + plan quality benchmark.

Measures, across graph sizes and device counts, (a) wall-clock of the full
compile path — partition + schedule synthesis + validation + layout — and
(b) the quality gap between the DP partition and the blockwise baseline on
heterogeneous graphs, via the event-driven simulator (modelled makespan).

CSV rows: ``name,us_per_call,derived`` (harness contract; derived is the
baseline/pulse simulated-makespan ratio for the quality rows).
"""
from __future__ import annotations

import time

# HLO measurement for the asymmetric fold: compile the lowered table
# executor's grad on 4 forced host devices and sum collective-permute
# bytes (the paper's skip-savings claim, measured on a newly runnable
# shape).  Analytic expectation: boundary-only traffic, zero skip bytes.
_ASYM_HLO_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro.models.diffusion import SkipViTConfig, skipvit_pipeline_graph
from repro.runtime.adapters import skipvit_model_fns, make_diffusion_microbatches
from repro.runtime.compile import auto_pipeline
from repro.runtime.hlo_analysis import collective_bytes
from repro.core.comm_model import partition_comm_volume

cfg = SkipViTConfig("b", n_enc=3, n_mid=2, n_dec=3)
g = skipvit_pipeline_graph(cfg, fwd_times=[1, 1, 4, .5, .5, .5, 1, 1])
cp = auto_pipeline(g, skipvit_model_fns(cfg), 2, pipeline_devices=2,
                   microbatches=4, lam=0.0, dp_size=2)
mesh = jax.make_mesh((2, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
params = cp.model_fns.init_fn(key)
state = cp.split_params(params)
B, M = 8, 4
batch = {"latents": jax.random.normal(key, (B, 8, 8, 4)),
         "labels": jax.random.randint(key, (B,), 0, 10)}
mb, aux = make_diffusion_microbatches(batch, key, M, cfg, "uvit")
loss = cp.bind(mesh)
comp = jax.jit(jax.grad(loss)).lower(state, mb, aux).compile()
st = collective_bytes(comp.as_text())
cpb = st.bytes_by_kind.get("collective-permute", 0)
v_p = partition_comm_volume(g, cp.partition)
print(f"auto_pipeline_asym_hlo_cp_bytes,{cpb},"
      f"analytic_boundary_fwd={v_p.boundary_bytes:.0f}_skip=0")
"""


def run(json_sink: dict | None = None):
    """CSV rows; ``json_sink`` (optional dict) additionally collects the
    machine-readable perf baseline ``benchmarks/run.py`` writes to
    ``BENCH_auto_pipeline.json`` (bubble fraction, simulated makespan and
    HLO collective-permute bytes per config) so future PRs can regress
    against it."""
    from repro.core.graph import Block, BlockGraph, make_unet_like
    from repro.core.partition import blockwise_partition, partition
    from repro.core.schedule import schedule_for_partition, simulate
    from repro.core.tuner import profile_partition
    from repro.models.diffusion import UViTConfig, uvit_pipeline_graph
    from repro.models.lm import LMConfig, lm_pipeline_graph
    from repro.models.layers import AttnConfig
    from repro.runtime.adapters import diffusion_model_fns, lm_model_fns
    from repro.runtime.compile import auto_pipeline

    rows = []
    if json_sink is None:
        json_sink = {}

    # ---- compile-path latency (plan + schedule + layout, no lowering) ---
    cases = []
    for n_pairs, D in [(8, 4), (16, 8), (32, 8)]:
        cfg = UViTConfig("b", img_size=8, in_ch=4, patch=2, d_model=32,
                         n_layers=2 * n_pairs, n_heads=4, d_ff=64,
                         n_classes=10)
        cases.append((f"auto_pipeline_plan_uvit{2*n_pairs}b_d{D}",
                      uvit_pipeline_graph(cfg),
                      diffusion_model_fns(cfg, "uvit"), D))
    lcfg = LMConfig(name="b", vocab=64, d_model=32, n_layers=32,
                    attn=AttnConfig(32, 4, 2, 8), d_ff=64)
    cases.append(("auto_pipeline_plan_lm32b_d8",
                  lm_pipeline_graph(lcfg), lm_model_fns(lcfg), 8))

    from repro.runtime.schedule_exec import StepTables

    for name, graph, fns, D in cases:
        t0 = time.perf_counter()
        iters = 5
        for _ in range(iters):
            cp = auto_pipeline(graph, fns, D, pipeline_devices=D,
                               microbatches=2 * D)
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append(f"{name},{us:.0f},makespan={cp.schedule.makespan}")
        # schedule -> step-table lowering cost (host-side, per compile)
        t0 = time.perf_counter()
        for _ in range(iters):
            tabs = StepTables.from_schedule(cp.schedule,
                                            folded=cp.folded)
            cp.schedule.device_programs()
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append(f"{name.replace('_plan_', '_lower_')},{us:.0f},"
                    f"steps={tabs.num_steps}")

    # ---- asymmetric folds: the shapes the layout used to reject ---------
    # partition objective + simulated makespan + compile latency vs the
    # blockwise folded baseline, plus HLO-measured collective-permute
    # bytes of the lowered executor (skip-communication-savings tracking)
    from repro.core.comm_model import partition_comm_volume
    from repro.models.diffusion import SkipViTConfig, skipvit_pipeline_graph
    from repro.runtime.adapters import skipvit_model_fns

    asym_cases = [
        ("asym_unet3x2_d2",
         SkipViTConfig("b", n_enc=3, n_mid=2, n_dec=3),
         [1, 1, 4, 0.5, 0.5, 0.5, 1, 1], 2),
        ("asym_sparse_d2",
         SkipViTConfig("b", n_enc=3, n_mid=2, n_dec=3,
                       skip_pairs=((0, 7), (2, 5))),
         [1, 1, 4, 0.5, 0.5, 0.5, 1, 1], 2),
        ("asym_unet6x3_d2",
         SkipViTConfig("b", n_enc=6, n_mid=3, n_dec=6),
         [1, 1, 1, 2, 2, 5, 0.5, 0.5, 0.5, 1, 1, 2, 2, 1, 1], 2),
    ]
    for name, scfg, times, D in asym_cases:
        g = skipvit_pipeline_graph(scfg, fwd_times=times)
        fns = skipvit_model_fns(scfg)
        t0 = time.perf_counter()
        cp = auto_pipeline(g, fns, D, pipeline_devices=D,
                           microbatches=2 * D, lam=0.0)
        us = (time.perf_counter() - t0) * 1e6
        part = cp.partition
        base = blockwise_partition(g, 2 * D, folded=True, lam=0.0)
        M = 2 * D
        mk_p, _ = simulate(cp.schedule,
                           profile_partition(g, part).fwd_time_per_sample)
        mk_b, _ = simulate(schedule_for_partition(base, M),
                           profile_partition(g, base).fwd_time_per_sample)
        rows.append(f"auto_pipeline_{name}_plan,{us:.0f},"
                    f"objective={part.objective:.3f}"
                    f"_vs_blockwise={base.objective:.3f}"
                    f"_sim_speedup={mk_b / mk_p:.3f}"
                    f"_mirror={int(part.mirror_symmetric())}")
        # comm volume vs the paper's *sequential* blockwise 1F1B baseline
        # (skips stacked into the boundary payload, relayed hop-by-hop) at
        # D=4, where the relaying actually crosses devices
        part4 = partition(g, 4, lam=0.0)
        base4 = blockwise_partition(g, 4, folded=False, lam=0.0)
        v_p = partition_comm_volume(g, part4)
        v_b = partition_comm_volume(g, base4)
        rows.append(
            f"auto_pipeline_{name}_comm_d4,{v_p.fwd_total:.0f},"
            f"seq1f1b={v_b.fwd_total:.0f}"
            f"_skip_share={100 * v_b.skip_bytes / max(v_b.fwd_total, 1):.0f}%")

    # HLO-measured cross-check on the first asym case (subprocess keeps the
    # parent single-device; cf. tests/helpers/comm_volume_hlo.py)
    import subprocess
    import sys as _sys
    hlo = subprocess.run(
        [_sys.executable, "-c", _ASYM_HLO_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ,
             "PYTHONPATH": "src:" + __import__("os").environ.get(
                 "PYTHONPATH", "")})
    if hlo.returncode == 0:
        hlo_row = hlo.stdout.strip().splitlines()[-1]
        rows.append(hlo_row)
        try:
            json_sink["hlo_collective_permute_bytes"] = int(
                hlo_row.split(",")[1])
        except (IndexError, ValueError):
            pass
    else:
        rows.append("auto_pipeline_asym_hlo_cp_bytes,0,"
                    f"ERROR={hlo.stderr.strip().splitlines()[-1][:80] if hlo.stderr.strip() else 'unknown'}")

    # ---- interleaved (virtual-stage) schedules: V = 1 / 2 / 4 -----------
    # Bubble fraction + simulated makespan of the synthesized schedule on
    # the heterogeneous SDv2-UNet / SkipViT / Hunyuan-DiT graphs: the
    # interleaved region of the plan space the S == 2D layout gate used to
    # reject.  V=1 is the 2D fold baseline; the derived field records the
    # bubble shrink (or the honest granularity loss where S does not
    # divide the block count, e.g. the 29-block SDv2 graph at V=2).
    import random as _random
    from repro.configs import hunyuan_dit, sdv2_unet
    from repro.core.hw import TPU_V5E
    from repro.core.tuner import tune
    from repro.models.diffusion import (SkipViTConfig, skipvit_pipeline_graph,
                                        unet_block_graph)

    _rnd = _random.Random(0)
    il_cases = [
        ("sdv2unet29", unet_block_graph(sdv2_unet.CFG, batch=1), 4),
        ("skipvit26", skipvit_pipeline_graph(
            SkipViTConfig("b", n_enc=12, n_mid=2, n_dec=12),
            fwd_times=[_rnd.uniform(0.5, 3.0) for _ in range(26)]), 4),
        ("hunyuan32", hunyuan_dit.pipeline_graph(), 4),
    ]
    il_json: dict = {}
    for name, g, D in il_cases:
        M = 2 * D
        per_v: dict = {}
        for Vdeg in (1, 2, 4):
            if 2 * Vdeg * D > g.n:
                continue
            t0 = time.perf_counter()
            try:
                part = partition(g, D, lam=0.0, interleave=Vdeg)
                sched = schedule_for_partition(part, M)
            except ValueError:
                continue
            us = (time.perf_counter() - t0) * 1e6
            prof = profile_partition(g, part)
            mk, bub = simulate(sched, prof.fwd_time_per_sample,
                               bwd_ratio=2.0)
            per_v[f"v{Vdeg}"] = {"bubble": round(bub, 4),
                                 "sim_makespan": mk,
                                 "makespan_slots": sched.makespan}
            base = per_v.get("v1", {}).get("bubble", bub)
            rows.append(
                f"auto_pipeline_interleave_{name}_d{D}_v{Vdeg},{us:.0f},"
                f"bubble={bub:.3f}_vs_fold={base:.3f}"
                f"_sim_makespan={mk:.4g}")
        il_json[name] = per_v
    json_sink["interleave"] = il_json

    # the hybrid tuner searches V as an axis (simulation scoring, default
    # TPU v5e memory budget): record the degree it picks for Hunyuan-DiT
    t0 = time.perf_counter()
    il_choices = tune(hunyuan_dit.pipeline_graph(), 4, hw=TPU_V5E,
                      use_simulation=True, interleave_options=(1, 2, 4))
    us = (time.perf_counter() - t0) * 1e6
    if il_choices:
        best = il_choices[0]
        rows.append(f"auto_pipeline_interleave_tuner_hunyuan32_n4,{us:.0f},"
                    f"chose_P={best.P}_V={best.V}_b={best.b}"
                    f"_t_sample={best.t_sample:.3e}")
        json_sink["tuner"] = {"graph": "hunyuan32", "N": 4, "P": best.P,
                              "V": best.V, "b": best.b,
                              "t_sample": best.t_sample}

    # ---- plan quality: DP partition vs blockwise on heterogeneous UNet --
    for n_pairs, D in [(8, 4), (24, 8)]:
        g0 = make_unet_like(n_pairs, 0)
        import random
        rnd = random.Random(0)
        g = BlockGraph(tuple(
            Block(b.name, rnd.uniform(0.2, 3.0), b.param_bytes, b.act_bytes,
                  b.skip_bytes) for b in g0.blocks), g0.skips)
        t0 = time.perf_counter()
        pulse = partition(g, D, lam=0.0)
        us = (time.perf_counter() - t0) * 1e6
        # same device count as the DP plan: 2D folded stages over D devices
        base = blockwise_partition(g, 2 * D, folded=True, lam=0.0)
        M = 2 * D
        mk_p, _ = simulate(schedule_for_partition(pulse, M),
                           profile_partition(g, pulse).fwd_time_per_sample)
        mk_b, _ = simulate(schedule_for_partition(base, M),
                           profile_partition(g, base).fwd_time_per_sample)
        rows.append(f"auto_pipeline_quality_k{2*n_pairs}_d{D},{us:.0f},"
                    f"sim_speedup={mk_b / mk_p:.3f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
