"""Paper Figs. 8 & 9: schedule synthesis (ILP at small scale + templates).

Reports makespans and bubble ratios; the ILP is solved at the paper's small
configuration (4 devices) and must match the replicated template.
"""
from __future__ import annotations

import time

from repro.core.schedule import (template_1f1b, template_wave,
                                 template_interleaved, ilp_schedule,
                                 validate_schedule, simulate)


def run() -> list[str]:
    rows = []
    s = template_1f1b(4, 4)
    assert not validate_schedule(s, lambda st: st)
    rows.append(f"schedule.1f1b_d4_m4.makespan_steps,{s.makespan},"
                f"bubble={s.bubble_ratio():.3f}")
    w = template_wave(4, 4)
    rows.append(f"schedule.wave_d4_m4.makespan_steps,{w.makespan},"
                f"bubble={w.bubble_ratio():.3f}")
    mk, bub = simulate(w, [1.0] * 8, bwd_ratio=2.0, p2p_time=0.05)
    rows.append(f"schedule.wave_d4_m4.simulated_time,{mk:.2f},"
                f"bubble={bub:.3f}")
    iw = template_interleaved(4, 4, 2)
    mk_i, bub_i = simulate(iw, [0.5] * 16, bwd_ratio=2.0, p2p_time=0.05)
    rows.append(f"schedule.interleaved_d4_m4_v2.simulated_time,{mk_i:.2f},"
                f"bubble={bub_i:.3f}_fold={bub:.3f}")

    # schedule -> step-table lowering: cold vs memoized (the tuner's
    # candidate loop and repeated auto_pipeline calls hit the cache)
    from repro.core.partition import interleaved_wave_devices
    from repro.runtime.schedule_exec import StepTables
    big = template_interleaved(8, 16, 2)
    devices = interleaved_wave_devices(big.S, 8)
    t0 = time.perf_counter()
    StepTables._build(big, True, lambda st: devices[st])
    cold = (time.perf_counter() - t0) * 1e6
    StepTables.from_schedule(big, folded=True, devices=devices)  # warm it
    t0 = time.perf_counter()
    for _ in range(100):
        StepTables.from_schedule(big, folded=True, devices=devices)
    memo = (time.perf_counter() - t0) / 100 * 1e6
    rows.append(f"schedule.lower_d8_m16_v2.cold_us,{cold:.0f},"
                f"memoized_us={memo:.2f}")
    t0 = time.perf_counter()
    ilp = ilp_schedule(4, 2, 2, device_of_stage=lambda s: min(s, 3 - s),
                       collocated=[(0, 3), (1, 2)])
    dt = time.perf_counter() - t0
    g = template_wave(2, 2)
    rows.append(f"schedule.ilp_s4_d2_m2.makespan_steps,{ilp.makespan},"
                f"solve={dt:.1f}s template={g.makespan}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
