"""Per-assigned-architecture smoke tests: reduced config of the same family
runs one forward/train step on CPU; output finite, shapes sane."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.smoke import SMOKE_FACTORIES
from repro.optim import AdamWConfig, adamw_init, adamw_update

# Large configs take multi-second jits each; tier-1 keeps a light
# cross-family subset and the rest run with `-m slow` / `-m ""`.
_HEAVY = {"deepseek-v3-671b", "sdv2-unet", "hunyuan-dit", "zamba2-2.7b",
          "granite-34b", "xlstm-125m", "whisper-base", "qwen3-moe-30b-a3b",
          "h2o-danube-1.8b", "internlm2-20b", "uvit-h", "internvl2-2b"}
ARCHS = [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY else a
         for a in sorted(SMOKE_FACTORIES)]


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    loss_fn, init_fn, make_batch, _cfg = SMOKE_FACTORIES[arch]()
    key = jax.random.PRNGKey(0)
    params = init_fn(key)
    batch = make_batch(key)
    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch, key)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))
    state = adamw_init(params)
    new_params, state = adamw_update(params, grads, state, AdamWConfig(lr=1e-3))
    # a step must change parameters but keep structure + shapes
    assert jax.tree.structure(new_params) == jax.tree.structure(params)
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert changed
    loss2 = jax.jit(loss_fn)(new_params, batch, key)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_loss_decreases(arch):
    """A few optimizer steps on a FIXED batch must reduce the loss."""
    loss_fn, init_fn, make_batch, _cfg = SMOKE_FACTORIES[arch]()
    key = jax.random.PRNGKey(1)
    params = init_fn(key)
    batch = make_batch(key)
    cfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    state = adamw_init(params)
    step = jax.jit(lambda p, s, b, k: _one(p, s, b, k, loss_fn, cfg))
    first = None
    for i in range(8):
        loss, params, state = step(params, state, batch, key)
        if first is None:
            first = float(loss)
    assert float(loss) < first, f"{arch}: {first} -> {float(loss)}"


def _one(params, state, batch, key, loss_fn, cfg):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, key)
    params, state = adamw_update(params, grads, state, cfg)
    return loss, params, state


def test_bundle_registry_loads():
    from repro.configs import get_arch, list_archs
    for name in list_archs():
        b = get_arch(name)
        assert b.name == name
        assert b.param_count > 0
        for shape, status in b.shape_support.items():
            assert status == "ok" or len(status) > 10   # documented skips


def test_assigned_param_counts_in_range():
    """Sanity: config sizes should be near their nameplates."""
    from repro.configs import get_arch
    expect = {
        "smollm-360m": (0.30e9, 0.45e9),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "internlm2-20b": (17e9, 23e9),
        "granite-34b": (30e9, 38e9),
        "whisper-base": (0.04e9, 0.11e9),
        "xlstm-125m": (0.08e9, 0.20e9),
        "internvl2-2b": (1.5e9, 2.5e9),
        "qwen3-moe-30b-a3b": (26e9, 34e9),
        "deepseek-v3-671b": (600e9, 720e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_arch(name).param_count
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
