"""Auto-pipeline compile path: planning invariants + differential tests.

Planning-layer tests run in-process on one device.  Numerical equivalence
against the single-device reference runs in a subprocess with 8 forced host
devices (tests/helpers/auto_pipeline_equiv.py): the uneven-partition
configs — the capability the hand-written executors lacked — run in tier-1;
the even S=D / S=2D configs are `slow` (they overlap the classic executors
already covered by test_pipeline_multidevice).
"""
import jax
import numpy as np
import pytest

from helpers import run_helper

from repro.core.partition import partition
from repro.core.schedule import schedule_for_partition, validate_schedule
from repro.core.tuner import tune
from repro.models.diffusion import UViTConfig, uvit_pipeline_graph
from repro.models.layers import AttnConfig
from repro.models.lm import LMConfig, lm_pipeline_graph
from repro.runtime.adapters import diffusion_model_fns, lm_model_fns
from repro.runtime.compile import StageLayout, auto_pipeline

def _run_equiv(*configs):
    out = run_helper("auto_pipeline_equiv.py", *configs)
    assert "AUTO PIPELINE EQUIVALENCE: ALL OK" in out
    return out


# ---------------------------------------------------------------------------
# planning layer (fast, single device)
# ---------------------------------------------------------------------------

def _lm_cfg():
    return LMConfig(name="t", vocab=64, d_model=32, n_layers=8,
                    attn=AttnConfig(32, 4, 2, 8), d_ff=64,
                    tied_embeddings=True)


def _uvit_cfg():
    return UViTConfig("t", img_size=8, in_ch=4, patch=2, d_model=32,
                      n_layers=8, n_heads=4, d_ff=64, n_classes=10)


def test_auto_pipeline_schedule_validates():
    """Every lowered plan ships with a schedule that passes all six
    constraint families for its own stage->device mapping."""
    for cp in (
        auto_pipeline(lm_pipeline_graph(_lm_cfg()), lm_model_fns(_lm_cfg()),
                      4, pipeline_devices=4, microbatches=4),
        auto_pipeline(uvit_pipeline_graph(_uvit_cfg()),
                      diffusion_model_fns(_uvit_cfg(), "uvit"),
                      2, pipeline_devices=2, microbatches=4),
    ):
        part = cp.partition
        errs = validate_schedule(cp.schedule, part.device_of_stage,
                                 collocated=part.collocated_pairs())
        assert not errs
        assert cp.schedule.M == cp.pcfg.num_microbatches
        assert cp.schedule.D == part.num_devices


def test_auto_pipeline_uneven_partition_plan():
    cfg = _lm_cfg()
    g = lm_pipeline_graph(cfg, fwd_times=[4, 1, 1, 1, 1, 1, 1, 4])
    cp = auto_pipeline(g, lm_model_fns(cfg), 4, pipeline_devices=4,
                       microbatches=4, lam=0.0)
    assert len(set(cp.layout.counts)) > 1          # genuinely uneven
    assert sum(cp.layout.counts) == g.n
    assert cp.partition.objective <= 4.0 + 1e-9    # balanced around block 0/7


def test_layout_split_merge_roundtrip():
    """split_params -> merge_params is the identity on real parameters,
    including uneven and folded layouts (this is the same path gradients
    take back to model form)."""
    key = jax.random.PRNGKey(0)
    cfg = _lm_cfg()
    cases = [
        auto_pipeline(lm_pipeline_graph(cfg,
                                        fwd_times=[4, 1, 1, 1, 1, 1, 1, 4]),
                      lm_model_fns(cfg), 4, pipeline_devices=4,
                      microbatches=4, lam=0.0),
        auto_pipeline(uvit_pipeline_graph(_uvit_cfg(),
                                          fwd_times=[3, 1, 1, 1, 1, 1, 1, 3]),
                      diffusion_model_fns(_uvit_cfg(), "uvit"), 2,
                      pipeline_devices=2, microbatches=4, lam=0.0),
    ]
    for cp in cases:
        assert len(set(cp.layout.counts)) > 1    # the hard (padded) layouts
        params = cp.model_fns.init_fn(key)
        stacks, edge = cp.split_params(params)
        back = cp.merge_params(stacks, edge)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tuner_choice_carries_partition():
    g = uvit_pipeline_graph(_uvit_cfg())
    choices = tune(g, 4)
    assert choices
    for c in choices:
        assert c.partition is not None
        if c.P > 1:
            assert c.partition.num_devices == c.P


def test_tuner_driven_auto_pipeline():
    """Without a pinned pipeline degree the tuner supplies the plan."""
    g = uvit_pipeline_graph(_uvit_cfg())
    cp = auto_pipeline(g, diffusion_model_fns(_uvit_cfg(), "uvit"), 4,
                       microbatches=4)
    assert cp.choice is not None and cp.choice.P > 1
    assert cp.partition is cp.choice.partition
    assert not validate_schedule(cp.schedule, cp.partition.device_of_stage,
                                 collocated=cp.partition.collocated_pairs())


def test_layout_rejects_asymmetric_fold():
    part = partition(lm_pipeline_graph(_lm_cfg()), 4)  # linear (no skips)
    assert StageLayout.from_partition(part).counts  # linear fine
    import dataclasses
    bad = dataclasses.replace(part, cuts=(0, 1, 2, 5, 8), folded=True)
    with pytest.raises(ValueError):
        StageLayout.from_partition(bad)


def test_schedule_for_partition_greedy_matches_templates():
    g = uvit_pipeline_graph(_uvit_cfg())
    part = partition(g, 2)
    sched = schedule_for_partition(part, 4)
    assert sched.makespan >= 4 * 4       # work bound: 2 stages x (F+B) x M


# ---------------------------------------------------------------------------
# differential executor tests (subprocess, mocked multi-device mesh)
# ---------------------------------------------------------------------------

def test_auto_pipeline_equivalence_uneven():
    """Uneven DP partitions (linear + folded wave) match the single-device
    reference — the configs the hand-written S=D / S=2D executors could
    not run at all."""
    _run_equiv("linear-uneven", "wave-uneven")


@pytest.mark.slow
def test_auto_pipeline_equivalence_even_and_forced_wave():
    """Even S=D / S=2D plans and the skip-free forced-wave (symmetric-fold
    partitioner + empty-skip executor) through the same compile path."""
    _run_equiv("linear-even", "wave-even", "wave-lm-uneven")
