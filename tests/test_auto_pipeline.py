"""Auto-pipeline compile path: planning invariants + differential tests.

Planning-layer tests run in-process on one device.  Numerical equivalence
against the single-device reference (and, differentially, against the
closed-form executors) runs in a subprocess with 8 forced host devices
(tests/helpers/auto_pipeline_equiv.py): the uneven-partition configs — the
capability the hand-written executors lacked — and the M < D config only
the table-driven lowering can run are tier-1; the even S=D / S=2D configs
and the ILP schedule are `slow`.
"""
import dataclasses

import jax
import numpy as np
import pytest

from helpers import run_helper

from repro.core.partition import partition
from repro.core.schedule import schedule_for_partition, validate_schedule
from repro.core.tuner import tune
from repro.models.diffusion import UViTConfig, uvit_pipeline_graph
from repro.models.layers import AttnConfig
from repro.models.lm import LMConfig, lm_pipeline_graph
from repro.runtime.adapters import diffusion_model_fns, lm_model_fns
from repro.runtime.compile import StageLayout, auto_pipeline
from repro.runtime.schedule_exec import StepTables

from helpers.schedule_checks import (assert_programs_match_grid,
                                     assert_step_tables_match_grid)

def _run_equiv(*configs):
    out = run_helper("auto_pipeline_equiv.py", *configs)
    assert "AUTO PIPELINE EQUIVALENCE: ALL OK" in out
    return out


# ---------------------------------------------------------------------------
# planning layer (fast, single device)
# ---------------------------------------------------------------------------

def _lm_cfg():
    return LMConfig(name="t", vocab=64, d_model=32, n_layers=8,
                    attn=AttnConfig(32, 4, 2, 8), d_ff=64,
                    tied_embeddings=True)


def _uvit_cfg():
    return UViTConfig("t", img_size=8, in_ch=4, patch=2, d_model=32,
                      n_layers=8, n_heads=4, d_ff=64, n_classes=10)


def test_auto_pipeline_schedule_validates():
    """Every lowered plan ships with a schedule that passes all six
    constraint families for its own stage->device mapping."""
    for cp in (
        auto_pipeline(lm_pipeline_graph(_lm_cfg()), lm_model_fns(_lm_cfg()),
                      4, pipeline_devices=4, microbatches=4),
        auto_pipeline(uvit_pipeline_graph(_uvit_cfg()),
                      diffusion_model_fns(_uvit_cfg(), "uvit"),
                      2, pipeline_devices=2, microbatches=4),
    ):
        part = cp.partition
        errs = validate_schedule(cp.schedule, part.device_of_stage,
                                 collocated=part.collocated_pairs())
        assert not errs
        assert cp.schedule.M == cp.pcfg.num_microbatches
        assert cp.schedule.D == part.num_devices


def test_auto_pipeline_uneven_partition_plan():
    cfg = _lm_cfg()
    g = lm_pipeline_graph(cfg, fwd_times=[4, 1, 1, 1, 1, 1, 1, 4])
    cp = auto_pipeline(g, lm_model_fns(cfg), 4, pipeline_devices=4,
                       microbatches=4, lam=0.0)
    assert len(set(cp.layout.counts)) > 1          # genuinely uneven
    assert sum(cp.layout.counts) == g.n
    assert cp.partition.objective <= 4.0 + 1e-9    # balanced around block 0/7


def test_layout_split_merge_roundtrip():
    """split_params -> merge_params is the identity on real parameters,
    including uneven and folded layouts (this is the same path gradients
    take back to model form)."""
    key = jax.random.PRNGKey(0)
    cfg = _lm_cfg()
    cases = [
        auto_pipeline(lm_pipeline_graph(cfg,
                                        fwd_times=[4, 1, 1, 1, 1, 1, 1, 4]),
                      lm_model_fns(cfg), 4, pipeline_devices=4,
                      microbatches=4, lam=0.0),
        auto_pipeline(uvit_pipeline_graph(_uvit_cfg(),
                                          fwd_times=[3, 1, 1, 1, 1, 1, 1, 3]),
                      diffusion_model_fns(_uvit_cfg(), "uvit"), 2,
                      pipeline_devices=2, microbatches=4, lam=0.0),
    ]
    for cp in cases:
        assert len(set(cp.layout.counts)) > 1    # the hard (padded) layouts
        params = cp.model_fns.init_fn(key)
        stacks, edge = cp.split_params(params)
        back = cp.merge_params(stacks, edge)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tuner_choice_carries_partition():
    g = uvit_pipeline_graph(_uvit_cfg())
    choices = tune(g, 4)
    assert choices
    for c in choices:
        assert c.partition is not None
        if c.P > 1:
            assert c.partition.num_devices == c.P


def test_tuner_driven_auto_pipeline():
    """Without a pinned pipeline degree the tuner supplies the plan."""
    g = uvit_pipeline_graph(_uvit_cfg())
    cp = auto_pipeline(g, diffusion_model_fns(_uvit_cfg(), "uvit"), 4,
                       microbatches=4)
    assert cp.choice is not None and cp.choice.P > 1
    assert cp.partition is cp.choice.partition
    assert not validate_schedule(cp.schedule, cp.partition.device_of_stage,
                                 collocated=cp.partition.collocated_pairs())


def test_tuner_driven_executes_scored_microbatch_count():
    """The tuner records the M its iteration-time score assumed
    (TunerChoice.M) and auto_pipeline executes exactly that M — previously
    the tuner scored M = P while the executor silently ran M = 2D."""
    g = uvit_pipeline_graph(_uvit_cfg())
    choices = tune(g, 4)
    for c in choices:
        assert c.M == max(c.P, 1)          # Eq. (15)'s closed-form setting
    cp = auto_pipeline(g, diffusion_model_fns(_uvit_cfg(), "uvit"), 4)
    assert cp.choice is not None
    assert cp.pcfg.num_microbatches == cp.choice.M
    assert cp.schedule.M == cp.choice.M


def test_device_programs_match_grid():
    """Schedule.device_programs() agrees with grid() slot-for-slot, and the
    executor-facing StepTables cover exactly the forward placements."""
    for cp in (
        auto_pipeline(lm_pipeline_graph(_lm_cfg()), lm_model_fns(_lm_cfg()),
                      4, pipeline_devices=4, microbatches=4),
        auto_pipeline(uvit_pipeline_graph(_uvit_cfg()),
                      diffusion_model_fns(_uvit_cfg(), "uvit"),
                      2, pipeline_devices=2, microbatches=4),
    ):
        assert_programs_match_grid(cp.schedule)
        tabs = assert_step_tables_match_grid(cp.schedule, cp.folded)
        assert all(p.step in tabs.forward_steps
                   for p in cp.schedule.placements
                   if p.virtual < cp.schedule.S)


def test_step_tables_reject_infeasible_schedule():
    """A schedule whose consumer runs before its input can arrive (or whose
    shape does not fit the executor) raises at lowering, not mid-scan."""
    from repro.core.schedule import Schedule, template_1f1b

    good = template_1f1b(2, 2)
    with pytest.raises(ValueError, match="folded|linear"):
        StepTables.from_schedule(good, folded=True)   # S=D, not S=2D

    # shift microbatch 0's stage-1 F to step 0: before its input exists
    bad_places = tuple(
        dataclasses.replace(p, step=0)
        if (p.virtual, p.microbatch) == (1, 0) else p
        for p in good.placements)
    bad = Schedule(good.S, good.M, good.D, bad_places)
    with pytest.raises(ValueError):
        StepTables.from_schedule(bad, folded=False)

    out_of_range = Schedule(good.S, good.M, good.D, tuple(
        dataclasses.replace(p, device=7)
        if (p.virtual, p.microbatch) == (0, 0) else p
        for p in good.placements))
    with pytest.raises(ValueError, match="validate_schedule"):
        StepTables.from_schedule(out_of_range, folded=False)
    assert any("out of range" in e for e in validate_schedule(out_of_range))

    # a *valid* schedule with a permuted stage->device mapping (what an ILP
    # free-mapping solve can legally return) is not realizable on the
    # executors' canonical stage layout — must raise, not run the wrong
    # stage's parameters silently
    from repro.core.schedule import greedy_schedule
    swapped = greedy_schedule(2, 2, lambda s: 1 - s, 2)
    assert not validate_schedule(swapped, lambda s: 1 - s)
    with pytest.raises(ValueError, match="stage layout"):
        StepTables.from_schedule(swapped, folded=False)


def test_closed_form_wave_rejects_short_iterations():
    """M < D folded plans lower through the table executor; the closed-form
    wave executor must refuse them with an actionable error."""
    cfg = _uvit_cfg()
    cp = auto_pipeline(uvit_pipeline_graph(cfg),
                       diffusion_model_fns(cfg, "uvit"), 4,
                       pipeline_devices=4, microbatches=3)
    assert cp.pcfg.num_microbatches == 3 < cp.pcfg.num_devices
    cp.build()                                        # table path: fine
    with pytest.raises(ValueError, match="M >= D"):
        dataclasses.replace(cp, executor="closed_form").build()
    with pytest.raises(ValueError, match="executor"):
        dataclasses.replace(cp, executor="wat").build()


def _asym_skipvit():
    """make_unet_like(3, 2)-shaped model whose costs force a
    mirror-ASYMMETRIC fold (turnaround cut inside the bottleneck run)."""
    from repro.models.diffusion import SkipViTConfig, skipvit_pipeline_graph
    cfg = SkipViTConfig("t", n_enc=3, n_mid=2, n_dec=3)
    return cfg, skipvit_pipeline_graph(cfg, fwd_times=[1, 1, 4, .5, .5, .5, 1, 1])


def test_layout_accepts_asymmetric_fold():
    """StageLayout.from_partition no longer raises on legal asymmetric
    folds: independent enc/dec counts and the stash pairing come from the
    partition's actual skip edges."""
    from repro.core.graph import make_unet_like
    cfg, g = _asym_skipvit()
    part = partition(g, 2, lam=0.0)
    assert part.folded and not part.mirror_symmetric()
    assert part.validate_collocation(g)
    layout = StageLayout.from_partition(part, g)
    assert layout.V == 1
    assert layout.enc_counts != layout.dec_counts
    assert (sum(c for cs in layout.enc_counts for c in cs)
            + sum(c for cs in layout.dec_counts for c in cs) == g.n)
    # every skip edge resolved to a stash row; skip-less rows are -1
    n_paired = sum(1 for dev in layout.skip_rows for row in dev
                   for r in row if r >= 0)
    assert n_paired == len(g.skips)
    # the synthetic acceptance graph partitions and lays out as well
    g2 = make_unet_like(3, 2)
    part2 = partition(g2, 2, lam=0.0)
    StageLayout.from_partition(part2, g2)


def test_asymmetric_fold_compiles_through_auto_pipeline():
    from repro.runtime.adapters import skipvit_model_fns
    cfg, g = _asym_skipvit()
    cp = auto_pipeline(g, skipvit_model_fns(cfg), 2, pipeline_devices=2,
                       microbatches=4, lam=0.0)
    assert not cp.partition.mirror_symmetric()
    assert not validate_schedule(cp.schedule, cp.partition.device_of_stage,
                                 collocated=cp.partition.collocated_pairs())
    cp.build()                       # lowers without a mirror gate
    # split/merge roundtrip on the asymmetric layout (the gradient path)
    key = jax.random.PRNGKey(0)
    params = cp.model_fns.init_fn(key)
    stacks, edge = cp.split_params(params)
    back = cp.merge_params(stacks, edge)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_layout_rejects_malformed_folds():
    """Genuinely unliftable shapes still raise: non-paired device mappings
    and skip edges that do not cross the fold."""
    import dataclasses as dc
    from repro.core.graph import BlockGraph, SkipEdge
    part = partition(lm_pipeline_graph(_lm_cfg()), 4)  # linear (no skips)
    assert StageLayout.from_partition(part).counts  # linear fine
    # identity device mapping marked folded: no enc/dec stage pairing
    bad = dc.replace(part, cuts=(0, 1, 2, 5, 8), folded=True)
    with pytest.raises(ValueError):
        StageLayout.from_partition(bad)
    # legal asymmetric cuts but a skip whose endpoints sit on one side
    cfg, g = _asym_skipvit()
    good = partition(g, 2, lam=0.0)
    g_bad = BlockGraph(g.blocks, g.skips + (SkipEdge(6, 7, 1),))
    with pytest.raises(ValueError, match="encoder-half|collocation"):
        StageLayout.from_partition(good, g_bad)
    # mirror-asymmetric fold without a graph: no pairing derivable
    with pytest.raises(ValueError, match="graph"):
        StageLayout.from_partition(good)


def test_hunyuan_config_plans_through_auto_pipeline():
    """configs/hunyuan_dit wires the paper's own model through the compile
    path: the full-size config plans, schedules and lays out (planning is
    host-side; the numerical smoke test runs in the subprocess harness)."""
    from repro.configs import hunyuan_dit
    cp = hunyuan_dit.auto_plan(8, pipeline_devices=8, microbatches=8)
    assert cp.folded and cp.partition.num_stages == 16
    assert cp.partition.validate_collocation(cp.graph)
    assert (sum(c for cs in cp.layout.enc_counts for c in cs)
            + sum(c for cs in cp.layout.dec_counts for c in cs) == 32)
    assert not validate_schedule(cp.schedule, cp.partition.device_of_stage,
                                 collocated=cp.partition.collocated_pairs())


def test_auto_pipeline_reports_dropped_plans():
    """When no plan survives, the error lists every candidate and why it
    was dropped (previously a bare 'no feasible, lowerable plan')."""
    # a 2-block skip graph on N=4: P=1 is pure DP, P=2 needs S=4 > 2
    # blocks, P=4 needs S=8 — nothing survives
    from repro.core.graph import Block, BlockGraph, SkipEdge
    g = BlockGraph((Block("a", 1.0, act_bytes=8), Block("b", 1.0)),
                   (SkipEdge(0, 1, 8),))
    cfg = _lm_cfg()
    with pytest.raises(ValueError) as ei:
        auto_pipeline(g, lm_model_fns(cfg), 4)
    msg = str(ei.value)
    assert "P=1" in msg and "P=2" in msg and "P=4" in msg
    assert "pure data parallelism" in msg
    assert "stages" in msg           # S > n explanation present


def test_auto_pipeline_zero_memory_drop_reasons():
    """On a memory-infeasible budget the raised error carries the full
    per-candidate drop list, naming the ZeRO constraint that killed each
    candidate — including that even ZeRO-2 sharding over the dp axis
    could not fit the smallest microbatch."""
    from repro.core.hw import TPU_V5E
    hw = dataclasses.replace(TPU_V5E, mem_limit=float(1 << 10))
    cfg = _lm_cfg()
    with pytest.raises(ValueError) as ei:
        auto_pipeline(lm_pipeline_graph(cfg), lm_model_fns(cfg), 4, hw)
    msg = str(ei.value)
    assert "memory budget" in msg
    assert "even with ZeRO-2 param/optimizer state sharded over dp=" in msg


def test_schedule_for_partition_greedy_matches_templates():
    g = uvit_pipeline_graph(_uvit_cfg())
    part = partition(g, 2)
    sched = schedule_for_partition(part, 4)
    assert sched.makespan >= 4 * 4       # work bound: 2 stages x (F+B) x M


# ---------------------------------------------------------------------------
# interleaved (virtual-stage) plans: V > 1 stage slot pairs per device
# ---------------------------------------------------------------------------

def _interleaved_skipvit(V=2, D=2):
    from repro.models.diffusion import SkipViTConfig, skipvit_pipeline_graph
    cfg = SkipViTConfig("t", n_enc=4, n_mid=2, n_dec=4)
    g = skipvit_pipeline_graph(
        cfg, fwd_times=[1, 1, 2, 4, 0.5, 0.5, 0.5, 1, 1, 2])
    return cfg, g


def test_interleaved_partition_layout_and_schedule():
    """partition(interleave=V) emits S = 2VD stages on the cyclic slot
    placement, keeps skip collocation, and StageLayout carries per-device
    slot lists — the S == 2D gate is gone."""
    cfg, g = _interleaved_skipvit()
    part = partition(g, 2, lam=0.0, interleave=2)
    assert part.folded and part.num_stages == 8 and part.num_devices == 2
    assert part.interleave == 2
    assert part.devices == (0, 1, 0, 1, 1, 0, 1, 0)
    assert part.validate_collocation(g)
    layout = StageLayout.from_partition(part, g)
    assert layout.V == 2
    assert all(len(ss) == 2 for ss in layout.enc_slots)
    assert all(len(ss) == 2 for ss in layout.dec_slots)
    assert (sum(c for cs in layout.enc_counts for c in cs)
            + sum(c for cs in layout.dec_counts for c in cs) == g.n)
    # every skip edge resolves to a flat (slot, row) stash index
    n_paired = sum(1 for dev in layout.skip_rows for row in dev
                   for r in row if r >= 0)
    assert n_paired == len(g.skips)
    assert all(0 <= r < layout.V * layout.enc_pad
               for dev in layout.skip_rows for row in dev for r in row
               if r >= 0)
    sched = schedule_for_partition(part, 4)
    assert not validate_schedule(sched, part.device_of_stage,
                                 collocated=part.collocated_pairs())


def test_interleaved_split_merge_roundtrip():
    """split_params -> merge_params stays the identity on V=2 interleaved
    layouts (the gradient path through [D, V, pad, ...] stacks)."""
    from repro.runtime.adapters import skipvit_model_fns
    cfg, g = _interleaved_skipvit()
    cp = auto_pipeline(g, skipvit_model_fns(cfg), 2, pipeline_devices=2,
                       microbatches=4, lam=0.0, interleave=2)
    assert cp.layout.V == 2
    params = cp.model_fns.init_fn(jax.random.PRNGKey(0))
    stacks, edge = cp.split_params(params)
    assert jax.tree.leaves(stacks[0])[0].shape[:2] == (2, 2)  # [D, V, ...]
    back = cp.merge_params(stacks, edge)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # closed-form executors cannot realize V > 1 slots
    with pytest.raises(ValueError, match="closed-form"):
        dataclasses.replace(cp, executor="closed_form").build()


def test_tuner_scores_interleave_axis():
    """V is a tuner search axis: V > 1 candidates carry their own V-fold
    partition, and drop reasons name the candidate's interleave degree."""
    cfg, g = _interleaved_skipvit()
    choices = tune(g, 4, lam=0.0, interleave_options=(1, 2))
    vs = {c.V for c in choices if c.P > 1}
    assert 1 in vs and 2 in vs
    for c in choices:
        if c.P > 1 and c.V > 1:
            assert c.partition.num_stages == 2 * c.V * c.P
            assert c.partition.interleave == c.V
    # a V too deep for the graph is dropped with its V recorded
    drops: list[str] = []
    tune(g, 4, lam=0.0, interleave_options=(4,), drops=drops)
    assert any("V=4" in d and "stages" in d for d in drops)


def test_compiled_pipeline_windows_and_wire():
    """The compiled plan's step tables carry schedule-proven liveness
    windows below M (the executors allocate W-slot rotating buffers, not
    [M] arrays), live-hop masks below the dense hop count, and the wire
    dtype threads from auto_pipeline to the executor config."""
    cfg = _uvit_cfg()
    cp = auto_pipeline(uvit_pipeline_graph(cfg),
                       diffusion_model_fns(cfg, "uvit"), 2,
                       pipeline_devices=2, microbatches=8)
    tabs = cp.step_tables()
    M = cp.schedule.M
    assert tabs.W_down < M and tabs.W_up < M and tabs.W_turn < M
    down, up = tabs.live_hops
    assert 0 < down + up < tabs.dense_hops
    assert cp.step_tables() is tabs            # memoized lowering
    assert cp.pcfg.wire_dtype == "bfloat16"    # default wire
    fp = auto_pipeline(uvit_pipeline_graph(cfg),
                       diffusion_model_fns(cfg, "uvit"), 2,
                       pipeline_devices=2, microbatches=4,
                       wire_dtype="float32")
    assert fp.pcfg.wire_dtype == "float32"
    import dataclasses as dc
    bad = dc.replace(cp, pcfg=dc.replace(cp.pcfg, wire_dtype="fp8"))
    with pytest.raises(ValueError, match="wire_dtype"):
        bad.build()


def test_tuner_prices_windowed_buffers():
    """tune() synthesizes + lowers every P > 1 candidate's schedule and
    prices peak_memory with the proven liveness windows.  The windows are
    steady-state properties — they do NOT grow with M — so the rx/turn
    footprint the tuner charges is M-independent, unlike any [M]-sized
    dense buffer sizing (the 'smaller proven footprints admit larger M'
    mechanism)."""
    from repro.core.schedule import schedule_for_partition
    from repro.core.tuner import peak_memory, profile_partition
    g = uvit_pipeline_graph(_uvit_cfg())
    choices = tune(g, 4)
    assert choices
    for c in choices:
        if c.P <= 1:
            continue
        sched = schedule_for_partition(c.partition, c.M)
        tabs = StepTables.from_schedule(sched, folded=c.partition.folded,
                                        devices=c.partition.devices)
        prof = profile_partition(g, c.partition)
        windowed = peak_memory(
            prof, c.P, c.b, wave=c.wave, V=c.V,
            windows=(tabs.W_down + tabs.W_up, tabs.W_turn, tabs.W_skip),
            dp=c.dp, zero_stage=c.zero_stage)
        assert c.peak_mem == windowed     # the score used the windows
        # vs the legacy 2-tuple (skip charged dense inside m_act), the
        # 3-tuple moves the skip stash to its proven rotating window:
        # out go P dense in-flight copies, in come W_skip fp32 entries
        legacy = peak_memory(
            prof, c.P, c.b, wave=c.wave, V=c.V,
            windows=(tabs.W_down + tabs.W_up, tabs.W_turn),
            dp=c.dp, zero_stage=c.zero_stage)
        if c.wave and c.V == 1:
            i, j = c.P - 1, c.P
            skips = prof.skip_bytes_per_sample
            dense_charge = c.P * (skips[i] + skips[j]) * c.b
            window_charge = tabs.W_skip * max(skips[i], skips[j]) * c.b * 2
            assert windowed == pytest.approx(
                legacy - dense_charge + window_charge)
        if c.V > 1:
            # interleaved greedy schedules may genuinely buffer O(M)
            # arrivals on a multiplexed slot — the window then reports
            # it honestly, and the tuner charges for it
            continue
        # V=1 wave templates: windows saturate at a steady-state
        # constant — doubling an already-large M leaves them unchanged
        # (and far below M), unlike any [M]-sized dense buffer sizing
        big = StepTables.from_schedule(
            schedule_for_partition(c.partition, 4 * c.M),
            folded=c.partition.folded, devices=c.partition.devices)
        bigger = StepTables.from_schedule(
            schedule_for_partition(c.partition, 8 * c.M),
            folded=c.partition.folded, devices=c.partition.devices)
        assert (big.W_down, big.W_up, big.W_turn) == \
            (bigger.W_down, bigger.W_up, bigger.W_turn)
        assert bigger.W_down < 8 * c.M and bigger.W_up < 8 * c.M


def test_step_tables_memoized_lowering():
    """Passing the mapping as a devices tuple memoizes the O(S*M*steps)
    lowering (same schedule + partition -> the identical StepTables
    object), and matches the callable-mapping build."""
    cfg, g = _interleaved_skipvit()
    part = partition(g, 2, lam=0.0, interleave=2)
    sched = schedule_for_partition(part, 4)
    t1 = StepTables.from_schedule(sched, folded=True, devices=part.devices)
    t2 = StepTables.from_schedule(sched, folded=True, devices=part.devices)
    assert t1 is t2
    t3 = StepTables.from_schedule(sched, folded=True,
                                  device_of_stage=part.device_of_stage)
    assert t3 is not t1
    np.testing.assert_array_equal(t1.sel, t3.sel)
    np.testing.assert_array_equal(t1.slot, t3.slot)
    # a schedule's dense programs are memoized per schedule too
    assert sched.device_programs() is sched.device_programs()


# ---------------------------------------------------------------------------
# differential executor tests (subprocess, mocked multi-device mesh)
# ---------------------------------------------------------------------------

_TIER1_EQUIV = ("linear-uneven", "wave-uneven", "wave-short",
                "wave-asym", "wave-sparse", "wave-interleaved",
                "linear-zero2", "wave-zero1", "wave-zero2")


@pytest.fixture(scope="session")
def tier1_equiv_out():
    """ONE subprocess for every tier-1 differential config: the
    multi-device jax startup (~8 s) is paid once instead of per test;
    each test below asserts on its own configs' result lines."""
    return _run_equiv(*_TIER1_EQUIV)


def test_auto_pipeline_equivalence_uneven_and_short(tier1_equiv_out):
    """Uneven DP partitions (linear + folded wave) lowered through the
    table-driven executor match the single-device reference AND the
    closed-form executors (loss + grads, rtol 1e-4) — the configs the
    hand-written S=D / S=2D executors could not run at all.  Plus the
    M = D - 1 wave: only the table-driven lowering can realize it (pinned
    behavior: the closed-form executor raises), and it matches the
    reference."""
    for cfg in ("linear-uneven", "wave-uneven", "wave-short"):
        assert f"{cfg}: " in tier1_equiv_out and "grads OK" in tier1_equiv_out
    assert "closed-form executor rejects M < D" in tier1_equiv_out


def test_auto_pipeline_equivalence_asymmetric_folds(tier1_equiv_out):
    """Mirror-ASYMMETRIC folds (make_unet_like(3, 2) shape + a sparse-skip
    variant) compile through auto_pipeline and their table executors match
    the single-device reference (loss + grads, rtol 1e-4); the asymmetric
    config is additionally checked against the closed-form wave executor.
    These are exactly the partitions StageLayout.from_partition used to
    reject."""
    assert "wave-asym: table executor == closed-form" in tier1_equiv_out
    assert "wave-sparse: cuts=" in tier1_equiv_out


def test_auto_pipeline_equivalence_interleaved(tier1_equiv_out):
    """V=2 interleaved wave on SkipViT (S = 4D stage slots, uneven slots,
    wraparound rings, slot-resolved skip stash): the table-driven executor
    matches the single-device reference (loss + grads, rtol 1e-4) — the
    region of the plan space the S == 2D layout gate made unreachable."""
    assert "wave-interleaved: closed-form executor rejects V=2" \
        in tier1_equiv_out
    assert "wave-interleaved: cuts=" in tier1_equiv_out


def test_auto_pipeline_equivalence_zero_hybrid(tier1_equiv_out):
    """Hybrid ZeRO x pipeline (dp=2, P=2, fp32 wire): with zero_stage=1
    (optimizer-state-only sharding) and zero_stage=2 (param stacks sharded
    at rest, all-gather-on-use inside the scan body, grads reduce-scattered
    over the data axis) the table executor still matches the unsharded
    single-replica reference on loss AND grads at rtol 1e-4."""
    for cfg in ("linear-zero2", "wave-zero1", "wave-zero2"):
        assert f"{cfg}: " in tier1_equiv_out and "grads OK" in tier1_equiv_out


@pytest.mark.slow
def test_auto_pipeline_equivalence_interleaved_ilp():
    """ILP-synthesized (Eqs. 6-13) V=2 interleaved schedule through the
    table-driven lowering matches the single-device reference — exact
    interleaved orders execute as synthesized, not just greedy ones.
    Plus the skip-free side of the axis: a V=2 interleaved linear 1F1B
    (S = VD, wraparound down ring) against the same reference."""
    _run_equiv("wave-interleaved-ilp", "linear-interleaved")


@pytest.mark.slow
def test_auto_pipeline_equivalence_hunyuan():
    """Hunyuan-DiT model_fns coverage (ROADMAP item): a small Hunyuan
    config through the full compile path matches hunyuan_apply (loss) and
    the aux-as-data block-loop reference (grads)."""
    _run_equiv("wave-hunyuan")


@pytest.mark.slow
def test_auto_pipeline_equivalence_even_and_forced_wave():
    """Even S=D / S=2D plans and the skip-free forced-wave (symmetric-fold
    partitioner + empty-skip executor) through the same compile path."""
    _run_equiv("linear-even", "wave-even", "wave-lm-uneven")


@pytest.mark.slow
def test_auto_pipeline_equivalence_ilp():
    """auto_pipeline(use_ilp=True) on a tiny graph: the exact ILP schedule
    validates, lowers via the table-driven executor (step tables == grid),
    and matches the single-device reference."""
    _run_equiv("wave-ilp")
