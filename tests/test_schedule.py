"""ILP scheduler + templates (paper Figs. 8/9, Eqs. 6-13) + property
tests: randomized (S, M, D, collocation) sweeps of the greedy synthesizer.
"""
import dataclasses
import random

import pytest

from helpers.hypothesis_compat import given, settings, st
from repro.core.schedule import (Placement, Schedule, TIMED_PRIORITIES,
                                 template_1f1b,
                                 template_wave, template_interleaved,
                                 ilp_schedule, greedy_schedule,
                                 greedy_schedule_timed,
                                 validate_schedule, simulate,
                                 schedule_for_partition)


def test_1f1b_template_valid_and_tight():
    for D, M in [(2, 2), (4, 4), (4, 8), (8, 4)]:
        s = template_1f1b(D, M)
        assert not validate_schedule(s, lambda st: st)
        assert s.makespan == 2 * (D + M - 1)   # classic 1F1B bound


def test_wave_template_valid():
    for D, M in [(2, 2), (4, 4), (4, 8)]:
        s = template_wave(D, M)
        S = 2 * D
        colloc = [(i, S - 1 - i) for i in range(D)]
        assert not validate_schedule(s, lambda st: min(st, S - 1 - st),
                                     collocated=colloc)
        # work bound: each device owns 2 stages x (F+B) x M unit tasks
        assert 4 * M <= s.makespan <= 4 * M + 2 * (S - 1)


@pytest.mark.slow
def test_ilp_matches_greedy_small():
    dev = lambda st: min(st, 3 - st)
    ilp = ilp_schedule(4, 2, 2, device_of_stage=dev,
                       collocated=[(0, 3), (1, 2)])
    assert not validate_schedule(ilp, dev, collocated=[(0, 3), (1, 2)])
    greedy = greedy_schedule(4, 2, dev, 2)
    assert ilp.makespan <= greedy.makespan


@pytest.mark.slow
def test_ilp_free_mapping_collocates():
    """Free device assignment must discover a collocated mapping."""
    ilp = ilp_schedule(4, 2, 2, device_of_stage=None,
                       collocated=[(0, 3), (1, 2)], horizon=10)
    errors = validate_schedule(ilp, None, collocated=[(0, 3), (1, 2)])
    assert not errors
    dev = ilp.device_of_stage_map()
    assert dev[0] == dev[3] and dev[1] == dev[2]
    assert dev[0] == 0    # anchored


def test_validate_rejects_out_of_bounds_placements():
    """Family (7) must flag out-of-range devices and negative steps — an
    unchecked placement used to sail through validation and crash later in
    grid()/lowering with an opaque IndexError."""
    good = template_1f1b(2, 2)

    def mutate(**kw):
        return Schedule(good.S, good.M, good.D, tuple(
            dataclasses.replace(p, **kw) if i == 0 else p
            for i, p in enumerate(good.placements)))

    errs = validate_schedule(mutate(device=5))
    assert any("out of range" in e and e.startswith("(7)") for e in errs)
    errs = validate_schedule(mutate(device=-1))
    assert any("out of range" in e for e in errs)
    errs = validate_schedule(mutate(step=-3))
    assert any("negative step" in e for e in errs)
    errs = validate_schedule(mutate(virtual=99))
    assert any("virtual stage 99 out of range" in e for e in errs)
    # a phantom EXTRA task referencing a nonexistent microbatch: family (6)
    # only checks required tasks exist, so the bounds check must catch it —
    # executors index [M]-sized buffers with clamped indices and would
    # otherwise silently corrupt microbatch M-1
    extra = Schedule(good.S, good.M, good.D,
                     good.placements + (Placement(0, 7, 0, good.makespan),))
    errs = validate_schedule(extra)
    assert any("microbatch 7 out of range" in e for e in errs)
    # device_programs refuses the same malformation with a clear message
    with pytest.raises(ValueError, match="validate_schedule"):
        mutate(device=5).device_programs()


def test_device_programs_match_grid_templates():
    """Dense per-device step programs agree with grid() slot-for-slot on
    both classic templates."""
    from helpers.schedule_checks import assert_programs_match_grid
    for sched in (template_1f1b(4, 6), template_wave(3, 4)):
        assert_programs_match_grid(sched)


def test_interleaved_template_valid():
    """The V-fold interleaved wave mapping (cyclic slots) synthesizes a
    valid schedule for every constraint family, including the all-pairs
    collocation of multi-slot devices."""
    from repro.core.partition import interleaved_wave_devices
    for D, M, V in [(2, 2, 2), (2, 4, 2), (3, 4, 2), (2, 4, 4)]:
        s = template_interleaved(D, M, V)
        S = 2 * V * D
        devices = interleaved_wave_devices(S, D)
        dev = lambda st: devices[st]
        by_dev = {}
        for st_ in range(S):
            by_dev.setdefault(dev(st_), []).append(st_)
        colloc = [(a, b) for ss in by_dev.values()
                  for i, a in enumerate(ss) for b in ss[i + 1:]]
        assert not validate_schedule(s, dev, collocated=colloc)
        # work bound: each device owns 2V stages x (F+B) x M unit tasks
        assert s.makespan >= 4 * V * M


def test_validate_schedule_reports_slot_context():
    """Constraint errors on interleaved schedules name the slot and wave
    of the offending stage (device, slot k/n, wave), not just a bare
    stage index — family (7) double-bookings and (10)/(11) order bugs."""
    from repro.core.partition import interleaved_wave_devices
    D, M, V = 2, 2, 2
    s = template_interleaved(D, M, V)
    S = 2 * V * D
    devices = interleaved_wave_devices(S, D)
    dev = lambda st: devices[st]
    # collide two tasks on one device/step: family (7) with both slots
    by_key = {(p.virtual, p.microbatch): p for p in s.placements}
    victim = by_key[(2, 0)]          # stage 2 = device 0 slot 1
    other = by_key[(0, 1)]           # stage 0 = device 0 slot 0
    bad = Schedule(s.S, s.M, s.D, tuple(
        dataclasses.replace(p, step=other.step)
        if p is victim else p for p in s.placements))
    errs = validate_schedule(bad, dev, folded=True)
    assert any("double-booked" in e and "slot" in e and "wave" in e
               for e in errs), errs
    # ordering violation (10) names the slot too
    bad2 = Schedule(s.S, s.M, s.D, tuple(
        dataclasses.replace(p, step=0)
        if (p.virtual, p.microbatch) == (2, 0) else p
        for p in s.placements))
    errs2 = validate_schedule(bad2, dev, folded=True)
    assert any(e.startswith("(10)") and "enc slot 1" in e
               for e in errs2), errs2


@given(st.integers(2, 4), st.integers(2, 5), st.integers(1, 2),
       st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_timed_greedy_always_valid(D, M, V, seed):
    """The duration-aware list scheduler satisfies every constraint family
    on interleaved mappings, for all priority orientations (including the
    window-minimizing arrival-order tie-break) and random durations."""
    from repro.core.partition import interleaved_wave_devices
    rnd = random.Random(seed)
    S = 2 * V * D
    devices = interleaved_wave_devices(S, D)
    dev = lambda st: devices[st]
    times = [rnd.uniform(0.1, 2.0) for _ in range(S)]
    for prio in TIMED_PRIORITIES:
        s = greedy_schedule_timed(S, M, dev, D, times, priority=prio,
                                  p2p_time=rnd.uniform(0.0, 0.3))
        assert not validate_schedule(s, dev)
        mk, bub = simulate(s, times, bwd_ratio=2.0)
        assert mk > 0 and 0.0 <= bub < 1.0
    with pytest.raises(ValueError, match="priority"):
        greedy_schedule_timed(S, M, dev, D, times, priority="sideways")


@given(st.integers(2, 4), st.integers(1, 2), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_interleaved_beats_fold_makespan(D, k, seed):
    """On randomly partially-skipped graphs whose block count admits a
    balanced V=2 interleave (n = 4Dk), the synthesized interleaved
    schedule's simulated makespan is <= the 2D fold's: the candidate
    portfolio (unit greedy + three duration-aware priorities) reliably
    converts the finer stages into smaller fill/drain bubbles."""
    from repro.core.graph import Block, BlockGraph, SkipEdge
    from repro.core.partition import partition
    from repro.core.tuner import profile_partition
    rnd = random.Random(seed)
    n = 4 * D * k
    pairs = [i for i in range(n // 2) if rnd.random() < 0.6]
    g = BlockGraph(tuple(Block(f"b{i}", 1.0) for i in range(n)),
                   tuple(SkipEdge(i, n - 1 - i, 8) for i in pairs))
    M = rnd.randint(2, 2 * D)
    try:
        p1 = partition(g, D, lam=0.0, interleave=1)
        p2 = partition(g, D, lam=0.0, interleave=2)
    except ValueError:
        return                       # no feasible stage-symmetric split
    mk1, _ = simulate(schedule_for_partition(p1, M),
                      profile_partition(g, p1).fwd_time_per_sample)
    mk2, _ = simulate(schedule_for_partition(p2, M),
                      profile_partition(g, p2).fwd_time_per_sample)
    assert mk2 <= mk1 + 1e-9, (mk2, mk1)


def test_simulation_durations():
    s = template_wave(4, 4)
    mk, bubble = simulate(s, [1.0] * 8, bwd_ratio=2.0, p2p_time=0.0)
    # useful work per device = M * (enc F + dec F + enc B + dec B) = 4*6
    assert mk >= 24.0
    assert 0.0 <= bubble < 0.5
    mk2, _ = simulate(s, [1.0] * 8, bwd_ratio=2.0, p2p_time=0.5)
    assert mk2 > mk


@given(st.integers(2, 4), st.integers(2, 6), st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_simulate_sync_never_beats_overlap(D, M, seed):
    """``simulate(overlap=False)`` charges the sender for every
    cross-device hop (the synchronous lowering); the default overlapped
    semantics let sends ride under the next task.  Synchronous makespan
    must therefore dominate, and they coincide when hops are free."""
    rnd = random.Random(seed)
    s = template_wave(D, M)
    times = [rnd.uniform(0.1, 2.0) for _ in range(2 * D)]
    p2p = rnd.uniform(0.0, 0.5)
    mk_ov, _ = simulate(s, times, bwd_ratio=2.0, p2p_time=p2p)
    mk_sync, _ = simulate(s, times, bwd_ratio=2.0, p2p_time=p2p,
                          overlap=False)
    assert mk_sync >= mk_ov - 1e-9
    free_ov, _ = simulate(s, times, bwd_ratio=2.0, p2p_time=0.0)
    free_sync, _ = simulate(s, times, bwd_ratio=2.0, p2p_time=0.0,
                            overlap=False)
    assert free_sync == pytest.approx(free_ov)


def test_empty_schedule_reports_shape():
    """A placement-free schedule must raise a clear error naming the
    schedule shape from makespan/bubble_ratio (not a bare ``max() arg is
    an empty sequence``), and validate as a family (6) violation."""
    empty = Schedule(S=4, M=2, D=2, placements=())
    with pytest.raises(ValueError, match=r"S=4.*no placements"):
        _ = empty.makespan
    with pytest.raises(ValueError, match=r"no placements.*bubble_ratio"):
        empty.bubble_ratio()
    errs = validate_schedule(empty, lambda st: min(st, 3 - st))
    assert errs and any("(6)" in e and "no placements" in e for e in errs)


def test_monotone_in_microbatches():
    prev = 0
    for M in (2, 4, 8):
        s = template_wave(4, M)
        assert s.makespan > prev
        prev = s.makespan


# ---------------------------------------------------------------------------
# property tests: schedule synthesis under random shapes + collocations
# ---------------------------------------------------------------------------

@given(st.integers(2, 5), st.integers(2, 6), st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_greedy_folded_always_valid(D, M, seed):
    """Folded S=2D mapping: zero constraint violations, simulate never
    deadlocks, for random shapes and durations."""
    rnd = random.Random(seed)
    S = 2 * D
    dev = lambda s: min(s, S - 1 - s)
    sched = greedy_schedule(S, M, dev, D)
    colloc = [(s, S - 1 - s) for s in range(D)]
    assert not validate_schedule(sched, dev, collocated=colloc)
    times = [rnd.uniform(0.1, 2.0) for _ in range(S)]
    mk, bubble = simulate(sched, times, bwd_ratio=rnd.uniform(1.0, 3.0),
                          p2p_time=rnd.uniform(0.0, 0.5))
    assert mk > 0 and 0.0 <= bubble < 1.0


@given(st.integers(2, 8), st.integers(2, 5), st.integers(2, 4),
       st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_greedy_random_mapping_always_valid(S, M, D, seed):
    """Arbitrary stage->device mappings (random collocation groups): the
    greedy synthesizer must still satisfy all six constraint families and
    produce a deadlock-free ordering."""
    rnd = random.Random(seed)
    devs = [rnd.randrange(D) for _ in range(S)]
    dev = lambda s: devs[s]
    sched = greedy_schedule(S, M, dev, D)
    colloc = [(i, j) for i in range(S) for j in range(i + 1, S)
              if devs[i] == devs[j]]
    assert not validate_schedule(sched, dev, collocated=colloc)
    times = [rnd.uniform(0.1, 2.0) for _ in range(S)]
    mk, _ = simulate(sched, times, bwd_ratio=2.0,
                     p2p_time=rnd.uniform(0.0, 0.3))
    assert mk > 0        # simulate raises RuntimeError on deadlock


@given(st.integers(1, 4), st.integers(2, 6), st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_schedule_for_partition_uneven_cuts(D, M, seed):
    """Partition-driven synthesis validates for random uneven cuts."""
    from repro.core.graph import Block, BlockGraph
    from repro.core.partition import linear_partition
    rnd = random.Random(seed)
    n = rnd.randint(max(2, D), 3 * D + 2)
    g = BlockGraph(tuple(Block(f"b{i}", rnd.uniform(0.2, 3.0))
                         for i in range(n)))
    part = linear_partition(g, D, lam=0.0)
    sched = schedule_for_partition(part, M)    # raises if invalid
    assert sched.makespan >= 2 * M             # F+B per microbatch somewhere


@pytest.mark.slow
@given(st.integers(2, 3), st.integers(2, 3), st.integers(0, 1000))
@settings(max_examples=4, deadline=None)
def test_ilp_never_worse_than_greedy_random(D, M, seed):
    """Exact ILP (Eqs. 6-13) matches or beats the greedy template on
    random small instances (random stage->device mappings)."""
    rnd = random.Random(seed)
    S = 2 * D
    devs = [rnd.randrange(D) for _ in range(S)]
    dev = lambda s: devs[s]
    colloc = [(i, j) for i in range(S) for j in range(i + 1, S)
              if devs[i] == devs[j]]
    greedy = greedy_schedule(S, M, dev, D)
    ilp = ilp_schedule(S, M, D, device_of_stage=dev, collocated=colloc)
    assert not validate_schedule(ilp, dev, collocated=colloc)
    assert ilp.makespan <= greedy.makespan
