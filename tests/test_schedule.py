"""ILP scheduler + templates (paper Figs. 8/9, Eqs. 6-13)."""
import pytest

from repro.core.schedule import (template_1f1b, template_wave, ilp_schedule,
                                 greedy_schedule, validate_schedule, simulate)


def test_1f1b_template_valid_and_tight():
    for D, M in [(2, 2), (4, 4), (4, 8), (8, 4)]:
        s = template_1f1b(D, M)
        assert not validate_schedule(s, lambda st: st)
        assert s.makespan == 2 * (D + M - 1)   # classic 1F1B bound


def test_wave_template_valid():
    for D, M in [(2, 2), (4, 4), (4, 8)]:
        s = template_wave(D, M)
        S = 2 * D
        colloc = [(i, S - 1 - i) for i in range(D)]
        assert not validate_schedule(s, lambda st: min(st, S - 1 - st),
                                     collocated=colloc)
        # work bound: each device owns 2 stages x (F+B) x M unit tasks
        assert 4 * M <= s.makespan <= 4 * M + 2 * (S - 1)


def test_ilp_matches_greedy_small():
    dev = lambda st: min(st, 3 - st)
    ilp = ilp_schedule(4, 2, 2, device_of_stage=dev,
                       collocated=[(0, 3), (1, 2)])
    assert not validate_schedule(ilp, dev, collocated=[(0, 3), (1, 2)])
    greedy = greedy_schedule(4, 2, dev, 2)
    assert ilp.makespan <= greedy.makespan


def test_ilp_free_mapping_collocates():
    """Free device assignment must discover a collocated mapping."""
    ilp = ilp_schedule(4, 2, 2, device_of_stage=None,
                       collocated=[(0, 3), (1, 2)], horizon=10)
    errors = validate_schedule(ilp, None, collocated=[(0, 3), (1, 2)])
    assert not errors
    dev = ilp.device_of_stage_map()
    assert dev[0] == dev[3] and dev[1] == dev[2]
    assert dev[0] == 0    # anchored


def test_simulation_durations():
    s = template_wave(4, 4)
    mk, bubble = simulate(s, [1.0] * 8, bwd_ratio=2.0, p2p_time=0.0)
    # useful work per device = M * (enc F + dec F + enc B + dec B) = 4*6
    assert mk >= 24.0
    assert 0.0 <= bubble < 0.5
    mk2, _ = simulate(s, [1.0] * 8, bwd_ratio=2.0, p2p_time=0.5)
    assert mk2 > mk


def test_monotone_in_microbatches():
    prev = 0
    for M in (2, 4, 8):
        s = template_wave(4, M)
        assert s.makespan > prev
        prev = s.makespan
