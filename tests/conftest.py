import jax
import pytest

# smoke tests and benches must see ONE device; the 512-device override is
# confined to launch/dryrun.py (and subprocess tests set their own flags).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
