import os
import sys

import jax
import pytest

# smoke tests and benches must see ONE device; the 512-device override is
# confined to launch/dryrun.py (and subprocess tests set their own flags).
jax.config.update("jax_platform_name", "cpu")

# make `helpers.*` (hypothesis shim, subprocess scripts) importable from
# test modules regardless of how pytest was invoked
sys.path.insert(0, os.path.dirname(__file__))


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
