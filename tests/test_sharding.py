"""runtime/sharding rules: param specs, ZeRO stack specs, optimizer
round-trip, and the tuner-vs-executor sharded-bytes property."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.tuner import zero_param_state_breakdown
from repro.optim.adamw import adamw_init, int8_adamw_init
from repro.runtime.sharding import build_param_specs, zero_stack_specs
from repro.train.steps import opt_specs_like

DATA = ("data",)


def _leaf(*shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# build_param_specs: leaf-wise rules
# ---------------------------------------------------------------------------

def test_build_param_specs_rule_table():
    d, f = 128, 512
    params = {
        "embed": _leaf(256, d),
        "layers": {
            "wq": _leaf(4, d, d),        # stacked: leading dim scanned
            "wo": _leaf(d, d),
            "w_down": _leaf(f, d),
            "mystery": _leaf(d, d),      # no rule -> trailing-dim FSDP
        },
    }
    specs = build_param_specs(params)
    assert specs["embed"] == P(DATA, "model")
    # right-aligned: the stacked leading dim stays unsharded
    assert specs["layers"]["wq"] == P(None, DATA, "model")
    assert specs["layers"]["wo"] == P("model", DATA)
    assert specs["layers"]["w_down"] == P("model", DATA)
    assert specs["layers"]["mystery"] == P(None, DATA)


def test_build_param_specs_small_and_scalar_leaves_replicate():
    params = {
        "wq": _leaf(16, 16),             # 256 elems < min_fsdp_size
        "scale": _leaf(),                # ndim-0
        "big": _leaf(64, 128),           # 8192 elems >= 2**12
    }
    specs = build_param_specs(params)
    assert specs["wq"] == P()
    assert specs["scale"] == P()
    assert specs["big"] == P(None, DATA)
    # the exemption threshold is a knob, not a constant
    assert build_param_specs(params, min_fsdp_size=1)["wq"] \
        == P(DATA, "model")


def test_build_param_specs_divisibility_fallback():
    # axis_sizes that do not divide a dim drop that entry to replication
    params = {"wq": _leaf(96, 96)}
    specs = build_param_specs(
        params, min_fsdp_size=1, axis_sizes={"data": 5, "model": 3})
    assert specs["wq"] == P(None, "model")   # 96 % 5 != 0, 96 % 3 == 0


# ---------------------------------------------------------------------------
# optimizer state mirrors param specs leaf-wise (ZeRO-1 round trip)
# ---------------------------------------------------------------------------

def test_adamw_state_round_trips_param_specs():
    params = {"wq": _leaf(64, 128), "wo": _leaf(128, 64), "b": _leaf(8)}
    specs = build_param_specs(params, min_fsdp_size=1)
    state = adamw_init(params)
    o_specs = opt_specs_like(specs, False, DATA)
    # m/v mirror the param tree, so the param specs apply unchanged
    assert o_specs["m"] == specs and o_specs["v"] == specs
    assert o_specs["step"] == P()
    jax.tree.map(lambda leaf, sp: (leaf, sp), state["m"], o_specs["m"],
                 is_leaf=lambda x: isinstance(x, P))  # structural match
    for leaf, sp in zip(jax.tree.leaves(state["m"]),
                        jax.tree.leaves(o_specs["m"],
                                        is_leaf=lambda x: isinstance(x, P))):
        assert len(sp) <= leaf.ndim


def test_int8_adamw_state_stays_zero_shardable():
    """int8 moments are flat (nblocks, 256) tensors; opt_specs_like
    shards the block dim over the ZeRO axes, and adamw's _BLOCK_ALIGN
    padding keeps nblocks divisible by up to 32-way data axes."""
    params = {"wq": _leaf(64, 100)}      # deliberately non-round size
    state = int8_adamw_init(params)
    specs = build_param_specs(params, min_fsdp_size=1)
    o_specs = opt_specs_like(specs, True, DATA)
    q = state["m"]["wq"]["q"]
    assert q.shape[0] % 32 == 0
    assert o_specs["m"]["wq"] == {"q": P(DATA), "s": P(DATA)}
    assert o_specs["step"] == P()


# ---------------------------------------------------------------------------
# zero_stack_specs: executor-facing [D, V, pad, ...] stage stacks
# ---------------------------------------------------------------------------

def test_zero_stack_specs_rule_placement_and_gather_dims():
    D, V, pad, d, f, dp = 2, 1, 3, 64, 256, 4
    stacks = {
        "w_up": _leaf(D, V, pad, d, f),     # rule (fsdp, tp) -> dim 0
        "w_down": _leaf(D, V, pad, f, d),   # rule (tp, fsdp) -> dim 1
        "bias": _leaf(D, V, pad, 2 * f),    # default (fsdp,) -> dim 0
    }
    specs, dims = zero_stack_specs(stacks, dp=dp)
    assert specs["w_up"] == P("model", None, None, DATA, None)
    assert specs["w_down"] == P("model", None, None, None, DATA)
    assert specs["bias"] == P("model", None, None, DATA)
    # gather dims index the per-slot [pad, ...] view: 1 + block dim
    assert dims == {"w_up": 1, "w_down": 2, "bias": 1}


def test_zero_stack_specs_small_leaves_and_indivisible_dims():
    D, V, pad, dp = 2, 1, 2, 4
    stacks = {
        "tiny": _leaf(D, V, pad, 8, 8),      # 64 < min_shard_size
        "w_up": _leaf(D, V, pad, 6, 512),    # fsdp dim 6 % 4 != 0 ->
        "odd": _leaf(D, V, pad, 3, 5),       # fallback dim 512; none here
    }
    specs, dims = zero_stack_specs(stacks, dp=dp)
    assert specs["tiny"] == P("model") and dims["tiny"] == -1
    # fallback: the largest dp-divisible block dim is scattered instead
    assert specs["w_up"] == P("model", None, None, None, DATA)
    assert dims["w_up"] == 2
    assert specs["odd"] == P("model") and dims["odd"] == -1
    # dp=1 short-circuits to fully replicated stacks
    specs1, dims1 = zero_stack_specs(stacks, dp=1)
    assert all(s == P("model") for s in jax.tree.leaves(
        specs1, is_leaf=lambda x: isinstance(x, P)))
    assert all(g == -1 for g in jax.tree.leaves(dims1))


def test_zero_stack_specs_mirror_optimizer_state():
    """The docstring contract: optimizer m/v mirror the stack tree, so
    the same specs shard ZeRO-1 state leaf-wise without modification."""
    stacks = {"w_up": _leaf(2, 1, 2, 64, 256)}
    specs, _ = zero_stack_specs(stacks, dp=4)
    state = adamw_init(stacks)
    mirrored = jax.tree.map(lambda _: specs["w_up"], state["m"],
                            is_leaf=lambda x: hasattr(x, "ndim"))
    assert mirrored == {"w_up": specs["w_up"]}


# ---------------------------------------------------------------------------
# acceptance property: the tuner's sharded charge is the executor's bytes
# ---------------------------------------------------------------------------

def test_peak_memory_sharded_charge_matches_executor_bytes():
    """zero_param_state_breakdown's per-device params/grads/opt terms
    equal the bytes the executor actually keeps resident: the stack
    leaves sharded per zero_stack_specs plus the leaf-wise-mirrored
    AdamW moments, divided over the data axis."""
    D, V, pad, dp = 2, 1, 2, 4
    stacks = {
        "w_up": _leaf(D, V, pad, 64, 256),
        "w_down": _leaf(D, V, pad, 256, 64),
        "proj": _leaf(D, V, pad, 128, 128),
    }
    specs, dims = zero_stack_specs(stacks, dp=dp)
    assert all(g >= 0 for g in jax.tree.leaves(dims)), \
        "property requires every leaf sharded (pick divisible shapes)"

    # per-stage param bytes (one [V, pad, ...] row of the stack)
    m_theta = sum(leaf.nbytes for leaf in jax.tree.leaves(stacks)) / D
    # executor-side at-rest bytes per device: sharded leaves keep 1/dp
    def resident(tree, gdims):
        return sum(leaf.nbytes / D / (dp if g >= 0 else 1)
                   for leaf, g in zip(jax.tree.leaves(tree),
                                      jax.tree.leaves(gdims)))

    actual_params = resident(stacks, dims)
    state = adamw_init(stacks)
    actual_opt = resident(state["m"], dims) + resident(state["v"], dims)

    # params are fp32 here, so m/v fp32 moments are exactly 2x params;
    # feed that measured ratio in as the factor (2 = params + grads)
    pf = 2.0 + actual_opt * dp / m_theta
    assert pf == 4.0
    bd = zero_param_state_breakdown(m_theta, dp=dp, zero_stage=2,
                                    param_state_factor=pf,
                                    m_gather=m_theta)
    assert bd["params"] == actual_params
    assert bd["grads"] == actual_params          # grads mirror params
    assert bd["opt"] == actual_opt
    assert bd["gathered"] == m_theta             # one transient slot copy
    # ZeRO-1 keeps params/grads dense but shards the same opt bytes
    bd1 = zero_param_state_breakdown(m_theta, dp=dp, zero_stage=1,
                                     param_state_factor=pf)
    assert bd1["params"] == m_theta and bd1["opt"] == actual_opt
    np.testing.assert_allclose(
        sum(bd.values()),
        actual_params * 2 + actual_opt + m_theta, rtol=0)
