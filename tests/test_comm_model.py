"""Analytic comm model == measured partition volumes (paper §II-C/§V-B)."""
from helpers.hypothesis_compat import given, settings, st

from repro.core.graph import make_unet_like
from repro.core.comm_model import (naive_pp_volume, pulse_volume,
                                   partition_comm_volume, zero_volume_per_iter)
from repro.core.partition import partition, blockwise_partition


@given(st.integers(2, 8), st.sampled_from([2, 4, 8]))
@settings(max_examples=20, deadline=None)
def test_formulas_match_measurement(n_pairs, D):
    K = 2 * n_pairs
    if K < 2 * D or n_pairs % (D // 2 or 1):
        return   # wave needs 2D stages over K blocks
    a = 1 << 20
    g = make_unet_like(n_pairs, 0, act_bytes=a, skip_bytes=a)
    pulse = partition(g, D)
    base = blockwise_partition(g, D)
    v_pulse = partition_comm_volume(g, pulse)
    v_base = partition_comm_volume(g, base)
    assert abs(v_pulse.fwd_total - pulse_volume(D, a)) < 1e-6
    assert abs(v_base.fwd_total - naive_pp_volume(K, D, a)) < 1e-6
    assert v_pulse.skip_bytes == 0.0        # skip locality


def test_reduction_grows_with_depth():
    a = 1 << 20
    red = []
    for n_pairs, D in [(4, 4), (8, 8), (24, 8)]:
        g = make_unet_like(n_pairs, 0, act_bytes=a, skip_bytes=a)
        vp = partition_comm_volume(g, partition(g, D)).fwd_total
        vb = partition_comm_volume(g, blockwise_partition(g, D)).fwd_total
        red.append(1 - vp / vb)
    assert red[0] < red[1] < red[2]
    assert red[2] > 0.85    # K=48,D=8: 1 - 2(D-1)/((K+4)D/4-1) = 0.86


def test_zero_volume():
    p = 10 * (1 << 20)
    assert zero_volume_per_iter(p, 8, 2) < zero_volume_per_iter(p, 8, 3)
    assert zero_volume_per_iter(p, 1, 2) == 0.0
