"""Analytic comm model == measured partition volumes (paper §II-C/§V-B)."""
from helpers.hypothesis_compat import given, settings, st

from repro.core.graph import make_unet_like
from repro.core.comm_model import (lowered_comm_volume, naive_pp_volume,
                                   pulse_volume, partition_comm_volume,
                                   wire_factor, zero_volume_per_iter)
from repro.core.partition import partition, blockwise_partition


@given(st.integers(2, 8), st.sampled_from([2, 4, 8]))
@settings(max_examples=20, deadline=None)
def test_formulas_match_measurement(n_pairs, D):
    K = 2 * n_pairs
    if K < 2 * D or n_pairs % (D // 2 or 1):
        return   # wave needs 2D stages over K blocks
    a = 1 << 20
    g = make_unet_like(n_pairs, 0, act_bytes=a, skip_bytes=a)
    pulse = partition(g, D)
    base = blockwise_partition(g, D)
    v_pulse = partition_comm_volume(g, pulse)
    v_base = partition_comm_volume(g, base)
    assert abs(v_pulse.fwd_total - pulse_volume(D, a)) < 1e-6
    assert abs(v_base.fwd_total - naive_pp_volume(K, D, a)) < 1e-6
    assert v_pulse.skip_bytes == 0.0        # skip locality


def test_reduction_grows_with_depth():
    a = 1 << 20
    red = []
    for n_pairs, D in [(4, 4), (8, 8), (24, 8)]:
        g = make_unet_like(n_pairs, 0, act_bytes=a, skip_bytes=a)
        vp = partition_comm_volume(g, partition(g, D)).fwd_total
        vb = partition_comm_volume(g, blockwise_partition(g, D)).fwd_total
        red.append(1 - vp / vb)
    assert red[0] < red[1] < red[2]
    assert red[2] > 0.85    # K=48,D=8: 1 - 2(D-1)/((K+4)D/4-1) = 0.86


def test_zero_volume():
    p = 10 * (1 << 20)
    assert zero_volume_per_iter(p, 8, 2) < zero_volume_per_iter(p, 8, 3)
    assert zero_volume_per_iter(p, 1, 2) == 0.0


def test_collective_bytes_parses_stablehlo():
    """Both StableHLO collective forms parse: single-line ops
    (collective_permute) and region-bearing ops (all_reduce), whose
    result type sits on the region's closing line — the region body's own
    `->` signatures must not be miscounted."""
    from repro.runtime.hlo_analysis import collective_bytes
    txt = """
    %71 = "stablehlo.collective_permute"(%70) <{channel_handle = #stablehlo.channel_handle<handle = 1, type = 1>, source_target_pairs = dense<[[0, 1]]> : tensor<1x2xi64>}> : (tensor<1x18x32xbf16>) -> tensor<1x18x32xbf16>
    %5 = "stablehlo.all_reduce"(%4) <{replica_groups = dense<[[0, 1]]> : tensor<1x2xi64>}> ({
    ^bb0(%arg0: tensor<f32>, %arg1: tensor<f32>):
      %6 = stablehlo.add %arg0, %arg1 : (tensor<f32>, tensor<f32>) -> tensor<f32>
      stablehlo.return %6 : tensor<f32>
    }) : (tensor<4xf32>) -> tensor<4xf32>
    """
    st = collective_bytes(txt)
    assert st.bytes_by_kind["collective-permute"] == 18 * 32 * 2
    assert st.bytes_by_kind["all-reduce"] == 4 * 4   # NOT the region's f32
    assert st.count_by_kind == {"collective-permute": 1, "all-reduce": 1}


def test_bench_compare_flags_regressions_and_missing_metrics():
    """The --compare gate: worse lower-is-better metrics fail, improved
    ones pass, and a gated metric that vanishes from the new run (probe
    started failing) fails instead of passing vacuously."""
    from benchmarks.run import compare_baseline
    old = {"hlo": {"g": {"bfloat16": 4608, "float32": 9216}},
           "hlo_collective_permute_bytes": 4608,
           "interleave": {"g": {"v1": {"bubble": 0.26,
                                       "sim_makespan": 1.0}}}}
    good = {"hlo": {"g": {"bfloat16": 4608, "float32": 9216}},
            "hlo_collective_permute_bytes": 4000,       # improvement
            "interleave": {"g": {"v1": {"bubble": 0.26,
                                        "sim_makespan": 5.0}}}}  # ungated
    assert compare_baseline(old, good) == []
    worse = {"hlo": {"g": {"bfloat16": 9216, "float32": 9216}},
             "hlo_collective_permute_bytes": 4608,
             "interleave": {"g": {"v1": {"bubble": 0.30,
                                         "sim_makespan": 1.0}}}}
    regs = compare_baseline(old, worse)
    assert any("bfloat16" in r for r in regs)
    assert any("bubble" in r for r in regs)
    vanished = {"hlo_collective_permute_bytes": 4608,
                "interleave": {"g": {"v1": {"bubble": 0.26,
                                            "sim_makespan": 1.0}}}}
    regs = compare_baseline(old, vanished)
    assert any("missing" in r and "bfloat16" in r for r in regs)


def test_lowered_comm_volume_prices_live_bf16_hops():
    """The lowered-executor pricing: live hops only (schedule activity
    masks), wire-dtype bytes — vs the dense every-step/both-rings fp32
    cost the pre-liveness table executors paid."""
    from repro.core.schedule import template_wave
    from repro.runtime.schedule_exec import StepTables
    D, M, a = 2, 4, 1 << 10
    tabs = StepTables.from_schedule(template_wave(D, M), folded=True)
    v_bf = lowered_comm_volume(tabs, a)                  # bf16 default
    v_fp = lowered_comm_volume(tabs, a, wire_dtype="float32")
    # one down + one up hop per microbatch on the 2-device fold
    assert v_bf.live_hops == 2 * M
    assert v_bf.dense_hops == 2 * D * tabs.num_steps > v_bf.live_hops
    assert v_bf.fwd_total == 2 * M * a                   # factor 1 (bf16)
    assert v_fp.fwd_total == 2.0 * v_bf.fwd_total        # fp32 doubles
    assert v_bf.train_total == 2.0 * v_bf.fwd_total      # bwd mirrors fwd
    # the dense pre-liveness cost dominates both
    assert v_bf.dense_fp32_total > v_fp.fwd_total
    assert wire_factor("bfloat16") == 1.0
    assert wire_factor("float32") == 2.0
