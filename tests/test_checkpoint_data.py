"""Checkpoint store + data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (save_checkpoint, restore_checkpoint,
                              latest_step, CheckpointManager)
from repro.data import SyntheticTokenDataset, SyntheticLatentDataset, \
    ShardedLoader


def _tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": [{"b": jnp.ones((2,))}, {"b": jnp.zeros((2,))}],
            "step": jnp.array(7)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    restored, step = restore_checkpoint(str(tmp_path), t)
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_incomplete(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 3, t)
    os.makedirs(tmp_path / "step_000000009")   # incomplete: no manifest
    assert latest_step(str(tmp_path)) == 3


def test_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"only": jnp.zeros((1,))})


def test_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree())
    mgr.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]


def test_token_data_deterministic_and_learnable():
    ds = SyntheticTokenDataset(vocab=64, seq_len=32, seed=1)
    a = ds.batch(3, 0, 4)["tokens"]
    b = ds.batch(3, 0, 4)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = ds.batch(4, 0, 4)["tokens"]
    assert not np.array_equal(a, c)
    d = ds.batch(3, 1, 4)["tokens"]
    assert not np.array_equal(a, d)
    # Markov structure: next-token entropy is bounded by k choices
    nxt = {}
    big = ds.batch(0, 0, 64)["tokens"]
    for row in big:
        for t in range(1, 32):
            nxt.setdefault(int(row[t - 1]), set()).add(int(row[t]))
    assert max(len(v) for v in nxt.values()) <= ds.k


def test_latent_data_and_loader():
    ds = SyntheticLatentDataset(img_size=8, channels=4, n_classes=5,
                                text_dim=16)
    loader = ShardedLoader(ds, global_batch=8, num_hosts=2, host_id=1)
    b = loader.get(0)
    assert b["latents"].shape == (4, 8, 8, 4)
    assert b["text_embeds"].shape == (4, 77, 16)
    b2 = loader.get(0)
    np.testing.assert_array_equal(b["latents"], b2["latents"])


def test_elastic_reshard_restore(tmp_path):
    """Restore a checkpoint onto a different device layout (the elastic
    path): shardings for the *current* mesh are applied at load."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = {"w": jnp.arange(64.0).reshape(8, 8)}
    save_checkpoint(str(tmp_path), 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, step = restore_checkpoint(str(tmp_path), t, shardings=sh)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t["w"]))
    assert restored["w"].sharding == sh["w"]
