"""Static plan verification: valid plans certify clean, corrupted ones don't.

Soundness here is mutation-tested: every lowered plan the synthesizers
emit (greedy / timed / ILP, V in {1, 2, 4}, asymmetric and interleaved
folds, both hop lowerings) must yield a clean ``PlanCertificate``, and
each targeted corruption class — swapped steps, shrunken liveness
window, flipped channel-activity bit, dropped skip-stash store, misrouted
buffer slot, falsified hop accounting — must be rejected with a *named*
check from ``repro.analysis.dataflow.CHECKS``.  An interpreter that
certified a corrupted table would be worse than no interpreter.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.analysis import (CHECKS, PlanCertificate, certify_tables,
                            interpret_tables)
from repro.analysis.certificate import (WIRE_DTYPES as CERT_WIRE_DTYPES,
                                        export_plan, load_plan)
from repro.core.partition import partition
from repro.core.schedule import (TIMED_PRIORITIES, greedy_schedule,
                                 greedy_schedule_timed,
                                 schedule_for_partition, template_1f1b,
                                 template_interleaved, template_wave)
from repro.runtime.schedule_exec import PlanError, StepTables


def _wave_tables(D=3, M=6):
    sched = template_wave(D, M)
    return StepTables.from_schedule(
        sched, folded=True,
        device_of_stage=lambda s, S=2 * D: min(s, S - 1 - s))


def _mutated(tabs, **muts):
    """dataclasses.replace with per-array copy-and-edit callbacks."""
    kw = {}
    for name, fn in muts.items():
        val = getattr(tabs, name)
        if isinstance(val, np.ndarray):
            val = np.array(val, copy=True)
            fn(val)
        else:
            val = fn(val)
        kw[name] = val
    return dataclasses.replace(tabs, **kw)


def _asym_part():
    from repro.models.diffusion import SkipViTConfig, skipvit_pipeline_graph
    cfg = SkipViTConfig("t", n_enc=3, n_mid=2, n_dec=3)
    g = skipvit_pipeline_graph(cfg,
                               fwd_times=[1, 1, 4, .5, .5, .5, 1, 1])
    return partition(g, 2, lam=0.0), g


def _interleaved_part(V=2):
    from repro.models.diffusion import SkipViTConfig, skipvit_pipeline_graph
    cfg = SkipViTConfig("t", n_enc=4, n_mid=2, n_dec=4)
    g = skipvit_pipeline_graph(
        cfg, fwd_times=[1, 1, 2, 4, 0.5, 0.5, 0.5, 1, 1, 2])
    return partition(g, 2, lam=0.0, interleave=V), g


def _consumers(part, g):
    from repro.runtime.compile import StageLayout
    return StageLayout.from_partition(part, g).skip_consumers()


# ===========================================================================
# Semantic-constant parity: the jax-free analysis layer re-declares the
# executor's selector codes and wire-dtype set — they must never drift.
# ===========================================================================

def test_analysis_constants_mirror_executor():
    from repro.analysis import dataflow
    from repro.runtime import schedule_exec, pipeline
    assert (dataflow.IDLE, dataflow.RUN_ENC, dataflow.RUN_DEC) == \
        (schedule_exec.IDLE, schedule_exec.RUN_ENC, schedule_exec.RUN_DEC)
    assert CERT_WIRE_DTYPES == pipeline.WIRE_DTYPES


# ===========================================================================
# Every synthesized plan certifies clean
# ===========================================================================

@pytest.mark.parametrize("D,M", [(2, 4), (3, 6), (4, 8)])
@pytest.mark.parametrize("overlap", [True, False])
def test_wave_templates_certify_clean(D, M, overlap):
    tabs = _wave_tables(D, M)
    cert = certify_tables(tabs, overlap=overlap)
    assert cert.ok, cert.violations
    assert cert.failed_checks == ()
    assert tuple(cert.checks) == CHECKS


@pytest.mark.parametrize("D,M", [(2, 4), (4, 8)])
def test_linear_templates_certify_clean(D, M):
    tabs = StepTables.from_schedule(template_1f1b(D, M), folded=False)
    cert = certify_tables(tabs)
    assert cert.ok, cert.violations
    assert cert.hops["live_up"] == 0       # single-ring plan


@pytest.mark.parametrize("prio", (None,) + TIMED_PRIORITIES)
def test_asym_fold_schedules_certify_clean(prio):
    """Greedy + every timed priority on the mirror-asymmetric fold."""
    part, g = _asym_part()
    S, D, M = part.num_stages, part.num_devices, 4
    if prio is None:
        sched = greedy_schedule(S, M, part.device_of_stage, D)
    else:
        times = part.stage_costs or (1.0,) * S
        sched = greedy_schedule_timed(S, M, part.device_of_stage, D,
                                      times, priority=prio)
    consumers = _consumers(part, g)
    tabs = StepTables.from_schedule(sched, folded=True,
                                    devices=part.devices,
                                    skip_consumers=consumers)
    for overlap in (True, False):
        cert = certify_tables(tabs, skip_consumers=consumers,
                              overlap=overlap)
        assert cert.ok, cert.violations


@pytest.mark.parametrize("V", [2])
def test_interleaved_portfolio_certifies_clean(V):
    part, g = _interleaved_part(V)
    sched = schedule_for_partition(part, 4)
    consumers = _consumers(part, g)
    tabs = StepTables.from_schedule(sched, folded=True,
                                    devices=part.devices,
                                    skip_consumers=consumers)
    cert = certify_tables(tabs, skip_consumers=consumers)
    assert cert.ok, cert.violations
    assert tabs.V == V


def test_v4_template_certifies_clean():
    tabs = StepTables.from_schedule(template_interleaved(2, 4, 4),
                                    folded=True)
    cert = certify_tables(tabs)
    assert cert.ok, cert.violations
    assert tabs.V == 4


def test_ilp_plan_certifies_clean():
    part, g = _asym_part()
    sched = schedule_for_partition(part, 4, use_ilp=True, time_limit=60.0)
    consumers = _consumers(part, g)
    tabs = StepTables.from_schedule(sched, folded=True,
                                    devices=part.devices,
                                    skip_consumers=consumers)
    cert = certify_tables(tabs, skip_consumers=consumers)
    assert cert.ok, cert.violations


def test_compiled_pipeline_certify():
    """End-to-end: auto_pipeline -> CompiledPipeline.certify()."""
    from repro.models.diffusion import SkipViTConfig, skipvit_pipeline_graph
    from repro.runtime.adapters import skipvit_model_fns
    from repro.runtime.compile import auto_pipeline
    cfg = SkipViTConfig("t", n_enc=3, n_mid=2, n_dec=3)
    g = skipvit_pipeline_graph(cfg,
                               fwd_times=[1, 1, 4, .5, .5, .5, 1, 1])
    cp = auto_pipeline(g, skipvit_model_fns(cfg), 2, pipeline_devices=2,
                       microbatches=4)
    cert = cp.certify(name="asym")
    assert cert.ok, cert.violations
    assert cert.plan["overlap"] is True
    assert cert.name == "asym"
    # the certificate's window proof matches the lowered tables
    tabs = cp.step_tables()
    assert cert.windows["down"]["declared"] == tabs.W_down
    assert cert.windows["down"]["peak"] <= tabs.W_down


# ===========================================================================
# Mutation soundness: every corruption class is rejected by name
# ===========================================================================

def _failed(tabs, **certify_kw):
    cert = certify_tables(tabs, **certify_kw)
    assert not cert.ok, "corrupted tables certified clean"
    assert set(cert.failed_checks) <= set(CHECKS)
    assert cert.failed_checks, "violations must carry a named check"
    return cert.failed_checks


def test_mutation_swap_two_steps():
    tabs = _wave_tables()
    cols = ("sel", "slot", "mb", "down_mb", "down_valid", "up_mb",
            "up_valid", "loss", "embed", "turn_rd", "turn_wr",
            "down_send", "up_send", "down_slot", "up_slot", "rx_slot",
            "turn_wr_slot", "turn_rd_slot", "skip_wr", "skip_wr_slot",
            "skip_rd_slot")

    def swap(a):
        a[1, [3, 4]] = a[1, [4, 3]]

    failed = _failed(_mutated(tabs, **{c: swap for c in cols}))
    assert "send-recv-pairing" in failed


def test_mutation_shrink_liveness_window():
    tabs = _wave_tables()
    assert _failed(_mutated(tabs, W_down=lambda w: w - 1)) == \
        ("buffer-bounds",)
    assert "buffer-bounds" in _failed(
        _mutated(tabs, W_skip=lambda w: w - 1))


def test_mutation_flip_channel_activity_bit():
    tabs = _wave_tables()
    sends = np.nonzero(tabs.down_send[0])[0]

    def drop(a):
        a[0, sends[1]] = False

    assert "send-recv-pairing" in _failed(_mutated(tabs, down_send=drop))

    quiet = np.nonzero(~tabs.down_send[0] & (tabs.sel[0] != 0))[0]

    def add(a):
        a[0, quiet[0]] = True

    assert "send-recv-pairing" in _failed(_mutated(tabs, down_send=add))


def test_mutation_drop_skip_stash_store():
    tabs = _wave_tables()
    writes = np.nonzero(tabs.skip_wr[1])[0]

    def drop(a):
        a[1, writes[0]] = False

    assert "matched-store-read" in _failed(_mutated(tabs, skip_wr=drop))


def test_mutation_misroute_store_slot():
    tabs = _wave_tables()
    arrivals = np.nonzero(tabs.down_valid[1])[0]

    def rotate(a):
        k = arrivals[1]
        a[1, k] = (a[1, k] + 1) % tabs.W_down

    failed = _failed(_mutated(tabs, down_slot=rotate))
    assert "no-live-overwrite" in failed or "matched-store-read" in failed


def test_mutation_slot_out_of_window():
    tabs = _wave_tables()
    arrivals = np.nonzero(tabs.down_valid[1])[0]

    def oob(a):
        a[1, arrivals[0]] = tabs.W_down + 3

    assert "buffer-bounds" in _failed(_mutated(tabs, down_slot=oob))


def test_mutation_falsified_hop_accounting():
    tabs = _wave_tables()
    assert _failed(_mutated(tabs, exposed_down=lambda x: x + 1)) == \
        ("overlap-accounting",)


def test_mutation_dropped_loss():
    tabs = _wave_tables()
    steps = np.nonzero(tabs.loss.any(axis=0))[0]

    def drop(a):
        a[:, steps[0]] = False

    assert "program-shape" in _failed(_mutated(tabs, loss=drop))


def test_mutation_interleaved_skip_misroute():
    """The V > 1 stash gather tables are verified per encoder slot."""
    part, g = _interleaved_part(2)
    consumers = _consumers(part, g)
    tabs = StepTables.from_schedule(schedule_for_partition(part, 4),
                                    folded=True, devices=part.devices,
                                    skip_consumers=consumers)
    d, k = np.argwhere(tabs.skip_wr)[1]

    def rotate(a):
        a[d, k] = (a[d, k] + 1) % max(tabs.W_skip, 2)

    failed = _failed(_mutated(tabs, skip_wr_slot=rotate),
                     skip_consumers=consumers)
    assert "matched-store-read" in failed or \
        "no-live-overwrite" in failed or "no-lost-message" in failed


# ===========================================================================
# Certificates and snapshots round-trip
# ===========================================================================

def test_certificate_json_roundtrip():
    cert = certify_tables(_wave_tables(), name="wave3")
    doc = json.loads(cert.to_json())
    back = PlanCertificate.from_json(cert.to_json())
    assert back == cert
    assert doc["schema"] == "repro.plan-certificate/v1"
    with pytest.raises(ValueError, match="schema"):
        PlanCertificate.from_dict({"schema": "bogus"})


def test_plan_snapshot_roundtrip(tmp_path):
    part, g = _interleaved_part(2)
    consumers = _consumers(part, g)
    tabs = StepTables.from_schedule(schedule_for_partition(part, 4),
                                    folded=True, devices=part.devices,
                                    skip_consumers=consumers)
    path = tmp_path / "plan.json"
    export_plan(tabs, path, skip_consumers=consumers, name="il2")
    saved = load_plan(path)
    cert = saved.certify()
    assert cert.ok, cert.violations
    assert saved.tables.num_steps == tabs.num_steps
    assert saved.tables.live_hops == tabs.live_hops
    # the rehydrated tables drive the same interpreter verdicts
    report = interpret_tables(saved.tables,
                              skip_consumers=saved.skip_consumers)
    assert report.ok


def test_certificate_and_snapshot_carry_hybrid_dims(tmp_path):
    """Certificates and saved plans record the (dp, zero_stage) hybrid
    dimensions, show them in summaries, and default pre-hybrid documents
    to the replicated single-replica reading."""
    tabs = _wave_tables(2, 4)
    cert = certify_tables(tabs, name="wave2", dp=2, zero_stage=1)
    assert cert.plan["dp"] == 2 and cert.plan["zero_stage"] == 1
    assert "dp=2 zero=1" in cert.summary()
    base = certify_tables(tabs, name="wave2")
    assert base.plan["dp"] == 1 and base.plan["zero_stage"] == 0
    assert "dp=" not in base.summary()

    path = tmp_path / "plan.json"
    export_plan(tabs, path, name="wave2", dp=2, zero_stage=2)
    saved = load_plan(path)
    assert (saved.dp, saved.zero_stage) == (2, 2)
    cert2 = saved.certify()
    assert cert2.ok and cert2.plan["dp"] == 2 \
        and cert2.plan["zero_stage"] == 2
    # snapshots written before the hybrid axes existed load as dp=1/z=0
    doc = json.loads(path.read_text())
    del doc["dp"], doc["zero_stage"]
    path.write_text(json.dumps(doc))
    old = load_plan(path)
    assert (old.dp, old.zero_stage) == (1, 0)
    assert old.certify().plan["zero_stage"] == 0


def test_verify_cli_on_snapshot(tmp_path):
    from repro.analysis import verify
    tabs = _wave_tables(2, 4)
    good = tmp_path / "good.json"
    export_plan(tabs, good, name="wave2")
    assert verify.main(["--plan", str(good)]) == 0
    bad = tmp_path / "bad.json"
    export_plan(dataclasses.replace(tabs, W_down=tabs.W_down - 1), bad,
                name="shrunk")
    assert verify.main(["--plan", str(bad)]) == 1


# ===========================================================================
# PlanError: structured lowering rejections
# ===========================================================================

def test_plan_error_carries_structure():
    dev = lambda s: min(s, 3 - s)
    with pytest.raises(PlanError, match="skip_consumers") as ei:
        StepTables.from_schedule(template_wave(2, 4), folded=True,
                                 device_of_stage=dev,
                                 skip_consumers=(((),),))
    assert ei.value.check == "program-shape"
    assert isinstance(ei.value, ValueError)
    assert "repro.analysis.verify" in str(ei.value)


def test_plan_error_stage_routing():
    """A schedule synthesized for a permuted device mapping is valid but
    unrealizable on the canonical layout — rejected with coordinates."""
    D, S, M = 2, 4, 4
    permuted = lambda s: (min(s, S - 1 - s) + 1) % D
    sched = greedy_schedule(S, M, permuted, D)
    with pytest.raises(PlanError, match="stage layout") as ei:
        StepTables.from_schedule(
            sched, folded=True,
            device_of_stage=lambda s: min(s, S - 1 - s))
    assert ei.value.check == "stage-routing"
    assert ei.value.device is not None
