"""LSE-merge sharded-KV decode attention == dense reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.collectives import local_attention_with_lse, merge_lse


def test_lse_merge_equals_dense():
    key = jax.random.PRNGKey(0)
    B, S, H, Dh, shards = 2, 64, 4, 16, 4
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, Dh))
    k = jax.random.normal(ks[1], (B, S, H, Dh))
    v = jax.random.normal(ks[2], (B, S, H, Dh))
    valid = 50   # cache only partially filled

    parts = []
    step = S // shards
    for i in range(shards):
        parts.append(local_attention_with_lse(
            q, k[:, i*step:(i+1)*step], v[:, i*step:(i+1)*step],
            kv_offset=i*step, kv_valid_len=valid))
    merged = merge_lse(parts)

    # dense reference
    s = jnp.einsum("bqhd,bshd->bqhs", q, k) / jnp.sqrt(jnp.float32(Dh))
    mask = (jnp.arange(S) < valid)[None, None, None, :]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bqhs,bshd->bqhd", p, v)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_single_shard_degenerate():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 1, 2, 8))
    k = jax.random.normal(key, (1, 16, 2, 8))
    out, m, l = local_attention_with_lse(q, k, k, kv_offset=0,
                                         kv_valid_len=16)
    merged = merge_lse([(out, m, l)])
    s = jnp.einsum("bqhd,bshd->bqhs", q, k) / jnp.sqrt(jnp.float32(8))
    ref = jnp.einsum("bqhs,bshd->bqhd", jax.nn.softmax(s, -1), k)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
