"""LM family: decode==full-forward consistency across attention flavours."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm import (LMConfig, init_lm, lm_loss, prefill, decode_step,
                             forward, unembed)
from repro.models.layers import AttnConfig, MLAConfig, MoEConfig

KEY = jax.random.PRNGKey(0)
TOK = jax.random.randint(KEY, (2, 16), 0, 128)


def _check_decode(cfg, steps=1, rtol=3e-4):
    p = init_lm(KEY, cfg)
    lg, caches = prefill(p, TOK[:, :8], cfg, max_len=16)
    for i in range(steps):
        lg, caches = decode_step(p, TOK[:, 8 + i:9 + i], caches, cfg)
    h, _, _ = forward(p, TOK[:, :8 + steps], cfg)
    ref = unembed(p, h[:, -1:], cfg)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref),
                               rtol=rtol, atol=rtol)
    return p


@pytest.mark.slow
def test_gqa_tied():
    cfg = LMConfig("t", vocab=128, d_model=64, n_layers=4,
                   attn=AttnConfig(64, 4, 2, 16), d_ff=128,
                   tied_embeddings=True)
    p = _check_decode(cfg, steps=3)
    loss = lm_loss(p, {"tokens": TOK}, cfg)
    assert jnp.isfinite(loss)
    g = jax.grad(lambda p: lm_loss(p, {"tokens": TOK}, cfg))(p)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


@pytest.mark.slow
def test_mqa():
    cfg = LMConfig("t", vocab=128, d_model=64, n_layers=3,
                   attn=AttnConfig(64, 4, 1, 16), d_ff=128)
    _check_decode(cfg)


@pytest.mark.slow
def test_swa():
    cfg = LMConfig("t", vocab=128, d_model=64, n_layers=3,
                   attn=AttnConfig(64, 4, 4, 16, window=6), d_ff=128)
    _check_decode(cfg, steps=4)


@pytest.mark.slow
def test_qk_norm_moe_scatter():
    cfg = LMConfig("t", vocab=128, d_model=64, n_layers=3,
                   attn=AttnConfig(64, 4, 2, 16, qk_norm=True),
                   moe=MoEConfig(64, 32, n_experts=8, top_k=2,
                                 capacity_factor=8.0),
                   moe_dispatch="scatter")
    _check_decode(cfg)


@pytest.mark.slow
def test_mla_moe_mtp():
    cfg = LMConfig("t", vocab=128, d_model=64, n_layers=4,
                   mla=MLAConfig(64, 4, q_lora_rank=32, kv_lora_rank=16,
                                 qk_nope_dim=16, qk_rope_dim=8,
                                 v_head_dim=16),
                   d_ff=128,
                   moe=MoEConfig(64, 32, n_experts=4, top_k=2, n_shared=1,
                                 capacity_factor=8.0),
                   n_dense_layers=1, mtp=True)
    p = _check_decode(cfg)
    loss = lm_loss(p, {"tokens": TOK}, cfg)
    assert jnp.isfinite(loss)


@pytest.mark.slow
def test_vision_prefix():
    cfg = LMConfig("t", vocab=128, d_model=64, n_layers=2,
                   attn=AttnConfig(64, 4, 2, 16), d_ff=128, vision_prefix=4)
    p = init_lm(KEY, cfg)
    batch = {"tokens": TOK,
             "prefix_embeds": jax.random.normal(KEY, (2, 4, 64))}
    loss = lm_loss(p, batch, cfg)
    assert jnp.isfinite(loss)


def test_mla_cache_is_compressed():
    from repro.models.lm import init_caches
    mla = MLAConfig(64, 4, q_lora_rank=32, kv_lora_rank=16,
                    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    cfg = LMConfig("t", vocab=128, d_model=64, n_layers=2, mla=mla, d_ff=128)
    caches = init_caches(cfg, 2, 16)
    # latent cache: kv_lora (16) + rope (8) per token — not H*Dh*2
    assert caches["layers"]["kv"].shape == (2, 2, 16, 16)
    assert caches["layers"]["k_rope"].shape == (2, 2, 16, 1, 8)
