"""Hybrid parallelism tuner (paper §VI, Eqs. 14-17)."""
import dataclasses

from repro.core.graph import make_unet_like
from repro.core.hw import V100_CLUSTER, Hardware
from repro.core.tuner import (tune, peak_memory, t_allreduce, t_grad_sync,
                              t_sched_paper, t_sched_simulated,
                              profile_partition, zero_param_state_breakdown,
                              zero_param_state_bytes)
from repro.core.partition import partition


def _graph():
    return make_unet_like(8, 0, enc_time=0.05, dec_time=0.05,
                          act_bytes=64 << 20, skip_bytes=64 << 20,
                          param_bytes=256 << 20)


def test_memory_monotone_in_microbatch():
    g = _graph()
    part = partition(g, 4)
    prof = profile_partition(g, part)
    mems = [peak_memory(prof, 4, b, wave=True) for b in (1, 2, 4, 8)]
    assert all(m2 > m1 for m1, m2 in zip(mems, mems[1:]))


def test_windowed_skip_pricing():
    """The 3-tuple windows form moves the skip stash from the dense
    ``P`` in-flight copies to ``W_skip`` rotating fp32 entries; the legacy
    2-tuple and windows-free forms still price skip dense (back-compat),
    and a profile without the skip split falls back to dense pricing."""
    g = _graph()
    part = partition(g, 4)
    prof = profile_partition(g, part)
    assert prof.skip_bytes_per_sample and any(prof.skip_bytes_per_sample)
    P, b = 4, 2
    legacy = peak_memory(prof, P, b, wave=True, windows=(2, 1))
    w0 = peak_memory(prof, P, b, wave=True, windows=(2, 1, 0))
    w2 = peak_memory(prof, P, b, wave=True, windows=(2, 1, 2))
    i, j = P - 1, P
    skip_dense = P * (prof.skip_bytes_per_sample[i]
                      + prof.skip_bytes_per_sample[j]) * b
    # W_skip=0: the whole dense skip charge is gone
    assert w0 == legacy - skip_dense
    # each W_skip entry bills the largest per-stage payload at fp32
    assert w2 - w0 == 2 * max(prof.skip_bytes_per_sample[i],
                              prof.skip_bytes_per_sample[j]) * b * 2
    # a profile that never split skip out ignores the 3rd window
    # component entirely (skip stays dense inside m_act)
    unsplit = dataclasses.replace(prof, skip_bytes_per_sample=())
    assert peak_memory(unsplit, P, b, wave=True, windows=(2, 1, 2)) == legacy


def test_paper_model_overlap_term():
    """Eq. (15)'s overlap-aware comm term: hidden steady-state hops cost
    max(0, p2p - t_f), so the overlapped price is <= the synchronous one
    and they coincide when every hop is exposed (no steady state)."""
    g = _graph()
    prof = profile_partition(g, partition(g, 4))
    hw = V100_CLUSTER
    for b in (1, 4):
        ov = t_sched_paper(prof, 4, b, 4, hw)
        sync = t_sched_paper(prof, 4, b, 4, hw, overlap=False)
        assert ov <= sync
    # simulation scoring exposes the same knob
    sim_ov = t_sched_simulated(prof, 4, 1, 4, hw, microbatches=4, wave=True)
    sim_sync = t_sched_simulated(prof, 4, 1, 4, hw, microbatches=4,
                                 wave=True, overlap=False)
    assert sim_ov <= sim_sync
    # overlap=True choices never rank worse than their sync-priced twins
    a = tune(g, 16, hw=hw)[0]
    s = tune(g, 16, hw=hw, overlap=False)[0]
    assert a.t_sample <= s.t_sample + 1e-12


def test_allreduce_model():
    hw = V100_CLUSTER
    assert t_allreduce(1 << 30, 1, hw) == 0.0
    t8 = t_allreduce(1 << 30, 8, hw)
    t16 = t_allreduce(1 << 30, 16, hw)
    assert 0 < t8 < t16 < 2 * (1 << 30) / hw.intra_bw + 1e-3


def test_tuner_respects_memory_limit():
    g = _graph()
    tight = dataclasses.replace(V100_CLUSTER, mem_limit=8 * (1 << 30))
    choices = tune(g, 16, hw=tight)
    assert choices, "some config must be feasible"
    assert all(c.peak_mem < tight.mem_limit for c in choices)


def test_tuner_prefers_pp_when_comm_bound():
    """On a comm-starved cluster with a heavy model, pure DP pays a huge
    all-reduce; the tuner should pick P > 1 (paper Fig. 10 Ascend trend)."""
    g = make_unet_like(8, 0, enc_time=0.01, dec_time=0.01,
                       act_bytes=1 << 20, skip_bytes=1 << 20,
                       param_bytes=2 << 30)       # 2 GiB per block
    slow_net = Hardware("slow", 100e12, 1e12, 2e9, 1e9, 32 * (1 << 30))
    best = tune(g, 16, hw=slow_net)[0]
    assert best.P > 1


def test_tuner_choice_records_scored_microbatches():
    """Every choice carries the M its t_sched score assumed (default: the
    M = P setting Eq. 15's closed form prices), so the compile path can
    execute the same iteration shape it ranked."""
    g = _graph()
    for c in tune(g, 16, hw=V100_CLUSTER):
        assert c.M == max(c.P, 1)
        # Eq. (17): t_sample is the scored iteration over b*M*G samples
        assert abs(c.t_sample * (c.b * c.M * c.G) - c.t_sched) < 1e-9
    override = tune(g, 16, hw=V100_CLUSTER,
                    microbatches_per_iter=lambda P: 2 * P)
    assert all(c.M == 2 * c.P for c in override)
    # the paper cost model prices the overridden M (a 2P iteration costs
    # more than the default P iteration for the same P, G, b, V — the
    # interleave axis makes V part of a candidate's identity)
    base = {(c.P, c.G, c.b, c.V): c for c in tune(g, 16, hw=V100_CLUSTER)}
    priced = [c for c in override
              if c.P > 1 and (c.P, c.G, c.b, c.V) in base]
    assert priced
    for c in priced:
        assert c.t_sched > base[(c.P, c.G, c.b, c.V)].t_sched


def test_simulation_mode_agrees_on_ranking():
    g = _graph()
    a = tune(g, 16, hw=V100_CLUSTER)[0]
    b = tune(g, 16, hw=V100_CLUSTER, use_simulation=True)[0]
    assert abs(a.t_sample / max(b.t_sample, 1e-12)) < 50   # same ballpark


# ---------------------------------------------------------------------------
# ZeRO x pipeline hybrid axes
# ---------------------------------------------------------------------------

def test_zero_param_state_bytes_legacy_identity():
    """dp <= 1 or zero_stage == 0 must reproduce the historical
    ``param_state_factor * m_theta`` lump bit-for-bit — the tuner's
    pinned-arithmetic tests ride on it."""
    m = 256 * (1 << 20) * 1.0
    assert zero_param_state_bytes(m) == 7.0 * m
    assert zero_param_state_bytes(m, dp=8, zero_stage=0) == 7.0 * m
    assert zero_param_state_bytes(m, dp=1, zero_stage=2) == 7.0 * m


def test_zero_param_state_breakdown_shards():
    """ZeRO-1 divides only the optimizer term by dp; ZeRO-2 also divides
    params-at-rest and grads, and adds one transient gathered copy."""
    m, dp = 1024.0, 4
    z0 = zero_param_state_breakdown(m, dp=dp, zero_stage=0)
    z1 = zero_param_state_breakdown(m, dp=dp, zero_stage=1)
    z2 = zero_param_state_breakdown(m, dp=dp, zero_stage=2)
    assert z0 == {"params": m, "grads": m, "opt": 5.0 * m, "gathered": 0.0}
    assert z1["params"] == m and z1["opt"] == 5.0 * m / dp
    assert z2["params"] == m / dp and z2["grads"] == m / dp
    assert z2["opt"] == 5.0 * m / dp and z2["gathered"] == m
    assert sum(z2.values()) < sum(z1.values()) < sum(z0.values())


def test_peak_memory_zero_charges_sharded_bytes():
    """peak_memory(dp, zero_stage) lowers exactly by the sharded
    param-state delta and never touches the activation terms."""
    g = _graph()
    part = partition(g, 4)
    prof = profile_partition(g, part)
    base = peak_memory(prof, 4, 2, wave=True)
    assert peak_memory(prof, 4, 2, wave=True, dp=4, zero_stage=0) == base
    i, j = 3, 4
    m_theta = prof.param_bytes[i] + prof.param_bytes[j]
    for z in (1, 2):
        got = peak_memory(prof, 4, 2, wave=True, dp=4, zero_stage=z)
        delta = (zero_param_state_bytes(m_theta)
                 - zero_param_state_bytes(m_theta, dp=4, zero_stage=z,
                                          m_gather=m_theta))
        assert abs((base - got) - delta) < 1e-6
        assert got < base


def test_t_grad_sync_prices_zero_volume():
    """Stage 0/1 gradient sync is the ring all-reduce; stage 2's
    all-gather + reduce-scatter moves the same 2(G-1)/G bytes (ZeRO's
    core claim), so the times coincide — memory, not wire time, drives
    stage selection."""
    hw = V100_CLUSTER
    pb, G = float(1 << 30), 8
    assert t_grad_sync(pb, 1, hw, 2) == 0.0
    assert t_grad_sync(pb, G, hw, 0) == t_allreduce(pb, G, hw)
    assert t_grad_sync(pb, G, hw, 1) == t_allreduce(pb, G, hw)
    assert abs(t_grad_sync(pb, G, hw, 2) - t_allreduce(pb, G, hw)) < 1e-12


def test_tuner_zero_ties_break_toward_less_sharding():
    """With identical modelled times across zero stages, the sort prefers
    the least sharding machinery: the top choice at any (P, G, b) is the
    zero_stage=0 variant when memory is not binding."""
    g = _graph()
    choices = tune(g, 16, hw=V100_CLUSTER)
    assert any(c.zero_stage > 0 for c in choices if c.G > 1)
    groups = {}
    for c in choices:             # choices are already rank-sorted
        groups.setdefault((c.P, c.G, c.b, c.V), []).append(c)
    for group in groups.values():
        if any(c.zero_stage == 0 for c in group):
            assert group[0].zero_stage == 0, group
    # sharding relaxes the memory constraint, never tightens it: some
    # microbatch sizes are reachable only with zero_stage > 0
    assert any(all(c.zero_stage > 0 for c in g2) for g2 in groups.values())
    assert all(c.dp == c.G for c in choices)


def test_tuner_zero_unlocks_memory_constrained_granite():
    """The acceptance flip on granite-34b: pipeline depth alone always
    minimises peak memory (sharding params over P stages avoids ZeRO-2's
    transient gathered copy), so a budget that kills *every* replicated
    candidate kills the hybrids too.  The win is per candidate: pick a
    budget between the (P=4, G=2) b=1 peaks at zero_stage 0 and 1 — now
    the replicated search can only fall back to the slow full-depth P=8
    pipeline, while the hybrid search returns a previously-infeasible
    shallower (P, dp, zero_stage > 0) plan that is strictly faster, and
    the drop reasons name the memory constraint that killed the
    replicated shallow candidates."""
    from repro.configs import granite_34b
    from repro.models.lm import lm_pipeline_graph
    g = lm_pipeline_graph(granite_34b.CFG)
    N = 8
    roomy = dataclasses.replace(V100_CLUSTER, mem_limit=1e18)
    all_c = tune(g, N, hw=roomy)
    p0 = next(c.peak_mem for c in all_c
              if (c.P, c.G, c.b, c.zero_stage) == (4, 2, 1, 0))
    pz = next(c.peak_mem for c in all_c
              if (c.P, c.G, c.b, c.zero_stage) == (4, 2, 1, 1))
    assert pz < p0
    tight = dataclasses.replace(V100_CLUSTER, mem_limit=(p0 + pz) / 2)

    drops0 = []
    only0 = tune(g, N, hw=tight, zero_stages=(0,), drops=drops0)
    assert only0 and {c.P for c in only0} == {N}, \
        "replicated search must be pushed to the full-depth pipeline"
    assert any("exceeds the memory budget" in d for d in drops0)

    drops = []
    feasible = tune(g, N, hw=tight, drops=drops)
    assert feasible
    best = feasible[0]
    assert best.zero_stage > 0 and best.dp == best.G > 1 and best.P > 1
    assert best.t_sample < only0[0].t_sample, \
        "the unlocked hybrid must beat the replicated fallback"
    assert all(c.peak_mem < tight.mem_limit for c in feasible)
    # both demise stories are visible in the drop reasons: replicated
    # shallow candidates die on the plain budget line, and the sharded
    # variants that still don't fit say so in ZeRO terms
    assert any("memory budget" in d and "zero" not in d for d in drops)
    assert any("even with ZeRO-" in d for d in drops)
