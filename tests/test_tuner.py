"""Hybrid parallelism tuner (paper §VI, Eqs. 14-17)."""
import dataclasses

from repro.core.graph import make_unet_like
from repro.core.hw import V100_CLUSTER, Hardware
from repro.core.tuner import tune, peak_memory, t_allreduce, profile_partition
from repro.core.partition import partition


def _graph():
    return make_unet_like(8, 0, enc_time=0.05, dec_time=0.05,
                          act_bytes=64 << 20, skip_bytes=64 << 20,
                          param_bytes=256 << 20)


def test_memory_monotone_in_microbatch():
    g = _graph()
    part = partition(g, 4)
    prof = profile_partition(g, part)
    mems = [peak_memory(prof, 4, b, wave=True) for b in (1, 2, 4, 8)]
    assert all(m2 > m1 for m1, m2 in zip(mems, mems[1:]))


def test_allreduce_model():
    hw = V100_CLUSTER
    assert t_allreduce(1 << 30, 1, hw) == 0.0
    t8 = t_allreduce(1 << 30, 8, hw)
    t16 = t_allreduce(1 << 30, 16, hw)
    assert 0 < t8 < t16 < 2 * (1 << 30) / hw.intra_bw + 1e-3


def test_tuner_respects_memory_limit():
    g = _graph()
    tight = dataclasses.replace(V100_CLUSTER, mem_limit=8 * (1 << 30))
    choices = tune(g, 16, hw=tight)
    assert choices, "some config must be feasible"
    assert all(c.peak_mem < tight.mem_limit for c in choices)


def test_tuner_prefers_pp_when_comm_bound():
    """On a comm-starved cluster with a heavy model, pure DP pays a huge
    all-reduce; the tuner should pick P > 1 (paper Fig. 10 Ascend trend)."""
    g = make_unet_like(8, 0, enc_time=0.01, dec_time=0.01,
                       act_bytes=1 << 20, skip_bytes=1 << 20,
                       param_bytes=2 << 30)       # 2 GiB per block
    slow_net = Hardware("slow", 100e12, 1e12, 2e9, 1e9, 32 * (1 << 30))
    best = tune(g, 16, hw=slow_net)[0]
    assert best.P > 1


def test_tuner_choice_records_scored_microbatches():
    """Every choice carries the M its t_sched score assumed (default: the
    M = P setting Eq. 15's closed form prices), so the compile path can
    execute the same iteration shape it ranked."""
    g = _graph()
    for c in tune(g, 16, hw=V100_CLUSTER):
        assert c.M == max(c.P, 1)
        # Eq. (17): t_sample is the scored iteration over b*M*G samples
        assert abs(c.t_sample * (c.b * c.M * c.G) - c.t_sched) < 1e-9
    override = tune(g, 16, hw=V100_CLUSTER,
                    microbatches_per_iter=lambda P: 2 * P)
    assert all(c.M == 2 * c.P for c in override)
    # the paper cost model prices the overridden M (a 2P iteration costs
    # more than the default P iteration for the same P, G, b, V — the
    # interleave axis makes V part of a candidate's identity)
    base = {(c.P, c.G, c.b, c.V): c for c in tune(g, 16, hw=V100_CLUSTER)}
    priced = [c for c in override
              if c.P > 1 and (c.P, c.G, c.b, c.V) in base]
    assert priced
    for c in priced:
        assert c.t_sched > base[(c.P, c.G, c.b, c.V)].t_sched


def test_simulation_mode_agrees_on_ranking():
    g = _graph()
    a = tune(g, 16, hw=V100_CLUSTER)[0]
    b = tune(g, 16, hw=V100_CLUSTER, use_simulation=True)[0]
    assert abs(a.t_sample / max(b.t_sample, 1e-12)) < 50   # same ballpark
