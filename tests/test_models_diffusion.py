"""Diffusion backbones + DDPM objective + block-graph exports."""
import jax
import pytest
import jax.numpy as jnp

from repro.models.diffusion import (UViTConfig, init_uvit, uvit_loss,
                                    uvit_apply, uvit_block_graph,
                                    HunyuanDiTConfig, init_hunyuan,
                                    hunyuan_loss, hunyuan_block_graph,
                                    UNetConfig, init_unet, unet_loss,
                                    unet_block_graph, cosine_alpha_bar)

KEY = jax.random.PRNGKey(2)


def test_cosine_schedule_bounds():
    t = jnp.linspace(0, 1, 11)
    ab = cosine_alpha_bar(t)
    assert float(ab[0]) > 0.99
    assert float(ab[-1]) < 0.01
    assert bool(jnp.all(ab[:-1] >= ab[1:]))


@pytest.mark.slow
def test_uvit_loss_and_shapes():
    cfg = UViTConfig("t", img_size=8, in_ch=4, patch=2, d_model=32,
                     n_layers=4, n_heads=4, d_ff=64, n_classes=10)
    p = init_uvit(KEY, cfg)
    batch = {"latents": jax.random.normal(KEY, (2, 8, 8, 4)),
             "labels": jnp.array([1, 2])}
    pred = uvit_apply(p, batch["latents"], jnp.array([0.1, 0.9]), batch, cfg)
    assert pred.shape == (2, 8, 8, 4)
    loss = uvit_loss(p, batch, KEY, cfg)
    assert jnp.isfinite(loss)
    g = jax.grad(lambda p: uvit_loss(p, batch, KEY, cfg))(p)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


def test_skip_kernel_differential_uvit():
    """use_skip_kernel routes every decoder skip-in through the fused
    Pallas skip_concat_matmul (interpret mode on CPU); forward and grads
    must match the jnp.concatenate(...) @ skip_proj reference."""
    import dataclasses
    import numpy as np
    cfg = UViTConfig("t", img_size=8, in_ch=4, patch=2, d_model=32,
                     n_layers=4, n_heads=4, d_ff=64, n_classes=10)
    cfg_k = dataclasses.replace(cfg, use_skip_kernel=True)
    p = init_uvit(KEY, cfg)
    batch = {"latents": jax.random.normal(KEY, (2, 8, 8, 4)),
             "labels": jnp.array([1, 2])}
    t = jnp.array([0.1, 0.9])
    ref = uvit_apply(p, batch["latents"], t, batch, cfg)
    ker = uvit_apply(p, batch["latents"], t, batch, cfg_k)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
    gr = jax.grad(lambda p: uvit_loss(p, batch, KEY, cfg))(p)
    gk = jax.grad(lambda p: uvit_loss(p, batch, KEY, cfg_k))(p)
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_skip_kernel_differential_hunyuan():
    """Same flag on the Hunyuan-DiT decoder blocks (adaLN + cross-attn
    around the fused skip-in)."""
    import dataclasses
    import numpy as np
    from repro.models.diffusion import hunyuan_apply
    cfg = HunyuanDiTConfig("t", img_size=8, in_ch=4, patch=2, d_model=32,
                           n_layers=4, n_heads=4, d_ff=64, ctx_dim=16,
                           ctx_len=7)
    cfg_k = dataclasses.replace(cfg, use_skip_kernel=True)
    p = init_hunyuan(KEY, cfg)
    batch = {"latents": jax.random.normal(KEY, (2, 8, 8, 4)),
             "text_embeds": jax.random.normal(KEY, (2, 7, 16))}
    t = jnp.array([0.1, 0.9])
    ref = hunyuan_apply(p, batch["latents"], t, batch, cfg)
    ker = hunyuan_apply(p, batch["latents"], t, batch, cfg_k)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_uvit_graph_nested_symmetric():
    cfg = UViTConfig("t", img_size=8, d_model=32, n_layers=8, n_heads=4,
                     d_ff=64)
    g = uvit_block_graph(cfg, 2)
    assert g.is_nested()
    assert len(g.skips) == cfg.half
    for e in g.skips:
        assert g.blocks[e.src].name.startswith("enc")
        assert g.blocks[e.dst].name.startswith("dec")


@pytest.mark.slow
def test_hunyuan_loss():
    cfg = HunyuanDiTConfig("t", img_size=8, in_ch=4, patch=2, d_model=32,
                           n_layers=4, n_heads=4, d_ff=64, ctx_dim=16,
                           ctx_len=7)
    p = init_hunyuan(KEY, cfg)
    batch = {"latents": jax.random.normal(KEY, (2, 8, 8, 4)),
             "text_embeds": jax.random.normal(KEY, (2, 7, 16))}
    loss = hunyuan_loss(p, batch, KEY, cfg)
    assert jnp.isfinite(loss)
    assert hunyuan_block_graph(cfg, 2).is_nested()


@pytest.mark.slow
def test_unet_loss_and_heterogeneous_graph():
    cfg = UNetConfig("t", img_size=16, in_ch=4, base_ch=16, ch_mults=(1, 2),
                     blocks_per_level=2, attn_levels=(1,), ctx_dim=16,
                     n_heads=4)
    p = init_unet(KEY, cfg)
    batch = {"latents": jax.random.normal(KEY, (2, 16, 16, 4)),
             "text_embeds": jax.random.normal(KEY, (2, 7, 16))}
    loss = unet_loss(p, batch, KEY, cfg)
    assert jnp.isfinite(loss)
    g = unet_block_graph(cfg, 2)
    assert g.is_nested()
    times = [b.fwd_time for b in g.blocks]
    assert max(times) / (sum(times) / len(times)) > 1.5  # Fig. 6 heavy tail
