"""Partitioner: Algorithm 1 vs brute-force reference; invariants."""
import random

import pytest
from helpers.hypothesis_compat import given, settings, st

from repro.core.graph import Block, BlockGraph, SkipEdge, make_unet_like
from repro.core.partition import (partition, partition_bidirectional,
                                  partition_reference, linear_partition,
                                  blockwise_partition)


def _random_nested_graph(rnd, n_pairs, mid):
    g = make_unet_like(n_pairs, mid)
    blocks = tuple(
        Block(b.name, rnd.uniform(0.2, 3.0), b.param_bytes,
              int(b.act_bytes * rnd.uniform(0.5, 2.0)), b.skip_bytes)
        for b in g.blocks)
    return BlockGraph(blocks, g.skips)


@given(st.integers(2, 4), st.integers(0, 2), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_bidirectional_matches_bruteforce(n_pairs, mid, seed):
    rnd = random.Random(seed)
    g = _random_nested_graph(rnd, n_pairs, mid)
    for p in (2, 4):
        if p > g.n:
            continue
        got = partition_bidirectional(g, p, lam=0.0)
        ref = partition_reference(g, p, lam=0.0)
        assert abs(got.objective - ref.objective) < 1e-9
        assert got.validate_collocation(g)


@given(st.integers(2, 4), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_bidirectional_with_comm_term(n_pairs, seed):
    rnd = random.Random(seed)
    g = _random_nested_graph(rnd, n_pairs, 1)
    got = partition_bidirectional(g, 4, lam=1.0)
    ref = partition_reference(g, 4, lam=1.0)
    assert abs(got.objective - ref.objective) < 1e-9


@given(st.lists(st.floats(0.1, 5.0), min_size=6, max_size=20),
       st.integers(2, 5))
@settings(max_examples=30, deadline=None)
def test_linear_partition_beats_blockwise(times, p):
    g = BlockGraph(tuple(Block(f"b{i}", t) for i, t in enumerate(times)))
    if p > g.n:
        return
    lp = linear_partition(g, p, lam=0.0)
    bw = blockwise_partition(g, p, lam=0.0)
    assert lp.objective <= bw.objective + 1e-9
    # lower bound: total/p and max single block
    assert lp.objective >= max(max(times), sum(times) / p) - 1e-9


def _random_partial_graph(rnd, n_pairs, mid, keep_prob=0.7, odd=False):
    """Partially-skipped graph: random pair subset dropped, optional mid
    blocks, optionally an odd total block count (extra tail block)."""
    g = make_unet_like(n_pairs, mid + (1 if odd else 0))
    kept = tuple(e for e in g.skips if rnd.random() < keep_prob)
    blocks = tuple(
        Block(b.name, rnd.uniform(0.2, 3.0), b.param_bytes,
              int(b.act_bytes * rnd.uniform(0.5, 2.0)), b.skip_bytes)
        for b in g.blocks)
    return BlockGraph(blocks, kept)


@given(st.integers(2, 4), st.integers(0, 2), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_bidirectional_matches_bruteforce_partially_skipped(n_pairs, mid,
                                                            seed):
    """The generalized DP returns the brute-force optimum on partially
    skipped graphs (sparse pairs, mid-block bottlenecks, odd block counts)
    — the shapes whose optima are mirror-asymmetric folds — and always
    satisfies collocation."""
    rnd = random.Random(seed)
    g = _random_partial_graph(rnd, n_pairs, mid, odd=bool(seed % 2))
    for p in (2, 4):
        if p > g.n:
            continue
        if not g.skips:
            got = partition_bidirectional(g, p, lam=0.0)
            assert got.folded and sum(got.stage_sizes()) == g.n
            continue
        got = partition_bidirectional(g, p, lam=0.0)
        ref = partition_reference(g, p, lam=0.0)
        assert abs(got.objective - ref.objective) < 1e-9
        assert got.validate_collocation(g)
        assert sum(got.stage_sizes()) == g.n


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_bidirectional_handles_crossing_skips(seed):
    """Non-nested (crossing) skip sets no longer detour through the
    exponential reference: the DP itself matches its objective."""
    rnd = random.Random(seed)
    n = rnd.randint(6, 9)
    blocks = tuple(Block(f"b{i}", rnd.uniform(0.2, 3.0)) for i in range(n))
    # two crossing skips within the feasible half-split structure
    s0, s1 = 0, 1
    d0 = rnd.randint(n // 2, n - 2)
    d1 = rnd.randint(d0 + 1, n - 1)          # dst order follows src order
    g = BlockGraph(blocks, (SkipEdge(s0, d0, 8), SkipEdge(s1, d1, 8)))
    assert not g.is_nested()
    try:
        ref = partition_reference(g, 2, lam=0.0)
    except ValueError:
        with pytest.raises(ValueError):
            partition_bidirectional(g, 2, lam=0.0)
        return
    got = partition_bidirectional(g, 2, lam=0.0)
    assert abs(got.objective - ref.objective) < 1e-9
    assert got.validate_collocation(g)


def test_symmetric_fold_odd_block_count():
    """Odd n folds: the unpaired middle block rides the innermost device;
    the result is asymmetric by one block and covers every block."""
    g = BlockGraph(tuple(Block(f"b{i}", 1.0 + 0.1 * i) for i in range(9)))
    part = partition_bidirectional(g, 4, lam=0.0)
    assert part.folded and sum(part.stage_sizes()) == 9
    assert not part.mirror_symmetric()
    # middle block (index 4) sits on the innermost device
    assert part.device_of_stage(part.stage_of_block(4)) == 1


def test_partition_devices_explicit():
    """The stage->device mapping is an explicit field, consistent with the
    legacy closed forms, and drives collocated_pairs."""
    g = make_unet_like(4, 1)
    part = partition_bidirectional(g, 4, lam=0.0)
    assert part.devices == (0, 1, 1, 0)
    assert part.collocated_pairs() == ((0, 3), (1, 2))
    lin = linear_partition(BlockGraph(g.blocks), 3, lam=0.0)
    assert lin.devices == (0, 1, 2) and lin.collocated_pairs() == ()
    import dataclasses as dc
    with pytest.raises(ValueError, match="devices"):
        dc.replace(part, devices=(0, 1))


def test_folded_device_mapping():
    g = make_unet_like(8, 0)
    part = partition(g, 4)
    assert part.num_stages == 8 and part.folded
    assert [part.device_of_stage(s) for s in range(8)] == [0, 1, 2, 3, 3, 2, 1, 0]
    assert part.validate_collocation(g)


def test_skipless_graph_degenerates_to_linear():
    g = BlockGraph(tuple(Block(f"b{i}", 1.0) for i in range(12)))
    part = partition(g, 4)
    assert not part.folded and part.num_stages == 4


def test_infeasible_raises():
    g = make_unet_like(2, 0)   # 4 blocks
    with pytest.raises(ValueError):
        partition_bidirectional(g, 6, lam=0.0)


def test_paper_fig7_style_improvement():
    """Heterogeneous UNet-like graph: skip-aware DP must beat block-wise."""
    from repro.models.diffusion import UNetConfig, unet_block_graph
    cfg = UNetConfig("x", img_size=32, base_ch=64, ch_mults=(1, 2, 4, 4),
                     blocks_per_level=2, attn_levels=(1, 2, 3), ctx_dim=256)
    g = unet_block_graph(cfg, batch=8)
    dp = partition_bidirectional(g, 8, lam=0.0)
    bw = blockwise_partition(g, 8, folded=True, lam=0.0)
    assert dp.objective < bw.objective
