"""Partitioner: Algorithm 1 vs brute-force reference; invariants."""
import random

import pytest
from helpers.hypothesis_compat import given, settings, st

from repro.core.graph import Block, BlockGraph, make_unet_like
from repro.core.partition import (partition, partition_bidirectional,
                                  partition_reference, linear_partition,
                                  blockwise_partition)


def _random_nested_graph(rnd, n_pairs, mid):
    g = make_unet_like(n_pairs, mid)
    blocks = tuple(
        Block(b.name, rnd.uniform(0.2, 3.0), b.param_bytes,
              int(b.act_bytes * rnd.uniform(0.5, 2.0)), b.skip_bytes)
        for b in g.blocks)
    return BlockGraph(blocks, g.skips)


@given(st.integers(2, 4), st.integers(0, 2), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_bidirectional_matches_bruteforce(n_pairs, mid, seed):
    rnd = random.Random(seed)
    g = _random_nested_graph(rnd, n_pairs, mid)
    for p in (2, 4):
        if p > g.n:
            continue
        got = partition_bidirectional(g, p, lam=0.0)
        ref = partition_reference(g, p, lam=0.0)
        assert abs(got.objective - ref.objective) < 1e-9
        assert got.validate_collocation(g)


@given(st.integers(2, 4), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_bidirectional_with_comm_term(n_pairs, seed):
    rnd = random.Random(seed)
    g = _random_nested_graph(rnd, n_pairs, 1)
    got = partition_bidirectional(g, 4, lam=1.0)
    ref = partition_reference(g, 4, lam=1.0)
    assert abs(got.objective - ref.objective) < 1e-9


@given(st.lists(st.floats(0.1, 5.0), min_size=6, max_size=20),
       st.integers(2, 5))
@settings(max_examples=30, deadline=None)
def test_linear_partition_beats_blockwise(times, p):
    g = BlockGraph(tuple(Block(f"b{i}", t) for i, t in enumerate(times)))
    if p > g.n:
        return
    lp = linear_partition(g, p, lam=0.0)
    bw = blockwise_partition(g, p, lam=0.0)
    assert lp.objective <= bw.objective + 1e-9
    # lower bound: total/p and max single block
    assert lp.objective >= max(max(times), sum(times) / p) - 1e-9


def test_folded_device_mapping():
    g = make_unet_like(8, 0)
    part = partition(g, 4)
    assert part.num_stages == 8 and part.folded
    assert [part.device_of_stage(s) for s in range(8)] == [0, 1, 2, 3, 3, 2, 1, 0]
    assert part.validate_collocation(g)


def test_skipless_graph_degenerates_to_linear():
    g = BlockGraph(tuple(Block(f"b{i}", 1.0) for i in range(12)))
    part = partition(g, 4)
    assert not part.folded and part.num_stages == 4


def test_infeasible_raises():
    g = make_unet_like(2, 0)   # 4 blocks
    with pytest.raises(ValueError):
        partition_bidirectional(g, 6, lam=0.0)


def test_paper_fig7_style_improvement():
    """Heterogeneous UNet-like graph: skip-aware DP must beat block-wise."""
    from repro.models.diffusion import UNetConfig, unet_block_graph
    cfg = UNetConfig("x", img_size=32, base_ch=64, ch_mults=(1, 2, 4, 4),
                     blocks_per_level=2, attn_levels=(1, 2, 3), ctx_dim=256)
    g = unet_block_graph(cfg, batch=8)
    dp = partition_bidirectional(g, 8, lam=0.0)
    bw = blockwise_partition(g, 8, folded=True, lam=0.0)
    assert dp.objective < bw.objective
