"""Liveness windows & channel activity: lowering == event-driven replay.

The lowering (``StepTables.from_schedule``) derives, by first-fit interval
coloring, the rotating-buffer windows W_down/W_up/W_turn/W_skip and the
per-step ring-activity masks the executors lower.  These property tests
cross-check every window against an INDEPENDENT event-driven replay of the
schedule (a message is in flight from the step after its producer runs
until its consumer runs, inclusive; a stash entry from its write until its
last read), across random valid schedules: greedy, duration-aware timed
greedy in all priority orientations, and (nightly) the exact ILP, for
interleave degrees V in {1, 2, 4}.

They also hold the planner and the executor to AGREE on the overlap
accounting: the planner-side ``core.schedule.comm_stats`` must report the
same windows, live-hop counts, and exposed/hidden hop split as the
executors' own lowering, and the double-buffered overlap mode never needs
buffers beyond the proven windows (exposed + hidden == live, per ring).
"""
import random

import pytest

from helpers.hypothesis_compat import given, settings, st
from repro.core.comm_model import overlap_accounting
from repro.core.partition import interleaved_wave_devices
from repro.core.schedule import (TIMED_PRIORITIES, comm_stats,
                                 greedy_schedule, greedy_schedule_timed,
                                 ilp_schedule, template_1f1b, template_wave,
                                 validate_schedule)
from repro.runtime.schedule_exec import StepTables


def replay_windows(sched, device_of_stage, folded):
    """Event-driven reference: max simultaneously-live entries per buffer.

    Deliberately brute force (per-step overlap counting, no coloring) so a
    bug in the lowering's interval analysis cannot hide in a shared
    implementation.
    """
    S = sched.S
    half = S // 2 if folded else S
    fwd = [p for p in sched.placements if p.virtual < S]
    steps = sorted({p.step for p in fwd})
    k_of_step = {t: k for k, t in enumerate(steps)}
    k_of = {(p.virtual, p.microbatch): k_of_step[p.step] for p in fwd}
    T = len(steps)

    def peak(intervals_by_dev):
        best = 0
        for ivs in intervals_by_dev.values():
            for k in range(T):
                best = max(best, sum(1 for a, b in ivs if a <= k <= b))
        return best

    rings = {"down": {}, "up": {}}
    n_msgs = {"down": 0, "up": 0}
    n_exposed = {"down": 0, "up": 0}
    for p in fwd:
        v, m = p.virtual, p.microbatch
        if v >= S - 1 or (folded and v == half - 1):
            continue                       # loss stage / local turnaround
        ring = "down" if v < half else "up"
        dst = device_of_stage(v + 1)
        rings[ring].setdefault(dst, []).append(
            (k_of[(v, m)] + 1, k_of[(v + 1, m)]))
        n_msgs[ring] += 1
        # exposed = the consumer runs on the very next forward step, so
        # the overlapped executor has no compute to hide the hop under
        if k_of[(v + 1, m)] == k_of[(v, m)] + 1:
            n_exposed[ring] += 1

    turn = {}
    if folded:
        for m in range(sched.M):
            kw = k_of.get((half - 1, m))
            kr = k_of.get((half, m))
            if kw is not None and kr is not None:
                turn.setdefault(device_of_stage(half - 1), []).append(
                    (kw, kr))

    # conservative skip liveness: an encoder slot's stash entry lives from
    # its write until the device's LAST decoder task of that microbatch
    skip = {}
    if folded:
        last_dec = {}
        for p in fwd:
            if p.virtual >= half:
                key = (p.device, p.microbatch)
                k = k_of[(p.virtual, p.microbatch)]
                if last_dec.get(key, -1) < k:
                    last_dec[key] = k
        for p in fwd:
            if p.virtual < half:
                end = last_dec.get((p.device, p.microbatch))
                if end is not None:
                    skip.setdefault(p.device, []).append(
                        (k_of[(p.virtual, p.microbatch)], end))

    return {"W_down": peak(rings["down"]), "W_up": peak(rings["up"]),
            "W_turn": peak(turn), "W_skip": peak(skip),
            "n_down": n_msgs["down"], "n_up": n_msgs["up"],
            "x_down": n_exposed["down"], "x_up": n_exposed["up"]}


def _check(sched, device_of_stage, folded):
    tabs = StepTables.from_schedule(sched, folded=folded,
                                    device_of_stage=device_of_stage)
    ref = replay_windows(sched, device_of_stage, folded)
    assert tabs.W_down == ref["W_down"], (tabs.W_down, ref)
    assert tabs.W_up == ref["W_up"], (tabs.W_up, ref)
    assert tabs.W_turn == ref["W_turn"], (tabs.W_turn, ref)
    assert tabs.W_skip == ref["W_skip"], (tabs.W_skip, ref)
    # the send masks mark exactly the hops that carry a message
    down, up = tabs.live_hops
    assert down == ref["n_down"] and up == ref["n_up"]
    assert down + up <= tabs.dense_hops
    # overlap accounting: every live hop is exposed or hidden, nothing
    # else — the double-buffered mode restructures WHEN hops are issued,
    # never how many, so it cannot widen the proven windows above
    assert tabs.exposed_down == ref["x_down"], (tabs.exposed_down, ref)
    assert tabs.exposed_up == ref["x_up"], (tabs.exposed_up, ref)
    assert tabs.exposed_hops + tabs.hidden_hops == down + up
    assert 0 <= tabs.hidden_hops
    # planner/executor agreement: the pure-python analysis the synthesizer
    # and tuner consult reports the identical windows + hop classification
    stats = comm_stats(sched, device_of_stage, folded)
    assert (stats.W_down, stats.W_up, stats.W_turn, stats.W_skip) == \
        (tabs.W_down, tabs.W_up, tabs.W_turn, tabs.W_skip)
    assert stats.live_hops == tabs.live_hops
    assert (stats.exposed_down, stats.exposed_up) == \
        (tabs.exposed_down, tabs.exposed_up)
    assert overlap_accounting(stats) == overlap_accounting(tabs)
    return tabs


def test_templates_windows_below_M():
    """Classic templates: the receive windows the lowering proves are far
    below the O(M) buffers the executors used to carry."""
    for D, M in [(2, 4), (4, 8), (4, 3)]:
        tabs = _check(template_wave(D, M),
                      lambda s, S=2 * D: min(s, S - 1 - s), True)
        assert tabs.W_down < M and tabs.W_up < M
        assert tabs.W_turn <= 2
    for D, M in [(2, 4), (4, 8)]:
        tabs = _check(template_1f1b(D, M), lambda s: s, False)
        assert tabs.W_down < M
        assert tabs.rings == 1 and tabs.W_up == 0 == tabs.W_turn


@given(st.integers(2, 4), st.integers(2, 5), st.sampled_from([1, 2, 4]),
       st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_windows_match_replay_greedy_and_timed(D, M, V, seed):
    """Lowering-derived windows == event-driven replay for the greedy and
    all duration-aware timed-greedy schedules on interleaved folds."""
    rnd = random.Random(seed)
    S = 2 * V * D
    devices = interleaved_wave_devices(S, D)
    dev = lambda s: devices[s]
    _check(greedy_schedule(S, M, dev, D), dev, True)
    times = [rnd.uniform(0.1, 2.0) for _ in range(S)]
    for prio in TIMED_PRIORITIES:
        sched = greedy_schedule_timed(S, M, dev, D, times, priority=prio,
                                      p2p_time=rnd.uniform(0.0, 0.3))
        assert not validate_schedule(sched, dev)
        _check(sched, dev, True)


@given(st.integers(2, 4), st.integers(2, 5), st.sampled_from([1, 2]),
       st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_windows_match_replay_linear(D, M, V, seed):
    """Same cross-check on linear S = VD schedules (down ring only)."""
    rnd = random.Random(seed)
    S = V * D
    dev = lambda s: s % D
    tabs = _check(greedy_schedule(S, M, dev, D), dev, False)
    assert tabs.W_up == 0 and tabs.W_skip == 0
    times = [rnd.uniform(0.1, 2.0) for _ in range(S)]
    sched = greedy_schedule_timed(S, M, dev, D, times, priority="backward")
    _check(sched, dev, False)


def test_sparse_skip_consumers_shrink_window():
    """Layout-derived skip_consumers elide dead stores: an encoder slot no
    decoder row consumes is never written and the skip window shrinks
    below the conservative all-slots analysis."""
    D, M = 2, 4
    sched = template_wave(D, M)
    dev = lambda s, S=2 * D: min(s, S - 1 - s)
    conservative = StepTables.from_schedule(sched, folded=True,
                                            device_of_stage=dev)
    none_consumed = StepTables.from_schedule(
        sched, folded=True, device_of_stage=dev,
        skip_consumers=(((),), ((),)))
    assert none_consumed.W_skip == 0
    assert not none_consumed.skip_wr.any()
    assert conservative.W_skip > 0
    with pytest.raises(ValueError, match="skip_consumers"):
        StepTables.from_schedule(sched, folded=True, device_of_stage=dev,
                                 skip_consumers=(((),),))   # wrong shape
    with pytest.raises(ValueError, match="enc slot"):
        StepTables.from_schedule(sched, folded=True, device_of_stage=dev,
                                 skip_consumers=(((7,),), ((0,),)))


@pytest.mark.slow
@given(st.integers(2, 3), st.integers(2, 3), st.integers(0, 1000))
@settings(max_examples=3, deadline=None)
def test_windows_match_replay_ilp(D, M, seed):
    """Exact ILP schedules (Eqs. 6-13) through the same cross-check —
    liveness analysis is schedule-shape-agnostic, not greedy-specific."""
    S = 2 * D
    dev = lambda s: min(s, S - 1 - s)
    colloc = [(s, S - 1 - s) for s in range(D)]
    sched = ilp_schedule(S, M, D, device_of_stage=dev, collocated=colloc)
    assert not validate_schedule(sched, dev, collocated=colloc)
    _check(sched, dev, True)
