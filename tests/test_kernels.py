"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, attention_reference
from repro.kernels.skip_matmul import (skip_concat_matmul,
                                       skip_concat_matmul_reference)
from repro.kernels.linear_scan import (gated_linear_scan,
                                       gated_linear_scan_reference)

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("B,S,T,Hq,Hkv,D", [
    (2, 128, 128, 4, 2, 64),
    (1, 256, 256, 2, 2, 32),
    (2, 128, 256, 4, 1, 64),
    (1, 128, 128, 8, 8, 128),
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_sweep(B, S, T, Hq, Hkv, D, causal, window):
    if not causal and T < S:
        pytest.skip("cross shapes need T >= S")
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    out = flash_attention(q, k, v, causal, window)
    g = Hq // Hkv
    ref = attention_reference(q, jnp.repeat(k, g, 2), jnp.repeat(v, g, 2),
                              causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(KEY, (1, 128, 2, 64)).astype(dtype)
    out = flash_attention(q, q, q, True, None)
    ref = attention_reference(q, q, q, causal=True)
    assert out.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_grad():
    q = jax.random.normal(KEY, (1, 128, 2, 32))
    g = jax.grad(lambda q: flash_attention(q, q, q, True, None).sum())(q)
    gr = jax.grad(lambda q: attention_reference(q, q, q, causal=True).sum())(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,D,N", [(128, 128, 128), (256, 256, 128),
                                   (128, 384, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_skip_matmul_sweep(M, D, N, dtype):
    ks = jax.random.split(KEY, 3)
    h = jax.random.normal(ks[0], (M, D)).astype(dtype)
    s = jax.random.normal(ks[1], (M, D)).astype(dtype)
    w = (jax.random.normal(ks[2], (2 * D, N)) * 0.1).astype(dtype)
    out = skip_concat_matmul(h, s, w)
    ref = skip_concat_matmul_reference(h, s, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_skip_matmul_batched_and_grad():
    ks = jax.random.split(KEY, 3)
    h = jax.random.normal(ks[0], (2, 128, 128))
    s = jax.random.normal(ks[1], (2, 128, 128))
    w = jax.random.normal(ks[2], (256, 128)) * 0.1
    out = skip_concat_matmul(h, s, w)
    assert out.shape == (2, 128, 128)
    gk = jax.grad(lambda *a: skip_concat_matmul(*a).sum(),
                  argnums=(0, 1, 2))(h, s, w)
    gr = jax.grad(lambda *a: skip_concat_matmul_reference(
        a[0].reshape(-1, 128), a[1].reshape(-1, 128), a[2]).sum(),
        argnums=(0, 1, 2))(h, s, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a).reshape(b.shape),
                                   np.asarray(b), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("R,T,C", [(2, 128, 128), (3, 256, 128),
                                   (1, 128, 256)])
def test_linear_scan_sweep(R, T, C):
    ks = jax.random.split(KEY, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (R, T, C)))
    x = jax.random.normal(ks[1], (R, T, C))
    h = gated_linear_scan(a, x)
    ref = gated_linear_scan_reference(a, x)
    np.testing.assert_allclose(np.asarray(h), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_linear_scan_grad():
    ks = jax.random.split(KEY, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 128, 128)))
    x = jax.random.normal(ks[1], (2, 128, 128))
    ga = jax.grad(lambda a, x: (gated_linear_scan(a, x) ** 2).sum(),
                  argnums=(0, 1))(a, x)
    gr = jax.grad(lambda a, x: (gated_linear_scan_reference(a, x) ** 2).sum(),
                  argnums=(0, 1))(a, x)
    for p, q in zip(ga, gr):
        np.testing.assert_allclose(np.asarray(p), np.asarray(q),
                                   rtol=1e-3, atol=1e-3)
