"""Elastic fault tolerance: plan fingerprints, de-stack/re-stack restore,
checkpoint verification/fallback, fault injection, and the NaN guard.

The cross-plan numerics (save -> kill -> elastic-restore reproducing the
uninterrupted loss trajectory on the fp32 wire) run in one subprocess
drill over the production driver — see ``helpers/resilience_drill.py``.
"""
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointError, CheckpointManager,
                              complete_steps, latest_step, read_manifest,
                              restore_checkpoint, save_checkpoint,
                              verify_step)
from repro.optim import adamw_init
from repro.runtime.resilience import (FaultPlan, GradGuard,
                                      GradGuardEscalation, all_finite,
                                      compiled_state_spec,
                                      corrupt_checkpoint, logical_to_state,
                                      plan_fingerprint,
                                      restore_training_state,
                                      state_to_logical)
from tests.helpers import run_helper


# ---------------------------------------------------------------------------
# Tiny plans (planning only — no mesh/execution, runs on one device)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _uvit_plan(P, V=1, dp=1, zero=0, M=2):
    from repro.models.diffusion import UViTConfig, uvit_pipeline_graph
    from repro.runtime.adapters import diffusion_model_fns
    from repro.runtime.compile import auto_pipeline
    cfg = UViTConfig("uvit-t", img_size=8, in_ch=4, patch=2, d_model=16,
                     n_layers=8, n_heads=2, d_ff=32, n_classes=10)
    graph = uvit_pipeline_graph(cfg, batch=2)
    return auto_pipeline(graph, diffusion_model_fns(cfg, "uvit"), P * dp,
                         pipeline_devices=P, microbatches=M, dp_size=dp,
                         zero_stage=zero,
                         interleave=V if V > 1 else None)


def _state(plan, seed=0):
    params = plan.init_pipeline_params(jax.random.PRNGKey(seed))
    return {"params": params, "opt": adamw_init(params)}


def _merged(plan, state):
    return jax.device_get(plan.merge_params(*state["params"]))


def _tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "b": [jnp.ones((2,)), jnp.zeros((5,))]}


# ---------------------------------------------------------------------------
# Fingerprints / state specs
# ---------------------------------------------------------------------------

def test_fingerprint_stable_and_layout_sensitive():
    a = _uvit_plan(2).state_spec()
    assert a["fingerprint"] == _uvit_plan(2).fingerprint()
    assert a["fingerprint"] == plan_fingerprint(a)
    # a different stacking layout changes the fingerprint ...
    assert _uvit_plan(4).fingerprint() != a["fingerprint"]
    assert _uvit_plan(2, V=2).fingerprint() != a["fingerprint"]
    # ... but M / dp / zero_stage don't: device_get reassembles full
    # logical arrays, so the at-rest format only depends on stacking
    assert _uvit_plan(2, M=4).fingerprint() == a["fingerprint"]
    assert _uvit_plan(2, dp=2, zero=2).fingerprint() == a["fingerprint"]


def test_state_spec_json_roundtrip():
    spec = compiled_state_spec(_uvit_plan(2, V=2))
    back = json.loads(json.dumps(spec))
    assert plan_fingerprint(back) == spec["fingerprint"]
    assert back["P"] == 2 and back["V"] == 2 and back["folded"]


def test_certificate_records_fingerprint():
    plan = _uvit_plan(2)
    cert = plan.certify(name="resilience-fp")
    assert cert.ok
    assert cert.plan["fingerprint"] == plan.fingerprint()


# ---------------------------------------------------------------------------
# Elastic de-stack / re-stack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("src,dst", [
    ((2, 1), (1, 1)),       # shrink
    ((2, 1), (4, 1)),       # grow
    ((2, 2), (2, 1)),       # V=2 -> V=1
    ((4, 1), (2, 2)),       # P and V change together
])
def test_destack_restack_roundtrip(src, dst):
    plan_a, plan_b = _uvit_plan(*src), _uvit_plan(*dst)
    state_a = _state(plan_a)
    logical = state_to_logical(jax.device_get(state_a),
                               plan_a.state_spec())
    state_b = logical_to_state(logical, plan_b)
    # identical model-space params and optimizer moments either way
    for ta, tb in zip(jax.tree.leaves(_merged(plan_a, state_a)),
                      jax.tree.leaves(_merged(plan_b, state_b))):
        np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))
    for mom in ("m", "v"):
        a = jax.device_get(plan_a.merge_params(*state_a["opt"][mom]))
        b = jax.device_get(plan_b.merge_params(*state_b["opt"][mom]))
        for ta, tb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))


def test_restore_training_state_elastic(tmp_path):
    plan_a, plan_b = _uvit_plan(2), _uvit_plan(1)
    state_a = _state(plan_a, seed=3)
    save_checkpoint(str(tmp_path), 7, state_a,
                    plan=plan_a.state_spec())
    state_b, info = restore_training_state(
        str(tmp_path), plan_b, _state(plan_b, seed=9))
    assert info.step == 7 and info.elastic
    assert info.saved_fingerprint == plan_a.fingerprint()
    assert info.fingerprint == plan_b.fingerprint()
    for ta, tb in zip(jax.tree.leaves(_merged(plan_a, state_a)),
                      jax.tree.leaves(_merged(plan_b, state_b))):
        np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))


def test_restore_training_state_fast_path_and_missing_spec(tmp_path):
    plan = _uvit_plan(2)
    state = _state(plan)
    save_checkpoint(str(tmp_path), 3, state, plan=plan.state_spec())
    _, info = restore_training_state(str(tmp_path), plan, _state(plan, 1))
    assert not info.elastic
    # a checkpoint without a recorded spec cannot feed elastic restore
    save_checkpoint(str(tmp_path), 5, state)
    with pytest.raises(CheckpointError) as ei:
        restore_training_state(str(tmp_path), plan, _state(plan, 1), step=5)
    assert ei.value.reason == "no-plan-spec"


# ---------------------------------------------------------------------------
# Verified checkpoints: corruption, completeness, fallback, GC
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("truncate", [False, True])
def test_corrupt_shard_detected_and_fallback(tmp_path, truncate):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 2, t)
    corrupt_checkpoint(str(tmp_path), truncate=truncate)
    # detection: the newest step no longer verifies
    assert latest_step(str(tmp_path)) == 1
    with pytest.raises(CheckpointError) as ei:
        restore_checkpoint(str(tmp_path), t, step=2)
    assert ei.value.reason == "checksum-mismatch"
    assert ei.value.step == 2 and ei.value.shard == "shard_00000.npz"
    # strict=False falls back to the previous complete step
    restored, step = restore_checkpoint(str(tmp_path), t, strict=False)
    assert step == 1
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_any_shard_mutation_detected(tmp_path):
    t = _tree()
    for h in range(2):
        save_checkpoint(str(tmp_path), 1, t, host_id=h, num_hosts=2)
    verify_step(str(tmp_path), 1)
    for shard in read_manifest(str(tmp_path), 1)["shards"]:
        path = tmp_path / "step_000000001" / shard
        raw = bytearray(path.read_bytes())
        raw[len(raw) // 3] ^= 0xFF
        path.write_bytes(bytes(raw))
        with pytest.raises(CheckpointError):
            verify_step(str(tmp_path), 1)
        raw[len(raw) // 3] ^= 0xFF            # restore the byte
        path.write_bytes(bytes(raw))
        verify_step(str(tmp_path), 1)


def test_multihost_completeness_race_closed(tmp_path):
    """Host 0's manifest alone must NOT mark the step complete."""
    t = _tree()
    save_checkpoint(str(tmp_path), 4, t, host_id=0, num_hosts=2)
    assert os.path.exists(tmp_path / "step_000000004" / "manifest.json")
    assert latest_step(str(tmp_path)) is None
    with pytest.raises(CheckpointError) as ei:
        verify_step(str(tmp_path), 4)
    assert ei.value.reason == "missing-shard"
    save_checkpoint(str(tmp_path), 4, t, host_id=1, num_hosts=2)
    assert latest_step(str(tmp_path)) == 4
    restored, _ = restore_checkpoint(str(tmp_path), t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keys_on_verified_complete_steps(tmp_path):
    t = _tree()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        mgr.save(s, t)
    # garbage that must NOT count toward keep: an old incomplete step
    # dir, a stale tmp dir, a stale dot-tmp file
    os.makedirs(tmp_path / "step_000000000")
    os.makedirs(tmp_path / "step_000000002.tmp1")
    (tmp_path / ".manifest.json.tmp99").write_text("{}")
    mgr.save(4, t)
    assert complete_steps(str(tmp_path)) == [3, 4]
    left = sorted(os.listdir(tmp_path))
    assert left == ["step_000000003", "step_000000004"], left


def test_gc_spares_newer_inflight_step(tmp_path):
    """An incomplete dir NEWER than the newest complete step may still be
    mid-write on another host — GC must leave it alone."""
    t = _tree()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, t)
    os.makedirs(tmp_path / "step_000000009")
    mgr.save(2, t)
    assert (tmp_path / "step_000000009").exists()


def test_save_retry_backoff_then_success(tmp_path):
    calls = []

    def flaky(step):
        calls.append(step)
        if len(calls) <= 2:
            raise OSError("transient")

    mgr = CheckpointManager(str(tmp_path), retries=3, backoff=0.001,
                            io_fault=flaky)
    path = mgr.save(1, _tree())
    assert path is not None and len(calls) == 3
    assert latest_step(str(tmp_path)) == 1


def test_save_final_failure_degrades_to_warning(tmp_path):
    def broken(step):
        raise OSError("disk on fire")

    mgr = CheckpointManager(str(tmp_path), retries=1, backoff=0.001,
                            io_fault=broken)
    with pytest.warns(RuntimeWarning, match="training continues"):
        assert mgr.save(1, _tree()) is None
    assert latest_step(str(tmp_path)) is None


# ---------------------------------------------------------------------------
# Fault plan + NaN guard
# ---------------------------------------------------------------------------

def test_faultplan_parse():
    fp = FaultPlan.parse(
        "kill@60,stop@4,nan@10,corrupt@80:shard_00001,truncate@9,"
        "iofail@20:3")
    kinds = [(a.kind, a.step) for a in fp.actions]
    assert kinds == [("kill", 60), ("stop", 4), ("nan", 10),
                     ("corrupt", 80), ("truncate", 9), ("iofail", 20)]
    assert fp.actions[3].arg == "shard_00001"
    assert fp.actions[5].count == 3
    assert FaultPlan.parse("").actions == ()
    with pytest.raises(ValueError, match="unparseable fault token"):
        FaultPlan.parse("explode@3")


def test_faultplan_env_fallback(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "nan@7")
    fp = FaultPlan.parse(None)
    assert fp.wants_nan(7) and not fp.wants_nan(8)


def test_faultplan_iofail_budget():
    fp = FaultPlan.parse("iofail@5:2")
    fp.io_fault(3)                       # before the step: no-op
    with pytest.raises(OSError):
        fp.io_fault(5)
    with pytest.raises(OSError):
        fp.io_fault(5)
    fp.io_fault(5)                       # budget exhausted: clean
    fp.io_fault(6)


def test_faultplan_poison_and_stop():
    fp = FaultPlan.parse("nan@2,stop@3")
    batch = {"latents": jnp.ones((2, 2)), "labels": jnp.zeros((2,),
                                                             jnp.int32)}
    out = fp.poison_batch(batch, 2)
    assert np.isnan(np.asarray(out["latents"])).all()
    np.testing.assert_array_equal(np.asarray(out["labels"]), 0)
    assert fp.poison_batch(batch, 1) is batch
    assert fp.post_step(3) == "stop"
    assert fp.post_step(2) is None


def test_all_finite_flags_nans():
    good = {"a": jnp.ones((2,)), "n": jnp.array(3, jnp.int32)}
    assert bool(all_finite(good))
    assert not bool(all_finite(good, {"g": jnp.array([1.0, jnp.nan])}))
    assert not bool(all_finite({"g": jnp.array([jnp.inf])}))


def test_gradguard_budget_and_reset():
    g = GradGuard(budget=2)
    assert g.observe(True, 0)
    assert not g.observe(False, 1)
    assert not g.observe(False, 2)
    with pytest.raises(RuntimeError, match="skip budget"):
        g.observe(False, 3)
    g = GradGuard(budget=1)
    g.observe(False, 0)
    g.observe(True, 1)                   # finite step resets the streak
    g.observe(False, 2)
    assert g.skipped_total == 2


def test_gradguard_escalation_carries_context():
    """The exhausted budget raises a STRUCTURED escalation (step, streak,
    budget as fields) so a supervisor can decide rollback vs abort —
    while staying a RuntimeError for legacy abort-only callers."""
    g = GradGuard(budget=2)
    g.observe(False, 10)
    g.observe(False, 11)
    with pytest.raises(GradGuardEscalation) as ei:
        g.observe(False, 12)
    e = ei.value
    assert (e.step, e.consecutive, e.budget) == (12, 3, 2)
    assert isinstance(e, RuntimeError)


# ---------------------------------------------------------------------------
# End-to-end drill (one subprocess for all scenarios; fp32 wire)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="session")
def drill_out():
    return run_helper("resilience_drill.py", "shrink", "vchange")


def test_drill_elastic_shrink(drill_out):
    assert "shrink: elastic P=2 dp=2 zero2 -> P=1 dp=2 zero0 OK" \
        in drill_out
    assert "shrink: corrupt-shard fallback to step 4 OK" in drill_out


def test_drill_interleave_change(drill_out):
    assert "vchange: elastic V=2 zero0 -> V=1 zero2 OK" in drill_out


def test_drill_all_ok(drill_out):
    assert "RESILIENCE DRILL: ALL OK" in drill_out
