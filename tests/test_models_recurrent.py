"""mLSTM / sLSTM / Mamba2 / Zamba2 parallel-recurrent equivalence."""
import jax
import pytest
import jax.numpy as jnp
import numpy as np
from helpers.hypothesis_compat import given, settings, st

from repro.models.xlstm import (XLSTMConfig, init_xlstm,
                                init_states, decode_step, forward, unembed,
                                mlstm_parallel, mlstm_recurrent,
                                init_mlstm_state)
from repro.models.mamba import (Mamba2Config, Zamba2Config, _ssd_chunked,
                                ssd_recurrent, init_zamba2, zamba2_loss,
                                init_states as z_states,
                                decode_step as z_decode, forward as z_forward)
from repro.models.layers import AttnConfig

KEY = jax.random.PRNGKey(1)


@pytest.mark.slow
@given(st.integers(0, 1000), st.sampled_from([4, 8]), st.sampled_from([2, 4]))
@settings(max_examples=8, deadline=None)
def test_mlstm_parallel_equals_recurrent(seed, S, H):
    key = jax.random.PRNGKey(seed)
    B, Dh = 2, 8
    ks = jax.random.split(key, 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, Dh)) for i in range(3))
    i_pre = jax.random.normal(ks[3], (B, S, H))
    f_pre = jax.random.normal(ks[4], (B, S, H)) * 2
    hp = mlstm_parallel(q, k, v, i_pre, f_pre)
    stt = init_mlstm_state(B, H, Dh)
    outs = []
    for t in range(S):
        o, stt = mlstm_recurrent(stt, q[:, t], k[:, t], v[:, t],
                                 i_pre[:, t], f_pre[:, t])
        outs.append(o)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(jnp.stack(outs, 1)),
                               rtol=2e-4, atol=2e-5)


@given(st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_ssd_chunked_equals_recurrent(seed):
    key = jax.random.PRNGKey(seed)
    cfg = Mamba2Config(d_model=32, d_state=8, head_dim=8, chunk=4)
    b, S, H, P, N = 2, 8, cfg.n_heads, 8, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (b, S, N))
    C_ = jax.random.normal(ks[4], (b, S, N))
    y, hf = _ssd_chunked(x, dt, a, B_, C_, 4)
    stt = jnp.zeros((b, H, N, P))
    ys = []
    for t in range(S):
        yt, stt = ssd_recurrent(stt, x[:, t], dt[:, t], a, B_[:, t], C_[:, t])
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.stack(ys, 1)),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(stt),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.slow
def test_xlstm_decode_matches_forward():
    cfg = XLSTMConfig("t", vocab=64, d_model=32, n_layers=4, n_heads=2,
                      slstm_every=3)
    p = init_xlstm(KEY, cfg)
    tok = jax.random.randint(KEY, (2, 10), 0, 64)
    sts = init_states(cfg, 2)
    outs = []
    for t in range(10):
        lg, sts = decode_step(p, tok[:, t:t + 1], sts, cfg)
        outs.append(lg)
    h, _ = forward(p, tok, cfg)
    ref = unembed(p, h, cfg)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(ref), rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_zamba2_decode_matches_forward():
    cfg = Zamba2Config("t", vocab=64, d_model=32, n_layers=6,
                       mamba=Mamba2Config(d_model=32, d_state=8, head_dim=8,
                                          chunk=4),
                       shared_attn=AttnConfig(32, 4, 4, 8), shared_d_ff=64,
                       shared_every=3, n_shared_blocks=2)
    p = init_zamba2(KEY, cfg)
    tok = jax.random.randint(KEY, (2, 8), 0, 64)
    sts = z_states(cfg, 2, 8)
    outs = []
    for t in range(8):
        lg, sts = z_decode(p, tok[:, t:t + 1], sts, cfg)
        outs.append(lg)
    h, _ = z_forward(p, tok, cfg)
    ref = h @ p["embed"].T
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(ref), rtol=2e-3, atol=2e-3)
    loss = zamba2_loss(p, {"tokens": tok}, cfg)
    assert jnp.isfinite(loss)


def test_zamba2_shares_parameters():
    cfg = Zamba2Config("t", vocab=64, d_model=32, n_layers=6,
                       mamba=Mamba2Config(d_model=32, d_state=8, head_dim=8,
                                          chunk=4),
                       shared_attn=AttnConfig(32, 4, 4, 8), shared_d_ff=64,
                       shared_every=3, n_shared_blocks=1)
    p = init_zamba2(KEY, cfg)
    assert len(p["shared_blocks"]) == 1      # one param set, two apply sites
    assert len(cfg.shared_sites()) == 2
