"""Training supervisor: heartbeats, watchdogs, topology, event log, and
the end-to-end detect -> rollback -> shrink drill.

Unit layers are jax-free (the supervisor is host-side control plane);
the e2e drill runs real worker subprocesses through
``tests/helpers/supervisor_drill.py``.
"""
import json
import os
import threading
import time

import pytest

from repro.core.tuner import shrink_plan
from repro.launch.mesh import BarrierTimeout, FileBarrier, HostTopology
from repro.launch.supervisor import EventLog, format_status, read_events
from repro.runtime.resilience import (FaultPlan, FaultPlanError, Heartbeat,
                                      StragglerDetector, Watchdog,
                                      read_heartbeats, write_heartbeat)

from helpers import run_helper


# ---------------------------------------------------------------------------
# Heartbeats
# ---------------------------------------------------------------------------

def test_heartbeat_roundtrip(tmp_path):
    d = str(tmp_path)
    write_heartbeat(d, Heartbeat(0, 5, "train", loss=1.25, grad_norm=0.5))
    write_heartbeat(d, Heartbeat(1, 4, "ckpt", gen=2))
    beats = read_heartbeats(d)
    assert set(beats) == {0, 1}
    assert beats[0].step == 5 and beats[0].loss == 1.25
    assert beats[0].t > 0 and beats[0].pid == os.getpid()
    assert beats[1].phase == "ckpt"


def test_heartbeat_gen_filter_and_torn_file(tmp_path):
    d = str(tmp_path)
    write_heartbeat(d, Heartbeat(0, 5, "train", gen=0))
    write_heartbeat(d, Heartbeat(1, 9, "train", gen=1))
    (tmp_path / "hb_h00002.json").write_text('{"host_id": 2, "st')  # torn
    (tmp_path / "hb_h00003.json").write_text('{"bogus": true}')     # schema
    beats = read_heartbeats(d, gen=1)
    assert set(beats) == {1}
    assert set(read_heartbeats(d)) == {0, 1}


# ---------------------------------------------------------------------------
# Watchdog
# ---------------------------------------------------------------------------

def _hb(host, step, phase="train", t=0.0):
    return {host: Heartbeat(host, step, phase, t=t)}


def test_watchdog_progress_based_not_write_based():
    """A hung host can still WRITE heartbeats — only (phase, step)
    advancing counts as progress."""
    dog = Watchdog([0], stall_timeout=10, miss_budget=3, now=0.0)
    dog.observe(_hb(0, 0), now=0.0)              # first train step: lenient
    dog.observe(_hb(0, 1), now=0.0)              # past it: stall deadline
    for t in range(1, 35):
        dog.observe(_hb(0, 1), now=float(t))     # same step, fresh writes
    assert dog.check(now=11.0)[0] == "suspect"
    assert dog.check(now=31.0)[0] == "hung"
    dog.observe(_hb(0, 2), now=31.0)             # progress resets the age
    assert dog.check(now=32.0)[0] == "ok"
    assert dog.progress(0) == ("train", 2)


def test_watchdog_startup_vs_stall_deadlines():
    dog = Watchdog([0, 1], stall_timeout=5, startup_timeout=100,
                   miss_budget=2, now=0.0)
    dog.observe(_hb(0, -1, "init"), now=0.0)
    dog.observe(_hb(1, 0, "train"), now=0.0)
    dog.observe(_hb(1, 1, "train"), now=0.0)
    # at t=20: host 0 still compiling (within startup_timeout) is ok,
    # host 1 past its first train step is judged on the stall deadline
    checks = dog.check(now=20.0)
    assert checks[0] == "ok" and checks[1] == "hung"
    # a host never seen at all is judged from construction time
    assert Watchdog([7], startup_timeout=100,
                    now=0.0).check(now=101.0)[7] == "suspect"


def test_watchdog_first_train_step_is_lenient():
    """The step in flight after the FIRST train beat still pays residual
    jit warmup — it gets the startup deadline, not the stall one."""
    dog = Watchdog([0], stall_timeout=5, startup_timeout=100,
                   miss_budget=2, now=0.0)
    dog.observe(_hb(0, 4, "train"), now=0.0)     # e.g. a resumed worker
    assert dog.check(now=20.0)[0] == "ok"        # warmup tolerated
    assert dog.check(now=101.0)[0] == "suspect"  # startup cap still bites
    dog.observe(_hb(0, 5, "train"), now=101.0)
    assert dog.check(now=107.0)[0] == "suspect"  # now on the tight clock


def test_watchdog_done_and_ckpt_phases():
    dog = Watchdog([0], stall_timeout=5, miss_budget=2, now=0.0)
    dog.observe(_hb(0, 3, "ckpt"), now=0.0)
    assert dog.check(now=6.0)[0] == "suspect"    # ckpt uses stall deadline
    dog.observe(_hb(0, 9, "done"), now=6.0)
    assert dog.check(now=1000.0)[0] == "done"    # clean exit never stalls


# ---------------------------------------------------------------------------
# Straggler detection
# ---------------------------------------------------------------------------

def _feed(det, host, steps, dt, t0=0.0):
    t = t0
    for s in range(steps):
        det.observe({host: Heartbeat(host, s, "train", t=t)})
        t += dt


def test_straggler_flags_persistently_slow_host():
    det = StragglerDetector(factor=2.0, patience=3)
    _feed(det, 0, 10, dt=1.0)
    _feed(det, 1, 10, dt=1.0)
    _feed(det, 2, 10, dt=3.0)                    # 3x the peer median
    out = det.stragglers()
    assert set(out) == {2} and out[2] == pytest.approx(3.0)


def test_straggler_needs_patience_and_peers():
    det = StragglerDetector(factor=2.0, patience=5)
    _feed(det, 0, 4, dt=1.0)
    _feed(det, 1, 4, dt=9.0)                     # slow, but only 3 steps
    assert det.stragglers() == {}
    solo = StragglerDetector()
    _feed(solo, 0, 10, dt=9.0)                   # no peers, no verdict
    assert solo.stragglers() == {}


def test_straggler_detected_under_sparse_polling():
    """A starved monitor observes beats in multi-step jumps; the worker
    -reported step_s samples and step-counted streaks still flag the
    slow host (time-derived averages would wash the slowdown out)."""
    det = StragglerDetector(factor=2.0, patience=3)
    # host 0 fast, host 1 3x slow — each observed only every 4 steps,
    # with wall-clock t polluted by warmup (huge first gap)
    for h, dur in ((0, 1.0), (1, 3.0)):
        t = 100.0
        for s in (0, 4, 8, 12):
            det.observe({h: Heartbeat(h, s, "train", t=t, step_s=dur)})
            t += 4 * dur
    out = det.stragglers()
    assert set(out) == {1} and out[1] == pytest.approx(3.0)


def test_straggler_recovers_when_speed_returns():
    det = StragglerDetector(factor=2.0, patience=2, window=4)
    _feed(det, 0, 12, dt=1.0)
    _feed(det, 1, 6, dt=5.0)
    assert 1 in det.stragglers()
    _feed(det, 1, 6, dt=1.0, t0=100.0)           # window forgets old steps
    assert det.stragglers() == {}


# ---------------------------------------------------------------------------
# FaultPlan edge cases + host scoping
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec,reason,fragment", [
    ("kill@", "syntax", "kill@"),
    ("explode@3", "unknown-kind", "explode"),
    ("kill@-1", "negative-step", "kill@-1"),
    ("nan@3,nan@3", "duplicate", "nan@3"),
    ("kill@2:oops", "bad-arg", "kill@2:oops"),
    ("iofail@2:0", "bad-arg", "N >= 1"),
    ("hostdown@5", "missing-host", "hostdown@5"),
    ("slow@5", "missing-factor", "slow@5"),
    ("slow@5:0.5", "bad-arg", "0.5"),
    ("hang@5:x", "bad-arg", "hang@5:x"),
])
def test_faultplan_rejects_malformed_tokens(spec, reason, fragment):
    with pytest.raises(FaultPlanError) as ei:
        FaultPlan.parse(spec)
    assert ei.value.reason == reason
    assert fragment in str(ei.value)             # names the offending token
    assert isinstance(ei.value, ValueError)      # legacy callers survive


def test_faultplan_multihost_verbs_parse():
    fp = FaultPlan.parse("hostdown@30:1,hang@40,slow@50:2.5:1,nan@10")
    a = {x.kind: x for x in fp.actions}
    assert a["hostdown"].host == 1
    assert a["hang"].host == 0                   # default host 0
    assert a["slow"].factor == 2.5 and a["slow"].host == 1
    assert a["nan"].host is None                 # host-less: every host


def test_faultplan_for_host_filters_and_validates():
    fp = FaultPlan.parse("hostdown@30:1,hang@40,nan@10")
    h0 = [x.kind for x in fp.for_host(0, 2).actions]
    h1 = [x.kind for x in fp.for_host(1, 2).actions]
    assert h0 == ["hang", "nan"] and h1 == ["hostdown", "nan"]
    with pytest.raises(FaultPlanError) as ei:
        fp.for_host(0, 1)                        # host 1 does not exist
    assert ei.value.reason == "unknown-host"
    assert "hostdown@30:1" in str(ei.value)


def test_faultplan_hang_and_slow_hooks():
    fp = FaultPlan.parse("hang@5,slow@3:4.0")
    slept = []
    assert fp.hang_before(5, sleep=slept.append, seconds=7.0)
    assert slept == [7.0]
    assert not fp.hang_before(4, sleep=slept.append)
    assert fp.slow_factor(2) == 1.0
    assert fp.slow_factor(3) == 4.0 and fp.slow_factor(9) == 4.0


# ---------------------------------------------------------------------------
# Shrink re-planning
# ---------------------------------------------------------------------------

def test_shrink_plan_sheds_dp_first():
    assert shrink_plan(2, dp=2, pp=2, zero_stage=2) == (1, 2, 0)
    assert shrink_plan(6, dp=4, pp=2, zero_stage=1) == (3, 2, 1)


def test_shrink_plan_folds_pipeline_when_it_must():
    assert shrink_plan(1, dp=2, pp=2) == (1, 1, 0)
    assert shrink_plan(3, dp=2, pp=4) == (1, 3, 0)


def test_shrink_plan_rejects_empty_cluster():
    with pytest.raises(ValueError):
        shrink_plan(0, dp=2, pp=2)


# ---------------------------------------------------------------------------
# Host topology + file barrier
# ---------------------------------------------------------------------------

def test_host_topology_mapping_and_ring():
    topo = HostTopology(num_hosts=3, devices_per_host=4)
    assert topo.num_devices == 12
    assert topo.host_of_device(0) == 0 and topo.host_of_device(11) == 2
    assert list(topo.host_devices(1)) == [4, 5, 6, 7]
    assert topo.ring_neighbors(0) == (2, 1)
    assert topo.ring_neighbors(2) == (1, 0)
    with pytest.raises(ValueError):
        topo.host_of_device(12)
    with pytest.raises(ValueError):
        topo.host_devices(3)


def test_host_topology_cross_host_edges():
    topo = HostTopology(num_hosts=2, devices_per_host=2)
    # stages on devices 0,1 (host 0) then 2,3 (host 1): one crossing
    assert topo.cross_host_edges([0, 1, 2, 3]) == [(0, 1)]
    assert topo.cross_host_edges([0, 1]) == []
    # zig-zag placement crosses twice but each direction reported once
    assert topo.cross_host_edges([0, 2, 1, 3]) == [(0, 1), (1, 0)]
    assert "cross-host hops" in topo.describe([0, 1, 2, 3])


def test_file_barrier_rendezvous_and_timeout(tmp_path):
    d = str(tmp_path)
    a = FileBarrier(d, host_id=0, num_hosts=2)
    b = FileBarrier(d, host_id=1, num_hosts=2)
    done = []
    t = threading.Thread(target=lambda: (a.wait("s", timeout=10),
                                         done.append(0)))
    t.start()
    time.sleep(0.1)
    assert not done                              # host 1 not there yet
    b.wait("s", timeout=10)
    t.join(timeout=10)
    assert done == [0]
    with pytest.raises(BarrierTimeout) as ei:
        a.wait("t2", timeout=0.2, poll=0.02)
    assert ei.value.missing == [1]
    a.reset("t2")
    assert not any(n.startswith("t2.") for n in os.listdir(d))


# ---------------------------------------------------------------------------
# Event log + status reader
# ---------------------------------------------------------------------------

def test_event_log_roundtrip_and_torn_tail(tmp_path):
    path = str(tmp_path / "events.jsonl")
    log = EventLog(path)
    log.emit("launch", gen=0, hosts=2)
    log.emit("hostdown", gen=0, host=1, rc=42)
    with open(path, "a") as f:
        f.write('{"t": 1, "kind": "tor')          # crashed writer
    events = read_events(path)
    assert [e["kind"] for e in events] == ["launch", "hostdown"]
    assert events[1]["rc"] == 42 and events[0]["t"] > 0
    assert read_events(str(tmp_path / "missing.jsonl")) == []


def test_format_status_renders_events_and_heartbeats(tmp_path):
    run_dir = str(tmp_path)
    assert "(no events yet)" in format_status(run_dir)
    log = EventLog(os.path.join(run_dir, "events.jsonl"))
    log.emit("launch", gen=0, hosts=2)
    log.emit("rollback", gen=0, step=8, reason="hostdown")
    write_heartbeat(os.path.join(run_dir, "hb"),
                    Heartbeat(0, 7, "train", loss=2.5))
    out = format_status(run_dir)
    assert "rollback" in out and "step=8" in out
    assert "host 0" in out and "loss=2.5000" in out
    assert "launch x1" in out and "rollback x1" in out


# ---------------------------------------------------------------------------
# Concurrent multi-host checkpoint commit (satellite: GC vs writers race)
# ---------------------------------------------------------------------------

def test_concurrent_writers_gc_never_collects_inflight_step(tmp_path):
    out = run_helper("concurrent_ckpt.py", str(tmp_path), timeout=300)
    assert "CONCURRENT CKPT: ALL OK" in out


# ---------------------------------------------------------------------------
# End-to-end supervisor drill (real worker subprocesses, fp32 wire)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="session")
def supervisor_drill_out():
    return run_helper("supervisor_drill.py", "hostdown", "hang",
                      timeout=1800)


def test_drill_hostdown_rollback_and_shrink(supervisor_drill_out):
    assert "hostdown: detect(hostdown) -> rollback(8) -> " \
        "shrink(dp=1 x P=2) -> resume OK" in supervisor_drill_out


def test_drill_hang_watchdog_detection(supervisor_drill_out):
    assert "hang: detect(hang) -> rollback(4) -> " \
        "shrink(dp=1 x P=2) -> resume OK" in supervisor_drill_out


def test_drill_all_ok(supervisor_drill_out):
    assert "SUPERVISOR DRILL: ALL OK" in supervisor_drill_out
