"""Unit tests for the jax-free analysis layer: kernel checks + policy lint.

The kernel checks must mirror the Pallas kernels' trace-time asserts
exactly (same clamping, same divisibility) — they are what routes
unsupported shapes to the reference implementations *before* tracing.
The policy linter is exercised against synthetic files placed at
policy-relevant paths, plus the real repo tree (which must be green).
"""
import pathlib
import subprocess
import sys
import textwrap

from repro.analysis.kernel_check import (check_flash_attention,
                                         check_gated_linear_scan,
                                         check_skip_concat_matmul,
                                         flash_attention_supported,
                                         gated_linear_scan_supported,
                                         skip_concat_matmul_supported)
from repro.analysis.lint import lint_file, lint_paths

REPO = pathlib.Path(__file__).resolve().parents[1]


# ===========================================================================
# kernel_check
# ===========================================================================

def test_skip_matmul_supported_matches_kernel_contract():
    """The predicate mirrors skip_concat_matmul_fwd's clamped-block
    asserts: dim % min(block, dim) == 0, positive dims."""
    assert skip_concat_matmul_supported(256, 128, 512)
    assert skip_concat_matmul_supported(32, 64, 16)      # all clamped
    assert skip_concat_matmul_supported(100, 128, 128)   # bm clamps to 100
    assert not skip_concat_matmul_supported(0, 128, 128)
    assert not skip_concat_matmul_supported(200, 128, 128)  # 200 % 128
    assert not skip_concat_matmul_supported(128, 129, 128)  # 129 % 128


def test_skip_matmul_ops_reexports_analysis_predicate():
    """kernels/ops delegates to the analysis layer — one source of
    truth for the launch constraint."""
    from repro.kernels.skip_matmul.ops import (
        skip_concat_matmul_supported as via_ops)
    assert via_ops is skip_concat_matmul_supported


def test_flash_attention_check():
    assert flash_attention_supported(256, 256, 64)
    assert flash_attention_supported(64, 64, 64)         # clamped blocks
    assert not flash_attention_supported(250, 256, 64)
    rep = check_flash_attention(8, 250, 256, 64)
    assert not rep.ok
    assert any("S=250" in f.detail for f in rep.errors())
    # whole K/V rows are VMEM-resident: absurd T must be rejected
    rep = check_flash_attention(1, 128, 128 * 65536, 128)
    assert not rep.ok and any("VMEM" in f.detail for f in rep.errors())
    # sub-lane head dim is a warning, not an error
    rep = check_flash_attention(8, 256, 256, 64, dtype="bfloat16")
    assert rep.ok and any(f.level == "warn" for f in rep.findings)
    assert not check_flash_attention(8, 256, 256, 64, window=0).ok
    assert not check_flash_attention(8, 256, 256, 64, dtype="int4").ok


def test_gated_linear_scan_check():
    assert gated_linear_scan_supported(1024, 256)
    assert gated_linear_scan_supported(32, 16)           # clamped
    assert not gated_linear_scan_supported(1000, 256)
    rep = check_gated_linear_scan(4, 2048, 256, block_t=2048)
    assert rep.ok and any("unroll" in f.detail for f in rep.findings)
    assert not check_gated_linear_scan(0, 128, 128).ok


def test_skip_matmul_check_reports():
    rep = check_skip_concat_matmul(256, 384, 512)
    assert rep.ok and not rep.findings
    rep = check_skip_concat_matmul(0, 128, 128)
    assert not rep.ok and "degenerate" in str(rep)


# ===========================================================================
# lint — synthetic files at policy-relevant paths
# ===========================================================================

def _lint_snippet(tmp_path, rel, src):
    path = tmp_path / "repro" / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(src))
    return [f.rule for f in lint_file(path)]


def test_lint_compat_only_experimental(tmp_path):
    bad = _lint_snippet(tmp_path, "runtime/foo.py",
                        "from jax.experimental import shard_map\n")
    assert bad == ["compat-only-experimental"]
    bad = _lint_snippet(tmp_path, "models/bar.py",
                        "import jax.experimental.pallas as pl\n")
    assert bad == ["compat-only-experimental"]
    # function-local does not escape the rule (compat is the only site)
    bad = _lint_snippet(tmp_path, "runtime/baz.py", """
        def f():
            from jax.experimental import mesh_utils
    """)
    assert bad == ["compat-only-experimental"]
    assert _lint_snippet(tmp_path, "runtime/compat.py",
                         "from jax.experimental import shard_map\n") == []
    # sharding rules build PartitionSpecs and sit under the same policy
    assert _lint_snippet(tmp_path, "runtime/sharding.py",
                         "from jax.experimental import shard_map\n") == []
    assert _lint_snippet(tmp_path, "kernels/fa/kernel.py",
                         "from jax.experimental import pallas as pl\n") == []


def test_lint_core_lazy_jax(tmp_path):
    assert _lint_snippet(tmp_path, "core/foo.py", "import jax\n") == \
        ["core-lazy-jax"]
    assert _lint_snippet(tmp_path, "core/foo.py",
                         "import jax.numpy as jnp\n") == ["core-lazy-jax"]
    assert _lint_snippet(tmp_path, "core/foo.py", """
        def f():
            import jax
            return jax
    """) == []
    assert _lint_snippet(tmp_path, "core/foo.py", """
        from typing import TYPE_CHECKING
        if TYPE_CHECKING:
            import jax
    """) == []
    # outside core/ a module-top jax import is fine
    assert _lint_snippet(tmp_path, "runtime/foo.py", "import jax\n") == []


def test_lint_guarded_placement_extrema(tmp_path):
    bad = _lint_snippet(tmp_path, "core/schedule.py", """
        def makespan(self):
            return max(p.step for p in self.placements)
    """)
    assert bad == ["guarded-placement-extrema"]
    assert _lint_snippet(tmp_path, "core/schedule.py", """
        def makespan(self):
            if not self.placements:
                raise ValueError("empty")
            return max(p.step for p in self.placements)
    """) == []
    assert _lint_snippet(tmp_path, "core/schedule.py", """
        def makespan(self):
            return max((p.step for p in self.placements), default=0)
    """) == []
    # the rule is scoped to core/schedule.py
    assert _lint_snippet(tmp_path, "core/other.py", """
        def f(placements):
            return max(p.step for p in placements)
    """) == []


def test_repo_tree_is_policy_clean():
    """The committed tree passes its own policy linter (profiler fix +
    compat discipline) — the same invocation CI runs."""
    paths = [REPO / d for d in ("src", "tests", "benchmarks")
             if (REPO / d).is_dir()]
    findings = lint_paths(paths)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_lint_cli_green():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint"],
        cwd=REPO, capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
