"""Optimizers: convergence, clipping, int8-state fidelity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         int8_adamw_init, int8_adamw_update,
                         clip_by_global_norm, cosine_schedule)


def _quadratic(params):
    return sum(jnp.sum(jnp.square(p - 3.0)) for p in jax.tree.leaves(params))


def test_adamw_converges():
    params = {"a": jnp.zeros((4,)), "b": {"c": jnp.zeros((2, 2))}}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw_init(params)
    for _ in range(200):
        g = jax.grad(_quadratic)(params)
        params, state = adamw_update(params, g, state, cfg)
    assert _quadratic(params) < 1e-2


def test_int8_matches_fp32_closely():
    params = {"w": jnp.linspace(-1, 1, 512).reshape(2, 256)}
    cfg = AdamWConfig(lr=0.01, weight_decay=0.0, clip_norm=0.0)
    s32 = adamw_init(params)
    s8 = int8_adamw_init(params)
    p32, p8 = params, params
    for i in range(20):
        g = jax.grad(_quadratic)(p32)
        p32, s32 = adamw_update(p32, g, s32, cfg)
        g8 = jax.grad(_quadratic)(p8)
        p8, s8 = int8_adamw_update(p8, g8, s8, cfg)
    np.testing.assert_allclose(np.asarray(p8["w"]), np.asarray(p32["w"]),
                               rtol=0.08, atol=0.02)


def test_clipping():
    g = {"a": jnp.full((100,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 99.0
    total = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                         for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-4


def test_cosine_schedule():
    lr0 = float(cosine_schedule(0, base_lr=1.0, warmup=10, total=100))
    lr_peak = float(cosine_schedule(10, base_lr=1.0, warmup=10, total=100))
    lr_end = float(cosine_schedule(100, base_lr=1.0, warmup=10, total=100))
    assert lr0 < lr_peak and abs(lr_peak - 1.0) < 0.11
    assert abs(lr_end - 0.1) < 1e-3


def test_adamw_preserves_tuple_pytrees():
    params = (({"w": jnp.ones((4,))},), {"e": jnp.ones((2,))})
    cfg = AdamWConfig(lr=0.1)
    state = adamw_init(params)
    g = jax.tree.map(jnp.ones_like, params)
    new_p, state = adamw_update(params, g, state, cfg)
    assert jax.tree.structure(new_p) == jax.tree.structure(params)
