"""Multi-device pipeline tests (subprocess: 8 forced host devices).

The heavyweight numerical equivalence checks live in tests/helpers/ and run
in a subprocess so the main pytest process keeps a single CPU device.
"""
import pytest

from helpers import run_helper


@pytest.mark.slow
def test_pipeline_equivalence():
    out = run_helper("pipeline_equiv.py")
    assert "PIPELINE EQUIVALENCE: ALL OK" in out


@pytest.mark.slow
def test_comm_volume_reduction():
    out = run_helper("comm_volume_hlo.py")
    assert "reduction=" in out
    # PULSE must cut collective-permute bytes vs the skip-carry baseline
    red = float(out.split("reduction=")[1].split("%")[0])
    assert red > 30.0
