"""Multi-device pipeline tests (subprocess: 8 forced host devices).

The heavyweight numerical equivalence checks live in tests/helpers/ and run
in a subprocess so the main pytest process keeps a single CPU device.
"""
import os
import subprocess
import sys

import pytest

HELPERS = os.path.join(os.path.dirname(__file__), "helpers")
ENV = dict(os.environ,
           PYTHONPATH=os.path.abspath(
               os.path.join(os.path.dirname(__file__), "..", "src")))


def _run(script):
    res = subprocess.run(
        [sys.executable, os.path.join(HELPERS, script)],
        env=ENV, capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, (
        f"{script} failed:\nSTDOUT:\n{res.stdout[-3000:]}\n"
        f"STDERR:\n{res.stderr[-3000:]}")
    return res.stdout


def test_pipeline_equivalence():
    out = _run("pipeline_equiv.py")
    assert "PIPELINE EQUIVALENCE: ALL OK" in out


def test_comm_volume_reduction():
    out = _run("comm_volume_hlo.py")
    assert "reduction=" in out
    # PULSE must cut collective-permute bytes vs the skip-carry baseline
    red = float(out.split("reduction=")[1].split("%")[0])
    assert red > 30.0
