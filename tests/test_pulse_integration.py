"""End-to-end PULSE planning: graph -> partition -> schedule -> tuner."""

from repro.core.partition import partition
from repro.core.schedule import template_wave, validate_schedule, simulate
from repro.core.tuner import tune, profile_partition
from repro.core.comm_model import partition_comm_volume
from repro.core.hw import ASCEND_910A_CLUSTER
from repro.models.diffusion import UViTConfig, uvit_block_graph


def test_full_planning_pipeline_uvit():
    cfg = UViTConfig("t", img_size=32, d_model=512, n_layers=16, n_heads=8,
                     d_ff=2048)
    g = uvit_block_graph(cfg, batch=32)
    D = 4
    part = partition(g, D)
    assert part.folded and part.validate_collocation(g)
    v = partition_comm_volume(g, part)
    assert v.skip_bytes == 0.0
    sched = template_wave(D, 8)
    colloc = [(s, part.num_stages - 1 - s) for s in range(D)]
    assert not validate_schedule(sched, lambda s: min(s, 2 * D - 1 - s),
                                 collocated=colloc)
    prof = profile_partition(g, part)
    mk, bubble = simulate(sched, prof.fwd_time_per_sample, bwd_ratio=2.0)
    assert mk > 0 and 0 <= bubble < 0.6
    choices = tune(g, 16, hw=ASCEND_910A_CLUSTER)
    assert choices
    assert choices[0].t_sample <= choices[-1].t_sample
