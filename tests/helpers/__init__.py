"""Test helpers: subprocess equivalence scripts + optional-dep shims."""
import os
import subprocess
import sys

_HELPERS_DIR = os.path.dirname(__file__)
_SUBPROCESS_ENV = dict(
    os.environ,
    PYTHONPATH=os.path.abspath(os.path.join(_HELPERS_DIR, "..", "..",
                                            "src")))


def run_helper(script: str, *args: str, timeout: int = 1200) -> str:
    """Run a helper script (multi-device subprocess) and return stdout.

    Asserts a zero exit, attaching the output tails on failure — shared by
    every subprocess-based equivalence test.
    """
    res = subprocess.run(
        [sys.executable, os.path.join(_HELPERS_DIR, script), *args],
        env=_SUBPROCESS_ENV, capture_output=True, text=True,
        timeout=timeout)
    assert res.returncode == 0, (
        f"{script} {args} failed:\nSTDOUT:\n{res.stdout[-3000:]}\n"
        f"STDERR:\n{res.stderr[-3000:]}")
    return res.stdout
