"""Shared assertions: lowered step programs/tables vs ``Schedule.grid()``.

One source of truth for the "the lowering matches the schedule
slot-for-slot" contract, used by the in-process planning tests
(test_schedule, test_auto_pipeline) and the multi-device subprocess
equivalence helper (auto_pipeline_equiv).
"""
from repro.runtime.schedule_exec import IDLE, RUN_DEC, RUN_ENC, StepTables


def assert_programs_match_grid(sched):
    """``Schedule.device_programs()`` equals ``grid()`` slot-for-slot."""
    progs = sched.device_programs()
    grid = sched.grid()
    assert progs.num_devices == sched.D
    assert progs.num_steps == sched.makespan
    for d in range(sched.D):
        for t in range(sched.makespan):
            p = grid[d][t]
            assert bool(progs.valid[d, t]) == (p is not None), (d, t)
            if p is None:
                assert progs.virtual[d, t] == -1
                assert progs.microbatch[d, t] == -1
            else:
                assert progs.virtual[d, t] == p.virtual, (d, t)
                assert progs.microbatch[d, t] == p.microbatch, (d, t)
    assert int(progs.valid.sum()) == len(sched.placements)
    return progs


def assert_step_tables_match_grid(sched, folded, device_of_stage=None):
    """The executor-facing ``StepTables`` cover exactly the schedule's
    forward placements, with the right selector/microbatch/slot per step
    (the enc/dec boundary is S/2 — a device may hold V slots per kind)."""
    tabs = StepTables.from_schedule(sched, folded=folded,
                                    device_of_stage=device_of_stage)
    grid = sched.grid()
    S = sched.S
    half = S // 2 if folded else S
    for k, t in enumerate(tabs.forward_steps):
        for d in range(sched.D):
            p = grid[d][t]
            if p is not None and p.virtual < S:
                want = RUN_DEC if folded and p.virtual >= half else RUN_ENC
                assert tabs.sel[d, k] == want, (d, k)
                assert tabs.mb[d, k] == p.microbatch, (d, k)
                assert 0 <= tabs.slot[d, k] < tabs.V, (d, k)
            else:
                assert tabs.sel[d, k] == IDLE, (d, k)
    n_fwd = sum(1 for p in sched.placements if p.virtual < S)
    assert int((tabs.sel != IDLE).sum()) == n_fwd
    return tabs
