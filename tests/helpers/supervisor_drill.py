"""End-to-end training-supervisor drill: detect -> rollback -> shrink.

Launches a REAL 2-host supervised run (the supervisor spawns one
``repro.launch.train`` worker subprocess per simulated host, P=2 x dp=2,
fp32 wire) and kills it mid-run:

- ``hostdown`` — host 1 hard-exits after step 7 (``hostdown@8:1``): the
  supervisor sees the exit code, rolls back to the step-8 checkpoint,
  re-tunes onto the surviving host (dp=1 x P=2) and resumes;
- ``hang``     — host 0 stalls before step 6 (``hang@6``, a stuck
  collective: the process stays alive, its heartbeat step freezes; host
  1 wedges later at the step-8 commit barrier): the watchdog flags the
  ROOT hung host within ``stall_timeout * miss_budget``, the supervisor
  kills the generation, rolls back to step 4 and resumes shrunk.

Both scenarios must finish with the uninterrupted reference loss
trajectory (single process, same plan, no faults) at rtol 1e-4, with
the full detect/rollback/shrink/restart event sequence in events.jsonl.

Scenarios share one jit compilation cache (reference plan == generation
0's plan, so workers mostly reuse the reference run's compilations).

Usage: python tests/helpers/supervisor_drill.py [hostdown hang ...]
Prints ``SUPERVISOR DRILL: ALL OK`` when every scenario passes.
"""
import os
import sys
import tempfile

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR",
                      tempfile.mkdtemp(prefix="repro_sup_cache_"))
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "1")

STEPS = 12
PLAN = ["--arch", "uvit-nano", "--pipeline", "--devices", "4",
        "--dp", "2", "--pp", "2", "--microbatches", "4",
        "--global-batch", "8", "--steps", str(STEPS), "--lr", "1e-3",
        "--wire-dtype", "float32", "--log-every", "4"]

_REF = {}


def _reference() -> dict:
    """Uninterrupted single-process trajectory on generation 0's plan."""
    if not _REF:
        from repro.launch.train import _parse_args, run
        res = run(_parse_args(PLAN))
        assert len(res.losses) == STEPS
        _REF.update(res.losses)
    return _REF


def _drill(name: str, faults: str, rollback_step: int,
           detect_kind: str) -> None:
    from repro.launch.supervisor import (Supervisor, SupervisorConfig,
                                         format_status, read_events)

    ref = _reference()
    d = tempfile.mkdtemp(prefix=f"repro_sup_{name}_")
    cfg = SupervisorConfig(
        run_dir=d, num_hosts=2, devices_per_host=2, steps=STEPS,
        global_batch=8, arch="uvit-nano", dp=2, pp=2, microbatches=4,
        wire_dtype="float32", lr=1e-3, ckpt_every=4, faults=faults,
        stall_timeout=8.0, miss_budget=2, poll=0.2, backoff_base=0.2,
        log_every=4)
    res = Supervisor(cfg).run()

    assert res.ok and res.outcome == "done", \
        f"{name}: supervisor ended {res.outcome}"
    assert res.generations == 2 and res.restarts == 1, \
        f"{name}: expected exactly one recovery, got " \
        f"{res.generations} gens / {res.restarts} restarts"
    assert res.final_hosts == 1 and res.final_plan == (1, 2, 0), \
        f"{name}: expected shrink to dp=1 x P=2 on 1 host, got " \
        f"{res.final_plan} on {res.final_hosts}"

    events = read_events(res.events_path)
    kinds = [e["kind"] for e in events]
    for k in (detect_kind, "rollback", "shrink", "restart", "gen-live",
              "done"):
        assert k in kinds, f"{name}: no {k!r} event in {kinds}"
    rb = next(e for e in events if e["kind"] == "rollback")
    assert rb["step"] == rollback_step, \
        f"{name}: rolled back to {rb['step']}, expected {rollback_step}"
    detect = next(e for e in events if e["kind"] == detect_kind)
    if detect_kind == "hang":
        # detected within the watchdog timeout (+ one poll of slack)
        budget = cfg.stall_timeout * cfg.miss_budget + 5 * cfg.poll
        assert detect["age"] <= budget, \
            f"{name}: hang detected after {detect['age']}s > {budget}s"
        assert detect["host"] == 0, \
            f"{name}: hang attributed to host {detect['host']}, not root 0"

    assert sorted(res.losses) == list(range(STEPS)), \
        f"{name}: merged trajectory incomplete: {sorted(res.losses)}"
    for s in range(STEPS):
        a, b = ref[s], res.losses[s]
        assert abs(a - b) <= 1e-4 * abs(a) + 1e-6, \
            f"{name}: step {s} loss {b} != reference {a}"

    status = format_status(d)
    assert detect_kind in status and "rollback" in status, status
    print(f"[drill] {name}: detect({detect_kind}) -> rollback("
          f"{rollback_step}) -> shrink(dp=1 x P=2) -> resume OK, "
          f"trajectory uninterrupted over {STEPS} steps")


def scenario_hostdown():
    # host 1 dies right after the step-8 checkpoint commits: rollback
    # loses nothing, the shrunk plan replays only steps 8..11
    _drill("hostdown", "hostdown@8:1", rollback_step=8,
           detect_kind="hostdown")


def scenario_hang():
    # host 0 freezes before step 6: last complete checkpoint is step 4
    # (host 1 parks its step-8 shard but the commit never closes)
    _drill("hang", "hang@6", rollback_step=4, detect_kind="hang")


SCENARIOS = {"hostdown": scenario_hostdown, "hang": scenario_hang}


def main(argv):
    names = argv or list(SCENARIOS)
    for name in names:
        SCENARIOS[name]()
    print("SUPERVISOR DRILL: ALL OK")


if __name__ == "__main__":
    main(sys.argv[1:])
