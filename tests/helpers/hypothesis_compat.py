"""`hypothesis` shim: property tests collect and run without the optional dep.

When hypothesis is installed (``pip install -e .[test]``) this module
re-exports the real ``given`` / ``settings`` / ``st``, with shrinking and
the full strategy library.  Otherwise a tiny deterministic fallback kicks
in: each ``@given`` test runs ``max_examples`` cases drawn from a
``random.Random`` seeded by the test's qualified name (crc32 — stable
across processes, unlike ``hash``).

Only the strategy surface this repo uses is implemented: ``integers``,
``floats``, ``sampled_from``, ``lists``.  Extend here before reaching for
new strategies in tests.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(seq) -> _Strategy:
            choices = list(seq)
            return _Strategy(lambda r: r.choice(choices))

        @staticmethod
        def lists(elem: _Strategy, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            return _Strategy(lambda r: [
                elem.sample(r)
                for _ in range(r.randint(min_size, max_size))])

    st = _Strategies()

    def settings(max_examples: int = 20, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*strategies_args):
        def deco(fn):
            inner = fn

            def wrapper():
                # read at call time so @settings works above OR below @given
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(inner, "_shim_max_examples", 20))
                seed0 = zlib.crc32(inner.__qualname__.encode())
                for i in range(n):
                    r = random.Random(seed0 + i)
                    drawn = tuple(s.sample(r) for s in strategies_args)
                    inner(*drawn)

            # no functools.wraps: __wrapped__ would make pytest read the
            # inner signature and demand fixtures for the drawn arguments
            for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
                setattr(wrapper, attr, getattr(inner, attr))
            return wrapper
        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
