"""Measure PULSE-vs-baseline collective-permute bytes from compiled HLO."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.runtime.compat import shard_map

from repro.models.diffusion import UViTConfig, init_uvit
from repro.runtime.pipeline import PipelineConfig
from repro.runtime.adapters import DiffusionPipelineAdapter, make_diffusion_microbatches
from repro.runtime.hlo_analysis import collective_bytes

mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
cfg = UViTConfig("t", img_size=8, in_ch=4, patch=2, d_model=32,
                 n_layers=8, n_heads=4, d_ff=64, n_classes=10)
params = init_uvit(key, cfg)
B, M = 8, 4
batch = {"latents": jax.random.normal(key, (B, 8, 8, 4)),
         "labels": jax.random.randint(key, (B,), 0, 10)}
mb, aux = make_diffusion_microbatches(batch, key, M, cfg, "uvit")
pcfg = PipelineConfig(num_devices=4, num_microbatches=M, data_axes=("data",), dp_size=2)
ad = DiffusionPipelineAdapter(cfg, pcfg, "uvit")
mb_spec = jax.tree.map(lambda _: P(None, "data"), mb)
aux_spec = jax.tree.map(lambda _: P(None, "data"), aux)

def lower(fn, stacks, edge):
    def loss(stacks, edge, mb, aux):
        return shard_map(fn, mesh=mesh,
                         in_specs=(jax.tree.map(lambda _: P("model"), stacks[0]),
                                   jax.tree.map(lambda _: P("model"), stacks[1]),
                                   jax.tree.map(lambda _: P(), edge),
                                   mb_spec, aux_spec),
                         out_specs=P(), check_vma=False)(stacks[0], stacks[1], edge, mb, aux)
    g = jax.jit(jax.grad(loss, argnums=(0, 1)))
    return g.lower(stacks, edge, mb, aux).compile()

stacks, edge = ad.split_params(params)
c_wave = lower(ad.build(), stacks, edge)
st_wave = collective_bytes(c_wave.as_text())
print("PULSE wave   :", st_wave)

stacks_b, edge_b = ad.split_params_skip_carry(params)
c_base = lower(ad.build_skip_carry_baseline(), stacks_b, edge_b)
st_base = collective_bytes(c_base.as_text())
print("1F1B baseline:", st_base)

cp_w = st_wave.bytes_by_kind.get("collective-permute", 0)
cp_b = st_base.bytes_by_kind.get("collective-permute", 0)
print(f"per-tick collective-permute: wave={cp_w} base={cp_b} "
      f"reduction={100*(1-cp_w/cp_b):.1f}%")
# correctness too: baseline loss should be finite
l = jax.jit(lambda s,e: shard_map(ad.build_skip_carry_baseline(), mesh=mesh,
      in_specs=(jax.tree.map(lambda _: P("model"), s[0]),
                jax.tree.map(lambda _: P("model"), s[1]),
                jax.tree.map(lambda _: P(), e), mb_spec, aux_spec),
      out_specs=P(), check_vma=False)(s[0], s[1], e, mb, aux))(stacks_b, edge_b)
print("baseline loss:", float(l))
assert np.isfinite(float(l))
