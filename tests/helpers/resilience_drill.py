"""Elastic fault-tolerance drill over the production training driver.

Runs ``repro.launch.train.run`` in-process on 8 simulated host devices
(fp32 wire so trajectories compare at rtol 1e-4) and checks the
save -> kill -> elastic-restore round trip across plan changes:

- ``shrink``  — UViT: P=2 x dp=2 ZeRO-2 stopped abruptly mid-run resumes
  onto P=1 x dp=2 zero=0 (different plan fingerprint: de-stack/re-stack)
  with the uninterrupted run's loss trajectory and final model-space
  params; then the newest checkpoint shard is byte-flipped and a resume
  on the original plan detects the corruption via SHA-256, falls back to
  the previous complete step, and still reproduces the trajectory.
- ``vchange`` — SkipViT: V=2 x P=2 zero=0 resumes onto V=1 x P=2 ZeRO-2.

Usage: python tests/helpers/resilience_drill.py [shrink vchange ...]
Prints ``RESILIENCE DRILL: ALL OK`` when every scenario passes.
"""
import os
import sys
import tempfile

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

BASE = ["--pipeline", "--devices", "8", "--dp", "2",
        "--microbatches", "2", "--global-batch", "4", "--steps", "6",
        "--ckpt-every", "2", "--log-every", "2", "--lr", "1e-3",
        "--wire-dtype", "float32"]


def _run(extra):
    from repro.launch.train import _parse_args, run
    return run(_parse_args(BASE + extra))


def _losses_close(ref, got, what):
    for s, b in got.items():
        a = ref[s]
        assert abs(a - b) <= 1e-4 * abs(a) + 1e-6, \
            f"{what}: step {s} loss {b} != reference {a}"


def _params_close(ref, got, what):
    import jax
    for pa, pb in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                                   rtol=1e-4, atol=1e-6, err_msg=what)


def scenario_shrink():
    from repro.checkpoint import latest_step
    from repro.runtime.resilience import corrupt_checkpoint

    plan_a = ["--arch", "uvit", "--pp", "2", "--zero-stage", "2"]
    ref = _run(plan_a)
    assert ref.losses and ref.logical_params is not None

    d = tempfile.mkdtemp(prefix="repro_drill_shrink_")
    killed = _run(plan_a + ["--ckpt-dir", d, "--faults", "stop@4"])
    assert max(killed.losses) == 3, "stop@4 should end after step 3"
    assert latest_step(d) == 4

    resumed = _run(["--arch", "uvit", "--pp", "1", "--zero-stage", "0",
                    "--ckpt-dir", d, "--resume"])
    assert resumed.resumed is not None and resumed.resumed.step == 4
    assert resumed.resumed.elastic, "P=2 -> P=1 must take the elastic path"
    _losses_close(ref.losses, resumed.losses, "shrink P=2->P=1 losses")
    _params_close(ref.logical_params, resumed.logical_params,
                  "shrink P=2->P=1 final params")
    print("[drill] shrink: elastic P=2 dp=2 zero2 -> P=1 dp=2 zero0 OK")

    # corrupt the newest checkpoint (step 6, written by the resumed run):
    # a further resume must detect it via SHA-256, fall back to step 4,
    # and still reproduce the reference trajectory.
    what = corrupt_checkpoint(d)
    print(f"[drill] shrink: {what}")
    assert latest_step(d) == 4, "corrupt step must fail verification"
    recovered = _run(plan_a + ["--ckpt-dir", d, "--resume"])
    assert recovered.resumed is not None and recovered.resumed.step == 4
    _losses_close(ref.losses, recovered.losses,
                  "corrupt-shard fallback losses")
    _params_close(ref.logical_params, recovered.logical_params,
                  "corrupt-shard fallback final params")
    print("[drill] shrink: corrupt-shard fallback to step 4 OK")


def scenario_vchange():
    from repro.checkpoint import latest_step

    plan_a = ["--arch", "skipvit", "--pp", "2", "--interleave", "2",
              "--zero-stage", "0"]
    ref = _run(plan_a)

    d = tempfile.mkdtemp(prefix="repro_drill_vchange_")
    _run(plan_a + ["--ckpt-dir", d, "--faults", "stop@4"])
    assert latest_step(d) == 4

    resumed = _run(["--arch", "skipvit", "--pp", "2", "--interleave", "1",
                    "--zero-stage", "2", "--ckpt-dir", d, "--resume"])
    assert resumed.resumed is not None and resumed.resumed.step == 4
    assert resumed.resumed.elastic, "V=2 -> V=1 must take the elastic path"
    _losses_close(ref.losses, resumed.losses, "V=2->V=1 losses")
    _params_close(ref.logical_params, resumed.logical_params,
                  "V=2->V=1 final params")
    print("[drill] vchange: elastic V=2 zero0 -> V=1 zero2 OK")


SCENARIOS = {"shrink": scenario_shrink, "vchange": scenario_vchange}


def main(argv):
    names = argv or list(SCENARIOS)
    for name in names:
        SCENARIOS[name]()
    print("RESILIENCE DRILL: ALL OK")


if __name__ == "__main__":
    main(sys.argv[1:])
