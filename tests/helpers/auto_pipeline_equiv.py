"""Subprocess helper: auto_pipeline executor == single-device reference.

Differential tests for the compile path (graph -> partition -> schedule ->
executor): for each config, `auto_pipeline` plans and lowers a pipeline on
mocked multi-device meshes (forced host devices) and

- the table-driven executor's loss + merged gradients must match a plain
  single-device forward/backward within rtol 1e-4;
- where the closed-form executors apply (greedy template orders, M >= D),
  the table-driven executor must also match them differentially
  (loss + grads) — the closed forms are the hand-written references;
- the lowered step tables must match ``Schedule.grid()`` exactly
  (``device_programs`` slot-for-slot; ``StepTables`` on the forward
  placements), for greedy *and* ILP schedules;
- on selected configs, the overlapped (double-buffered ring hops)
  executor must match the synchronous reference lowering
  (``PipelineConfig.overlap=False``) — loss + grads at rtol 1e-4 on the
  exact fp32 wire.

Configs (pass names as argv to run a subset; default: all):
  linear-even    LM, S=D=4, uniform costs -> even 1F1B split
  linear-uneven  LM, S=D=4, heterogeneous profiled times -> uneven DP cuts
  wave-even      UViT, S=2D (D=2), uniform costs -> even folded wave
  wave-uneven    UViT, S=2D (D=2), heterogeneous times -> uneven symmetric
                 cuts from the bidirectional DP (Algorithm 1)
  wave-short     UViT, D=4, M=D-1: the closed-form wave executor must
                 refuse (stale-row clip), the table executor must match ref
  wave-ilp       UViT, D=2, ILP-synthesized schedule through the
                 table-driven lowering
  wave-asym      SkipViT 3 enc + 2 mid + 3 dec (make_unet_like(3, 2)
                 shape), heterogeneous times -> mirror-ASYMMETRIC fold:
                 independent enc/dec counts + graph-derived skip pairing
  wave-sparse    SkipViT with a sparse skip set (one pair dropped) ->
                 asymmetric fold with skip-less decoder rows
  wave-hunyuan   Hunyuan-DiT small config through the compile path
                 (adaLN + cross-attn blocks; time-MLP grads flow through
                 the aux conditioning closure)
  linear-zero2 / wave-zero1 / wave-zero2
                 hybrid ZeRO x pipeline (dp=2, P=2): ZeRO-sharded
                 param/optimizer stacks vs the unsharded reference

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import dataclasses
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.diffusion import (HunyuanDiTConfig, SkipViTConfig,
                                    UViTConfig, hunyuan_apply,
                                    hunyuan_pipeline_graph, skipvit_apply,
                                    skipvit_pipeline_graph,
                                    uvit_apply, uvit_pipeline_graph)
from repro.models.layers import AttnConfig
from repro.models.lm import LMConfig, lm_loss, lm_pipeline_graph
from repro.runtime.adapters import (diffusion_model_fns, lm_model_fns,
                                    make_diffusion_microbatches,
                                    skipvit_model_fns)
from repro.runtime.compile import auto_pipeline

from schedule_checks import (assert_programs_match_grid,
                             assert_step_tables_match_grid)

KEY = jax.random.PRNGKey(0)
RTOL = 1e-4
# bf16-wire vs fp32-wire tolerance: every boundary hop rounds the
# activation (and, in the transposed scan, its cotangent) to bf16's 8-bit
# mantissa (~0.4% relative per hop); losses and grads of these small
# configs stay within a few percent relative, with near-zero entries
# absorbed by the absolute floor.  Documented in README "Wire format &
# buffer liveness" — exactness is what wire_dtype="float32" is for.
WIRE_RTOL = 5e-2
WIRE_ATOL = 1e-3


def _check_grads(gm, gr, label, rtol=RTOL, atol=1e-6):
    flat_m = jax.tree_util.tree_flatten_with_path(gm)[0]
    flat_r = jax.tree.leaves(gr)
    assert len(flat_m) == len(flat_r)
    for (path, a), b in zip(flat_m, flat_r):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=rtol, atol=atol,
            err_msg=f"{label}: grad mismatch at "
                    f"{jax.tree_util.keystr(path)}")


def _check_tables_match_grid(cp, label):
    """The lowered step programs equal Schedule.grid() slot-for-slot."""
    assert_programs_match_grid(cp.schedule)
    tabs = assert_step_tables_match_grid(cp.schedule, cp.folded)
    n_fwd = int((tabs.sel != 0).sum())
    print(f"{label}: step tables == grid "
          f"({n_fwd} forward slots over {tabs.num_steps} steps)")


def _check_windows(cp, label):
    """The rx buffers are sized by the schedule-proven liveness window,
    not by the microbatch count (the acceptance-criterion assertion)."""
    tabs = cp.step_tables()
    M = cp.schedule.M
    assert tabs.W_down < M and tabs.W_up < M, (
        label, tabs.W_down, tabs.W_up, M)
    live_d, live_u = tabs.live_hops
    assert live_d + live_u < tabs.dense_hops
    print(f"{label}: rx windows W_down={tabs.W_down} W_up={tabs.W_up} "
          f"< M={M}; live hops {live_d}+{live_u} < dense "
          f"{tabs.dense_hops}")


def _diff_wire(cp, mesh, state, batch_args, label):
    """bf16-wire executor vs the fp32-wire escape hatch: loss + grads
    within the documented bf16 rounding tolerance (WIRE_RTOL)."""
    fp = dataclasses.replace(
        cp, pcfg=dataclasses.replace(cp.pcfg, wire_dtype="float32"))
    bf = dataclasses.replace(
        cp, pcfg=dataclasses.replace(cp.pcfg, wire_dtype="bfloat16"))
    lb, gb = jax.jit(jax.value_and_grad(bf.bind(mesh)))(state, *batch_args)
    lf, gf = jax.jit(jax.value_and_grad(fp.bind(mesh)))(state, *batch_args)
    np.testing.assert_allclose(float(lb), float(lf), rtol=WIRE_RTOL)
    _check_grads(cp.merge_params(gb[0], gb[1]),
                 cp.merge_params(gf[0], gf[1]), f"{label}[bf16-vs-fp32]",
                 rtol=WIRE_RTOL, atol=WIRE_ATOL)
    print(f"{label}: bf16-wire == fp32-wire within rtol {WIRE_RTOL} "
          f"(loss {float(lb):.6f} vs {float(lf):.6f})")


def _diff_overlap(cp, mesh, state, batch_args, label):
    """Overlapped (double-buffered) executor vs the synchronous reference
    lowering (``PipelineConfig.overlap=False``): loss + grads at rtol RTOL
    on the exact fp32 wire — moving each step's ring sends to the top of
    the next step's scan body must not change any value, only when the
    collective runs relative to compute."""
    ov = dataclasses.replace(
        cp, pcfg=dataclasses.replace(cp.pcfg, overlap=True))
    sync = dataclasses.replace(
        cp, pcfg=dataclasses.replace(cp.pcfg, overlap=False))
    lo, go = jax.jit(jax.value_and_grad(ov.bind(mesh)))(state, *batch_args)
    ls, gs = jax.jit(jax.value_and_grad(sync.bind(mesh)))(state, *batch_args)
    np.testing.assert_allclose(float(lo), float(ls), rtol=RTOL)
    _check_grads(cp.merge_params(go[0], go[1]),
                 cp.merge_params(gs[0], gs[1]), f"{label}[overlap-vs-sync]")
    print(f"{label}: overlapped executor == synchronous lowering "
          f"(loss {float(lo):.6f}; grads OK)")


def _diff_executors(cp, mesh, state, batch_args, label):
    """Table executor vs closed-form executor: loss + grads (rtol 1e-4)."""
    cf = dataclasses.replace(cp, executor="closed_form")
    table_loss = cp.bind(mesh)
    closed_loss = cf.bind(mesh)
    lt, gt = jax.jit(jax.value_and_grad(table_loss))(state, *batch_args)
    lc, gc = jax.jit(jax.value_and_grad(closed_loss))(state, *batch_args)
    np.testing.assert_allclose(float(lt), float(lc), rtol=RTOL)
    _check_grads(cp.merge_params(gt[0], gt[1]),
                 cp.merge_params(gc[0], gc[1]), f"{label}[table-vs-closed]")
    print(f"{label}: table executor == closed-form executor "
          f"(loss {float(lt):.6f}; grads OK)")


def _run_lm(name, fwd_times, expect_uneven, *, force_wave=None,
            pipeline_devices=4, compare_closed=True, interleave=None,
            check_overlap=False, zero_stage=None):
    cfg = LMConfig(name="t", vocab=64, d_model=32, n_layers=8,
                   attn=AttnConfig(32, 4, 2, 8), d_ff=64,
                   tied_embeddings=True)
    graph = lm_pipeline_graph(cfg, fwd_times=fwd_times)
    # wire_dtype="float32": the exact-wire escape hatch — these checks
    # demand rtol 1e-4 against the reference; _diff_wire covers bf16
    cp = auto_pipeline(graph, lm_model_fns(cfg), pipeline_devices,
                       pipeline_devices=pipeline_devices, microbatches=4,
                       lam=0.0, dp_size=2, force_wave=force_wave,
                       interleave=interleave, wire_dtype="float32",
                       zero_stage=zero_stage)
    if zero_stage is not None:
        assert cp.pcfg.zero_stage == zero_stage, (name, cp.pcfg.zero_stage)
        if zero_stage >= 2:
            specs, dims = cp._zero_layout()
            assert specs is not None
            flat_dims = jax.tree.leaves(dims)
            assert any(d >= 0 for d in flat_dims), (
                f"{name}: ZeRO-2 layout sharded no stack leaf", flat_dims)
    V = interleave or 1
    if force_wave:
        assert cp.folded
        assert cp.partition.num_stages == 2 * V * pipeline_devices
    else:
        assert not cp.folded
        assert cp.partition.num_stages == V * pipeline_devices   # S = VD
    assert cp.layout.V == V
    uneven = len(set(cp.layout.counts)) > 1
    assert uneven == expect_uneven, (name, cp.layout.counts)
    _check_tables_match_grid(cp, name)

    mesh = jax.make_mesh((2, pipeline_devices), ("data", "model"))
    params = cp.model_fns.init_fn(KEY)
    state = cp.split_params(params)
    B, S, M = 8, 16, 4
    tokens = jax.random.randint(KEY, (B, S), 0, 64)
    mbs = {"tokens": tokens.reshape(M, B // M, S)}

    bound = cp.bind(mesh)
    # folded executors take (params, mbs, aux); LM carries no aux
    loss = (lambda st, mb: bound(st, mb, {})) if cp.folded else bound
    lp, gp = jax.jit(jax.value_and_grad(loss))(state, mbs)

    def ref(params):
        return jnp.mean(jnp.asarray(
            [lm_loss(params, {"tokens": mbs["tokens"][m]}, cfg)
             for m in range(M)]))

    lr, gr = jax.jit(jax.value_and_grad(ref))(params)
    np.testing.assert_allclose(float(lp), float(lr), rtol=RTOL)
    _check_grads(cp.merge_params(gp[0], gp[1]), gr, name)
    print(f"{name}: counts={cp.layout.counts} loss={float(lp):.6f} "
          f"== ref {float(lr):.6f}; grads OK")
    batch_args = (mbs, {}) if cp.folded else (mbs,)
    if compare_closed:
        _diff_executors(cp, mesh, state, batch_args, name)
    if check_overlap:
        _diff_overlap(cp, mesh, state, batch_args, name)


def _run_uvit(name, fwd_times, expect_uneven, *, pipeline_devices=2,
              microbatches=4, use_ilp=False, compare_closed=True,
              expect_closed_rejects=False, check_wire=False):
    cfg = UViTConfig("t", img_size=8, in_ch=4, patch=2, d_model=32,
                     n_layers=8, n_heads=4, d_ff=64, n_classes=10)
    graph = uvit_pipeline_graph(cfg, fwd_times=fwd_times)
    cp = auto_pipeline(graph, diffusion_model_fns(cfg, "uvit"),
                       pipeline_devices, pipeline_devices=pipeline_devices,
                       microbatches=microbatches, lam=0.0, dp_size=2,
                       use_ilp=use_ilp, wire_dtype="float32")
    assert cp.folded and cp.partition.num_stages == 2 * pipeline_devices
    uneven = len(set(cp.layout.counts)) > 1
    assert uneven == expect_uneven, (name, cp.layout.counts)
    _check_tables_match_grid(cp, name)
    _check_windows(cp, name)
    if expect_closed_rejects:
        # M < D: the closed-form wave executor's clip reads stale rows —
        # it must refuse, while the table-driven lowering stays correct.
        try:
            dataclasses.replace(cp, executor="closed_form").build()
        except ValueError as e:
            assert "M >= D" in str(e), e
            print(f"{name}: closed-form executor rejects M < D as expected")
        else:
            raise AssertionError(
                f"{name}: closed-form executor accepted M < D")

    mesh = jax.make_mesh((2, pipeline_devices), ("data", "model"))
    params = cp.model_fns.init_fn(KEY)
    state = cp.split_params(params)
    M = microbatches
    B = 2 * M            # per-microbatch batch 2, sharded over data axis 2
    batch = {"latents": jax.random.normal(KEY, (B, 8, 8, 4)),
             "labels": jax.random.randint(KEY, (B,), 0, 10)}
    mb, aux = make_diffusion_microbatches(batch, KEY, M, cfg, "uvit")

    loss = cp.bind(mesh)
    lp, gp = jax.jit(jax.value_and_grad(loss))(state, mb, aux)

    def ref(params):
        losses = []
        for m in range(M):
            pred = uvit_apply(params, mb["xt"][m], aux["t"][m],
                              {"labels": mb["labels"][m]}, cfg)
            losses.append(jnp.mean(jnp.square(pred - mb["noise"][m])))
        return jnp.mean(jnp.asarray(losses))

    lr, gr = jax.jit(jax.value_and_grad(ref))(params)
    np.testing.assert_allclose(float(lp), float(lr), rtol=RTOL)
    _check_grads(cp.merge_params(gp[0], gp[1]), gr, name)
    print(f"{name}: counts={cp.layout.counts} loss={float(lp):.6f} "
          f"== ref {float(lr):.6f}; grads OK")
    if compare_closed:
        _diff_executors(cp, mesh, state, (mb, aux), name)
    if check_wire:
        _diff_wire(cp, mesh, state, (mb, aux), name)


def _run_skipvit(name, cfg, fwd_times, *, pipeline_devices=2,
                 microbatches=4, compare_closed=True, interleave=None,
                 use_ilp=False, expect_asym=True, remat=True,
                 check_wire=False, check_overlap=False):
    """SkipViT (homogeneous stack, sparse/mid-block skips): the partitions
    are mirror-ASYMMETRIC folds — the configs StageLayout used to reject.
    Table executor vs single-device reference; closed-form wave (which now
    also reads the generalized counts/pairing) differentially when M>=D.
    ``interleave=V`` pins a V-fold interleaved plan (S = 2VD stage slots;
    the closed-form executors cannot realize those at all)."""
    graph = skipvit_pipeline_graph(cfg, fwd_times=fwd_times)
    cp = auto_pipeline(graph, skipvit_model_fns(cfg), pipeline_devices,
                       pipeline_devices=pipeline_devices,
                       microbatches=microbatches, lam=0.0, dp_size=2,
                       interleave=interleave, use_ilp=use_ilp,
                       remat=remat, wire_dtype="float32")
    if interleave is not None and interleave > 1:
        assert cp.layout.V == interleave, (name, cp.layout.V)
        assert cp.partition.num_stages == 2 * interleave * pipeline_devices
        try:
            dataclasses.replace(cp, executor="closed_form").build()
        except ValueError as e:
            assert "closed-form" in str(e), e
            print(f"{name}: closed-form executor rejects V={interleave} "
                  "as expected")
        else:
            raise AssertionError(
                f"{name}: closed-form executor accepted V={interleave}")
    if expect_asym:
        assert cp.folded and not cp.partition.mirror_symmetric(), (
            name, cp.partition.cuts)
        assert cp.layout.enc_counts != cp.layout.dec_counts
    _check_tables_match_grid(cp, name)

    mesh = jax.make_mesh((2, pipeline_devices), ("data", "model"))
    params = cp.model_fns.init_fn(KEY)
    state = cp.split_params(params)
    M = microbatches
    B = 2 * M
    batch = {"latents": jax.random.normal(KEY, (B, 8, 8, 4)),
             "labels": jax.random.randint(KEY, (B,), 0, 10)}
    mb, aux = make_diffusion_microbatches(batch, KEY, M, cfg, "uvit")

    loss = cp.bind(mesh)
    lp, gp = jax.jit(jax.value_and_grad(loss))(state, mb, aux)

    def ref(params):
        losses = []
        for m in range(M):
            pred = skipvit_apply(params, mb["xt"][m], aux["t"][m],
                                 {"labels": mb["labels"][m]}, cfg)
            losses.append(jnp.mean(jnp.square(pred - mb["noise"][m])))
        return jnp.mean(jnp.asarray(losses))

    lr, gr = jax.jit(jax.value_and_grad(ref))(params)
    np.testing.assert_allclose(float(lp), float(lr), rtol=RTOL)
    _check_grads(cp.merge_params(gp[0], gp[1]), gr, name)
    print(f"{name}: cuts={cp.partition.cuts} enc={cp.layout.enc_counts} "
          f"dec={cp.layout.dec_counts} loss={float(lp):.6f} "
          f"== ref {float(lr):.6f}; grads OK")
    if compare_closed:
        _diff_executors(cp, mesh, state, (mb, aux), name)
    if check_wire:
        _check_windows(cp, name)
        _diff_wire(cp, mesh, state, (mb, aux), name)
    if check_overlap:
        _diff_overlap(cp, mesh, state, (mb, aux), name)


def _run_hunyuan(name, *, pipeline_devices=2, microbatches=4):
    """Hunyuan-DiT small config through auto_pipeline vs the single-device
    model.

    Loss is checked against the *true* ``hunyuan_apply`` (which recomputes
    the adaLN ``temb`` from the time-MLP params — identical values since
    the aux conditioning was produced from the same params).  Gradients are
    checked against a block-loop reference that, like the executor, takes
    (temb, ctx) as microbatch data — both sides differentiate the same
    function of the block/edge parameters.  Stage stacks are computed
    outside the executor jit (see README "JAX compat imports": fusing
    split_params into the same jit as the shard_map executor miscompiles
    on legacy JAX)."""
    from repro.models import diffusion as dm
    from repro.models.layers import rms_norm

    cfg = HunyuanDiTConfig("t", img_size=8, in_ch=4, patch=2, d_model=32,
                           n_layers=8, n_heads=4, d_ff=64, ctx_dim=16,
                           ctx_len=4)
    graph = hunyuan_pipeline_graph(cfg)
    cp = auto_pipeline(graph, diffusion_model_fns(cfg, "hunyuan"),
                       pipeline_devices, pipeline_devices=pipeline_devices,
                       microbatches=microbatches, lam=0.0, dp_size=2,
                       wire_dtype="float32")
    assert cp.folded and cp.partition.num_stages == 2 * pipeline_devices
    _check_tables_match_grid(cp, name)

    mesh = jax.make_mesh((2, pipeline_devices), ("data", "model"))
    params = cp.model_fns.init_fn(KEY)
    state = cp.split_params(params)
    M = microbatches
    B = 2 * M
    batch = {"latents": jax.random.normal(KEY, (B, 8, 8, 4)),
             "text_embeds": jax.random.normal(KEY, (B, 4, 16))}
    mb, aux = make_diffusion_microbatches(batch, KEY, M, cfg, "hunyuan",
                                          params=params)
    loss = cp.bind(mesh)
    lp = jax.jit(loss)(state, mb, aux)

    def ref_true(params):
        """End-to-end model: temb recomputed from params inside."""
        losses = []
        ctx_mb = batch["text_embeds"].reshape(M, B // M, 4, 16)
        for m in range(M):
            pred = hunyuan_apply(params, mb["xt"][m], aux["t"][m],
                                 {"text_embeds": ctx_mb[m]}, cfg)
            losses.append(jnp.mean(jnp.square(pred - mb["noise"][m])))
        return jnp.mean(jnp.asarray(losses))

    def ref_aux(params):
        """Same dataflow as the executor: (temb, ctx) enter as data."""
        losses = []
        for m in range(M):
            x = (dm._patchify(mb["xt"][m], cfg.patch)
                 @ params["patch_embed"] + params["pos_embed"][None])
            kw = {"ctx": aux["ctx"][m], "temb": aux["temb"][m]}
            skips = []
            for r in range(cfg.half):
                bp = jax.tree.map(lambda a: a[r], params["enc_blocks"])
                x = dm._apply_vit_block(bp, x, cfg, **kw)
                skips.append(x)
            for r in range(cfg.half):
                bp = jax.tree.map(lambda a: a[r], params["dec_blocks"])
                x = dm._apply_vit_block(bp, x, cfg,
                                        skip=skips[cfg.half - 1 - r], **kw)
            h = rms_norm(x, params["out_norm"], cfg.norm_eps)
            pred = dm._unpatchify(h @ params["out_proj"], cfg.patch,
                                  cfg.img_size, cfg.in_ch)
            losses.append(jnp.mean(jnp.square(pred - mb["noise"][m])))
        return jnp.mean(jnp.asarray(losses))

    lt = jax.jit(ref_true)(params)
    la = jax.jit(ref_aux)(params)
    np.testing.assert_allclose(float(lp), float(lt), rtol=RTOL)
    np.testing.assert_allclose(float(lp), float(la), rtol=RTOL)
    gp = jax.jit(jax.grad(loss))(state, mb, aux)
    _check_grads(cp.merge_params(gp[0], gp[1]),
                 jax.jit(jax.grad(ref_aux))(params), name)
    print(f"{name}: counts={cp.layout.counts} loss={float(lp):.6f} "
          f"== hunyuan_apply {float(lt):.6f}; grads OK")


CONFIGS = {
    "linear-even": lambda: _run_lm("linear-even", None, False),
    "linear-uneven": lambda: _run_lm(
        "linear-uneven", [4, 1, 1, 1, 1, 1, 1, 4], True,
        check_overlap=True),
    "wave-even": lambda: _run_uvit("wave-even", None, False),
    "wave-uneven": lambda: _run_uvit(
        "wave-uneven", [3, 1, 1, 1, 1, 1, 1, 3], True, check_wire=True),
    # skip-free graph forced into a fold: symmetric-fold partitioner +
    # empty-skip wave executor (partition_symmetric_fold)
    "wave-lm-uneven": lambda: _run_lm(
        "wave-lm-uneven", [4, 1, 1, 1, 1, 1, 1, 4], True,
        force_wave=True, pipeline_devices=2),
    # M = D - 1: only the table-driven lowering can run this; the
    # closed-form executor must reject it (stale-row clip)
    "wave-short": lambda: _run_uvit(
        "wave-short", None, False, pipeline_devices=4, microbatches=3,
        compare_closed=False, expect_closed_rejects=True),
    # exact ILP schedule (Eqs. 6-13) through the table-driven lowering;
    # the closed-form executor cannot realize a non-template order at all
    "wave-ilp": lambda: _run_uvit(
        "wave-ilp", None, False, microbatches=2, use_ilp=True,
        compare_closed=False),
    # mirror-ASYMMETRIC fold (make_unet_like(3, 2) shape): block costs pull
    # the turnaround cut off-centre -> cuts (0,2,3,6,8), enc/dec counts
    # (2,1)/(2,3) — the partitions StageLayout.from_partition rejected
    "wave-asym": lambda: _run_skipvit(
        "wave-asym", SkipViTConfig("t", n_enc=3, n_mid=2, n_dec=3),
        [1, 1, 4, 0.5, 0.5, 0.5, 1, 1], check_overlap=True),
    # sparse skips: pair (1, 6) dropped -> decoder rows without a skip
    # read zeros via the pairing table's -1 sentinel (closed-form diff
    # covered by wave-asym; skipped here to keep tier-1 lean)
    "wave-sparse": lambda: _run_skipvit(
        "wave-sparse",
        SkipViTConfig("t", n_enc=3, n_mid=2, n_dec=3,
                      skip_pairs=((0, 7), (2, 5))),
        [1, 1, 4, 0.5, 0.5, 0.5, 1, 1], compare_closed=False),
    # Hunyuan-DiT model_fns coverage (ROADMAP item): adaLN + cross-attn
    # blocks through the full compile path vs the single-device reference
    "wave-hunyuan": lambda: _run_hunyuan("wave-hunyuan"),
    # Hybrid ZeRO x pipeline (dp=2, P=2, fp32 wire): the executor runs DP
    # replicas of the pipeline with ZeRO-sharded state, and must still
    # match the unsharded single-replica reference at rtol 1e-4.
    # zero1 shards only optimizer state (executors untouched — this pins
    # that the plan records the stage without perturbing values); zero2
    # stores the stacks sharded at rest, all-gathers each slot row on use
    # inside the remat region, and reduce-scatters param grads over data.
    "linear-zero2": lambda: _run_lm(
        "linear-zero2", [4, 1, 1, 1, 1, 1, 1, 4], False,
        pipeline_devices=2, zero_stage=2, compare_closed=False),
    "wave-zero1": lambda: _run_lm(
        "wave-zero1", [4, 1, 1, 1, 1, 1, 1, 4], True, force_wave=True,
        pipeline_devices=2, zero_stage=1, compare_closed=False),
    "wave-zero2": lambda: _run_lm(
        "wave-zero2", [4, 1, 1, 1, 1, 1, 1, 4], True, force_wave=True,
        pipeline_devices=2, zero_stage=2, compare_closed=False),
    # V=2 interleaved 1F1B (linear S = VD, cyclic slot placement, the
    # wraparound down ring): the skip-free side of the interleave axis
    "linear-interleaved": lambda: _run_lm(
        "linear-interleaved", [4, 1, 1, 1, 1, 1, 1, 4], True,
        pipeline_devices=2, interleave=2, compare_closed=False),
    # V=2 interleaved wave (S = 4D stage slots, two (enc, dec) slot pairs
    # per device, wraparound rings, slot-resolved skip pairing): the plans
    # the S == 2D layout gate used to reject outright
    "wave-interleaved": lambda: _run_skipvit(
        "wave-interleaved",
        SkipViTConfig("t", n_enc=4, n_mid=2, n_dec=4),
        [1, 1, 2, 4, 0.5, 0.5, 0.5, 1, 1, 2],
        interleave=2, compare_closed=False, expect_asym=False,
        remat=False, check_wire=True, check_overlap=True),
    # ILP-synthesized (Eqs. 6-13) V=2 interleaved schedule through the
    # same table-driven lowering — exact orders, not just greedy ones
    "wave-interleaved-ilp": lambda: _run_skipvit(
        "wave-interleaved-ilp",
        SkipViTConfig("t", n_enc=3, n_mid=2, n_dec=3),
        [1, 1, 4, 0.5, 0.5, 0.5, 1, 1],
        interleave=2, microbatches=2, use_ilp=True,
        compare_closed=False, expect_asym=False),
}


if __name__ == "__main__":
    names = sys.argv[1:] or list(CONFIGS)
    for n in names:
        CONFIGS[n]()
    print("AUTO PIPELINE EQUIVALENCE: ALL OK")
