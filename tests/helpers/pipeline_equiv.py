"""Subprocess helper: wave/linear pipeline == single-device reference.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.diffusion import UViTConfig, init_uvit, uvit_apply
from repro.models.lm import LMConfig, init_lm, lm_loss
from repro.models.layers import AttnConfig
from repro.runtime.pipeline import PipelineConfig
from repro.runtime.adapters import (DiffusionPipelineAdapter, LMPipelineAdapter,
                                    make_diffusion_microbatches)
from repro.runtime.compat import shard_map

mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)

def test_uvit_wave():
    cfg = UViTConfig("t", img_size=8, in_ch=4, patch=2, d_model=32,
                     n_layers=8, n_heads=4, d_ff=64, n_classes=10)
    params = init_uvit(key, cfg)
    B, M = 8, 4
    batch = {"latents": jax.random.normal(key, (B, 8, 8, 4)),
             "labels": jax.random.randint(key, (B,), 0, 10)}
    mb, aux = make_diffusion_microbatches(batch, key, M, cfg, "uvit")

    pcfg = PipelineConfig(num_devices=4, num_microbatches=M,
                          data_axes=("data",), dp_size=2)
    ad = DiffusionPipelineAdapter(cfg, pcfg, "uvit")
    stacks, edge = ad.split_params(params)
    fn = ad.build()

    mb_spec = jax.tree.map(lambda _: P(None, "data"), mb)
    aux_spec = jax.tree.map(lambda _: P(None, "data"), aux)
    def loss_pipe(stacks, edge, mb, aux):
        return shard_map(fn, mesh=mesh,
                         in_specs=(jax.tree.map(lambda _: P("model"), stacks[0]),
                                   jax.tree.map(lambda _: P("model"), stacks[1]),
                                   jax.tree.map(lambda _: P(), edge),
                                   mb_spec, aux_spec),
                         out_specs=P(), check_vma=False)(
            stacks[0], stacks[1], edge, mb, aux)

    lp = jax.jit(loss_pipe)(stacks, edge, mb, aux)

    # single-device reference with the same (xt, noise, t)
    def ref_loss(params):
        losses = []
        for m in range(M):
            pred = uvit_apply(params, mb["xt"][m], aux["t"][m],
                              {"labels": mb["labels"][m]}, cfg)
            losses.append(jnp.mean(jnp.square(pred - mb["noise"][m])))
        return jnp.mean(jnp.asarray(losses))

    lr = jax.jit(ref_loss)(params)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), rtol=1e-5)
    print(f"uvit wave: pipeline={float(lp):.6f} ref={float(lr):.6f} OK")

    # gradients
    gp = jax.jit(jax.grad(loss_pipe, argnums=(0, 1)))(stacks, edge, mb, aux)
    gmerged = ad.merge_params(gp[0], gp[1])
    gr = jax.jit(jax.grad(ref_loss))(params)
    for kk in ("enc_blocks", "dec_blocks"):
        for leaf_p, leaf_r in zip(jax.tree.leaves(gmerged[kk]),
                                  jax.tree.leaves(gr[kk])):
            np.testing.assert_allclose(np.asarray(leaf_p), np.asarray(leaf_r),
                                       rtol=2e-4, atol=1e-6)
    for kk in ("patch_embed", "pos_embed", "time_mlp", "class_embed",
               "out_norm", "out_proj"):
        for leaf_p, leaf_r in zip(jax.tree.leaves(gmerged[kk]),
                                  jax.tree.leaves(gr[kk])):
            np.testing.assert_allclose(np.asarray(leaf_p), np.asarray(leaf_r),
                                       rtol=2e-4, atol=1e-6)
    print("uvit wave grads OK")


def test_lm_linear_and_wave():
    cfg = LMConfig(name="t", vocab=64, d_model=32, n_layers=8,
                   attn=AttnConfig(32, 4, 2, 8), d_ff=64, tied_embeddings=True)
    params = init_lm(key, cfg)
    B, S, M = 8, 16, 4
    tokens = jax.random.randint(key, (B, S), 0, 64)
    mbs = {"tokens": tokens.reshape(M, B // M, S)}
    mb_spec = jax.tree.map(lambda _: P(None, "data"), mbs)

    def ref_loss(params):
        losses = [lm_loss(params, {"tokens": mbs["tokens"][m]}, cfg)
                  for m in range(M)]
        return jnp.mean(jnp.asarray(losses))
    lr = jax.jit(ref_loss)(params)

    for wave in (False, True):
        pcfg = PipelineConfig(num_devices=4, num_microbatches=M,
                              data_axes=("data",), dp_size=2)
        ad = LMPipelineAdapter(cfg, pcfg, wave=wave)
        stacks, edge = ad.split_params(params)
        fn = ad.build()

        def loss_pipe(stacks, edge, mbs):
            specs = tuple(jax.tree.map(lambda _: P("model"), s) for s in stacks)
            return shard_map(fn, mesh=mesh,
                             in_specs=(*specs,
                                       jax.tree.map(lambda _: P(), edge),
                                       mb_spec),
                             out_specs=P(), check_vma=False)(*stacks, edge, mbs)

        lp = jax.jit(loss_pipe)(stacks, edge, mbs)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), rtol=1e-5)
        gp = jax.jit(jax.grad(loss_pipe, argnums=(0, 1)))(stacks, edge, mbs)
        gmerged = ad.merge_params(gp[0], gp[1])
        gr = jax.jit(jax.grad(ref_loss))(params)
        for leaf_p, leaf_r in zip(jax.tree.leaves(gmerged),
                                  jax.tree.leaves(gr)):
            np.testing.assert_allclose(np.asarray(leaf_p), np.asarray(leaf_r),
                                       rtol=3e-4, atol=1e-6)
        print(f"lm wave={wave}: loss {float(lp):.6f} == ref {float(lr):.6f}; grads OK")


if __name__ == "__main__":
    test_uvit_wave()
    test_lm_linear_and_wave()
    print("PIPELINE EQUIVALENCE: ALL OK")
