"""CheckpointManager retention under CONCURRENT multi-host writers.

Two real OS processes commit shards for the same step (host 1
deliberately lands late) while host 0's GC runs retention the whole
time.  The invariants under test:

- a half-complete step (host 0's shard + manifest down, host 1's shard
  still in flight) is NEVER observed as complete (``latest_step`` keeps
  reporting the previous step) and NEVER collected by GC (it is newer
  than the newest complete step, so retention must leave it alone);
- retention counts only verified-complete steps, so the in-flight step
  cannot crowd a good checkpoint out of the keep window;
- once the late shard lands, the step verifies and ordinary retention
  applies.

Usage:    python tests/helpers/concurrent_ckpt.py <dir>
Internal: python tests/helpers/concurrent_ckpt.py --writer <dir> <host> <delay>
Prints ``CONCURRENT CKPT: ALL OK`` on success.
"""
import os
import subprocess
import sys
import time

STEP = 4


def _tree():
    import numpy as np
    return {"w": np.arange(12.0).reshape(3, 4), "b": np.ones((5,)),
            "k": np.full((2, 2), 7.0)}


def writer(directory: str, host: int, delay: float) -> None:
    from repro.checkpoint.store import save_checkpoint
    time.sleep(delay)
    save_checkpoint(directory, STEP, _tree(), host_id=host, num_hosts=2)


def main(directory: str) -> None:
    from repro.checkpoint.store import (CheckpointManager, complete_steps,
                                        latest_step, verify_step,
                                        wait_step_complete)

    t = _tree()
    for s in (1, 2, 3):                  # history: complete 2-host steps
        for h in (0, 1):
            from repro.checkpoint.store import save_checkpoint
            save_checkpoint(directory, s, t, host_id=h, num_hosts=2)
    assert complete_steps(directory) == [1, 2, 3]

    helper = os.path.abspath(__file__)
    procs = [subprocess.Popen(
        [sys.executable, helper, "--writer", directory, str(h), str(dl)],
        env=os.environ) for h, dl in ((0, 0.0), (1, 3.0))]

    mgr = CheckpointManager(directory, keep=2, host_id=0, num_hosts=2)
    step_dir = os.path.join(directory, f"step_{STEP:09d}")
    gc_runs = raced = 0
    deadline = time.time() + 60.0
    while True:                          # GC races the in-flight commit
        mgr._gc()
        gc_runs += 1
        newest = latest_step(directory)
        assert newest in (3, STEP), \
            f"half-complete step surfaced as newest: {newest}"
        if newest == 3 and os.path.isdir(step_dir):
            # the race window: host 0's half of step 4 is on disk but
            # the step is incomplete — GC must have left it alone
            try:
                verify_step(directory, STEP)
                raise AssertionError("incomplete step verified")
            except ValueError:
                raced += 1
        if newest == STEP:
            break
        assert time.time() < deadline, "step 4 never completed"
        time.sleep(0.05)
    for p in procs:
        assert p.wait(timeout=60) == 0, f"writer failed: {p.args}"
    assert raced > 0, "race window never observed (host 1 landed too fast)"

    wait_step_complete(directory, STEP, timeout=5.0)
    mgr._gc()                            # ordinary retention now applies
    assert complete_steps(directory) == [3, STEP]
    left = sorted(n for n in os.listdir(directory)
                  if n.startswith("step_"))
    assert left == ["step_000000003", f"step_{STEP:09d}"], left
    print(f"[concurrent-ckpt] {gc_runs} GC sweeps raced the commit "
          f"({raced} inside the incomplete window); step {STEP} survived "
          "and retention converged")
    print("CONCURRENT CKPT: ALL OK")


if __name__ == "__main__":
    if sys.argv[1] == "--writer":
        writer(sys.argv[2], int(sys.argv[3]), float(sys.argv[4]))
    else:
        main(sys.argv[1])
