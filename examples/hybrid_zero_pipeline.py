"""Hybrid ZeRO x pipeline parallelism through the compile path.

Runs an LM pipeline as DP replicas over a ("data", "model") mesh with
ZeRO-2 partitioning over the data axis: parameter stacks live sharded at
rest, each stage slot row is all-gathered on use inside the scan body,
and gradients come back reduce-scattered.  Trains a few AdamW steps with
the optimizer state sharded leaf-wise by the same specs (ZeRO-1 falls
out for free), then shows the tuner unlocking a memory-constrained
granite-34b plan that is infeasible with replicated state.

    PYTHONPATH=src python examples/hybrid_zero_pipeline.py
"""
import dataclasses
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp

from repro.analysis import certify_plan
from repro.models.layers import AttnConfig
from repro.models.lm import LMConfig, lm_pipeline_graph
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.runtime.adapters import lm_model_fns
from repro.runtime.compile import auto_pipeline

# 1. compile the hybrid plan: N=4 devices = P=2 pipeline x dp=2 ZeRO-2 --
cfg = LMConfig(name="demo", vocab=64, d_model=32, n_layers=8,
               attn=AttnConfig(32, 4, 2, 8), d_ff=64,
               tied_embeddings=True)
graph = lm_pipeline_graph(cfg, fwd_times=[4, 1, 1, 1, 1, 1, 1, 4])
cp = auto_pipeline(graph, lm_model_fns(cfg), 4, pipeline_devices=2,
                   dp_size=2, microbatches=4, lam=0.0, zero_stage=2)
print(cp.describe())
print(certify_plan(cp, name="hybrid-demo").summary())

specs, dims = cp._zero_layout()
n_sharded = sum(d >= 0 for d in jax.tree.leaves(dims))
print(f"ZeRO-2 rest layout: {n_sharded} stack leaves sharded over 'data' "
      f"(gather-on-use inside the scan body)\n")

# 2. train: grads reduce-scatter over data; AdamW state mirrors the
#    param specs leaf-wise, so ZeRO-1 optimizer sharding is the same
#    spec tree applied to m/v ---------------------------------------------
mesh = jax.make_mesh((2, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
state = cp.split_params(cp.model_fns.init_fn(key))
opt_state = adamw_init(state)
opt_cfg = AdamWConfig(lr=1e-2)
loss_fn = cp.bind(mesh)
B, S, M = 8, 16, 4
tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
mbs = {"tokens": tokens.reshape(M, B // M, S)}


@jax.jit
def train_step(state, opt_state, mbs):
    loss, grads = jax.value_and_grad(lambda st: loss_fn(st, mbs))(state)
    state, opt_state = adamw_update(state, grads, opt_state, opt_cfg)
    return loss, state, opt_state


for step in range(10):
    loss, state, opt_state = train_step(state, opt_state, mbs)
    if step % 3 == 0 or step == 9:
        print(f"step {step:2d}  loss {float(loss):.4f}")

# 3. the tuner's ZeRO axes: a budget that kills every shallow replicated
#    granite-34b candidate still admits a faster hybrid plan --------------
from repro.configs import granite_34b
from repro.core.hw import V100_CLUSTER
from repro.core.tuner import tune

g34 = lm_pipeline_graph(granite_34b.CFG)
tight = dataclasses.replace(V100_CLUSTER, mem_limit=115e9)
drops: list = []
best = tune(g34, 8, hw=tight, drops=drops)[0]
best0 = tune(g34, 8, hw=tight, zero_stages=(0,))[0]
print(f"\ngranite-34b on 8x {tight.name}, {tight.mem_limit / 1e9:.0f} GB "
      "budget:")
print(f"  replicated best: P={best0.P} dp={best0.G} zero=0  "
      f"t/sample={best0.t_sample * 1e3:.1f} ms  "
      f"peak={best0.peak_mem / 1e9:.1f} GB")
print(f"  hybrid best:     P={best.P} dp={best.dp} zero={best.zero_stage}  "
      f"t/sample={best.t_sample * 1e3:.1f} ms  "
      f"peak={best.peak_mem / 1e9:.1f} GB")
print("  dropped along the way:")
for d in drops[:4]:
    print(f"    {d}")
