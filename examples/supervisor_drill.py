"""Training-supervisor drill matrix: detect -> decide -> recover.

Each scenario runs a real supervised cluster (``launch/supervisor.py``
spawning one ``repro.launch.train`` worker subprocess per simulated
host) and exercises one arm of the escalation matrix:

- ``hostdown`` (``--fast``): host 1 hard-exits mid-run; the supervisor
  sees the exit code, rolls back to the last verified checkpoint and
  relaunches shrunk (dp=2 x P=2 -> dp=1 x P=2 on the survivor).
- ``hang`` (``--fast``): host 0 stalls with its process alive (a stuck
  collective); the progress watchdog flags the ROOT hung host within
  ``stall_timeout * miss_budget`` and recovery proceeds as above.
- ``straggler``: host 1 runs 3x slow from step 4; the detector flags it
  from per-step timing medians — report-only, the run completes with no
  restart.
- ``gradguard-escalate``: a persistent NaN stream exhausts the workers'
  skip budget; they exit ``EXIT_ESCALATE`` (43) and the supervisor rolls
  back to last-good WITHOUT shrinking (the hosts are healthy — the
  *state* was poisoned), relaunching on the same plan.
- ``iofail-rollback``: transient save failures are injected into the
  post-rollback generation; the checkpoint manager's retry/backoff
  absorbs them and recovery still completes.

Every scenario leaves a structured ``events.jsonl`` + per-worker logs
under its run dir and prints the ``--status`` rendering.

    PYTHONPATH=src python examples/supervisor_drill.py          # all
    PYTHONPATH=src python examples/supervisor_drill.py --fast   # CI subset
"""
import argparse
import os
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

STEPS = 12


def _cfg(run_dir, **kw):
    from repro.launch.supervisor import SupervisorConfig
    base = dict(run_dir=run_dir, num_hosts=2, devices_per_host=2,
                steps=STEPS, global_batch=8, arch="uvit-nano", dp=2,
                pp=2, microbatches=4, wire_dtype="float32", lr=1e-3,
                ckpt_every=4, stall_timeout=12.0, miss_budget=2, poll=0.2,
                backoff_base=0.2, log_every=4)
    base.update(kw)
    return SupervisorConfig(**base)


def _run(cfg):
    from repro.launch.supervisor import Supervisor, format_status, \
        read_events
    res = Supervisor(cfg).run()
    print(format_status(cfg.run_dir))
    return res, [e["kind"] for e in read_events(res.events_path)]


def _expect(cond, msg):
    assert cond, msg


def scenario_hostdown(tmp):
    print("=== hostdown: host 1 exits after the step-8 commit")
    res, kinds = _run(_cfg(os.path.join(tmp, "hostdown"),
                           faults="hostdown@8:1"))
    _expect(res.ok and res.restarts == 1, f"{res.outcome}/{res.restarts}")
    _expect(res.final_hosts == 1 and res.final_plan == (1, 2, 0),
            f"{res.final_plan} on {res.final_hosts}")
    for k in ("hostdown", "rollback", "shrink", "restart", "done"):
        _expect(k in kinds, f"missing {k} in {kinds}")
    print("=== detected by exit code; rolled back + shrunk + finished.\n")


def scenario_hang(tmp):
    print("=== hang: host 0 freezes before step 6 (process stays alive)")
    res, kinds = _run(_cfg(os.path.join(tmp, "hang"), faults="hang@6"))
    _expect(res.ok and res.restarts == 1, f"{res.outcome}/{res.restarts}")
    _expect(res.final_hosts == 1, f"{res.final_hosts} hosts")
    _expect("hang" in kinds and "shrink" in kinds, kinds)
    print("=== watchdog flagged the frozen host; recovered shrunk.\n")


def scenario_straggler(tmp):
    print("=== straggler: host 1 runs 3x slow from step 4 (report-only)")
    res, kinds = _run(_cfg(os.path.join(tmp, "straggler"),
                           faults="slow@4:3.0:1", steps=16,
                           straggler_factor=1.8, straggler_patience=3,
                           # the healthy host legitimately sits at the
                           # commit barrier while the straggler catches
                           # up — keep the hang threshold above that lag
                           stall_timeout=15.0))
    _expect(res.ok and res.restarts == 0,
            f"straggler must not trigger recovery: {res.outcome}/"
            f"{res.restarts} restarts")
    _expect("straggler" in kinds, f"no straggler event in {kinds}")
    _expect("shrink" not in kinds, "straggler wrongly shrank the cluster")
    print("=== flagged from timing medians; run completed untouched.\n")


def scenario_gradguard_escalate(tmp):
    print("=== gradguard-escalate: NaN stream blows the skip budget; "
          "workers exit 43; rollback WITHOUT shrink")
    res, kinds = _run(_cfg(os.path.join(tmp, "escalate"),
                           faults="nan@6,nan@7,nan@8,nan@9",
                           nan_skip_budget=2))
    _expect(res.ok and res.restarts == 1, f"{res.outcome}/{res.restarts}")
    _expect(res.final_hosts == 2 and res.final_plan == (2, 2, 0),
            f"escalation must keep the plan: {res.final_plan} on "
            f"{res.final_hosts}")
    _expect("escalate" in kinds and "rollback" in kinds, kinds)
    _expect("shrink" not in kinds, "escalation wrongly shrank the cluster")
    _expect("anomaly" in kinds, f"no anomaly event for NaN loss: {kinds}")
    print("=== poisoned state discarded; same plan relaunched clean.\n")


def scenario_iofail_rollback(tmp):
    print("=== iofail-rollback: transient save failures injected into "
          "the post-rollback generation")
    d = os.path.join(tmp, "iofail")
    res, kinds = _run(_cfg(d, faults="hostdown@8:1",
                           relaunch_faults="iofail@0:2"))
    _expect(res.ok and res.restarts == 1, f"{res.outcome}/{res.restarts}")
    _expect("hostdown" in kinds and "done" in kinds, kinds)
    log = os.path.join(d, "logs", "worker_h0.g1.log")
    with open(log) as f:
        text = f.read()
    _expect("retry" in text,
            f"no retry/backoff in the relaunched worker: {text[-1500:]}")
    print("=== rollback survived flaky storage via retry/backoff.\n")


SCENARIOS = {
    "hostdown": scenario_hostdown,
    "hang": scenario_hang,
    "straggler": scenario_straggler,
    "gradguard-escalate": scenario_gradguard_escalate,
    "iofail-rollback": scenario_iofail_rollback,
}

FAST = ("hostdown", "hang")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI subset: hostdown + hang")
    ap.add_argument("--keep-run-dirs", action="store_true",
                    help="keep run dirs (events.jsonl, worker logs) for "
                         "artifact upload")
    ap.add_argument("scenarios", nargs="*", metavar="scenario",
                    help=f"subset to run (default: all): {list(SCENARIOS)}")
    args = ap.parse_args()
    unknown = [s for s in args.scenarios if s not in SCENARIOS]
    if unknown:
        ap.error(f"unknown scenario(s) {unknown}; choose from "
                 f"{list(SCENARIOS)}")
    names = args.scenarios or (FAST if args.fast else list(SCENARIOS))
    os.environ.setdefault("JAX_COMPILATION_CACHE_DIR",
                          tempfile.mkdtemp(prefix="repro_supx_cache_"))
    os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "1")
    tmp = (os.environ.get("SUPERVISOR_DRILL_DIR")
           or tempfile.mkdtemp(prefix="repro_supx_"))
    os.makedirs(tmp, exist_ok=True)
    try:
        for name in names:
            SCENARIOS[name](tmp)
        print(f"SUPERVISOR DRILL: {len(names)} scenario(s) OK")
    finally:
        if not args.keep_run_dirs and "SUPERVISOR_DRILL_DIR" not in \
                os.environ:
            shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
