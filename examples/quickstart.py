"""Quickstart: the full PULSE planning stack in 30 seconds (CPU).

Builds the paper's UViT model graph, runs the skip-aware partitioner, the
schedule synthesizer, the analytic communication model, and the hybrid
tuner — printing each artefact.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.comm_model import (naive_pp_volume, partition_comm_volume,
                                   pulse_volume)
from repro.core.hw import ASCEND_910A_CLUSTER, TPU_V5E
from repro.core.partition import blockwise_partition, partition
from repro.core.schedule import template_1f1b, template_wave
from repro.core.tuner import tune
from repro.models.diffusion import UViTConfig, uvit_block_graph

# 1. model -> block graph with skip edges -------------------------------
cfg = UViTConfig("uvit", img_size=32, d_model=1024, n_layers=16,
                 n_heads=16, d_ff=4096)
g = uvit_block_graph(cfg, batch=32)
print(f"UViT graph: {g.n} blocks, {len(g.skips)} skip edges "
      f"(nested={g.is_nested()})")

# 2. skip-aware partitioning (Alg. 1) -----------------------------------
D = 4
part = partition(g, D)
print(f"\nPULSE partition over {D} devices (S={part.num_stages} folded):")
for s in range(part.num_stages):
    lo, hi = part.stage_range(s)
    names = ",".join(b.name for b in g.blocks[lo:hi])
    print(f"  stage {s} -> device {part.device_of_stage(s)}: [{names}]")
assert part.validate_collocation(g)

# 3. communication volumes (paper §II-C vs §V-B) ------------------------
a = g.blocks[1].act_bytes
v_pulse = partition_comm_volume(g, part)
v_base = partition_comm_volume(g, blockwise_partition(g, D))
print(f"\ncomm/microbatch: PULSE {v_pulse.fwd_total/1e6:.1f} MB "
      f"(skip bytes: {v_pulse.skip_bytes/1e6:.1f}) vs sequential "
      f"{v_base.fwd_total/1e6:.1f} MB "
      f"-> {100*(1-v_pulse.fwd_total/v_base.fwd_total):.0f}% reduction")
print(f"closed forms: naive {naive_pp_volume(g.n-2, D, a)/1e6:.1f} MB, "
      f"pulse {pulse_volume(D, a)/1e6:.1f} MB")

# 4. schedules (paper Figs. 8/9) ----------------------------------------
print("\n1F1B schedule (S=D):")
print(template_1f1b(D, 4).to_ascii())
print("\nPULSE wave schedule (S=2D, folded):")
print(template_wave(D, 4).to_ascii())

# 5. hybrid tuner (paper §VI) -------------------------------------------
print("\nhybrid tuner on the Ascend cluster (16 devices):")
for c in tune(g, 16, hw=ASCEND_910A_CLUSTER)[:3]:
    print(f"  P={c.P:2d} G={c.G:2d} b={c.b:3d}  "
          f"t/sample={c.t_sample*1e3:.2f} ms  "
          f"peak={c.peak_mem/2**30:.1f} GiB  wave={c.wave}")

# 6. the auto-pipeline compile path (graph -> partition -> schedule ->
#    executor; runtime/compile.py) -----------------------------------------
from repro.runtime.adapters import diffusion_model_fns
from repro.runtime.compile import auto_pipeline

small = UViTConfig("uvit-s", img_size=8, in_ch=4, patch=2, d_model=64,
                   n_layers=8, n_heads=4, d_ff=128, n_classes=10)
from repro.models.diffusion import uvit_pipeline_graph
rg = uvit_pipeline_graph(small)
compiled = auto_pipeline(rg, diffusion_model_fns(small, "uvit"), 4,
                         microbatches=8)
print("\ncompile path (planning on one device; executor runs under a")
print("multi-device mesh — see launch/train.py --pipeline):")
print(compiled.describe())
print(compiled.schedule.to_ascii())

# 7. the lowered step programs: the same grid as dense arrays, and the
#    executor-facing step tables the scan body actually reads ------------
from repro.runtime.schedule_exec import StepTables

progs = compiled.schedule.device_programs()
print(f"\ndevice_programs: virtual[D, T] over {progs.num_devices} devices x "
      f"{progs.num_steps} steps (-1 = idle):")
print(progs.virtual)
tabs = StepTables.from_schedule(compiled.schedule, folded=compiled.folded)
print(f"step tables (forward slots only, {tabs.num_steps} steps; "
      "0=idle 1=enc 2=dec):")
print(tabs.sel)
