"""End-to-end driver: train a UViT diffusion model for a few hundred steps
on synthetic latents, with checkpointing, then resume once to prove exact
restart.  CPU-sized model; the identical loop drives the pod-scale configs.

    PYTHONPATH=src python examples/train_diffusion_e2e.py
"""
import shutil
import tempfile

from repro.launch.train import main as train_main

ckpt = tempfile.mkdtemp(prefix="repro_uvit_")
try:
    print("=== phase 1: train 120 steps (checkpoint every 40)")
    train_main(["--arch", "uvit-h", "--steps", "120", "--ckpt-dir", ckpt,
                "--ckpt-every", "40", "--global-batch", "16",
                "--lr", "2e-3"])
    print("=== phase 2: resume to 200 steps")
    loss = train_main(["--arch", "uvit-h", "--steps", "200", "--ckpt-dir",
                       ckpt, "--ckpt-every", "40", "--resume",
                       "--global-batch", "16", "--lr", "2e-3"])
    print(f"final loss {loss:.4f}")
finally:
    shutil.rmtree(ckpt, ignore_errors=True)
