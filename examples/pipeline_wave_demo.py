"""PULSE wave pipeline running on 8 simulated devices: trains a UViT with
the folded-stage executor and shows the live loss + schedule/comm facts.

    PYTHONPATH=src python examples/pipeline_wave_demo.py
"""
from repro.launch.train import main as train_main

print("wave pipeline over 8 simulated host devices (4 stages x DP 2):")
train_main(["--arch", "uvit", "--pipeline", "--devices", "8",
            "--steps", "30", "--global-batch", "16",
            "--microbatches", "4", "--lr", "2e-3", "--log-every", "5"])
