"""Batched LM serving demo (prefill + greedy decode) across families.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main

for arch in ("smollm-360m", "xlstm-125m", "zamba2-2.7b"):
    serve_main(["--arch", arch, "--batch", "4", "--prompt-len", "8",
                "--gen", "16"])
