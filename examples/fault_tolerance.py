"""Fault-tolerance drill: hard-kill training mid-run, then resume.

The data pipeline is stateless in (step, host), so the resumed run
reproduces the exact same batch stream — the loss trajectory continues
as if the failure never happened.

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import shutil
import subprocess
import sys
import tempfile
import os

ckpt = tempfile.mkdtemp(prefix="repro_ft_")
env = dict(os.environ, PYTHONPATH="src")
try:
    print("=== run 1: will be killed at step 60 (checkpoints every 25)")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "uvit-h",
         "--steps", "100", "--ckpt-dir", ckpt, "--ckpt-every", "25",
         "--simulate-failure", "60", "--global-batch", "8"],
        env=env)
    assert r.returncode == 42, f"expected simulated crash, got {r.returncode}"
    print("=== node died (rc=42). relaunching with --resume")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "uvit-h",
         "--steps", "100", "--ckpt-dir", ckpt, "--ckpt-every", "25",
         "--resume", "--global-batch", "8"],
        env=env)
    assert r.returncode == 0
    print("=== recovered and completed 100 steps.")
finally:
    shutil.rmtree(ckpt, ignore_errors=True)
