"""Multi-scenario fault-tolerance drill over the production driver.

Each scenario launches ``repro.launch.train`` subprocesses and injects
faults through the ``--faults`` plan (runtime.resilience.FaultPlan):

- ``kill-resume`` (also ``--fast``): classic hard-kill (os._exit) at
  step K, relaunch with ``--resume`` — the stateless data pipeline
  regenerates the exact step stream.
- ``shrink-restore``: a P=2 x dp=2 ZeRO-2 pipeline run is hard-killed
  mid-epoch and resumed onto a *different* plan (P=1 x dp=2, zero=0);
  the resumed loss trajectory must match an uninterrupted reference
  run at rtol 1e-4 (fp32 wire).
- ``corrupt-shard``: a checkpoint shard is byte-flipped (via the fault
  plan) before the kill; the resume detects the bad SHA-256, falls back
  to the previous complete step, and still completes.
- ``io-backoff``: transient save failures are retried with exponential
  backoff; an exhausted retry budget degrades to keep-training-and-warn
  (the step loop never crashes on storage trouble).
- ``nan-guard``: a poisoned batch produces non-finite grads; the guard
  skips the update and training recovers — unless the consecutive-skip
  budget is exceeded, which aborts.

    PYTHONPATH=src python examples/fault_tolerance.py          # all
    PYTHONPATH=src python examples/fault_tolerance.py --fast   # CI subset
"""
import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile

ENV = dict(os.environ, PYTHONPATH="src")

PIPE = ["--pipeline", "--arch", "uvit", "--devices", "8", "--dp", "2",
        "--pp", "2", "--zero-stage", "2", "--microbatches", "2",
        "--global-batch", "4", "--steps", "12", "--ckpt-every", "4",
        "--log-every", "4", "--wire-dtype", "float32", "--lr", "1e-3"]


def train(args, expect_rc=0):
    r = subprocess.run([sys.executable, "-m", "repro.launch.train", *args],
                       env=ENV, capture_output=True, text=True)
    assert r.returncode == expect_rc, (
        f"expected rc={expect_rc}, got {r.returncode}\n"
        f"STDOUT:\n{r.stdout[-3000:]}\nSTDERR:\n{r.stderr[-2000:]}")
    return r.stdout + r.stderr


def losses_of(path):
    with open(path) as f:
        doc = json.load(f)
    return {int(k): v for k, v in doc["losses"].items()}, doc


def check_traj(ref, got, what):
    assert got, f"{what}: no steps ran"
    for s, b in got.items():
        a = ref[s]
        assert abs(a - b) <= 1e-4 * abs(a) + 1e-6, \
            f"{what}: step {s} loss {b} != reference {a}"


def scenario_kill_resume(tmp):
    print("=== kill-resume: killed at step 60 (checkpoints every 25)")
    d = os.path.join(tmp, "kill")
    base = ["--arch", "uvit-h", "--steps", "100", "--ckpt-dir", d,
            "--ckpt-every", "25", "--global-batch", "8"]
    train(base + ["--faults", "kill@60"], expect_rc=42)
    print("=== node died (rc=42). relaunching with --resume")
    out = train(base + ["--resume"])
    assert "resumed from step 50" in out, out[-1500:]
    print("=== recovered and completed 100 steps.")


def scenario_shrink_restore(tmp):
    print("=== shrink-restore: P=2 dp=2 ZeRO-2 killed at step 10, "
          "resumed as P=1 dp=2 zero=0")
    ref_json = os.path.join(tmp, "ref.json")
    train(PIPE + ["--out-json", ref_json])
    ref, _ = losses_of(ref_json)
    d = os.path.join(tmp, "shrink")
    train(PIPE + ["--ckpt-dir", d, "--faults", "kill@10"], expect_rc=42)
    out_json = os.path.join(tmp, "shrink.json")
    out = train(PIPE + ["--pp", "1", "--zero-stage", "0", "--ckpt-dir", d,
                        "--resume", "--out-json", out_json])
    got, doc = losses_of(out_json)
    assert doc["resumed_step"] == 8 and doc["elastic"], doc
    assert "elastic restore: plan changed" in out
    check_traj(ref, got, "shrink-restore")
    print("=== elastic shrink reproduced the reference trajectory.")


def scenario_corrupt_shard(tmp):
    print("=== corrupt-shard: newest checkpoint byte-flipped before the "
          "kill; resume must fall back to the previous verified step")
    d = os.path.join(tmp, "corrupt")
    base = PIPE + ["--ckpt-dir", d, "--ckpt-every", "2"]
    train(base + ["--faults", "corrupt@5:shard_00000,kill@5"],
          expect_rc=42)
    out_json = os.path.join(tmp, "corrupt.json")
    out = train(base + ["--resume", "--out-json", out_json])
    _, doc = losses_of(out_json)
    assert doc["resumed_step"] == 2, doc       # step 4 was corrupted
    assert "failed verification" in out and "fell back to step 2" in out
    print("=== checksum caught the corruption; fell back and completed.")


def scenario_io_backoff(tmp):
    print("=== io-backoff: transient save failures retry; exhausted "
          "retries degrade to keep-training-and-warn")
    sys.path.insert(0, "src")
    from repro.checkpoint import complete_steps

    d = os.path.join(tmp, "io1")
    out = train(PIPE + ["--ckpt-dir", d, "--faults", "iofail@4:2"])
    assert "retry" in out, out[-1500:]
    assert complete_steps(d)[-1] == 12
    d = os.path.join(tmp, "io2")
    out = train(PIPE + ["--ckpt-dir", d, "--faults", "iofail@8:4"])
    assert "training continues WITHOUT this checkpoint" in out
    assert complete_steps(d) == [4, 12], complete_steps(d)
    print("=== storage trouble never crashed the step loop.")


def scenario_nan_guard(tmp):
    print("=== nan-guard: poisoned batch skipped within budget; "
          "persistent NaNs abort")
    out_json = os.path.join(tmp, "nan.json")
    out = train(PIPE + ["--faults", "nan@6", "--out-json", out_json])
    assert "update skipped" in out
    _, doc = losses_of(out_json)
    assert doc["skipped_steps"] == 1 and doc["final_loss"] is not None
    out = train(PIPE + ["--faults", "nan@2,nan@3,nan@4",
                        "--nan-skip-budget", "2"], expect_rc=1)
    assert "exceed the skip budget" in out
    print("=== guard skipped one bad step and aborted a divergence.")


SCENARIOS = {
    "kill-resume": scenario_kill_resume,
    "shrink-restore": scenario_shrink_restore,
    "corrupt-shard": scenario_corrupt_shard,
    "io-backoff": scenario_io_backoff,
    "nan-guard": scenario_nan_guard,
}

FAST = ("kill-resume",)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI subset: kill/resume only")
    ap.add_argument("scenarios", nargs="*", metavar="scenario",
                    help=f"subset to run (default: all): {list(SCENARIOS)}")
    args = ap.parse_args()
    unknown = [s for s in args.scenarios if s not in SCENARIOS]
    if unknown:
        ap.error(f"unknown scenario(s) {unknown}; choose from "
                 f"{list(SCENARIOS)}")
    names = args.scenarios or (FAST if args.fast else list(SCENARIOS))
    tmp = tempfile.mkdtemp(prefix="repro_ft_")
    try:
        for name in names:
            SCENARIOS[name](tmp)
        print(f"FAULT TOLERANCE DRILL: {len(names)} scenario(s) OK")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
