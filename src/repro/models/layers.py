"""Shared functional layers for every model family.

Everything is pure-functional: ``init_*`` returns a params pytree (dict of
jnp arrays), ``apply``-style functions take ``(params, inputs, ...)``.
dtype policy: params in ``param_dtype`` (default float32 for CPU numerics,
bfloat16 in production configs), activations in ``dtype``.

KV caches are plain dicts ``{"k": (B,S,H,Dh), "v": ..., "pos": int32}``;
``decode_*`` functions append one token at ``pos`` via dynamic updates.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict
Array = jax.Array


# --------------------------------------------------------------------------
# Initializers / norms
# --------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32) -> Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (GQA / MQA / MHA, causal + sliding window), dense reference.
# The Pallas flash kernel (kernels/flash_attention.py) is a drop-in
# replacement selected by config `use_flash`.
# --------------------------------------------------------------------------

def _attn_mask(q_len: int, kv_len: int, *, causal: bool, window: int | None,
               q_offset: Array | int = 0) -> Array:
    """(q_len, kv_len) boolean mask. q_offset = absolute pos of query row 0."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    return mask


def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              window: int | None = None, q_offset: Array | int = 0,
              kv_valid_len: Array | None = None) -> Array:
    """Grouped-query attention. q: (B,S,Hq,Dh), k/v: (B,T,Hkv,Dh)."""
    B, S, Hq, Dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    groups = Hq // Hkv
    qg = q.reshape(B, S, Hkv, groups, Dh)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    logits *= 1.0 / math.sqrt(Dh)
    mask = _attn_mask(S, T, causal=causal, window=window, q_offset=q_offset)
    if kv_valid_len is not None:
        mask = mask & (jnp.arange(T)[None, :] < kv_valid_len)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, v.shape[-1]).astype(q.dtype)


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    window: int | None = None       # sliding-window size (None = full)
    causal: bool = True
    qk_norm: bool = False           # Qwen3-style per-head q/k RMSNorm


def init_attention(key, cfg: AttnConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.head_dim, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * cfg.head_dim, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.head_dim, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dtype)
    return p


def apply_attention(p: Params, x: Array, cfg: AttnConfig, *,
                    positions: Array | None = None,
                    cache: Params | None = None,
                    cross_kv: tuple[Array, Array] | None = None,
                    ) -> tuple[Array, Params | None]:
    """Self- or cross-attention.  With ``cache`` (decode), x is (B,1,D) and
    the cache is updated in place (functionally).  ``cross_kv`` supplies
    precomputed encoder K/V (whisper-style cross attention; no cache update).
    """
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, Dh)
    if cross_kv is None:
        k = (x @ p["wk"]).reshape(B, S, Hkv, Dh)
        v = (x @ p["wv"]).reshape(B, S, Hkv, Dh)
    else:
        k, v = cross_kv
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        if cross_kv is None:
            k = rms_norm(k, p["k_norm"])
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if cfg.rope_theta > 0 and cross_kv is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and cross_kv is None:
        pos = cache["pos"]
        k_all = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
        new_cache = {"k": k_all, "v": v_all, "pos": pos + S}
        out = attention(q, k_all, v_all, causal=cfg.causal, window=cfg.window,
                        q_offset=pos, kv_valid_len=pos + S)
    else:
        out = attention(q, k, v, causal=cfg.causal and cross_kv is None,
                        window=cfg.window)
    out = out.reshape(B, S, H * Dh) @ p["wo"]
    return out, new_cache


def init_kv_cache(batch: int, max_len: int, cfg: AttnConfig, dtype=jnp.float32) -> Params:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


# --------------------------------------------------------------------------
# MLA — DeepSeek-V3 multi-head latent attention
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0


def init_mla(key, cfg: MLAConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    H = cfg.n_heads
    return {
        "wq_a": dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype),
        "q_norm": jnp.ones((cfg.q_lora_rank,), dtype),
        "wq_b": dense_init(ks[1], cfg.q_lora_rank,
                           H * (cfg.qk_nope_dim + cfg.qk_rope_dim), dtype),
        "wkv_a": dense_init(ks[2], cfg.d_model,
                            cfg.kv_lora_rank + cfg.qk_rope_dim, dtype),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), dtype),
        "wkv_b": dense_init(ks[3], cfg.kv_lora_rank,
                            H * (cfg.qk_nope_dim + cfg.v_head_dim), dtype),
        "wo": dense_init(ks[4], H * cfg.v_head_dim, cfg.d_model, dtype),
    }


def apply_mla(p: Params, x: Array, cfg: MLAConfig, *,
              positions: Array | None = None,
              cache: Params | None = None) -> tuple[Array, Params | None]:
    """MLA with a *compressed* KV cache (kv_lora + k_rope per token)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]

    q = rms_norm(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ p["wkv_a"]                       # (B,S, r + dr)
    kv_latent = rms_norm(kv_a[..., :cfg.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(kv_a[..., None, cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)          # (B,S,1,dr) shared across heads

    q_offset: Array | int = 0
    kv_valid: Array | None = None
    new_cache = None
    if cache is not None:
        pos = cache["pos"]
        kv_latent = jax.lax.dynamic_update_slice_in_dim(
            cache["kv"], kv_latent.astype(cache["kv"].dtype), pos, axis=1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), pos, axis=1)
        new_cache = {"kv": kv_latent, "k_rope": k_rope, "pos": pos + S}
        q_offset, kv_valid = pos, pos + S

    # Decompress latent -> per-head K_nope and V (einsum keeps it fused).
    kv = kv_latent @ p["wkv_b"]                 # (B,T,H*(dn+dv))
    T = kv.shape[1]
    kv = kv.reshape(B, T, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, T, H, dr))], -1)
    qq = jnp.concatenate([q_nope, q_rope], -1)
    out = attention(qq, k, v, causal=True, q_offset=q_offset,
                    kv_valid_len=kv_valid)
    out = out.reshape(B, S, H * dv) @ p["wo"]
    return out, new_cache


def init_mla_cache(batch: int, max_len: int, cfg: MLAConfig, dtype=jnp.float32) -> Params:
    return {
        "kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, 1, cfg.qk_rope_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


# --------------------------------------------------------------------------
# FFN: SwiGLU and MoE
# --------------------------------------------------------------------------

def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
        "w_up": dense_init(ks[1], d_model, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d_model, dtype),
    }


def apply_swiglu(p: Params, x: Array) -> Array:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "w_up": dense_init(ks[0], d_model, d_ff, dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(ks[1], d_ff, d_model, dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def apply_gelu_mlp(p: Params, x: Array) -> Array:
    return jax.nn.gelu(x @ p["w_up"] + p["b_up"]) @ p["w_down"] + p["b_down"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                  # expert intermediate size
    n_experts: int
    top_k: int
    n_shared: int = 0          # shared (always-on) experts
    shared_d_ff: int = 0       # their intermediate size (0 => d_ff)
    capacity_factor: float = 1.25
    router_dtype: Any = jnp.float32


def init_moe(key, cfg: MoEConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f)) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, f)) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d)) / math.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared:
        sf = cfg.shared_d_ff or cfg.d_ff
        p["shared"] = init_swiglu(ks[4], d, cfg.n_shared * sf, dtype)
    return p


def apply_moe(p: Params, x: Array, cfg: MoEConfig, *,
              dispatch: str = "onehot") -> tuple[Array, Array]:
    """Top-k MoE with capacity-based SPMD-safe dispatch.

    Returns (output, aux_loss).  ``dispatch``:
      - "onehot": GShard/MaxText-style one-hot dispatch/combine einsums.
        Cost of the dispatch einsums is O(T*E*C*d) which for fine-grained
        MoE (small d_ff, large top_k: qwen3/deepseek-v3) exceeds the expert
        FFN FLOPs by >10x — kept as the historical baseline.
      - "scatter": sort-based dispatch — argsort assignments by expert,
        scatter rows into the (E, C, d) buffer, grouped FFN, gather back.
        O(T*k*d) data movement, zero matmul overhead; the scalable default
        for the large MoE configs (see EXPERIMENTS.md §Perf).
      - "dense": every token through its selected experts via weight gather
        (exact FLOPs, memory-heavy; small models / decode only).
    """
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt.astype(cfg.router_dtype) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (T,E)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)               # (T,k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)            # renormalise

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, cfg.n_experts), axis=1), axis=0)
    aux = cfg.n_experts * jnp.sum(me * ce) / cfg.top_k

    if dispatch == "dense":
        wg = p["w_gate"][top_i]                                  # (T,k,d,f)
        wu = p["w_up"][top_i]
        wd = p["w_down"][top_i]
        h = jax.nn.silu(jnp.einsum("td,tkdf->tkf", xt, wg))
        h = h * jnp.einsum("td,tkdf->tkf", xt, wu)
        y = jnp.einsum("tkf,tkfd,tk->td", h, wd, top_p)
    elif dispatch == "scatter":
        # Grouped sort-based dispatch, vmapped over batch rows so the sort
        # and scatters stay local to each data shard under GSPMD (a global
        # argsort would force an all-gather).  Capacity is per row.
        E = cfg.n_experts
        Tr = S                                                   # row tokens
        cap = max(1, int(math.ceil(Tr * cfg.top_k / E
                                   * cfg.capacity_factor)))
        top_i_r = top_i.reshape(B, S, cfg.top_k)
        top_p_r = top_p.reshape(B, S, cfg.top_k)
        x_r = x

        def row(xr, ir, pr):
            eid = ir.reshape(-1)                                 # (S*k,)
            gates = pr.reshape(-1)
            tok = jnp.repeat(jnp.arange(Tr), cfg.top_k)
            order = jnp.argsort(eid)
            eid_s, tok_s, gate_s = eid[order], tok[order], gates[order]
            counts = jnp.bincount(eid, length=E)
            starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                      jnp.cumsum(counts)[:-1]])
            pos = jnp.arange(Tr * cfg.top_k) - starts[eid_s]
            keep = pos < cap
            slot = eid_s * cap + jnp.where(keep, pos, 0)
            buf = jnp.zeros((E * cap, d), xr.dtype)
            buf = buf.at[jnp.where(keep, slot, E * cap)].set(
                xr[tok_s], mode="drop")
            return buf.reshape(E, cap, d), (slot, keep, tok_s, gate_s)

        xe, (slot, keep, tok_s, gate_s) = jax.vmap(row)(
            x_r, top_i_r, top_p_r)                               # (B,E,cap,d)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["w_gate"]))
        h = h * jnp.einsum("becd,edf->becf", xe, p["w_up"])
        ye = jnp.einsum("becf,efd->becd", h, p["w_down"])
        ye = ye.reshape(B, E * cap, d)

        def combine(yer, slot_r, keep_r, tok_r, gate_r):
            rows = jnp.where(keep_r[:, None], yer[slot_r], 0.0) \
                * gate_r[:, None].astype(yer.dtype)
            return jnp.zeros((Tr, d), yer.dtype).at[tok_r].add(rows)

        y = jax.vmap(combine)(ye, slot, keep, tok_s, gate_s)     # (B,S,d)
        y = y.reshape(T, d)
    else:
        E = cfg.n_experts
        cap = max(1, int(math.ceil(T * cfg.top_k / E * cfg.capacity_factor)))
        # position of each (token, slot) within its expert
        onehot = jax.nn.one_hot(top_i, E, dtype=jnp.int32)       # (T,k,E)
        flat = onehot.reshape(T * cfg.top_k, E)
        pos_in_e = jnp.cumsum(flat, axis=0) * flat - 1            # (T*k,E)
        pos = jnp.max(pos_in_e, axis=-1).reshape(T, cfg.top_k)    # (T,k)
        keep = (pos < cap) & (pos >= 0)
        gate = jnp.where(keep, top_p, 0.0)
        # dispatch tensor (T, E, cap) one-hot
        d_onehot = (
            jax.nn.one_hot(top_i, E, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(jnp.clip(pos, 0, cap - 1), cap, dtype=x.dtype)[..., None, :]
            * keep[..., None, None].astype(x.dtype)
        ).sum(axis=1)                                            # (T,E,cap)
        xe = jnp.einsum("tec,td->ecd", d_onehot, xt)             # (E,cap,d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
        ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])          # (E,cap,d)
        combine = (
            jax.nn.one_hot(top_i, E, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(jnp.clip(pos, 0, cap - 1), cap, dtype=x.dtype)[..., None, :]
            * (gate[..., None, None].astype(x.dtype))
        ).sum(axis=1)                                            # (T,E,cap)
        y = jnp.einsum("tec,ecd->td", combine, ye)

    if "shared" in p:
        y = y + apply_swiglu(p["shared"], xt)
    return y.reshape(B, S, d), aux
