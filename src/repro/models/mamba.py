"""Mamba2 (SSD) blocks and the Zamba2 hybrid architecture.

Mamba2 state-space recurrence per head (state size N, head dim P):

    h_t = exp(a * dt_t) * h_{t-1} + dt_t * B_t (outer) x_t      (N x P)
    y_t = C_t . h_t + D * x_t

Training uses a *chunked* formulation: scan over chunks of length Q with an
intra-chunk quadratic form — the same structure the Pallas ``linear_scan``
kernel accelerates.  Decoding uses the O(1) recurrent step.

Zamba2 = a stack of Mamba2 blocks with a *shared* full-attention transformer
block applied every ``shared_every`` layers (shared parameters, distinct KV
caches per application) — the genuinely PULSE-relevant structure: the shared
block's parameter reuse sites are long-range graph edges, and the folded
placement collocates them (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import AttnConfig, Params, Array
from repro.models.xlstm import causal_conv, _init_conv


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64           # N
    head_dim: int = 64          # P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_mamba2_block(key, cfg: Mamba2Config, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    # fused in-projection: [z (di), x (di), B (N), C (N), dt (H)]
    d_in_proj = 2 * di + 2 * N + H
    dt = jnp.exp(jax.random.uniform(ks[2], (H,)) *
                 (math.log(cfg.dt_max) - math.log(cfg.dt_min))
                 + math.log(cfg.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))     # inverse softplus
    return {
        "ln": jnp.ones((d,), dtype),
        "w_in": L.dense_init(ks[0], d, d_in_proj, dtype),
        "conv": _init_conv(ks[1], cfg.conv_width, di + 2 * N, dtype),
        "dt_bias": dt_bias.astype(dtype),
        "a_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "gn": jnp.ones((di,), dtype),
        "w_out": L.dense_init(ks[3], di, d, dtype),
    }


def _ssd_chunked(x: Array, dt: Array, a: Array, B: Array, C: Array,
                 chunk: int, h0: Array | None = None
                 ) -> tuple[Array, Array]:
    """Chunked SSD scan.

    x: (b,S,H,P), dt: (b,S,H), a: (H,) (negative), B,C: (b,S,N).
    Returns (y (b,S,H,P), final_state (b,H,N,P)).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, "sequence must be divisible by chunk"
    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = B.reshape(b, nc, chunk, N)
    Cc = C.reshape(b, nc, chunk, N)
    da = dtc * a                                # (b,nc,Q,H) log-decay per step
    cums = jnp.cumsum(da, axis=2)               # within-chunk cumulative decay

    def chunk_step(h, inp):
        xq, dtq, Bq, Cq, daq, cumq = inp        # (b,Q,...)
        # intra-chunk quadratic: y_intra[t] = sum_{s<=t} C_t.B_s dt_s
        #                         exp(cum[t]-cum[s]) x_s
        decay = jnp.exp(cumq[:, :, None, :] - cumq[:, None, :, :])  # (b,t,s,H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(mask[None, :, :, None], decay, 0.0)
        cb = jnp.einsum("btn,bsn->bts", Cq, Bq)                     # (b,t,s)
        w = cb[..., None] * decay * dtq[:, None, :, :]              # (b,t,s,H)
        y = jnp.einsum("btsh,bshp->bthp", w, xq)
        # contribution of carry-in state: y += C_t exp(cum[t]) h
        y = y + jnp.einsum("btn,bth,bhnp->bthp", Cq, jnp.exp(cumq), h)
        # state update: h' = exp(cum[-1]) h + sum_s exp(cum[-1]-cum[s]) dt_s B_s x_s
        dec_last = jnp.exp(cumq[:, -1:, :] - cumq)                  # (b,Q,H)
        h_new = (jnp.exp(cumq[:, -1, :])[:, :, None, None] * h
                 + jnp.einsum("bsh,bsn,bshp->bhnp",
                              dec_last * dtq, Bq, xq))
        return h_new, y

    if h0 is None:
        h0 = jnp.zeros((b, H, N, P), jnp.float32)
    inputs = (
        jnp.swapaxes(xc, 0, 1).astype(jnp.float32),
        jnp.swapaxes(dtc, 0, 1).astype(jnp.float32),
        jnp.swapaxes(Bc, 0, 1).astype(jnp.float32),
        jnp.swapaxes(Cc, 0, 1).astype(jnp.float32),
        jnp.swapaxes(da, 0, 1).astype(jnp.float32),
        jnp.swapaxes(cums, 0, 1).astype(jnp.float32),
    )
    h_final, ys = jax.lax.scan(chunk_step, h0, inputs)
    y = jnp.swapaxes(ys, 0, 1).reshape(b, S, H, P)
    return y.astype(x.dtype), h_final


def ssd_recurrent(state: Array, x: Array, dt: Array, a: Array, B: Array,
                  C: Array) -> tuple[Array, Array]:
    """One decode step.  state: (b,H,N,P); x: (b,H,P); dt: (b,H); B,C: (b,N)."""
    da = jnp.exp(dt * a)                                        # (b,H)
    state = (state * da[..., None, None]
             + jnp.einsum("bh,bn,bhp->bhnp", dt, B, x))
    y = jnp.einsum("bn,bhnp->bhp", C, state)
    return y, state


def apply_mamba2_block(p: Params, x: Array, cfg: Mamba2Config, *,
                       state: Params | None = None
                       ) -> tuple[Array, Params | None]:
    b, S, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    h = L.rms_norm(x, p["ln"])
    zxbcdt = h @ p["w_in"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * N]
    dt_pre = zxbcdt[..., -H:]
    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = causal_conv(xbc, p["conv"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(b, S, H, P)
    B = xbc[..., di:di + N]
    C = xbc[..., di + N:]
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    if state is None:
        y, _ = _ssd_chunked(xs, dt, a, B, C, min(cfg.chunk, S))
        new_state = None
    else:
        y, ssm = ssd_recurrent(state["ssm"], xs[:, 0], dt[:, 0], a,
                               B[:, 0], C[:, 0])
        y = y[:, None]
        new_state = {"ssm": ssm, "conv": new_conv}
    y = y + xs * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, S, di)
    y = L.rms_norm(y, p["gn"]) * jax.nn.silu(z)
    return x + y @ p["w_out"], new_state


def init_mamba2_state(batch: int, cfg: Mamba2Config, dtype=jnp.float32) -> Params:
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim),
                         jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1,
                           cfg.d_inner + 2 * cfg.d_state), dtype),
    }


# --------------------------------------------------------------------------
# Zamba2 hybrid
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Zamba2Config:
    name: str
    vocab: int
    d_model: int
    n_layers: int                 # number of Mamba2 blocks
    mamba: Mamba2Config = None    # type: ignore
    shared_attn: AttnConfig = None  # type: ignore
    shared_d_ff: int = 10240
    shared_every: int = 6         # apply shared block after every k mamba blocks
    n_shared_blocks: int = 2      # alternate between this many shared blocks
    norm_eps: float = 1e-6
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    tied_embeddings: bool = True

    def shared_sites(self) -> list[int]:
        """Mamba-layer indices after which a shared block runs."""
        return [i for i in range(self.n_layers)
                if i % self.shared_every == self.shared_every - 1]

    def param_count(self) -> int:
        d, di = self.d_model, self.mamba.d_inner
        N, H = self.mamba.d_state, self.mamba.n_heads
        per_mamba = d * (2 * di + 2 * N + H) + di * d + 2 * d + di
        a = self.shared_attn
        per_shared = (d * a.head_dim * (a.n_heads * 2 + a.n_kv_heads * 2)
                      + 3 * d * self.shared_d_ff)
        return (self.vocab * d + self.n_layers * per_mamba
                + self.n_shared_blocks * per_shared)


def init_zamba2(key, cfg: Zamba2Config) -> Params:
    ks = jax.random.split(key, cfg.n_layers + cfg.n_shared_blocks + 2)
    pd = cfg.param_dtype
    blocks = [init_mamba2_block(ks[i], cfg.mamba, pd)
              for i in range(cfg.n_layers)]
    shared = []
    for j in range(cfg.n_shared_blocks):
        k1, k2 = jax.random.split(ks[cfg.n_layers + j])
        shared.append({
            "ln1": jnp.ones((cfg.d_model,), pd),
            "attn": L.init_attention(k1, cfg.shared_attn, pd),
            "ln2": jnp.ones((cfg.d_model,), pd),
            "ffn": L.init_swiglu(k2, cfg.d_model, cfg.shared_d_ff, pd),
        })
    return {
        "embed": L.dense_init(ks[-1], cfg.vocab, cfg.d_model, pd),
        "mamba_blocks": blocks,
        "shared_blocks": shared,
        "final_norm": jnp.ones((cfg.d_model,), pd),
    }


def _apply_shared(p: Params, x: Array, cfg: Zamba2Config, *,
                  cache: Params | None = None,
                  positions: Array | None = None) -> tuple[Array, Params | None]:
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    a, new_cache = L.apply_attention(p["attn"], h, cfg.shared_attn,
                                     cache=cache, positions=positions)
    x = x + a
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    return x + L.apply_swiglu(p["ffn"], h), new_cache


def forward(params: Params, tokens: Array, cfg: Zamba2Config, *,
            states: dict | None = None) -> tuple[Array, dict | None]:
    x = params["embed"][tokens].astype(cfg.dtype)
    sites = cfg.shared_sites()
    new_states: dict | None = None
    positions = None
    if states is not None:
        new_states = {"mamba": [], "shared": []}
        pos = states["shared"][0]["pos"] if states["shared"] else jnp.zeros((), jnp.int32)
        positions = pos[None, None]
    site_counter = 0
    for i, bp in enumerate(params["mamba_blocks"]):
        st = states["mamba"][i] if states is not None else None
        x, ns = apply_mamba2_block(bp, x, cfg.mamba, state=st)
        if new_states is not None:
            new_states["mamba"].append(ns)
        if i in sites:
            j = site_counter % cfg.n_shared_blocks
            sp = params["shared_blocks"][j]
            cache = states["shared"][site_counter] if states is not None else None
            x, nc = _apply_shared(sp, x, cfg, cache=cache, positions=positions)
            if new_states is not None:
                new_states["shared"].append(nc)
            site_counter += 1
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_states


def zamba2_loss(params: Params, batch: dict, cfg: Zamba2Config) -> Array:
    h, _ = forward(params, batch["tokens"], cfg)
    logits = h @ params["embed"].T.astype(h.dtype)
    from repro.models.lm import softmax_xent
    return softmax_xent(logits[:, :-1], batch["tokens"][:, 1:])


def init_states(cfg: Zamba2Config, batch: int, max_len: int) -> dict:
    n_sites = len(cfg.shared_sites())
    return {
        "mamba": [init_mamba2_state(batch, cfg.mamba, cfg.dtype)
                  for _ in range(cfg.n_layers)],
        "shared": [L.init_kv_cache(batch, max_len, cfg.shared_attn, cfg.dtype)
                   for _ in range(n_sites)],
    }


def decode_step(params: Params, token: Array, states: dict, cfg: Zamba2Config
                ) -> tuple[Array, dict]:
    h, states = forward(params, token, cfg, states=states)
    return h @ params["embed"].T.astype(h.dtype), states
