"""Diffusion backbones from the paper: UViT, Hunyuan-DiT, SDv2-style UNet.

All three share the latent-diffusion training objective (DDPM noise
prediction; VAE/text encoders are preprocessing per paper §VII and enter as
precomputed latents / embeddings).

Structure is deliberately pipeline-aligned:
- UViT / Hunyuan-DiT: ``enc_blocks`` (stacked [L/2,...]) and ``dec_blocks``
  (stacked, with an extra ``skip_proj``) — exactly the two parameter groups
  the folded wave executor shards over devices.
- SDv2 UNet: heterogeneous conv/attention blocks at four resolutions;
  exported to a BlockGraph whose per-block costs reproduce the paper's
  Fig. 6 heavy-tail imbalance.

``to_block_graph`` exports each model for the PULSE planner.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.graph import Block, BlockGraph, SkipEdge
from repro.core.hw import Hardware, TPU_V5E
from repro.kernels.skip_matmul import (skip_concat_matmul,
                                       skip_concat_matmul_supported)
from repro.models import layers as L
from repro.models.layers import AttnConfig, Params, Array


# --------------------------------------------------------------------------
# DDPM objective
# --------------------------------------------------------------------------

def cosine_alpha_bar(t: Array, s: float = 0.008) -> Array:
    """t in [0,1] -> cumulative alpha (Nichol & Dhariwal cosine schedule)."""
    f = jnp.cos((t + s) / (1 + s) * jnp.pi / 2) ** 2
    f0 = math.cos(s / (1 + s) * math.pi / 2) ** 2
    return jnp.clip(f / f0, 1e-5, 1.0)


def ddpm_loss(apply_fn, params: Params, batch: dict, rng: Array) -> Array:
    """batch: {"latents": (B,H,W,C), ...conditioning...}."""
    x0 = batch["latents"]
    B = x0.shape[0]
    rt, rn = jax.random.split(rng)
    t = jax.random.uniform(rt, (B,))
    ab = cosine_alpha_bar(t)[:, None, None, None]
    noise = jax.random.normal(rn, x0.shape, x0.dtype)
    xt = jnp.sqrt(ab) * x0 + jnp.sqrt(1 - ab) * noise
    pred = apply_fn(params, xt, t, batch)
    return jnp.mean(jnp.square(pred.astype(jnp.float32)
                               - noise.astype(jnp.float32)))


def timestep_embedding(t: Array, dim: int) -> Array:
    """t in [0,1] -> (B, dim) sinusoidal features."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    ang = t[:, None] * 1000.0 * freqs[None]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


# --------------------------------------------------------------------------
# UViT (paper [8]): ViT with symmetric long skips
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UViTConfig:
    name: str
    img_size: int = 32
    in_ch: int = 4
    patch: int = 2
    d_model: int = 512
    n_layers: int = 12            # even: L/2 enc + L/2 dec
    n_heads: int = 8
    d_ff: int = 2048
    n_classes: int = 1001         # class-conditional (UViT on ImageNet)
    norm_eps: float = 1e-6
    use_skip_kernel: bool = False  # fused Pallas skip-in (see _skip_project)
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    @property
    def n_tokens(self) -> int:
        return (self.img_size // self.patch) ** 2 + 2  # + time + class tokens

    @property
    def half(self) -> int:
        return self.n_layers // 2

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.n_heads,
                          self.d_model // self.n_heads, rope_theta=0.0,
                          causal=False)

    def param_count(self) -> int:
        d = self.d_model
        per = 4 * d * d + 2 * d * self.d_ff
        skip = d * 2 * d
        return (self.n_layers * per + self.half * skip
                + self.n_classes * d + self.patch ** 2 * self.in_ch * d * 2)


def _init_vit_block(key, cfg, d_ff: int, with_skip: bool,
                    cross_dim: int = 0, ada: bool = False) -> Params:
    ks = jax.random.split(key, 6)
    d, pd = cfg.d_model, cfg.param_dtype
    p: Params = {
        "ln1": jnp.ones((d,), pd),
        "attn": L.init_attention(ks[0], cfg.attn_cfg(), pd),
        "ln2": jnp.ones((d,), pd),
        "mlp": L.init_gelu_mlp(ks[1], d, d_ff, pd),
    }
    if with_skip:
        p["skip_proj"] = L.dense_init(ks[2], 2 * d, d, pd)
    if cross_dim:
        p["lnx"] = jnp.ones((d,), pd)
        p["xattn"] = L.init_attention(ks[3], cfg.attn_cfg(), pd)
        p["ctx_kv"] = L.dense_init(ks[4], cross_dim, 2 * d, pd)
    if ada:
        p["ada"] = (jax.random.normal(ks[5], (d, 6 * d)) * 0.02 / math.sqrt(d)
                    ).astype(pd)
    return p


def _skip_project(p: Params, x: Array, skip: Array, cfg) -> Array:
    """Decoder skip-in projection: ``y = [x | skip] @ skip_proj``.

    With ``cfg.use_skip_kernel`` the fused Pallas kernel
    (``h @ W1 + s @ W2``, f32 accumulation; interpret mode off-TPU)
    replaces the concat matmul — the concat materialises the ``(.., 2D)``
    activation in HBM just to read it back once.  Falls back to the
    reference contraction when the operand shapes do not tile the
    kernel's 128-square MXU blocks.
    """
    w = p["skip_proj"].astype(x.dtype)
    if getattr(cfg, "use_skip_kernel", False) and \
            skip_concat_matmul_supported(math.prod(x.shape[:-1]),
                                         x.shape[-1], w.shape[1]):
        return skip_concat_matmul(x, skip.astype(x.dtype), w)
    return jnp.concatenate([x, skip], axis=-1) @ w


def _apply_vit_block(p: Params, x: Array, cfg, *, skip: Array | None = None,
                     ctx: Array | None = None, temb: Array | None = None
                     ) -> Array:
    if skip is not None:
        x = _skip_project(p, x, skip, cfg)
    if temb is not None and "ada" in p:
        mods = (jax.nn.silu(temb) @ p["ada"].astype(temb.dtype))[:, None]
        s1, b1, g1, s2, b2, g2 = jnp.split(mods, 6, axis=-1)
    else:
        s1 = b1 = s2 = b2 = 0.0
        g1 = g2 = 1.0
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps) * (1 + s1) + b1
    a, _ = L.apply_attention(p["attn"], h, cfg.attn_cfg())
    x = x + g1 * a
    if ctx is not None and "xattn" in p:
        h = L.rms_norm(x, p["lnx"], cfg.norm_eps)
        kv = ctx @ p["ctx_kv"].astype(ctx.dtype)
        d = cfg.d_model
        B, T = ctx.shape[0], ctx.shape[1]
        hd = cfg.attn_cfg().head_dim
        kx = kv[..., :d].reshape(B, T, cfg.n_heads, hd)
        vx = kv[..., d:].reshape(B, T, cfg.n_heads, hd)
        a, _ = L.apply_attention(p["xattn"], h, cfg.attn_cfg(),
                                 cross_kv=(kx, vx))
        x = x + a
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps) * (1 + s2) + b2
    return x + g2 * L.apply_gelu_mlp(p["mlp"], h)


def init_uvit(key, cfg: UViTConfig) -> Params:
    ks = jax.random.split(key, 8)
    d, pd = cfg.d_model, cfg.param_dtype
    pp = cfg.patch ** 2 * cfg.in_ch
    ek = jax.random.split(ks[0], cfg.half)
    dk = jax.random.split(ks[1], cfg.half)
    return {
        "patch_embed": L.dense_init(ks[2], pp, d, pd),
        "pos_embed": (jax.random.normal(ks[3], (cfg.n_tokens, d)) * 0.02
                      ).astype(pd),
        "time_mlp": L.init_gelu_mlp(ks[4], d, 4 * d, pd),
        "class_embed": L.dense_init(ks[5], cfg.n_classes, d, pd),
        "enc_blocks": jax.vmap(
            lambda k: _init_vit_block(k, cfg, cfg.d_ff, False))(ek),
        "dec_blocks": jax.vmap(
            lambda k: _init_vit_block(k, cfg, cfg.d_ff, True))(dk),
        "out_norm": jnp.ones((d,), pd),
        "out_proj": L.dense_init(ks[6], d, pp, pd),
    }


def _patchify(x: Array, patch: int) -> Array:
    B, H, W, C = x.shape
    x = x.reshape(B, H // patch, patch, W // patch, patch, C)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(
        B, (H // patch) * (W // patch), patch * patch * C)


def _unpatchify(x: Array, patch: int, img: int, ch: int) -> Array:
    B = x.shape[0]
    g = img // patch
    x = x.reshape(B, g, g, patch, patch, ch)
    return x.transpose(0, 1, 3, 2, 4, 5).reshape(B, img, img, ch)


def uvit_embed(params: Params, xt: Array, t: Array, batch: dict,
               cfg: UViTConfig) -> Array:
    tok = _patchify(xt.astype(cfg.dtype), cfg.patch) @ params["patch_embed"].astype(cfg.dtype)
    temb = L.apply_gelu_mlp(params["time_mlp"],
                            timestep_embedding(t, cfg.d_model).astype(cfg.dtype))
    cemb = params["class_embed"][batch["labels"]].astype(cfg.dtype)
    x = jnp.concatenate([temb[:, None], cemb[:, None], tok], axis=1)
    return x + params["pos_embed"].astype(cfg.dtype)[None]


def uvit_output(params: Params, x: Array, cfg: UViTConfig) -> Array:
    x = L.rms_norm(x, params["out_norm"], cfg.norm_eps)
    pix = x[:, 2:] @ params["out_proj"].astype(x.dtype)
    return _unpatchify(pix, cfg.patch, cfg.img_size, cfg.in_ch)


def uvit_apply(params: Params, xt: Array, t: Array, batch: dict,
               cfg: UViTConfig) -> Array:
    """Reference (non-pipelined) forward; the wave executor replicates this
    computation distributed over stages and is tested for exact agreement."""
    x = uvit_embed(params, xt, t, batch, cfg)

    def enc(x, bp):
        x = _apply_vit_block(bp, x, cfg)
        return x, x                       # ys = skip activations

    x, skips = jax.lax.scan(enc, x, params["enc_blocks"])

    def dec(x, inp):
        bp, skip = inp
        return _apply_vit_block(bp, x, cfg, skip=skip), None

    # decoder block j consumes the skip of encoder block half-1-j
    x, _ = jax.lax.scan(dec, x, (params["dec_blocks"], skips[::-1]))
    return uvit_output(params, x, cfg)


def uvit_loss(params: Params, batch: dict, rng: Array, cfg: UViTConfig) -> Array:
    return ddpm_loss(lambda p, xt, t, b: uvit_apply(p, xt, t, b, cfg),
                     params, batch, rng)


def uvit_block_graph(cfg: UViTConfig, batch: int,
                     hw: Hardware = TPU_V5E) -> BlockGraph:
    d, n, ff = cfg.d_model, cfg.n_tokens, cfg.d_ff
    act = batch * n * d * 2                     # bf16 activation bytes
    attn_fl = 2 * batch * (4 * n * d * d + 2 * n * n * d)
    mlp_fl = 2 * batch * (2 * n * d * ff)
    blk_fl = attn_fl + mlp_fl
    per_param = (4 * d * d + 2 * d * ff) * 2
    blocks = [Block("embed", 0.0, cfg.n_classes * d * 2, act, 0,
                    2 * batch * n * (cfg.patch ** 2 * cfg.in_ch) * d)]
    for i in range(cfg.half):
        blocks.append(Block(f"enc{i}", 0.0, per_param, act, act, blk_fl))
    for i in range(cfg.half):
        blocks.append(Block(f"dec{i}", 0.0, per_param + 2 * d * d * 2, act, 0,
                            blk_fl + 2 * batch * n * 2 * d * d))
    blocks.append(Block("out", 0.0, d * cfg.patch ** 2 * cfg.in_ch * 2, act, 0,
                        2 * batch * n * d * (cfg.patch ** 2 * cfg.in_ch)))
    total = len(blocks)
    skips = tuple(SkipEdge(1 + i, total - 2 - i, act) for i in range(cfg.half))
    from repro.core.profiler import analytic_block_costs
    return BlockGraph(analytic_block_costs(blocks, hw), skips)


# --------------------------------------------------------------------------
# Hunyuan-DiT (paper [7]): DiT with adaLN + text cross-attention + skips
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HunyuanDiTConfig:
    name: str
    img_size: int = 64
    in_ch: int = 4
    patch: int = 2
    d_model: int = 1024
    n_layers: int = 24
    n_heads: int = 16
    d_ff: int = 4096
    ctx_dim: int = 1024           # CLIP+T5 text embedding dim (stub input)
    ctx_len: int = 77
    norm_eps: float = 1e-6
    use_skip_kernel: bool = False  # fused Pallas skip-in (see _skip_project)
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    @property
    def n_tokens(self) -> int:
        return (self.img_size // self.patch) ** 2

    @property
    def half(self) -> int:
        return self.n_layers // 2

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.n_heads,
                          self.d_model // self.n_heads, rope_theta=0.0,
                          causal=False)

    def param_count(self) -> int:
        d = self.d_model
        per = 4 * d * d + 2 * d * self.d_ff + 4 * d * d + 6 * d * d \
            + self.ctx_dim * 2 * d
        return self.n_layers * per + self.half * 2 * d * d


def init_hunyuan(key, cfg: HunyuanDiTConfig) -> Params:
    ks = jax.random.split(key, 6)
    d, pd = cfg.d_model, cfg.param_dtype
    pp = cfg.patch ** 2 * cfg.in_ch
    ek = jax.random.split(ks[0], cfg.half)
    dk = jax.random.split(ks[1], cfg.half)
    mk = lambda k, skip: _init_vit_block(k, cfg, cfg.d_ff, skip,
                                         cross_dim=cfg.ctx_dim, ada=True)
    return {
        "patch_embed": L.dense_init(ks[2], pp, d, pd),
        "pos_embed": (jax.random.normal(ks[3], (cfg.n_tokens, d)) * 0.02
                      ).astype(pd),
        "time_mlp": L.init_gelu_mlp(ks[4], d, 4 * d, pd),
        "enc_blocks": jax.vmap(lambda k: mk(k, False))(ek),
        "dec_blocks": jax.vmap(lambda k: mk(k, True))(dk),
        "out_norm": jnp.ones((d,), pd),
        "out_proj": L.dense_init(ks[5], d, pp, pd),
    }


def hunyuan_apply(params: Params, xt: Array, t: Array, batch: dict,
                  cfg: HunyuanDiTConfig) -> Array:
    tok = _patchify(xt.astype(cfg.dtype), cfg.patch) @ params["patch_embed"].astype(cfg.dtype)
    x = tok + params["pos_embed"].astype(cfg.dtype)[None]
    temb = L.apply_gelu_mlp(params["time_mlp"],
                            timestep_embedding(t, cfg.d_model).astype(cfg.dtype))
    ctx = batch["text_embeds"].astype(cfg.dtype)

    def enc(x, bp):
        x = _apply_vit_block(bp, x, cfg, ctx=ctx, temb=temb)
        return x, x

    x, skips = jax.lax.scan(enc, x, params["enc_blocks"])

    def dec(x, inp):
        bp, skip = inp
        return _apply_vit_block(bp, x, cfg, skip=skip, ctx=ctx, temb=temb), None

    x, _ = jax.lax.scan(dec, x, (params["dec_blocks"], skips[::-1]))
    x = L.rms_norm(x, params["out_norm"], cfg.norm_eps)
    pix = x @ params["out_proj"].astype(x.dtype)
    return _unpatchify(pix, cfg.patch, cfg.img_size, cfg.in_ch)


def hunyuan_loss(params: Params, batch: dict, rng: Array,
                 cfg: HunyuanDiTConfig) -> Array:
    return ddpm_loss(lambda p, xt, t, b: hunyuan_apply(p, xt, t, b, cfg),
                     params, batch, rng)


def hunyuan_block_graph(cfg: HunyuanDiTConfig, batch: int,
                        hw: Hardware = TPU_V5E) -> BlockGraph:
    d, n, ff, lt = cfg.d_model, cfg.n_tokens, cfg.d_ff, cfg.ctx_len
    act = batch * n * d * 2
    blk_fl = 2 * batch * (4 * n * d * d + 2 * n * n * d + 2 * n * d * ff
                          + 2 * n * d * d + cfg.ctx_dim * 2 * d * lt
                          + 2 * n * lt * d + 6 * n * d * d // n)
    per_param = (4 * d * d + 2 * d * ff + 2 * d * d + cfg.ctx_dim * 2 * d
                 + 6 * d * d) * 2
    blocks = [Block("embed", 0.0, d * 8, act, 0, 2 * batch * n * 16 * d)]
    for i in range(cfg.half):
        blocks.append(Block(f"enc{i}", 0.0, per_param, act, act, blk_fl))
    for i in range(cfg.half):
        blocks.append(Block(f"dec{i}", 0.0, per_param + 8 * d * d, act, 0,
                            blk_fl + 2 * batch * n * 2 * d * d))
    blocks.append(Block("out", 0.0, d * 16 * 2, act, 0, 2 * batch * n * d * 16))
    total = len(blocks)
    skips = tuple(SkipEdge(1 + i, total - 2 - i, act) for i in range(cfg.half))
    from repro.core.profiler import analytic_block_costs
    return BlockGraph(analytic_block_costs(blocks, hw), skips)


# --------------------------------------------------------------------------
# SDv2-style UNet (heterogeneous conv + attention blocks)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UNetConfig:
    name: str
    img_size: int = 32
    in_ch: int = 4
    base_ch: int = 128
    ch_mults: tuple[int, ...] = (1, 2, 4, 4)
    blocks_per_level: int = 2
    attn_levels: tuple[int, ...] = (1, 2, 3)
    ctx_dim: int = 512            # CLIP text embedding dim
    ctx_len: int = 77
    n_heads: int = 8
    norm_eps: float = 1e-5
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    def level_ch(self, lvl: int) -> int:
        return self.base_ch * self.ch_mults[lvl]

    def param_count(self) -> int:
        total = 0
        for lvl, m in enumerate(self.ch_mults):
            c = self.base_ch * m
            total += self.blocks_per_level * (2 * 9 * c * c + c * c)
            if lvl in self.attn_levels:
                total += self.blocks_per_level * (4 * c * c + self.ctx_dim * 2 * c
                                                  + 8 * c * c)
        return 2 * total + 10 * self.base_ch ** 2 * self.ch_mults[-1] ** 2


def _conv_init(key, kh, kw, cin, cout, dtype):
    scale = 1.0 / math.sqrt(kh * kw * cin)
    return (jax.random.normal(key, (kh, kw, cin, cout)) * scale).astype(dtype)


def conv2d(x: Array, w: Array, stride: int = 1) -> Array:
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def group_norm(x: Array, scale: Array, bias: Array, groups: int = 8,
               eps: float = 1e-5) -> Array:
    B, H, W, C = x.shape
    xg = x.reshape(B, H, W, groups, C // groups).astype(jnp.float32)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    out = xg.reshape(B, H, W, C) * scale + bias
    return out.astype(x.dtype)


def _init_resblock(key, cin, cout, temb_dim, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "gn1": jnp.ones((cin,)), "gb1": jnp.zeros((cin,)),
        "conv1": _conv_init(ks[0], 3, 3, cin, cout, dtype),
        "temb": L.dense_init(ks[1], temb_dim, cout, dtype),
        "gn2": jnp.ones((cout,)), "gb2": jnp.zeros((cout,)),
        "conv2": _conv_init(ks[2], 3, 3, cout, cout, dtype),
    }
    if cin != cout:
        p["skip_conv"] = _conv_init(ks[3], 1, 1, cin, cout, dtype)
    return p


def _apply_resblock(p: Params, x: Array, temb: Array, cfg: UNetConfig) -> Array:
    h = jax.nn.silu(group_norm(x, p["gn1"], p["gb1"], eps=cfg.norm_eps))
    h = conv2d(h, p["conv1"])
    h = h + (jax.nn.silu(temb) @ p["temb"].astype(temb.dtype))[:, None, None]
    h = jax.nn.silu(group_norm(h, p["gn2"], p["gb2"], eps=cfg.norm_eps))
    h = conv2d(h, p["conv2"])
    if "skip_conv" in p:
        x = conv2d(x, p["skip_conv"])
    return x + h


def _init_attnblock(key, c, cfg: UNetConfig) -> Params:
    ks = jax.random.split(key, 4)
    d = c
    acfg = AttnConfig(d, cfg.n_heads, cfg.n_heads, d // cfg.n_heads,
                      rope_theta=0.0, causal=False)
    return {
        "gn": jnp.ones((c,)), "gb": jnp.zeros((c,)),
        "attn": L.init_attention(ks[0], acfg, cfg.param_dtype),
        "lnx": jnp.ones((c,)),
        "ctx_kv": L.dense_init(ks[1], cfg.ctx_dim, 2 * c, cfg.param_dtype),
        "xattn": L.init_attention(ks[2], acfg, cfg.param_dtype),
        "ln2": jnp.ones((c,)),
        "mlp": L.init_gelu_mlp(ks[3], c, 4 * c, cfg.param_dtype),
    }


def _apply_attnblock(p: Params, x: Array, ctx: Array, cfg: UNetConfig) -> Array:
    B, H, W, C = x.shape
    acfg = AttnConfig(C, cfg.n_heads, cfg.n_heads, C // cfg.n_heads,
                      rope_theta=0.0, causal=False)
    t = group_norm(x, p["gn"], p["gb"], eps=cfg.norm_eps).reshape(B, H * W, C)
    a, _ = L.apply_attention(p["attn"], t, acfg)
    t = x.reshape(B, H * W, C) + a
    h = L.rms_norm(t, p["lnx"], cfg.norm_eps)
    kv = ctx @ p["ctx_kv"].astype(ctx.dtype)
    hd = C // cfg.n_heads
    kx = kv[..., :C].reshape(B, -1, cfg.n_heads, hd)
    vx = kv[..., C:].reshape(B, -1, cfg.n_heads, hd)
    a, _ = L.apply_attention(p["xattn"], h, acfg, cross_kv=(kx, vx))
    t = t + a
    h = L.rms_norm(t, p["ln2"], cfg.norm_eps)
    t = t + L.apply_gelu_mlp(p["mlp"], h)
    return t.reshape(B, H, W, C)


def init_unet(key, cfg: UNetConfig) -> Params:
    pd = cfg.param_dtype
    keys = iter(jax.random.split(key, 256))
    temb_dim = 4 * cfg.base_ch
    k1, k2 = jax.random.split(next(keys))
    p: Params = {
        "time_mlp": {"w1": L.dense_init(k1, cfg.base_ch, temb_dim, pd),
                     "b1": jnp.zeros((temb_dim,), pd),
                     "w2": L.dense_init(k2, temb_dim, temb_dim, pd),
                     "b2": jnp.zeros((temb_dim,), pd)},
        "in_conv": _conv_init(next(keys), 3, 3, cfg.in_ch, cfg.base_ch, pd),
        "down": [], "up": [],
    }
    c = cfg.base_ch
    chans = [c]
    for lvl, m in enumerate(cfg.ch_mults):
        cout = cfg.base_ch * m
        level = []
        for _ in range(cfg.blocks_per_level):
            blk = {"res": _init_resblock(next(keys), c, cout, temb_dim, pd)}
            if lvl in cfg.attn_levels:
                blk["attn"] = _init_attnblock(next(keys), cout, cfg)
            level.append(blk)
            c = cout
            chans.append(c)
        if lvl < len(cfg.ch_mults) - 1:
            level.append({"downsample": _conv_init(next(keys), 3, 3, c, c, pd)})
            chans.append(c)
        p["down"].append(level)
    p["mid"] = {
        "res1": _init_resblock(next(keys), c, c, temb_dim, pd),
        "attn": _init_attnblock(next(keys), c, cfg),
        "res2": _init_resblock(next(keys), c, c, temb_dim, pd),
    }
    for lvl in reversed(range(len(cfg.ch_mults))):
        cout = cfg.base_ch * cfg.ch_mults[lvl]
        level = []
        for _ in range(cfg.blocks_per_level + 1):
            cskip = chans.pop()
            blk = {"res": _init_resblock(next(keys), c + cskip, cout, temb_dim, pd)}
            if lvl in cfg.attn_levels:
                blk["attn"] = _init_attnblock(next(keys), cout, cfg)
            level.append(blk)
            c = cout
        if lvl > 0:
            level.append({"upsample": _conv_init(next(keys), 3, 3, c, c, pd)})
        p["up"].append(level)
    p["out_gn"] = jnp.ones((c,))
    p["out_gb"] = jnp.zeros((c,))
    p["out_conv"] = _conv_init(next(keys), 3, 3, c, cfg.in_ch, pd)
    return p


def unet_apply(params: Params, xt: Array, t: Array, batch: dict,
               cfg: UNetConfig) -> Array:
    ctx = batch["text_embeds"].astype(cfg.dtype)
    tm = params["time_mlp"]
    temb = timestep_embedding(t, cfg.base_ch).astype(cfg.dtype)
    temb = jax.nn.gelu(temb @ tm["w1"].astype(cfg.dtype) + tm["b1"])
    temb = temb @ tm["w2"].astype(cfg.dtype) + tm["b2"]
    x = conv2d(xt.astype(cfg.dtype), params["in_conv"])
    skips = [x]
    for lvl, level in enumerate(params["down"]):
        for blk in level:
            if "downsample" in blk:
                x = conv2d(x, blk["downsample"], stride=2)
            else:
                x = _apply_resblock(blk["res"], x, temb, cfg)
                if "attn" in blk:
                    x = _apply_attnblock(blk["attn"], x, ctx, cfg)
            skips.append(x)
    x = _apply_resblock(params["mid"]["res1"], x, temb, cfg)
    x = _apply_attnblock(params["mid"]["attn"], x, ctx, cfg)
    x = _apply_resblock(params["mid"]["res2"], x, temb, cfg)
    for level in params["up"]:
        for blk in level:
            if "upsample" in blk:
                B, H, W, C = x.shape
                x = jax.image.resize(x, (B, 2 * H, 2 * W, C), "nearest")
                x = conv2d(x, blk["upsample"])
            else:
                x = jnp.concatenate([x, skips.pop()], axis=-1)
                x = _apply_resblock(blk["res"], x, temb, cfg)
                if "attn" in blk:
                    x = _apply_attnblock(blk["attn"], x, ctx, cfg)
    x = jax.nn.silu(group_norm(x, params["out_gn"], params["out_gb"],
                               eps=cfg.norm_eps))
    return conv2d(x, params["out_conv"])


def unet_loss(params: Params, batch: dict, rng: Array, cfg: UNetConfig) -> Array:
    return ddpm_loss(lambda p, xt, t, b: unet_apply(p, xt, t, b, cfg),
                     params, batch, rng)


def unet_block_graph(cfg: UNetConfig, batch: int,
                     hw: Hardware = TPU_V5E) -> BlockGraph:
    """Exports the UNet as a heterogeneous BlockGraph (paper Fig. 6: per-block
    cost varies ~3x across resolutions)."""
    blocks: list[Block] = []
    skip_meta: list[tuple[int, int]] = []   # (blk_index, bytes)
    res = cfg.img_size

    def res_cost(cin, cout, r):
        fl = 2 * batch * r * r * 9 * cin * cout + 2 * batch * r * r * 9 * cout * cout
        return fl, batch * r * r * cout * 2

    def attn_cost(c, r):
        n = r * r
        fl = 2 * batch * (8 * n * c * c + 4 * n * n * c + 8 * n * c * c
                          + cfg.ctx_len * n * c * 2)
        return fl

    c = cfg.base_ch
    fl, act = res_cost(cfg.in_ch, c, res)
    blocks.append(Block("in_conv", 0.0, 9 * cfg.in_ch * c * 2, act, act, fl))
    skip_meta.append((0, act))
    for lvl, m in enumerate(cfg.ch_mults):
        cout = cfg.base_ch * m
        for b in range(cfg.blocks_per_level):
            fl, act = res_cost(c, cout, res)
            pbytes = (9 * c * cout + 9 * cout * cout) * 2
            if lvl in cfg.attn_levels:
                fl += attn_cost(cout, res)
                pbytes += (16 * cout * cout + cfg.ctx_dim * 2 * cout) * 2
            blocks.append(Block(f"d{lvl}b{b}", 0.0, pbytes, act, act, fl))
            skip_meta.append((len(blocks) - 1, act))
            c = cout
        if lvl < len(cfg.ch_mults) - 1:
            fl = 2 * batch * (res // 2) ** 2 * 9 * c * c
            act = batch * (res // 2) ** 2 * c * 2
            blocks.append(Block(f"down{lvl}", 0.0, 9 * c * c * 2, act, act, fl))
            skip_meta.append((len(blocks) - 1, act))
            res //= 2
    fl, act = res_cost(c, c, res)
    blocks.append(Block("mid", 0.0, (18 * c * c + 16 * c * c) * 2, act, 0,
                        2 * fl + attn_cost(c, res)))
    for lvl in reversed(range(len(cfg.ch_mults))):
        cout = cfg.base_ch * cfg.ch_mults[lvl]
        for b in range(cfg.blocks_per_level + 1):
            src, sbytes = skip_meta.pop()
            cin = c + sbytes // (batch * res * res * 2)
            fl, act = res_cost(cin, cout, res)
            pbytes = (9 * cin * cout + 9 * cout * cout) * 2
            if lvl in cfg.attn_levels:
                fl += attn_cost(cout, res)
                pbytes += (16 * cout * cout + cfg.ctx_dim * 2 * cout) * 2
            blocks.append(Block(f"u{lvl}b{b}", 0.0, pbytes, act, 0, fl))
            c = cout
        if lvl > 0:
            res *= 2
            fl = 2 * batch * res * res * 9 * c * c
            act = batch * res * res * c * 2
            blocks.append(Block(f"up{lvl}", 0.0, 9 * c * c * 2, act, 0, fl))
    blocks.append(Block("out_conv", 0.0, 9 * c * cfg.in_ch * 2,
                        batch * cfg.img_size ** 2 * cfg.in_ch * 2, 0,
                        2 * batch * cfg.img_size ** 2 * 9 * c * cfg.in_ch))
    # Skip edges follow the UNet's LIFO stack discipline (nested by
    # construction): producers are the down-path blocks with skip_bytes > 0,
    # consumers are the up-path res blocks, popping in reverse order.
    producers = [i for i, b in enumerate(blocks) if b.skip_bytes > 0]
    consumers = [i for i, b in enumerate(blocks)
                 if b.name.startswith("u") and not b.name.startswith("up")]
    edges = []
    stack = list(producers)
    for cons in consumers:
        if stack:
            src = stack.pop()
            edges.append(SkipEdge(src, cons, blocks[src].skip_bytes))
    from repro.core.profiler import analytic_block_costs
    return BlockGraph(analytic_block_costs(blocks, hw),
                      tuple(sorted(edges, key=lambda e: e.src)))


def uvit_pipeline_graph(cfg: UViTConfig, batch: int = 1,
                        fwd_times=None, hw: Hardware = TPU_V5E) -> BlockGraph:
    """Runtime-aligned UViT graph for the auto-pipeline compile path.

    Unlike :func:`uvit_block_graph` (which models embed/out as blocks for
    the analytic comm studies), this graph has exactly one block per
    enc/dec transformer block — matching ``params["enc_blocks"]`` /
    ``params["dec_blocks"]`` rows — with the fully-paired skip edges
    (enc i -> dec mirror) the partitioner collocates.  ``fwd_times``
    (length 2*half) injects profiled per-block times.
    """
    d, n, ff = cfg.d_model, cfg.n_tokens, cfg.d_ff
    act = batch * n * d * 2
    attn_fl = 2 * batch * (4 * n * d * d + 2 * n * n * d)
    mlp_fl = 2 * batch * (2 * n * d * ff)
    per_param = (4 * d * d + 2 * d * ff) * 2
    blocks = []
    for i in range(cfg.half):
        blocks.append(Block(f"enc{i}", 0.0, per_param, act, act,
                            attn_fl + mlp_fl))
    for i in range(cfg.half):
        blocks.append(Block(f"dec{i}", 0.0, per_param + 2 * d * d * 2, act, 0,
                            attn_fl + mlp_fl + 2 * batch * n * 2 * d * d))
    return _runtime_graph(blocks,
                          _paired_skips(2 * cfg.half, cfg.half, act),
                          fwd_times, hw)


def _runtime_graph(blocks, skip_edges, fwd_times, hw) -> BlockGraph:
    """Shared tail of the ``*_pipeline_graph`` builders: analytic block
    costs, optional profiled fwd-time injection, skip-edge attachment."""
    from repro.core.profiler import analytic_block_costs
    blocks = list(analytic_block_costs(tuple(blocks), hw))
    if fwd_times is not None:
        if len(fwd_times) != len(blocks):
            raise ValueError("fwd_times must have one entry per block")
        blocks = [dataclasses.replace(b, fwd_time=float(t))
                  for b, t in zip(blocks, fwd_times)]
    return BlockGraph(tuple(blocks), tuple(skip_edges))


def _paired_skips(n_total: int, n_pairs: int, act: int
                  ) -> tuple[SkipEdge, ...]:
    """Fully-paired UNet edges: block i -> its mirror ``n_total-1-i``."""
    return tuple(SkipEdge(i, n_total - 1 - i, act) for i in range(n_pairs))


def hunyuan_pipeline_graph(cfg: HunyuanDiTConfig, batch: int = 1,
                           fwd_times=None,
                           hw: Hardware = TPU_V5E) -> BlockGraph:
    """Runtime-aligned Hunyuan-DiT graph for the auto-pipeline compile path.

    Like :func:`uvit_pipeline_graph`: exactly one block per
    ``enc_blocks``/``dec_blocks`` row (embed/out live in edge params), with
    the fully-paired skip edges enc i -> dec mirror.  ``fwd_times``
    (length 2*half) injects profiled per-block times.
    """
    d, n, ff, lt = cfg.d_model, cfg.n_tokens, cfg.d_ff, cfg.ctx_len
    act = batch * n * d * 2
    blk_fl = 2 * batch * (4 * n * d * d + 2 * n * n * d + 2 * n * d * ff
                          + 2 * n * d * d + cfg.ctx_dim * 2 * d * lt
                          + 2 * n * lt * d)
    per_param = (4 * d * d + 2 * d * ff + 2 * d * d + cfg.ctx_dim * 2 * d
                 + 6 * d * d) * 2
    blocks = []
    for i in range(cfg.half):
        blocks.append(Block(f"enc{i}", 0.0, per_param, act, act, blk_fl))
    for i in range(cfg.half):
        blocks.append(Block(f"dec{i}", 0.0, per_param + 2 * d * d * 2, act,
                            0, blk_fl + 2 * batch * n * 2 * d * d))
    return _runtime_graph(blocks,
                          _paired_skips(2 * cfg.half, cfg.half, act),
                          fwd_times, hw)


# --------------------------------------------------------------------------
# SkipViT: homogeneous ViT stack with an arbitrary (possibly sparse) skip
# topology — the asymmetric-fold workload (mid-block bottlenecks, sparse
# skips, odd block counts) the generalized layout/lowering stack runs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SkipViTConfig:
    """UNet-shaped ViT over ONE homogeneous block stack.

    ``n_enc`` skip-emitting blocks, ``n_mid`` bottleneck blocks (no skip
    endpoints), ``n_dec`` blocks that may consume a skip.  ``skip_pairs``
    (block-index ``(src, dst)`` tuples) defaults to full pairing
    ``(i, n-1-i)``; pass a subset for sparse-skip variants.  Every block
    carries a ``skip_in`` projection and consumes *additively*
    (``x + skip @ skip_in``), so blocks without an incoming skip see zeros
    and reduce to a plain ViT block — one scan body covers emitters,
    bottlenecks and consumers, which is what lets the fold's turnaround cut
    land anywhere the partitioner puts it.
    """

    name: str
    img_size: int = 8
    in_ch: int = 4
    patch: int = 2
    d_model: int = 32
    n_heads: int = 4
    d_ff: int = 64
    n_classes: int = 10
    n_enc: int = 3
    n_mid: int = 2
    n_dec: int = 3
    skip_pairs: tuple[tuple[int, int], ...] | None = None
    norm_eps: float = 1e-6
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    @property
    def n_blocks(self) -> int:
        return self.n_enc + self.n_mid + self.n_dec

    @property
    def n_tokens(self) -> int:
        return (self.img_size // self.patch) ** 2 + 2  # + time/class tokens

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.n_heads,
                          self.d_model // self.n_heads, rope_theta=0.0,
                          causal=False)

    def skip_edges(self) -> tuple[tuple[int, int], ...]:
        if self.skip_pairs is not None:
            return self.skip_pairs
        k = min(self.n_enc, self.n_dec)
        return tuple((i, self.n_blocks - 1 - i) for i in range(k))


def init_skipvit(key, cfg: SkipViTConfig) -> Params:
    ks = jax.random.split(key, 8)
    d, pd = cfg.d_model, cfg.param_dtype
    pp = cfg.patch ** 2 * cfg.in_ch
    bk = jax.random.split(ks[0], cfg.n_blocks)

    def mk(k):
        k1, k2 = jax.random.split(k)
        p = _init_vit_block(k1, cfg, cfg.d_ff, False)
        p["skip_in"] = L.dense_init(k2, d, d, pd)
        return p

    return {
        "patch_embed": L.dense_init(ks[2], pp, d, pd),
        "pos_embed": (jax.random.normal(ks[3], (cfg.n_tokens, d)) * 0.02
                      ).astype(pd),
        "time_mlp": L.init_gelu_mlp(ks[4], d, 4 * d, pd),
        "class_embed": L.dense_init(ks[5], cfg.n_classes, d, pd),
        "blocks": jax.vmap(mk)(bk),
        "out_norm": jnp.ones((d,), pd),
        "out_proj": L.dense_init(ks[6], d, pp, pd),
    }


def skipvit_apply(params: Params, xt: Array, t: Array, batch: dict,
                  cfg: SkipViTConfig) -> Array:
    """Single-device reference; the pipeline executors must match it for
    every legal partition, mirror-symmetric or not."""
    x = uvit_embed(params, xt, t, batch, cfg)
    consumes = {dst: src for src, dst in cfg.skip_edges()}
    stash: dict[int, Array] = {}
    for b in range(cfg.n_blocks):
        bp = jax.tree.map(lambda a: a[b], params["blocks"])
        if b in consumes:
            x = x + stash[consumes[b]] @ bp["skip_in"].astype(x.dtype)
        x = _apply_vit_block(bp, x, cfg)
        stash[b] = x
    return uvit_output(params, x, cfg)


def skipvit_loss(params: Params, batch: dict, rng: Array,
                 cfg: SkipViTConfig) -> Array:
    return ddpm_loss(lambda p, xt, t, b: skipvit_apply(p, xt, t, b, cfg),
                     params, batch, rng)


def skipvit_pipeline_graph(cfg: SkipViTConfig, batch: int = 1,
                           fwd_times=None,
                           hw: Hardware = TPU_V5E) -> BlockGraph:
    """Runtime-aligned SkipViT graph: one block per ``params['blocks']``
    row with the config's (possibly sparse / mid-block) skip edges."""
    d, n, ff = cfg.d_model, cfg.n_tokens, cfg.d_ff
    act = batch * n * d * 2
    blk_fl = 2 * batch * (4 * n * d * d + 2 * n * n * d + 2 * n * d * ff)
    per_param = (4 * d * d + 2 * d * ff + d * d) * 2
    edges = cfg.skip_edges()
    srcs = {s for s, _ in edges}
    blocks = [Block(f"blk{i}", 0.0, per_param, act,
                    act if i in srcs else 0, blk_fl)
              for i in range(cfg.n_blocks)]
    return _runtime_graph(blocks,
                          (SkipEdge(s, t, act) for s, t in edges),
                          fwd_times, hw)
