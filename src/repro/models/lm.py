"""Unified LM-transformer family.

One configurable decoder-only implementation covers: smollm-360m,
h2o-danube-1.8b (SWA), internlm2-20b, granite-34b (MQA), internvl2-2b
(vision-prefix), qwen3-moe-30b-a3b (MoE + qk-norm), deepseek-v3-671b
(MLA + shared/routed MoE + dense prelude + MTP).

Layers are stacked with a leading ``[n_layers, ...]`` axis so that the
pipeline runtime can reshape them into ``[stages, layers_per_stage, ...]``
and shard the stage axis over the ``model`` mesh axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import (AttnConfig, MLAConfig, MoEConfig, Params,
                                 Array)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    attn: AttnConfig | None = None
    mla: MLAConfig | None = None
    d_ff: int = 0                      # SwiGLU FFN size (dense layers)
    moe: MoEConfig | None = None       # MoE FFN (replaces dense except prelude)
    n_dense_layers: int = 0            # deepseek: first k layers dense
    tied_embeddings: bool = False
    mtp: bool = False                  # multi-token prediction head
    norm_eps: float = 1e-6
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    vision_prefix: int = 0             # of stubbed patch-embedding tokens
    moe_aux_weight: float = 0.01
    mtp_weight: float = 0.3
    moe_dispatch: str = "onehot"
    mlp_gelu: bool = False             # 2-matrix GELU MLP (gpt_bigcode/granite)
    remat: bool = False                # checkpoint each layer in the scan
    remat_policy: str | None = None    # "dots": save matmul outputs
    seq_shard_activations: str | None = None  # Megatron-SP residual stream

    @property
    def head_dim(self) -> int:
        return self.attn.head_dim if self.attn else self.mla.v_head_dim

    def param_count(self) -> int:
        """Approximate total parameters (for roofline MODEL_FLOPS)."""
        d = self.d_model
        emb = self.vocab * d * (1 if self.tied_embeddings else 2)
        if self.mla:
            m = self.mla
            attn = (d * m.q_lora_rank + m.q_lora_rank * m.n_heads *
                    (m.qk_nope_dim + m.qk_rope_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank * m.n_heads * (m.qk_nope_dim + m.v_head_dim)
                    + m.n_heads * m.v_head_dim * d)
        else:
            a = self.attn
            attn = d * a.head_dim * (a.n_heads * 2 + a.n_kv_heads * 2)
        dense_ffn = (2 if self.mlp_gelu else 3) * d * self.d_ff
        n_moe = self.n_layers - self.n_dense_layers if self.moe else 0
        n_dense = self.n_layers - n_moe
        total = emb + self.n_layers * attn + n_dense * dense_ffn
        if self.moe:
            c = self.moe
            per_expert = 3 * d * c.d_ff
            shared = 3 * d * (c.shared_d_ff or c.d_ff) * c.n_shared
            total += n_moe * (c.n_experts * per_expert + shared + d * c.n_experts)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k + shared experts)."""
        if not self.moe:
            return self.param_count()
        d, c = self.d_model, self.moe
        n_moe = self.n_layers - self.n_dense_layers
        inactive = n_moe * (c.n_experts - c.top_k) * 3 * d * c.d_ff
        return self.param_count() - inactive


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_layer(key, cfg: LMConfig, dense_ffn: bool) -> Params:
    k1, k2 = jax.random.split(key)
    d, pd = cfg.d_model, cfg.param_dtype
    p: Params = {"ln1": jnp.ones((d,), pd), "ln2": jnp.ones((d,), pd)}
    if cfg.mla is not None:
        p["attn"] = L.init_mla(k1, cfg.mla, pd)
    else:
        p["attn"] = L.init_attention(k1, cfg.attn, pd)
    if dense_ffn or cfg.moe is None:
        if cfg.mlp_gelu:
            p["ffn"] = L.init_gelu_mlp(k2, d, cfg.d_ff, pd)
        else:
            p["ffn"] = L.init_swiglu(k2, d, cfg.d_ff, pd)
    else:
        p["ffn"] = L.init_moe(k2, cfg.moe, pd)
    return p


def init_lm(key, cfg: LMConfig) -> Params:
    keys = jax.random.split(key, 6)
    d, pd = cfg.d_model, cfg.param_dtype
    params: Params = {
        "embed": L.dense_init(keys[0], cfg.vocab, d, pd),
        "final_norm": jnp.ones((d,), pd),
    }
    n_moe = cfg.n_layers - cfg.n_dense_layers if cfg.moe else cfg.n_layers
    n_dense = cfg.n_layers - n_moe
    if n_dense:
        dk = jax.random.split(keys[1], n_dense)
        params["dense_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, dense_ffn=True))(dk)
    lk = jax.random.split(keys[2], n_moe)
    params["layers"] = jax.vmap(
        lambda k: _init_layer(k, cfg, dense_ffn=cfg.moe is None))(lk)
    if not cfg.tied_embeddings:
        params["head"] = L.dense_init(keys[3], d, cfg.vocab, pd)
    if cfg.mtp:
        params["mtp"] = {
            "proj": L.dense_init(keys[4], 2 * d, d, pd),
            "norm_h": jnp.ones((d,), pd),
            "norm_e": jnp.ones((d,), pd),
            "block": _init_layer(keys[5], cfg, dense_ffn=True),
        }
    return params


# --------------------------------------------------------------------------
# apply
# --------------------------------------------------------------------------

def apply_layer(p: Params, x: Array, cfg: LMConfig, *, dense_ffn: bool,
                positions: Array | None = None,
                cache: Params | None = None) -> tuple[Array, Params | None, Array]:
    """One decoder layer. Returns (x, new_cache, moe_aux_loss)."""
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, new_cache = L.apply_mla(p["attn"], h, cfg.mla,
                                   positions=positions, cache=cache)
    else:
        a, new_cache = L.apply_attention(p["attn"], h, cfg.attn,
                                         positions=positions, cache=cache)
    x = x + a
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if dense_ffn or cfg.moe is None:
        mlp = L.apply_gelu_mlp if cfg.mlp_gelu else L.apply_swiglu
        f, aux = mlp(p["ffn"], h), jnp.zeros((), jnp.float32)
    else:
        f, aux = L.apply_moe(p["ffn"], h, cfg.moe, dispatch=cfg.moe_dispatch)
    x = x + f
    if cfg.seq_shard_activations and x.shape[1] > 1:
        # Megatron-SP: keep the residual stream sequence-sharded between
        # blocks; GSPMD turns the 2 per-block all-reduces into RS+AG pairs
        # at half the bytes.
        x = jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(
                None, cfg.seq_shard_activations, None))
    return x, new_cache, aux


def embed_tokens(params: Params, tokens: Array, cfg: LMConfig,
                 prefix_embeds: Array | None = None) -> Array:
    x = params["embed"][tokens].astype(cfg.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.dtype), x], axis=1)
    return x


def unembed(params: Params, x: Array, cfg: LMConfig) -> Array:
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tied_embeddings else params["head"]
    return x @ w.astype(x.dtype)


def _scan_layers(stack: Params, x: Array, cfg: LMConfig, *, dense_ffn: bool,
                 positions: Array, caches: Params | None
                 ) -> tuple[Array, Params | None, Array]:
    """lax.scan over a stacked layer group (O(1) HLO in depth)."""

    def body(carry, inp):
        x, aux = carry
        lp, cache = inp
        x, new_cache, a = apply_layer(lp, x, cfg, dense_ffn=dense_ffn,
                                      positions=positions, cache=cache)
        return (x, aux + a), new_cache

    if cfg.remat and caches is None:
        if cfg.remat_policy == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots)
        elif cfg.remat_policy == "dots_nb":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            body = jax.checkpoint(body)
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stack, caches))
    return x, new_caches, aux


def forward(params: Params, tokens: Array, cfg: LMConfig, *,
            prefix_embeds: Array | None = None,
            caches: Params | None = None,
            positions: Array | None = None,
            ) -> tuple[Array, Params | None, Array]:
    """Full forward -> (hidden (B,S,d), new_caches, moe_aux).

    ``caches``: {"dense": stacked, "layers": stacked} or None.
    """
    x = embed_tokens(params, tokens, cfg, prefix_embeds)
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    aux = jnp.zeros((), jnp.float32)
    new_caches: Params = {}
    if "dense_layers" in params:
        c = caches["dense"] if caches else None
        x, nc, a = _scan_layers(params["dense_layers"], x, cfg,
                                dense_ffn=True, positions=positions, caches=c)
        aux += a
        new_caches["dense"] = nc
    c = caches["layers"] if caches else None
    x, nc, a = _scan_layers(params["layers"], x, cfg, dense_ffn=False,
                            positions=positions, caches=c)
    aux += a
    new_caches["layers"] = nc
    return x, (new_caches if caches is not None else None), aux


# --------------------------------------------------------------------------
# losses / serving steps
# --------------------------------------------------------------------------

def softmax_xent(logits: Array, labels: Array, mask: Array | None = None) -> Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0] - logz
    nll = -ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def lm_loss(params: Params, batch: dict, cfg: LMConfig) -> Array:
    """Causal LM loss. batch: {"tokens": (B,S) int32, "prefix_embeds"?}."""
    tokens = batch["tokens"]
    prefix = batch.get("prefix_embeds")
    h, _, aux = forward(params, tokens, cfg, prefix_embeds=prefix)
    P = cfg.vision_prefix if prefix is not None else 0
    h_text = h[:, P:]
    logits = unembed(params, h_text[:, :-1], cfg)
    loss = softmax_xent(logits, tokens[:, 1:])
    if cfg.mtp:
        loss = loss + cfg.mtp_weight * _mtp_loss(params, h_text, tokens, cfg)
    return loss + cfg.moe_aux_weight * aux


def _mtp_loss(params: Params, h: Array, tokens: Array, cfg: LMConfig) -> Array:
    """DeepSeek-V3 multi-token prediction: predict token t+2 from the main
    stream's hidden at t combined with the embedding of token t+1."""
    mp = params["mtp"]
    h_in = L.rms_norm(h[:, :-2], mp["norm_h"], cfg.norm_eps)
    e_in = L.rms_norm(params["embed"][tokens[:, 1:-1]].astype(h.dtype),
                      mp["norm_e"], cfg.norm_eps)
    merged = jnp.concatenate([h_in, e_in], axis=-1) @ mp["proj"].astype(h.dtype)
    pos = jnp.arange(merged.shape[1])[None, :]
    out, _, _ = apply_layer(mp["block"], merged, cfg, dense_ffn=True,
                            positions=pos)
    logits = unembed(params, out, cfg)
    return softmax_xent(logits, tokens[:, 2:])


def init_caches(cfg: LMConfig, batch: int, max_len: int,
                dtype=None) -> Params:
    dtype = dtype or cfg.dtype
    n_moe = cfg.n_layers - cfg.n_dense_layers if cfg.moe else cfg.n_layers
    n_dense = cfg.n_layers - n_moe

    def one(_):
        if cfg.mla is not None:
            return L.init_mla_cache(batch, max_len, cfg.mla, dtype)
        return L.init_kv_cache(batch, max_len, cfg.attn, dtype)

    caches: Params = {"layers": jax.vmap(one)(jnp.arange(n_moe))}
    if n_dense:
        caches["dense"] = jax.vmap(one)(jnp.arange(n_dense))
    return caches


def prefill(params: Params, tokens: Array, cfg: LMConfig, max_len: int, *,
            prefix_embeds: Array | None = None,
            ) -> tuple[Array, Params]:
    """Prime a KV cache with a prompt; returns (last-token logits, caches)."""
    B = tokens.shape[0]
    caches = init_caches(cfg, B, max_len)
    h, caches, _ = forward(params, tokens, cfg, prefix_embeds=prefix_embeds,
                           caches=caches)
    logits = unembed(params, h[:, -1:], cfg)
    return logits, caches


def decode_step(params: Params, token: Array, caches: Params, cfg: LMConfig
                ) -> tuple[Array, Params]:
    """One greedy decode step. token: (B,1) int32."""
    pos = caches["layers"]["pos"][0] if "pos" in caches["layers"] else None
    positions = pos[None, None] if pos is not None else None
    h, caches, _ = forward(params, token, cfg, caches=caches,
                           positions=positions)
    logits = unembed(params, h, cfg)
    return logits, caches


# --------------------------------------------------------------------------
# PULSE planner export (runtime-aligned: one block per decoder layer)
# --------------------------------------------------------------------------

def lm_pipeline_graph(cfg: LMConfig, batch: int = 1, seq: int = 512,
                      fwd_times=None, hw=None):
    """Block graph for the auto-pipeline compile path.

    One block per row of ``params["layers"]``; embeddings / head / norms are
    edge params (replicated) and excluded, so the graph lines up 1:1 with
    the stacked block parameters the executor shards.  ``fwd_times``
    overrides the analytic roofline estimate with profiled per-layer times
    (the paper's §IV-A profiling step).
    """
    from repro.core.graph import Block, BlockGraph
    from repro.core.hw import TPU_V5E
    from repro.core.profiler import analytic_block_costs

    d, ff = cfg.d_model, cfg.d_ff
    act = batch * seq * d * 2
    flops = 2 * batch * seq * (4 * d * d + 2 * d * ff)
    per_param = (4 * d * d + 2 * d * ff) * 2
    blocks = [Block(f"layer{i}", 0.0, per_param, act, 0, flops)
              for i in range(cfg.n_layers)]
    blocks = list(analytic_block_costs(blocks, hw or TPU_V5E))
    if fwd_times is not None:
        if len(fwd_times) != cfg.n_layers:
            raise ValueError("fwd_times must have one entry per layer")
        blocks = [dataclasses.replace(b, fwd_time=float(t))
                  for b, t in zip(blocks, fwd_times)]
    return BlockGraph(tuple(blocks))
