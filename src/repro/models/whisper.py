"""Whisper-style encoder-decoder (audio frontend stubbed).

Per the assignment, the conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, T_frames, d).  The transformer backbone is
real: bidirectional encoder, causal decoder with cross-attention.

PULSE applicability (§VIII-B "partial skip patterns"): the encoder output is
a skip-like tensor consumed by *every* decoder layer; the folded placement
collocates enc/dec mirror pairs so the encoded audio rides the up-stream
ring once instead of being re-sent per stage.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import AttnConfig, Params, Array
from repro.models.lm import softmax_xent


@dataclasses.dataclass(frozen=True)
class WhisperConfig:
    name: str
    vocab: int
    d_model: int
    n_enc_layers: int
    n_dec_layers: int
    n_heads: int
    d_ff: int
    norm_eps: float = 1e-5
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def attn_cfg(self, causal: bool) -> AttnConfig:
        return AttnConfig(self.d_model, self.n_heads, self.n_heads,
                          self.head_dim, rope_theta=0.0, causal=causal)

    def param_count(self) -> int:
        d = self.d_model
        per_enc = 4 * d * d + 2 * d * self.d_ff
        per_dec = 8 * d * d + 2 * d * self.d_ff
        return (self.vocab * d + self.n_enc_layers * per_enc
                + self.n_dec_layers * per_dec)


def _sinusoid(n: int, d: int) -> Array:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_layer(key, cfg: WhisperConfig) -> Params:
    k1, k2 = jax.random.split(key)
    d, pd = cfg.d_model, cfg.param_dtype
    return {
        "ln1": jnp.ones((d,), pd), "b1": jnp.zeros((d,), pd),
        "attn": L.init_attention(k1, cfg.attn_cfg(False), pd),
        "ln2": jnp.ones((d,), pd), "b2": jnp.zeros((d,), pd),
        "mlp": L.init_gelu_mlp(k2, d, cfg.d_ff, pd),
    }


def _init_dec_layer(key, cfg: WhisperConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d, pd = cfg.d_model, cfg.param_dtype
    return {
        "ln1": jnp.ones((d,), pd), "b1": jnp.zeros((d,), pd),
        "attn": L.init_attention(k1, cfg.attn_cfg(True), pd),
        "lnx": jnp.ones((d,), pd), "bx": jnp.zeros((d,), pd),
        "xattn": L.init_attention(k2, cfg.attn_cfg(False), pd),
        "ln2": jnp.ones((d,), pd), "b2": jnp.zeros((d,), pd),
        "mlp": L.init_gelu_mlp(k3, d, cfg.d_ff, pd),
    }


def init_whisper(key, cfg: WhisperConfig) -> Params:
    ks = jax.random.split(key, 4)
    pd = cfg.param_dtype
    ek = jax.random.split(ks[0], cfg.n_enc_layers)
    dk = jax.random.split(ks[1], cfg.n_dec_layers)
    return {
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(ek),
        "enc_norm": jnp.ones((cfg.d_model,), pd),
        "enc_norm_b": jnp.zeros((cfg.d_model,), pd),
        "tok_embed": L.dense_init(ks[2], cfg.vocab, cfg.d_model, pd),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dk),
        "dec_norm": jnp.ones((cfg.d_model,), pd),
        "dec_norm_b": jnp.zeros((cfg.d_model,), pd),
    }


def encode(params: Params, frames: Array, cfg: WhisperConfig) -> Array:
    """frames: (B, T, d) stubbed frame embeddings."""
    x = frames.astype(cfg.dtype) + _sinusoid(frames.shape[1], cfg.d_model
                                             ).astype(cfg.dtype)[None]

    def body(x, lp):
        h = L.layer_norm(x, lp["ln1"], lp["b1"], cfg.norm_eps)
        a, _ = L.apply_attention(lp["attn"], h, cfg.attn_cfg(False))
        x = x + a
        h = L.layer_norm(x, lp["ln2"], lp["b2"], cfg.norm_eps)
        return x + L.apply_gelu_mlp(lp["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.layer_norm(x, params["enc_norm"], params["enc_norm_b"], cfg.norm_eps)


def decode(params: Params, tokens: Array, enc_out: Array, cfg: WhisperConfig,
           *, caches: Params | None = None, positions: Array | None = None
           ) -> tuple[Array, Params | None]:
    x = params["tok_embed"][tokens].astype(cfg.dtype)
    if positions is None:
        positions = jnp.arange(tokens.shape[1])[None, :]
    x = x + _sinusoid(4096, cfg.d_model).astype(cfg.dtype)[positions[0]][None]
    xa = cfg.attn_cfg(False)
    # precompute cross K/V once per layer from enc_out (scan over layers)
    def body(carry, inp):
        x = carry
        lp, cache = inp
        h = L.layer_norm(x, lp["ln1"], lp["b1"], cfg.norm_eps)
        a, new_cache = L.apply_attention(lp["attn"], h, cfg.attn_cfg(True),
                                         cache=cache, positions=positions)
        x = x + a
        h = L.layer_norm(x, lp["lnx"], lp["bx"], cfg.norm_eps)
        B, T = enc_out.shape[0], enc_out.shape[1]
        kx = (enc_out @ lp["xattn"]["wk"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
        vx = (enc_out @ lp["xattn"]["wv"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
        a, _ = L.apply_attention(lp["xattn"], h, xa, cross_kv=(kx, vx))
        x = x + a
        h = L.layer_norm(x, lp["ln2"], lp["b2"], cfg.norm_eps)
        return x + L.apply_gelu_mlp(lp["mlp"], h), new_cache

    x, new_caches = jax.lax.scan(body, x, (params["dec_layers"], caches))
    x = L.layer_norm(x, params["dec_norm"], params["dec_norm_b"], cfg.norm_eps)
    return x, (new_caches if caches is not None else None)


def whisper_loss(params: Params, batch: dict, cfg: WhisperConfig) -> Array:
    """batch: {"frames": (B,T,d), "tokens": (B,S)}."""
    enc = encode(params, batch["frames"], cfg)
    h, _ = decode(params, batch["tokens"][:, :-1], enc, cfg)
    logits = h @ params["tok_embed"].T.astype(h.dtype)
    return softmax_xent(logits, batch["tokens"][:, 1:])


def init_dec_caches(cfg: WhisperConfig, batch: int, max_len: int) -> Params:
    def one(_):
        return L.init_kv_cache(batch, max_len, cfg.attn_cfg(True), cfg.dtype)
    return jax.vmap(one)(jnp.arange(cfg.n_dec_layers))


def prefill(params: Params, frames: Array, tokens: Array, cfg: WhisperConfig,
            max_len: int) -> tuple[Array, Array, Params]:
    """Encode audio + prime decoder cache. Returns (logits, enc_out, caches)."""
    enc = encode(params, frames, cfg)
    caches = init_dec_caches(cfg, tokens.shape[0], max_len)
    h, caches = decode(params, tokens, enc, cfg, caches=caches)
    logits = h[:, -1:] @ params["tok_embed"].T.astype(h.dtype)
    return logits, enc, caches


def decode_step(params: Params, token: Array, enc_out: Array, caches: Params,
                cfg: WhisperConfig) -> tuple[Array, Params]:
    pos = caches["pos"][0]
    h, caches = decode(params, token, enc_out, cfg, caches=caches,
                       positions=pos[None, None])
    logits = h @ params["tok_embed"].T.astype(h.dtype)
    return logits, caches
