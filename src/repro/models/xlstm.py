"""xLSTM: mLSTM (matrix-memory) and sLSTM (scalar-memory) blocks.

mLSTM has two equivalent forms which we both implement and cross-test:
  - parallel (training): stabilized gated-linear-attention quadratic form,
  - recurrent (decoding): O(1)-state update.

The recurrent state is the model's "KV cache" analogue: it does not grow
with sequence length, which is why xlstm runs the ``long_500k`` shape.

Block layout follows the xLSTM paper in simplified form: pre-LN, up-proj,
causal conv(4) + SiLU on the q/k path, cell, group-norm, output gate,
down-proj, residual.  sLSTM blocks are placed every ``slstm_every`` layers
(ratio ~7:1 in the paper's xLSTM[7:1]).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import Params, Array


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    slstm_every: int = 6          # layer i is sLSTM iff i % slstm_every == slstm_every-1
    conv_width: int = 4
    proj_factor: float = 2.0      # mLSTM up-projection factor
    norm_eps: float = 1e-6
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    tied_embeddings: bool = True

    @property
    def d_inner(self) -> int:
        return int(self.proj_factor * self.d_model)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads

    def is_slstm(self, layer: int) -> bool:
        return self.slstm_every > 0 and layer % self.slstm_every == self.slstm_every - 1

    def param_count(self) -> int:
        """Rough analytic parameter count (mLSTM-block dominated)."""
        d, di = self.d_model, self.d_inner
        per_block = 2 * d * di + di * d + 3 * di * di + 2 * di * self.n_heads
        return self.vocab * d + self.n_layers * per_block


# --------------------------------------------------------------------------
# mLSTM cell
# --------------------------------------------------------------------------

def mlstm_parallel(q: Array, k: Array, v: Array, i_pre: Array, f_pre: Array
                   ) -> Array:
    """Stabilized parallel form.

    q,k,v: (B,S,H,Dh); i_pre,f_pre: (B,S,H) pre-activations.
    Returns h: (B,S,H,Dh).
    """
    B, S, H, Dh = q.shape
    q = q.astype(jnp.float32) / math.sqrt(Dh)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))        # (B,S,H)
    F = jnp.cumsum(log_f, axis=1)                                 # (B,S,H)
    # log D[t,s] = F[t] - F[s] + i[s], masked to s <= t
    logD = (F[:, :, None, :] - F[:, None, :, :]
            + i_pre.astype(jnp.float32)[:, None, :, :])           # (B,t,s,H)
    mask = jnp.tril(jnp.ones((S, S), bool))
    logD = jnp.where(mask[None, :, :, None], logD, -jnp.inf)
    m = jnp.max(logD, axis=2)                                     # (B,t,H)
    D = jnp.exp(logD - m[:, :, None, :])                          # (B,t,s,H)
    scores = jnp.einsum("bthd,bshd->btsh", q, k) * D
    n = jnp.maximum(jnp.abs(jnp.sum(scores, axis=2)), jnp.exp(-m))  # (B,t,H)
    h = jnp.einsum("btsh,bshd->bthd", scores, v) / n[..., None]
    return h.astype(v.dtype)


def mlstm_recurrent(state: Params, q: Array, k: Array, v: Array,
                    i_pre: Array, f_pre: Array) -> tuple[Array, Params]:
    """One step. q,k,v: (B,H,Dh); i_pre,f_pre: (B,H).
    state: {"C": (B,H,Dh,Dh), "n": (B,H,Dh), "m": (B,H)}."""
    Dh = q.shape[-1]
    q = q.astype(jnp.float32) / math.sqrt(Dh)
    k = k.astype(jnp.float32); v = v.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    i_ = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(log_f + state["m"], i_)
    a = jnp.exp(log_f + state["m"] - m_new)[..., None]            # (B,H,1)
    b = jnp.exp(i_ - m_new)[..., None]
    C = state["C"] * a[..., None] + b[..., None] * (v[..., :, None] * k[..., None, :])
    n = state["n"] * a + b * k
    num = jnp.einsum("bhvd,bhd->bhv", C, q)                        # (B,H,Dh)
    den = jnp.maximum(jnp.abs(jnp.sum(n * q, -1)), jnp.exp(-m_new))
    h = num / den[..., None]
    return h.astype(v.dtype), {"C": C, "n": n, "m": m_new}


def init_mlstm_state(batch: int, H: int, Dh: int, dtype=jnp.float32) -> Params:
    return {
        "C": jnp.zeros((batch, H, Dh, Dh), jnp.float32),
        "n": jnp.zeros((batch, H, Dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


# --------------------------------------------------------------------------
# sLSTM cell (per-head vector memories, recurrent h feedback)
# --------------------------------------------------------------------------

def slstm_scan(p: Params, x: Array, state: Params) -> tuple[Array, Params]:
    """x: (B,S,Di). Sequential scan over time.  Gates take x_t and h_{t-1}.
    state: {"h","c","n","m"} each (B,Di)."""

    def step(st, xt):
        zi = xt @ p["wz"] + st["h"] @ p["rz"]
        ii = xt @ p["wi"] + st["h"] @ p["ri"]
        ff = xt @ p["wf"] + st["h"] @ p["rf"]
        oo = xt @ p["wo"] + st["h"] @ p["ro"]
        z = jnp.tanh(zi)
        log_f = jax.nn.log_sigmoid(ff)
        m_new = jnp.maximum(log_f + st["m"], ii)
        i_s = jnp.exp(ii - m_new)
        f_s = jnp.exp(log_f + st["m"] - m_new)
        c = f_s * st["c"] + i_s * z
        n = jnp.maximum(f_s * st["n"] + i_s, 1e-6)
        h = jax.nn.sigmoid(oo) * (c / n)
        return {"h": h, "c": c, "n": n, "m": m_new}, h

    xs = jnp.swapaxes(x.astype(jnp.float32), 0, 1)    # (S,B,Di)
    state, hs = jax.lax.scan(step, state, xs)
    return jnp.swapaxes(hs, 0, 1).astype(x.dtype), state


def init_slstm_state(batch: int, d_inner: int) -> Params:
    z = jnp.zeros((batch, d_inner), jnp.float32)
    return {"h": z, "c": z, "n": z + 1e-6, "m": jnp.full((batch, d_inner), -1e30)}


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------

def _init_conv(key, width: int, channels: int, dtype) -> Array:
    return (jax.random.normal(key, (width, channels)) / math.sqrt(width)).astype(dtype)


def causal_conv(x: Array, w: Array, state: Array | None = None
                ) -> tuple[Array, Array | None]:
    """Depthwise causal conv. x: (B,S,C), w: (W,C).
    With ``state`` (B,W-1,C) performs streaming (decode) convolution."""
    W = w.shape[0]
    if state is None:
        pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
        new_state = None
    else:
        pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = pad[:, -(W - 1):]
    out = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return out, new_state


def init_mlstm_block(key, cfg: XLSTMConfig) -> Params:
    ks = jax.random.split(key, 8)
    d, di, pd = cfg.d_model, cfg.d_inner, cfg.param_dtype
    H, Dh = cfg.n_heads, cfg.head_dim
    return {
        "ln": jnp.ones((d,), pd),
        "w_up": L.dense_init(ks[0], d, 2 * di, pd),
        "conv": _init_conv(ks[1], cfg.conv_width, di, pd),
        "wq": L.dense_init(ks[2], di, di, pd),
        "wk": L.dense_init(ks[3], di, di, pd),
        "wv": L.dense_init(ks[4], di, di, pd),
        "w_if": L.dense_init(ks[5], di, 2 * H, pd),
        "gn": jnp.ones((di,), pd),
        "w_down": L.dense_init(ks[6], di, d, pd),
    }


def apply_mlstm_block(p: Params, x: Array, cfg: XLSTMConfig, *,
                      state: Params | None = None) -> tuple[Array, Params | None]:
    B, S, d = x.shape
    H, Dh, di = cfg.n_heads, cfg.head_dim, cfg.d_inner
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    up = h @ p["w_up"]
    a, z = up[..., :di], up[..., di:]
    conv_state = state["conv"] if state is not None else None
    c, new_conv = causal_conv(a, p["conv"], conv_state)
    c = jax.nn.silu(c)
    q = (c @ p["wq"]).reshape(B, S, H, Dh)
    k = (c @ p["wk"]).reshape(B, S, H, Dh)
    v = (a @ p["wv"]).reshape(B, S, H, Dh)
    gates = c @ p["w_if"]
    i_pre, f_pre = gates[..., :H], gates[..., H:]
    if state is None:
        out = mlstm_parallel(q, k, v, i_pre, f_pre)
        new_state = None
    else:
        out, cell = mlstm_recurrent(state["cell"], q[:, 0], k[:, 0], v[:, 0],
                                    i_pre[:, 0], f_pre[:, 0])
        out = out[:, None]
        new_state = {"cell": cell, "conv": new_conv}
    out = out.reshape(B, S, di)
    out = L.rms_norm(out, p["gn"], cfg.norm_eps)       # per-channel group norm
    out = out * jax.nn.silu(z)
    return x + out @ p["w_down"], new_state


def init_slstm_block(key, cfg: XLSTMConfig) -> Params:
    ks = jax.random.split(key, 11)
    d, di, pd = cfg.d_model, cfg.d_inner, cfg.param_dtype
    p = {"ln": jnp.ones((d,), pd),
         "w_up": L.dense_init(ks[0], d, di, pd),
         "conv": _init_conv(ks[1], cfg.conv_width, di, pd),
         "gn": jnp.ones((di,), pd),
         "w_down": L.dense_init(ks[2], di, d, pd)}
    for n, kk in zip(("wz", "wi", "wf", "wo"), ks[3:7]):
        p[n] = L.dense_init(kk, di, di, pd)
    for n, kk in zip(("rz", "ri", "rf", "ro"), ks[7:11]):
        p[n] = (jax.random.normal(kk, (di, di)) / math.sqrt(di) * 0.1).astype(pd)
    return p


def apply_slstm_block(p: Params, x: Array, cfg: XLSTMConfig, *,
                      state: Params | None = None) -> tuple[Array, Params | None]:
    B, S, d = x.shape
    di = cfg.d_inner
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    u = h @ p["w_up"]
    conv_state = state["conv"] if state is not None else None
    c, new_conv = causal_conv(u, p["conv"], conv_state)
    c = jax.nn.silu(c)
    cell_state = state["cell"] if state is not None else init_slstm_state(B, di)
    out, new_cell = slstm_scan(p, c, cell_state)
    out = L.rms_norm(out, p["gn"], cfg.norm_eps)
    new_state = ({"cell": new_cell, "conv": new_conv}
                 if state is not None else None)
    return x + out @ p["w_down"], new_state


# --------------------------------------------------------------------------
# Full model
# --------------------------------------------------------------------------

def init_xlstm(key, cfg: XLSTMConfig) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = []
    for i in range(cfg.n_layers):
        if cfg.is_slstm(i):
            blocks.append(init_slstm_block(keys[i], cfg))
        else:
            blocks.append(init_mlstm_block(keys[i], cfg))
    p: Params = {
        "embed": L.dense_init(keys[-2], cfg.vocab, cfg.d_model, cfg.param_dtype),
        "blocks": blocks,   # heterogeneous list (not stacked)
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tied_embeddings:
        p["head"] = L.dense_init(keys[-1], cfg.d_model, cfg.vocab, cfg.param_dtype)
    return p


def forward(params: Params, tokens: Array, cfg: XLSTMConfig, *,
            states: list | None = None) -> tuple[Array, list | None]:
    x = params["embed"][tokens].astype(cfg.dtype)
    new_states = [] if states is not None else None
    for i, bp in enumerate(params["blocks"]):
        st = states[i] if states is not None else None
        if cfg.is_slstm(i):
            x, ns = apply_slstm_block(bp, x, cfg, state=st)
        else:
            x, ns = apply_mlstm_block(bp, x, cfg, state=st)
        if new_states is not None:
            new_states.append(ns)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_states


def unembed(params: Params, x: Array, cfg: XLSTMConfig) -> Array:
    w = params["embed"].T if cfg.tied_embeddings else params["head"]
    return x @ w.astype(x.dtype)


def xlstm_loss(params: Params, batch: dict, cfg: XLSTMConfig) -> Array:
    h, _ = forward(params, batch["tokens"], cfg)
    logits = unembed(params, h[:, :-1], cfg)
    from repro.models.lm import softmax_xent
    return softmax_xent(logits, batch["tokens"][:, 1:])


def init_states(cfg: XLSTMConfig, batch: int) -> list:
    states = []
    for i in range(cfg.n_layers):
        conv = jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), cfg.dtype)
        if cfg.is_slstm(i):
            states.append({"cell": init_slstm_state(batch, cfg.d_inner),
                           "conv": conv})
        else:
            states.append({"cell": init_mlstm_state(batch, cfg.n_heads,
                                                    cfg.head_dim), "conv": conv})
    return states


def decode_step(params: Params, token: Array, states: list, cfg: XLSTMConfig
                ) -> tuple[Array, list]:
    h, states = forward(params, token, cfg, states=states)
    return unembed(params, h, cfg), states
