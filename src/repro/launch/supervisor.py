"""Multi-host training supervisor: the detect -> decide -> recover loop.

Spawns one worker subprocess per (simulated) host over
``launch/train.py``, then closes the loop the single-process driver
cannot: it *watches* the workers (file-based heartbeats + process exit
codes), *decides* what a signal means (missed heartbeat -> suspect;
persistent stall -> hung; nonzero exit -> host down; exit code
``EXIT_ESCALATE`` -> the GradGuard asked for a rollback), and
*recovers* (coordinated teardown, roll back to the last
verified-complete checkpoint, re-tune the plan on the surviving device
count via ``core.tuner.shrink_plan``, relaunch on the shrunk plan) —
under an exponential-backoff restart budget so a persistent failure
aborts instead of crash-looping.

Escalation matrix (what each signal triggers):

    NaN batch            -> GradGuard skips the update (worker-local)
    skip budget blown    -> worker exits 43 -> rollback, same plan
    missed heartbeat     -> 'heartbeat-miss' event, host marked suspect
    persistent stall     -> host hung: killed -> rollback + shrink
    worker exit != 0     -> host down:        rollback + shrink
    straggler (slow host) -> 'straggler' event (report, no action)
    restart budget blown -> abort

Every decision lands in ``<run-dir>/events.jsonl`` (one JSON object per
line: heartbeat-miss, hang, hostdown, escalate, anomaly, rollback,
shrink, restart, gen-live, done, abort); ``--status`` renders the log +
live heartbeats without touching the training processes.

This module is host-side control plane: pure Python, no jax at import —
it must run on a node whose accelerator runtime is wedged.

Usage:
    PYTHONPATH=src python -m repro.launch.supervisor \
        --run-dir /tmp/sup --hosts 2 --dp 2 --pp 2 --steps 40 \
        --faults hostdown@20:1
    PYTHONPATH=src python -m repro.launch.supervisor \
        --run-dir /tmp/sup --status
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time

from repro.checkpoint.store import latest_step
from repro.core.tuner import shrink_plan
from repro.runtime.resilience import (EXIT_ESCALATE, StragglerDetector,
                                      Watchdog, read_heartbeats)

EVENTS_FILE = "events.jsonl"


# ---------------------------------------------------------------------------
# Structured event log
# ---------------------------------------------------------------------------

class EventLog:
    """Append-only JSONL event stream (one self-contained object per
    line; a torn tail line — crashed writer — is skipped on read)."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def emit(self, kind: str, **fields) -> dict:
        doc = {"t": time.time(), "kind": kind, **fields}
        with open(self.path, "a") as f:
            f.write(json.dumps(doc) + "\n")
        detail = ", ".join(f"{k}={v}" for k, v in fields.items())
        print(f"[supervisor] {kind}" + (f" ({detail})" if detail else ""))
        sys.stdout.flush()
        return doc


def read_events(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return out


# ---------------------------------------------------------------------------
# Config / result
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SupervisorConfig:
    run_dir: str                    # events.jsonl, heartbeats, logs, results
    num_hosts: int = 2
    devices_per_host: int = 2
    steps: int = 40
    global_batch: int = 8
    arch: str = "uvit-nano"
    dp: int = 2
    pp: int = 2
    zero_stage: int = 0
    microbatches: int = 4
    wire_dtype: str = "float32"
    lr: float = 3e-4
    ckpt_dir: str | None = None     # default: <run_dir>/ckpt
    ckpt_every: int = 10
    keep: int = 3
    faults: str | None = None       # injected into generation 0 only
    relaunch_faults: str | None = None   # injected into every relaunch
    nan_skip_budget: int = 3
    escalation: str = "rollback"
    # watchdog / detection knobs
    poll: float = 0.2               # monitor poll interval (s)
    stall_timeout: float = 10.0     # s without step progress -> suspect
    startup_timeout: float = 300.0  # pre-first-train-step allowance
    miss_budget: int = 3            # suspect -> hung multiplier
    straggler_factor: float = 2.0
    straggler_patience: int = 3
    # recovery policy
    max_restarts: int = 3
    backoff_base: float = 1.0       # restart n sleeps base * 2**(n-1)
    commit_timeout: float = 60.0    # worker-side checkpoint barrier
    worker_env: dict = dataclasses.field(default_factory=dict)
    log_every: int = 10


@dataclasses.dataclass
class SupervisorResult:
    ok: bool
    outcome: str                    # done | abort
    generations: int                # launches performed (>= 1)
    restarts: int
    final_hosts: int
    final_plan: tuple               # (dp, pp, zero_stage)
    events_path: str
    losses: dict                    # merged step -> loss across generations


# ---------------------------------------------------------------------------
# Supervisor
# ---------------------------------------------------------------------------

class _Worker:
    def __init__(self, host_id: int, proc: subprocess.Popen, log: str,
                 out_json: str):
        self.host_id = host_id
        self.proc = proc
        self.log = log
        self.out_json = out_json


class Supervisor:
    def __init__(self, cfg: SupervisorConfig):
        self.cfg = cfg
        os.makedirs(cfg.run_dir, exist_ok=True)
        self.ckpt_dir = cfg.ckpt_dir or os.path.join(cfg.run_dir, "ckpt")
        self.hb_dir = os.path.join(cfg.run_dir, "hb")
        self.log_dir = os.path.join(cfg.run_dir, "logs")
        os.makedirs(self.log_dir, exist_ok=True)
        self.events = EventLog(os.path.join(cfg.run_dir, EVENTS_FILE))

    # ---- launch ------------------------------------------------------

    def _worker_cmd(self, host_id: int, num_hosts: int, plan, gen: int,
                    faults: str | None, out_json: str) -> list[str]:
        dp, pp, zero = plan
        cmd = [sys.executable, "-m", "repro.launch.train",
               "--arch", self.cfg.arch, "--pipeline",
               "--steps", str(self.cfg.steps),
               "--global-batch", str(self.cfg.global_batch),
               "--lr", str(self.cfg.lr),
               "--devices", str(dp * pp), "--dp", str(dp), "--pp", str(pp),
               "--zero-stage", str(zero),
               "--microbatches", str(self.cfg.microbatches),
               "--wire-dtype", self.cfg.wire_dtype,
               "--ckpt-dir", self.ckpt_dir,
               "--ckpt-every", str(self.cfg.ckpt_every),
               "--keep", str(self.cfg.keep), "--resume",
               "--host-id", str(host_id), "--num-hosts", str(num_hosts),
               "--heartbeat-dir", self.hb_dir, "--gen", str(gen),
               "--commit-timeout", str(self.cfg.commit_timeout),
               "--nan-skip-budget", str(self.cfg.nan_skip_budget),
               "--escalation", self.cfg.escalation,
               "--log-every", str(self.cfg.log_every),
               "--out-json", out_json]
        if faults:
            cmd += ["--faults", faults]
        return cmd

    def _worker_env(self, plan) -> dict:
        dp, pp, _ = plan
        env = dict(os.environ)
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH")) \
            + env.get("PYTHONPATH", "")
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                            f"{dp * pp}")
        env.pop("REPRO_FAULTS", None)   # faults go through the CLI only
        env.update(self.cfg.worker_env)
        return env

    def _launch(self, num_hosts: int, plan, gen: int,
                faults: str | None) -> list[_Worker]:
        workers = []
        for h in range(num_hosts):
            log = os.path.join(self.log_dir, f"worker_h{h}.g{gen}.log")
            out = os.path.join(self.log_dir, f"result_h{h}.g{gen}.json")
            cmd = self._worker_cmd(h, num_hosts, plan, gen, faults, out)
            with open(log, "w") as lf:
                proc = subprocess.Popen(cmd, env=self._worker_env(plan),
                                        stdout=lf, stderr=subprocess.STDOUT)
            workers.append(_Worker(h, proc, log, out))
        self.events.emit("launch", gen=gen, hosts=num_hosts,
                         plan={"dp": plan[0], "pp": plan[1],
                               "zero_stage": plan[2]},
                         faults=faults or "")
        return workers

    def _teardown(self, workers: list[_Worker]) -> None:
        for w in workers:
            if w.proc.poll() is None:
                w.proc.terminate()
        deadline = time.time() + 5.0
        for w in workers:
            if w.proc.poll() is None:
                try:
                    w.proc.wait(timeout=max(deadline - time.time(), 0.1))
                except subprocess.TimeoutExpired:
                    w.proc.kill()
                    w.proc.wait()

    # ---- monitor -----------------------------------------------------

    def _monitor(self, workers: list[_Worker], gen: int
                 ) -> tuple[str, list[int]]:
        """Watch one generation until it finishes or fails.

        Returns ``(outcome, hosts)``: ``("done", [])``, ``("escalate",
        [h])`` (rollback, same plan), or ``("hostdown", dead_hosts)``
        (rollback + shrink; includes hung hosts the supervisor killed).
        """
        cfg = self.cfg
        hosts = [w.host_id for w in workers]
        dog = Watchdog(hosts, stall_timeout=cfg.stall_timeout,
                       startup_timeout=cfg.startup_timeout,
                       miss_budget=cfg.miss_budget)
        straggle = StragglerDetector(factor=cfg.straggler_factor,
                                     patience=cfg.straggler_patience)
        verdicts = {h: "ok" for h in hosts}
        flagged: set[int] = set()
        anomalous: set[tuple[int, int]] = set()
        live = True
        while True:
            time.sleep(cfg.poll)
            beats = read_heartbeats(self.hb_dir, gen=gen)
            dog.observe(beats)
            straggle.observe(beats)

            if live and beats and all(
                    beats[h].phase in ("train", "ckpt", "done")
                    for h in hosts if h in beats) \
                    and all(h in beats for h in hosts):
                self.events.emit("gen-live", gen=gen, hosts=len(hosts))
                live = False

            for h, hb in beats.items():
                key = (h, hb.step)
                bad_loss = hb.loss is not None and not _finite(hb.loss)
                bad_norm = (hb.grad_norm is not None
                            and not _finite(hb.grad_norm))
                if (bad_loss or bad_norm) and key not in anomalous:
                    anomalous.add(key)
                    self.events.emit("anomaly", gen=gen, host=h,
                                     step=hb.step, loss=hb.loss,
                                     grad_norm=hb.grad_norm)

            # process exits take precedence over heartbeat inference
            dead, escalated, running = [], [], []
            for w in workers:
                rc = w.proc.poll()
                if rc is None:
                    running.append(w)
                elif rc == EXIT_ESCALATE:
                    escalated.append(w.host_id)
                elif rc != 0:
                    dead.append(w.host_id)
            if escalated:
                self.events.emit("escalate", gen=gen, hosts=escalated)
                return "escalate", escalated
            if dead:
                for h in dead:
                    self.events.emit("hostdown", gen=gen, host=h,
                                     rc=next(w.proc.returncode
                                             for w in workers
                                             if w.host_id == h))
                return "hostdown", dead
            if not running:
                return "done", []

            checks = dog.check()
            hung = []
            for h in hosts:
                v = checks[h]
                if v != verdicts[h]:
                    if v == "suspect":
                        self.events.emit("heartbeat-miss", gen=gen, host=h,
                                         age=round(dog.age(h), 2))
                    verdicts[h] = v
                if v == "hung" and any(w.host_id == h
                                       and w.proc.poll() is None
                                       for w in workers):
                    hung.append(h)
            if hung:
                # one hung host wedges its peers (stuck collectives, the
                # checkpoint commit barrier), so several hosts stall at
                # once: attribute the hang to the ROOT cause — the hung
                # host(s) with the least step progress — and count the
                # rest as survivors for the shrink
                low = min(dog.progress(h)[1] for h in hung)
                roots = [h for h in hung if dog.progress(h)[1] == low]
                for h in roots:
                    self.events.emit("hang", gen=gen, host=h,
                                     age=round(dog.age(h), 2),
                                     step=dog.progress(h)[1])
                return "hostdown", roots

            for h, ratio in straggle.stragglers().items():
                if h not in flagged:
                    flagged.add(h)
                    self.events.emit("straggler", gen=gen, host=h,
                                     ratio=round(ratio, 2))

    # ---- recover -----------------------------------------------------

    def run(self) -> SupervisorResult:
        cfg = self.cfg
        num_hosts = cfg.num_hosts
        plan = (cfg.dp, cfg.pp, cfg.zero_stage)
        losses: dict[int, float] = {}
        gen, restarts = 0, 0
        faults = cfg.faults
        while True:
            workers = self._launch(num_hosts, plan, gen, faults)
            outcome, bad = self._monitor(workers, gen)
            self._teardown(workers)
            self._collect_losses(workers, losses)
            if outcome == "done":
                self.events.emit("done", gen=gen,
                                 steps=cfg.steps, hosts=num_hosts)
                return SupervisorResult(
                    True, "done", gen + 1, restarts, num_hosts, plan,
                    self.events.path, losses)

            restarts += 1
            if restarts > cfg.max_restarts:
                self.events.emit("abort", gen=gen, restarts=restarts - 1,
                                 reason="restart budget exhausted")
                return SupervisorResult(
                    False, "abort", gen + 1, restarts - 1, num_hosts, plan,
                    self.events.path, losses)

            step = latest_step(self.ckpt_dir)
            self.events.emit("rollback", gen=gen, step=step,
                             reason=outcome)
            if outcome == "hostdown":
                survivors = num_hosts - len(bad)
                if survivors < 1:
                    self.events.emit("abort", gen=gen, restarts=restarts,
                                     reason="no surviving hosts")
                    return SupervisorResult(
                        False, "abort", gen + 1, restarts, 0, plan,
                        self.events.path, losses)
                new_plan = shrink_plan(
                    survivors * cfg.devices_per_host, dp=plan[0],
                    pp=plan[1], zero_stage=plan[2])
                self.events.emit(
                    "shrink", gen=gen, hosts=survivors, lost=bad,
                    plan={"dp": new_plan[0], "pp": new_plan[1],
                          "zero_stage": new_plan[2]})
                num_hosts, plan = survivors, new_plan

            delay = cfg.backoff_base * (2 ** (restarts - 1))
            self.events.emit("restart", gen=gen + 1, attempt=restarts,
                             budget=cfg.max_restarts,
                             backoff_s=round(delay, 2))
            time.sleep(delay)
            gen += 1
            faults = cfg.relaunch_faults

    def _collect_losses(self, workers: list[_Worker],
                        losses: dict[int, float]) -> None:
        """Merge a generation's step->loss map (workers are SPMD replicas
        of the same computation, so any one host's trajectory is THE
        trajectory; post-rollback steps overwrite their first attempt)."""
        for w in workers:
            try:
                with open(w.out_json) as f:
                    doc = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            for k, v in doc.get("losses", {}).items():
                losses[int(k)] = v


def _finite(x: float) -> bool:
    return x == x and abs(x) != float("inf")


# ---------------------------------------------------------------------------
# Status reader
# ---------------------------------------------------------------------------

def format_status(run_dir: str, *, tail: int = 12) -> str:
    """Render a run's event log + live heartbeats (read-only)."""
    events = read_events(os.path.join(run_dir, EVENTS_FILE))
    lines = [f"supervisor run: {run_dir}"]
    if not events:
        return lines[0] + "\n  (no events yet)"
    t0 = events[0]["t"]
    counts: dict[str, int] = {}
    for e in events:
        counts[e["kind"]] = counts.get(e["kind"], 0) + 1
    lines.append("  events: " + ", ".join(
        f"{k} x{n}" for k, n in sorted(counts.items())))
    for e in events[-tail:]:
        extra = {k: v for k, v in e.items() if k not in ("t", "kind")}
        detail = ", ".join(f"{k}={v}" for k, v in extra.items())
        lines.append(f"  +{e['t'] - t0:8.2f}s  {e['kind']:<15}"
                     + (f" {detail}" if detail else ""))
    beats = read_heartbeats(os.path.join(run_dir, "hb"))
    if beats:
        now = time.time()
        lines.append("  heartbeats:")
        for h in sorted(beats):
            hb = beats[h]
            loss = f" loss={hb.loss:.4f}" if hb.loss is not None else ""
            lines.append(
                f"    host {h}: gen {hb.gen} {hb.phase} step {hb.step}"
                f"{loss} ({now - hb.t:.1f}s ago, pid {hb.pid})")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-dir", required=True,
                    help="supervisor state root (events.jsonl, heartbeats, "
                         "worker logs, checkpoints)")
    ap.add_argument("--status", action="store_true",
                    help="print the run's event log + heartbeats and exit")
    ap.add_argument("--hosts", type=int, default=2)
    ap.add_argument("--devices-per-host", type=int, default=2)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--arch", default="uvit-nano")
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--pp", type=int, default=2)
    ap.add_argument("--zero-stage", type=int, default=0, choices=(0, 1, 2))
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--wire-dtype", default="float32")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--faults", default=None,
                    help="fault plan injected into generation 0 (e.g. "
                         "'hostdown@20:1' or 'hang@15')")
    ap.add_argument("--relaunch-faults", default=None,
                    help="fault plan injected into every relaunch "
                         "(e.g. 'iofail@0:2' to stress rollback)")
    ap.add_argument("--escalation", default="rollback",
                    choices=("abort", "rollback"))
    ap.add_argument("--nan-skip-budget", type=int, default=3)
    ap.add_argument("--stall-timeout", type=float, default=10.0)
    ap.add_argument("--startup-timeout", type=float, default=300.0)
    ap.add_argument("--miss-budget", type=int, default=3)
    ap.add_argument("--poll", type=float, default=0.2)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--backoff-base", type=float, default=1.0)
    ap.add_argument("--straggler-factor", type=float, default=2.0)
    ap.add_argument("--straggler-patience", type=int, default=3)
    ap.add_argument("--commit-timeout", type=float, default=60.0)
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse_args(argv)
    if args.status:
        print(format_status(args.run_dir))
        return 0
    cfg = SupervisorConfig(
        run_dir=args.run_dir, num_hosts=args.hosts,
        devices_per_host=args.devices_per_host, steps=args.steps,
        global_batch=args.global_batch, arch=args.arch, dp=args.dp,
        pp=args.pp, zero_stage=args.zero_stage,
        microbatches=args.microbatches, wire_dtype=args.wire_dtype,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        faults=args.faults, relaunch_faults=args.relaunch_faults,
        escalation=args.escalation, nan_skip_budget=args.nan_skip_budget,
        stall_timeout=args.stall_timeout,
        startup_timeout=args.startup_timeout, miss_budget=args.miss_budget,
        poll=args.poll, max_restarts=args.max_restarts,
        backoff_base=args.backoff_base,
        straggler_factor=args.straggler_factor,
        straggler_patience=args.straggler_patience,
        commit_timeout=args.commit_timeout)
    res = Supervisor(cfg).run()
    print(f"[supervisor] {res.outcome}: {res.generations} generation(s), "
          f"{res.restarts} restart(s), final plan dp={res.final_plan[0]} "
          f"pp={res.final_plan[1]} zero={res.final_plan[2]} on "
          f"{res.final_hosts} host(s)")
    return 0 if res.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
