"""Batched serving driver: prefill a prompt batch, decode greedily.

CPU-runnable on the smoke configs; the same step builders drive the
production TP/EP serving cells in the dry-run.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --batch 4 --prompt-len 16 --gen 32
"""
import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    from repro.configs.smoke import SMOKE_FACTORIES

    # Serve the smoke variant of the requested arch (CPU-runnable); the
    # arch families share decode implementations with the full configs.
    if args.arch not in SMOKE_FACTORIES:
        raise SystemExit(f"unknown arch {args.arch}")
    name = args.arch
    key = jax.random.PRNGKey(0)

    # build the family-appropriate decode path via the smoke config's family
    factory = SMOKE_FACTORIES[name]
    loss_fn, init_fn, make_batch, cfg = factory()
    params = init_fn(key)
    proto = make_batch(key)
    if "tokens" not in proto:
        raise SystemExit(f"{name} is not a token-serving arch")
    vocab = 256
    max_len = args.prompt_len + args.gen

    # All LM-family smokes route through repro.models.lm; recurrent archs
    # have their own states.
    import repro.models.lm as lm_mod
    import repro.models.xlstm as xm
    import repro.models.mamba as zm

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, vocab)
    t0 = time.time()
    if isinstance(cfg, lm_mod.LMConfig):
        logits, caches = lm_mod.prefill(params, prompts, cfg, max_len)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        step = jax.jit(lambda p, t, c: lm_mod.decode_step(p, t, c, cfg))
        outs = [tok]
        for _ in range(args.gen - 1):
            logits, caches = step(params, tok, caches)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs.append(tok)
    elif isinstance(cfg, xm.XLSTMConfig):
        states = xm.init_states(cfg, args.batch)
        step = jax.jit(lambda p, t, s: xm.decode_step(p, t, s, cfg))
        tok = prompts[:, :1]
        outs = []
        for i in range(args.prompt_len - 1):
            _, states = step(params, prompts[:, i:i + 1], states)
        for _ in range(args.gen):
            logits, states = step(params, tok, states)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs.append(tok)
    elif isinstance(cfg, zm.Zamba2Config):
        states = zm.init_states(cfg, args.batch, max_len)
        step = jax.jit(lambda p, t, s: zm.decode_step(p, t, s, cfg))
        tok = prompts[:, :1]
        outs = []
        for i in range(args.prompt_len - 1):
            _, states = step(params, prompts[:, i:i + 1], states)
        for _ in range(args.gen):
            logits, states = step(params, tok, states)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs.append(tok)
    else:
        raise SystemExit(f"{name}: serving not wired for this family")
    gen = jnp.concatenate(outs, axis=1)
    dt = time.time() - t0
    print(f"[serve] {name}: batch={args.batch} generated {gen.shape[1]} "
          f"tokens/seq in {dt:.2f}s "
          f"({args.batch * gen.shape[1] / dt:.1f} tok/s)")
    print("[serve] sample:", gen[0, :16].tolist())
    return gen


if __name__ == "__main__":
    main()
