"""Production mesh construction + the multi-host topology layer.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  The single-pod mesh
is 16x16 = 256 chips ('data' x 'model'); the multi-pod mesh is 2x16x16 =
512 chips with a leading 'pod' axis (DCN-connected pods; 'pod' carries only
data parallelism / ZeRO sharding — no model collectives cross pods).

Multi-host wiring (used by ``launch/supervisor.py`` + ``launch/train.py``):

- :class:`HostTopology` maps global device ids to host ranks (contiguous
  slices, the standard pod layout), gives each host its ring neighbours,
  and — given a partition's stage->device map — names the pipeline ring
  hops that cross host boundaries (the links a dead host severs, which
  is why one stalled collective silences the whole ring).
- :class:`FileBarrier` is a shared-filesystem rendezvous for worker
  processes (each participant atomically drops a marker file and waits
  for the full set): workers use it to enter the step loop together, and
  the checkpoint layer's ``wait_step_complete`` plays the same role on
  step commit with the shard files themselves as the markers.

Everything here is host-side control plane — pure Python/numpy, no jax
at import (the supervisor must stay importable on a node whose
accelerator runtime is wedged).
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    try:
        return jax.make_mesh(shape, axes)
    except ValueError:
        # host has more placeholder devices than the mesh needs (e.g. 512
        # forced devices, single-pod 256-chip mesh): build from a prefix.
        from jax.sharding import Mesh
        devs = np.array(jax.devices()[:n]).reshape(shape)
        return Mesh(devs, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_size(mesh, batch_axes=("pod", "data")) -> int:
    sizes = mesh_axis_sizes(mesh)
    out = 1
    for a in batch_axes:
        out *= sizes.get(a, 1)
    return out


# ---------------------------------------------------------------------------
# Multi-host topology
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HostTopology:
    """Host rank <-> device mapping for a multi-process launch.

    Devices are numbered globally and sliced contiguously per host (host
    ``h`` owns ``[h * devices_per_host, (h+1) * devices_per_host)``) —
    the standard TPU-pod process layout, and what the simulated workers
    reproduce with forced host-platform devices.
    """

    num_hosts: int
    devices_per_host: int

    def __post_init__(self):
        if self.num_hosts < 1 or self.devices_per_host < 1:
            raise ValueError(
                f"HostTopology needs num_hosts >= 1 and devices_per_host "
                f">= 1, got {self.num_hosts} x {self.devices_per_host}")

    @property
    def num_devices(self) -> int:
        return self.num_hosts * self.devices_per_host

    def host_of_device(self, device: int) -> int:
        if not 0 <= device < self.num_devices:
            raise ValueError(f"device {device} outside the "
                             f"{self.num_devices}-device topology")
        return device // self.devices_per_host

    def host_devices(self, host: int) -> range:
        if not 0 <= host < self.num_hosts:
            raise ValueError(f"host {host} outside the "
                             f"{self.num_hosts}-host topology")
        lo = host * self.devices_per_host
        return range(lo, lo + self.devices_per_host)

    def ring_neighbors(self, host: int) -> tuple[int, int]:
        """(previous, next) host on the host-level ring."""
        if not 0 <= host < self.num_hosts:
            raise ValueError(f"host {host} outside the "
                             f"{self.num_hosts}-host topology")
        return ((host - 1) % self.num_hosts, (host + 1) % self.num_hosts)

    def cross_host_edges(self, stage_devices) -> list[tuple[int, int]]:
        """Host pairs exchanging pipeline boundary hops, from a
        partition's stage->device map (``Partition.devices``).

        Consecutive stages on devices owned by different hosts put their
        activation hop on the inter-host fabric; the unique (host_a,
        host_b) pairs — order preserved, first crossing first — are the
        links whose loss the supervisor attributes to a dead host.
        """
        edges: list[tuple[int, int]] = []
        seen: set[tuple[int, int]] = set()
        devs = [int(d) for d in stage_devices]
        for a, b in zip(devs, devs[1:]):
            ha, hb = self.host_of_device(a), self.host_of_device(b)
            if ha != hb and (ha, hb) not in seen:
                seen.add((ha, hb))
                edges.append((ha, hb))
        return edges

    def describe(self, stage_devices=None) -> str:
        lines = [f"hosts: {self.num_hosts} x {self.devices_per_host} "
                 f"devices = {self.num_devices}"]
        for h in range(self.num_hosts):
            prev, nxt = self.ring_neighbors(h)
            lines.append(f"  host {h}: devices "
                         f"{list(self.host_devices(h))}, ring prev={prev} "
                         f"next={nxt}")
        if stage_devices is not None:
            lines.append(f"  cross-host hops: "
                         f"{self.cross_host_edges(stage_devices) or 'none'}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# File-based rendezvous
# ---------------------------------------------------------------------------

class BarrierTimeout(TimeoutError):
    """A :class:`FileBarrier` participant gave up waiting — some host
    never arrived (dead, hung, or still compiling past the timeout)."""

    def __init__(self, name: str, missing: list[int], timeout: float):
        self.name = name
        self.missing = missing
        super().__init__(
            f"barrier {name!r}: host(s) {missing} did not arrive within "
            f"{timeout:.1f}s")


class FileBarrier:
    """Shared-filesystem rendezvous for worker processes.

    ``wait(name)`` atomically drops ``<dir>/<name>.h<rank>`` and blocks
    until all ``num_hosts`` marker files exist.  Names must be unique per
    rendezvous (callers append the step/generation); markers persist so
    late arrivals sail through — reuse a name only after ``reset``.
    """

    def __init__(self, directory: str, *, host_id: int, num_hosts: int):
        self.directory = directory
        self.host_id = host_id
        self.num_hosts = num_hosts
        os.makedirs(directory, exist_ok=True)

    def _marker(self, name: str, host: int) -> str:
        return os.path.join(self.directory, f"{name}.h{host:05d}")

    def wait(self, name: str, *, timeout: float = 120.0,
             poll: float = 0.05) -> None:
        tmp = self._marker(name, self.host_id) + f".tmp{os.getpid()}"
        with open(tmp, "w") as f:
            f.write(str(os.getpid()))
        os.replace(tmp, self._marker(name, self.host_id))
        deadline = time.time() + timeout
        while True:
            missing = [h for h in range(self.num_hosts)
                       if not os.path.exists(self._marker(name, h))]
            if not missing:
                return
            if time.time() > deadline:
                raise BarrierTimeout(name, missing, timeout)
            time.sleep(poll)

    def reset(self, name: str) -> None:
        for h in range(self.num_hosts):
            try:
                os.remove(self._marker(name, h))
            except OSError:
                pass
