"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  The single-pod mesh
is 16x16 = 256 chips ('data' x 'model'); the multi-pod mesh is 2x16x16 =
512 chips with a leading 'pod' axis (DCN-connected pods; 'pod' carries only
data parallelism / ZeRO sharding — no model collectives cross pods).
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    try:
        return jax.make_mesh(shape, axes)
    except ValueError:
        # host has more placeholder devices than the mesh needs (e.g. 512
        # forced devices, single-pod 256-chip mesh): build from a prefix.
        from jax.sharding import Mesh
        devs = np.array(jax.devices()[:n]).reshape(shape)
        return Mesh(devs, axes)


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_size(mesh, batch_axes=("pod", "data")) -> int:
    sizes = mesh_axis_sizes(mesh)
    out = 1
    for a in batch_axes:
        out *= sizes.get(a, 1)
    return out
