"""End-to-end training driver.

Runs a real training loop on the host (CPU here; the same code path drives
TPU pods — the mesh/shardings come from launch.mesh): synthetic-but-
learnable data, AdamW, periodic async checkpointing, exact resume, optional
pipeline-parallel execution over simulated devices.

Fault tolerance contract (exercised by examples/fault_tolerance.py):
- ``--simulate-failure K`` hard-kills the process at step K;
- rerunning with ``--resume`` restores the latest complete checkpoint and
  the stateless data pipeline regenerates the exact step stream, so the
  loss trajectory continues as if uninterrupted.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch uvit --steps 200
    PYTHONPATH=src python -m repro.launch.train --arch uvit --pipeline \
        --devices 8 --steps 50          # wave PP over 8 simulated devices
"""
import argparse
import os
import sys


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="uvit",
                    help="smoke arch key (see repro.configs.smoke) or "
                         "'uvit'/'hunyuan' for the pipeline path")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--pipeline", action="store_true",
                    help="wave pipeline over simulated devices")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--simulate-failure", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args(argv)


def main(argv=None):
    args = _parse_args(argv)
    if args.pipeline and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager, restore_checkpoint, \
        latest_step
    from repro.data import SyntheticLatentDataset, SyntheticTokenDataset, \
        ShardedLoader
    from repro.optim import AdamWConfig, adamw_init, adamw_update, \
        cosine_schedule

    opt_cfg = AdamWConfig(lr=args.lr)
    key = jax.random.PRNGKey(0)

    if args.pipeline:
        params, opt_state, step_fn, loader, pack = _build_pipeline_trainer(
            args, key, opt_cfg)
    else:
        params, opt_state, step_fn, loader, pack = _build_smoke_trainer(
            args, key, opt_cfg)

    start = 0
    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start = restore_checkpoint(
            args.ckpt_dir, (params, opt_state))
        print(f"[train] resumed from step {start}")

    if start >= args.steps:
        print(f"[train] nothing to do: resumed step {start} >= "
              f"--steps {args.steps}")
        return None

    import time
    t0 = time.time()
    for step in range(start, args.steps):
        batch = pack(loader.get(step))
        rng = jax.random.fold_in(key, step)
        lr = cosine_schedule(step, base_lr=args.lr, warmup=20,
                             total=args.steps)
        params, opt_state, loss = step_fn(params, opt_state, batch, rng, lr)
        if step % args.log_every == 0 or step == args.steps - 1:
            sps = (step - start + 1) * args.global_batch / (time.time() - t0)
            print(f"[train] step {step:5d} loss {float(loss):.4f} "
                  f"lr {float(lr):.2e} ({sps:.1f} samples/s)")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step + 1, (params, opt_state))
        if args.simulate_failure and step + 1 == args.simulate_failure:
            print("[train] simulating hard node failure (os._exit)")
            sys.stdout.flush()
            if mgr:
                mgr.wait()
            os._exit(42)
    if mgr:
        mgr.save_async(args.steps, (params, opt_state))
        mgr.wait()
    print(f"[train] done: final loss {float(loss):.4f}")
    return float(loss)


def _build_smoke_trainer(args, key, opt_cfg):
    import jax
    from repro.configs.smoke import SMOKE_FACTORIES
    from repro.optim import adamw_init, adamw_update
    from repro.data import SyntheticLatentDataset, SyntheticTokenDataset, \
        ShardedLoader

    name = args.arch if args.arch in SMOKE_FACTORIES else {
        "uvit": "uvit-h", "hunyuan": "hunyuan-dit"}.get(args.arch, args.arch)
    loss_fn, init_fn, make_batch, _cfg = SMOKE_FACTORIES[name]()
    params = init_fn(key)
    opt_state = adamw_init(params)
    proto = make_batch(key)
    if "latents" in proto:
        ds = SyntheticLatentDataset(
            img_size=proto["latents"].shape[1],
            channels=proto["latents"].shape[-1],
            n_classes=10,
            text_dim=(proto["text_embeds"].shape[-1]
                      if "text_embeds" in proto else 0),
            text_len=(proto["text_embeds"].shape[1]
                      if "text_embeds" in proto else 77))
    else:
        ds = SyntheticTokenDataset(vocab=256, seq_len=proto["tokens"].shape[1])
    loader = ShardedLoader(ds, global_batch=args.global_batch)

    def pack(raw):
        import jax.numpy as jnp
        out = {k: jnp.asarray(v) for k, v in raw.items()
               if k in proto or k == "labels"}
        if "frames" in proto:   # whisper: frames stub from latents? tokens ds
            out = {"frames": jax.random.normal(key, (args.global_batch,)
                                               + proto["frames"].shape[1:]),
                   "tokens": out["tokens"][:, :proto["tokens"].shape[1]]}
        return {k: v for k, v in out.items() if k in proto}

    @jax.jit
    def step_fn(params, opt_state, batch, rng, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, rng)
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg,
                                         lr=lr)
        return params, opt_state, loss

    return params, opt_state, step_fn, loader, pack


def _build_pipeline_trainer(args, key, opt_cfg):
    """Wave-PP trainer on simulated host devices via the PULSE compile path:
    graph -> partition -> schedule -> executor (runtime.compile)."""
    import jax
    import jax.numpy as jnp
    from repro.models.diffusion import UViTConfig, uvit_pipeline_graph
    from repro.runtime.compile import auto_pipeline
    from repro.runtime.adapters import (diffusion_model_fns,
                                        make_diffusion_microbatches)
    from repro.optim import adamw_init, adamw_update
    from repro.data import SyntheticLatentDataset, ShardedLoader

    D = args.devices // 2
    mesh = jax.make_mesh((2, D), ("data", "model"))
    cfg = UViTConfig("uvit-pp", img_size=8, in_ch=4, patch=2, d_model=64,
                     n_layers=2 * D, n_heads=4, d_ff=128, n_classes=10)
    M = args.microbatches
    graph = uvit_pipeline_graph(cfg, batch=args.global_batch // M)
    compiled = auto_pipeline(graph, diffusion_model_fns(cfg, "uvit"),
                             args.devices, pipeline_devices=D,
                             microbatches=M, dp_size=2)
    print("[train] " + compiled.describe().replace("\n", "\n[train] "))
    params = compiled.init_pipeline_params(key)
    opt_state = adamw_init(params)
    loss_of_mb = compiled.bind(mesh)

    ds = SyntheticLatentDataset(img_size=8, channels=4, n_classes=10)
    loader = ShardedLoader(ds, global_batch=args.global_batch)

    def pack(raw):
        return {k: jnp.asarray(v) for k, v in raw.items()}

    def loss_of(params, batch, rng):
        mb, aux = make_diffusion_microbatches(batch, rng, M, cfg, "uvit")
        return loss_of_mb(params, mb, aux)

    @jax.jit
    def step_fn(params, opt_state, batch, rng, lr):
        loss, grads = jax.value_and_grad(loss_of)(params, batch, rng)
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg,
                                         lr=lr)
        return params, opt_state, loss

    return params, opt_state, step_fn, loader, pack


if __name__ == "__main__":
    main()
