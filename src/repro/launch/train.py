"""End-to-end training driver: a thin loop over ``auto_pipeline`` +
``CheckpointManager`` + a fault plan.

Runs a real training loop on the host (CPU here; the same code path
drives TPU pods — the mesh/shardings come from launch.mesh):
synthetic-but-learnable data, AdamW, periodic async checkpointing with
verified manifests, exact resume, optional pipeline-parallel execution
over simulated devices with a (dp, pp) mesh and ZeRO sharding.

Fault-tolerance contract (exercised by examples/fault_tolerance.py and
tests/helpers/resilience_drill.py):

- ``--faults kill@K`` (or legacy ``--simulate-failure K``) hard-kills
  the process after step K; ``stop@K`` stops abruptly in-process;
  ``nan@K`` poisons a batch (the GradGuard skips the update);
  ``corrupt@K[:shard]`` / ``truncate@K[:shard]`` mutate the newest
  checkpoint shard; ``iofail@K:N`` makes the next N save attempts fail
  transiently (retry/backoff).  The same script parses from
  ``$REPRO_FAULTS``.
- Rerunning with ``--resume`` restores the newest *verified* checkpoint
  (corrupt/partial steps are skipped with a warning) and the stateless
  data pipeline regenerates the exact step stream, so the loss
  trajectory continues as if uninterrupted.
- Resume may use a DIFFERENT plan (``--pp``/``--dp``/``--zero-stage``/
  ``--interleave``): restore de-stacks the saved stage stacks through
  the manifest's recorded plan spec and re-stacks onto the new plan
  (runtime.resilience) — a P=4 run killed mid-epoch resumes as
  P=2 x dp=2 ZeRO-2 with an identical loss trajectory.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch uvit --steps 200
    PYTHONPATH=src python -m repro.launch.train --arch uvit --pipeline \
        --devices 8 --steps 50          # wave PP over 8 simulated devices
"""
import argparse
import dataclasses
import json
import os
from typing import Any


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="uvit",
                    help="smoke arch key (see repro.configs.smoke) or "
                         "'uvit'/'skipvit' for the pipeline path")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3,
                    help="checkpoint retention (verified-complete steps)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--pipeline", action="store_true",
                    help="wave pipeline over simulated devices")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--dp", type=int, default=2,
                    help="data-parallel degree of the (data, model) mesh")
    ap.add_argument("--pp", type=int, default=None,
                    help="pipeline degree (default: devices // dp)")
    ap.add_argument("--zero-stage", type=int, default=0, choices=(0, 1, 2))
    ap.add_argument("--interleave", type=int, default=None,
                    help="virtual stage slots per device (V)")
    ap.add_argument("--wire-dtype", default="bfloat16",
                    help="boundary-hop dtype; float32 = exact wire")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--faults", default=None,
                    help="fault plan, e.g. 'kill@60,corrupt@80:shard_00000,"
                         "nan@10,iofail@20:2' (default: $REPRO_FAULTS)")
    ap.add_argument("--nan-skip-budget", type=int, default=3,
                    help="max consecutive non-finite steps before abort")
    ap.add_argument("--simulate-failure", type=int, default=0,
                    help="legacy alias for --faults kill@K")
    ap.add_argument("--out-json", default=None,
                    help="write the step->loss trajectory + resume "
                         "metadata here on exit")
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args(argv)


@dataclasses.dataclass
class TrainResult:
    """What one driver invocation did (consumed by drills and examples)."""
    final_loss: float | None
    losses: dict                    # step -> float (host)
    start: int                      # first step this invocation ran
    resumed: Any = None             # RestoreInfo | None
    logical_params: Any = None      # model-space params (plan-independent)
    skipped_steps: int = 0          # non-finite updates the guard skipped


def main(argv=None):
    res = run(_parse_args(argv))
    return res.final_loss


def run(args) -> TrainResult:
    from repro.runtime.resilience import FaultPlan, GradGuard, \
        restore_training_state

    faults = FaultPlan.parse(args.faults)
    if args.simulate_failure:
        faults = faults.with_kill(args.simulate_failure)
    if args.pipeline and "XLA_FLAGS" not in os.environ:
        need = max(args.devices,
                   args.dp * (args.pp or max(args.devices // args.dp, 1)))
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={need}")

    import jax

    from repro.checkpoint import CheckpointManager, latest_step, \
        restore_checkpoint
    from repro.optim import AdamWConfig, cosine_schedule

    opt_cfg = AdamWConfig(lr=args.lr)
    key = jax.random.PRNGKey(0)

    if args.pipeline:
        params, opt_state, step_fn, loader, pack, compiled = \
            _build_pipeline_trainer(args, key, opt_cfg)
    else:
        params, opt_state, step_fn, loader, pack = _build_smoke_trainer(
            args, key, opt_cfg)
        compiled = None

    mgr = CheckpointManager(
        args.ckpt_dir, keep=args.keep,
        plan=compiled.state_spec() if compiled is not None else None,
        io_fault=faults.io_fault) if args.ckpt_dir else None

    start, resumed = 0, None
    if args.resume and args.ckpt_dir \
            and latest_step(args.ckpt_dir) is not None:
        state = {"params": params, "opt": opt_state}
        if compiled is not None:
            state, info = restore_training_state(
                args.ckpt_dir, compiled, state, strict=False)
            start, resumed = info.step, info
            print(f"[train] resumed from step {info.step}"
                  + (" (elastic restore: plan changed)" if info.elastic
                     else ""))
        else:
            state, start = restore_checkpoint(args.ckpt_dir, state,
                                              strict=False)
            print(f"[train] resumed from step {start}")
        params, opt_state = state["params"], state["opt"]

    guard = GradGuard(budget=args.nan_skip_budget)
    losses: dict[int, float] = {}

    def finish(loss) -> TrainResult:
        logical = None
        if compiled is not None:
            logical = jax.device_get(compiled.merge_params(*params))
        res = TrainResult(
            final_loss=None if loss is None else float(loss),
            losses=losses, start=start, resumed=resumed,
            logical_params=logical, skipped_steps=guard.skipped_total)
        if args.out_json:
            doc = {"final_loss": res.final_loss,
                   "losses": {str(k): v for k, v in losses.items()},
                   "start": start,
                   "resumed_step": resumed.step if resumed else None,
                   "elastic": bool(resumed.elastic) if resumed else False,
                   "skipped_steps": res.skipped_steps}
            with open(args.out_json, "w") as f:
                json.dump(doc, f)
        return res

    if start >= args.steps:
        print(f"[train] nothing to do: resumed step {start} >= "
              f"--steps {args.steps}")
        return finish(None)

    import time
    t0 = time.time()
    loss = None
    for step in range(start, args.steps):
        batch = faults.poison_batch(pack(loader.get(step)), step)
        rng = jax.random.fold_in(key, step)
        lr = cosine_schedule(step, base_lr=args.lr, warmup=20,
                             total=args.steps)
        params, opt_state, loss, finite = step_fn(params, opt_state, batch,
                                                  rng, lr)
        guard.observe(bool(finite), step)
        losses[step] = float(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            sps = (step - start + 1) * args.global_batch / (time.time() - t0)
            print(f"[train] step {step:5d} loss {float(loss):.4f} "
                  f"lr {float(lr):.2e} ({sps:.1f} samples/s)")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step + 1, {"params": params, "opt": opt_state})
        if faults.post_step(step + 1, ckpt_dir=args.ckpt_dir,
                            flush=mgr.wait if mgr else None) == "stop":
            print(f"[train] fault plan: abrupt stop after step {step} "
                  "(no final save)")
            return finish(loss)
    if mgr:
        mgr.save_async(args.steps, {"params": params, "opt": opt_state})
        mgr.wait()
    print(f"[train] done: final loss {float(loss):.4f}")
    return finish(loss)


def _build_smoke_trainer(args, key, opt_cfg):
    import jax
    from repro.configs.smoke import SMOKE_FACTORIES
    from repro.optim import adamw_init, adamw_update
    from repro.data import SyntheticLatentDataset, SyntheticTokenDataset, \
        ShardedLoader
    from repro.runtime.resilience import all_finite

    name = args.arch if args.arch in SMOKE_FACTORIES else {
        "uvit": "uvit-h", "hunyuan": "hunyuan-dit"}.get(args.arch, args.arch)
    loss_fn, init_fn, make_batch, _cfg = SMOKE_FACTORIES[name]()
    params = init_fn(key)
    opt_state = adamw_init(params)
    proto = make_batch(key)
    if "latents" in proto:
        ds = SyntheticLatentDataset(
            img_size=proto["latents"].shape[1],
            channels=proto["latents"].shape[-1],
            n_classes=10,
            text_dim=(proto["text_embeds"].shape[-1]
                      if "text_embeds" in proto else 0),
            text_len=(proto["text_embeds"].shape[1]
                      if "text_embeds" in proto else 77))
    else:
        ds = SyntheticTokenDataset(vocab=256, seq_len=proto["tokens"].shape[1])
    loader = ShardedLoader(ds, global_batch=args.global_batch)

    def pack(raw):
        import jax.numpy as jnp
        out = {k: jnp.asarray(v) for k, v in raw.items()
               if k in proto or k == "labels"}
        if "frames" in proto:   # whisper: frames stub from latents? tokens ds
            out = {"frames": jax.random.normal(key, (args.global_batch,)
                                               + proto["frames"].shape[1:]),
                   "tokens": out["tokens"][:, :proto["tokens"].shape[1]]}
        return {k: v for k, v in out.items() if k in proto}

    @jax.jit
    def step_fn(params, opt_state, batch, rng, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, rng)
        finite = all_finite(loss, grads)
        new_p, new_o = adamw_update(params, grads, opt_state, opt_cfg,
                                    lr=lr)
        params, opt_state = jax.lax.cond(
            finite, lambda: (new_p, new_o), lambda: (params, opt_state))
        return params, opt_state, loss, finite

    return params, opt_state, step_fn, loader, pack


def _pipeline_mesh(dp: int, pp: int):
    """(data, model) mesh; prefix-slice when the host exposes more
    devices than the plan needs (the shrink-restore drill resumes a
    P=1 x dp=2 plan inside a process forced to 8 host devices)."""
    import jax
    try:
        return jax.make_mesh((dp, pp), ("data", "model"))
    except ValueError:
        import numpy as np
        from jax.sharding import Mesh
        devs = jax.devices()
        if len(devs) < dp * pp:
            raise
        return Mesh(np.asarray(devs[:dp * pp]).reshape(dp, pp),
                    ("data", "model"))


def _build_pipeline_trainer(args, key, opt_cfg):
    """Wave-PP trainer on simulated host devices via the PULSE compile
    path: graph -> partition -> schedule -> executor (runtime.compile).

    The model architecture is FIXED (independent of the mesh shape) so a
    checkpoint from one (pp, dp, zero, V) plan restores elastically onto
    any other.
    """
    import jax
    import jax.numpy as jnp
    from repro.models.diffusion import (SkipViTConfig, UViTConfig,
                                        skipvit_pipeline_graph,
                                        uvit_pipeline_graph)
    from repro.runtime.compile import auto_pipeline
    from repro.runtime.adapters import (diffusion_model_fns,
                                        make_diffusion_microbatches,
                                        skipvit_model_fns)
    from repro.runtime.resilience import all_finite
    from repro.optim import adamw_init, adamw_update
    from repro.data import SyntheticLatentDataset, ShardedLoader

    dp = args.dp
    P = args.pp or max(args.devices // dp, 1)
    mesh = _pipeline_mesh(dp, P)
    M = args.microbatches
    if args.arch == "skipvit":
        cfg = SkipViTConfig("skipvit-pp", img_size=8, in_ch=4, patch=2,
                            d_model=64, n_heads=4, d_ff=128, n_classes=10,
                            n_enc=4, n_mid=2, n_dec=4)
        graph = skipvit_pipeline_graph(cfg, batch=args.global_batch // M)
        fns = skipvit_model_fns(cfg)
    else:
        cfg = UViTConfig("uvit-pp", img_size=8, in_ch=4, patch=2,
                         d_model=64, n_layers=8, n_heads=4, d_ff=128,
                         n_classes=10)
        graph = uvit_pipeline_graph(cfg, batch=args.global_batch // M)
        fns = diffusion_model_fns(cfg, "uvit")
    compiled = auto_pipeline(graph, fns, dp * P, pipeline_devices=P,
                             microbatches=M, dp_size=dp,
                             zero_stage=args.zero_stage,
                             interleave=args.interleave,
                             wire_dtype=args.wire_dtype)
    print("[train] " + compiled.describe().replace("\n", "\n[train] "))
    params = compiled.init_pipeline_params(key)
    opt_state = adamw_init(params)
    loss_of_mb = compiled.bind(mesh)

    ds = SyntheticLatentDataset(img_size=8, channels=4, n_classes=10)
    loader = ShardedLoader(ds, global_batch=args.global_batch)

    def pack(raw):
        return {k: jnp.asarray(v) for k, v in raw.items()}

    def loss_of(params, batch, rng):
        mb, aux = make_diffusion_microbatches(batch, rng, M, cfg, "uvit")
        return loss_of_mb(params, mb, aux)

    @jax.jit
    def step_fn(params, opt_state, batch, rng, lr):
        loss, grads = jax.value_and_grad(loss_of)(params, batch, rng)
        finite = all_finite(loss, grads)
        new_p, new_o = adamw_update(params, grads, opt_state, opt_cfg,
                                    lr=lr)
        params, opt_state = jax.lax.cond(
            finite, lambda: (new_p, new_o), lambda: (params, opt_state))
        return params, opt_state, loss, finite

    return params, opt_state, step_fn, loader, pack, compiled


if __name__ == "__main__":
    main()
