"""End-to-end training driver: a thin loop over ``auto_pipeline`` +
``CheckpointManager`` + a fault plan.

Runs a real training loop on the host (CPU here; the same code path
drives TPU pods — the mesh/shardings come from launch.mesh):
synthetic-but-learnable data, AdamW, periodic async checkpointing with
verified manifests, exact resume, optional pipeline-parallel execution
over simulated devices with a (dp, pp) mesh and ZeRO sharding.

Fault-tolerance contract (exercised by examples/fault_tolerance.py and
tests/helpers/resilience_drill.py):

- ``--faults kill@K`` (or legacy ``--simulate-failure K``) hard-kills
  the process after step K; ``stop@K`` stops abruptly in-process;
  ``nan@K`` poisons a batch (the GradGuard skips the update);
  ``corrupt@K[:shard]`` / ``truncate@K[:shard]`` mutate the newest
  checkpoint shard; ``iofail@K:N`` makes the next N save attempts fail
  transiently (retry/backoff).  The same script parses from
  ``$REPRO_FAULTS``.
- Rerunning with ``--resume`` restores the newest *verified* checkpoint
  (corrupt/partial steps are skipped with a warning) and the stateless
  data pipeline regenerates the exact step stream, so the loss
  trajectory continues as if uninterrupted.
- Resume may use a DIFFERENT plan (``--pp``/``--dp``/``--zero-stage``/
  ``--interleave``): restore de-stacks the saved stage stacks through
  the manifest's recorded plan spec and re-stacks onto the new plan
  (runtime.resilience) — a P=4 run killed mid-epoch resumes as
  P=2 x dp=2 ZeRO-2 with an identical loss trajectory.

Multi-host worker mode (how ``launch/supervisor.py`` runs this driver —
one subprocess per host):

- ``--host-id h --num-hosts H`` makes this process host ``h`` of ``H``:
  it writes ONLY its own checkpoint shard (``shard_{h:05d}.npz``; host 0
  owns the manifest and GC) and blocks on ``wait_step_complete`` at each
  checkpoint step — the commit barrier that keeps any host from racing
  past a step its peers have not durably finished.  Startup rendezvous
  goes through a ``FileBarrier`` under the heartbeat dir.
- ``--heartbeat-dir D`` emits an atomic per-step heartbeat (host, step,
  phase, loss, grad-norm, wall-clock, generation) the supervisor's
  watchdog/straggler detectors consume.
- ``--escalation rollback`` turns an exhausted GradGuard skip budget
  into exit code ``EXIT_ESCALATE`` (43) instead of an abort, asking the
  supervisor to roll the cluster back to the last verified checkpoint.
- the multi-host fault verbs (``hostdown@K:h``, ``hang@K[:h]``,
  ``slow@K:factor[:h]``) are filtered per host via
  ``FaultPlan.for_host`` — malformed specs (unknown host, duplicate
  verb, negative step) fail at startup, not mid-training.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch uvit --steps 200
    PYTHONPATH=src python -m repro.launch.train --arch uvit --pipeline \
        --devices 8 --steps 50          # wave PP over 8 simulated devices
"""
import argparse
import dataclasses
import json
import os
from typing import Any


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="uvit",
                    help="smoke arch key (see repro.configs.smoke) or "
                         "'uvit'/'skipvit' for the pipeline path")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3,
                    help="checkpoint retention (verified-complete steps)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--pipeline", action="store_true",
                    help="wave pipeline over simulated devices")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--dp", type=int, default=2,
                    help="data-parallel degree of the (data, model) mesh")
    ap.add_argument("--pp", type=int, default=None,
                    help="pipeline degree (default: devices // dp)")
    ap.add_argument("--zero-stage", type=int, default=0, choices=(0, 1, 2))
    ap.add_argument("--interleave", type=int, default=None,
                    help="virtual stage slots per device (V)")
    ap.add_argument("--wire-dtype", default="bfloat16",
                    help="boundary-hop dtype; float32 = exact wire")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--faults", default=None,
                    help="fault plan, e.g. 'kill@60,corrupt@80:shard_00000,"
                         "nan@10,iofail@20:2,hostdown@30:1,hang@40,"
                         "slow@50:2.5:1' (default: $REPRO_FAULTS)")
    ap.add_argument("--nan-skip-budget", type=int, default=3,
                    help="max consecutive non-finite steps before the "
                         "escalation policy fires")
    ap.add_argument("--escalation", default="abort",
                    choices=("abort", "rollback"),
                    help="exhausted GradGuard budget: 'abort' raises "
                         "(standalone default); 'rollback' exits "
                         "EXIT_ESCALATE=43 so a supervisor rolls back to "
                         "the last verified checkpoint")
    ap.add_argument("--host-id", type=int, default=0,
                    help="this process's host rank (multi-host worker "
                         "mode; writes shard_<host-id>.npz only)")
    ap.add_argument("--num-hosts", type=int, default=1,
                    help="total host processes cooperating on the run")
    ap.add_argument("--heartbeat-dir", default=None,
                    help="emit per-step heartbeats (+ host the startup "
                         "barrier) here for the training supervisor")
    ap.add_argument("--gen", type=int, default=0,
                    help="supervisor generation tag stamped into "
                         "heartbeats (stale-file filtering)")
    ap.add_argument("--commit-timeout", type=float, default=60.0,
                    help="multi-host barrier timeout (s) on checkpoint "
                         "step commit")
    ap.add_argument("--simulate-failure", type=int, default=0,
                    help="legacy alias for --faults kill@K")
    ap.add_argument("--out-json", default=None,
                    help="write the step->loss trajectory + resume "
                         "metadata here on exit")
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args(argv)


def _dump_losses(path: str, losses: dict, start: int) -> None:
    doc = {"losses": {str(k): v for k, v in losses.items()},
           "start": start, "partial": True}
    tmp = path + f".tmp{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)


@dataclasses.dataclass
class TrainResult:
    """What one driver invocation did (consumed by drills and examples)."""
    final_loss: float | None
    losses: dict                    # step -> float (host)
    start: int                      # first step this invocation ran
    resumed: Any = None             # RestoreInfo | None
    logical_params: Any = None      # model-space params (plan-independent)
    skipped_steps: int = 0          # non-finite updates the guard skipped


def main(argv=None):
    res = run(_parse_args(argv))
    return res.final_loss


def run(args) -> TrainResult:
    from repro.runtime.resilience import (EXIT_ESCALATE, FaultPlan,
                                          GradGuard, GradGuardEscalation,
                                          Heartbeat, restore_training_state,
                                          write_heartbeat)

    faults = FaultPlan.parse(args.faults)
    if args.simulate_failure:
        faults = faults.with_kill(args.simulate_failure)
    # validates host-scoped tokens against the real host count and keeps
    # this host's share — malformed specs die HERE, not mid-training
    faults = faults.for_host(args.host_id, args.num_hosts)

    def beat(step, phase, loss=None, gnorm=None, step_s=None):
        if args.heartbeat_dir:
            write_heartbeat(args.heartbeat_dir, Heartbeat(
                args.host_id, step, phase, loss=loss, grad_norm=gnorm,
                step_s=step_s, gen=args.gen))

    beat(-1, "init")
    if args.pipeline and "XLA_FLAGS" not in os.environ:
        need = max(args.devices,
                   args.dp * (args.pp or max(args.devices // args.dp, 1)))
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={need}")

    import jax

    from repro.checkpoint import CheckpointManager, latest_step, \
        restore_checkpoint
    from repro.optim import AdamWConfig, cosine_schedule

    opt_cfg = AdamWConfig(lr=args.lr)
    key = jax.random.PRNGKey(0)

    if args.pipeline:
        params, opt_state, step_fn, loader, pack, compiled = \
            _build_pipeline_trainer(args, key, opt_cfg)
    else:
        params, opt_state, step_fn, loader, pack = _build_smoke_trainer(
            args, key, opt_cfg)
        compiled = None

    mgr = CheckpointManager(
        args.ckpt_dir, keep=args.keep, host_id=args.host_id,
        num_hosts=args.num_hosts,
        plan=compiled.state_spec() if compiled is not None else None,
        io_fault=faults.io_fault) if args.ckpt_dir else None

    multi_host = args.num_hosts > 1
    if multi_host:
        from repro.launch.mesh import FileBarrier, HostTopology
        topo = HostTopology(args.num_hosts,
                            max(args.devices // args.num_hosts, 1))
        print("[train] " + topo.describe().replace("\n", "\n[train] "))
        if args.heartbeat_dir:
            barrier = FileBarrier(
                os.path.join(args.heartbeat_dir, "barrier"),
                host_id=args.host_id, num_hosts=args.num_hosts)
            barrier.wait(f"start.g{args.gen}", timeout=args.commit_timeout)

    start, resumed = 0, None
    if args.resume and args.ckpt_dir \
            and latest_step(args.ckpt_dir) is not None:
        state = {"params": params, "opt": opt_state}
        if compiled is not None:
            state, info = restore_training_state(
                args.ckpt_dir, compiled, state, strict=False)
            start, resumed = info.step, info
            print(f"[train] resumed from step {info.step}"
                  + (" (elastic restore: plan changed)" if info.elastic
                     else ""))
        else:
            state, start = restore_checkpoint(args.ckpt_dir, state,
                                              strict=False)
            print(f"[train] resumed from step {start}")
        params, opt_state = state["params"], state["opt"]

    guard = GradGuard(budget=args.nan_skip_budget)
    losses: dict[int, float] = {}

    def finish(loss) -> TrainResult:
        beat(args.steps, "done")
        logical = None
        if compiled is not None:
            logical = jax.device_get(compiled.merge_params(*params))
        res = TrainResult(
            final_loss=None if loss is None else float(loss),
            losses=losses, start=start, resumed=resumed,
            logical_params=logical, skipped_steps=guard.skipped_total)
        if args.out_json:
            doc = {"final_loss": res.final_loss,
                   "losses": {str(k): v for k, v in losses.items()},
                   "start": start,
                   "resumed_step": resumed.step if resumed else None,
                   "elastic": bool(resumed.elastic) if resumed else False,
                   "skipped_steps": res.skipped_steps}
            with open(args.out_json, "w") as f:
                json.dump(doc, f)
        return res

    if start >= args.steps:
        print(f"[train] nothing to do: resumed step {start} >= "
              f"--steps {args.steps}")
        return finish(None)

    import time

    from repro.checkpoint import CheckpointError, wait_step_complete

    def save_at(step_next):
        """Single-host: async save.  Multi-host: blocking shard write +
        rendezvous on step completeness (the commit barrier)."""
        state = {"params": params, "opt": opt_state}
        # a checkpoint save IS progress — tell the watchdog so a slow
        # commit (device_get + hashing on a busy box) is not mistaken
        # for a stalled step loop
        beat(step_next, "ckpt")
        if not multi_host:
            mgr.save_async(step_next, state)
            return
        if mgr.save(step_next, state) is None:
            return                  # degraded save: no barrier to meet
        try:
            wait_step_complete(args.ckpt_dir, step_next,
                               timeout=args.commit_timeout)
        except CheckpointError as e:
            # degrade-and-warn, same contract as single-host iofail: the
            # supervisor's watchdog owns declaring a peer dead
            print(f"[train] WARNING: commit barrier at step {step_next} "
                  f"did not close: {e}")

    t0 = time.time()
    loss = None
    # iteration boundary, reset at the END of each loop body: the step
    # period it measures spans compute + host-side bookkeeping, which is
    # what a straggler's peers actually experience (the device-blocking
    # slice alone can be a small fraction of the wall period)
    t_step = time.time()
    for step in range(start, args.steps):
        if faults.hang_before(step):
            # unreachable in practice (hang sleeps ~forever and the
            # supervisor kills us) — guard for mocked sleeps in tests
            print(f"[train] fault plan: woke from hang at step {step}")
            t_step = time.time()
        batch = faults.poison_batch(pack(loader.get(step)), step)
        rng = jax.random.fold_in(key, step)
        lr = cosine_schedule(step, base_lr=args.lr, warmup=20,
                             total=args.steps)
        params, opt_state, loss, finite, gnorm = step_fn(
            params, opt_state, batch, rng, lr)
        try:
            guard.observe(bool(finite), step)
        except GradGuardEscalation as e:
            if args.escalation == "rollback":
                print(f"[train] {e}; requesting supervisor rollback")
                if mgr:
                    mgr.wait()
                beat(step, "done")
                raise SystemExit(EXIT_ESCALATE) from None
            raise
        losses[step] = float(loss)
        if args.out_json:
            # incremental (atomic) trajectory dump: a worker killed or
            # torn down mid-run still leaves its losses for the
            # supervisor to merge
            _dump_losses(args.out_json, losses, start)
        slow = faults.slow_factor(step)
        if slow > 1.0:     # straggle: stretch this step by the factor
            time.sleep(min((time.time() - t_step) * (slow - 1.0), 5.0))
        # the measured duration rides the heartbeat: a supervisor starved
        # of poll slots still gets exact per-step samples for straggler
        # detection (time-derived deltas would average over jit warmup)
        beat(step, "train", loss=float(loss), gnorm=float(gnorm),
             step_s=time.time() - t_step)
        if step % args.log_every == 0 or step == args.steps - 1:
            sps = (step - start + 1) * args.global_batch / (time.time() - t0)
            print(f"[train] step {step:5d} loss {float(loss):.4f} "
                  f"lr {float(lr):.2e} ({sps:.1f} samples/s)")
        if mgr and (step + 1) % args.ckpt_every == 0:
            save_at(step + 1)
        if faults.post_step(step + 1, ckpt_dir=args.ckpt_dir,
                            flush=mgr.wait if mgr else None) == "stop":
            print(f"[train] fault plan: abrupt stop after step {step} "
                  "(no final save)")
            return finish(loss)
        t_step = time.time()   # boundary: commit barrier waits excluded
    if mgr:
        save_at(args.steps)
        mgr.wait()
    print(f"[train] done: final loss {float(loss):.4f}")
    return finish(loss)


def _grad_norm(grads):
    """Global L2 norm of a gradient pytree (reported in heartbeats so the
    supervisor can flag divergence before the GradGuard trips)."""
    import jax
    import jax.numpy as jnp
    leaves = jax.tree_util.tree_leaves(grads)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def _build_smoke_trainer(args, key, opt_cfg):
    import jax
    from repro.configs.smoke import SMOKE_FACTORIES
    from repro.optim import adamw_init, adamw_update
    from repro.data import SyntheticLatentDataset, SyntheticTokenDataset, \
        ShardedLoader
    from repro.runtime.resilience import all_finite

    name = args.arch if args.arch in SMOKE_FACTORIES else {
        "uvit": "uvit-h", "hunyuan": "hunyuan-dit"}.get(args.arch, args.arch)
    loss_fn, init_fn, make_batch, _cfg = SMOKE_FACTORIES[name]()
    params = init_fn(key)
    opt_state = adamw_init(params)
    proto = make_batch(key)
    if "latents" in proto:
        ds = SyntheticLatentDataset(
            img_size=proto["latents"].shape[1],
            channels=proto["latents"].shape[-1],
            n_classes=10,
            text_dim=(proto["text_embeds"].shape[-1]
                      if "text_embeds" in proto else 0),
            text_len=(proto["text_embeds"].shape[1]
                      if "text_embeds" in proto else 77))
    else:
        ds = SyntheticTokenDataset(vocab=256, seq_len=proto["tokens"].shape[1])
    loader = ShardedLoader(ds, global_batch=args.global_batch)

    def pack(raw):
        import jax.numpy as jnp
        out = {k: jnp.asarray(v) for k, v in raw.items()
               if k in proto or k == "labels"}
        if "frames" in proto:   # whisper: frames stub from latents? tokens ds
            out = {"frames": jax.random.normal(key, (args.global_batch,)
                                               + proto["frames"].shape[1:]),
                   "tokens": out["tokens"][:, :proto["tokens"].shape[1]]}
        return {k: v for k, v in out.items() if k in proto}

    @jax.jit
    def step_fn(params, opt_state, batch, rng, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, rng)
        finite = all_finite(loss, grads)
        gnorm = _grad_norm(grads)
        new_p, new_o = adamw_update(params, grads, opt_state, opt_cfg,
                                    lr=lr)
        params, opt_state = jax.lax.cond(
            finite, lambda: (new_p, new_o), lambda: (params, opt_state))
        return params, opt_state, loss, finite, gnorm

    return params, opt_state, step_fn, loader, pack


def _pipeline_mesh(dp: int, pp: int):
    """(data, model) mesh; prefix-slice when the host exposes more
    devices than the plan needs (the shrink-restore drill resumes a
    P=1 x dp=2 plan inside a process forced to 8 host devices)."""
    import jax
    try:
        return jax.make_mesh((dp, pp), ("data", "model"))
    except ValueError:
        import numpy as np
        from jax.sharding import Mesh
        devs = jax.devices()
        if len(devs) < dp * pp:
            raise
        return Mesh(np.asarray(devs[:dp * pp]).reshape(dp, pp),
                    ("data", "model"))


def _build_pipeline_trainer(args, key, opt_cfg):
    """Wave-PP trainer on simulated host devices via the PULSE compile
    path: graph -> partition -> schedule -> executor (runtime.compile).

    The model architecture is FIXED (independent of the mesh shape) so a
    checkpoint from one (pp, dp, zero, V) plan restores elastically onto
    any other.
    """
    import jax
    import jax.numpy as jnp
    from repro.models.diffusion import (SkipViTConfig, UViTConfig,
                                        skipvit_pipeline_graph,
                                        uvit_pipeline_graph)
    from repro.runtime.compile import auto_pipeline
    from repro.runtime.adapters import (diffusion_model_fns,
                                        make_diffusion_microbatches,
                                        skipvit_model_fns)
    from repro.runtime.resilience import all_finite
    from repro.optim import adamw_init, adamw_update
    from repro.data import SyntheticLatentDataset, ShardedLoader

    dp = args.dp
    P = args.pp or max(args.devices // dp, 1)
    mesh = _pipeline_mesh(dp, P)
    M = args.microbatches
    if args.arch == "skipvit":
        cfg = SkipViTConfig("skipvit-pp", img_size=8, in_ch=4, patch=2,
                            d_model=64, n_heads=4, d_ff=128, n_classes=10,
                            n_enc=4, n_mid=2, n_dec=4)
        graph = skipvit_pipeline_graph(cfg, batch=args.global_batch // M)
        fns = skipvit_model_fns(cfg)
    elif args.arch == "uvit-nano":
        # smallest arch that still pipelines: keeps the multi-process
        # supervisor drill inside a CI time budget on a 1-core box
        cfg = UViTConfig("uvit-nano", img_size=8, in_ch=4, patch=4,
                         d_model=32, n_layers=8, n_heads=2, d_ff=64,
                         n_classes=10)
        graph = uvit_pipeline_graph(cfg, batch=args.global_batch // M)
        fns = diffusion_model_fns(cfg, "uvit")
    else:
        cfg = UViTConfig("uvit-pp", img_size=8, in_ch=4, patch=2,
                         d_model=64, n_layers=8, n_heads=4, d_ff=128,
                         n_classes=10)
        graph = uvit_pipeline_graph(cfg, batch=args.global_batch // M)
        fns = diffusion_model_fns(cfg, "uvit")
    compiled = auto_pipeline(graph, fns, dp * P, pipeline_devices=P,
                             microbatches=M, dp_size=dp,
                             zero_stage=args.zero_stage,
                             interleave=args.interleave,
                             wire_dtype=args.wire_dtype)
    print("[train] " + compiled.describe().replace("\n", "\n[train] "))
    params = compiled.init_pipeline_params(key)
    opt_state = adamw_init(params)
    loss_of_mb = compiled.bind(mesh)

    ds = SyntheticLatentDataset(img_size=8, channels=4, n_classes=10)
    loader = ShardedLoader(ds, global_batch=args.global_batch)

    def pack(raw):
        return {k: jnp.asarray(v) for k, v in raw.items()}

    def loss_of(params, batch, rng):
        mb, aux = make_diffusion_microbatches(batch, rng, M, cfg, "uvit")
        return loss_of_mb(params, mb, aux)

    @jax.jit
    def step_fn(params, opt_state, batch, rng, lr):
        loss, grads = jax.value_and_grad(loss_of)(params, batch, rng)
        finite = all_finite(loss, grads)
        gnorm = _grad_norm(grads)
        new_p, new_o = adamw_update(params, grads, opt_state, opt_cfg,
                                    lr=lr)
        params, opt_state = jax.lax.cond(
            finite, lambda: (new_p, new_o), lambda: (params, opt_state))
        return params, opt_state, loss, finite, gnorm

    return params, opt_state, step_fn, loader, pack, compiled


if __name__ == "__main__":
    main()
