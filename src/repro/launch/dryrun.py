import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh).

For each cell this driver:
  1. builds the train/serve step for the arch's ParallelPlan,
  2. ``jax.jit(step).lower(*ShapeDtypeStructs)`` (no allocation),
  3. ``.compile()`` — proving the sharding config is coherent,
  4. records ``memory_analysis()`` / ``cost_analysis()`` / the parsed
     collective schedule into an incremental JSON
     (results/dryrun_<mesh>.json) consumed by benchmarks/roofline.py.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import get_arch, ASSIGNED, PAPER_ARCHS
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes, dp_size
from repro.runtime.hlo_analysis import collective_bytes, cost_summary, \
    memory_summary
from repro.train import steps as steps_mod


def adjust_plan(plan, bundle, shape, mesh):
    """Clamp PP microbatch count to the per-replica batch on this mesh."""
    if not plan.strategy.startswith("pp"):
        return plan
    dp = dp_size(mesh, plan.batch_axes)
    per_replica = shape.global_batch // dp
    M = min(plan.microbatches, per_replica)
    return dataclasses.replace(plan, microbatches=max(M, 1))


def build_cell(bundle, shape_name: str, mesh):
    shape = SHAPES[shape_name]
    plan = adjust_plan(bundle.plans[shape_name], bundle, shape, mesh)
    if shape.kind in ("train", "prefill"):
        if plan.strategy.startswith("pp"):
            adapter = bundle.make_adapter(plan, mesh)
            batch = bundle.batch_struct(shape, plan)
            step, example, in_sh, out_sh = steps_mod.build_pp_train_step(
                adapter, mesh, batch, plan, bundle.make_microbatches)
        elif shape.kind == "train":
            batch = bundle.batch_struct(shape, plan)
            step, example, in_sh, out_sh = steps_mod.build_sharded_train_step(
                bundle.loss_fn, bundle.init_fn, batch, mesh, plan)
        else:  # prefill: forward pass only (inference compute)
            batch = bundle.batch_struct(shape, plan)
            step, example, in_sh, out_sh = steps_mod.build_forward_step(
                bundle.loss_fn, bundle.init_fn, batch, mesh, plan)
    else:  # decode
        decode_fn = bundle.make_decode_fn(shape)
        cache = bundle.cache_struct(shape)
        B = shape.global_batch
        token = {"token": jax.ShapeDtypeStruct((B, 1), jax.numpy.int32)}

        def serve(params, tok, caches):
            return decode_fn(params, tok["token"], caches)

        step, example, in_sh, out_sh = steps_mod.build_sharded_serve_step(
            serve, bundle.init_fn, cache, token, mesh, plan)
    return step, example, plan


def run_cell(arch: str, shape_name: str, mesh, multi_pod: bool) -> dict:
    bundle = get_arch(arch)
    support = bundle.shape_support.get(shape_name, "unknown shape")
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "params": bundle.param_count,
        "active_params": bundle.active_param_count,
    }
    if support != "ok":
        rec["status"] = "skipped"
        rec["reason"] = support
        return rec
    t0 = time.time()
    try:
        step, example, plan = build_cell(bundle, shape_name, mesh)
        lowered = step.lower(*example)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rec.update({
            "status": "ok",
            "plan": {"strategy": plan.strategy, "tp": plan.tp_axis,
                     "ep": plan.ep, "fsdp": list(plan.fsdp_axes),
                     "batch_axes": list(plan.batch_axes),
                     "microbatches": plan.microbatches,
                     "int8_opt": plan.int8_optimizer,
                     "notes": plan.notes},
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": memory_summary(compiled),
            "cost": cost_summary(compiled),
        })
        stats = collective_bytes(compiled.as_text())
        rec["collectives"] = {
            "bytes_by_kind": stats.bytes_by_kind,
            "count_by_kind": stats.count_by_kind,
        }
        print(f"[dryrun] {arch} x {shape_name}: OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s; "
              f"temp={rec['memory'].get('temp_size_in_bytes', 0) or 0:,}B; "
              f"colls: {stats})")
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {arch} x {shape_name}: FAILED {rec['error'][:200]}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--assigned-only", action="store_true")
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(f"mesh: {mesh_axis_sizes(mesh)} over {mesh.devices.size} devices")

    out_path = args.out or os.path.join(
        "results", f"dryrun_{'2x16x16' if args.multi_pod else '16x16'}.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    results = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)

    if args.all or args.assigned_only:
        archs = ASSIGNED if args.assigned_only else ASSIGNED + PAPER_ARCHS
        cells = [(a, s) for a in archs
                 for s in get_arch(a).plans.keys()]
    else:
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        key = f"{arch}|{shape}"
        if key in results and results[key].get("status") in ("ok", "skipped") \
                and not args.force:
            print(f"[dryrun] {key}: cached ({results[key]['status']})")
            continue
        results[key] = run_cell(arch, shape, mesh, args.multi_pod)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"-> {out_path}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
