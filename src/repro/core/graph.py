"""Block-graph IR for PULSE.

A model is an ordered sequence of *blocks* (the paper's fine-grained
operations, §IV-B) plus a set of *skip edges* ``(src, dst)`` with
``dst > src`` denoting a long-range activation dependency (UNet/UViT skip
connections, whisper cross-attention, tied embeddings, ...).

The IR is deliberately tiny: the partitioner (`core.partition`), the
schedule synthesizer (`core.schedule`), the hybrid tuner (`core.tuner`) and
the comm-volume model (`core.comm_model`) all consume this structure, while
`models/*.py` export their architectures into it via ``to_block_graph()``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Block:
    """One atomic unit of the partitionable sequence."""

    name: str
    fwd_time: float          # profiled or analytic forward time (seconds)
    param_bytes: int = 0     # parameter footprint (M_theta contribution)
    act_bytes: int = 0       # boundary activation size it emits (M_o / M_a)
    skip_bytes: int = 0      # size of the skip tensor it emits (0 if none)
    flops: float = 0.0       # analytic forward FLOPs (roofline bookkeeping)


@dataclasses.dataclass(frozen=True)
class SkipEdge:
    src: int                 # producing block index
    dst: int                 # consuming block index (dst > src)
    bytes: int = 0           # activation volume carried by the edge

    def __post_init__(self):
        if self.dst <= self.src:
            raise ValueError(f"skip edge must go forward: {self.src}->{self.dst}")


@dataclasses.dataclass(frozen=True)
class BlockGraph:
    blocks: tuple[Block, ...]
    skips: tuple[SkipEdge, ...] = ()

    def __post_init__(self):
        n = len(self.blocks)
        for e in self.skips:
            if not (0 <= e.src < n and 0 <= e.dst < n):
                raise ValueError(f"skip edge {e} out of range for {n} blocks")

    @property
    def n(self) -> int:
        return len(self.blocks)

    @property
    def fwd_times(self) -> tuple[float, ...]:
        return tuple(b.fwd_time for b in self.blocks)

    def is_nested(self) -> bool:
        """True iff skip edges are symmetric-nested (UNet-style).

        Sorted by src ascending, dsts must be strictly descending and all
        edges non-crossing: src_0 < src_1 < ... and dst_0 > dst_1 > ...
        with src_k < dst_k for all k.  This is the structure PULSE's
        bidirectional DP exploits (paper §IV-B); arbitrary DAG skips fall
        back to the reference partitioner.
        """
        es = sorted(self.skips, key=lambda e: e.src)
        for a, b in zip(es, es[1:]):
            if not (a.src < b.src and a.dst > b.dst and b.src < b.dst):
                return False
        return True

    def sorted_skips(self) -> tuple[SkipEdge, ...]:
        return tuple(sorted(self.skips, key=lambda e: e.src))

    def total_fwd_time(self) -> float:
        return sum(b.fwd_time for b in self.blocks)

    def total_param_bytes(self) -> int:
        return sum(b.param_bytes for b in self.blocks)


def make_unet_like(
    n_pairs: int,
    mid_blocks: int = 1,
    enc_time: float = 1.0,
    dec_time: float = 1.0,
    act_bytes: int = 1 << 20,
    skip_bytes: int = 1 << 20,
    param_bytes: int = 1 << 20,
) -> BlockGraph:
    """Synthetic symmetric encoder-decoder graph (test/benchmark helper).

    ``n_pairs`` encoder blocks, ``mid_blocks`` bottleneck blocks, ``n_pairs``
    decoder blocks; skip edge from encoder block i to its mirror decoder.
    """
    blocks = []
    for i in range(n_pairs):
        blocks.append(Block(f"enc{i}", enc_time, param_bytes, act_bytes, skip_bytes))
    for i in range(mid_blocks):
        blocks.append(Block(f"mid{i}", enc_time, param_bytes, act_bytes, 0))
    for i in range(n_pairs):
        blocks.append(Block(f"dec{i}", dec_time, param_bytes, act_bytes, 0))
    total = 2 * n_pairs + mid_blocks
    skips = tuple(
        SkipEdge(i, total - 1 - i, skip_bytes) for i in range(n_pairs)
    )
    return BlockGraph(tuple(blocks), skips)
