"""Analytic communication-volume models (paper §II-C, §V-B, Table III).

All volumes are *bytes per microbatch* unless stated otherwise.  These
formulas are validated against byte counts parsed from compiled HLO by
``benchmarks/comm_volume.py`` (collective-permute operand sums).

Graph ``act_bytes`` are denominated at 2 bytes/element (bf16 activations —
see the graph builders in ``models.diffusion``).  :func:`wire_factor`
rescales them to the executor's wire format, and
:func:`lowered_comm_volume` prices what the table executors *actually*
lower — live hops only (the schedule's channel-activity masks) at the wire
dtype — against the dense pre-liveness cost (every step, both rings,
fp32).  This is the point where the planner's model and the executor's
measured HLO bytes are held to agree.
"""
from __future__ import annotations

import dataclasses

from repro.core.graph import BlockGraph
from repro.core.partition import Partition

# Bytes per element of the wire formats the lowered executors support
# (runtime.pipeline.WIRE_DTYPES).  Graph act_bytes assume 2 (bf16).
WIRE_BYTES = {"bfloat16": 2, "float32": 4}
ACT_DENOM_BYTES = 2


def wire_factor(wire_dtype: str = "bfloat16") -> float:
    """Scale from the graph's act_bytes denomination to wire bytes."""
    return WIRE_BYTES[wire_dtype] / ACT_DENOM_BYTES


def naive_pp_volume(K: int, D: int, a: int) -> float:
    """Paper §II-C: sequential block-wise partition of a UNet with K blocks
    (K/2 skip pairs) over D devices; every skip hops stage-by-stage.
    Total per-microbatch forward volume: ((K+4)*D/4 - 1) * a."""
    return ((K + 4) * D / 4 - 1) * a


def pulse_volume(D: int, a: int) -> float:
    """Paper §V-B: skip-collocated wave needs only boundary transfers:
    2*(D-1)*a per microbatch (down-stream + up-stream)."""
    return 2 * (D - 1) * a


def zero_volume_per_iter(param_bytes: int, G: int, stage: int = 2) -> float:
    """ZeRO-stage-2/3 per-iteration collective volume per device (ring):
    reduce-scatter(grads) + all-gather(params) ~= 2 * (G-1)/G * P bytes,
    ZeRO-3 re-gathers params in both passes (x2)."""
    base = 2.0 * (G - 1) / G * param_bytes
    return base * (2.0 if stage >= 3 else 1.0)


@dataclasses.dataclass(frozen=True)
class PartitionCommVolume:
    boundary_bytes: float     # short-range stage-to-stage (fwd, per microbatch)
    skip_bytes: float         # long-range skip traffic (fwd, per microbatch)

    @property
    def fwd_total(self) -> float:
        return self.boundary_bytes + self.skip_bytes

    @property
    def train_total(self) -> float:
        # backward transfers mirror the forward ones (activation gradients)
        return 2.0 * self.fwd_total


def partition_comm_volume(graph: BlockGraph, part: Partition) -> PartitionCommVolume:
    """Exact per-microbatch P2P volume for an arbitrary partition.

    Boundary: each stage sends its output tensor to the next stage if it is
    on a different device.  Skip: each skip edge whose endpoints live on
    different devices is relayed hop-by-hop through every intermediate
    stage boundary (the paper's 1F1B/Hanayo baseline semantics: stacked,
    transferred, popped).
    """
    boundary = 0.0
    for s in range(part.num_stages - 1):
        if part.device_of_stage(s) != part.device_of_stage(s + 1):
            lo, hi = part.stage_range(s)
            boundary += graph.blocks[hi - 1].act_bytes
    skip = 0.0
    for e in graph.skips:
        s_src = part.stage_of_block(e.src)
        s_dst = part.stage_of_block(e.dst)
        if part.device_of_stage(s_src) == part.device_of_stage(s_dst):
            continue  # collocated: local buffer, no transfer
        hops = 0
        for s in range(s_src, s_dst):
            if part.device_of_stage(s) != part.device_of_stage(s + 1):
                hops += 1
        skip += hops * e.bytes
    return PartitionCommVolume(boundary, skip)


def per_sample_volume(
    graph: BlockGraph, part: Partition, microbatch_size: int
) -> float:
    """Bytes/sample of P2P traffic for one training iteration (fwd+bwd)."""
    v = partition_comm_volume(graph, part)
    return v.train_total / max(microbatch_size, 1)


@dataclasses.dataclass(frozen=True)
class LoweredCommVolume:
    """What the table executors put on the ring for one iteration's
    forward pass, as lowered from the schedule's channel-activity masks.

    ``live_hops`` counts (device, step, ring) hops that carry a message;
    ``dense_hops`` is what the pre-liveness lowering paid (every step,
    both rings); ``payload_bytes`` is the boundary activation size at the
    graph's 2-byte/element denomination.
    """

    live_hops: int
    dense_hops: int
    payload_bytes: float
    wire_dtype: str = "bfloat16"

    @property
    def hop_bytes(self) -> float:
        return self.payload_bytes * wire_factor(self.wire_dtype)

    @property
    def fwd_total(self) -> float:
        return self.live_hops * self.hop_bytes

    @property
    def train_total(self) -> float:
        # backward hops mirror the forward ones through the cast/ppermute
        # transposes, at the same wire dtype
        return 2.0 * self.fwd_total

    @property
    def dense_fp32_total(self) -> float:
        """The pre-liveness executor's cost: every-step/both-rings fp32."""
        return self.dense_hops * self.payload_bytes * wire_factor("float32")


def lowered_comm_volume(tables, payload_bytes: float,
                        wire_dtype: str = "bfloat16") -> LoweredCommVolume:
    """Price a lowered schedule's actual ring traffic.

    ``tables`` is duck-typed on the
    :class:`~repro.runtime.schedule_exec.StepTables` activity analysis
    (``live_hops`` / ``dense_hops``) so the planning layer never imports
    the runtime; ``payload_bytes`` is the boundary activation size
    (``StageProfile.out_bytes_per_sample`` x microbatch size).
    """
    down, up = tables.live_hops
    return LoweredCommVolume(live_hops=down + up,
                             dense_hops=tables.dense_hops,
                             payload_bytes=payload_bytes,
                             wire_dtype=wire_dtype)


@dataclasses.dataclass(frozen=True)
class OverlapAccounting:
    """Exposed-vs-hidden split of a schedule's live ring hops.

    An **exposed** hop's consumer runs on the very next forward step, so
    even the overlapped executors (``PipelineConfig.overlap``) pay its
    full wire time on the critical path; a **hidden** hop has at least
    one intervening step of compute to ride under, costing only the wire
    time the covering compute does not absorb.  This is the accounting
    the tuner's overlap-aware Eq. 15 term prices and the point where the
    planner (``core.schedule.comm_stats``) and the executor lowering
    (``StepTables.exposed_hops``) are held to agree — the overlap
    counterpart of :func:`lowered_comm_volume`'s byte agreement.
    """

    exposed_hops: int
    hidden_hops: int

    @property
    def total_hops(self) -> int:
        return self.exposed_hops + self.hidden_hops

    def comm_time(self, t_p2p: float, t_f: float,
                  overlap: bool = True) -> float:
        """Total wire seconds on the critical path.

        ``t_p2p`` is one hop's wire time, ``t_f`` the typical compute a
        hidden hop rides under.  ``overlap=False`` prices the synchronous
        lowering: every live hop serializes at full ``t_p2p``.
        """
        if not overlap:
            return self.total_hops * t_p2p
        return (self.exposed_hops * t_p2p
                + self.hidden_hops * max(0.0, t_p2p - t_f))


def overlap_accounting(tables) -> OverlapAccounting:
    """Extract the exposed/hidden split from a lowered or analyzed
    schedule.  ``tables`` is duck-typed on ``exposed_hops`` /
    ``hidden_hops`` — both :class:`~repro.runtime.schedule_exec.StepTables`
    and the planner-side :class:`~repro.core.schedule.ScheduleCommStats`
    qualify, so either layer's analysis can be priced (and the property
    tests hold the two to agree)."""
    return OverlapAccounting(exposed_hops=int(tables.exposed_hops),
                             hidden_hops=int(tables.hidden_hops))
