"""Per-block cost profiling (paper §IV-A "profile layer runtimes").

Two paths:

- ``analytic_block_costs``: FLOPs / peak + bytes / HBM-bandwidth roofline
  estimate — deterministic, used for dry-runs and the tuner on CPU where
  wall-clock timing of TPU kernels is meaningless.
- ``measure_block_times``: real wall-clock timing of jitted per-block apply
  functions (usable on any backend; used by tests and the CPU examples).
"""
from __future__ import annotations

import time
from typing import Callable, Sequence

from repro.core.graph import Block, BlockGraph
from repro.core.hw import Hardware, TPU_V5E


def analytic_time(flops: float, bytes_moved: float, hw: Hardware = TPU_V5E) -> float:
    """max(compute, memory) roofline time for one block."""
    return max(flops / hw.peak_flops, bytes_moved / hw.hbm_bw)


def analytic_block_costs(
    blocks: Sequence[Block], hw: Hardware = TPU_V5E
) -> tuple[Block, ...]:
    """Return blocks with ``fwd_time`` replaced by the roofline estimate."""
    out = []
    for b in blocks:
        bytes_moved = 2 * b.param_bytes + 2 * b.act_bytes  # read params+act, write act
        t = analytic_time(b.flops, bytes_moved, hw)
        out.append(Block(b.name, t, b.param_bytes, b.act_bytes, b.skip_bytes, b.flops))
    return tuple(out)


def measure_block_times(
    fns: Sequence[Callable],
    args: Sequence[tuple],
    *,
    warmup: int = 1,
    iters: int = 3,
) -> list[float]:
    """Wall-clock seconds per call for each jitted block function."""
    import jax                       # lazy: core/ imports without jax

    times = []
    for fn, a in zip(fns, args):
        jfn = jax.jit(fn)
        for _ in range(warmup):
            jax.block_until_ready(jfn(*a))
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(jfn(*a))
        times.append((time.perf_counter() - t0) / iters)
    return times


def reprofile_graph(graph: BlockGraph, hw: Hardware = TPU_V5E) -> BlockGraph:
    """Analytically re-profile every block of a graph for hardware ``hw``."""
    return BlockGraph(analytic_block_costs(graph.blocks, hw), graph.skips)
