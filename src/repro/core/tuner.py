"""Hybrid parallelism tuner (paper §VI, Eqs. 14-17).

Given per-stage profiled costs, enumerate every factorization ``N = P * G``
and every power-of-two microbatch size ``b``; reject configurations whose
peak memory (Eq. 14) exceeds the device budget; score the rest with the
iteration-time model (Eq. 15 + 16) and return the argmin of per-sample time
(Eq. 17).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.core.comm_model import (WIRE_BYTES, wire_factor,
                                   zero_volume_per_iter)
from repro.core.graph import BlockGraph
from repro.core.hw import Hardware, TPU_V5E
from repro.core import partition as part_mod
from repro.core.schedule import (schedule_for_partition, simulate,
                                 template_1f1b, template_wave)


@dataclasses.dataclass(frozen=True)
class StageProfile:
    """Per-stage profiled quantities; indices follow pipeline stage order.

    ``act_bytes_per_sample`` includes each stage's skip-stash bytes (the
    historical aggregate every dense consumer prices);
    ``skip_bytes_per_sample`` additionally breaks the skip share out per
    stage so :func:`peak_memory` can price the stash at the proven
    ``W_skip`` rotating window instead of dense over all ``P`` in-flight
    microbatches.  Legacy profiles may leave it empty (skip treated as
    inseparable from the activations — the dense pricing)."""

    fwd_time_per_sample: tuple[float, ...]   # T_f^s(b) = b * this
    param_bytes: tuple[int, ...]             # M_theta^s
    act_bytes_per_sample: tuple[int, ...]    # M_a^s (incl. skip share)
    out_bytes_per_sample: tuple[int, ...]    # M_o^s
    skip_bytes_per_sample: tuple[int, ...] = ()   # skip share of M_a^s

    @property
    def num_stages(self) -> int:
        return len(self.fwd_time_per_sample)


def profile_partition(graph: BlockGraph, part: part_mod.Partition) -> StageProfile:
    f, p, a, o, k = [], [], [], [], []
    for s in range(part.num_stages):
        lo, hi = part.stage_range(s)
        blocks = graph.blocks[lo:hi]
        f.append(sum(b.fwd_time for b in blocks))
        p.append(sum(b.param_bytes for b in blocks))
        a.append(sum(b.act_bytes + b.skip_bytes for b in blocks))
        o.append(blocks[-1].act_bytes)
        k.append(sum(b.skip_bytes for b in blocks))
    return StageProfile(tuple(f), tuple(p), tuple(a), tuple(o), tuple(k))


@dataclasses.dataclass(frozen=True)
class TunerChoice:
    P: int                 # pipeline-parallel degree (devices per pipeline)
    G: int                 # data-parallel replicas
    b: int                 # microbatch size
    t_sample: float        # modelled seconds per training sample (Eq. 17)
    t_sched: float         # modelled iteration time (Eq. 15)
    peak_mem: float        # modelled peak bytes (Eq. 14)
    wave: bool             # folded wave (S=2VP) vs plain 1F1B (S=VP)
    M: int = 1             # microbatches per iteration the score assumed —
    #   auto_pipeline executes this M so the iteration it runs is the one
    #   the tuner ranked (previously the executor silently ran M = 2D).
    V: int = 1             # interleave degree: stage slot pairs per device
    #   (V > 1 = interleaved/virtual-stage schedule; finer stages shrink
    #   the fill/drain ramp ~1/V at the cost of V padded weight shards and
    #   more p2p hops per microbatch)
    partition: "part_mod.Partition | None" = None
    # ^ the partition this choice was scored on — the compile path
    #   (runtime.compile.auto_pipeline) lowers it directly.
    zero_stage: int = 0    # ZeRO sharding over the dp axis: 0 = replicated,
    #   1 = optimizer state sharded, 2 = params-at-rest + grads +
    #   optimizer state sharded (all-gather-on-use in the scan body)

    @property
    def dp(self) -> int:
        """Data-parallel degree (alias: the mesh's 'data' axis size)."""
        return self.G


def zero_param_state_breakdown(
    m_theta: float, *, dp: int = 1, zero_stage: int = 0,
    param_state_factor: float = 7.0, m_gather: float | None = None,
) -> dict[str, float]:
    """Per-device param/grad/optimizer resident bytes under ZeRO sharding.

    Decomposes the legacy lump ``param_state_factor * m_theta`` into
    params (1x), grads (1x) and optimizer state (``param_state_factor -
    2`` x, the AdamW m/v/master share).  ZeRO-1 shards the optimizer
    term over the ``dp`` replicas; ZeRO-2 also shards params-at-rest and
    the (reduce-scattered) grads, charging one transient all-gathered
    working copy ``m_gather`` for the stage slot currently in use
    (default: all of ``m_theta`` — conservative for multi-slot layouts
    whose callers don't pass the per-slot size).  The components are the
    executor's actual sharded leaf bytes (``runtime.sharding.
    zero_stack_specs`` scatters every eligible leaf by exactly ``dp``),
    which the property tests pin.
    """
    opt = max(param_state_factor - 2.0, 0.0)
    if dp <= 1 or zero_stage <= 0:
        return {"params": m_theta, "grads": m_theta,
                "opt": opt * m_theta, "gathered": 0.0}
    if zero_stage == 1:
        return {"params": m_theta, "grads": m_theta,
                "opt": opt * m_theta / dp, "gathered": 0.0}
    if m_gather is None:
        m_gather = m_theta
    return {"params": m_theta / dp, "grads": m_theta / dp,
            "opt": opt * m_theta / dp, "gathered": float(m_gather)}


def zero_param_state_bytes(
    m_theta: float, *, dp: int = 1, zero_stage: int = 0,
    param_state_factor: float = 7.0, m_gather: float | None = None,
) -> float:
    """Scalar form of :func:`zero_param_state_breakdown`; bit-identical
    to the legacy ``param_state_factor * m_theta`` when unsharded."""
    if dp <= 1 or zero_stage <= 0:
        return param_state_factor * m_theta
    return sum(zero_param_state_breakdown(
        m_theta, dp=dp, zero_stage=zero_stage,
        param_state_factor=param_state_factor, m_gather=m_gather).values())


def peak_memory(
    prof: StageProfile, P: int, b: int, *, wave: bool, V: int = 1,
    param_state_factor: float = 7.0,
    windows: "tuple[int, int] | tuple[int, int, int] | None" = None,
    wire_bytes: int = 2,
    dp: int = 1, zero_stage: int = 0,
) -> float:
    """Eq. (14).  The busiest devices are the innermost collocated pair
    (stages P-1 and P, 0-indexed) which retain activations for all
    in-flight microbatches (P of them in the wave steady state).

    ``V > 1`` prices the interleaved layout instead: each device carries
    ``2V`` (``V`` linear) stage slots whose parameter/activation stacks
    are padded to the *largest* slot — the memory side of the
    bubble-vs-V trade-off the tuner searches over.

    ``windows = (W_rx, W_turn)`` replaces the dense in-flight boundary
    term (``P`` / ``P + 2V - 2`` activations) with the liveness windows
    the schedule lowering proved: ``W_rx`` receive-buffer entries at
    ``wire_bytes``/element (the wire format of the hops) plus two ring
    registers, and ``W_turn`` turnaround entries at fp32.  The 3-tuple
    form ``(W_rx, W_turn, W_skip)`` additionally prices the skip stash
    at its proven rotating window — ``W_skip`` fp32 entries of the
    largest per-stage skip payload — instead of dense over all ``P``
    in-flight microbatches (the executor allocates exactly ``W_skip``
    rotating entries, so the dense charge over-billed skip-heavy
    candidates).  ``tune`` passes the lowered 3-tuple, so smaller proven
    footprints admit larger microbatches on memory-bound candidates.
    Without windows the dense pre-liveness sizing is priced (back-compat
    / no schedule yet); the legacy 2-tuple keeps skip dense.

    ``dp``/``zero_stage`` charge the ZeRO-sharded param/optimizer bytes
    instead of the replicated ``param_state_factor * m_theta`` lump (see
    :func:`zero_param_state_breakdown`): optimizer state ``/dp`` at
    ZeRO-1+, params-at-rest and grads ``/dp`` plus one transient
    gathered stage copy at ZeRO-2.  ``dp <= 1`` or ``zero_stage == 0``
    is bit-identical to the historical form.
    """
    from repro.core.comm_model import ACT_DENOM_BYTES

    def param_state(m_theta: float, m_gather: float) -> float:
        return zero_param_state_bytes(
            m_theta, dp=dp, zero_stage=zero_stage,
            param_state_factor=param_state_factor, m_gather=m_gather)

    def boundary_term(m_out: float, dense_count: float) -> float:
        if windows is None:
            return dense_count * m_out * b
        w_rx, w_turn = windows[0], windows[1]
        return m_out * b * ((w_rx + 2) * wire_bytes / ACT_DENOM_BYTES
                            + w_turn * 4 / ACT_DENOM_BYTES)

    w_skip = None
    if windows is not None and len(windows) == 3 and prof.skip_bytes_per_sample:
        w_skip = windows[2]
    skips = prof.skip_bytes_per_sample or (0,) * prof.num_stages
    # The skip stash lives at fp32 regardless of the wire format (it never
    # rides the ring; act bytes are denominated at ACT_DENOM_BYTES/elem).
    skip_entry_factor = b * 4 / ACT_DENOM_BYTES

    if V > 1:
        slots = 2 * V if wave else V
        m_theta = slots * max(prof.param_bytes)
        if w_skip is None:
            m_act = slots * max(prof.act_bytes_per_sample)
            skip_term = 0.0
        else:
            m_act = slots * max(a - k for a, k in
                                zip(prof.act_bytes_per_sample, skips))
            skip_term = w_skip * max(skips) * skip_entry_factor
        m_out = max(prof.out_bytes_per_sample)
        return (param_state(m_theta, max(prof.param_bytes))
                + P * m_act * b
                + skip_term
                + boundary_term(m_out, P + slots - 2))
    if wave:
        i, j = P - 1, P  # innermost pair on the same device
        m_theta = prof.param_bytes[i] + prof.param_bytes[j]
        if w_skip is None:
            m_act = prof.act_bytes_per_sample[i] + prof.act_bytes_per_sample[j]
            skip_term = 0.0
        else:
            m_act = (prof.act_bytes_per_sample[i] - skips[i]
                     + prof.act_bytes_per_sample[j] - skips[j])
            skip_term = w_skip * max(skips[i], skips[j]) * skip_entry_factor
        m_out = prof.out_bytes_per_sample[i - 1] if i >= 1 else prof.out_bytes_per_sample[0]
    else:
        # 1F1B: stage 0 retains P microbatches (skip-free graphs, but the
        # windowed form stays uniform if a profile carries skip bytes)
        m_theta = prof.param_bytes[0]
        if w_skip is None:
            m_act = prof.act_bytes_per_sample[0]
            skip_term = 0.0
        else:
            m_act = prof.act_bytes_per_sample[0] - skips[0]
            skip_term = w_skip * skips[0] * skip_entry_factor
        m_out = prof.out_bytes_per_sample[0]
    return (
        param_state(m_theta, m_theta)
        + P * m_act * b
        + skip_term
        + boundary_term(m_out, P)
    )


def t_allreduce(param_bytes: float, G: int, hw: Hardware) -> float:
    """Eq. (16): ring all-reduce of the largest stage's gradients.

    Routed through the same ``2(G-1)/G`` volume arithmetic as the ZeRO
    term so stage-0/1 and stage-2 candidates with identical modelled
    volume tie *exactly* (bit-for-bit) and the tuner's zero_stage
    tie-break stays deterministic."""
    if G <= 1:
        return 0.0
    return hw.t_lat + zero_volume_per_iter(param_bytes, G, 2) / hw.intra_bw


def t_grad_sync(param_bytes: float, G: int, hw: Hardware,
                zero_stage: int = 0) -> float:
    """Eq. (16) generalized to the ZeRO stages.

    ZeRO-0/1 all-reduce the gradients (ZeRO-1's optimizer shard update is
    local, so its wire cost is the same ring all-reduce).  ZeRO-2 pays
    the all-gather-on-use + gradient reduce-scatter volume instead
    (:func:`repro.core.comm_model.zero_volume_per_iter` — the same
    ``2(G-1)/G`` ring bytes an all-reduce moves, which is ZeRO's claim:
    sharding the state costs no extra steady-state volume).
    """
    if G <= 1:
        return 0.0
    if zero_stage >= 2:
        return hw.t_lat + (zero_volume_per_iter(param_bytes, G, zero_stage)
                           / hw.intra_bw)
    return t_allreduce(param_bytes, G, hw)


def t_sched_paper(
    prof: StageProfile, P: int, b: int, G: int, hw: Hardware,
    *, M: int | None = None, V: int = 1, wire_dtype: str = "bfloat16",
    overlap: bool = True, zero_stage: int = 0,
) -> float:
    """Eq. (15): (10P-4) T_f(b) + (10P-12)(t_lat + b M_o / B) + T_AR.

    The paper's closed form corresponds to M = P microbatches per
    iteration on the S = 2P wave: 6 T_f steady state per microbatch per
    device plus a ~4P ramp, i.e. (6M + 4P - 4) T_f at M = P.  Passing a
    different ``M`` prices that iteration shape with the same wave model
    (so custom ``microbatches_per_iter`` overrides in :func:`tune` are
    scored for the M they actually execute); ``tune`` records the scored M
    on ``TunerChoice.M`` and the executor runs the same iteration shape.

    ``V`` generalizes the form to the interleaved S = 2VP wave: the
    steady state becomes 6V unit tasks per microbatch per device and every
    unit task (compute *and* p2p event) counts one of the finer V-fold
    stages, so with ``prof`` profiled on the V-fold partition (t_f roughly
    1/V of the 2P fold's), the compute steady state is unchanged, the
    fill/drain ramp ``4P * t_f`` shrinks ~1/V, and the p2p event count
    grows ~V — exactly the bubble-vs-communication trade the interleave
    axis searches.  V = 1 is Eq. (15) verbatim.

    ``wire_dtype`` prices the boundary hops at the executor's wire format
    (``m_o`` is denominated at 2 bytes/element, so bf16 — the default —
    is a factor of 1 and fp32-wire doubles the hop bytes).  Until the
    liveness lowering landed, the table executors paid fp32 on every hop
    while this model priced bf16 — the executors now pay what Eq. (15)
    prices.

    ``overlap`` prices the double-buffered executors
    (``PipelineConfig.overlap``, the default): the ~``4P-12`` fill/drain
    ramp hops stay *exposed* (their consumer runs on the very next step,
    nothing to hide under) and cost full ``p2p``, while the ``6VM``
    steady-state hops ride under the next step's compute and only cost
    what that compute does not absorb, ``max(0, p2p - t_f)`` — the same
    split :class:`repro.core.comm_model.OverlapAccounting` prices from a
    lowered schedule.  ``overlap=False`` is the synchronous lowering:
    every hop serializes at full ``p2p`` (the historical form).
    """
    if M is None:
        M = P
    t_f = max(prof.fwd_time_per_sample) * b
    m_o = max(prof.out_bytes_per_sample) * b * wire_factor(wire_dtype)
    m_theta = max(prof.param_bytes)
    p2p = hw.t_lat + m_o / hw.inter_bw
    n_hops = max(6 * V * M + 4 * P - 12, 0)
    if overlap:
        n_ramp = min(max(4 * P - 12, 0), n_hops)
        t_comm = n_ramp * p2p + (n_hops - n_ramp) * max(0.0, p2p - t_f)
    else:
        t_comm = n_hops * p2p
    return (
        (6 * V * M + 4 * P - 4) * t_f
        + t_comm
        + t_grad_sync(m_theta, G, hw, zero_stage)
    )


def t_sched_simulated(
    prof: StageProfile, P: int, b: int, G: int, hw: Hardware,
    *, microbatches: int, wave: bool,
    part: "part_mod.Partition | None" = None,
    sched=None, wire_dtype: str = "bfloat16", overlap: bool = True,
    zero_stage: int = 0,
) -> float:
    """Higher-fidelity alternative: event-driven simulation of the actual
    schedule with per-stage durations (beyond-paper option).  With a
    ``part``, the schedule is synthesized for that partition's own
    stage->device mapping (required to price interleaved V > 1 plans);
    otherwise the classic V = 1 templates are simulated.  The schedule
    depends only on (part, microbatches) — callers sweeping b (the
    tuner's inner loop) should synthesize once and pass ``sched``.
    ``overlap`` selects whether cross-device sends occupy the sender
    (synchronous lowering) or ride under its next task (the
    double-buffered executors) — see :func:`repro.core.schedule.simulate`."""
    if sched is None:
        if part is not None:
            sched = schedule_for_partition(part, microbatches)
        else:
            sched = (template_wave(P, microbatches) if wave
                     else template_1f1b(P, microbatches))
    times = [t * b for t in prof.fwd_time_per_sample]
    m_o = max(prof.out_bytes_per_sample) * b * wire_factor(wire_dtype)
    mk, _ = simulate(sched, times, bwd_ratio=2.0,
                     p2p_time=hw.t_lat + m_o / hw.inter_bw,
                     overlap=overlap)
    return mk + t_grad_sync(max(prof.param_bytes), G, hw, zero_stage)


def tune(
    graph: BlockGraph,
    N: int,
    *,
    hw: Hardware = TPU_V5E,
    max_microbatch: int = 512,
    lam: float = 1.0,
    use_simulation: bool = False,
    microbatches_per_iter: Callable[[int], int] | None = None,
    drops: list[str] | None = None,
    interleave_options: Sequence[int] | None = None,
    wire_dtype: str = "bfloat16",
    overlap: bool = True,
    zero_stages: Sequence[int] = (0, 1, 2),
) -> list[TunerChoice]:
    """Enumerate (P, G, b) — and the interleave degree V for wave plans —
    and return all feasible choices, best first.

    ``N`` is the total device count.  ``microbatches_per_iter(P)`` defaults
    to M = P — the iteration shape Eq. (15)'s (10P-4) closed form prices
    (6*T_f steady-state per microbatch per device + ~4P ramp), which makes
    Eq. (17)'s denominator b*M*G the per-iteration sample count.  The M
    each choice was scored with is recorded on ``TunerChoice.M``;
    ``auto_pipeline`` executes that M.

    ``interleave_options`` lists the V values to search (default: (1, 2)
    for wave graphs, (1,) for skip-free ones).  Each V gets its own V-fold
    partition, profile, memory check (``peak_memory`` prices the V padded
    weight shards) and iteration-time score (the V-generalized Eq. (15),
    or the event-driven simulation of the interleaved schedule under
    ``use_simulation``) — V is a search axis exactly like (P, G, b), and
    the winning choice's V rides to the executor through its partition.

    ``drops`` (optional out-param) collects one human-readable reason per
    (pipeline degree, interleave degree) that yielded NO choice — recorded
    here, at the point each filter fires, so error reports read facts
    rather than re-simulating the filter (``auto_pipeline`` surfaces them
    when nothing survives).

    Every P > 1 candidate's schedule is synthesized and lowered to step
    tables here, so (a) ``peak_memory`` is checked against the
    schedule-proven liveness windows (rotating rx/turn buffers, not the
    dense ``O(P)`` in-flight sizing — memory-bound candidates admit
    larger microbatches) at ``wire_dtype`` hop bytes, and (b) plans whose
    schedule the executors cannot realize are dropped with a reason
    instead of failing later in ``auto_pipeline``.

    ``overlap`` must match the executor mode the winning choice will run
    (``PipelineConfig.overlap``): both scorers price hidden steady-state
    hops at ``max(0, p2p - t_f)`` when True and full ``p2p`` when False,
    so the tuner ranks candidates by the comm cost the lowering actually
    pays.

    ``zero_stages`` lists the ZeRO stages to search for every dp > 1
    candidate (dp is ``G``, the data axis of the hybrid mesh): 0
    replicates param/optimizer state, 1 shards the optimizer state over
    dp, 2 also shards params-at-rest + grads with an all-gather-on-use
    in the executor scan body.  ``peak_memory`` charges the sharded
    bytes and the scorers price the ZeRO collective volume
    (:func:`t_grad_sync`), so memory-bound big configs become feasible
    at higher stages — ties on modelled time break toward the *lowest*
    stage (least sharding machinery).  ``G == 1`` candidates only ever
    score stage 0 (there is nothing to shard over).
    """
    if microbatches_per_iter is None:
        microbatches_per_iter = lambda P: max(P, 1)
    wave = bool(graph.skips)
    if interleave_options is None:
        interleave_options = (1, 2) if wave else (1,)
    choices: list[TunerChoice] = []
    for P in sorted({d for d in range(1, N + 1) if N % d == 0}):
        G = N // P
        for V in (interleave_options if P > 1 else (1,)):
            vtag = f"P={P}" if V == 1 else f"P={P} V={V}"
            S = (2 * V * P if wave else V * P) if P > 1 else 1
            if S > graph.n or S < 1:
                if drops is not None:
                    drops.append(f"{vtag}: needs S={S} stages but the "
                                 f"graph has only {graph.n} blocks")
                continue
            try:
                if P == 1:
                    part = part_mod.Partition((0, graph.n), False, 0.0,
                                              (0.0,))
                else:
                    part = part_mod.partition(graph, P, hw=hw, lam=lam,
                                              force_wave=wave, interleave=V)
            except ValueError as e:
                if drops is not None:
                    drops.append(f"{vtag}: partitioner infeasible: {e}")
                continue
            prof = profile_partition(graph, part)
            M = microbatches_per_iter(P)
            # the synthesized schedule depends on (part, M) only — hoist
            # it out of the b sweep (the interleaved portfolio race is
            # the expensive part of simulation scoring), and lower it to
            # step tables for the liveness windows peak_memory prices
            sched = None
            windows = None
            if P > 1:
                # Deliberate layering exception: the windows charged here
                # must be EXACTLY the buffers the executor will allocate,
                # so the tuner reuses the executors' own (memoized)
                # lowering instead of re-deriving the liveness analysis
                # in core and risking divergence.  The import stays lazy
                # so planning modules don't pull jax in at import time.
                from repro.runtime.schedule_exec import StepTables
                try:
                    sched = schedule_for_partition(part, M)
                    tabs = StepTables.from_schedule(
                        sched, folded=bool(getattr(part, "folded", False)),
                        devices=part.devices)
                except (ValueError, RuntimeError) as e:
                    if drops is not None:
                        drops.append(f"{vtag}: schedule synthesis/lowering "
                                     f"infeasible: {e}")
                    continue
                windows = (tabs.W_down + tabs.W_up, tabs.W_turn,
                           tabs.W_skip)
            for z in (tuple(zero_stages) if G > 1 else (0,)):
                ztag = vtag if z == 0 else f"{vtag} zero{z}"
                b = 1
                while b <= max_microbatch:
                    mem = peak_memory(prof, max(P, 1), b,
                                      wave=wave and P > 1, V=V,
                                      windows=windows,
                                      wire_bytes=WIRE_BYTES[wire_dtype],
                                      dp=G, zero_stage=z)
                    if mem >= hw.mem_limit:
                        if b == 1 and drops is not None:
                            if z == 0:
                                drops.append(
                                    f"{ztag}: smallest microbatch already "
                                    f"exceeds the memory budget (peak "
                                    f"{mem / 1e9:.2f} GB >= "
                                    f"{hw.mem_limit / 1e9:.2f} GB)")
                            else:
                                drops.append(
                                    f"{ztag}: smallest microbatch exceeds "
                                    f"the memory budget even with ZeRO-{z} "
                                    f"param/optimizer state sharded over "
                                    f"dp={G} (peak {mem / 1e9:.2f} GB >= "
                                    f"{hw.mem_limit / 1e9:.2f} GB)")
                        break
                    if use_simulation and P > 1:
                        t_iter = t_sched_simulated(prof, P, b, G, hw,
                                                   microbatches=M, wave=wave,
                                                   part=part, sched=sched,
                                                   wire_dtype=wire_dtype,
                                                   overlap=overlap,
                                                   zero_stage=z)
                    elif P > 1:
                        t_iter = t_sched_paper(prof, P, b, G, hw, M=M, V=V,
                                               wire_dtype=wire_dtype,
                                               overlap=overlap,
                                               zero_stage=z)
                    else:
                        # pure DP: compute + gradient synchronization
                        t_f = sum(prof.fwd_time_per_sample) * b
                        t_iter = 3.0 * t_f * M + t_grad_sync(
                            sum(prof.param_bytes), G, hw, z
                        )
                    samples = b * M * G
                    choices.append(TunerChoice(
                        P=P, G=G, b=b,
                        t_sample=t_iter / samples,
                        t_sched=t_iter,
                        peak_mem=mem,
                        wave=wave and P > 1,
                        M=M,
                        V=V if P > 1 else 1,
                        partition=part,
                        zero_stage=z,
                    ))
                    b *= 2
    # ties on modelled time break toward the least sharding machinery
    choices.sort(key=lambda c: (c.t_sample, c.zero_stage))
    return choices


def shrink_plan(surviving_devices: int, *, dp: int, pp: int,
                zero_stage: int = 0,
                graph: BlockGraph | None = None,
                hw: Hardware = TPU_V5E) -> tuple[int, int, int]:
    """Re-plan ``(dp, pp, zero_stage)`` for a shrunken device pool — the
    supervisor's re-tune entry point after a host loss.

    With ``graph`` the full tuner re-runs on the surviving count
    (``tune(graph, surviving_devices, ...)``) and the best feasible
    choice wins — the paper's Eq. 14-17 machinery pricing the smaller
    cluster.  Without it (the supervisor is a jax-free process manager
    that does not hold the model graph) a deterministic structural
    policy applies:

    - keep the pipeline depth while it still fits (per-device weight
      shard size is set by ``pp``, so preserving it preserves memory
      feasibility) and shed data-parallel replicas first;
    - once even ``dp = 1`` cannot fund the old depth, halve ``pp`` until
      ``dp * pp <= surviving_devices`` (power-of-two descent mirrors the
      tuner's factorization lattice);
    - cap ``zero_stage`` by the new dp (sharding over one replica is a
      no-op: stage drops to 0 when ``dp`` reaches 1).

    Raises ``ValueError`` when no device survives.
    """
    if surviving_devices < 1:
        raise ValueError(
            f"cannot re-plan for {surviving_devices} surviving devices — "
            "the cluster is gone")
    if graph is not None:
        choices = tune(graph, surviving_devices, hw=hw,
                       zero_stages=tuple(sorted({0, zero_stage})))
        if choices:
            best = choices[0]
            return best.G, best.P, best.zero_stage
    new_pp = max(min(pp, surviving_devices), 1)
    while surviving_devices // new_pp < 1:
        new_pp = max(new_pp // 2, 1)
    new_dp = max(min(dp, surviving_devices // new_pp), 1)
    new_zero = zero_stage if new_dp > 1 else 0
    return new_dp, new_pp, new_zero
