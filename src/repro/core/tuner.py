"""Hybrid parallelism tuner (paper §VI, Eqs. 14-17).

Given per-stage profiled costs, enumerate every factorization ``N = P * G``
and every power-of-two microbatch size ``b``; reject configurations whose
peak memory (Eq. 14) exceeds the device budget; score the rest with the
iteration-time model (Eq. 15 + 16) and return the argmin of per-sample time
(Eq. 17).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.graph import BlockGraph
from repro.core.hw import Hardware, TPU_V5E
from repro.core import partition as part_mod
from repro.core.schedule import simulate, template_1f1b, template_wave


@dataclasses.dataclass(frozen=True)
class StageProfile:
    """Per-stage profiled quantities; indices follow pipeline stage order."""

    fwd_time_per_sample: tuple[float, ...]   # T_f^s(b) = b * this
    param_bytes: tuple[int, ...]             # M_theta^s
    act_bytes_per_sample: tuple[int, ...]    # M_a^s
    out_bytes_per_sample: tuple[int, ...]    # M_o^s

    @property
    def num_stages(self) -> int:
        return len(self.fwd_time_per_sample)


def profile_partition(graph: BlockGraph, part: part_mod.Partition) -> StageProfile:
    f, p, a, o = [], [], [], []
    for s in range(part.num_stages):
        lo, hi = part.stage_range(s)
        blocks = graph.blocks[lo:hi]
        f.append(sum(b.fwd_time for b in blocks))
        p.append(sum(b.param_bytes for b in blocks))
        a.append(sum(b.act_bytes + b.skip_bytes for b in blocks))
        o.append(blocks[-1].act_bytes)
    return StageProfile(tuple(f), tuple(p), tuple(a), tuple(o))


@dataclasses.dataclass(frozen=True)
class TunerChoice:
    P: int                 # pipeline-parallel degree (devices per pipeline)
    G: int                 # data-parallel replicas
    b: int                 # microbatch size
    t_sample: float        # modelled seconds per training sample (Eq. 17)
    t_sched: float         # modelled iteration time (Eq. 15)
    peak_mem: float        # modelled peak bytes (Eq. 14)
    wave: bool             # folded wave (S=2P) vs plain 1F1B (S=P)
    M: int = 1             # microbatches per iteration the score assumed —
    #   auto_pipeline executes this M so the iteration it runs is the one
    #   the tuner ranked (previously the executor silently ran M = 2D).
    partition: "part_mod.Partition | None" = None
    # ^ the partition this choice was scored on — the compile path
    #   (runtime.compile.auto_pipeline) lowers it directly.


def peak_memory(
    prof: StageProfile, P: int, b: int, *, wave: bool, param_state_factor: float = 7.0
) -> float:
    """Eq. (14).  The busiest devices are the innermost collocated pair
    (stages P-1 and P, 0-indexed) which retain activations for all
    in-flight microbatches (P of them in the wave steady state)."""
    if wave:
        i, j = P - 1, P  # innermost pair on the same device
        m_theta = prof.param_bytes[i] + prof.param_bytes[j]
        m_act = prof.act_bytes_per_sample[i] + prof.act_bytes_per_sample[j]
        m_out = prof.out_bytes_per_sample[i - 1] if i >= 1 else prof.out_bytes_per_sample[0]
    else:
        # 1F1B: stage 0 retains P microbatches
        m_theta = prof.param_bytes[0]
        m_act = prof.act_bytes_per_sample[0]
        m_out = prof.out_bytes_per_sample[0]
    return (
        param_state_factor * m_theta
        + P * m_act * b
        + P * m_out * b
    )


def t_allreduce(param_bytes: float, G: int, hw: Hardware) -> float:
    """Eq. (16): ring all-reduce of the largest stage's gradients."""
    if G <= 1:
        return 0.0
    return hw.t_lat + 2.0 * (G - 1) * param_bytes / (G * hw.intra_bw)


def t_sched_paper(
    prof: StageProfile, P: int, b: int, G: int, hw: Hardware,
    *, M: int | None = None,
) -> float:
    """Eq. (15): (10P-4) T_f(b) + (10P-12)(t_lat + b M_o / B) + T_AR.

    The paper's closed form corresponds to M = P microbatches per
    iteration on the S = 2P wave: 6 T_f steady state per microbatch per
    device plus a ~4P ramp, i.e. (6M + 4P - 4) T_f at M = P.  Passing a
    different ``M`` prices that iteration shape with the same wave model
    (so custom ``microbatches_per_iter`` overrides in :func:`tune` are
    scored for the M they actually execute); ``tune`` records the scored M
    on ``TunerChoice.M`` and the executor runs the same iteration shape."""
    if M is None:
        M = P
    t_f = max(prof.fwd_time_per_sample) * b
    m_o = max(prof.out_bytes_per_sample) * b
    m_theta = max(prof.param_bytes)
    p2p = hw.t_lat + m_o / hw.inter_bw
    return (
        (6 * M + 4 * P - 4) * t_f
        + max(6 * M + 4 * P - 12, 0) * p2p
        + t_allreduce(m_theta, G, hw)
    )


def t_sched_simulated(
    prof: StageProfile, P: int, b: int, G: int, hw: Hardware,
    *, microbatches: int, wave: bool,
) -> float:
    """Higher-fidelity alternative: event-driven simulation of the actual
    template schedule with per-stage durations (beyond-paper option)."""
    sched = template_wave(P, microbatches) if wave else template_1f1b(P, microbatches)
    times = [t * b for t in prof.fwd_time_per_sample]
    m_o = max(prof.out_bytes_per_sample) * b
    mk, _ = simulate(sched, times, bwd_ratio=2.0,
                     p2p_time=hw.t_lat + m_o / hw.inter_bw)
    return mk + t_allreduce(max(prof.param_bytes), G, hw)


def tune(
    graph: BlockGraph,
    N: int,
    *,
    hw: Hardware = TPU_V5E,
    max_microbatch: int = 512,
    lam: float = 1.0,
    use_simulation: bool = False,
    microbatches_per_iter: Callable[[int], int] | None = None,
    drops: list[str] | None = None,
) -> list[TunerChoice]:
    """Enumerate (P, G, b) and return all feasible choices, best first.

    ``N`` is the total device count.  ``microbatches_per_iter(P)`` defaults
    to M = P — the iteration shape Eq. (15)'s (10P-4) closed form prices
    (6*T_f steady-state per microbatch per device + ~4P ramp), which makes
    Eq. (17)'s denominator b*M*G the per-iteration sample count.  The M
    each choice was scored with is recorded on ``TunerChoice.M``;
    ``auto_pipeline`` executes that M.

    ``drops`` (optional out-param) collects one human-readable reason per
    pipeline degree that yielded NO choice — recorded here, at the point
    each filter fires, so error reports read facts rather than
    re-simulating the filter (``auto_pipeline`` surfaces them when nothing
    survives).
    """
    if microbatches_per_iter is None:
        microbatches_per_iter = lambda P: max(P, 1)
    wave = bool(graph.skips)
    choices: list[TunerChoice] = []
    for P in sorted({d for d in range(1, N + 1) if N % d == 0}):
        G = N // P
        if wave and P >= 1:
            S = 2 * P
        else:
            S = P
        if S > graph.n or S < 1:
            if drops is not None:
                drops.append(f"P={P}: needs S={S} stages but the graph "
                             f"has only {graph.n} blocks")
            continue
        try:
            if P == 1:
                part = part_mod.Partition((0, graph.n), False, 0.0, (0.0,))
            else:
                part = part_mod.partition(graph, P, hw=hw, lam=lam,
                                          force_wave=wave)
        except ValueError as e:
            if drops is not None:
                drops.append(f"P={P}: partitioner infeasible: {e}")
            continue
        prof = profile_partition(graph, part)
        b = 1
        while b <= max_microbatch:
            mem = peak_memory(prof, max(P, 1), b, wave=wave and P > 1)
            if mem >= hw.mem_limit:
                if b == 1 and drops is not None:
                    drops.append(
                        f"P={P}: smallest microbatch already exceeds the "
                        f"memory budget (peak {mem / 1e9:.2f} GB >= "
                        f"{hw.mem_limit / 1e9:.2f} GB)")
                break
            M = microbatches_per_iter(P)
            if use_simulation and P > 1:
                t_iter = t_sched_simulated(prof, P, b, G, hw,
                                           microbatches=M, wave=wave)
            elif P > 1:
                t_iter = t_sched_paper(prof, P, b, G, hw, M=M)
            else:
                # pure DP: compute + all-reduce
                t_f = sum(prof.fwd_time_per_sample) * b
                t_iter = 3.0 * t_f * M + t_allreduce(
                    sum(prof.param_bytes), G, hw
                )
            samples = b * M * G
            choices.append(TunerChoice(
                P=P, G=G, b=b,
                t_sample=t_iter / samples,
                t_sched=t_iter,
                peak_mem=mem,
                wave=wave and P > 1,
                M=M,
                partition=part,
            ))
            b *= 2
    choices.sort(key=lambda c: c.t_sample)
    return choices
