"""Hardware models used by the partitioner / tuner / roofline analysis.

The paper profiles V100 (NVLink + IB) and Ascend 910A clusters; our target
is a TPU v5e pod, so that is the default.  All benchmark scripts can swap in
the paper's clusters to reproduce its analytic numbers.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # per-chip peak (bf16/fp16) FLOP/s
    hbm_bw: float              # per-chip HBM bytes/s
    intra_bw: float            # effective intra-node / intra-pod link bytes/s
    inter_bw: float            # effective inter-node / inter-pod bytes/s
    mem_limit: float           # per-device memory budget (bytes)
    t_lat: float = 5e-6        # static latency of a communication kernel (s)


# TPU v5e constants given by the assignment: 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s per ICI link.  DCN (inter-pod) is far slower; 25 GB/s effective.
TPU_V5E = Hardware(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    intra_bw=50e9,
    inter_bw=25e9,
    mem_limit=16 * (1 << 30),
)

# Paper's clusters (Section VII): used to reproduce paper-table numbers.
V100_CLUSTER = Hardware(
    name="v100-2node",
    peak_flops=125e12,          # V100 tensor-core fp16
    hbm_bw=900e9,
    intra_bw=300e9,             # NVLink
    inter_bw=10e9,              # InfiniBand
    mem_limit=32 * (1 << 30),
)

ASCEND_910A_CLUSTER = Hardware(
    name="ascend910a-8node",
    peak_flops=256e12,
    hbm_bw=1228e9,
    intra_bw=30e9,
    inter_bw=19e9,
    mem_limit=32 * (1 << 30),
)


PRESETS = {h.name: h for h in (TPU_V5E, V100_CLUSTER, ASCEND_910A_CLUSTER)}
