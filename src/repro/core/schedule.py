"""Pipeline schedule synthesis under collocation constraints (paper §V).

Tasks are *virtual stages*: for a partition with S pipeline stages, each
microbatch m executes the chain

    F_0 -> F_1 -> ... -> F_{S-1} -> B_{S-1} -> ... -> B_0

(2S unit tasks).  F_s and B_s run on the stage's device; skip collocation
pins stage s and its mirror onto one device (folded mapping).

Components:

- ``ilp_schedule``     — the paper's ILP (Eqs. 6-13) via scipy/HiGHS; exact
                         bubble-minimal schedules for small instances.
                         Supports free device mapping or a fixed mapping.
- ``greedy_schedule``  — scalable template generator (backward-first list
                         scheduling).  Recovers classic 1F1B when S == D and
                         the Hanayo-style wave when S == 2D folded; this is
                         the "replicate the small-instance pattern" mechanism
                         of §V-B.
- ``validate_schedule`` — checks all six constraint families.
- ``simulate``          — event-driven makespan with real per-stage durations
                          and p2p latency; bubble-ratio reporting.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import numpy as np


# --------------------------------------------------------------------------
# Virtual-stage helpers
# --------------------------------------------------------------------------

def num_virtual(S: int) -> int:
    return 2 * S

def stage_of_virtual(v: int, S: int) -> int:
    return v if v < S else 2 * S - 1 - v

def is_backward(v: int, S: int) -> bool:
    return v >= S


@dataclasses.dataclass(frozen=True)
class Placement:
    virtual: int      # virtual stage index (0..2S-1)
    microbatch: int
    device: int
    step: int         # scheduling step (unit slot)


@dataclasses.dataclass(frozen=True)
class DevicePrograms:
    """Dense per-device step programs lowered from a :class:`Schedule`.

    Three ``[D, makespan]`` arrays: ``virtual[d, t]`` / ``microbatch[d, t]``
    give the task device ``d`` runs at step ``t`` (``-1`` when idle) and
    ``valid[d, t]`` marks occupied slots.  This is ``Schedule.grid()`` in
    array form — the lowering-facing representation the table-driven
    executors (``runtime.schedule_exec``) consume, and the thing to print
    next to :meth:`Schedule.to_ascii` when debugging a plan.
    """

    virtual: np.ndarray
    microbatch: np.ndarray
    valid: np.ndarray

    @property
    def num_devices(self) -> int:
        return self.virtual.shape[0]

    @property
    def num_steps(self) -> int:
        return self.virtual.shape[1]


@dataclasses.dataclass(frozen=True)
class Schedule:
    S: int            # pipeline stages
    M: int            # microbatches
    D: int            # devices
    placements: tuple[Placement, ...]

    @property
    def makespan(self) -> int:
        if not self.placements:
            raise ValueError(
                f"schedule (S={self.S}, M={self.M}, D={self.D}) has no "
                "placements — makespan is undefined on an empty schedule "
                "(validate_schedule reports this as a family (6) violation)")
        return 1 + max(p.step for p in self.placements)

    def grid(self) -> list[list[Placement | None]]:
        g: list[list[Placement | None]] = [
            [None] * self.makespan for _ in range(self.D)
        ]
        for p in self.placements:
            g[p.device][p.step] = p
        return g

    def device_programs(self) -> DevicePrograms:
        """Extract the per-device step programs as dense arrays.

        The arrays agree with :meth:`grid` slot-for-slot (property-tested);
        executors lower *these*, so what runs is exactly what was
        synthesized and validated.  Raises ``ValueError`` (not an opaque
        ``IndexError``) on out-of-range placements — ``validate_schedule``
        reports the same malformations as constraint family (7).

        Memoized per schedule (schedules are frozen/hashable): the tuner's
        candidate loop and repeated ``auto_pipeline`` calls reuse the
        O(S*M*steps) lowering instead of recomputing it.  Treat the
        returned arrays as read-only.
        """
        return _device_programs_cached(self)

    def _device_programs_uncached(self) -> DevicePrograms:
        T = self.makespan
        for p in self.placements:
            err = placement_bounds_error(p, self.S, self.M, self.D)
            if err is not None:
                raise ValueError(
                    f"placement v={p.virtual} m={p.microbatch}: {err}; "
                    "run validate_schedule for the full report")
        virt = np.full((self.D, T), -1, dtype=np.int32)
        mb = np.full((self.D, T), -1, dtype=np.int32)
        valid = np.zeros((self.D, T), dtype=bool)
        for p in self.placements:
            virt[p.device, p.step] = p.virtual
            mb[p.device, p.step] = p.microbatch
            valid[p.device, p.step] = True
        return DevicePrograms(virt, mb, valid)

    def bubble_ratio(self) -> float:
        if not self.placements:
            raise ValueError(
                f"schedule (S={self.S}, M={self.M}, D={self.D}) has no "
                "placements — bubble_ratio is undefined on an empty "
                "schedule (validate_schedule reports this as a family (6) "
                "violation)")
        busy = len(self.placements)
        return 1.0 - busy / (self.D * self.makespan)

    def device_of_stage_map(self) -> dict[int, int]:
        out: dict[int, int] = {}
        for p in self.placements:
            s = stage_of_virtual(p.virtual, self.S)
            out.setdefault(s, p.device)
        return out

    def to_ascii(self) -> str:
        """Fig. 8/9-style diagram: rows = devices, columns = steps."""
        g = self.grid()
        width = max(3, len(str(self.M - 1)) + 2)
        lines = []
        for d, row in enumerate(g):
            cells = []
            for p in row:
                if p is None:
                    cells.append("." * width)
                else:
                    kind = "B" if is_backward(p.virtual, self.S) else "F"
                    s = stage_of_virtual(p.virtual, self.S)
                    cells.append(f"{kind}{s}{p.microbatch}".ljust(width))
            lines.append(f"d{d}| " + " ".join(cells))
        return "\n".join(lines)


@functools.lru_cache(maxsize=256)
def _device_programs_cached(sched: Schedule) -> DevicePrograms:
    return sched._device_programs_uncached()


# --------------------------------------------------------------------------
# Validation (paper constraints (6)-(11))
# --------------------------------------------------------------------------

def placement_bounds_error(p: Placement, S: int, M: int, D: int
                           ) -> str | None:
    """Bounds check shared by validate_schedule / device_programs /
    StepTables lowering — one source of truth for what 'in bounds' means.

    Microbatch/virtual bounds matter as much as device/step: the executors
    index [M]-sized buffers with clamped dynamic indices, so an
    out-of-range microbatch would silently corrupt microbatch M-1's slots
    instead of failing.
    """
    if not 0 <= p.virtual < num_virtual(S):
        return f"virtual stage {p.virtual} out of range [0, {num_virtual(S)})"
    if not 0 <= p.microbatch < M:
        return f"microbatch {p.microbatch} out of range [0, {M})"
    if not 0 <= p.device < D:
        return f"device {p.device} out of range [0, {D})"
    if p.step < 0:
        return f"negative step {p.step}"
    return None

def slot_maps(S: int, D: int, folded: bool,
              device_of_stage: Callable[[int], int]
              ) -> tuple[int, dict[int, int], dict[int, int]]:
    """(V, enc_slot_of_stage, dec_slot_of_stage) for a stage->device map.

    A device's stages of one kind (encoder-half s < S/2, decoder-half
    otherwise; everything is 'encoder' for linear pipelines), sorted by
    stage id, occupy slots 0..V-1.  Every device must hold the same slot
    count per kind — the SPMD executors run one program with [V, pad, ...]
    parameter stacks, so a ragged slot layout is unliftable and raises
    here with per-device context.
    """
    half = S // 2 if folded else S
    enc_by_dev: dict[int, list[int]] = {}
    dec_by_dev: dict[int, list[int]] = {}
    for s in range(S):
        (enc_by_dev if s < half else dec_by_dev).setdefault(
            device_of_stage(s), []).append(s)
    counts = {d: (len(enc_by_dev.get(d, ())), len(dec_by_dev.get(d, ())))
              for d in range(D)}
    kinds = set(counts.values())
    ok = len(kinds) == 1
    if ok:
        e, c = next(iter(kinds))
        ok = e > 0 and ((e == c) if folded else (c == 0))
    if not ok:
        detail = ", ".join(
            f"device {d}: {e} prefix-half + {c} suffix-half slots"
            if folded else f"device {d}: {e} stage slots"
            for d, (e, c) in sorted(counts.items()))
        raise ValueError(
            f"stage->device mapping is not an even interleave over D={D} "
            f"devices ({detail}); the table executors need V equal slots "
            "per device and kind")
    V = next(iter(kinds))[0]
    enc_slot = {s: k for ss in enc_by_dev.values()
                for k, s in enumerate(sorted(ss))}
    dec_slot = {s: k for ss in dec_by_dev.values()
                for k, s in enumerate(sorted(ss))}
    return V, enc_slot, dec_slot


def _slot_context(S: int, device_of_stage: Callable[[int], int] | None,
                  folded: bool = False) -> Callable[[int], str]:
    """Virtual task -> ``[stage s = device d enc slot k/V, wave w]`` label.

    Interleaved schedules place several stage slots per device; constraint
    errors name the slot and the wave (the w-th forward visit of that
    device) so an infeasible interleaved plan reads as *which slot of
    which device* went wrong, not just a bare stage index.  With
    ``folded`` the slot index counts within the stage's kind (encoder
    half s < S/2 vs decoder half) — the same numbering ``StageLayout``,
    ``StepTables`` and the executors use — while the wave counts across
    both kinds.  Degenerates to the empty label for one-slot devices and
    when no mapping is supplied.
    """
    if device_of_stage is None:
        return lambda v: ""
    by_dev: dict[int, list[int]] = {}
    for s in range(S):
        by_dev.setdefault(device_of_stage(s), []).append(s)
    info: dict[int, str] = {}
    for d, ss in by_dev.items():
        ss = sorted(ss)
        if len(ss) <= 1:
            continue
        for w, s in enumerate(ss):
            if folded:
                same = [t for t in ss if (t < S // 2) == (s < S // 2)]
                kind = "enc " if s < S // 2 else "dec "
                k, n = same.index(s), len(same)
            else:
                kind, k, n = "", w, len(ss)
            info[s] = (f" [stage {s} = device {d} {kind}slot {k}/{n}, "
                       f"wave {w}]")

    def ctx(v: int) -> str:
        return info.get(stage_of_virtual(v, S), "")

    return ctx


def validate_schedule(
    sched: Schedule,
    device_of_stage: Callable[[int], int] | None = None,
    collocated: Sequence[tuple[int, int]] = (),
    folded: bool = False,
) -> list[str]:
    """Return a list of violated-constraint descriptions (empty == valid).

    ``folded`` only affects error *labels*: multi-slot devices get their
    per-kind (enc/dec) slot numbering in slot-context messages."""
    errors: list[str] = []
    S, M, D = sched.S, sched.M, sched.D
    if not sched.placements:
        # One aggregate violation instead of 2*S*M missing-task lines: a
        # placement-free schedule is a malformed *schedule*, not 2SM
        # individually missing tasks, and makespan/bubble_ratio raise on
        # it with the same diagnosis.
        return [f"(6) schedule (S={S}, M={M}, D={D}) has no placements "
                f"(expected {num_virtual(S) * M} tasks)"]
    ctx = _slot_context(S, device_of_stage, folded)
    # Placement bounds first (family (7)): an out-of-range virtual stage,
    # microbatch, device, or negative step would otherwise pass validation
    # and crash later in grid()/device_programs()/lowering with an opaque
    # IndexError — or worse, silently corrupt a clamped buffer slot.
    for p in sched.placements:
        err = placement_bounds_error(p, S, M, D)
        if err is not None:
            where = ctx(p.virtual) if 0 <= p.virtual < num_virtual(S) else ""
            errors.append(f"(7) v={p.virtual} m={p.microbatch}: {err}{where}")
    seen: dict[tuple[int, int], Placement] = {}
    for p in sched.placements:
        key = (p.virtual, p.microbatch)
        if key in seen:
            errors.append(f"(6) duplicate assignment {key}")
        seen[key] = p
    for v in range(num_virtual(S)):
        for m in range(M):
            if (v, m) not in seen:
                errors.append(f"(6) missing task v={v} m={m}")
    if errors:
        return errors

    # (7) device exclusivity (bounds were checked up front)
    busy: dict[tuple[int, int], Placement] = {}
    for p in sched.placements:
        key = (p.device, p.step)
        if key in busy:
            q = busy[key]
            errors.append(
                f"(7) device {p.device} double-booked at t={p.step}: "
                f"v={q.virtual}{ctx(q.virtual)} and v={p.virtual}"
                f"{ctx(p.virtual)}")
        busy[key] = p

    # (8) fixed device mapping per stage (and F/B of a stage share a device)
    dev_of: dict[int, int] = {}
    for p in sched.placements:
        s = stage_of_virtual(p.virtual, S)
        if s in dev_of and dev_of[s] != p.device:
            errors.append(f"(8) stage {s} on devices {dev_of[s]} and {p.device}")
        dev_of.setdefault(s, p.device)
    if device_of_stage is not None:
        for s, d in dev_of.items():
            if device_of_stage(s) != d:
                errors.append(f"(8) stage {s} expected dev {device_of_stage(s)} got {d}")

    # (9) collocation
    for s1, s2 in collocated:
        if dev_of.get(s1) != dev_of.get(s2):
            errors.append(f"(9) stages {s1},{s2} not collocated")

    # (10) sequential execution within a microbatch
    for m in range(M):
        for v in range(1, num_virtual(S)):
            if seen[(v, m)].step < seen[(v - 1, m)].step + 1:
                errors.append(f"(10) v={v}{ctx(v)} m={m} starts before "
                              "v-1 finishes")

    # (11) monotonic microbatch ordering per stage
    for v in range(num_virtual(S)):
        for m in range(1, M):
            if seen[(v, m)].step <= seen[(v, m - 1)].step:
                errors.append(f"(11) v={v}{ctx(v)}: m={m} not after m={m-1}")
    return errors


# --------------------------------------------------------------------------
# Planner-side communication statistics (liveness windows + overlap slack)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScheduleCommStats:
    """Ring-message accounting of a schedule's forward placements.

    The planning-layer mirror of the executor lowering's channel analysis
    (``runtime.schedule_exec.StepTables``): per-ring liveness windows (max
    simultaneously-live receive-buffer entries) and the exposed-vs-hidden
    hop split the overlapped executors realize.  A hop is **exposed** when
    its consumer runs on the very next forward step — the arrival's
    dependency forces the collective onto the critical path — and
    **hidden** otherwise (the receive slot is dead until the consumer
    runs, so the overlapped executor prefetches it under intervening
    compute).  Pure host-side analysis (no jax import); the property
    tests hold it to agree with the lowered ``StepTables`` field for
    field, the same way ``lowered_comm_volume`` is held to the measured
    HLO bytes.
    """

    W_down: int
    W_up: int
    W_turn: int
    W_skip: int
    exposed_down: int
    exposed_up: int
    hidden_down: int
    hidden_up: int

    @property
    def exposed_hops(self) -> int:
        return self.exposed_down + self.exposed_up

    @property
    def hidden_hops(self) -> int:
        return self.hidden_down + self.hidden_up

    @property
    def live_hops(self) -> tuple[int, int]:
        return (self.exposed_down + self.hidden_down,
                self.exposed_up + self.hidden_up)

    @property
    def window_total(self) -> int:
        return self.W_down + self.W_up + self.W_turn + self.W_skip


def comm_stats(sched: Schedule, device_of_stage: Callable[[int], int],
               folded: bool) -> ScheduleCommStats:
    """Compute :class:`ScheduleCommStats` for a valid schedule.

    Uses the same message model as the executor lowering: an enc->enc
    boundary rides the down ring, dec->dec the up ring; a message is live
    in its receiver's buffer from the step after its producer until its
    consumer runs; the turnaround and the (conservative, all-slots) skip
    stash are device-local lifetimes.  Windows are max-overlap counts per
    device, so they equal the first-fit coloring's slot counts.
    """
    S, M = sched.S, sched.M
    half = S // 2 if folded else S
    fwd = [p for p in sched.placements if p.virtual < S]
    steps = sorted({p.step for p in fwd})
    k_of_step = {t: k for k, t in enumerate(steps)}
    k_of = {(p.virtual, p.microbatch): k_of_step[p.step] for p in fwd}

    def peak(ivs_by_dev: dict[int, list[tuple[int, int]]]) -> int:
        best = 0
        for ivs in ivs_by_dev.values():
            events: dict[int, int] = {}
            for a, b in ivs:
                events[a] = events.get(a, 0) + 1
                events[b + 1] = events.get(b + 1, 0) - 1
            live = 0
            for k in sorted(events):
                live += events[k]
                best = max(best, live)
        return best

    rings: dict[str, dict[int, list[tuple[int, int]]]] = {
        "down": {}, "up": {}}
    exposed = {"down": 0, "up": 0}
    hidden = {"down": 0, "up": 0}
    for p in fwd:
        v, m = p.virtual, p.microbatch
        if v >= S - 1 or (folded and v == half - 1):
            continue                       # loss stage / local turnaround
        ring = "down" if v < half else "up"
        k_prod, k_cons = k_of[(v, m)], k_of[(v + 1, m)]
        rings[ring].setdefault(device_of_stage(v + 1), []).append(
            (k_prod + 1, k_cons))
        if k_cons == k_prod + 1:
            exposed[ring] += 1
        else:
            hidden[ring] += 1

    turn: dict[int, list[tuple[int, int]]] = {}
    skip: dict[int, list[tuple[int, int]]] = {}
    if folded:
        for m in range(M):
            kw, kr = k_of.get((half - 1, m)), k_of.get((half, m))
            if kw is not None and kr is not None:
                turn.setdefault(device_of_stage(half - 1), []).append(
                    (kw, kr))
        last_dec: dict[tuple[int, int], int] = {}
        for p in fwd:
            if p.virtual >= half:
                key = (p.device, p.microbatch)
                k = k_of[(p.virtual, p.microbatch)]
                if last_dec.get(key, -1) < k:
                    last_dec[key] = k
        for p in fwd:
            if p.virtual < half:
                end = last_dec.get((p.device, p.microbatch))
                if end is not None:
                    skip.setdefault(p.device, []).append(
                        (k_of[(p.virtual, p.microbatch)], end))

    return ScheduleCommStats(
        W_down=peak(rings["down"]), W_up=peak(rings["up"]),
        W_turn=peak(turn), W_skip=peak(skip),
        exposed_down=exposed["down"], exposed_up=exposed["up"],
        hidden_down=hidden["down"], hidden_up=hidden["up"])


# --------------------------------------------------------------------------
# Greedy template generator (scalable; 1F1B / wave patterns)
# --------------------------------------------------------------------------

def greedy_schedule(
    S: int,
    M: int,
    device_of_stage: Callable[[int], int],
    D: int,
    *,
    backward_first: bool = True,
    max_steps: int | None = None,
) -> Schedule:
    """Backward-first list scheduling.

    Reproduces 1F1B when S == D with the identity mapping, and the wave
    schedule when S == 2D with the folded mapping (paper Figs. 8/9).
    """
    V = num_virtual(S)
    done_at = -np.ones((V, M), dtype=int)      # finish step of each task
    placed: list[Placement] = []
    remaining = V * M
    t = 0
    horizon = max_steps or (V * M + 4 * (S + M))
    while remaining and t < horizon:
        for d in range(D):
            best = None
            for v in range(V):
                if device_of_stage(stage_of_virtual(v, S)) != d:
                    continue
                for m in range(M):
                    if done_at[v, m] >= 0:
                        continue
                    if v > 0 and not (0 <= done_at[v - 1, m] <= t - 1):
                        break  # chain: earlier microbatches of this v first
                    if m > 0 and done_at[v, m - 1] < 0:
                        continue
                    if m > 0 and done_at[v, m - 1] > t - 1:
                        continue
                    # candidate; rank: backward first, then microbatch, then depth
                    key = (
                        0 if (backward_first and is_backward(v, S)) else 1,
                        m,
                        -v,
                    )
                    if best is None or key < best[0]:
                        best = (key, v, m)
                    break  # only the first pending microbatch of v is eligible
            if best is not None:
                _, v, m = best
                placed.append(Placement(v, m, d, t))
                done_at[v, m] = t
                remaining -= 1
        t += 1
    if remaining:
        raise RuntimeError("greedy scheduler did not finish within horizon")
    return Schedule(S, M, D, tuple(placed))


# Tie-break orientations greedy_schedule_timed accepts; the interleaved
# portfolio in schedule_for_partition races all of them.
TIMED_PRIORITIES = ("backward", "forward", "critical_path", "window")

# Portfolio candidates whose simulated makespan lands within this relative
# band of the best compete on liveness windows / exposed hops instead of
# raw makespan: below 1% the event-driven model's fidelity cannot rank
# candidates (it ignores launch overheads and overlap jitter), while the
# windows are exact executor buffer memory.  The band is the hard bound on
# how much modelled makespan a buffer win may spend.
MAKESPAN_BAND = 0.01


def greedy_schedule_timed(
    S: int,
    M: int,
    device_of_stage: Callable[[int], int],
    D: int,
    times: Sequence[float],
    *,
    bwd_ratio: float = 2.0,
    p2p_time: float = 0.0,
    priority: str = "backward",
) -> Schedule:
    """Duration-aware list scheduling: event-driven over real per-stage
    durations, then layered back onto unit steps.

    The unit-slot greedy models every task as one slot, which misorders
    interleaved (V > 1) plans whose fine stages have heterogeneous
    durations — the drain fills with avoidable stalls.  Here each device
    picks, at its next free instant, the eligible task with the earliest
    real start time; ties break by ``priority``:

    - ``"backward"`` — backward tasks first (the unit greedy's 1F1B rule);
    - ``"forward"`` — forward tasks first (keeps downstream devices fed
      through the interleave's extra fill phases);
    - ``"critical_path"`` — longest remaining chain duration first
      (HEFT-style upward rank; packs the drain the way the ILP does);
    - ``"window"`` — oldest-resident input first: among equally-early
      candidates, run the task whose predecessor finished *earliest*, so
      arrivals drain FIFO.  A consumed arrival frees its receive slot, so
      this orientation directly targets small liveness windows (W_down /
      W_up) and leaves later-arriving messages the most overlap slack;
      embeds (no arrival) yield to any task with a resident input.

    None of the orientations dominates on interleaved mappings, so
    :func:`schedule_for_partition` races all of them.  The resulting
    per-device *order* is layered onto unit steps (longest-path over the
    chain / monotone / exclusivity constraints), producing a valid
    :class:`Schedule` whose order ``simulate`` — and the table-driven
    executors — replay exactly.
    """
    if priority not in TIMED_PRIORITIES:
        raise ValueError(
            f"unknown priority {priority!r}; expected one of "
            f"{TIMED_PRIORITIES}")
    V = num_virtual(S)
    dur_of = [times[stage_of_virtual(v, S)] * (
        bwd_ratio if is_backward(v, S) else 1.0) for v in range(V)]
    rem = [0.0] * (V + 1)           # remaining chain duration from v
    for v in range(V - 1, -1, -1):
        rem[v] = rem[v + 1] + dur_of[v]

    start: dict[tuple[int, int], float] = {}
    finish: dict[tuple[int, int], float] = {}

    def tie_key(v: int, m: int):
        if priority == "critical_path":
            return (-rem[v], m)
        if priority == "window":
            # FIFO over resident inputs: the earliest-finished predecessor
            # has occupied its receive slot longest — consuming it first
            # keeps the rx liveness windows small.  Tasks with no arrival
            # (embeds) defer to any task holding a slot.
            arr = finish[(v - 1, m)] if v > 0 else float("inf")
            return (arr, m, -v)
        bwd_first = priority == "backward"
        return (0 if (bwd_first == is_backward(v, S)) else 1, m, -v)
    dev_free = [0.0] * D
    next_m = [0] * V        # lowest pending microbatch per v (monotone)
    dev_of_v = [device_of_stage(stage_of_virtual(v, S)) for v in range(V)]
    n_left = V * M
    while n_left:
        best = None
        for d in range(D):
            for v in range(V):
                m = next_m[v]
                if m >= M or dev_of_v[v] != d:
                    continue
                if v > 0 and (v - 1, m) not in finish:
                    continue
                ready = 0.0
                if v > 0:
                    ready = finish[(v - 1, m)]
                    if dev_of_v[v - 1] != d:
                        ready += p2p_time
                if m > 0:
                    ready = max(ready, start[(v, m - 1)])
                est = max(ready, dev_free[d])
                key = (est,) + tie_key(v, m)
                if best is None or key < best[0]:
                    best = (key, d, v, m)
        if best is None:
            raise RuntimeError("timed greedy deadlocked")
        (est, *_), d, v, m = best
        dur = dur_of[v]
        start[(v, m)] = est
        finish[(v, m)] = est + dur
        dev_free[d] = est + dur
        next_m[v] += 1
        n_left -= 1
    # layer onto unit steps in global start order (device order preserved;
    # same-device starts are strictly ordered by the event loop)
    order = sorted(start, key=lambda vm: (start[vm], vm[1], vm[0]))
    step: dict[tuple[int, int], int] = {}
    dev_last = [-1] * D
    for (v, m) in order:
        t = dev_last[dev_of_v[v]] + 1
        if v > 0:
            t = max(t, step[(v - 1, m)] + 1)
        if m > 0:
            t = max(t, step[(v, m - 1)] + 1)
        step[(v, m)] = t
        dev_last[dev_of_v[v]] = t
    return Schedule(S, M, D, tuple(
        Placement(v, m, dev_of_v[v], step[(v, m)]) for (v, m) in order))


def template_1f1b(D: int, M: int) -> Schedule:
    """Classic 1F1B: S == D stages, identity mapping (paper Fig. 8)."""
    return greedy_schedule(D, M, lambda s: s, D)


def template_wave(D: int, M: int) -> Schedule:
    """PULSE wave: S == 2D folded stages (paper Fig. 9)."""
    S = 2 * D
    return greedy_schedule(S, M, lambda s: min(s, S - 1 - s), D)


def template_interleaved(D: int, M: int, V: int) -> Schedule:
    """Interleaved wave: S == 2VD folded stages, cyclic slot placement
    (uniform durations; partition-driven synthesis races duration-aware
    candidates — see :func:`schedule_for_partition`)."""
    from repro.core.partition import interleaved_wave_devices
    devices = interleaved_wave_devices(2 * V * D, D)
    return greedy_schedule(2 * V * D, M, lambda s: devices[s], D)


def schedule_for_partition(part, M: int, *, use_ilp: bool = False,
                           time_limit: float = 120.0) -> Schedule:
    """Synthesize + validate a schedule for a partitioner output.

    ``part`` is any object with the :class:`~repro.core.partition.Partition`
    interface (num_stages / num_devices / device_of_stage /
    collocated_pairs).  Greedy template synthesis by default (recovers 1F1B
    and the wave pattern, §V-B); ``use_ilp`` solves Eqs. (6)-(13) exactly.

    Interleaved partitions (more than one stage slot pair per device) race
    a small candidate portfolio — the unit-slot greedy plus the
    duration-aware :func:`greedy_schedule_timed` in every priority
    orientation (including the window-minimizing ``"window"``
    tie-break) — because no single list-scheduling priority dominates
    once a device multiplexes V slots.  Candidates are scored in two
    passes: simulated makespan first; candidates within
    :data:`MAKESPAN_BAND` of the best then compete on total liveness
    windows (W_down + W_up + W_turn + W_skip — the buffers the executors
    allocate), then exposed hops (messages whose consumer runs on the
    very next step, which the overlapped executors cannot hide under
    compute), with makespan as the final tie-break.  The windows and
    overlap slack are optimization terms of the synthesis, not post-hoc
    measurements; the band bounds how much modelled makespan a buffer
    win may spend — below it the cost model's fidelity cannot separate
    candidates, while the windows are exact executor memory.  V = 1
    plans keep the exact paper templates.

    Raises ``ValueError`` listing every violated constraint if the
    synthesized schedule is invalid — planning bugs surface here, before an
    executor is built.
    """
    S, D = part.num_stages, part.num_devices
    if use_ilp:
        sched = ilp_schedule(S, M, D, device_of_stage=part.device_of_stage,
                             collocated=part.collocated_pairs(),
                             time_limit=time_limit)
    else:
        folded = bool(getattr(part, "folded", False))
        interleaved = S > (2 * D if folded else D)
        if interleaved:
            times = getattr(part, "stage_costs", None) or (1.0,) * S
            cands = [greedy_schedule(S, M, part.device_of_stage, D)] + [
                greedy_schedule_timed(S, M, part.device_of_stage, D, times,
                                      priority=prio)
                for prio in TIMED_PRIORITIES
            ]
            scored = [(simulate(s, times)[0], s) for s in cands]
            best_mk = min(mk for mk, _ in scored)
            near = [(mk, s) for mk, s in scored
                    if mk <= best_mk * (1.0 + MAKESPAN_BAND)]

            def residency(entry: tuple[float, Schedule]):
                mk, s = entry
                st = comm_stats(s, part.device_of_stage, folded)
                return (st.window_total, st.exposed_hops, mk)

            sched = min(near, key=residency)[1]
        else:
            sched = greedy_schedule(S, M, part.device_of_stage, D)
    errors = validate_schedule(sched, part.device_of_stage,
                               collocated=part.collocated_pairs(),
                               folded=getattr(part, "folded", False))
    if errors:
        raise ValueError(
            f"synthesized schedule violates constraints: {errors[:5]}"
            + (f" (+{len(errors) - 5} more)" if len(errors) > 5 else ""))
    return sched


# --------------------------------------------------------------------------
# ILP synthesizer (paper Eqs. (6)-(13)) via scipy HiGHS
# --------------------------------------------------------------------------

def ilp_schedule(
    S: int,
    M: int,
    D: int,
    *,
    device_of_stage: Callable[[int], int] | None = None,
    collocated: Sequence[tuple[int, int]] = (),
    horizon: int | None = None,
    time_limit: float = 120.0,
) -> Schedule:
    """Solve the scheduling ILP exactly.

    ``device_of_stage`` fixes the stage->device mapping (partitioner output);
    if None, device assignment variables y[s,d] are free (Eqs. 8/9/13) with
    stage 0 anchored to device 0.
    """
    from scipy import sparse
    from scipy.optimize import LinearConstraint, milp, Bounds

    V = num_virtual(S)
    # A feasible horizon: greedy gives an upper bound.
    if horizon is None:
        if device_of_stage is not None:
            horizon = greedy_schedule(S, M, device_of_stage, D).makespan
        else:
            horizon = V * M
    T = horizon

    def xid(v: int, m: int, d: int, t: int) -> int:
        return ((v * M + m) * D + d) * T + t

    nx = V * M * D * T
    free_map = device_of_stage is None
    ny = S * D if free_map else 0

    def yid(s: int, d: int) -> int:
        return nx + s * D + d

    tmax_id = nx + ny
    nvar = nx + ny + 1

    rows, cols, vals, lbs, ubs = [], [], [], [], []
    r = 0

    def add_row(entries: list[tuple[int, float]], lo: float, hi: float):
        nonlocal r
        for c, a in entries:
            rows.append(r); cols.append(c); vals.append(a)
        lbs.append(lo); ubs.append(hi)
        r += 1

    # (6) unique assignment
    for v in range(V):
        for m in range(M):
            add_row([(xid(v, m, d, t), 1.0) for d in range(D) for t in range(T)],
                    1.0, 1.0)

    # (7) device exclusivity
    for d in range(D):
        for t in range(T):
            add_row([(xid(v, m, d, t), 1.0) for v in range(V) for m in range(M)],
                    -np.inf, 1.0)

    # (8) device mapping
    if free_map:
        # sum_d y[s,d] == 1 ; link: sum_t x[v,m,d,t] == y[stage(v),d]
        for s in range(S):
            add_row([(yid(s, d), 1.0) for d in range(D)], 1.0, 1.0)
        for v in range(V):
            s = stage_of_virtual(v, S)
            for m in range(M):
                for d in range(D):
                    ent = [(xid(v, m, d, t), 1.0) for t in range(T)]
                    ent.append((yid(s, d), -1.0))
                    add_row(ent, 0.0, 0.0)
        # (9) collocation + anchor
        for s1, s2 in collocated:
            for d in range(D):
                add_row([(yid(s1, d), 1.0), (yid(s2, d), -1.0)], 0.0, 0.0)
        add_row([(yid(0, 0), 1.0)], 1.0, 1.0)
    else:
        # pin x to the fixed mapping: x[v,m,d,t] == 0 for d != dev(stage)
        for v in range(V):
            dv = device_of_stage(stage_of_virtual(v, S))
            for m in range(M):
                for d in range(D):
                    if d != dv:
                        add_row([(xid(v, m, d, t), 1.0) for t in range(T)],
                                0.0, 0.0)

    # times: time(v,m) = sum t * x
    def time_entries(v: int, m: int, sign: float) -> list[tuple[int, float]]:
        return [
            (xid(v, m, d, t), sign * t) for d in range(D) for t in range(T)
        ]

    # (10) sequential execution
    for m in range(M):
        for v in range(1, V):
            add_row(time_entries(v, m, 1.0) + time_entries(v - 1, m, -1.0),
                    1.0, np.inf)
    # (11) monotonic microbatches
    for v in range(V):
        for m in range(1, M):
            add_row(time_entries(v, m, 1.0) + time_entries(v, m - 1, -1.0),
                    1.0, np.inf)
    # (12) T_max >= time(V-1, m)  (chain+monotone make this the global max)
    for m in range(M):
        add_row([(tmax_id, 1.0)] + time_entries(V - 1, m, -1.0), 0.0, np.inf)

    A = sparse.csr_matrix((vals, (rows, cols)), shape=(r, nvar))
    constraints = LinearConstraint(A, np.array(lbs), np.array(ubs))

    # objective: min T_max + eps * sum(t * x)  (canonical early schedules)
    #            + eps_w * sum_cross-edges (t(v+1,m) - t(v,m))
    # The second tiebreak is a *residency* penalty on cross-device chain
    # edges: each message occupies its receiver's rotating buffer slot
    # from production until consumption, so total residency upper-bounds
    # the liveness windows the executors allocate — the ILP prefers, among
    # makespan-optimal schedules, ones with shorter in-flight lifetimes
    # (smaller rx windows, more overlap slack).  Both weights are scaled
    # so their combined contribution stays below one unit step: eps's
    # term is <= 1/(T+1) and eps_w's <= 1/(2(T+1)), so T_max remains
    # strictly dominant and ilp.makespan <= greedy.makespan is preserved.
    # Residency needs a fixed stage->device mapping; with free device
    # variables the cross-edge set is unknown, so the penalty is skipped.
    c = np.zeros(nvar)
    c[tmax_id] = 1.0
    eps = 1.0 / (V * M * T * (T + 1))
    for v in range(V):
        for m in range(M):
            for d in range(D):
                for t in range(T):
                    c[xid(v, m, d, t)] = eps * t
    if not free_map:
        eps_w = eps / 2.0
        for v in range(V - 1):
            dv = device_of_stage(stage_of_virtual(v, S))
            dn = device_of_stage(stage_of_virtual(v + 1, S))
            if dv == dn:
                continue
            for m in range(M):
                for d in range(D):
                    for t in range(T):
                        c[xid(v + 1, m, d, t)] += eps_w * t
                        c[xid(v, m, d, t)] -= eps_w * t

    integrality = np.ones(nvar)
    res = milp(
        c=c,
        constraints=constraints,
        integrality=integrality,
        bounds=Bounds(0, np.concatenate([np.ones(nx + ny), [T]])),
        options={"time_limit": time_limit, "presolve": True},
    )
    if res.status != 0 or res.x is None:
        raise RuntimeError(f"ILP failed: status={res.status} msg={res.message}")
    x = np.round(res.x[:nx]).astype(int).reshape(V, M, D, T)
    placements = []
    for v in range(V):
        for m in range(M):
            d, t = np.argwhere(x[v, m] == 1)[0]
            placements.append(Placement(v, m, int(d), int(t)))
    return Schedule(S, M, D, tuple(placements))


# --------------------------------------------------------------------------
# Simulation with real durations (wall-clock model)
# --------------------------------------------------------------------------

def simulate(
    sched: Schedule,
    fwd_time_of_stage: Sequence[float],
    *,
    bwd_ratio: float = 2.0,
    p2p_time: float = 0.0,
    overlap: bool = True,
) -> tuple[float, float]:
    """Event-driven makespan with real durations.

    Respects the schedule's per-device task *ordering* (not its unit slots);
    a task starts when (a) its predecessor in the chain has finished
    (+``p2p_time`` if it crossed devices) and (b) its device is free.
    Returns ``(makespan_seconds, bubble_ratio)``.

    ``overlap`` (default) models asynchronous sends — the table executors'
    overlapped lowering: a producer hands its boundary activation to the
    ring and immediately starts its next task, so only the *receiver*
    waits out ``p2p_time``.  ``overlap=False`` models the synchronous
    lowering (the ``PipelineConfig.overlap=False`` escape hatch), where
    the producing device also blocks for ``p2p_time`` after every
    cross-device send before its next compute.
    """
    S = sched.S
    by_dev: dict[int, list[Placement]] = {}
    for p in sorted(sched.placements, key=lambda p: p.step):
        by_dev.setdefault(p.device, []).append(p)
    finish: dict[tuple[int, int], float] = {}
    dev_free = {d: 0.0 for d in range(sched.D)}
    dev_of: dict[int, int] = {
        stage_of_virtual(p.virtual, S): p.device for p in sched.placements
    }
    pending = {d: list(ps) for d, ps in by_dev.items()}
    busy_time = 0.0
    progressed = True
    n_done = 0
    total = len(sched.placements)
    while n_done < total and progressed:
        progressed = False
        for d, queue in pending.items():
            while queue:
                p = queue[0]
                key = (p.virtual, p.microbatch)
                if p.virtual > 0:
                    dep = (p.virtual - 1, p.microbatch)
                    if dep not in finish:
                        break
                    ready = finish[dep]
                    s_prev = stage_of_virtual(p.virtual - 1, S)
                    s_cur = stage_of_virtual(p.virtual, S)
                    if dev_of[s_prev] != dev_of[s_cur]:
                        ready += p2p_time
                else:
                    ready = 0.0
                s = stage_of_virtual(p.virtual, S)
                dur = fwd_time_of_stage[s] * (
                    bwd_ratio if is_backward(p.virtual, S) else 1.0
                )
                start = max(ready, dev_free[d])
                finish[key] = start + dur
                dev_free[d] = start + dur
                if not overlap and p.virtual < sched.S * 2 - 1:
                    s_next = stage_of_virtual(p.virtual + 1, S)
                    if s_next in dev_of and dev_of[s_next] != d:
                        # synchronous lowering: the sender's ppermute sits
                        # on its own timeline before the next compute
                        dev_free[d] += p2p_time
                busy_time += dur
                queue.pop(0)
                n_done += 1
                progressed = True
    if n_done < total:
        raise RuntimeError("simulation deadlocked (invalid schedule ordering)")
    makespan = max(finish.values())
    bubble = 1.0 - busy_time / (sched.D * makespan)
    return makespan, bubble
