"""Skip-aware model partitioning (paper §IV, Algorithm 1).

Five partitioners:

- ``blockwise_partition``      — the paper's baseline: equal-count contiguous
                                 stages, no cost awareness.
- ``linear_partition``         — classic cost-balanced linear partition
                                 (the S = D skip-free default).
- ``partition_symmetric_fold`` — mirror-symmetric fold for skip-free graphs
                                 forced into a wave (min-max over mirror-pair
                                 costs); the skip-free dispatch target of
                                 ``partition_bidirectional``.
- ``partition_bidirectional``  — Algorithm 1: bidirectional DP over
                                 prefix/suffix states.  The per-state
                                 feasibility predicate handles *any* skip
                                 structure (nested, sparse, partially
                                 skipped, crossing), so it returns its
                                 asymmetric optimum directly instead of
                                 detouring through the exponential
                                 reference.
- ``partition_reference``      — exact brute-force reference with the
                                 paper's full constraint predicate
                                 c(i',i,j,j'); any skip structure;
                                 exponential — used for validation only.

All partitioners return a :class:`Partition` whose ``cuts`` are ``p+1``
monotone boundaries over block indices; stage ``s`` covers
``[cuts[s], cuts[s+1])`` and executes s-th in pipeline order.  Stage
placement is carried *explicitly* in ``Partition.devices`` (one device id
per stage); the partitioners here emit the folded mirror placement
``min(s, p-1-s)`` for waves and the identity for linear pipelines, but the
rest of the stack (layout, schedule, executors) reads ``devices``, not the
closed form — folded cuts need not be mirror-symmetric.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import numpy as np

from repro.core.graph import Block, BlockGraph
from repro.core.hw import Hardware, TPU_V5E

INF = float("inf")


@dataclasses.dataclass(frozen=True)
class Partition:
    cuts: tuple[int, ...]            # p+1 boundaries, cuts[0]=0, cuts[p]=n
    folded: bool                     # True => wave (two stages per device)
    objective: float                 # max over stages of Eq. (1) cost
    stage_costs: tuple[float, ...]   # per-stage Eq. (1) cost
    devices: tuple[int, ...] = ()    # per-stage device id; () derives the
    #   canonical placement (mirror fold min(s, p-1-s), identity linear)

    def __post_init__(self):
        p = len(self.cuts) - 1
        if not self.devices:
            object.__setattr__(self, "devices", tuple(
                min(s, p - 1 - s) if self.folded else s for s in range(p)))
        elif len(self.devices) != p:
            raise ValueError(
                f"devices maps {len(self.devices)} stages but cuts describe "
                f"{p}")

    @property
    def num_stages(self) -> int:
        return len(self.cuts) - 1

    @property
    def num_devices(self) -> int:
        return max(self.devices) + 1

    def stage_range(self, s: int) -> tuple[int, int]:
        return self.cuts[s], self.cuts[s + 1]

    def device_of_stage(self, s: int) -> int:
        return self.devices[s]

    def stages_of_device(self, d: int) -> tuple[int, ...]:
        return tuple(s for s, dev in enumerate(self.devices) if dev == d)

    def stage_of_block(self, b: int) -> int:
        for s in range(self.num_stages):
            if self.cuts[s] <= b < self.cuts[s + 1]:
                return s
        raise ValueError(f"block {b} outside partition")

    def stage_sizes(self) -> tuple[int, ...]:
        return tuple(self.cuts[s + 1] - self.cuts[s]
                     for s in range(self.num_stages))

    def collocated_pairs(self) -> tuple[tuple[int, int], ...]:
        """Stage pairs pinned to one device (schedule Eq. (9)), read off the
        explicit device mapping.  A device may hold any number of stage
        slots (2V for a V-fold interleaved wave); every same-device pair is
        reported so the schedule validator/ILP see the full collocation
        set."""
        by_dev: dict[int, list[int]] = {}
        for s, d in enumerate(self.devices):
            by_dev.setdefault(d, []).append(s)
        return tuple((a, b)
                     for _, ss in sorted(by_dev.items())
                     for i, a in enumerate(ss) for b in ss[i + 1:])

    @property
    def interleave(self) -> int:
        """Stage slot pairs per device: V = S / 2D folded (S / D linear).
        V == 1 is the classic mirror fold / plain linear pipeline."""
        S, D = self.num_stages, self.num_devices
        return S // (2 * D) if self.folded else S // D

    def mirror_symmetric(self) -> bool:
        """True iff stage s and stage S-1-s have equal block counts — the
        shape fully-paired skip graphs force.  Informational only: the
        layout/lowering stack no longer requires it (asymmetric folds from
        partially-skipped graphs lower through the same executors)."""
        if not self.folded:
            return False
        S, n = self.num_stages, self.cuts[-1]
        return all(self.cuts[s] + self.cuts[S - s] == n
                   for s in range(S + 1))

    def validate_collocation(self, graph: BlockGraph) -> bool:
        """All skip endpoints on the same device?"""
        return all(
            self.device_of_stage(self.stage_of_block(e.src))
            == self.device_of_stage(self.stage_of_block(e.dst))
            for e in graph.skips
        )


def _stage_cost(
    graph: BlockGraph, lo: int, hi: int, hw: Hardware, lam: float
) -> float:
    """Eq. (1)/(2)/(3): forward time of [lo,hi) + weighted p2p of its output."""
    t = sum(graph.blocks[l].fwd_time for l in range(lo, hi))
    out = graph.blocks[hi - 1].act_bytes if hi > lo else 0
    return t + lam * (hw.t_lat + out / hw.inter_bw)


def _mk_partition(
    graph: BlockGraph, cuts: Sequence[int], folded: bool, hw: Hardware, lam: float
) -> Partition:
    cuts = tuple(cuts)
    costs = tuple(
        _stage_cost(graph, cuts[s], cuts[s + 1], hw, lam)
        for s in range(len(cuts) - 1)
    )
    return Partition(cuts, folded, max(costs), costs)


# --------------------------------------------------------------------------
# Baseline: block-wise equal-count partition (paper's comparison baseline)
# --------------------------------------------------------------------------

def blockwise_partition(
    graph: BlockGraph, p: int, *, folded: bool = False,
    hw: Hardware = TPU_V5E, lam: float = 0.0,
) -> Partition:
    n = graph.n
    if p > n:
        raise ValueError(f"cannot split {n} blocks into {p} stages")
    cuts = [round(s * n / p) for s in range(p + 1)]
    # de-duplicate to keep stages non-empty
    for s in range(1, p + 1):
        cuts[s] = max(cuts[s], cuts[s - 1] + 1)
    cuts[p] = n
    for s in range(p - 1, 0, -1):
        cuts[s] = min(cuts[s], cuts[s + 1] - 1)
    return _mk_partition(graph, cuts, folded, hw, lam)


# --------------------------------------------------------------------------
# Classic linear partition (no skip constraints)
# --------------------------------------------------------------------------

def linear_partition(
    graph: BlockGraph, p: int, *,
    hw: Hardware = TPU_V5E, lam: float = 1.0, folded: bool = False,
) -> Partition:
    """Min-max cost contiguous partition via DP, O(p n^2)."""
    n = graph.n
    if p > n:
        raise ValueError(f"cannot split {n} blocks into {p} stages")
    cost = np.full((n + 1, n + 1), INF)
    for lo in range(n):
        for hi in range(lo + 1, n + 1):
            cost[lo, hi] = _stage_cost(graph, lo, hi, hw, lam)
    dp = np.full((p + 1, n + 1), INF)
    parent = np.zeros((p + 1, n + 1), dtype=int)
    dp[0, 0] = 0.0
    for k in range(1, p + 1):
        for i in range(k, n - (p - k) + 1):
            # last stage covers [i', i)
            cand = np.maximum(dp[k - 1, :i], cost[:i, i])
            j = int(np.argmin(cand))
            dp[k, i] = cand[j]
            parent[k, i] = j
    cuts = [n]
    k, i = p, n
    while k > 0:
        i = int(parent[k, i])
        cuts.append(i)
        k -= 1
    cuts.reverse()
    return _mk_partition(graph, cuts, folded, hw, lam)


# --------------------------------------------------------------------------
# Mirror-symmetric fold for skip-free graphs (force_wave)
# --------------------------------------------------------------------------

def partition_symmetric_fold(
    graph: BlockGraph, p: int, *,
    hw: Hardware = TPU_V5E, lam: float = 1.0,
) -> Partition:
    """Folded partition with mirror-symmetric cuts for skip-free graphs.

    The folded executor collocates stage s with stage p-1-s and requires
    equal block counts per pair, so a plain min-max linear partition is not
    a valid fold shape under heterogeneous costs.  Since each device runs
    both stages of its pair, balancing device load reduces to a min-max
    linear partition over mirror-pair costs t[i] + t[n-1-i]; the resulting
    half-cuts are mirrored onto the full graph.

    The lam comm term on the pair graph is an approximation: it charges the
    summed enc+dec act bytes of the stage's last pair under one latency,
    whereas the true up-stream transfer leaves from the stage's first
    pair's mirror and each boundary is two physical hops.  Exact for
    uniform act_bytes; a heuristic otherwise (compute balance dominates).

    Odd block counts leave one unpaired middle block; it always executes on
    the innermost device (the mirrored cuts pin it there), so its cost is
    charged to the innermost pair and the resulting fold is *asymmetric by
    one block* (the middle block rides the first suffix stage) — a legal
    shape for the generalized layout.
    """
    n = graph.n
    if p % 2 != 0:
        raise ValueError("symmetric fold needs an even stage count")
    if p > n:
        raise ValueError(f"cannot split {n} blocks into {p} stages")
    D, h = p // 2, n // 2
    mid_t = graph.blocks[h].fwd_time if n % 2 else 0.0
    pairs = tuple(
        Block(f"pair{i}",
              (graph.blocks[i].fwd_time + graph.blocks[n - 1 - i].fwd_time
               + (mid_t if i == h - 1 else 0.0)),
              act_bytes=(graph.blocks[i].act_bytes
                         + graph.blocks[n - 1 - i].act_bytes))
        for i in range(h))
    half = linear_partition(BlockGraph(pairs), D, hw=hw, lam=lam)
    cuts = list(half.cuts) + [n - c for c in reversed(half.cuts[:-1])]
    return _mk_partition(graph, cuts, True, hw, lam)


# --------------------------------------------------------------------------
# Algorithm 1: bidirectional skip-aware DP (any skip structure)
# --------------------------------------------------------------------------

def _feasible_j_interval(graph: BlockGraph, i: int) -> tuple[int, int]:
    """Feasible suffix starts j for prefix end i — any skip structure.

    State (i, j): prefix covers [0, i), suffix covers [j, n).  The state is
    consistent iff every skip pairs prefix with suffix at this boundary:
    ``(src < i) <=> (dst >= j)``.  That pins j into the inclusive interval
    ``(max dst over skips with src >= i, min dst over skips with src < i]``
    — for nested skips this collapses to the paper's (d_m, d_{m-1}]
    interval, but no nestedness is required: sparse, partially-skipped and
    crossing topologies all reduce to the same interval form.  A chain of
    states each consistent at its boundary realizes exactly the paper's
    c(i',i,j,j') stage-symmetry predicate (skip src in stage q <=> dst in
    stage p-1-q), which is what :func:`partition_reference` enumerates.
    Returns an inclusive interval (j_lo, j_hi); empty if j_lo > j_hi.
    """
    n = graph.n
    lo, hi = i, n
    for e in graph.skips:
        if e.src < i:
            hi = min(hi, e.dst)
        else:
            lo = max(lo, e.dst + 1)
    return max(lo, i), hi


def partition_bidirectional(
    graph: BlockGraph, p: int, *,
    hw: Hardware = TPU_V5E, lam: float = 1.0,
) -> Partition:
    """Skip-aware bidirectional DP (Algorithm 1) for skip graphs.

    Builds p stages (p even) pairwise from both sequence ends; stage q is
    collocated with stage p-1-q on device q.  DP state dp[(i, j)] after k
    stage-pairs = minimal max-cost covering prefix [0,i) and suffix [j,n).
    The per-state feasibility interval handles *any* skip structure —
    nested, sparse, mid-block bottlenecks, crossing — so partially-skipped
    graphs get their (generally mirror-asymmetric) DP optimum directly; the
    exponential :func:`partition_reference` is a test oracle, not a
    fallback.  For nested skips the interval collapses to the paper's
    state space, giving the O(p n^3) bound (and far less when most blocks
    carry skips).
    """
    n = graph.n
    if p % 2 != 0:
        raise ValueError("bidirectional partition needs an even stage count")
    if p > n:
        raise ValueError(f"cannot split {n} blocks into {p} stages")
    if not graph.skips:
        return partition_symmetric_fold(graph, p, hw=hw, lam=lam)

    # Pre-compute prefix sums of fwd time; stage costs on demand.
    pref = np.concatenate([[0.0], np.cumsum([b.fwd_time for b in graph.blocks])])

    def L(lo: int, hi: int) -> float:  # prefix stage [lo, hi)
        return (pref[hi] - pref[lo]) + lam * (
            hw.t_lat + graph.blocks[hi - 1].act_bytes / hw.inter_bw
        )

    def R(lo: int, hi: int) -> float:  # suffix stage [lo, hi)
        return (pref[hi] - pref[lo]) + lam * (
            hw.t_lat + graph.blocks[lo - 1].act_bytes / hw.inter_bw
        )

    # Enumerate feasible states per prefix end i (nested-skip interval).
    feas: dict[int, tuple[int, int]] = {}
    for i in range(1, n):
        lo, hi = _feasible_j_interval(graph, i)
        if lo <= hi:
            feas[i] = (lo, hi)

    return _partition_bidirectional_backtrack(graph, p, hw, lam, L, R, feas)


def _partition_bidirectional_backtrack(graph, p, hw, lam, L, R, feas) -> Partition:
    """Full DP keeping one table per generation for exact backtracking."""
    n = graph.n
    tables: list[dict[tuple[int, int], tuple[float, tuple[int, int] | None]]] = []
    t0: dict[tuple[int, int], tuple[float, tuple[int, int] | None]] = {}
    for i, (jlo, jhi) in feas.items():
        # j == i is a valid (middle-empty) state; it can only close the DP.
        for j in range(max(jlo, i), min(jhi, n - 1) + 1):
            t0[(i, j)] = (max(L(0, i), R(j, n)), None)
    tables.append(t0)
    gens = (p - 2) // 2
    for _ in range(gens):
        prev = tables[-1]
        ndp: dict[tuple[int, int], tuple[float, tuple[int, int] | None]] = {}
        for (i2, j2), (c_prev, _) in prev.items():
            for i in range(i2 + 1, n):
                if i not in feas:
                    continue
                jlo, jhi = feas[i]
                lcost = L(i2, i)
                lb = max(c_prev, lcost)
                for j in range(max(jlo, i), min(jhi, j2 - 1) + 1):
                    cand = max(lb, R(j, j2))
                    key = (i, j)
                    if key not in ndp or cand < ndp[key][0]:
                        ndp[key] = (cand, (i2, j2))
        tables.append(ndp)

    final = tables[-1]
    best, best_state = INF, None
    for (i, j), (c, _) in final.items():
        if j == i and c < best:
            best, best_state = c, (i, j)
    if best_state is None:
        raise ValueError(
            f"no feasible {p}-stage bidirectional partition "
            f"(graph n={n}, skips={len(graph.skips)})"
        )

    # collect boundaries generation by generation
    pre_cuts, suf_cuts = [], []
    state = best_state
    for g in range(len(tables) - 1, -1, -1):
        i, j = state
        pre_cuts.append(i)
        suf_cuts.append(j)
        parent = tables[g][state][1]
        if parent is None:
            break
        state = parent
    pre_cuts.reverse()           # increasing prefix ends
    suf_cuts.sort()              # increasing suffix starts
    cuts = [0] + pre_cuts + suf_cuts[1:] + [n]
    # pre_cuts[-1] == suf_cuts[0] (middle closed); stage boundaries are
    # 0, pre..., (=mid), suf..., n
    return _mk_partition(graph, cuts, True, hw, lam)


# --------------------------------------------------------------------------
# Exact reference (paper's c(i',i,j,j') predicate, any skip structure)
# --------------------------------------------------------------------------

def partition_reference(
    graph: BlockGraph, p: int, *,
    hw: Hardware = TPU_V5E, lam: float = 1.0,
) -> Partition:
    """Brute-force over all cut placements; checks the paper's symmetric
    stage constraint exactly: skip (c1, c2) with c1 in stage q requires c2
    in stage p-1-q (0-indexed; Eq. (4)'s c(i',i,j,j') predicate).  Device
    collocation follows from the fold.  Exponential — tests only."""
    n = graph.n
    if p % 2 != 0:
        raise ValueError("reference partitioner assumes even stage count")

    def stage_symmetric(part: Partition) -> bool:
        return all(
            part.stage_of_block(e.dst) == p - 1 - part.stage_of_block(e.src)
            for e in graph.skips)

    best_cuts, best_cost = None, INF
    for inner in itertools.combinations(range(1, n), p - 1):
        cuts = (0,) + inner + (n,)
        part = _mk_partition(graph, cuts, True, hw, lam)
        if not stage_symmetric(part):
            continue
        if part.objective < best_cost:
            best_cost, best_cuts = part.objective, cuts
    if best_cuts is None:
        raise ValueError("no feasible partition (reference)")
    return _mk_partition(graph, best_cuts, True, hw, lam)


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------

def interleaved_wave_devices(S: int, D: int) -> tuple[int, ...]:
    """Cyclic stage->device mapping for a V-fold interleaved wave (S = 2VD).

    Encoder-half stage s runs on device ``s % D``; decoder-half stage s on
    ``(S-1-s) % D``, so skip-paired stages (q, S-1-q) stay collocated for
    every interleave degree.  For V == 1 this is exactly the classic mirror
    fold ``min(s, S-1-s)``.  The cyclic pattern is not a free choice: the
    ring executors deliver enc->enc messages to device (d+1) % D and
    dec->dec to (d-1) % D, which pins the placement up to rotation.
    """
    return tuple((s % D) if s < S // 2 else (S - 1 - s) % D
                 for s in range(S))


def partition(
    graph: BlockGraph, num_devices: int, *,
    hw: Hardware = TPU_V5E, lam: float = 1.0, force_wave: bool | None = None,
    interleave: int = 1,
) -> Partition:
    """PULSE partitioning entry point.

    With skip edges (C != empty), uses S = 2VD folded stages and the
    bidirectional DP (paper default, §V-B).  Without skips, uses S = VD
    linear partitioning + 1F1B unless ``force_wave`` requests folding.
    ``interleave`` (V) is the number of stage slots per device and kind:
    V == 1 keeps the classic fold / linear shapes; V > 1 emits the
    interleaved (virtual-stage) placement ``interleaved_wave_devices``
    whose finer stages shrink fill/drain bubbles roughly from
    ``(D-1)/(M+D-1)`` toward ``(D-1)/(V*M+D-1)`` at the price of V weight
    shards and more ppermute hops per microbatch.
    """
    if interleave < 1:
        raise ValueError(f"interleave degree must be >= 1, got {interleave}")
    V, D = interleave, num_devices
    wave = force_wave if force_wave is not None else bool(graph.skips)
    if wave:
        S = 2 * V * D
        part = partition_bidirectional(graph, S, hw=hw, lam=lam)
        if V > 1:
            part = dataclasses.replace(
                part, devices=interleaved_wave_devices(S, D))
        return part
    if V > 1:
        S = V * D
        part = linear_partition(graph, S, hw=hw, lam=lam, folded=False)
        return dataclasses.replace(
            part, devices=tuple(s % D for s in range(S)))
    return linear_partition(graph, num_devices, hw=hw, lam=lam, folded=False)
