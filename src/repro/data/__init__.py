from repro.data.pipeline import (SyntheticTokenDataset, SyntheticLatentDataset,
                                 ShardedLoader)
