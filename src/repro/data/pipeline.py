"""Data pipeline: deterministic synthetic datasets + sharded host loader.

Synthetic-but-learnable data (per paper §VII, preprocessing — VAE latents /
text embeddings — is outside the measured loop, so training inputs are
precomputed tensors; we synthesize them deterministically from the step
index so any host can (re)generate its shard independently):

- fault tolerance: a restarted/replaced host resumes from (step, host_id)
  alone — no data-state checkpoint needed;
- elasticity: re-sharding to a different host count only changes the
  host_id -> slice mapping, not the global stream;
- straggler tolerance: no inter-host coordination in the input pipeline.

``SyntheticTokenDataset`` draws from a fixed Markov chain so LM losses
actually decrease; ``SyntheticLatentDataset`` mixes class/text-conditioned
Gaussian modes so diffusion losses decrease.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticTokenDataset:
    vocab: int
    seq_len: int
    seed: int = 0
    order: int = 2          # Markov order of the synthetic language

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse transition table: each context prefers ~8 next tokens
        self.k = 8
        self.table = rng.integers(0, self.vocab,
                                  size=(self.vocab, self.k)).astype(np.int32)

    def batch(self, step: int, host_id: int, batch: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + host_id)
        toks = np.empty((batch, self.seq_len), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        choices = rng.integers(0, self.k, size=(batch, self.seq_len))
        for t in range(1, self.seq_len):
            toks[:, t] = self.table[toks[:, t - 1], choices[:, t]]
        return {"tokens": toks}


@dataclasses.dataclass
class SyntheticLatentDataset:
    img_size: int
    channels: int
    n_classes: int = 10
    text_dim: int = 0
    text_len: int = 77
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.modes = rng.normal(
            0, 1, size=(self.n_classes, self.img_size, self.img_size,
                        self.channels)).astype(np.float32)
        if self.text_dim:
            self.text_bank = rng.normal(
                0, 1, size=(self.n_classes, self.text_len, self.text_dim)
            ).astype(np.float32)

    def batch(self, step: int, host_id: int, batch: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_539 + host_id)
        labels = rng.integers(0, self.n_classes, size=batch).astype(np.int32)
        lat = (self.modes[labels]
               + 0.3 * rng.normal(0, 1, size=(batch, self.img_size,
                                              self.img_size, self.channels))
               ).astype(np.float32)
        out = {"latents": lat, "labels": labels}
        if self.text_dim:
            out["text_embeds"] = self.text_bank[labels]
        return out


@dataclasses.dataclass
class ShardedLoader:
    """Host-sharded loader with simple double-buffer prefetch."""

    dataset: object
    global_batch: int
    num_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0
        self.local_batch = self.global_batch // self.num_hosts
        self._next = None
        self._next_step = None

    def get(self, step: int) -> dict:
        if self._next_step == step and self._next is not None:
            out = self._next
        else:
            out = self.dataset.batch(step, self.host_id, self.local_batch)
        # prefetch (synchronously built here; on a real host this is a
        # background thread — numpy generation is cheap and overlap-safe)
        self._next = self.dataset.batch(step + 1, self.host_id,
                                        self.local_batch)
        self._next_step = step + 1
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.get(step)
            step += 1
