"""Train/serve step builders for every parallelism strategy.

``build_train_step(bundle, mesh, shape_name, ...)`` dispatches on the
bundle's :class:`ParallelPlan`:

- ``"sharded"``: pure GSPMD — TP / EP / FSDP / DP entirely via parameter &
  batch PartitionSpecs; XLA inserts the collectives.  Covers every arch
  whose layer count or memory footprint makes PP the wrong tool (DESIGN.md
  §4 table).
- ``"pp_1f1b"`` / ``"pp_wave"``: the PULSE runtime — shard_map pipeline over
  the 'model' axis, DP (+ZeRO-1 gradient/optimizer sharding) over 'data'
  (+'pod'); wave folds stages symmetrically per the paper.

All builders return ``(step_fn, example_inputs, in_shardings,
out_shardings)`` where example_inputs are ShapeDtypeStructs — the dry-run
lowers without allocating anything.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         int8_adamw_init, int8_adamw_update)
from repro.runtime import sharding as shard_rules
from repro.runtime.compat import shard_map

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    strategy: str = "sharded"           # sharded | pp_1f1b | pp_wave
    batch_axes: tuple = ("pod", "data")
    tp_axis: str | None = "model"
    fsdp_axes: tuple = ("data",)
    ep: bool = False                    # expert parallelism over tp_axis
    pp_degree: int = 16
    microbatches: int = 16
    int8_optimizer: bool = False
    # ZeRO stage for the pp strategies: 0 = replicate per DP rank,
    # 1 = shard optimizer state over fsdp_axes (leaf-wise stack specs),
    # 2 = additionally shard the stage param stacks at rest (requires an
    #     adapter compiled with the matching PipelineConfig.zero_stage).
    zero_stage: int = 0
    seq_shard_axis: str | None = None   # decode-cache sequence sharding
    custom_rules: dict | None = None
    notes: str = ""


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _filter_axes(mesh, axes):
    return tuple(a for a in axes if a in mesh.axis_names)


def param_specs_for(params_struct, mesh, plan: ParallelPlan) -> Pytree:
    fsdp = _filter_axes(mesh, plan.fsdp_axes)
    return shard_rules.build_param_specs(
        params_struct,
        tp_axis=plan.tp_axis if plan.tp_axis in mesh.axis_names else None,
        fsdp_axes=fsdp or None,
        ep_axis=(plan.tp_axis if plan.ep else None),
        rules=plan.custom_rules,
        axis_sizes=dict(zip(mesh.axis_names, mesh.devices.shape)))


def opt_specs_like(param_specs: Pytree, int8: bool,
                   fsdp_axes: tuple = ()) -> Pytree:
    if not int8:
        return {"m": param_specs, "v": param_specs, "step": P()}
    # int8 moments are flat (nblocks, 256) tensors; shard blocks over the
    # ZeRO axes (block count is padded to stay divisible — optim.adamw).
    zspec = P(fsdp_axes) if fsdp_axes else P()
    q = jax.tree.map(lambda s: {"q": zspec, "s": zspec}, param_specs,
                     is_leaf=lambda x: isinstance(x, P))
    return {"m": q, "v": q, "step": P()}


# ===========================================================================
# GSPMD ("sharded") strategy
# ===========================================================================

def build_sharded_train_step(loss_fn: Callable, init_fn: Callable,
                             batch_struct: Pytree, mesh, plan: ParallelPlan,
                             opt_cfg: AdamWConfig = AdamWConfig()):
    key_s = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    params_struct = jax.eval_shape(init_fn, key_s)
    o_init = int8_adamw_init if plan.int8_optimizer else adamw_init
    o_update = int8_adamw_update if plan.int8_optimizer else adamw_update
    opt_struct = jax.eval_shape(o_init, params_struct)

    p_specs = param_specs_for(params_struct, mesh, plan)
    o_specs = opt_specs_like(p_specs, plan.int8_optimizer,
                             _filter_axes(mesh, plan.fsdp_axes))
    b_specs = shard_rules.batch_specs(
        batch_struct, dp_axes=_filter_axes(mesh, plan.batch_axes), mesh=mesh)

    def train_step(params, opt_state, batch, rng):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, rng)
        params, opt_state = o_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss

    in_sh = (_ns(mesh, p_specs), _ns(mesh, o_specs), _ns(mesh, b_specs),
             NamedSharding(mesh, P()))
    out_sh = (_ns(mesh, p_specs), _ns(mesh, o_specs), NamedSharding(mesh, P()))
    step = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0, 1))
    example = (params_struct, opt_struct, batch_struct, key_s)
    return step, example, in_sh, out_sh


def build_forward_step(loss_fn: Callable, init_fn: Callable,
                       batch_struct: Pytree, mesh, plan: ParallelPlan):
    """Inference-prefill proxy: lower the forward pass only (no grad,
    no optimizer) with the same parameter shardings."""
    key_s = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    params_struct = jax.eval_shape(init_fn, key_s)
    p_specs = param_specs_for(params_struct, mesh, plan)
    b_specs = shard_rules.batch_specs(
        batch_struct, dp_axes=_filter_axes(mesh, plan.batch_axes), mesh=mesh)
    in_sh = (_ns(mesh, p_specs), _ns(mesh, b_specs), NamedSharding(mesh, P()))
    out_sh = NamedSharding(mesh, P())
    step = jax.jit(lambda params, batch, rng: loss_fn(params, batch, rng),
                   in_shardings=in_sh, out_shardings=out_sh)
    example = (params_struct, batch_struct, key_s)
    return step, example, in_sh, out_sh


def build_sharded_serve_step(decode_fn: Callable, init_fn: Callable,
                             cache_struct: Pytree, token_struct: Pytree,
                             mesh, plan: ParallelPlan):
    """decode_fn(params, token, caches) -> (logits, caches)."""
    key_s = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    params_struct = jax.eval_shape(init_fn, key_s)
    p_specs = param_specs_for(params_struct, mesh, plan)
    c_specs = shard_rules.cache_specs(
        cache_struct, dp_axes=_filter_axes(mesh, plan.batch_axes),
        tp_axis=plan.tp_axis if plan.tp_axis in mesh.axis_names else None,
        seq_shard_axis=plan.seq_shard_axis, mesh=mesh)
    t_specs = shard_rules.batch_specs(
        token_struct, dp_axes=_filter_axes(mesh, plan.batch_axes), mesh=mesh)

    dp_axes = _filter_axes(mesh, plan.batch_axes)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tok_leaf = jax.tree.leaves(token_struct)[0]
    tok_spec = shard_rules.fit_spec(
        P(dp_axes, None) if dp_axes else P(), tok_leaf.shape, sizes)
    tok_out = NamedSharding(mesh, tok_spec)
    in_sh = (_ns(mesh, p_specs), _ns(mesh, t_specs), _ns(mesh, c_specs))
    out_sh = (tok_out, _ns(mesh, c_specs))

    def serve_step(params, token, caches):
        logits, caches = decode_fn(params, token, caches)
        next_tok = jnp.argmax(logits[..., -1:, :], axis=-1).astype(jnp.int32)
        return next_tok, caches

    step = jax.jit(serve_step, in_shardings=in_sh,
                   out_shardings=out_sh, donate_argnums=(2,))
    example = (params_struct, token_struct, cache_struct)
    return step, example, in_sh, out_sh


# ===========================================================================
# PULSE pipeline strategies
# ===========================================================================

def build_pp_train_step(adapter, mesh, batch_struct: Pytree,
                        plan: ParallelPlan,
                        make_microbatches: Callable,
                        opt_cfg: AdamWConfig = AdamWConfig(),
                        extra_stack_fsdp: bool = False):
    """adapter: LMPipelineAdapter | DiffusionPipelineAdapter — or a
    CompiledPipeline from ``runtime.compile.auto_pipeline`` (same
    interface) — already built with a PipelineConfig matching the mesh's
    'model' axis.

    ``make_microbatches(batch, rng, params_edge)`` -> pipeline args after the
    stacks (e.g. (edge, mbs) or (edge, mbs, aux)); the step differentiates
    w.r.t. stacks + edge.
    """
    key_s = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    # Parameter state is stored in pipeline form: (stacks tuple, edge dict).
    params_struct = jax.eval_shape(adapter.init_pipeline_params, key_s)
    o_init = int8_adamw_init if plan.int8_optimizer else adamw_init
    o_update = int8_adamw_update if plan.int8_optimizer else adamw_update
    opt_struct = jax.eval_shape(o_init, params_struct)

    fsdp = _filter_axes(mesh, plan.fsdp_axes)
    stack_spec = P("model") if not extra_stack_fsdp else P("model", fsdp)
    stacks_struct, edge_struct = params_struct

    def stack_specs(tree):
        return jax.tree.map(lambda _: stack_spec, tree)

    # ZeRO over the DP axes: stage 1 shards only optimizer state with the
    # leaf-wise stack specs (adamw state mirrors params leaf-for-leaf);
    # stage 2 stores the stacks themselves sharded at rest — legal only
    # when the adapter's executor was compiled to all-gather on use
    # (PipelineConfig.zero_stage >= 2), so rest-sharding keys off the
    # adapter's pcfg, never off the plan alone.
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    zs_exec = getattr(getattr(adapter, "pcfg", None), "zero_stage", 0)
    zdp = 1
    for a in fsdp:
        zdp *= sizes.get(a, 1)
    zero_stage = max(zs_exec, plan.zero_stage) if (fsdp and zdp > 1) else 0
    zstack_specs = (tuple(
        shard_rules.zero_stack_specs(s, dp=zdp, axis="model",
                                     data_axes=fsdp)[0]
        for s in stacks_struct) if zero_stage >= 1 else None)

    edge_specs = shard_rules.build_param_specs(
        edge_struct, tp_axis=None, fsdp_axes=fsdp or None)
    p_stack_specs = (zstack_specs if zs_exec >= 2
                     else tuple(stack_specs(s) for s in stacks_struct))
    p_specs = (p_stack_specs, edge_specs)
    o_like = ((zstack_specs, edge_specs) if zero_stage >= 1 else p_specs)
    o_specs = opt_specs_like(o_like, plan.int8_optimizer, fsdp)
    b_specs = shard_rules.batch_specs(
        batch_struct, dp_axes=_filter_axes(mesh, plan.batch_axes), mesh=mesh)

    pipe_fn = adapter.build()
    dp_axes = _filter_axes(mesh, plan.batch_axes)

    def loss_of(params, batch, rng):
        stacks, edge = params
        args = make_microbatches(batch, rng, edge)
        in_specs = (
            *(p_stack_specs if zs_exec >= 2 else
              tuple(jax.tree.map(lambda _: P("model"), s) for s in stacks)),
            jax.tree.map(lambda _: P(), edge),
            *(jax.tree.map(
                lambda x: P(None, dp_axes, *([None] * (x.ndim - 2)))
                if hasattr(x, "ndim") and x.ndim >= 2 else P(), a)
              for a in args),
        )
        return shard_map(pipe_fn, mesh=mesh, in_specs=in_specs,
                         out_specs=P(), check_vma=False)(*stacks, edge, *args)

    def train_step(params, opt_state, batch, rng):
        loss, grads = jax.value_and_grad(loss_of)(params, batch, rng)
        params, opt_state = o_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss

    in_sh = (_ns(mesh, p_specs), _ns(mesh, o_specs), _ns(mesh, b_specs),
             NamedSharding(mesh, P()))
    out_sh = (_ns(mesh, p_specs), _ns(mesh, o_specs), NamedSharding(mesh, P()))
    step = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0, 1))
    example = (params_struct, opt_struct, batch_struct, key_s)
    return step, example, in_sh, out_sh
