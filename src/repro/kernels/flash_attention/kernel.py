"""Blocked online-softmax attention (FlashAttention) as a Pallas TPU kernel.

TPU adaptation (not a CUDA port): the kernel is organised around MXU-shaped
matmul tiles — q/k/v blocks live in VMEM via BlockSpec; block sizes default
to (128 x head_dim) so both q.kT and p.v contractions feed the 128x128
systolic array; running max/sum are rank-1 f32 VREG-resident columns.

Grid: (batch*heads, S/block_q).  The kv loop is a fori_loop inside the
kernel over T/block_k tiles of the *whole* K/V rows, which stream
HBM->VMEM block by block.  Causal and sliding-window masking are applied
per tile; fully-masked tiles still execute (masked) — tile skipping is a
known follow-up optimization (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool,
                 window: int | None, sm_scale: float, q_block: int,
                 kv_len: int):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * sm_scale      # (block_q, D)
    bq, D = q.shape
    nk = kv_len // block_k

    def body(ki, carry):
        acc, m_prev, l_prev = carry
        k = pl.load(k_ref, (pl.dslice(ki * block_k, block_k),
                            pl.dslice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(ki * block_k, block_k),
                            pl.dslice(None))).astype(jnp.float32)
        s = q @ k.T                                     # (bq, bk)
        q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_cur, l_cur

    acc0 = jnp.zeros((bq, D), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out.astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: (BH, S, D); k,v: (BH, T, D).  S % block_q == 0, T % block_k == 0."""
    BH, S, D = q.shape
    T = k.shape[1]
    assert S % block_q == 0 and T % block_k == 0
    sm_scale = 1.0 / math.sqrt(D)
    kernel = functools.partial(
        _attn_kernel, block_k=block_k, causal=causal, window=window,
        sm_scale=sm_scale, q_block=block_q, kv_len=T)
    return pl.pallas_call(
        kernel,
        grid=(BH, S // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, T, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, T, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
