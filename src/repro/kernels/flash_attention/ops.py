"""jit'd public wrapper: GQA layout handling + custom VJP.

Forward runs the Pallas kernel (interpret=True on CPU so the kernel body
itself is what's validated); backward recomputes through the jnp reference
(flash backward kernel is follow-up work — the training hot path already
runs under per-layer remat, so the recompute is the same one remat pays).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_reference


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, window=None,
                    block_q=128, block_k=128):
    """q: (B,S,Hq,D); k,v: (B,T,Hkv,D) with Hq % Hkv == 0. -> (B,S,Hq,D)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    groups = Hq // Hkv
    kf = jnp.repeat(k, groups, axis=2) if groups > 1 else k
    vf = jnp.repeat(v, groups, axis=2) if groups > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    kf = kf.transpose(0, 2, 1, 3).reshape(B * Hq, T, D)
    vf = vf.transpose(0, 2, 1, 3).reshape(B * Hq, T, D)
    out = flash_attention_fwd(qf, kf, vf, causal=causal, window=window,
                              block_q=min(block_q, S),
                              block_k=min(block_k, T),
                              interpret=_use_interpret())
    return out.reshape(B, Hq, S, D).transpose(0, 2, 1, 3)


def _ref_gqa(q, k, v, causal, window):
    groups = q.shape[2] // k.shape[2]
    kf = jnp.repeat(k, groups, axis=2) if groups > 1 else k
    vf = jnp.repeat(v, groups, axis=2) if groups > 1 else v
    return attention_reference(q, kf, vf, causal=causal, window=window)


def _fwd(q, k, v, causal, window, block_q, block_k):
    out = flash_attention(q, k, v, causal, window, block_q, block_k)
    return out, (q, k, v)


def _bwd(causal, window, block_q, block_k, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: _ref_gqa(q, k, v, causal, window),
                     q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
