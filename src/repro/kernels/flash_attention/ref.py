"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """q,k,v: (B, S, H, D) (same H — GQA grouping happens in the caller).
    fp32 softmax, returns q.dtype."""
    B, S, H, D = q.shape
    T = k.shape[1]
    logits = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # rows that are fully masked produce NaN from softmax(-inf): zero them
    probs = jnp.where(jnp.any(mask, -1)[None, None, :, None], probs, 0.0)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
