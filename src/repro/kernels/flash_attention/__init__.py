from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.analysis.kernel_check import flash_attention_supported  # noqa: F401
