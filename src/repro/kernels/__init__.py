"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as <name>/kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), <name>/ops.py (jit'd public wrapper + custom VJP; interpret=True
on CPU) and <name>/ref.py (pure-jnp oracle swept in tests/test_kernels.py).
"""
from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_supported)
from repro.kernels.skip_matmul import (skip_concat_matmul,
                                       skip_concat_matmul_supported)
from repro.kernels.linear_scan import (gated_linear_scan,
                                       gated_linear_scan_supported)
