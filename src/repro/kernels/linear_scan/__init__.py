from repro.kernels.linear_scan.ops import gated_linear_scan
from repro.kernels.linear_scan.ref import gated_linear_scan_reference
from repro.analysis.kernel_check import gated_linear_scan_supported  # noqa: F401
