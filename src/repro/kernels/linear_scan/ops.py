"""jit'd wrapper with custom VJP.

Backward of h_t = a_t h_{t-1} + x_t is itself a *reversed* gated scan:
    dx_t = g_t,   g_{t-1} += a_t * g_t  =>  dX = reverse-scan(a_{t+1}, dh)
    da_t = dX_t * h_{t-1}
so the same kernel serves both directions (time-flipped).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.linear_scan.kernel import gated_linear_scan_fwd


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.custom_vjp
def gated_linear_scan(a, x):
    """a, x: (R, T, C) -> h: (R, T, C) with h_t = a_t*h_{t-1} + x_t."""
    return gated_linear_scan_fwd(a, x, interpret=_use_interpret())


def _fwd(a, x):
    h = gated_linear_scan(a, x)
    return h, (a, h)


def _bwd(res, g):
    a, h = res
    # dX solves the reversed recurrence: dX_t = g_t + a_{t+1} dX_{t+1}
    a_next = jnp.concatenate([a[:, 1:], jnp.zeros_like(a[:, :1])], axis=1)
    dx = gated_linear_scan(a_next[:, ::-1], g[:, ::-1].astype(a.dtype))[:, ::-1]
    h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    da = (dx.astype(jnp.float32) * h_prev.astype(jnp.float32)).astype(a.dtype)
    return da, dx.astype(g.dtype)


gated_linear_scan.defvjp(_fwd, _bwd)
