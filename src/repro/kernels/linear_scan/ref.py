"""Oracle: gated linear recurrence  h_t = a_t * h_{t-1} + x_t  (elementwise).

This is the core state update shared by Mamba2 (per-head decay) and mLSTM
(per-head forget gates) after the input projections; the chunked Pallas
kernel parallelises it over (batch*channel) rows and streams time in VMEM
chunks.
"""
import jax
import jax.numpy as jnp


def gated_linear_scan_reference(a: jax.Array, x: jax.Array,
                                h0: jax.Array | None = None) -> jax.Array:
    """a, x: (B, T, C) with 0 <= a <= 1 typically.  Returns h: (B, T, C)."""
    B, T, C = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, C), jnp.float32)

    def step(h, inp):
        at, xt = inp
        h = at * h + xt
        return h, h

    a32 = jnp.swapaxes(a.astype(jnp.float32), 0, 1)
    x32 = jnp.swapaxes(x.astype(jnp.float32), 0, 1)
    _, hs = jax.lax.scan(step, h0, (a32, x32))
    return jnp.swapaxes(hs, 0, 1).astype(x.dtype)
