"""Chunked gated linear scan as a Pallas TPU kernel.

TPU adaptation: the recurrence h_t = a_t*h_{t-1} + x_t is sequential in t
but embarrassingly parallel over channels.  We put channels on the lane
dimension (128-wide VPU lanes), tile time into VMEM-resident chunks, and
carry the running state in a VMEM scratch buffer that persists across the
sequentially-iterated time-chunk grid dimension — the TPU-native analogue
of the GPU warp-scan formulations.

Grid: (B*C/block_c, T/block_t) — the second dimension iterates
sequentially on TPU, so ``state`` scratch carries between chunks of the
same row block.  Within a chunk the scan is an unrolled loop over rows of
the (block_t, block_c) tile (each row is a full vector op).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, x_ref, o_ref, state_ref, *, block_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[...].astype(jnp.float32)     # (block_t, block_c)
    x = x_ref[...].astype(jnp.float32)
    h = state_ref[...]                     # (1, block_c)

    rows = []
    for t in range(block_t):               # static unroll within the tile
        h = a[t][None, :] * h + x[t][None, :]
        rows.append(h)
    out = jnp.concatenate(rows, axis=0)
    state_ref[...] = h
    o_ref[...] = out.astype(o_ref.dtype)


def gated_linear_scan_fwd(a: jax.Array, x: jax.Array, *,
                          block_t: int = 128, block_c: int = 128,
                          interpret: bool = False) -> jax.Array:
    """a, x: (R, T, C) — R independent rows (batch*heads).  T % block_t == 0,
    C % block_c == 0."""
    R, T, C = x.shape
    bt, bc = min(block_t, T), min(block_c, C)
    assert T % bt == 0 and C % bc == 0
    kernel = functools.partial(_kernel, block_t=bt)
    grid = (R * (C // bc), T // bt)

    def idx(r, t):
        return (r // (C // bc), t, r % (C // bc))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bt, bc), idx),
            pl.BlockSpec((None, bt, bc), idx),
        ],
        out_specs=pl.BlockSpec((None, bt, bc), idx),
        out_shape=jax.ShapeDtypeStruct((R, T, C), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, bc), jnp.float32)],
        interpret=interpret,
    )(a, x)
