"""Oracle: decoder-side skip projection  y = concat([h, s], -1) @ W."""
import jax
import jax.numpy as jnp


def skip_concat_matmul_reference(h: jax.Array, s: jax.Array,
                                 w: jax.Array) -> jax.Array:
    """h: (M, D); s: (M, D); w: (2D, N).  Returns (M, N) in h.dtype."""
    x = jnp.concatenate([h, s], axis=-1)
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(h.dtype)
