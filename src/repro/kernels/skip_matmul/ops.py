"""jit'd wrapper with custom VJP (backward = three plain matmuls)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.skip_matmul.kernel import skip_concat_matmul_fwd


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def skip_concat_matmul_supported(rows: int, d: int, n: int,
                                 block: int = 128) -> bool:
    """Whether (rows, D) x (2D, N) operands tile the kernel's grid.

    Single source of truth for the divisibility rule
    ``skip_concat_matmul_fwd`` asserts (each dim must be a multiple of
    its clamped block size); callers use it to fall back to the
    reference contraction instead of tripping the assert.  Empty
    operands are unsupported (the grid would be degenerate).
    """
    def tiles(dim: int) -> bool:
        return dim > 0 and dim % min(block, dim) == 0

    return tiles(rows) and tiles(d) and tiles(n)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def skip_concat_matmul(h, s, w):
    """h,s: (..., D); w: (2D, N) -> (..., N)."""
    shape = h.shape
    D = shape[-1]
    hf = h.reshape(-1, D)
    sf = s.reshape(-1, D)
    out = skip_concat_matmul_fwd(hf, sf, w, interpret=_use_interpret())
    return out.reshape(*shape[:-1], w.shape[1])


def _fwd(h, s, w):
    return skip_concat_matmul(h, s, w), (h, s, w)


def _bwd(res, g):
    h, s, w = res
    D = h.shape[-1]
    gf = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    hf = h.reshape(-1, D).astype(jnp.float32)
    sf = s.reshape(-1, D).astype(jnp.float32)
    w1, w2 = w[:D].astype(jnp.float32), w[D:].astype(jnp.float32)
    dh = (gf @ w1.T).reshape(h.shape).astype(h.dtype)
    ds = (gf @ w2.T).reshape(s.shape).astype(s.dtype)
    dw = jnp.concatenate([hf.T @ gf, sf.T @ gf], axis=0).astype(w.dtype)
    return dh, ds, dw


skip_concat_matmul.defvjp(_fwd, _bwd)
