"""jit'd wrapper with custom VJP (backward = three plain matmuls)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.skip_matmul.kernel import skip_concat_matmul_fwd


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


# Single source of truth for the launch constraints lives in the static
# analysis layer (repro.analysis.kernel_check, jax-free): each dim must
# be a positive multiple of its clamped block size and the VMEM-resident
# blocks must fit the core budget.  Callers use the predicate to fall
# back to the reference contraction instead of tripping the kernel's
# trace-time assert.
from repro.analysis.kernel_check import skip_concat_matmul_supported  # noqa: F401


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def skip_concat_matmul(h, s, w):
    """h,s: (..., D); w: (2D, N) -> (..., N)."""
    shape = h.shape
    D = shape[-1]
    hf = h.reshape(-1, D)
    sf = s.reshape(-1, D)
    out = skip_concat_matmul_fwd(hf, sf, w, interpret=_use_interpret())
    return out.reshape(*shape[:-1], w.shape[1])


def _fwd(h, s, w):
    return skip_concat_matmul(h, s, w), (h, s, w)


def _bwd(res, g):
    h, s, w = res
    D = h.shape[-1]
    gf = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    hf = h.reshape(-1, D).astype(jnp.float32)
    sf = s.reshape(-1, D).astype(jnp.float32)
    w1, w2 = w[:D].astype(jnp.float32), w[D:].astype(jnp.float32)
    dh = (gf @ w1.T).reshape(h.shape).astype(h.dtype)
    ds = (gf @ w2.T).reshape(s.shape).astype(s.dtype)
    dw = jnp.concatenate([hf.T @ gf, sf.T @ gf], axis=0).astype(w.dtype)
    return dh, ds, dw


skip_concat_matmul.defvjp(_fwd, _bwd)
