from repro.kernels.skip_matmul.ops import (skip_concat_matmul,
                                           skip_concat_matmul_supported)
from repro.kernels.skip_matmul.ref import skip_concat_matmul_reference
