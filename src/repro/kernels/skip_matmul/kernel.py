"""Fused skip-concat matmul:  y = [h | s] @ W  ==  h @ W1 + s @ W2.

Every decoder block of UViT / Hunyuan-DiT (and the UNet up-path) consumes
its locally-cached skip activation through exactly this contraction; fusing
it avoids materialising the (M, 2D) concat in HBM — on TPU that halves the
activation read traffic of the projection (the concat would round-trip
HBM->VMEM twice).

Grid: (M/bm, N/bn); the K loop streams both halves of W and reuses the
h/s tiles already resident in VMEM.  f32 accumulation in VREGs; tiles are
(bm x bk)·(bk x bn) MXU shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(h_ref, s_ref, w1_ref, w2_ref, o_ref, *, block_k: int, K: int):
    bm = h_ref.shape[0]
    bn = o_ref.shape[1]
    nk = K // block_k

    def body(ki, acc):
        sl = pl.dslice(ki * block_k, block_k)
        h = pl.load(h_ref, (pl.dslice(None), sl)).astype(jnp.float32)
        s = pl.load(s_ref, (pl.dslice(None), sl)).astype(jnp.float32)
        w1 = pl.load(w1_ref, (sl, pl.dslice(None))).astype(jnp.float32)
        w2 = pl.load(w2_ref, (sl, pl.dslice(None))).astype(jnp.float32)
        return acc + h @ w1 + s @ w2

    acc = jax.lax.fori_loop(0, nk, body,
                            jnp.zeros((bm, bn), jnp.float32))
    o_ref[...] = acc.astype(o_ref.dtype)


def skip_concat_matmul_fwd(h: jax.Array, s: jax.Array, w: jax.Array, *,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """h,s: (M, D); w: (2D, N)."""
    M, D = h.shape
    N = w.shape[1]
    w1, w2 = w[:D], w[D:]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, D)
    assert M % bm == 0 and N % bn == 0 and D % bk == 0
    kernel = functools.partial(_kernel, block_k=bk, K=D)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, D), lambda i, j: (i, 0)),
            pl.BlockSpec((D, bn), lambda i, j: (0, j)),
            pl.BlockSpec((D, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), h.dtype),
        interpret=interpret,
    )(h, s, w1, w2)
