"""Table-driven pipeline executors lowered from a validated Schedule.

The closed-form executors in ``runtime.pipeline`` realize the wave / 1F1B
orders through index arithmetic baked into the scan body (``my_mb = t - d``,
``skip_row = t2 - (D-1) + 2d``).  A synthesized
:class:`~repro.core.schedule.Schedule` — greedy *or* ILP — therefore never
changed what actually ran, and planner/executor disagreements stayed
invisible.  This module makes the Schedule the single source of truth:

1. :class:`StepTables` extracts, per device, a dense *forward step program*
   from the schedule's F placements: which task (encoder/decoder selector
   and *stage slot* — a device runs V slots per kind under an interleaved
   S = 2VD plan) runs at each step, on which microbatch, which receive
   slot the incoming boundary activation lands in, whether the slot
   embeds / reads / writes the turnaround buffer, and when to emit the
   loss.  Every cross-device dependency is checked against the
   synchronous-scan dataflow at lowering time — a schedule the executor
   could not realize raises ``ValueError`` here instead of silently
   computing garbage.  Pass the stage->device mapping as a ``devices``
   tuple to memoize the lowering per (schedule, partition).

2. :func:`make_wave_pipeline_from_schedule` /
   :func:`make_linear_pipeline_from_schedule` lower those tables into
   shard_map executors.  The scan body reads its (selector, slot,
   microbatch, receive slot, loss mask) from the precomputed per-device
   arrays; parameters carry a leading ``[V, pad, ...]`` slot axis indexed
   per step, incoming activations live in microbatch-indexed buffers and
   each device's skip stash in a (microbatch, slot)-indexed buffer, and
   the rings wrap so interleaved slot boundaries cross device D-1 -> 0.
   Any *valid* schedule — including ILP schedules whose step timing
   differs from the greedy templates, and interleaved V > 1 plans —
   executes exactly as synthesized.

Backward placements (virtual stage >= S) are realized by JAX autodiff as
the transposed scan, mirroring the forward order — the same convention as
the closed-form executors (paper Figs. 8/9 backward halves).

Cost model vs the closed forms: the table executors ppermute both ring
directions every step and carry ``O(M)`` activation buffers (the closed
forms carry one register per direction), trading peak memory for complete
schedule generality.  The closed forms remain available as differential
references via ``auto_pipeline(..., executor="closed_form")``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import Schedule, placement_bounds_error
from repro.runtime.pipeline import (PipelineConfig, _wrap_remat, ring_perms,
                                    tree_index, tree_local)

Pytree = Any

IDLE, RUN_ENC, RUN_DEC = 0, 1, 2


def _slot_maps(S: int, D: int, folded: bool,
               device_of_stage: Callable[[int], int]
               ) -> tuple[int, dict[int, int], dict[int, int]]:
    """(V, enc_slot_of_stage, dec_slot_of_stage) for a stage->device map.

    A device's stages of one kind (encoder-half s < S/2, decoder-half
    otherwise; everything is 'encoder' for linear pipelines), sorted by
    stage id, occupy slots 0..V-1.  Every device must hold the same slot
    count per kind — the SPMD executors run one program with [V, pad, ...]
    parameter stacks, so a ragged slot layout is unliftable and raises
    here with per-device context.
    """
    half = S // 2 if folded else S
    enc_by_dev: dict[int, list[int]] = {}
    dec_by_dev: dict[int, list[int]] = {}
    for s in range(S):
        (enc_by_dev if s < half else dec_by_dev).setdefault(
            device_of_stage(s), []).append(s)
    counts = {d: (len(enc_by_dev.get(d, ())), len(dec_by_dev.get(d, ())))
              for d in range(D)}
    kinds = set(counts.values())
    ok = len(kinds) == 1
    if ok:
        e, c = next(iter(kinds))
        ok = e > 0 and ((e == c) if folded else (c == 0))
    if not ok:
        detail = ", ".join(
            f"device {d}: {e} prefix-half + {c} suffix-half slots"
            if folded else f"device {d}: {e} stage slots"
            for d, (e, c) in sorted(counts.items()))
        raise ValueError(
            f"stage->device mapping is not an even interleave over D={D} "
            f"devices ({detail}); the table executors need V equal slots "
            "per device and kind")
    V = next(iter(kinds))[0]
    enc_slot = {s: k for ss in enc_by_dev.values()
                for k, s in enumerate(sorted(ss))}
    dec_slot = {s: k for ss in dec_by_dev.values()
                for k, s in enumerate(sorted(ss))}
    return V, enc_slot, dec_slot


# ===========================================================================
# Step-table extraction (host-side, numpy)
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class StepTables:
    """Per-device forward step programs + message routing for one Schedule.

    All arrays are ``[D, num_steps]`` over the *compressed forward step
    axis*: the schedule's global steps that contain at least one forward
    placement, in order (``forward_steps`` maps compressed index -> global
    step).  Compression preserves the relative order of every placement, so
    the synchronous scan (one ppermute hop per step) realizes the same
    partial order the schedule was validated against.

    - ``sel``: ``IDLE`` / ``RUN_ENC`` / ``RUN_DEC`` (linear pipelines only
      use ``IDLE`` / ``RUN_ENC``).
    - ``slot``: which of the device's V same-kind stage slots the task
      runs (0 for classic V=1 plans; interleaved plans index the [V, pad]
      parameter stacks and per-slot count/pairing tables with it).
    - ``mb``: microbatch of the slot (0 when idle — never read).
    - ``down_mb`` / ``down_valid``: receive slot for the down-ring channel
      at the *start* of the step (what the upstream device sent last step).
    - ``up_mb`` / ``up_valid``: same for the up-ring channel.
    - ``loss``: slot computes the final-stage output and emits the loss.
    - ``embed`` / ``turn_rd`` / ``turn_wr``: the slot runs stage 0 (embeds
      its input), the first decoder-half stage (reads the local turn
      buffer) or the last encoder-half stage (writes it).  With V > 1 a
      device runs several enc/dec slots, so these are per-(device, step)
      facts, not per-device ones — ``embed_device`` / ``turn_device`` stay
      as informational summaries.
    """

    D: int
    M: int
    V: int
    forward_steps: tuple[int, ...]
    sel: np.ndarray
    slot: np.ndarray
    mb: np.ndarray
    down_mb: np.ndarray
    down_valid: np.ndarray
    up_mb: np.ndarray
    up_valid: np.ndarray
    loss: np.ndarray
    embed: np.ndarray
    turn_rd: np.ndarray
    turn_wr: np.ndarray
    embed_device: int = 0
    turn_device: int = -1

    @property
    def num_steps(self) -> int:
        return self.sel.shape[1]

    @classmethod
    def from_schedule(cls, sched: Schedule, *, folded: bool,
                      device_of_stage=None,
                      devices: tuple[int, ...] | None = None) -> "StepTables":
        """Lower a schedule's forward placements to step tables.

        ``device_of_stage`` is the partition's *explicit* stage->device
        mapping; when omitted the canonical placements (mirror fold /
        identity, or their V-fold interleaved generalization) are assumed.
        Pass the mapping as a ``devices`` *tuple* instead to memoize the
        lowering per (schedule, folded, devices) — the tuner's candidate
        loop and repeated ``auto_pipeline`` calls then reuse the
        O(S*M*steps) extraction.  Raises ``ValueError`` on any shape the
        synchronous scan cannot realize (malformed placements, a stage
        mapped off the ring neighbourhood its messages need, double-booked
        channels, a consumer scheduled before its input can arrive) — the
        planner/executor mismatches the closed forms used to hide surface
        here.
        """
        if devices is not None:
            if device_of_stage is not None:
                raise ValueError("pass device_of_stage or devices, not both")
            return _tables_cached(sched, folded, tuple(devices))
        return cls._build(sched, folded, device_of_stage)

    @classmethod
    def _build(cls, sched: Schedule, folded: bool,
               device_of_stage) -> "StepTables":
        S, M, D = sched.S, sched.M, sched.D
        if (S % (2 * D) if folded else S % D) != 0:
            raise ValueError(
                f"schedule has S={S} stages but a "
                f"{'folded' if folded else 'linear'} executor over D={D} "
                f"devices lowers S = {'2*V*D' if folded else 'V*D'} "
                "(an integer number of stage slots per device)")
        half = S // 2 if folded else S
        if device_of_stage is None:
            if folded:
                device_of_stage = (
                    lambda s: (s % D) if s < half else (S - 1 - s) % D)
            else:
                device_of_stage = lambda s: s % D
        V, enc_slot, dec_slot = _slot_maps(S, D, folded, device_of_stage)
        fwd = sorted((p for p in sched.placements if p.virtual < S),
                     key=lambda p: (p.step, p.device))
        steps = sorted({p.step for p in fwd})
        k_of_step = {t: k for k, t in enumerate(steps)}
        T = len(steps)

        sel = np.zeros((D, T), dtype=np.int32)
        slot = np.zeros((D, T), dtype=np.int32)
        mb = np.zeros((D, T), dtype=np.int32)
        down_mb = np.zeros((D, T), dtype=np.int32)
        down_valid = np.zeros((D, T), dtype=bool)
        up_mb = np.zeros((D, T), dtype=np.int32)
        up_valid = np.zeros((D, T), dtype=bool)
        loss = np.zeros((D, T), dtype=bool)
        embed = np.zeros((D, T), dtype=bool)
        turn_rd = np.zeros((D, T), dtype=bool)
        turn_wr = np.zeros((D, T), dtype=bool)

        def mark_rx(tab, ok, dev, k, m, chan):
            if k >= T:
                raise ValueError(
                    f"message for m={m} sent on the last forward step has "
                    "no consumer step — run validate_schedule")
            if ok[dev, k]:
                raise ValueError(
                    f"two messages on the {chan} channel of device {dev} "
                    f"at forward step {k} — run validate_schedule")
            tab[dev, k] = m
            ok[dev, k] = True

        k_of_task: dict[tuple[int, int], int] = {}
        for p in fwd:
            v, m, dev = p.virtual, p.microbatch, p.device
            err = placement_bounds_error(p, S, M, D)
            if err is not None:
                raise ValueError(
                    f"placement v={v} m={m}: {err}; run validate_schedule")
            # The stage layout pins each stage to the partition's device
            # mapping; routing below assumes it.  A schedule with a
            # permuted device mapping (e.g. an ILP free-mapping solve) is
            # *valid* but not realizable on this layout — reject it here
            # rather than run the wrong stage's parameters silently.
            canon = device_of_stage(v)
            if dev != canon:
                raise ValueError(
                    f"placement v={v} m={m} on device {dev}, but this "
                    f"executor's stage layout pins stage {v} to device "
                    f"{canon} (slot "
                    f"{enc_slot.get(v, dec_slot.get(v))}); re-synthesize "
                    "the schedule with the partition's device_of_stage")
            k = k_of_step[p.step]
            if sel[dev, k] != IDLE:
                raise ValueError(
                    f"device {dev} double-booked at step {p.step} — run "
                    "validate_schedule")
            k_of_task[(v, m)] = k
            mb[dev, k] = m
            is_enc = v < half
            sel[dev, k] = RUN_ENC if is_enc else RUN_DEC
            slot[dev, k] = enc_slot[v] if is_enc else dec_slot[v]
            if v == 0:
                embed[dev, k] = True
            if folded and v == half:
                turn_rd[dev, k] = True
            if folded and v == half - 1:
                # turnaround — consumed locally from the turn buffer by
                # stage S/2, which must share the device; no send.
                turn_wr[dev, k] = True
                if device_of_stage(half) != dev:
                    raise ValueError(
                        f"turnaround stages {half - 1},{half} on devices "
                        f"{dev},{device_of_stage(half)}: the fold "
                        "collocates them (constraint (9))")
            elif v < S - 1:
                # enc -> enc rides the down ring, dec -> dec the up ring
                # (both wrap: interleaved slot boundaries cross D-1 -> 0);
                # the consumer must be the matching ring neighbour.
                nd = device_of_stage(v + 1)
                want = (dev + 1) % D if is_enc else (dev - 1) % D
                if nd != want:
                    raise ValueError(
                        f"stage {v} on device {dev} (slot "
                        f"{slot[dev, k]}) feeds stage {v + 1} on device "
                        f"{nd}, but the ring executors only deliver to "
                        f"device {want}")
                if is_enc:
                    mark_rx(down_mb, down_valid, nd, k + 1, m, "down")
                else:
                    mark_rx(up_mb, up_valid, nd, k + 1, m, "up")
            if v == S - 1:
                loss[dev, k] = True

        # Dataflow feasibility: each forward task's input must have been
        # produced at an earlier compressed step (so it arrived — one
        # ppermute hop — at or before the consumer's step).
        for p in fwd:
            if p.virtual == 0:
                continue
            dep = (p.virtual - 1, p.microbatch)
            if dep not in k_of_task:
                raise ValueError(
                    f"task v={p.virtual} m={p.microbatch} has no scheduled "
                    "predecessor — run validate_schedule")
            if k_of_task[(p.virtual, p.microbatch)] < k_of_task[dep] + 1:
                raise ValueError(
                    f"task v={p.virtual} m={p.microbatch} runs before its "
                    "input can arrive (constraint (10)) — run "
                    "validate_schedule")

        return cls(D=D, M=M, V=V, forward_steps=tuple(steps), sel=sel,
                   slot=slot, mb=mb,
                   down_mb=down_mb, down_valid=down_valid, up_mb=up_mb,
                   up_valid=up_valid, loss=loss, embed=embed,
                   turn_rd=turn_rd, turn_wr=turn_wr,
                   embed_device=device_of_stage(0),
                   turn_device=device_of_stage(half - 1) if folded else -1)


@functools.lru_cache(maxsize=256)
def _tables_cached(sched: Schedule, folded: bool,
                   devices: tuple[int, ...]) -> StepTables:
    return StepTables._build(sched, folded, lambda s: devices[s])


# ===========================================================================
# Microbatch-indexed scan buffers
# ===========================================================================

def _zeros_buffer(proto: Pytree, M: int) -> Pytree:
    """``[M, ...]`` zero buffer per leaf (proto may be concrete or structs)."""
    return jax.tree.map(
        lambda t: jnp.zeros((M,) + tuple(t.shape), t.dtype), proto)


def _buf_store(buf: Pytree, m, val: Pytree, pred) -> Pytree:
    """``buf[m] = val`` where ``pred`` (scalar bool), identity otherwise."""
    return jax.tree.map(
        lambda b, v: jnp.where(
            pred, jax.lax.dynamic_update_index_in_dim(b, v, m, 0), b),
        buf, val)


def _buf_store2(buf: Pytree, m, v_idx, val: Pytree, pred) -> Pytree:
    """``buf[m, v_idx] = val`` where ``pred`` — the (microbatch, slot)
    indexed store interleaved plans use for their per-slot skip stash."""
    def upd(b, x):
        idx = (m, v_idx) + (0,) * (b.ndim - 2)
        return jnp.where(
            pred, jax.lax.dynamic_update_slice(b, x[None, None], idx), b)

    return jax.tree.map(upd, buf, val)


# ===========================================================================
# Folded wave executor from tables
# ===========================================================================

def make_wave_pipeline_from_schedule(
    cfg: PipelineConfig,
    sched: Schedule,
    *,
    embed_fn: Callable,       # (edge_p, mb, aux) -> tokens
    enc_stage_fn: Callable,   # (stage_p, x, aux, slot) -> (x_out, skips)
    dec_stage_fn: Callable,   # (stage_p, x, skips, aux, slot) -> x_out
    loss_fn: Callable,        # (edge_p, x_final, mb, aux) -> scalar
    device_of_stage=None,     # partition's explicit stage->device mapping
    devices=None,             # ...same, as a tuple (memoized lowering)
) -> Callable:
    """Lower a folded S=2VD schedule to ``fn(enc_stack, dec_stack, edge_p,
    mbs, aux) -> loss`` (same call signature as ``make_wave_pipeline``, but
    the stage stacks carry a leading slot axis: ``[D, V, pad, ...]``).

    Each scan step consults the schedule-derived tables: arrivals are
    stored into microbatch-indexed receive buffers, the selected stage slot
    runs on the slot's microbatch with its own parameter rows
    (``stack[d, slot]``), encoder slots stash their skips under the
    (microbatch, slot) index — and the turnaround slot the activation under
    the microbatch — so each decoder slot reads exactly the skips its
    collocated encoder slot produced.  Correct for any valid schedule,
    including ``M < D`` and interleaved V > 1 plans; the rings wrap
    (interleaved slot boundaries cross device D-1 -> 0).

    ``enc_stage_fn`` / ``dec_stage_fn`` receive the *slot index* as their
    last argument so callers can select per-slot block counts and skip
    pairings (see ``runtime.compile``).
    """
    D, M, axis = cfg.num_devices, cfg.num_microbatches, cfg.axis
    if sched.M != M or sched.D != D:
        raise ValueError(
            f"schedule (M={sched.M}, D={sched.D}) does not match the "
            f"pipeline config (M={M}, D={D})")
    tables = StepTables.from_schedule(sched, folded=True,
                                      device_of_stage=device_of_stage,
                                      devices=devices)
    T, V = tables.num_steps, tables.V
    down_perm, up_perm = ring_perms(D, wrap=True)
    enc_stage = _wrap_remat(enc_stage_fn, cfg)
    dec_stage = _wrap_remat(dec_stage_fn, cfg)

    def fn(enc_stack, dec_stack, edge_p, mbs, aux):
        d = jax.lax.axis_index(axis)
        enc_p = tree_local(enc_stack)       # [V, enc_pad, ...]
        dec_p = tree_local(dec_stack)       # [V, dec_pad, ...]

        mb0 = tree_index(mbs, 0)
        aux0 = tree_index(aux, 0)
        x_proto = jax.eval_shape(embed_fn, edge_p, mb0, aux0)
        zero_x = jnp.zeros(x_proto.shape, x_proto.dtype)
        skips_proto = jax.eval_shape(
            lambda p, x, a: enc_stage(p, x, a, 0)[1],
            tree_index(enc_p, 0), zero_x, aux0)
        zero_skips = jax.tree.map(
            lambda t: jnp.zeros(t.shape, t.dtype), skips_proto)

        # This device's rows of every table (host constants -> jnp).
        sel_t = jnp.asarray(tables.sel)[d]
        slot_t = jnp.asarray(tables.slot)[d]
        mb_t = jnp.asarray(tables.mb)[d]
        dmb_t = jnp.asarray(tables.down_mb)[d]
        dok_t = jnp.asarray(tables.down_valid)[d]
        umb_t = jnp.asarray(tables.up_mb)[d]
        uok_t = jnp.asarray(tables.up_valid)[d]
        loss_t = jnp.asarray(tables.loss)[d]
        emb_t = jnp.asarray(tables.embed)[d]
        trd_t = jnp.asarray(tables.turn_rd)[d]
        twr_t = jnp.asarray(tables.turn_wr)[d]

        def cache_zeros(proto):
            # [M, V, enc_pad, ...]: per-(microbatch, slot) skip stash
            return jax.tree.map(
                lambda t: jnp.zeros((M, V) + tuple(t.shape), t.dtype), proto)

        init = (
            zero_x,                         # down-ring register
            zero_x,                         # up-ring register
            _zeros_buffer(zero_x, M),       # enc_rx[m]: down arrivals
            _zeros_buffer(zero_x, M),       # dec_rx[m]: up arrivals
            _zeros_buffer(zero_x, M),       # turn[m]: own turn-slot output
            cache_zeros(zero_skips),        # cache[m, v]: stashed skips
        )

        def step(carry, t):
            down_in, up_in, enc_rx, dec_rx, turn, cache = carry
            enc_rx = _buf_store(enc_rx, dmb_t[t], down_in, dok_t[t])
            dec_rx = _buf_store(dec_rx, umb_t[t], up_in, uok_t[t])
            sel = sel_t[t]
            vslot = slot_t[t]
            m = mb_t[t]
            mb_m = tree_index(mbs, m)
            aux_m = tree_index(aux, m)

            def run_idle(_):
                return zero_x, zero_skips

            def run_enc(_):
                x0 = jax.lax.cond(
                    emb_t[t], lambda: embed_fn(edge_p, mb_m, aux_m),
                    lambda: zero_x)
                x_in = jnp.where(emb_t[t], x0, tree_index(enc_rx, m))
                return enc_stage(tree_index(enc_p, vslot), x_in, aux_m,
                                 vslot)

            def run_dec(_):
                x_in = jnp.where(trd_t[t], tree_index(turn, m),
                                 tree_index(dec_rx, m))
                # flatten the slot axis: consumers address the stash by
                # flat row slot*enc_pad + row (StageLayout.skip_rows)
                skips_m = jax.tree.map(
                    lambda s: s.reshape((s.shape[0] * s.shape[1],)
                                        + s.shape[2:]),
                    tree_index(cache, m))
                x_out = dec_stage(tree_index(dec_p, vslot), x_in, skips_m,
                                  aux_m, vslot)
                return x_out, zero_skips

            x_out, skips = jax.lax.switch(
                sel, (run_idle, run_enc, run_dec), None)
            is_enc = sel == RUN_ENC
            # only the turnaround slot's output is ever read back from
            # turn[m]; gating the store on the table flag saves the
            # [M, ...] buffer write (and its transpose in the backward
            # pass) everywhere else
            turn = _buf_store(turn, m, x_out, twr_t[t])
            cache = _buf_store2(cache, m, vslot, skips, is_enc)
            loss = jax.lax.cond(
                loss_t[t],
                lambda: loss_fn(edge_p, x_out, mb_m, aux_m),
                lambda: jnp.zeros((), jnp.float32))
            down_next = jax.lax.ppermute(x_out, axis, down_perm)
            up_next = jax.lax.ppermute(x_out, axis, up_perm)
            return (down_next, up_next, enc_rx, dec_rx, turn, cache), loss

        _, losses = jax.lax.scan(step, init, jnp.arange(T))
        total = jnp.sum(losses) / M
        return jax.lax.psum(total, (axis, *cfg.data_axes)) / cfg.dp_size

    return fn


# ===========================================================================
# Linear executor from tables
# ===========================================================================

def make_linear_pipeline_from_schedule(
    cfg: PipelineConfig,
    sched: Schedule,
    *,
    embed_fn: Callable,       # (edge_p, mb) -> x
    stage_fn: Callable,       # (stage_p, x, slot) -> x
    loss_fn: Callable,        # (edge_p, x_final, mb) -> scalar
    device_of_stage=None,     # partition's explicit stage->device mapping
    devices=None,             # ...same, as a tuple (memoized lowering)
) -> Callable:
    """Lower a linear S=VD schedule to ``fn(stack, edge_p, mbs) -> loss``
    (same call signature as ``make_linear_pipeline``; the stack carries a
    leading slot axis ``[D, V, pad, ...]`` and ``stage_fn`` receives the
    slot index).  The down ring wraps so interleaved (V > 1) plans cross
    the D-1 -> 0 slot boundary."""
    D, M, axis = cfg.num_devices, cfg.num_microbatches, cfg.axis
    if sched.M != M or sched.D != D:
        raise ValueError(
            f"schedule (M={sched.M}, D={sched.D}) does not match the "
            f"pipeline config (M={M}, D={D})")
    tables = StepTables.from_schedule(sched, folded=False,
                                      device_of_stage=device_of_stage,
                                      devices=devices)
    T = tables.num_steps
    down_perm, _ = ring_perms(D, wrap=True)
    stage = _wrap_remat(stage_fn, cfg)

    def fn(stack, edge_p, mbs):
        d = jax.lax.axis_index(axis)
        my_p = tree_local(stack)            # [V, pad, ...]
        mb0 = tree_index(mbs, 0)
        x_proto = jax.eval_shape(embed_fn, edge_p, mb0)
        zero_x = jnp.zeros(x_proto.shape, x_proto.dtype)

        sel_t = jnp.asarray(tables.sel)[d]
        slot_t = jnp.asarray(tables.slot)[d]
        mb_t = jnp.asarray(tables.mb)[d]
        dmb_t = jnp.asarray(tables.down_mb)[d]
        dok_t = jnp.asarray(tables.down_valid)[d]
        loss_t = jnp.asarray(tables.loss)[d]
        emb_t = jnp.asarray(tables.embed)[d]

        init = (zero_x, _zeros_buffer(zero_x, M))

        def step(carry, t):
            h_in, rx = carry
            rx = _buf_store(rx, dmb_t[t], h_in, dok_t[t])
            m = mb_t[t]
            vslot = slot_t[t]
            mb_m = tree_index(mbs, m)

            def run_idle(_):
                return zero_x

            def run_stage(_):
                x0 = jax.lax.cond(
                    emb_t[t], lambda: embed_fn(edge_p, mb_m),
                    lambda: zero_x)
                x_in = jnp.where(emb_t[t], x0, tree_index(rx, m))
                return stage(tree_index(my_p, vslot), x_in, vslot)

            x_out = jax.lax.switch(sel_t[t], (run_idle, run_stage), None)
            loss = jax.lax.cond(
                loss_t[t],
                lambda: loss_fn(edge_p, x_out, mb_m),
                lambda: jnp.zeros((), jnp.float32))
            h_next = jax.lax.ppermute(x_out, axis, down_perm)
            return (h_next, rx), loss

        _, losses = jax.lax.scan(step, init, jnp.arange(T))
        total = jnp.sum(losses) / M
        return jax.lax.psum(total, (axis, *cfg.data_axes)) / cfg.dp_size

    return fn
