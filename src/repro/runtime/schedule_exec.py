"""Table-driven pipeline executors lowered from a validated Schedule.

The closed-form executors in ``runtime.pipeline`` realize the wave / 1F1B
orders through index arithmetic baked into the scan body (``my_mb = t - d``,
``skip_row = t2 - (D-1) + 2d``).  A synthesized
:class:`~repro.core.schedule.Schedule` — greedy *or* ILP — therefore never
changed what actually ran, and planner/executor disagreements stayed
invisible.  This module makes the Schedule the single source of truth:

1. :class:`StepTables` extracts, per device, a dense *forward step program*
   from the schedule's F placements: which task (encoder/decoder selector)
   runs at each step, on which microbatch, which receive slot the incoming
   boundary activation lands in, and when to emit the loss.  Every
   cross-device dependency is checked against the synchronous-scan dataflow
   at lowering time — a schedule the executor could not realize raises
   ``ValueError`` here instead of silently computing garbage.

2. :func:`make_wave_pipeline_from_schedule` /
   :func:`make_linear_pipeline_from_schedule` lower those tables into
   shard_map executors.  The scan body reads its (selector, microbatch,
   receive slot, loss mask) from the precomputed per-device arrays; incoming
   activations and each device's skip stash live in microbatch-indexed
   buffers carried through the scan, so the skip cache pairing comes from
   the schedule's actual F placement, not a closed form.  Any *valid*
   schedule — including ILP schedules whose step timing differs from the
   greedy templates — executes exactly as synthesized.

Backward placements (virtual stage >= S) are realized by JAX autodiff as
the transposed scan, mirroring the forward order — the same convention as
the closed-form executors (paper Figs. 8/9 backward halves).

Cost model vs the closed forms: the table executors ppermute both ring
directions every step and carry ``O(M)`` activation buffers (the closed
forms carry one register per direction), trading peak memory for complete
schedule generality.  The closed forms remain available as differential
references via ``auto_pipeline(..., executor="closed_form")``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import Schedule, placement_bounds_error
from repro.runtime.pipeline import (PipelineConfig, _wrap_remat, ring_perms,
                                    tree_index, tree_local)

Pytree = Any

IDLE, RUN_ENC, RUN_DEC = 0, 1, 2


# ===========================================================================
# Step-table extraction (host-side, numpy)
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class StepTables:
    """Per-device forward step programs + message routing for one Schedule.

    All arrays are ``[D, num_steps]`` over the *compressed forward step
    axis*: the schedule's global steps that contain at least one forward
    placement, in order (``forward_steps`` maps compressed index -> global
    step).  Compression preserves the relative order of every placement, so
    the synchronous scan (one ppermute hop per step) realizes the same
    partial order the schedule was validated against.

    - ``sel``: ``IDLE`` / ``RUN_ENC`` / ``RUN_DEC`` (linear pipelines only
      use ``IDLE`` / ``RUN_ENC``).
    - ``mb``: microbatch of the slot (0 when idle — never read).
    - ``down_mb`` / ``down_valid``: receive slot for the down-ring channel
      at the *start* of the step (what the upstream device sent last step).
    - ``up_mb`` / ``up_valid``: same for the up-ring channel.
    - ``loss``: slot computes the final-stage output and emits the loss.
    - ``embed_device`` / ``turn_device``: devices hosting stage 0 (embeds)
      and the turnaround (last encoder / first decoder stage pair) — read
      from the stage->device mapping instead of hardcoding 0 / D-1.
    """

    D: int
    M: int
    forward_steps: tuple[int, ...]
    sel: np.ndarray
    mb: np.ndarray
    down_mb: np.ndarray
    down_valid: np.ndarray
    up_mb: np.ndarray
    up_valid: np.ndarray
    loss: np.ndarray
    embed_device: int = 0
    turn_device: int = -1

    @property
    def num_steps(self) -> int:
        return self.sel.shape[1]

    @classmethod
    def from_schedule(cls, sched: Schedule, *, folded: bool,
                      device_of_stage=None) -> "StepTables":
        """Lower a schedule's forward placements to step tables.

        ``device_of_stage`` is the partition's *explicit* stage->device
        mapping; when omitted the canonical placements (mirror fold /
        identity) are assumed.  Raises ``ValueError`` on any shape the
        synchronous scan cannot realize (malformed placements, a stage
        mapped off the ring neighbourhood its messages need, double-booked
        channels, a consumer scheduled before its input can arrive) — the
        planner/executor mismatches the closed forms used to hide surface
        here.
        """
        S, M, D = sched.S, sched.M, sched.D
        expect_S = 2 * D if folded else D
        if S != expect_S:
            raise ValueError(
                f"schedule has S={S} stages but a "
                f"{'folded' if folded else 'linear'} executor over D={D} "
                f"devices lowers S={expect_S}")
        if device_of_stage is None:
            device_of_stage = (
                (lambda s: min(s, S - 1 - s)) if folded else (lambda s: s))
        fwd = sorted((p for p in sched.placements if p.virtual < S),
                     key=lambda p: (p.step, p.device))
        steps = sorted({p.step for p in fwd})
        k_of_step = {t: k for k, t in enumerate(steps)}
        T = len(steps)

        sel = np.zeros((D, T), dtype=np.int32)
        mb = np.zeros((D, T), dtype=np.int32)
        down_mb = np.zeros((D, T), dtype=np.int32)
        down_valid = np.zeros((D, T), dtype=bool)
        up_mb = np.zeros((D, T), dtype=np.int32)
        up_valid = np.zeros((D, T), dtype=bool)
        loss = np.zeros((D, T), dtype=bool)

        def mark_rx(tab, ok, dev, k, m, chan):
            if k >= T:
                raise ValueError(
                    f"message for m={m} sent on the last forward step has "
                    "no consumer step — run validate_schedule")
            if ok[dev, k]:
                raise ValueError(
                    f"two messages on the {chan} channel of device {dev} "
                    f"at forward step {k} — run validate_schedule")
            tab[dev, k] = m
            ok[dev, k] = True

        k_of_task: dict[tuple[int, int], int] = {}
        for p in fwd:
            v, m, dev = p.virtual, p.microbatch, p.device
            err = placement_bounds_error(p, S, M, D)
            if err is not None:
                raise ValueError(
                    f"placement v={v} m={m}: {err}; run validate_schedule")
            # The stage layout pins each stage to the partition's device
            # mapping; routing below assumes it.  A schedule with a
            # permuted device mapping (e.g. an ILP free-mapping solve) is
            # *valid* but not realizable on this layout — reject it here
            # rather than run the wrong stage's parameters silently.
            canon = device_of_stage(v)
            if dev != canon:
                raise ValueError(
                    f"placement v={v} m={m} on device {dev}, but this "
                    f"executor's stage layout pins stage {v} to device "
                    f"{canon}; re-synthesize the schedule with the "
                    "partition's device_of_stage")
            k = k_of_step[p.step]
            if sel[dev, k] != IDLE:
                raise ValueError(
                    f"device {dev} double-booked at step {p.step} — run "
                    "validate_schedule")
            k_of_task[(v, m)] = k
            mb[dev, k] = m
            if folded:
                sel[dev, k] = RUN_ENC if v < D else RUN_DEC
                if v == D - 1:
                    # turnaround — consumed locally from the turn buffer
                    # by stage D, which must share the device; no send.
                    if device_of_stage(D) != dev:
                        raise ValueError(
                            f"turnaround stages {D - 1},{D} on devices "
                            f"{dev},{device_of_stage(D)}: the fold "
                            "collocates them (constraint (9))")
                elif v < S - 1:
                    # enc -> enc rides the down ring, dec -> dec the up
                    # ring; the consumer must be the matching neighbour.
                    nd = device_of_stage(v + 1)
                    want = dev + 1 if v < D else dev - 1
                    if nd != want:
                        raise ValueError(
                            f"stage {v} on device {dev} feeds stage "
                            f"{v + 1} on device {nd}, but the ring "
                            f"executors only deliver to device {want}")
                    if v < D:
                        mark_rx(down_mb, down_valid, nd, k + 1, m, "down")
                    else:
                        mark_rx(up_mb, up_valid, nd, k + 1, m, "up")
            else:
                sel[dev, k] = RUN_ENC
                if v < S - 1:
                    nd = device_of_stage(v + 1)
                    if nd != dev + 1:
                        raise ValueError(
                            f"stage {v} on device {dev} feeds stage "
                            f"{v + 1} on device {nd}, but the linear "
                            f"executor only delivers to device {dev + 1}")
                    mark_rx(down_mb, down_valid, nd, k + 1, m, "down")
            if v == S - 1:
                loss[dev, k] = True

        # Dataflow feasibility: each forward task's input must have been
        # produced at an earlier compressed step (so it arrived — one
        # ppermute hop — at or before the consumer's step).
        for p in fwd:
            if p.virtual == 0:
                continue
            dep = (p.virtual - 1, p.microbatch)
            if dep not in k_of_task:
                raise ValueError(
                    f"task v={p.virtual} m={p.microbatch} has no scheduled "
                    "predecessor — run validate_schedule")
            if k_of_task[(p.virtual, p.microbatch)] < k_of_task[dep] + 1:
                raise ValueError(
                    f"task v={p.virtual} m={p.microbatch} runs before its "
                    "input can arrive (constraint (10)) — run "
                    "validate_schedule")

        return cls(D=D, M=M, forward_steps=tuple(steps), sel=sel, mb=mb,
                   down_mb=down_mb, down_valid=down_valid, up_mb=up_mb,
                   up_valid=up_valid, loss=loss,
                   embed_device=device_of_stage(0),
                   turn_device=device_of_stage(D - 1) if folded else -1)


# ===========================================================================
# Microbatch-indexed scan buffers
# ===========================================================================

def _zeros_buffer(proto: Pytree, M: int) -> Pytree:
    """``[M, ...]`` zero buffer per leaf (proto may be concrete or structs)."""
    return jax.tree.map(
        lambda t: jnp.zeros((M,) + tuple(t.shape), t.dtype), proto)


def _buf_store(buf: Pytree, m, val: Pytree, pred) -> Pytree:
    """``buf[m] = val`` where ``pred`` (scalar bool), identity otherwise."""
    return jax.tree.map(
        lambda b, v: jnp.where(
            pred, jax.lax.dynamic_update_index_in_dim(b, v, m, 0), b),
        buf, val)


# ===========================================================================
# Folded wave executor from tables
# ===========================================================================

def make_wave_pipeline_from_schedule(
    cfg: PipelineConfig,
    sched: Schedule,
    *,
    embed_fn: Callable,       # (edge_p, mb, aux) -> tokens
    enc_stage_fn: Callable,   # (stage_p, x, aux) -> (x_out, skips)
    dec_stage_fn: Callable,   # (stage_p, x, skips, aux) -> x_out
    loss_fn: Callable,        # (edge_p, x_final, mb, aux) -> scalar
    device_of_stage=None,     # partition's explicit stage->device mapping
) -> Callable:
    """Lower a folded S=2D schedule to ``fn(enc_stack, dec_stack, edge_p,
    mbs, aux) -> loss`` (same signature as ``make_wave_pipeline``).

    Each scan step consults the schedule-derived tables: arrivals are
    stored into microbatch-indexed receive buffers, the selected stage runs
    on the slot's microbatch, encoder outputs stash their skips (and, on
    the turnaround device, the activation) under the *microbatch* index, so
    the decoder reads exactly the skips its collocated encoder produced —
    correct for any valid schedule, including ``M < D``.
    """
    D, M, axis = cfg.num_devices, cfg.num_microbatches, cfg.axis
    if sched.M != M or sched.D != D:
        raise ValueError(
            f"schedule (M={sched.M}, D={sched.D}) does not match the "
            f"pipeline config (M={M}, D={D})")
    tables = StepTables.from_schedule(sched, folded=True,
                                      device_of_stage=device_of_stage)
    T = tables.num_steps
    embed_dev, turn_dev = tables.embed_device, tables.turn_device
    down_perm, up_perm = ring_perms(D)
    enc_stage = _wrap_remat(enc_stage_fn, cfg)
    dec_stage = _wrap_remat(dec_stage_fn, cfg)

    def fn(enc_stack, dec_stack, edge_p, mbs, aux):
        d = jax.lax.axis_index(axis)
        enc_p = tree_local(enc_stack)
        dec_p = tree_local(dec_stack)

        mb0 = tree_index(mbs, 0)
        aux0 = tree_index(aux, 0)
        x_proto = jax.eval_shape(embed_fn, edge_p, mb0, aux0)
        zero_x = jnp.zeros(x_proto.shape, x_proto.dtype)
        skips_proto = jax.eval_shape(
            lambda p, x, a: enc_stage(p, x, a)[1], enc_p, zero_x, aux0)
        zero_skips = jax.tree.map(
            lambda t: jnp.zeros(t.shape, t.dtype), skips_proto)

        # This device's rows of every table (host constants -> jnp).
        sel_t = jnp.asarray(tables.sel)[d]
        mb_t = jnp.asarray(tables.mb)[d]
        dmb_t = jnp.asarray(tables.down_mb)[d]
        dok_t = jnp.asarray(tables.down_valid)[d]
        umb_t = jnp.asarray(tables.up_mb)[d]
        uok_t = jnp.asarray(tables.up_valid)[d]
        loss_t = jnp.asarray(tables.loss)[d]

        init = (
            zero_x,                         # down-ring register
            zero_x,                         # up-ring register
            _zeros_buffer(zero_x, M),       # enc_rx[m]: down arrivals
            _zeros_buffer(zero_x, M),       # dec_rx[m]: up arrivals
            _zeros_buffer(zero_x, M),       # turn[m]: own enc output
            _zeros_buffer(zero_skips, M),   # cache[m]: own stashed skips
        )

        def step(carry, t):
            down_in, up_in, enc_rx, dec_rx, turn, cache = carry
            enc_rx = _buf_store(enc_rx, dmb_t[t], down_in, dok_t[t])
            dec_rx = _buf_store(dec_rx, umb_t[t], up_in, uok_t[t])
            sel = sel_t[t]
            m = mb_t[t]
            mb_m = tree_index(mbs, m)
            aux_m = tree_index(aux, m)

            def run_idle(_):
                return zero_x, zero_skips

            def run_enc(_):
                x0 = jax.lax.cond(
                    d == embed_dev, lambda: embed_fn(edge_p, mb_m, aux_m),
                    lambda: zero_x)
                x_in = jnp.where(d == embed_dev, x0, tree_index(enc_rx, m))
                return enc_stage(enc_p, x_in, aux_m)

            def run_dec(_):
                x_in = jnp.where(d == turn_dev, tree_index(turn, m),
                                 tree_index(dec_rx, m))
                x_out = dec_stage(dec_p, x_in, tree_index(cache, m), aux_m)
                return x_out, zero_skips

            x_out, skips = jax.lax.switch(
                sel, (run_idle, run_enc, run_dec), None)
            is_enc = sel == RUN_ENC
            # only the turnaround device ever reads turn[m]; gating the
            # store saves the [M, ...] buffer write (and its transpose in
            # the backward pass) on the other D-1 devices
            turn = _buf_store(turn, m, x_out, is_enc & (d == turn_dev))
            cache = _buf_store(cache, m, skips, is_enc)
            loss = jax.lax.cond(
                loss_t[t],
                lambda: loss_fn(edge_p, x_out, mb_m, aux_m),
                lambda: jnp.zeros((), jnp.float32))
            down_next = jax.lax.ppermute(x_out, axis, down_perm)
            up_next = jax.lax.ppermute(x_out, axis, up_perm)
            return (down_next, up_next, enc_rx, dec_rx, turn, cache), loss

        _, losses = jax.lax.scan(step, init, jnp.arange(T))
        total = jnp.sum(losses) / M
        return jax.lax.psum(total, (axis, *cfg.data_axes)) / cfg.dp_size

    return fn


# ===========================================================================
# Linear executor from tables
# ===========================================================================

def make_linear_pipeline_from_schedule(
    cfg: PipelineConfig,
    sched: Schedule,
    *,
    embed_fn: Callable,       # (edge_p, mb) -> x
    stage_fn: Callable,       # (stage_p, x) -> x
    loss_fn: Callable,        # (edge_p, x_final, mb) -> scalar
    device_of_stage=None,     # partition's explicit stage->device mapping
) -> Callable:
    """Lower a linear S=D schedule to ``fn(stack, edge_p, mbs) -> loss``
    (same signature as ``make_linear_pipeline``)."""
    D, M, axis = cfg.num_devices, cfg.num_microbatches, cfg.axis
    if sched.M != M or sched.D != D:
        raise ValueError(
            f"schedule (M={sched.M}, D={sched.D}) does not match the "
            f"pipeline config (M={M}, D={D})")
    tables = StepTables.from_schedule(sched, folded=False,
                                      device_of_stage=device_of_stage)
    T = tables.num_steps
    embed_dev = tables.embed_device
    down_perm, _ = ring_perms(D)
    stage = _wrap_remat(stage_fn, cfg)

    def fn(stack, edge_p, mbs):
        d = jax.lax.axis_index(axis)
        my_p = tree_local(stack)
        mb0 = tree_index(mbs, 0)
        x_proto = jax.eval_shape(embed_fn, edge_p, mb0)
        zero_x = jnp.zeros(x_proto.shape, x_proto.dtype)

        sel_t = jnp.asarray(tables.sel)[d]
        mb_t = jnp.asarray(tables.mb)[d]
        dmb_t = jnp.asarray(tables.down_mb)[d]
        dok_t = jnp.asarray(tables.down_valid)[d]
        loss_t = jnp.asarray(tables.loss)[d]

        init = (zero_x, _zeros_buffer(zero_x, M))

        def step(carry, t):
            h_in, rx = carry
            rx = _buf_store(rx, dmb_t[t], h_in, dok_t[t])
            m = mb_t[t]
            mb_m = tree_index(mbs, m)

            def run_idle(_):
                return zero_x

            def run_stage(_):
                x0 = jax.lax.cond(
                    d == embed_dev, lambda: embed_fn(edge_p, mb_m),
                    lambda: zero_x)
                x_in = jnp.where(d == embed_dev, x0, tree_index(rx, m))
                return stage(my_p, x_in)

            x_out = jax.lax.switch(sel_t[t], (run_idle, run_stage), None)
            loss = jax.lax.cond(
                loss_t[t],
                lambda: loss_fn(edge_p, x_out, mb_m),
                lambda: jnp.zeros((), jnp.float32))
            h_next = jax.lax.ppermute(x_out, axis, down_perm)
            return (h_next, rx), loss

        _, losses = jax.lax.scan(step, init, jnp.arange(T))
        total = jnp.sum(losses) / M
        return jax.lax.psum(total, (axis, *cfg.data_axes)) / cfg.dp_size

    return fn
