"""Table-driven pipeline executors lowered from a validated Schedule.

The closed-form executors in ``runtime.pipeline`` realize the wave / 1F1B
orders through index arithmetic baked into the scan body (``my_mb = t - d``,
``skip_row = t2 - (D-1) + 2d``).  A synthesized
:class:`~repro.core.schedule.Schedule` — greedy *or* ILP — therefore never
changed what actually ran, and planner/executor disagreements stayed
invisible.  This module makes the Schedule the single source of truth:

1. :class:`StepTables` extracts, per device, a dense *forward step program*
   from the schedule's F placements: which task (encoder/decoder selector
   and *stage slot* — a device runs V slots per kind under an interleaved
   S = 2VD plan) runs at each step, on which microbatch, which receive
   slot the incoming boundary activation lands in, whether the slot
   embeds / reads / writes the turnaround buffer, and when to emit the
   loss.  Every cross-device dependency is checked against the
   synchronous-scan dataflow at lowering time — a schedule the executor
   could not realize raises ``ValueError`` here instead of silently
   computing garbage.  Pass the stage->device mapping as a ``devices``
   tuple to memoize the lowering per (schedule, partition).

2. :func:`make_wave_pipeline_from_schedule` /
   :func:`make_linear_pipeline_from_schedule` lower those tables into
   shard_map executors.  The scan body reads its (selector, slot,
   microbatch, receive slot, loss mask) from the precomputed per-device
   arrays; parameters carry a leading ``[V, pad, ...]`` slot axis indexed
   per step and the rings wrap so interleaved slot boundaries cross
   device D-1 -> 0.  Any *valid* schedule — including ILP schedules whose
   step timing differs from the greedy templates, and interleaved V > 1
   plans — executes exactly as synthesized.

Backward placements (virtual stage >= S) are realized by JAX autodiff as
the transposed scan, mirroring the forward order — the same convention as
the closed-form executors (paper Figs. 8/9 backward halves).

Communication & memory lowering: the step tables are the source of truth
for *what moves and what is resident*, not just execution order.
``StepTables.from_schedule`` additionally runs a per-step, per-ring
**channel activity analysis** (``down_send`` / ``up_send``: which
(device, step) hops actually carry a message) and a **liveness-window
analysis** (first-fit interval coloring of every message / turnaround /
skip-stash lifetime).  The executors lower these directly:

- quiescent hops are zero-masked (a dead step's payload — and, via the
  ``where`` transpose, its backward cotangent — is all-zeros), and a ring
  no schedule message ever rides is elided from the scan body entirely;
- receive / turnaround / skip-stash buffers are *rotating* buffers sized
  by the proven windows ``W_down`` / ``W_up`` / ``W_turn`` / ``W_skip``
  (the max simultaneously-live entries per channel) instead of
  microbatch-indexed ``O(M)`` arrays, with store/read slots precomputed
  per step; skip-stash entries no decoder row ever consumes are dead
  stores and are never written;
- boundary activations cross the wire in ``PipelineConfig.wire_dtype``
  (default bf16; compute stays fp32 — cast-on-send, upcast-on-read).
  The transposed scan converts cotangents through the same casts, so
  backward hops ride the wire dtype symmetrically.  ``wire_dtype=
  "float32"`` is the escape hatch the exact differential tests pin
  (see README "Wire format & buffer liveness" for tolerance guidance).

Comm/compute overlap: with ``PipelineConfig.overlap`` (the default) the
executors *double-buffer* the ring hops — step t's payload rides the
``ppermute`` issued at the top of step t+1's scan body, before that
step's compute, instead of at the bottom of step t.  The store tables
prove the target receive slot is dead until the arrival's consumer runs,
so prefetching into it is safe; values, arrival steps and windows are
identical to the synchronous lowering (``overlap=False``, the
differential reference), but the collective and the next step's
independent compute now sit in the same scan iteration with no data
dependency between them, so XLA's latency-hiding scheduler can overlap
them.  The analysis classifies each hop as **exposed** (its consumer
runs on the very next forward step — the dependency forces the
collective onto the critical path; cost ``t_p2p``) or **hidden**
(intervening compute covers it; cost ``max(0, t_p2p - t_f)``) —
``exposed_hops`` / ``hidden_hops`` here, mirrored by the planner's
``core.schedule.comm_stats`` and priced by
``core.comm_model.overlap_accounting`` and the tuner's Eq. 15
generalization, so the planner and the executor are held to the same
split the way ``lowered_comm_volume`` already holds the live-hop bytes.

The closed-form executors remain fp32-wire, O(1)-register differential
references via ``auto_pipeline(..., executor="closed_form")``;
``core.comm_model.lowered_comm_volume`` prices exactly the live hops and
wire bytes lowered here, and the tuner's ``peak_memory`` consumes the
same windows.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import (Schedule, placement_bounds_error,
                                 slot_maps)
from repro.runtime.pipeline import (WIRE_DTYPES, PipelineConfig,
                                    _wrap_remat, ring_perms, tree_index,
                                    tree_local, zero_all_gather)

Pytree = Any

IDLE, RUN_ENC, RUN_DEC = 0, 1, 2


class PlanError(ValueError):
    """A plan the lowering cannot realize, with structured context.

    Every rejection carries the name of the violated check plus the
    (device, step, slot) coordinates where the lowering noticed it, so
    callers — and the mutation-soundness suite — can dispatch on
    ``err.check`` instead of grepping message strings.  Subclasses
    ``ValueError``: every pre-existing ``except ValueError`` /
    ``pytest.raises(ValueError, match=...)`` site keeps working, and the
    original message text is preserved verbatim inside the formatted
    string.  ``python -m repro.analysis.verify`` replays the same plan
    through the full static dataflow proof for the complete report.
    """

    POINTER = ("see `python -m repro.analysis.verify` for the full "
               "diagnostic report")

    def __init__(self, message: str, *, check: str,
                 device: int | None = None, step: int | None = None,
                 slot: int | None = None):
        self.check = check
        self.device = device
        self.step = step
        self.slot = slot
        where = ", ".join(
            f"{k}={v}" for k, v in (("device", device), ("step", step),
                                    ("slot", slot)) if v is not None)
        super().__init__(
            f"[{check}{'; ' + where if where else ''}] {message} "
            f"({self.POINTER})")


def _color_intervals(ivs) -> tuple[dict[tuple[int, int], int], int]:
    """First-fit interval coloring by start step.

    ``ivs`` is a list of closed ``(start, end)`` step intervals on ONE
    device's channel; a slot is reusable only *strictly after* its last
    read (stores happen before reads within a step, so an entry arriving
    at the step its slot was last read would clobber it).  First-fit on
    start-sorted intervals is optimal for interval graphs, so the slot
    count equals the max number of simultaneously-live entries — the
    liveness window W the property tests cross-check against an
    event-driven replay.
    """
    ends: list[int] = []                 # slot -> last occupied step
    out: dict[tuple[int, int], int] = {}
    for s, e in sorted(ivs):
        for i, last in enumerate(ends):
            if last < s:
                ends[i] = e
                out[(s, e)] = i
                break
        else:
            out[(s, e)] = len(ends)
            ends.append(e)
    return out, len(ends)


# ===========================================================================
# Step-table extraction (host-side, numpy)
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class StepTables:
    """Per-device forward step programs + message routing for one Schedule.

    All arrays are ``[D, num_steps]`` over the *compressed forward step
    axis*: the schedule's global steps that contain at least one forward
    placement, in order (``forward_steps`` maps compressed index -> global
    step).  Compression preserves the relative order of every placement, so
    the synchronous scan (one ppermute hop per step) realizes the same
    partial order the schedule was validated against.

    - ``sel``: ``IDLE`` / ``RUN_ENC`` / ``RUN_DEC`` (linear pipelines only
      use ``IDLE`` / ``RUN_ENC``).
    - ``slot``: which of the device's V same-kind stage slots the task
      runs (0 for classic V=1 plans; interleaved plans index the [V, pad]
      parameter stacks and per-slot count/pairing tables with it).
    - ``mb``: microbatch of the slot (0 when idle — never read).
    - ``down_mb`` / ``down_valid``: arrival on the down-ring channel at the
      *start* of the step (what the upstream device sent last step), with
      the microbatch for introspection; ``up_mb`` / ``up_valid`` the same
      for the up ring.  ``down_slot`` / ``up_slot`` give the rotating
      receive-buffer slot the arrival is stored into, and ``rx_slot`` the
      slot the step's *running* task reads its input from (undefined — 0 —
      on embed / turnaround-read / idle steps, where the buffers are not
      consulted).
    - ``down_send`` / ``up_send``: this device's hop on the ring actually
      carries a message this step (the channel activity analysis); on
      quiescent steps the executors send zeros and the transposed scan
      carries zero cotangents.
    - ``loss``: slot computes the final-stage output and emits the loss.
    - ``embed`` / ``turn_rd`` / ``turn_wr``: the slot runs stage 0 (embeds
      its input), the first decoder-half stage (reads the local turn
      buffer) or the last encoder-half stage (writes it).  With V > 1 a
      device runs several enc/dec slots, so these are per-(device, step)
      facts, not per-device ones — ``embed_device`` / ``turn_device`` stay
      as informational summaries.  ``turn_wr_slot`` / ``turn_rd_slot``
      give the rotating turn-buffer slot written / read.
    - ``skip_wr`` / ``skip_wr_slot``: the encoder slot's skip stash is
      live (some decoder row consumes it — dead stores are elided) and
      where it goes; ``skip_rd_slot[d, t, v]`` is the stash slot holding
      encoder-slot ``v``'s entry for the decoder task's microbatch
      (gathered into the ``[V * enc_pad]`` flat view
      ``StageLayout.skip_rows`` addresses).
    - ``W_down`` / ``W_up`` / ``W_turn`` / ``W_skip``: the proven liveness
      windows — max simultaneously-live entries per channel across
      devices; the executors allocate exactly these many buffer slots.
    """

    D: int
    M: int
    V: int
    rings: int                     # 2 folded (down + up), 1 linear
    forward_steps: tuple[int, ...]
    sel: np.ndarray
    slot: np.ndarray
    mb: np.ndarray
    down_mb: np.ndarray
    down_valid: np.ndarray
    up_mb: np.ndarray
    up_valid: np.ndarray
    loss: np.ndarray
    embed: np.ndarray
    turn_rd: np.ndarray
    turn_wr: np.ndarray
    # ---- channel activity + liveness lowering --------------------------
    down_send: np.ndarray
    up_send: np.ndarray
    down_slot: np.ndarray
    up_slot: np.ndarray
    rx_slot: np.ndarray
    turn_wr_slot: np.ndarray
    turn_rd_slot: np.ndarray
    skip_wr: np.ndarray
    skip_wr_slot: np.ndarray
    skip_rd_slot: np.ndarray
    W_down: int
    W_up: int
    W_turn: int
    W_skip: int
    # hops whose consumer runs on the very next forward step: the arrival's
    # dependency serializes the collective against compute even in the
    # overlapped lowering (the rest are hidden under intervening steps)
    exposed_down: int
    exposed_up: int
    embed_device: int = 0
    turn_device: int = -1

    @property
    def num_steps(self) -> int:
        return self.sel.shape[1]

    @property
    def live_hops(self) -> tuple[int, int]:
        """(down, up) hops that actually carry a message (fwd pass)."""
        return int(self.down_send.sum()), int(self.up_send.sum())

    @property
    def dense_hops(self) -> int:
        """Hops the pre-liveness lowering paid: every ring, every step."""
        return self.rings * self.D * self.num_steps

    @property
    def exposed_hops(self) -> int:
        """Live hops whose consumer runs one step after the producer —
        the overlapped executor cannot hide these under compute."""
        return self.exposed_down + self.exposed_up

    @property
    def hidden_hops(self) -> int:
        """Live hops with at least one intervening step before their
        consumer: the overlapped lowering prefetches them under compute."""
        down, up = self.live_hops
        return down + up - self.exposed_hops

    @classmethod
    def from_schedule(cls, sched: Schedule, *, folded: bool,
                      device_of_stage=None,
                      devices: tuple[int, ...] | None = None,
                      skip_consumers=None) -> "StepTables":
        """Lower a schedule's forward placements to step tables.

        ``device_of_stage`` is the partition's *explicit* stage->device
        mapping; when omitted the canonical placements (mirror fold /
        identity, or their V-fold interleaved generalization) are assumed.
        Pass the mapping as a ``devices`` *tuple* instead to memoize the
        lowering per (schedule, folded, devices, skip_consumers) — the
        tuner's candidate loop and repeated ``auto_pipeline`` calls then
        reuse the O(S*M*steps) extraction.

        ``skip_consumers[d][dec_slot]`` optionally lists the encoder slots
        whose stash entries device ``d``'s decoder slot actually consumes
        (``StageLayout`` derives this from the graph's skip edges — see
        ``runtime.compile``).  Without it the analysis is conservative:
        every decoder slot may read every encoder slot, so stash entries
        stay live until the device's last decoder task of the microbatch.
        With it, unconsumed entries become dead stores (never written) and
        the skip window shrinks on sparse graphs.  Must be nested tuples
        when combined with ``devices`` (the memoization key).

        Raises ``ValueError`` on any shape the synchronous scan cannot
        realize (malformed placements, a stage mapped off the ring
        neighbourhood its messages need, double-booked channels, a
        consumer scheduled before its input can arrive) — the
        planner/executor mismatches the closed forms used to hide surface
        here.
        """
        if devices is not None:
            if device_of_stage is not None:
                raise ValueError("pass device_of_stage or devices, not both")
            return _tables_cached(sched, folded, tuple(devices),
                                  skip_consumers)
        return cls._build(sched, folded, device_of_stage, skip_consumers)

    @classmethod
    def _build(cls, sched: Schedule, folded: bool,
               device_of_stage, skip_consumers=None) -> "StepTables":
        S, M, D = sched.S, sched.M, sched.D
        if (S % (2 * D) if folded else S % D) != 0:
            raise PlanError(
                f"schedule has S={S} stages but a "
                f"{'folded' if folded else 'linear'} executor over D={D} "
                f"devices lowers S = {'2*V*D' if folded else 'V*D'} "
                "(an integer number of stage slots per device)",
                check="program-shape")
        half = S // 2 if folded else S
        if device_of_stage is None:
            if folded:
                device_of_stage = (
                    lambda s: (s % D) if s < half else (S - 1 - s) % D)
            else:
                device_of_stage = lambda s: s % D
        V, enc_slot, dec_slot = slot_maps(S, D, folded, device_of_stage)
        if skip_consumers is not None:
            if len(skip_consumers) != D or any(
                    len(dev) != V for dev in skip_consumers):
                raise PlanError(
                    f"skip_consumers must list every (device, dec slot): "
                    f"expected [{D}][{V}], got "
                    f"{[len(dev) for dev in skip_consumers]}",
                    check="program-shape")
        fwd = sorted((p for p in sched.placements if p.virtual < S),
                     key=lambda p: (p.step, p.device))
        steps = sorted({p.step for p in fwd})
        k_of_step = {t: k for k, t in enumerate(steps)}
        T = len(steps)

        sel = np.zeros((D, T), dtype=np.int32)
        slot = np.zeros((D, T), dtype=np.int32)
        mb = np.zeros((D, T), dtype=np.int32)
        down_mb = np.zeros((D, T), dtype=np.int32)
        down_valid = np.zeros((D, T), dtype=bool)
        up_mb = np.zeros((D, T), dtype=np.int32)
        up_valid = np.zeros((D, T), dtype=bool)
        loss = np.zeros((D, T), dtype=bool)
        embed = np.zeros((D, T), dtype=bool)
        turn_rd = np.zeros((D, T), dtype=bool)
        turn_wr = np.zeros((D, T), dtype=bool)

        def mark_rx(tab, ok, dev, k, m, chan):
            if k >= T:
                raise PlanError(
                    f"message for m={m} sent on the last forward step has "
                    "no consumer step — run validate_schedule",
                    check="no-lost-message", device=dev)
            if ok[dev, k]:
                raise PlanError(
                    f"two messages on the {chan} channel of device {dev} "
                    f"at forward step {k} — run validate_schedule",
                    check="send-recv-pairing", device=dev, step=k)
            tab[dev, k] = m
            ok[dev, k] = True

        # message / buffer-lifetime event logs for the liveness analysis
        msgs_down: list[tuple[int, int, int, int, int]] = []
        msgs_up: list[tuple[int, int, int, int, int]] = []
        turn_writes: dict[tuple[int, int], int] = {}   # (dev, m) -> step
        turn_reads: dict[tuple[int, int], int] = {}
        enc_runs: list[tuple[int, int, int, int]] = []  # (dev, k, m, vslot)
        dec_runs: list[tuple[int, int, int, int]] = []

        k_of_task: dict[tuple[int, int], int] = {}
        for p in fwd:
            v, m, dev = p.virtual, p.microbatch, p.device
            err = placement_bounds_error(p, S, M, D)
            if err is not None:
                raise PlanError(
                    f"placement v={v} m={m}: {err}; run validate_schedule",
                    check="placement-bounds")
            # The stage layout pins each stage to the partition's device
            # mapping; routing below assumes it.  A schedule with a
            # permuted device mapping (e.g. an ILP free-mapping solve) is
            # *valid* but not realizable on this layout — reject it here
            # rather than run the wrong stage's parameters silently.
            canon = device_of_stage(v)
            if dev != canon:
                raise PlanError(
                    f"placement v={v} m={m} on device {dev}, but this "
                    f"executor's stage layout pins stage {v} to device "
                    f"{canon} (slot "
                    f"{enc_slot.get(v, dec_slot.get(v))}); re-synthesize "
                    "the schedule with the partition's device_of_stage",
                    check="stage-routing", device=dev)
            k = k_of_step[p.step]
            if sel[dev, k] != IDLE:
                raise PlanError(
                    f"device {dev} double-booked at step {p.step} — run "
                    "validate_schedule",
                    check="program-shape", device=dev, step=k)
            k_of_task[(v, m)] = k
            mb[dev, k] = m
            is_enc = v < half
            sel[dev, k] = RUN_ENC if is_enc else RUN_DEC
            slot[dev, k] = enc_slot[v] if is_enc else dec_slot[v]
            (enc_runs if is_enc else dec_runs).append(
                (dev, k, m, int(slot[dev, k])))
            if v == 0:
                embed[dev, k] = True
            if folded and v == half:
                turn_rd[dev, k] = True
                turn_reads[(dev, m)] = k
            if folded and v == half - 1:
                # turnaround — consumed locally from the turn buffer by
                # stage S/2, which must share the device; no send.
                turn_wr[dev, k] = True
                turn_writes[(dev, m)] = k
                if device_of_stage(half) != dev:
                    raise PlanError(
                        f"turnaround stages {half - 1},{half} on devices "
                        f"{dev},{device_of_stage(half)}: the fold "
                        "collocates them (constraint (9))",
                        check="stage-routing", device=dev)
            elif v < S - 1:
                # enc -> enc rides the down ring, dec -> dec the up ring
                # (both wrap: interleaved slot boundaries cross D-1 -> 0);
                # the consumer must be the matching ring neighbour.
                nd = device_of_stage(v + 1)
                want = (dev + 1) % D if is_enc else (dev - 1) % D
                if nd != want:
                    raise PlanError(
                        f"stage {v} on device {dev} (slot "
                        f"{slot[dev, k]}) feeds stage {v + 1} on device "
                        f"{nd}, but the ring executors only deliver to "
                        f"device {want}",
                        check="stage-routing", device=dev, step=k,
                        slot=int(slot[dev, k]))
                if is_enc:
                    mark_rx(down_mb, down_valid, nd, k + 1, m, "down")
                    msgs_down.append((dev, nd, k, v, m))
                else:
                    mark_rx(up_mb, up_valid, nd, k + 1, m, "up")
                    msgs_up.append((dev, nd, k, v, m))
            if v == S - 1:
                loss[dev, k] = True

        # Dataflow feasibility: each forward task's input must have been
        # produced at an earlier compressed step (so it arrived — one
        # ppermute hop — at or before the consumer's step).
        for p in fwd:
            if p.virtual == 0:
                continue
            dep = (p.virtual - 1, p.microbatch)
            if dep not in k_of_task:
                raise PlanError(
                    f"task v={p.virtual} m={p.microbatch} has no scheduled "
                    "predecessor — run validate_schedule",
                    check="matched-store-read", device=p.device)
            if k_of_task[(p.virtual, p.microbatch)] < k_of_task[dep] + 1:
                raise PlanError(
                    f"task v={p.virtual} m={p.microbatch} runs before its "
                    "input can arrive (constraint (10)) — run "
                    "validate_schedule",
                    check="matched-store-read", device=p.device,
                    step=k_of_task[(p.virtual, p.microbatch)])

        # ---- channel activity + liveness windows -----------------------
        down_send = np.zeros((D, T), dtype=bool)
        up_send = np.zeros((D, T), dtype=bool)
        down_slot = np.zeros((D, T), dtype=np.int32)
        up_slot = np.zeros((D, T), dtype=np.int32)
        rx_slot = np.zeros((D, T), dtype=np.int32)
        windows = {}
        exposed = {}
        for name, msgs, send_tab, slot_tab in (
                ("down", msgs_down, down_send, down_slot),
                ("up", msgs_up, up_send, up_slot)):
            by_dev: dict[int, list[tuple[int, int]]] = {}
            n_exposed = 0
            for src, dst, k_prod, v, m in msgs:
                send_tab[src, k_prod] = True
                # in flight in the receiver's buffer from arrival (start
                # of k_prod + 1) until its consumer runs
                k_cons = k_of_task[(v + 1, m)]
                by_dev.setdefault(dst, []).append((k_prod + 1, k_cons))
                if k_cons == k_prod + 1:
                    n_exposed += 1
            exposed[name] = n_exposed
            W = 0
            for dst, ivs in by_dev.items():
                assign, w = _color_intervals(ivs)
                W = max(W, w)
                for (k_arr, k_cons), sl in assign.items():
                    slot_tab[dst, k_arr] = sl
                    rx_slot[dst, k_cons] = sl
            windows[name] = W

        turn_wr_slot = np.zeros((D, T), dtype=np.int32)
        turn_rd_slot = np.zeros((D, T), dtype=np.int32)
        by_dev = {}
        for (dev, m), kw in turn_writes.items():
            kr = turn_reads.get((dev, m))
            if kr is None:
                turn_wr[dev, kw] = False    # dead store: no reader
                continue
            by_dev.setdefault(dev, []).append((kw, kr))
        W_turn = 0
        for dev, ivs in by_dev.items():
            assign, w = _color_intervals(ivs)
            W_turn = max(W_turn, w)
            for (kw, kr), sl in assign.items():
                turn_wr_slot[dev, kw] = sl
                turn_rd_slot[dev, kr] = sl

        # Skip stash: entry (device, microbatch, enc slot) is written when
        # the encoder slot runs and stays live until the last decoder task
        # whose slot consumes it.  Without skip_consumers every decoder
        # slot is assumed to read every encoder slot (conservative).
        skip_wr = np.zeros((D, T), dtype=bool)
        skip_wr_slot = np.zeros((D, T), dtype=np.int32)
        skip_rd_slot = np.zeros((D, T, V), dtype=np.int32)
        last_read: dict[tuple[int, int, int], int] = {}
        for dev, k2, m, dv in dec_runs:
            evs = (range(V) if skip_consumers is None
                   else skip_consumers[dev][dv])
            for ev in evs:
                if not 0 <= ev < V:
                    raise PlanError(
                        f"skip_consumers names enc slot {ev} on device "
                        f"{dev}, but the layout has V={V} slots",
                        check="program-shape", device=dev, slot=ev)
                key = (dev, m, ev)
                if last_read.get(key, -1) < k2:
                    last_read[key] = k2
        per_dev: dict[int, list[tuple[int, int]]] = {}
        entry_of: dict[tuple[int, int, int], tuple[int, int]] = {}
        for dev, k, m, vslot in enc_runs:
            if not folded:
                continue
            end = last_read.get((dev, m, vslot))
            if end is None:
                continue                    # dead store: never consumed
            skip_wr[dev, k] = True
            per_dev.setdefault(dev, []).append((k, end))
            entry_of[(dev, m, vslot)] = (k, end)
        W_skip = 0
        entry_slot: dict[tuple[int, int, int], int] = {}
        for dev, ivs in per_dev.items():
            assign, w = _color_intervals(ivs)
            W_skip = max(W_skip, w)
            for key, iv in entry_of.items():
                if key[0] == dev:
                    entry_slot[key] = assign[iv]
        for dev, k2, m, dv in dec_runs:
            for ev in range(V):
                skip_rd_slot[dev, k2, ev] = entry_slot.get((dev, m, ev), 0)
        for dev, k, m, vslot in enc_runs:
            if skip_wr[dev, k]:
                skip_wr_slot[dev, k] = entry_slot[(dev, m, vslot)]

        return cls(D=D, M=M, V=V, rings=2 if folded else 1,
                   forward_steps=tuple(steps), sel=sel,
                   slot=slot, mb=mb,
                   down_mb=down_mb, down_valid=down_valid, up_mb=up_mb,
                   up_valid=up_valid, loss=loss, embed=embed,
                   turn_rd=turn_rd, turn_wr=turn_wr,
                   down_send=down_send, up_send=up_send,
                   down_slot=down_slot, up_slot=up_slot, rx_slot=rx_slot,
                   turn_wr_slot=turn_wr_slot, turn_rd_slot=turn_rd_slot,
                   skip_wr=skip_wr, skip_wr_slot=skip_wr_slot,
                   skip_rd_slot=skip_rd_slot,
                   W_down=windows["down"], W_up=windows["up"],
                   W_turn=W_turn, W_skip=W_skip,
                   exposed_down=exposed["down"], exposed_up=exposed["up"],
                   embed_device=device_of_stage(0),
                   turn_device=device_of_stage(half - 1) if folded else -1)


@functools.lru_cache(maxsize=256)
def _tables_cached(sched: Schedule, folded: bool,
                   devices: tuple[int, ...],
                   skip_consumers) -> StepTables:
    return StepTables._build(sched, folded, lambda s: devices[s],
                             skip_consumers)


# ===========================================================================
# Rotating scan buffers (slot-indexed; sized by the liveness windows)
# ===========================================================================

def _zeros_buffer(proto: Pytree, W: int, dtype=None) -> Pytree:
    """``[W, ...]`` zero buffer per leaf (proto may be concrete or structs)."""
    return jax.tree.map(
        lambda t: jnp.zeros((W,) + tuple(t.shape), dtype or t.dtype), proto)


def _buf_store(buf: Pytree, i, val: Pytree, pred) -> Pytree:
    """``buf[i] = val`` where ``pred`` (scalar bool), identity otherwise."""
    return jax.tree.map(
        lambda b, v: jnp.where(
            pred, jax.lax.dynamic_update_index_in_dim(
                b, v.astype(b.dtype), i, 0), b),
        buf, val)


def _gather_rows(buf: Pytree, rows) -> Pytree:
    """``buf[rows]`` flattened over the gathered axis: ``[W, pad, ...]``
    leaves gathered with a ``[V]`` slot vector -> ``[V * pad, ...]`` (the
    flat stash view ``StageLayout.skip_rows`` addresses)."""
    return jax.tree.map(
        lambda b: jnp.take(b, rows, axis=0).reshape(
            (rows.shape[0] * b.shape[1],) + b.shape[2:]), buf)


def _wire_dtype(cfg: PipelineConfig):
    if cfg.wire_dtype not in WIRE_DTYPES:
        raise PlanError(
            f"unknown wire_dtype {cfg.wire_dtype!r}; expected one of "
            f"{WIRE_DTYPES} (float32 is the exact-differential escape "
            "hatch)",
            check="wire-dtype-flow")
    return jnp.dtype(cfg.wire_dtype)


# ===========================================================================
# Folded wave executor from tables
# ===========================================================================

def make_wave_pipeline_from_schedule(
    cfg: PipelineConfig,
    sched: Schedule,
    *,
    embed_fn: Callable,       # (edge_p, mb, aux) -> tokens
    enc_stage_fn: Callable,   # (stage_p, x, aux, slot) -> (x_out, skips)
    dec_stage_fn: Callable,   # (stage_p, x, skips, aux, slot) -> x_out
    loss_fn: Callable,        # (edge_p, x_final, mb, aux) -> scalar
    device_of_stage=None,     # partition's explicit stage->device mapping
    devices=None,             # ...same, as a tuple (memoized lowering)
    skip_consumers=None,      # layout-derived (device, dec slot) -> enc slots
    zero_dims=None,           # (enc_dims, dec_dims): ZeRO-2 slot-view
    #   gather dims per stack leaf (runtime.sharding.zero_stack_specs);
    #   None = unsharded stacks
) -> Callable:
    """Lower a folded S=2VD schedule to ``fn(enc_stack, dec_stack, edge_p,
    mbs, aux) -> loss`` (same call signature as ``make_wave_pipeline``, but
    the stage stacks carry a leading slot axis: ``[D, V, pad, ...]``).

    With ``zero_dims`` the stacks arrive ZeRO-2 rest-sharded over
    ``cfg.data_axes`` (their shard_map in_specs carry the matching
    ``P("data", ...)``-suffixed entries): each stage invocation
    all-gathers its slot's leaves on use *inside* the remat region, so
    backward re-gathers instead of retaining the full params and the
    gather's transpose reduce-scatters the gradient over the data axis.

    Each scan step consults the schedule-derived tables: arrivals are
    stored into rotating receive buffers sized by the proven windows, the
    selected stage slot runs on the slot's microbatch with its own
    parameter rows (``stack[d, slot]``), encoder slots stash their skips
    under the precomputed stash slot — and the turnaround slot the
    activation under its turn slot — so each decoder slot reads exactly
    the skips its collocated encoder slot produced.  Boundary activations
    cross the rings in ``cfg.wire_dtype`` (zero-masked on quiescent
    steps); compute stays in the model dtype.  Correct for any valid
    schedule, including ``M < D`` and interleaved V > 1 plans; the rings
    wrap (interleaved slot boundaries cross device D-1 -> 0).

    ``enc_stage_fn`` / ``dec_stage_fn`` receive the *slot index* as their
    last argument so callers can select per-slot block counts and skip
    pairings (see ``runtime.compile``).
    """
    D, M, axis = cfg.num_devices, cfg.num_microbatches, cfg.axis
    if sched.M != M or sched.D != D:
        raise PlanError(
            f"schedule (M={sched.M}, D={sched.D}) does not match the "
            f"pipeline config (M={M}, D={D})",
            check="program-shape")
    tables = StepTables.from_schedule(sched, folded=True,
                                      device_of_stage=device_of_stage,
                                      devices=devices,
                                      skip_consumers=skip_consumers)
    T, V = tables.num_steps, tables.V
    wire = _wire_dtype(cfg)
    down_perm, up_perm = ring_perms(D, wrap=True)
    # a ring no message ever rides is elided from the scan body entirely
    down_used = bool(tables.down_send.any())
    up_used = bool(tables.up_send.any())
    W_down = max(tables.W_down, 1)
    W_up = max(tables.W_up, 1)
    W_turn = max(tables.W_turn, 1)
    W_skip = max(tables.W_skip, 1)
    if zero_dims is not None:
        enc_dims, dec_dims = zero_dims
        enc_inner, dec_inner = enc_stage_fn, dec_stage_fn

        def enc_stage_fn(stage_p, x, aux_m, slot):  # noqa: F811
            stage_p = zero_all_gather(stage_p, enc_dims, cfg.data_axes)
            return enc_inner(stage_p, x, aux_m, slot)

        def dec_stage_fn(stage_p, x, skips, aux_m, slot):  # noqa: F811
            stage_p = zero_all_gather(stage_p, dec_dims, cfg.data_axes)
            return dec_inner(stage_p, x, skips, aux_m, slot)
    enc_stage = _wrap_remat(enc_stage_fn, cfg)
    dec_stage = _wrap_remat(dec_stage_fn, cfg)

    def fn(enc_stack, dec_stack, edge_p, mbs, aux):
        d = jax.lax.axis_index(axis)
        enc_p = tree_local(enc_stack)       # [V, enc_pad, ...]
        dec_p = tree_local(dec_stack)       # [V, dec_pad, ...]

        mb0 = tree_index(mbs, 0)
        aux0 = tree_index(aux, 0)
        x_proto = jax.eval_shape(embed_fn, edge_p, mb0, aux0)
        zero_x = jnp.zeros(x_proto.shape, x_proto.dtype)
        zero_w = jnp.zeros(x_proto.shape, wire)
        skips_proto = jax.eval_shape(
            lambda p, x, a: enc_stage(p, x, a, 0)[1],
            tree_index(enc_p, 0), zero_x, aux0)
        zero_skips = jax.tree.map(
            lambda t: jnp.zeros(t.shape, t.dtype), skips_proto)

        # This device's rows of every table (host constants -> jnp).
        sel_t = jnp.asarray(tables.sel)[d]
        slot_t = jnp.asarray(tables.slot)[d]
        mb_t = jnp.asarray(tables.mb)[d]
        dok_t = jnp.asarray(tables.down_valid)[d]
        uok_t = jnp.asarray(tables.up_valid)[d]
        dsl_t = jnp.asarray(tables.down_slot)[d]
        usl_t = jnp.asarray(tables.up_slot)[d]
        rx_t = jnp.asarray(tables.rx_slot)[d]
        dsnd_t = jnp.asarray(tables.down_send)[d]
        usnd_t = jnp.asarray(tables.up_send)[d]
        loss_t = jnp.asarray(tables.loss)[d]
        emb_t = jnp.asarray(tables.embed)[d]
        trd_t = jnp.asarray(tables.turn_rd)[d]
        twr_t = jnp.asarray(tables.turn_wr)[d]
        twrs_t = jnp.asarray(tables.turn_wr_slot)[d]
        trds_t = jnp.asarray(tables.turn_rd_slot)[d]
        swr_t = jnp.asarray(tables.skip_wr)[d]
        swrs_t = jnp.asarray(tables.skip_wr_slot)[d]
        srd_t = jnp.asarray(tables.skip_rd_slot)[d]     # [T, V]

        init = (
            zero_w,                              # down-ring register (wire)
            zero_w,                              # up-ring register (wire)
            _zeros_buffer(zero_x, W_down, wire),  # enc_rx[W_down]: arrivals
            _zeros_buffer(zero_x, W_up, wire),    # dec_rx[W_up]: arrivals
            _zeros_buffer(zero_x, W_turn),        # turn[W_turn]
            _zeros_buffer(zero_skips, W_skip),    # cache[W_skip]: skips
        )

        def hop(down_pl, up_pl):
            down = (jax.lax.ppermute(down_pl, axis, down_perm)
                    if down_used else down_pl)
            up = (jax.lax.ppermute(up_pl, axis, up_perm)
                  if up_used else up_pl)
            return down, up

        def body(down_in, up_in, enc_rx, dec_rx, turn, cache, t):
            enc_rx = _buf_store(enc_rx, dsl_t[t], down_in, dok_t[t])
            dec_rx = _buf_store(dec_rx, usl_t[t], up_in, uok_t[t])
            sel = sel_t[t]
            vslot = slot_t[t]
            m = mb_t[t]
            mb_m = tree_index(mbs, m)
            aux_m = tree_index(aux, m)

            def run_idle(_):
                return zero_x, zero_skips

            def run_enc(_):
                x0 = jax.lax.cond(
                    emb_t[t], lambda: embed_fn(edge_p, mb_m, aux_m),
                    lambda: zero_x)
                x_rx = tree_index(enc_rx, rx_t[t]).astype(zero_x.dtype)
                x_in = jnp.where(emb_t[t], x0, x_rx)
                return enc_stage(tree_index(enc_p, vslot), x_in, aux_m,
                                 vslot)

            def run_dec(_):
                x_rx = tree_index(dec_rx, rx_t[t]).astype(zero_x.dtype)
                x_in = jnp.where(trd_t[t], tree_index(turn, trds_t[t]),
                                 x_rx)
                # gather the stash slots holding this microbatch's V
                # encoder-slot entries -> the flat [V * enc_pad] view
                # consumers address via StageLayout.skip_rows
                skips_m = _gather_rows(cache, srd_t[t])
                x_out = dec_stage(tree_index(dec_p, vslot), x_in, skips_m,
                                  aux_m, vslot)
                return x_out, zero_skips

            x_out, skips = jax.lax.switch(
                sel, (run_idle, run_enc, run_dec), None)
            # gated stores: only the turnaround slot's output is read back
            # from the turn buffer, and only stash entries some decoder
            # row consumes are written (dead stores are elided — the
            # liveness analysis cleared their flags)
            turn = _buf_store(turn, twrs_t[t], x_out, twr_t[t])
            cache = _buf_store(cache, swrs_t[t], skips, swr_t[t])
            loss = jax.lax.cond(
                loss_t[t],
                lambda: loss_fn(edge_p, x_out, mb_m, aux_m),
                lambda: jnp.zeros((), jnp.float32))
            # cast-on-send; quiescent hops carry zeros (the where
            # transpose zeroes their backward cotangents too)
            payload = x_out.astype(wire)
            down_pl = jnp.where(dsnd_t[t], payload, zero_w)
            up_pl = jnp.where(usnd_t[t], payload, zero_w)
            return down_pl, up_pl, enc_rx, dec_rx, turn, cache, loss

        if cfg.overlap:
            # Double-buffered hops: the carry holds step t-1's *unsent*
            # payload and its ppermute is issued at the top of body t,
            # before this step's compute.  The arrival still lands at the
            # same step as the synchronous lowering (values identical),
            # but the collective no longer depends on — nor is depended
            # on by — this step's compute unless the arrival's consumer
            # runs right now (an *exposed* hop), so XLA's latency-hiding
            # scheduler can run hop and compute concurrently.
            def step(carry, t):
                pend_down, pend_up, enc_rx, dec_rx, turn, cache = carry
                down_in, up_in = hop(pend_down, pend_up)
                down_pl, up_pl, enc_rx, dec_rx, turn, cache, loss = body(
                    down_in, up_in, enc_rx, dec_rx, turn, cache, t)
                return (down_pl, up_pl, enc_rx, dec_rx, turn, cache), loss
        else:
            # Synchronous reference: hop at the bottom of the producing
            # step; the carry holds the arrival.
            def step(carry, t):
                down_in, up_in, enc_rx, dec_rx, turn, cache = carry
                down_pl, up_pl, enc_rx, dec_rx, turn, cache, loss = body(
                    down_in, up_in, enc_rx, dec_rx, turn, cache, t)
                down_nx, up_nx = hop(down_pl, up_pl)
                return (down_nx, up_nx, enc_rx, dec_rx, turn, cache), loss

        _, losses = jax.lax.scan(step, init, jnp.arange(T))
        total = jnp.sum(losses) / M
        return jax.lax.psum(total, (axis, *cfg.data_axes)) / cfg.dp_size

    return fn


# ===========================================================================
# Linear executor from tables
# ===========================================================================

def make_linear_pipeline_from_schedule(
    cfg: PipelineConfig,
    sched: Schedule,
    *,
    embed_fn: Callable,       # (edge_p, mb) -> x
    stage_fn: Callable,       # (stage_p, x, slot) -> x
    loss_fn: Callable,        # (edge_p, x_final, mb) -> scalar
    device_of_stage=None,     # partition's explicit stage->device mapping
    devices=None,             # ...same, as a tuple (memoized lowering)
    zero_dims=None,           # ZeRO-2 slot-view gather dims per stack leaf
) -> Callable:
    """Lower a linear S=VD schedule to ``fn(stack, edge_p, mbs) -> loss``
    (same call signature as ``make_linear_pipeline``; the stack carries a
    leading slot axis ``[D, V, pad, ...]`` and ``stage_fn`` receives the
    slot index).  The down ring wraps so interleaved (V > 1) plans cross
    the D-1 -> 0 slot boundary; arrivals land in a rotating ``W_down``
    receive buffer in ``cfg.wire_dtype`` and quiescent hops carry
    zeros.  ``zero_dims`` rest-shards the stack exactly as in
    :func:`make_wave_pipeline_from_schedule` (all-gather-on-use inside
    the remat region; grads reduce-scatter through the transpose)."""
    D, M, axis = cfg.num_devices, cfg.num_microbatches, cfg.axis
    if sched.M != M or sched.D != D:
        raise PlanError(
            f"schedule (M={sched.M}, D={sched.D}) does not match the "
            f"pipeline config (M={M}, D={D})",
            check="program-shape")
    tables = StepTables.from_schedule(sched, folded=False,
                                      device_of_stage=device_of_stage,
                                      devices=devices)
    T = tables.num_steps
    wire = _wire_dtype(cfg)
    down_perm, _ = ring_perms(D, wrap=True)
    down_used = bool(tables.down_send.any())
    W_down = max(tables.W_down, 1)
    if zero_dims is not None:
        stage_inner = stage_fn

        def stage_fn(stage_p, x, slot):  # noqa: F811
            stage_p = zero_all_gather(stage_p, zero_dims, cfg.data_axes)
            return stage_inner(stage_p, x, slot)
    stage = _wrap_remat(stage_fn, cfg)

    def fn(stack, edge_p, mbs):
        d = jax.lax.axis_index(axis)
        my_p = tree_local(stack)            # [V, pad, ...]
        mb0 = tree_index(mbs, 0)
        x_proto = jax.eval_shape(embed_fn, edge_p, mb0)
        zero_x = jnp.zeros(x_proto.shape, x_proto.dtype)
        zero_w = jnp.zeros(x_proto.shape, wire)

        sel_t = jnp.asarray(tables.sel)[d]
        slot_t = jnp.asarray(tables.slot)[d]
        mb_t = jnp.asarray(tables.mb)[d]
        dok_t = jnp.asarray(tables.down_valid)[d]
        dsl_t = jnp.asarray(tables.down_slot)[d]
        rx_t = jnp.asarray(tables.rx_slot)[d]
        dsnd_t = jnp.asarray(tables.down_send)[d]
        loss_t = jnp.asarray(tables.loss)[d]
        emb_t = jnp.asarray(tables.embed)[d]

        init = (zero_w, _zeros_buffer(zero_x, W_down, wire))

        def hop(h_pl):
            return (jax.lax.ppermute(h_pl, axis, down_perm)
                    if down_used else h_pl)

        def body(h_in, rx, t):
            rx = _buf_store(rx, dsl_t[t], h_in, dok_t[t])
            m = mb_t[t]
            vslot = slot_t[t]
            mb_m = tree_index(mbs, m)

            def run_idle(_):
                return zero_x

            def run_stage(_):
                x0 = jax.lax.cond(
                    emb_t[t], lambda: embed_fn(edge_p, mb_m),
                    lambda: zero_x)
                x_rx = tree_index(rx, rx_t[t]).astype(zero_x.dtype)
                x_in = jnp.where(emb_t[t], x0, x_rx)
                return stage(tree_index(my_p, vslot), x_in, vslot)

            x_out = jax.lax.switch(sel_t[t], (run_idle, run_stage), None)
            loss = jax.lax.cond(
                loss_t[t],
                lambda: loss_fn(edge_p, x_out, mb_m),
                lambda: jnp.zeros((), jnp.float32))
            h_pl = jnp.where(dsnd_t[t], x_out.astype(wire), zero_w)
            return h_pl, rx, loss

        if cfg.overlap:
            # double-buffered hop: carry = pending payload, permuted at
            # the top of the next step's body (see the wave executor)
            def step(carry, t):
                pend, rx = carry
                h_pl, rx, loss = body(hop(pend), rx, t)
                return (h_pl, rx), loss
        else:
            def step(carry, t):
                h_in, rx = carry
                h_pl, rx, loss = body(h_in, rx, t)
                return (hop(h_pl), rx), loss

        _, losses = jax.lax.scan(step, init, jnp.arange(T))
        total = jnp.sum(losses) / M
        return jax.lax.psum(total, (axis, *cfg.data_axes)) / cfg.dp_size

    return fn
