"""Version-compatible JAX API imports.

``jax.shard_map`` is a top-level API from JAX 0.6 on; earlier releases ship
it as ``jax.experimental.shard_map.shard_map`` with a ``check_rep`` kwarg
instead of ``check_vma``.  Every caller in this repo (runtime, train steps,
tests/helpers, benchmarks) imports ``shard_map`` from here and writes
against the modern signature; this wrapper translates for old releases.

Policy (see README "JAX compat imports"): never ``from jax import <new
API>`` directly in runtime or test code — route through this module so a
single site handles the version split.
"""
from __future__ import annotations

from typing import Any, Callable

try:  # JAX >= 0.6: public API, `check_vma` kwarg
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
    _LEGACY = False
except ImportError:  # JAX < 0.6: experimental API, `check_rep` kwarg
    from jax.experimental.shard_map import shard_map as _shard_map
    _LEGACY = True


def shard_map(f: Callable, mesh: Any = None, in_specs: Any = None,
              out_specs: Any = None, check_vma: bool = True,
              **kwargs) -> Callable:
    """``jax.shard_map`` with the modern signature on any JAX version."""
    if _LEGACY:
        kwargs.setdefault("check_rep", check_vma)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma, **kwargs)


def tree_to_host(tree: Any) -> Any:
    """Pull every concrete array in a pytree to host memory.

    Workaround for a legacy-JAX CPU miscompile: re-assembling shard_map
    gradient outputs (NamedSharding over the 'model' axis, replicated over
    'data') with ``jnp.concatenate`` outside jit inserts an all-reduce that
    treats the replicated 'data' copies as partial sums — every value comes
    back exactly dp_size times too large.  Device_get first: the host copy
    is a plain committed array and reassembles correctly.  No-op on tracers
    so merge helpers stay usable under jit (where sharding propagation
    handles the concat correctly).

    Applied on every JAX version, not just the legacy branch: the host
    copy costs one transfer per merge (a cold path — grad checks and
    checkpoint export), while gating on the version risks silent wrong
    gradients on untested intermediate releases.  Correctness wins.
    """
    import jax
    import numpy as np

    def pull(x):
        if isinstance(x, jax.core.Tracer):
            return x
        if isinstance(x, jax.Array):
            return np.asarray(x)
        return x

    return jax.tree.map(pull, tree)
