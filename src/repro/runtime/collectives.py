"""Explicit collective building blocks.

``sharded_decode_attention``: flash-decode over a sequence-sharded KV cache
(the long_500k layout: batch=1, cache split over 'data').  Each shard
computes a partial attention with a local log-sum-exp; partials merge with
the numerically-stable LSE combine:

    m      = pmax(m_local)
    out    = psum(out_local * exp(lse_local - m))
           / psum(exp(lse_local - m) * l_local_norm)

This is the hand-rolled alternative to letting GSPMD partition the softmax
(which it does correctly but with an all-gather of logits for long
contexts); at 500k tokens the LSE merge moves O(B*H*Dh) bytes instead of
O(B*H*S/shards) logits.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def local_attention_with_lse(q, k, v, *, kv_offset, kv_valid_len):
    """Partial attention over a local KV shard.

    q: (B, 1, H, Dh); k,v: (B, S_shard, H, Dh).
    Returns (out_unnormalised (B,1,H,Dh), m (B,1,H), l (B,1,H)) where
    out = sum_j exp(s_j - m) v_j and l = sum_j exp(s_j - m).
    ``kv_offset``: absolute position of this shard's row 0;
    ``kv_valid_len``: global #valid tokens (mask beyond it).
    """
    B, _, H, Dh = q.shape
    S = k.shape[1]
    s = jnp.einsum("bqhd,bshd->bqhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(Dh)
    pos = kv_offset + jnp.arange(S)
    mask = (pos < kv_valid_len)[None, None, None, :]
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1)                                   # (B,1,H)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bqhs,bshd->bqhd", p, v.astype(jnp.float32))
    return out, m, l


def merge_lse(parts):
    """Merge [(out_i, m_i, l_i)] partials -> normalised attention output."""
    ms = jnp.stack([m for _, m, _ in parts])
    m_glob = jnp.max(ms, axis=0)
    num = 0.0
    den = 0.0
    for out, m, l in parts:
        scale = jnp.exp(m - m_glob)
        num = num + out * scale[..., None]
        den = den + l * scale
    return (num / jnp.maximum(den[..., None], 1e-30))


def sharded_decode_attention(q, k_shard, v_shard, *, axis: str,
                             kv_valid_len) -> jax.Array:
    """Inside shard_map over ``axis``: decode attention with the KV cache's
    sequence dim sharded.  q replicated (B,1,H,Dh); k/v local shards."""
    idx = jax.lax.axis_index(axis)
    S_shard = k_shard.shape[1]
    out, m, l = local_attention_with_lse(
        q, k_shard, v_shard, kv_offset=idx * S_shard,
        kv_valid_len=kv_valid_len)
    m_glob = jax.lax.pmax(m, axis)
    scale = jnp.exp(m - m_glob)
    num = jax.lax.psum(out * scale[..., None], axis)
    den = jax.lax.psum(l * scale, axis)
    return (num / jnp.maximum(den[..., None], 1e-30)).astype(q.dtype)
