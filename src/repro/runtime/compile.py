"""PULSE auto-pipeline compile path: graph -> partition -> schedule -> executor.

This is the paper's end-to-end story wired together.  :func:`auto_pipeline`
takes a :class:`~repro.core.graph.BlockGraph`, a block-level model
description (:class:`PipelineModelFns`) and a device budget, then

1. **plans**: runs the hybrid tuner (§VI) — or a pinned partitioner call —
   to pick (P, G, b) and the skip-aware partition (§IV, Algorithm 1);
2. **schedules**: synthesizes the pipeline schedule from the partition's
   stage->device mapping (§V: wave / 1F1B templates via the greedy
   synthesizer, optionally the exact ILP) and validates every constraint
   family before anything executes;
3. **lowers**: builds a shard_map executor for the partition.  Unlike the
   hand-written executors' hard-wired S=D / S=2D even splits, stages here
   carry *padded block stacks* plus true per-device block counts — with
   independent encoder-/decoder-half counts and a skip-stash pairing
   derived from the graph's actual skip edges, so the uneven and
   mirror-asymmetric stage boundaries the DP partitioner emits for
   partially-skipped graphs run unchanged
   (masked block scans; see runtime.pipeline).  The execution *order* is
   lowered from the validated schedule itself: per-device step tables
   extracted by ``runtime.schedule_exec`` drive the scan body, so a
   different synthesized schedule (e.g. an ILP improvement) changes what
   runs.  ``executor="closed_form"`` selects the closed-form wave/1F1B
   executors instead — kept as differential references.

The returned :class:`CompiledPipeline` is adapter-compatible (``build`` /
``split_params`` / ``merge_params`` / ``init_pipeline_params``) so the
training step builders in ``train.steps`` drive it directly, and carries
the planning artefacts (choice, partition, schedule) for inspection.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.graph import BlockGraph
from repro.core.hw import Hardware, TPU_V5E
from repro.core.partition import Partition, partition as partition_graph
from repro.core.schedule import Schedule, schedule_for_partition
from repro.core.tuner import TunerChoice, tune
from repro.runtime.compat import tree_to_host
from repro.runtime.pipeline import (PipelineConfig, make_linear_pipeline,
                                    make_wave_pipeline, scan_blocks,
                                    scan_blocks_consume, scan_blocks_emit,
                                    shard_pipeline)
from repro.runtime.schedule_exec import (make_linear_pipeline_from_schedule,
                                         make_wave_pipeline_from_schedule)

Pytree = Any


# ===========================================================================
# Model description consumed by the compiler
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class PipelineModelFns:
    """Block-level callables + parameter layout for one model family.

    The graph handed to :func:`auto_pipeline` must have exactly one block
    per row of the model's stacked block parameters (edge params — embed,
    head, norms — live outside the graph and are replicated).

    ``split_blocks(params) -> (stacks, edge)`` where ``stacks`` is a
    1-tuple ``(blocks,)`` for a homogeneous stack (rows 0..n-1 in graph
    order) or a 2-tuple ``(enc_blocks, dec_blocks)`` when encoder and
    decoder blocks have different parameter structures (UNet/UViT).
    ``merge_blocks`` is the exact inverse.
    """

    init_fn: Callable                      # key -> params
    embed_fn: Callable                     # (edge_p, mb, aux) -> x
    loss_fn: Callable                      # (edge_p, x, mb, aux) -> scalar
    split_blocks: Callable                 # params -> (stacks, edge)
    merge_blocks: Callable                 # (stacks, edge) -> params
    block_fn: Callable | None = None       # (block_p, x, aux) -> x
    enc_block_fn: Callable | None = None   # (block_p, x, aux) -> (x, skip)
    dec_block_fn: Callable | None = None   # (block_p, x, skip, aux) -> x
    num_param_stacks: int = 1              # len(split_blocks(params)[0])


# ===========================================================================
# Stage layout: partition cuts -> padded per-device stacks
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class StageLayout:
    """Mapping between a model's flat block stack and per-device stage-slot
    stacks for a (possibly uneven, mirror-asymmetric, interleaved)
    partition.

    Device ``d`` runs ``V`` encoder-half (prefix) stage slots and — for
    folded partitions — ``V`` decoder-half (suffix) slots;
    ``enc_slots[d][v]`` / ``dec_slots[d][v]`` name the pipeline stages in
    slot order (ascending stage id == the order the forward chain visits
    the device) and ``enc_counts[d][v]`` / ``dec_counts[d][v]`` their true
    block counts.  V == 1 recovers the classic one-(enc, dec)-pair-per-
    device fold; V > 1 is the interleaved (virtual-stage) layout that
    shrinks pipeline bubbles at the price of V padded weight shards per
    device.  All slots pad to ``enc_pad`` / ``dec_pad`` rows so one SPMD
    program covers every (device, slot).

    ``skip_rows[d][v][i]`` is the *flat* stash row device d's decoder slot
    v consumes at its row ``i``: ``src_slot * enc_pad + src_row`` into the
    device's ``[V * enc_pad]`` skip stash — derived from the partition's
    actual skip edges, not a mirror closed form; ``-1`` marks rows without
    a skip (they receive zeros).  Linear partitions use only
    ``enc_slots``/``enc_counts``/``enc_pad``.
    """

    partition: Partition
    enc_slots: tuple[tuple[int, ...], ...]
    dec_slots: tuple[tuple[int, ...], ...]
    enc_counts: tuple[tuple[int, ...], ...]
    dec_counts: tuple[tuple[int, ...], ...]
    enc_pad: int
    dec_pad: int
    skip_rows: tuple[tuple[tuple[int, ...], ...], ...] = ()

    # ---- legacy aliases (planning tests / describe output) -------------
    @property
    def V(self) -> int:
        """Interleave degree: stage slots per device and kind."""
        return len(self.enc_slots[0])

    @property
    def counts(self) -> tuple[int, ...]:
        """Per-device encoder-half block totals (legacy flat view)."""
        return tuple(sum(c) for c in self.enc_counts)

    @property
    def pad(self) -> int:
        return self.enc_pad

    @classmethod
    def from_partition(cls, part: Partition,
                       graph: BlockGraph | None = None) -> "StageLayout":
        """Lay out ``part``; ``graph`` supplies the skip edges that define
        the stash pairing.  Without a graph, folded layouts fall back to
        the LIFO mirror pairing (which requires V = 1 mirror-symmetric
        cuts — the only pairing derivable without edges); ``auto_pipeline``
        always passes the graph.
        """
        D = part.num_devices
        sizes = part.stage_sizes()
        if not part.folded:
            slots: list[list[int]] = [[] for _ in range(D)]
            for s in range(part.num_stages):
                slots[part.device_of_stage(s)].append(s)
            V = len(slots[0])
            if any(len(ss) != V for ss in slots):
                raise ValueError(
                    "linear partition is not an even interleave: devices "
                    f"hold {[len(ss) for ss in slots]} stage slots")
            enc_slots = tuple(map(tuple, slots))
            enc_counts = tuple(tuple(sizes[s] for s in ss)
                               for ss in enc_slots)
            pad = max(c for cs in enc_counts for c in cs)
            return cls(part, enc_slots, ((),) * D, enc_counts, ((),) * D,
                       pad, 0)
        S = part.num_stages
        half = S // 2
        enc: list[list[int]] = [[] for _ in range(D)]
        dec: list[list[int]] = [[] for _ in range(D)]
        for s in range(S):
            (enc if s < half else dec)[part.device_of_stage(s)].append(s)
        V = len(enc[0])
        if any(len(ss) != V for ss in enc) or any(len(ss) != V
                                                  for ss in dec) or V == 0:
            raise ValueError(
                "folded partition is not an even interleave: devices hold "
                f"{[(len(e), len(c)) for e, c in zip(enc, dec)]} "
                "(prefix, suffix)-half stage slots; the wave layout needs "
                "V of each per device")
        enc_slots = tuple(map(tuple, enc))
        dec_slots = tuple(map(tuple, dec))
        enc_counts = tuple(tuple(sizes[s] for s in ss) for ss in enc_slots)
        dec_counts = tuple(tuple(sizes[s] for s in ss) for ss in dec_slots)
        enc_pad = max(c for cs in enc_counts for c in cs)
        dec_pad = max(c for cs in dec_counts for c in cs)
        if graph is not None:
            skip_rows = cls._pair_skips(part, graph, enc_slots, dec_slots,
                                        enc_pad, dec_pad)
        else:
            if V != 1 or not part.mirror_symmetric():
                raise ValueError(
                    "mirror-asymmetric or interleaved folds need the block "
                    "graph to derive their skip pairing; call "
                    "StageLayout.from_partition(part, graph)")
            skip_rows = tuple(
                (tuple(enc_counts[d][0] - 1 - i if i < dec_counts[d][0]
                       else -1 for i in range(dec_pad)),)
                for d in range(D))
        return cls(part, enc_slots, dec_slots, enc_counts, dec_counts,
                   enc_pad, dec_pad, skip_rows)

    @staticmethod
    def _pair_skips(part: Partition, graph: BlockGraph,
                    enc_slots: tuple[tuple[int, ...], ...],
                    dec_slots: tuple[tuple[int, ...], ...],
                    enc_pad: int, dec_pad: int
                    ) -> tuple[tuple[tuple[int, ...], ...], ...]:
        """Per (device, dec slot): decoder row -> flat encoder stash row
        (``src_slot * enc_pad + src_row``), from the graph's skip edges."""
        D, cuts = part.num_devices, part.cuts
        V = len(enc_slots[0])
        rows = [[[-1] * dec_pad for _ in range(V)] for _ in range(D)]
        for e in graph.skips:
            s_src = part.stage_of_block(e.src)
            s_dst = part.stage_of_block(e.dst)
            d = part.device_of_stage(s_src)
            if part.device_of_stage(s_dst) != d:
                raise ValueError(
                    f"skip {e.src}->{e.dst} spans devices "
                    f"{d} and {part.device_of_stage(s_dst)}: the partition "
                    "violates collocation (validate_collocation)")
            if s_src not in enc_slots[d] or s_dst not in dec_slots[d]:
                raise ValueError(
                    f"skip {e.src}->{e.dst} is not encoder-half -> "
                    f"decoder-half on device {d} (stages {s_src}->{s_dst}): "
                    "the stash executors cache skips across the fold only")
            src_slot = enc_slots[d].index(s_src)
            dst_slot = dec_slots[d].index(s_dst)
            dec_row = e.dst - cuts[s_dst]
            enc_row = e.src - cuts[s_src]
            if rows[d][dst_slot][dec_row] != -1:
                raise ValueError(
                    f"block {e.dst} consumes two skips; one stash slot per "
                    "decoder row")
            rows[d][dst_slot][dec_row] = src_slot * enc_pad + enc_row
        return tuple(tuple(map(tuple, dev_rows)) for dev_rows in rows)

    def skip_consumers(self) -> tuple[tuple[tuple[int, ...], ...], ...]:
        """Per (device, dec slot): the encoder slots whose stash entries
        the decoder slot actually consumes (from ``skip_rows``).  Feeds
        the lowering's skip-liveness analysis: entries no decoder slot
        names are dead stores and their stash lifetime ends at the last
        *naming* decoder task, not the device's last decoder task."""
        return tuple(
            tuple(tuple(sorted({r // self.enc_pad for r in rows if r >= 0}))
                  for rows in dev)
            for dev in self.skip_rows)

    # ---- (device, slot) -> block-row ranges ----------------------------
    def enc_ranges(self) -> list[list[tuple[int, int]]]:
        cuts = self.partition.cuts
        return [[(cuts[s], cuts[s + 1]) for s in ss]
                for ss in self.enc_slots]

    def dec_ranges(self) -> list[list[tuple[int, int]]]:
        """Rows into the decoder-half stack (block index minus mid cut)."""
        part, cuts = self.partition, self.partition.cuts
        mid = cuts[part.num_stages // 2]
        return [[(cuts[s] - mid, cuts[s + 1] - mid) for s in ss]
                for ss in self.dec_slots]

    # ---- padded stacking (host-level; runs outside jit) ----------------
    def _stack(self, blocks: Pytree,
               ranges: Sequence[Sequence[tuple[int, int]]],
               pad: int) -> Pytree:
        def f(x):
            devs = []
            for dev_ranges in ranges:
                rows = []
                for lo, hi in dev_ranges:
                    r = x[lo:hi]
                    if hi - lo < pad:
                        z = jnp.zeros((pad - (hi - lo),) + r.shape[1:],
                                      r.dtype)
                        r = jnp.concatenate([r, z], 0)
                    rows.append(r)
                devs.append(jnp.stack(rows))
            return jnp.stack(devs)          # [D, V, pad, ...]

        return jax.tree.map(f, blocks)

    def _unstack(self, stacked: Pytree,
                 ranges: Sequence[Sequence[tuple[int, int]]]) -> Pytree:
        stacked = tree_to_host(stacked)   # legacy-JAX shard reassembly fix
        order = sorted(
            ((d, v) for d in range(len(ranges))
             for v in range(len(ranges[d]))),
            key=lambda dv: ranges[dv[0]][dv[1]][0])

        def f(x):
            parts = [x[d, v, : ranges[d][v][1] - ranges[d][v][0]]
                     for d, v in order]
            return jnp.concatenate(parts, 0)

        return jax.tree.map(f, stacked)

    def split(self, stacks: tuple) -> tuple:
        """Model block stacks -> per-(device, slot) padded stage stacks."""
        part = self.partition
        if not part.folded:
            if len(stacks) != 1:
                raise ValueError("linear pipeline needs one block stack")
            return (self._stack(stacks[0], self.enc_ranges(), self.enc_pad),)
        mid = part.cuts[part.num_stages // 2]
        if len(stacks) == 1:
            enc_b = jax.tree.map(lambda x: x[:mid], stacks[0])
            dec_b = jax.tree.map(lambda x: x[mid:], stacks[0])
        else:
            enc_b, dec_b = stacks
            enc_rows = jax.tree.leaves(enc_b)[0].shape[0]
            if enc_rows != mid:
                # with two param structures the fold's turnaround must sit
                # exactly on the model's own enc/dec boundary; a fully
                # paired skip graph forces this, a sparse one may not
                raise ValueError(
                    f"partition turnaround cut at block {mid} but the "
                    f"model's encoder stack has {enc_rows} rows; two-stack "
                    "models need the mid cut on the stack boundary (add "
                    "skip edges pinning it, or use a homogeneous stack)")
        return (self._stack(enc_b, self.enc_ranges(), self.enc_pad),
                self._stack(dec_b, self.dec_ranges(), self.dec_pad))

    def merge(self, stage_stacks: tuple, n_model_stacks: int) -> tuple:
        """Inverse of :meth:`split` (also correct for gradients)."""
        part = self.partition
        if not part.folded:
            return (self._unstack(stage_stacks[0], self.enc_ranges()),)
        enc_b = self._unstack(stage_stacks[0], self.enc_ranges())
        dec_b = self._unstack(stage_stacks[1], self.dec_ranges())
        if n_model_stacks == 1:
            return (jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], 0), enc_b, dec_b),)
        return (enc_b, dec_b)


# ===========================================================================
# Compiled pipeline
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class CompiledPipeline:
    """Planner output lowered to a runnable shard_map pipeline."""

    graph: BlockGraph
    partition: Partition
    schedule: Schedule
    layout: StageLayout
    pcfg: PipelineConfig
    model_fns: PipelineModelFns
    choice: TunerChoice | None = None      # set when the tuner drove the plan
    executor: str = "table"                # "table" | "closed_form"

    @property
    def folded(self) -> bool:
        return self.partition.folded

    # ---- parameter plumbing (adapter-compatible) -----------------------
    def split_params(self, params: Pytree) -> tuple:
        stacks, edge = self.model_fns.split_blocks(params)
        return self.layout.split(tuple(stacks)), edge

    def merge_params(self, stage_stacks: tuple, edge: Pytree) -> Pytree:
        stacks = self.layout.merge(tuple(stage_stacks),
                                   self.model_fns.num_param_stacks)
        return self.model_fns.merge_blocks(stacks, edge)

    def init_pipeline_params(self, key) -> tuple:
        return self.split_params(self.model_fns.init_fn(key))

    # ---- lowering artefacts --------------------------------------------
    def step_tables(self):
        """The lowered :class:`~repro.runtime.schedule_exec.StepTables`
        (memoized): step programs, channel activity masks and the proven
        liveness windows (W_down/W_up/W_turn/W_skip) the executors size
        their rotating buffers by."""
        from repro.runtime.schedule_exec import StepTables
        if not self.folded:
            return StepTables.from_schedule(
                self.schedule, folded=False,
                devices=self.partition.devices)
        return StepTables.from_schedule(
            self.schedule, folded=True, devices=self.partition.devices,
            skip_consumers=self.layout.skip_consumers())

    def state_spec(self) -> dict:
        """JSON-serializable spec of how this plan lays out training
        state at rest: partition cuts, stage->device map, the layout's
        slot/count/pad tables, (dp, zero_stage, V, M, wire_dtype) — what
        ``checkpoint.store`` records in every manifest and
        ``runtime.resilience`` de-stacks saved state through when the
        restore-time plan differs."""
        from repro.runtime.resilience import compiled_state_spec
        return compiled_state_spec(self)

    def fingerprint(self) -> str:
        """Digest of the state-layout-relevant subset of
        :meth:`state_spec` — equal fingerprints mean a checkpoint loads
        directly; different ones route through the elastic
        de-stack/re-stack path."""
        from repro.runtime.resilience import plan_fingerprint
        return plan_fingerprint(self.state_spec())

    def certify(self, *, name: str | None = None):
        """Statically verify the lowered plan and return the
        :class:`~repro.analysis.certificate.PlanCertificate`.

        Abstractly interprets the step tables (no execution): race- and
        deadlock-freedom of the ring hops, store/read matching on every
        rotating buffer, wire-dtype flow, and the liveness-window bounds
        — the proof ``python -m repro.analysis.verify`` re-checks
        offline.  Raises nothing on failure; inspect ``cert.ok`` /
        ``cert.violations`` (a freshly planned pipeline always
        certifies clean — a FAIL here means a planner/lowering bug).
        """
        from repro.analysis.certificate import certify_plan
        return certify_plan(self, name=name)

    # ---- ZeRO-2 stack sharding -----------------------------------------
    def _zero_layout(self) -> tuple:
        """(stacked_specs, gather_dims) for ZeRO-2 rest-sharded stage
        stacks, or ``(None, None)`` below stage 2 / without a dp axis.

        One entry per param stack: the ``P(axis, None, None, ...,
        "data", ...)`` in_specs :func:`runtime.sharding.zero_stack_specs`
        derives (``bind`` hands them to ``shard_pipeline``) and the
        matching slot-view gather dims the table executors all-gather on
        use.  Stack shapes come from ``eval_shape`` of the model's own
        init — no parameters are materialized.
        """
        if self.pcfg.zero_stage < 2 or self.pcfg.dp_size <= 1:
            return None, None
        from repro.runtime.sharding import zero_stack_specs
        stacks, _ = jax.eval_shape(
            lambda k: self.split_params(self.model_fns.init_fn(k)),
            jax.random.PRNGKey(0))
        specs, dims = [], []
        for st in stacks:
            sp, dm = zero_stack_specs(st, dp=self.pcfg.dp_size,
                                      axis=self.pcfg.axis,
                                      data_axes=self.pcfg.data_axes)
            specs.append(sp)
            dims.append(dm)
        return tuple(specs), tuple(dims)

    # ---- executor ------------------------------------------------------
    def build(self) -> Callable:
        """Lower to an executor.

        ``executor="table"`` (default) lowers the *validated schedule
        itself*: per-device step tables extracted from ``self.schedule``
        drive the scan body (runtime.schedule_exec), so greedy and ILP
        schedules alike execute exactly as synthesized.
        ``executor="closed_form"`` selects the hand-written wave/1F1B
        executors whose scan dataflow realizes the template orders
        implicitly — kept as differential references.

        Folded: ``fn(enc_stack, dec_stack, edge, mbs, aux) -> loss``.
        Linear: ``fn(stack, edge, mbs) -> loss``.
        """
        if self.executor not in ("table", "closed_form"):
            raise ValueError(
                f"unknown executor {self.executor!r}; expected 'table' or "
                "'closed_form'")
        fns, pcfg, layout = self.model_fns, self.pcfg, self.layout
        axis = pcfg.axis
        if self.executor == "closed_form" and layout.V > 1:
            raise ValueError(
                f"closed-form executors realize one (enc, dec) stage slot "
                f"pair per device; this plan interleaves V={layout.V} "
                "slots — lower through executor='table'")
        if self.executor == "closed_form" and pcfg.zero_stage >= 2 \
                and pcfg.dp_size > 1:
            raise ValueError(
                "closed-form executors keep stage stacks replicated over "
                f"the data axes; zero_stage={pcfg.zero_stage} shards them "
                "at rest — lower through executor='table'")
        _, zero_dims = self._zero_layout()

        def my(table):
            # device-local lookup into a per-device host constant table
            return jnp.asarray(table, jnp.int32)[jax.lax.axis_index(axis)]

        def squeeze_slot(stage_p):
            # closed-form executors predate the slot axis: drop the V=1 dim
            return jax.tree.map(lambda t: t[0], stage_p)

        if self.folded:
            if fns.block_fn is None and (fns.enc_block_fn is None
                                         or fns.dec_block_fn is None):
                raise ValueError(
                    "folded pipeline needs model_fns.block_fn or both "
                    "enc_block_fn and dec_block_fn")
            enc_block = fns.enc_block_fn or (
                lambda bp, x, aux: (fns.block_fn(bp, x, aux), {}))
            dec_block = fns.dec_block_fn or (
                lambda bp, x, skip, aux: fns.block_fn(bp, x, aux))

            if self.executor == "table":
                # every slot carries its own count (asymmetric and
                # interleaved folds) and the stash pairing comes from the
                # partition's skip edges, resolved per (device, slot)
                def enc_stage_fn(stage_p, x, aux, slot):
                    return scan_blocks_emit(enc_block, stage_p, x,
                                            my(layout.enc_counts)[slot],
                                            aux)

                def dec_stage_fn(stage_p, x, skips, aux, slot):
                    return scan_blocks_consume(
                        dec_block, stage_p, skips, x,
                        my(layout.dec_counts)[slot],
                        my(layout.skip_rows)[slot], aux)

                return make_wave_pipeline_from_schedule(
                    pcfg, self.schedule, embed_fn=fns.embed_fn,
                    enc_stage_fn=enc_stage_fn, dec_stage_fn=dec_stage_fn,
                    loss_fn=fns.loss_fn,
                    devices=self.partition.devices,
                    skip_consumers=layout.skip_consumers(),
                    zero_dims=zero_dims)

            flat_enc = tuple(c[0] for c in layout.enc_counts)
            flat_dec = tuple(c[0] for c in layout.dec_counts)
            flat_rows = tuple(r[0] for r in layout.skip_rows)

            def enc_stage_cf(stage_p, x, aux):
                return scan_blocks_emit(enc_block, squeeze_slot(stage_p), x,
                                        my(flat_enc), aux)

            def dec_stage_cf(stage_p, x, skips, aux):
                return scan_blocks_consume(
                    dec_block, squeeze_slot(stage_p), skips, x,
                    my(flat_dec), my(flat_rows), aux)

            return make_wave_pipeline(
                pcfg, embed_fn=fns.embed_fn, enc_stage_fn=enc_stage_cf,
                dec_stage_fn=dec_stage_cf, loss_fn=fns.loss_fn)

        if fns.block_fn is None:
            raise ValueError("linear pipeline needs model_fns.block_fn")

        embed = lambda e, mb: fns.embed_fn(e, mb, None)
        loss = lambda e, x, mb: fns.loss_fn(e, x, mb, None)
        if self.executor == "table":
            def stage_fn(stage_p, x, slot):
                return scan_blocks(fns.block_fn, stage_p, x,
                                   my(layout.enc_counts)[slot], None)

            return make_linear_pipeline_from_schedule(
                pcfg, self.schedule, embed_fn=embed, stage_fn=stage_fn,
                loss_fn=loss,
                devices=self.partition.devices,
                zero_dims=zero_dims[0] if zero_dims is not None else None)

        def stage_cf(stage_p, x):
            return scan_blocks(fns.block_fn, squeeze_slot(stage_p), x,
                               my(tuple(c[0] for c in layout.enc_counts)),
                               None)

        return make_linear_pipeline(
            pcfg, embed_fn=embed, stage_fn=stage_cf, loss_fn=loss)

    def bind(self, mesh) -> Callable:
        """``loss(params, mbs[, aux])`` with params = (stage_stacks, edge),
        ready for jit/grad on a multi-device mesh."""
        fn = self.build()
        pcfg = self.pcfg
        axis, data = pcfg.axis, pcfg.data_axes
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        missing = [a for a in (axis, *data) if a not in sizes]
        if missing:
            # the lowered executor psums over every configured axis; a mesh
            # without them would fail mid-trace with an unbound-axis error
            raise ValueError(
                f"mesh axes {mesh.axis_names} missing {missing} required by "
                "this plan (pass matching data_axes to auto_pipeline)")
        dp = math.prod(sizes[a] for a in data)
        if sizes[axis] != pcfg.num_devices or dp != pcfg.dp_size:
            # a size mismatch would not raise — it would silently mis-scale
            # the loss (dp) or gather clamped stage counts (model axis)
            raise ValueError(
                f"mesh sizes {sizes} do not match the plan "
                f"(model={pcfg.num_devices}, dp={pcfg.dp_size}); rebuild "
                f"with auto_pipeline(..., dp_size={dp})")

        def batch_spec(t):
            return jax.tree.map(
                lambda x: P(None, data)
                if data and getattr(x, "ndim", 0) >= 2 else P(), t)

        stacked_specs, _ = self._zero_layout()

        def wrap(edge, *batch_args):
            return shard_pipeline(
                fn, mesh, stacked_args=2 if self.folded else 1, axis=axis,
                batch_specs=(jax.tree.map(lambda _: P(), edge),
                             *(batch_spec(a) for a in batch_args)),
                stacked_specs=stacked_specs)

        if self.folded:
            def loss(params, mbs, aux):
                stacks, edge = params
                return wrap(edge, mbs, aux)(stacks[0], stacks[1], edge,
                                            mbs, aux)
        else:
            def loss(params, mbs):
                stacks, edge = params
                return wrap(edge, mbs)(stacks[0], edge, mbs)
        return loss

    def describe(self) -> str:
        part, sched = self.partition, self.schedule
        V = self.layout.V
        kind = "folded wave" if part.folded else "linear 1F1B"
        if V > 1:
            kind += f", interleaved V={V}"
        lines = [
            f"auto_pipeline: S={part.num_stages} stages over "
            f"D={part.num_devices} devices ({kind}), "
            f"M={self.pcfg.num_microbatches} microbatches",
            f"  cuts={part.cuts} stage sizes={part.stage_sizes()}",
            (f"  layout: enc counts={self.layout.enc_counts} "
             f"dec counts={self.layout.dec_counts}"
             + ("" if part.mirror_symmetric() else " (asymmetric fold)")
             if part.folded else
             f"  layout: stage counts={self.layout.enc_counts}"),
            f"  schedule: makespan={sched.makespan} slots, "
            f"bubble={sched.bubble_ratio():.2f}",
            f"  executor: {self.executor}",
        ]
        if self.executor == "table":
            tabs = self.step_tables()
            live_d, live_u = tabs.live_hops
            mode = "overlapped" if self.pcfg.overlap else "synchronous"
            lines.append(
                f"  wire: {self.pcfg.wire_dtype}, live hops "
                f"{live_d}+{live_u}/{tabs.dense_hops} (down+up/dense), "
                f"windows W_down={tabs.W_down} W_up={tabs.W_up} "
                f"W_turn={tabs.W_turn} W_skip={tabs.W_skip} (M={sched.M})")
            lines.append(
                f"  comm: {mode}, exposed hops {tabs.exposed_hops} / "
                f"hidden {tabs.hidden_hops} (of {live_d + live_u} live)")
        if self.pcfg.dp_size > 1 or self.pcfg.zero_stage > 0:
            lines.append(
                f"  hybrid: dp={self.pcfg.dp_size} over "
                f"{self.pcfg.data_axes}, zero_stage={self.pcfg.zero_stage}")
        if self.choice is not None:
            c = self.choice
            lines.append(f"  tuner: P={c.P} G={c.G} b={c.b} M={c.M} "
                         f"zero={c.zero_stage} "
                         f"t/sample={c.t_sample*1e3:.3f} ms")
        return "\n".join(lines)


# ===========================================================================
# Entry point
# ===========================================================================

def auto_pipeline(
    graph: BlockGraph,
    model_fns: PipelineModelFns,
    N: int,
    hw: Hardware = TPU_V5E,
    *,
    microbatches: int | None = None,
    lam: float = 1.0,
    force_wave: bool | None = None,
    pipeline_devices: int | None = None,
    interleave: int | None = None,
    data_axes: tuple[str, ...] = ("data",),
    dp_size: int | None = None,
    zero_stage: int | None = None,
    remat: bool = True,
    remat_policy: str | None = None,
    use_ilp: bool = False,
    executor: str = "table",
    wire_dtype: str = "bfloat16",
    overlap: bool = True,
) -> CompiledPipeline:
    """Plan, schedule, and lower a pipeline for ``graph`` on ``N`` devices.

    By default the hybrid tuner (§VI) picks (P, G, b) — and, for wave
    plans, the interleave degree V — and supplies its partition;
    ``microbatches`` then defaults to the M the tuner's iteration-time
    score assumed (``TunerChoice.M``), and ``dp_size`` to the chosen G —
    the executed iteration matches the scored one.  Pass
    ``pipeline_devices`` to pin the pipeline degree and call the
    partitioner directly (deterministic; used by tests and the training
    driver, which already knows its mesh shape — ``dp_size`` defaults to 1
    there, ``microbatches`` to 2D folded / max(D, 2) linear).
    ``interleave`` pins V the same way (V stage slot pairs per device,
    S = 2VD folded / VD linear); with the tuner driving, pinning
    ``interleave`` restricts its search to that V.

    ``executor`` selects the lowering: ``"table"`` (default) executes the
    validated schedule via per-device step tables (runtime.schedule_exec);
    ``"closed_form"`` uses the hand-written wave/1F1B executors as
    differential references (these require M >= D and V = 1 for folded
    plans).

    ``wire_dtype`` sets the boundary-hop dtype of the table executors
    (default bf16 — cast-on-send, fp32 compute; backward hops ride the
    same dtype through the cast transposes).  ``"float32"`` is the
    exact-wire escape hatch the strict differential tests pin; closed-form
    executors are always fp32-wire references.

    ``overlap`` (default True) double-buffers the table executors' ring
    hops: each step's sends are issued at the top of the next step's scan
    body, before that step's compute, so XLA's latency-hiding scheduler
    can run the collective-permute concurrently with independent compute.
    Values, arrival steps, and liveness windows are identical either way
    — ``overlap=False`` is the synchronous reference lowering the
    differential tests compare against.  The tuner scores candidates with
    the matching comm term (hidden steady-state hops cost
    ``max(0, t_p2p - t_f)``, exposed ramp hops full ``t_p2p``).

    ``zero_stage`` selects ZeRO sharding over the data axes of the
    ``("data", "model")`` mesh: 0 replicates everything per DP rank, 1
    shards only optimizer state (train.steps applies the leaf-wise specs;
    executors are untouched), 2 additionally shards the stage parameter
    stacks at rest — the table executors all-gather each slot row on use
    inside the remat region, and the gather's transpose reduce-scatters
    the parameter gradients over ``data``.  With the tuner driving,
    ``None`` (default) searches stages {0, 1, 2} and ``peak_memory``
    charges each candidate its sharded param/optimizer bytes; pinning
    restricts the search.  With ``pipeline_devices`` pinned, ``None``
    means 0.
    """
    if zero_stage is not None and zero_stage not in (0, 1, 2):
        raise ValueError(f"zero_stage must be in (0, 1, 2), got {zero_stage}")
    choice: TunerChoice | None = None
    if pipeline_devices is not None:
        part = partition_graph(graph, pipeline_devices, hw=hw, lam=lam,
                               force_wave=force_wave,
                               interleave=interleave or 1)
        if graph.skips and not part.folded:
            raise ValueError(
                "graph has skip edges but the plan is linear: the linear "
                "executor has no skip transport, so skips would be "
                "silently dropped — skip graphs need a folded plan")
    else:
        if force_wave is not None:
            raise ValueError(
                "force_wave requires pipeline_devices: the tuner derives "
                "wave vs linear from graph.skips and would ignore it")
        drops: list[str] = []
        choices = tune(graph, N, hw=hw, lam=lam, drops=drops,
                       zero_stages=((zero_stage,) if zero_stage is not None
                                    else (0, 1, 2)),
                       interleave_options=(
                           (interleave,) if interleave is not None
                           else None),
                       overlap=overlap)
        pure_dp = sorted({(c.P, c.G, c.zero_stage) for c in choices
                          if c.partition is None or c.P <= 1})
        drops += [f"P={p} G={g}" + (f" zero{z}" if z else "")
                  + ": pure data parallelism "
                  "(P=1 plans carry no pipeline to lower)"
                  for p, g, z in pure_dp]
        keep = [c for c in choices if c.partition is not None and c.P > 1]
        if not keep:
            # every per-candidate drop reason the tuner and the P>1 filter
            # collected, in full — truncating this list hides the memory /
            # network constraint that actually killed the plan
            detail = "\n  ".join(drops) or "tuner enumerated no candidates"
            raise ValueError(
                f"tuner found no feasible pipeline plan for N={N}; "
                f"candidates considered:\n  {detail}")
        choice = keep[0]
        part = choice.partition

    D = part.num_devices
    if microbatches is not None:
        M = microbatches
    elif choice is not None:
        # execute the M the tuner scored (Eq. 15 assumed M = P) — the
        # planner and the executor must agree on the iteration shape
        M = choice.M
    else:
        M = 2 * D if part.folded else max(D, 2)
    if dp_size is None:
        dp_size = choice.G if choice is not None else 1
    if choice is not None and zero_stage is None:
        zero_stage = choice.zero_stage
    zero_stage = zero_stage or 0
    if zero_stage > 0 and dp_size <= 1:
        # nothing to shard over — a stage-1/2 request on a single replica
        # is the replicated plan; record it as such
        zero_stage = 0
    # Schedule synthesis + full constraint validation happens here; an
    # invalid plan raises before any executor is built.
    sched = schedule_for_partition(part, M, use_ilp=use_ilp)

    pcfg = PipelineConfig(num_devices=D, num_microbatches=M,
                          data_axes=data_axes, dp_size=dp_size,
                          zero_stage=zero_stage,
                          remat=remat, remat_policy=remat_policy,
                          wire_dtype=wire_dtype, overlap=overlap)
    layout = StageLayout.from_partition(part, graph)
    return CompiledPipeline(graph=graph, partition=part, schedule=sched,
                            layout=layout, pcfg=pcfg, model_fns=model_fns,
                            choice=choice, executor=executor)
