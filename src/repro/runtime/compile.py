"""PULSE auto-pipeline compile path: graph -> partition -> schedule -> executor.

This is the paper's end-to-end story wired together.  :func:`auto_pipeline`
takes a :class:`~repro.core.graph.BlockGraph`, a block-level model
description (:class:`PipelineModelFns`) and a device budget, then

1. **plans**: runs the hybrid tuner (§VI) — or a pinned partitioner call —
   to pick (P, G, b) and the skip-aware partition (§IV, Algorithm 1);
2. **schedules**: synthesizes the pipeline schedule from the partition's
   stage->device mapping (§V: wave / 1F1B templates via the greedy
   synthesizer, optionally the exact ILP) and validates every constraint
   family before anything executes;
3. **lowers**: builds a shard_map executor for the partition.  Unlike the
   hand-written executors' hard-wired S=D / S=2D even splits, stages here
   carry *padded block stacks* plus true per-device block counts, so the
   uneven stage boundaries the DP partitioner actually emits run unchanged
   (masked block scans; see runtime.pipeline).  The execution *order* is
   lowered from the validated schedule itself: per-device step tables
   extracted by ``runtime.schedule_exec`` drive the scan body, so a
   different synthesized schedule (e.g. an ILP improvement) changes what
   runs.  ``executor="closed_form"`` selects the closed-form wave/1F1B
   executors instead — kept as differential references.

The returned :class:`CompiledPipeline` is adapter-compatible (``build`` /
``split_params`` / ``merge_params`` / ``init_pipeline_params``) so the
training step builders in ``train.steps`` drive it directly, and carries
the planning artefacts (choice, partition, schedule) for inspection.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.graph import BlockGraph
from repro.core.hw import Hardware, TPU_V5E
from repro.core.partition import Partition, partition as partition_graph
from repro.core.schedule import Schedule, schedule_for_partition
from repro.core.tuner import TunerChoice, tune
from repro.runtime.compat import tree_to_host
from repro.runtime.pipeline import (PipelineConfig, make_linear_pipeline,
                                    make_wave_pipeline, scan_blocks,
                                    scan_blocks_consume, scan_blocks_emit,
                                    shard_pipeline)
from repro.runtime.schedule_exec import (make_linear_pipeline_from_schedule,
                                         make_wave_pipeline_from_schedule)

Pytree = Any


# ===========================================================================
# Model description consumed by the compiler
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class PipelineModelFns:
    """Block-level callables + parameter layout for one model family.

    The graph handed to :func:`auto_pipeline` must have exactly one block
    per row of the model's stacked block parameters (edge params — embed,
    head, norms — live outside the graph and are replicated).

    ``split_blocks(params) -> (stacks, edge)`` where ``stacks`` is a
    1-tuple ``(blocks,)`` for a homogeneous stack (rows 0..n-1 in graph
    order) or a 2-tuple ``(enc_blocks, dec_blocks)`` when encoder and
    decoder blocks have different parameter structures (UNet/UViT).
    ``merge_blocks`` is the exact inverse.
    """

    init_fn: Callable                      # key -> params
    embed_fn: Callable                     # (edge_p, mb, aux) -> x
    loss_fn: Callable                      # (edge_p, x, mb, aux) -> scalar
    split_blocks: Callable                 # params -> (stacks, edge)
    merge_blocks: Callable                 # (stacks, edge) -> params
    block_fn: Callable | None = None       # (block_p, x, aux) -> x
    enc_block_fn: Callable | None = None   # (block_p, x, aux) -> (x, skip)
    dec_block_fn: Callable | None = None   # (block_p, x, skip, aux) -> x
    num_param_stacks: int = 1              # len(split_blocks(params)[0])


# ===========================================================================
# Stage layout: partition cuts -> padded per-device stacks
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class StageLayout:
    """Mapping between a model's flat block stack and per-device stage
    stacks for a (possibly uneven) partition.

    ``counts[d]`` is device d's true block count per half (folded) or per
    stage (linear); every stage stack is padded to ``pad`` rows so one SPMD
    program covers all devices.
    """

    partition: Partition
    counts: tuple[int, ...]
    pad: int

    @classmethod
    def from_partition(cls, part: Partition) -> "StageLayout":
        cuts, D = part.cuts, part.num_devices
        if part.folded and not part.mirror_symmetric():
            raise ValueError(
                "folded executor needs mirror-symmetric cuts "
                f"(stage s and stage S-1-s of equal size); got {cuts}. "
                "Partially-skipped graphs (mid blocks, sparse skips) can "
                "yield legal asymmetric folds the executor cannot lower "
                "yet — see ROADMAP open items")
        # with mirror symmetry the first D cuts describe both halves
        counts = part.stage_sizes()[:D]
        return cls(part, counts, max(counts))

    # ---- device -> block-row ranges ------------------------------------
    def enc_ranges(self) -> list[tuple[int, int]]:
        cuts = self.partition.cuts
        return [(cuts[d], cuts[d + 1])
                for d in range(self.partition.num_devices)]

    def dec_ranges(self) -> list[tuple[int, int]]:
        """Rows into the decoder-half stack; index d = stage S-1-d."""
        cuts = self.partition.cuts
        mid = cuts[self.partition.num_stages // 2]
        return [(mid - cuts[d + 1], mid - cuts[d])
                for d in range(self.partition.num_devices)]

    # ---- padded stacking (host-level; runs outside jit) ----------------
    def _stack(self, blocks: Pytree, ranges: Sequence[tuple[int, int]]
               ) -> Pytree:
        pad = self.pad

        def f(x):
            rows = []
            for lo, hi in ranges:
                r = x[lo:hi]
                if hi - lo < pad:
                    z = jnp.zeros((pad - (hi - lo),) + r.shape[1:], r.dtype)
                    r = jnp.concatenate([r, z], 0)
                rows.append(r)
            return jnp.stack(rows)

        return jax.tree.map(f, blocks)

    def _unstack(self, stacked: Pytree, ranges: Sequence[tuple[int, int]]
                 ) -> Pytree:
        stacked = tree_to_host(stacked)   # legacy-JAX shard reassembly fix
        order = sorted(range(len(ranges)), key=lambda d: ranges[d][0])

        def f(x):
            parts = [x[d, : ranges[d][1] - ranges[d][0]] for d in order]
            return jnp.concatenate(parts, 0)

        return jax.tree.map(f, stacked)

    def split(self, stacks: tuple) -> tuple:
        """Model block stacks -> per-device padded stage stacks."""
        part = self.partition
        if not part.folded:
            if len(stacks) != 1:
                raise ValueError("linear pipeline needs one block stack")
            return (self._stack(stacks[0], self.enc_ranges()),)
        if len(stacks) == 1:
            mid = part.cuts[part.num_stages // 2]
            enc_b = jax.tree.map(lambda x: x[:mid], stacks[0])
            dec_b = jax.tree.map(lambda x: x[mid:], stacks[0])
        else:
            enc_b, dec_b = stacks
        return (self._stack(enc_b, self.enc_ranges()),
                self._stack(dec_b, self.dec_ranges()))

    def merge(self, stage_stacks: tuple, n_model_stacks: int) -> tuple:
        """Inverse of :meth:`split` (also correct for gradients)."""
        part = self.partition
        if not part.folded:
            return (self._unstack(stage_stacks[0], self.enc_ranges()),)
        enc_b = self._unstack(stage_stacks[0], self.enc_ranges())
        dec_b = self._unstack(stage_stacks[1], self.dec_ranges())
        if n_model_stacks == 1:
            return (jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], 0), enc_b, dec_b),)
        return (enc_b, dec_b)


# ===========================================================================
# Compiled pipeline
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class CompiledPipeline:
    """Planner output lowered to a runnable shard_map pipeline."""

    graph: BlockGraph
    partition: Partition
    schedule: Schedule
    layout: StageLayout
    pcfg: PipelineConfig
    model_fns: PipelineModelFns
    choice: TunerChoice | None = None      # set when the tuner drove the plan
    executor: str = "table"                # "table" | "closed_form"

    @property
    def folded(self) -> bool:
        return self.partition.folded

    # ---- parameter plumbing (adapter-compatible) -----------------------
    def split_params(self, params: Pytree) -> tuple:
        stacks, edge = self.model_fns.split_blocks(params)
        return self.layout.split(tuple(stacks)), edge

    def merge_params(self, stage_stacks: tuple, edge: Pytree) -> Pytree:
        stacks = self.layout.merge(tuple(stage_stacks),
                                   self.model_fns.num_param_stacks)
        return self.model_fns.merge_blocks(stacks, edge)

    def init_pipeline_params(self, key) -> tuple:
        return self.split_params(self.model_fns.init_fn(key))

    # ---- executor ------------------------------------------------------
    def build(self) -> Callable:
        """Lower to an executor.

        ``executor="table"`` (default) lowers the *validated schedule
        itself*: per-device step tables extracted from ``self.schedule``
        drive the scan body (runtime.schedule_exec), so greedy and ILP
        schedules alike execute exactly as synthesized.
        ``executor="closed_form"`` selects the hand-written wave/1F1B
        executors whose scan dataflow realizes the template orders
        implicitly — kept as differential references.

        Folded: ``fn(enc_stack, dec_stack, edge, mbs, aux) -> loss``.
        Linear: ``fn(stack, edge, mbs) -> loss``.
        """
        if self.executor not in ("table", "closed_form"):
            raise ValueError(
                f"unknown executor {self.executor!r}; expected 'table' or "
                "'closed_form'")
        fns, pcfg = self.model_fns, self.pcfg
        axis, counts = pcfg.axis, self.layout.counts

        def my_count():
            return jnp.asarray(counts, jnp.int32)[jax.lax.axis_index(axis)]

        if self.folded:
            if fns.block_fn is None and (fns.enc_block_fn is None
                                         or fns.dec_block_fn is None):
                raise ValueError(
                    "folded pipeline needs model_fns.block_fn or both "
                    "enc_block_fn and dec_block_fn")
            enc_block = fns.enc_block_fn or (
                lambda bp, x, aux: (fns.block_fn(bp, x, aux), {}))
            dec_block = fns.dec_block_fn or (
                lambda bp, x, skip, aux: fns.block_fn(bp, x, aux))

            def enc_stage_fn(stage_p, x, aux):
                return scan_blocks_emit(enc_block, stage_p, x, my_count(), aux)

            def dec_stage_fn(stage_p, x, skips, aux):
                return scan_blocks_consume(
                    dec_block, stage_p, skips, x, my_count(), aux)

            if self.executor == "table":
                return make_wave_pipeline_from_schedule(
                    pcfg, self.schedule, embed_fn=fns.embed_fn,
                    enc_stage_fn=enc_stage_fn, dec_stage_fn=dec_stage_fn,
                    loss_fn=fns.loss_fn)
            return make_wave_pipeline(
                pcfg, embed_fn=fns.embed_fn, enc_stage_fn=enc_stage_fn,
                dec_stage_fn=dec_stage_fn, loss_fn=fns.loss_fn)

        if fns.block_fn is None:
            raise ValueError("linear pipeline needs model_fns.block_fn")

        def stage_fn(stage_p, x):
            return scan_blocks(fns.block_fn, stage_p, x, my_count(), None)

        embed = lambda e, mb: fns.embed_fn(e, mb, None)
        loss = lambda e, x, mb: fns.loss_fn(e, x, mb, None)
        if self.executor == "table":
            return make_linear_pipeline_from_schedule(
                pcfg, self.schedule, embed_fn=embed, stage_fn=stage_fn,
                loss_fn=loss)
        return make_linear_pipeline(
            pcfg, embed_fn=embed, stage_fn=stage_fn, loss_fn=loss)

    def bind(self, mesh) -> Callable:
        """``loss(params, mbs[, aux])`` with params = (stage_stacks, edge),
        ready for jit/grad on a multi-device mesh."""
        fn = self.build()
        pcfg = self.pcfg
        axis, data = pcfg.axis, pcfg.data_axes
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        missing = [a for a in (axis, *data) if a not in sizes]
        if missing:
            # the lowered executor psums over every configured axis; a mesh
            # without them would fail mid-trace with an unbound-axis error
            raise ValueError(
                f"mesh axes {mesh.axis_names} missing {missing} required by "
                "this plan (pass matching data_axes to auto_pipeline)")
        dp = math.prod(sizes[a] for a in data)
        if sizes[axis] != pcfg.num_devices or dp != pcfg.dp_size:
            # a size mismatch would not raise — it would silently mis-scale
            # the loss (dp) or gather clamped stage counts (model axis)
            raise ValueError(
                f"mesh sizes {sizes} do not match the plan "
                f"(model={pcfg.num_devices}, dp={pcfg.dp_size}); rebuild "
                f"with auto_pipeline(..., dp_size={dp})")

        def batch_spec(t):
            return jax.tree.map(
                lambda x: P(None, data)
                if data and getattr(x, "ndim", 0) >= 2 else P(), t)

        def wrap(edge, *batch_args):
            return shard_pipeline(
                fn, mesh, stacked_args=2 if self.folded else 1, axis=axis,
                batch_specs=(jax.tree.map(lambda _: P(), edge),
                             *(batch_spec(a) for a in batch_args)))

        if self.folded:
            def loss(params, mbs, aux):
                stacks, edge = params
                return wrap(edge, mbs, aux)(stacks[0], stacks[1], edge,
                                            mbs, aux)
        else:
            def loss(params, mbs):
                stacks, edge = params
                return wrap(edge, mbs)(stacks[0], edge, mbs)
        return loss

    def describe(self) -> str:
        part, sched = self.partition, self.schedule
        lines = [
            f"auto_pipeline: S={part.num_stages} stages over "
            f"D={part.num_devices} devices "
            f"({'folded wave' if part.folded else 'linear 1F1B'}), "
            f"M={self.pcfg.num_microbatches} microbatches",
            f"  cuts={part.cuts} stage sizes={part.stage_sizes()}",
            f"  schedule: makespan={sched.makespan} slots, "
            f"bubble={sched.bubble_ratio():.2f}",
            f"  executor: {self.executor}",
        ]
        if self.choice is not None:
            c = self.choice
            lines.append(f"  tuner: P={c.P} G={c.G} b={c.b} M={c.M} "
                         f"t/sample={c.t_sample*1e3:.3f} ms")
        return "\n".join(lines)


# ===========================================================================
# Entry point
# ===========================================================================

def auto_pipeline(
    graph: BlockGraph,
    model_fns: PipelineModelFns,
    N: int,
    hw: Hardware = TPU_V5E,
    *,
    microbatches: int | None = None,
    lam: float = 1.0,
    force_wave: bool | None = None,
    pipeline_devices: int | None = None,
    data_axes: tuple[str, ...] = ("data",),
    dp_size: int | None = None,
    remat: bool = True,
    remat_policy: str | None = None,
    use_ilp: bool = False,
    executor: str = "table",
) -> CompiledPipeline:
    """Plan, schedule, and lower a pipeline for ``graph`` on ``N`` devices.

    By default the hybrid tuner (§VI) picks (P, G, b) and supplies its
    partition; ``microbatches`` then defaults to the M the tuner's
    iteration-time score assumed (``TunerChoice.M``), and ``dp_size`` to
    the chosen G — the executed iteration matches the scored one.  Pass
    ``pipeline_devices`` to pin the pipeline degree and call the
    partitioner directly (deterministic; used by tests and the training
    driver, which already knows its mesh shape — ``dp_size`` defaults to 1
    there, ``microbatches`` to 2D folded / max(D, 2) linear).

    ``executor`` selects the lowering: ``"table"`` (default) executes the
    validated schedule via per-device step tables (runtime.schedule_exec);
    ``"closed_form"`` uses the hand-written wave/1F1B executors as
    differential references (these require M >= D for folded plans).
    """
    def lowerable(p: Partition) -> bool:
        return not p.folded or p.mirror_symmetric()

    choice: TunerChoice | None = None
    if pipeline_devices is not None:
        part = partition_graph(graph, pipeline_devices, hw=hw, lam=lam,
                               force_wave=force_wave)
        if not lowerable(part):
            raise ValueError(
                f"partition {part.cuts} is folded but not mirror-symmetric "
                "(partially-skipped graph); the executor cannot lower it — "
                "only fully-paired skip graphs fold today (ROADMAP open "
                "item)")
        if graph.skips and not part.folded:
            raise ValueError(
                "graph has skip edges but the plan is linear: the linear "
                "executor has no skip transport, so skips would be "
                "silently dropped — skip graphs need a folded plan")
    else:
        if force_wave is not None:
            raise ValueError(
                "force_wave requires pipeline_devices: the tuner derives "
                "wave vs linear from graph.skips and would ignore it")
        choices = tune(graph, N, hw=hw, lam=lam)
        choices = [c for c in choices if c.partition is not None and c.P > 1
                   and lowerable(c.partition)]
        if not choices:
            raise ValueError(
                f"tuner found no feasible, lowerable pipeline plan for N={N}")
        choice = choices[0]
        part = choice.partition

    D = part.num_devices
    if microbatches is not None:
        M = microbatches
    elif choice is not None:
        # execute the M the tuner scored (Eq. 15 assumed M = P) — the
        # planner and the executor must agree on the iteration shape
        M = choice.M
    else:
        M = 2 * D if part.folded else max(D, 2)
    if dp_size is None:
        dp_size = choice.G if choice is not None else 1
    # Schedule synthesis + full constraint validation happens here; an
    # invalid plan raises before any executor is built.
    sched = schedule_for_partition(part, M, use_ilp=use_ilp)

    pcfg = PipelineConfig(num_devices=D, num_microbatches=M,
                          data_axes=data_axes, dp_size=dp_size,
                          remat=remat, remat_policy=remat_policy)
    layout = StageLayout.from_partition(part)
    return CompiledPipeline(graph=graph, partition=part, schedule=sched,
                            layout=layout, pcfg=pcfg, model_fns=model_fns,
                            choice=choice, executor=executor)
