"""Model -> pipeline adapters.

These functions reshape a model's stacked parameters into per-device stage
stacks and provide the embed/stage/loss callbacks for the executors in
``runtime.pipeline``.  The stage grouping follows the PULSE partitioner's
output; for homogeneous transformer stacks the partition is the even split,
which the bidirectional DP returns for uniform costs (validated in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import lm as lm_mod
from repro.models import diffusion as diff_mod
from repro.models.lm import LMConfig
from repro.runtime.compat import tree_to_host
from repro.runtime.pipeline import (PipelineConfig, make_linear_pipeline,
                                    make_wave_pipeline,
                                    make_skip_carry_pipeline)

Pytree = Any


def _regroup(stack: Pytree, D: int, reverse: bool = False) -> Pytree:
    """[L, ...] stacked params -> [D, L/D, ...]; optionally flip device order
    (decoder stacks execute in reverse device order under the fold)."""

    def f(x):
        L = x.shape[0]
        assert L % D == 0, f"layer count {L} not divisible by {D} stages"
        y = x.reshape(D, L // D, *x.shape[1:])
        return y[::-1] if reverse else y

    return jax.tree.map(f, stack)


def _ungroup(stack: Pytree, reverse: bool = False) -> Pytree:
    def f(x):
        y = x[::-1] if reverse else x
        return y.reshape(y.shape[0] * y.shape[1], *y.shape[2:])
    return jax.tree.map(f, stack)


# ===========================================================================
# LM family
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class LMPipelineAdapter:
    """Linear (1F1B) or folded-wave pipeline for the unified LM family."""

    cfg: LMConfig
    pcfg: PipelineConfig
    wave: bool = False       # True: fold layers symmetrically (S = 2D)

    def init_pipeline_params(self, key) -> tuple:
        return self.split_params(lm_mod.init_lm(key, self.cfg))

    def split_params(self, params: Pytree) -> tuple:
        """-> (stacks..., edge_params) for the pipeline fn."""
        D = self.pcfg.num_devices
        layers = params["layers"]
        edge = {k: v for k, v in params.items() if k != "layers"}
        if not self.wave:
            return (_regroup(layers, D),), edge
        half = jax.tree.map(lambda x: x[: x.shape[0] // 2], layers)
        rest = jax.tree.map(lambda x: x[x.shape[0] // 2:], layers)
        return (_regroup(half, D), _regroup(rest, D, reverse=True)), edge

    def merge_params(self, stacks: tuple, edge: Pytree) -> Pytree:
        stacks = tree_to_host(stacks)   # legacy-JAX shard reassembly fix
        if not self.wave:
            layers = _ungroup(stacks[0])
        else:
            enc = _ungroup(stacks[0])
            dec = _ungroup(stacks[1], reverse=True)
            layers = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], 0), enc, dec)
        return {**edge, "layers": layers}

    # ---- callbacks ----
    def embed_fn(self, edge_p, mb, aux=None):
        return lm_mod.embed_tokens(edge_p, mb["tokens"], self.cfg)

    def _run_layers(self, stage_p, x):
        cfg = self.cfg
        positions = jnp.arange(x.shape[1])[None, :]

        def body(x, lp):
            x, _, _ = lm_mod.apply_layer(lp, x, cfg, dense_ffn=False,
                                         positions=positions)
            return x, None

        x, _ = jax.lax.scan(body, x, stage_p)
        return x

    def stage_fn(self, stage_p, x):
        return self._run_layers(stage_p, x)

    def enc_stage_fn(self, stage_p, x, aux):
        return self._run_layers(stage_p, x), {}

    def dec_stage_fn(self, stage_p, x, skips, aux):
        return self._run_layers(stage_p, x)

    def loss_fn(self, edge_p, x, mb, aux=None):
        logits = lm_mod.unembed(edge_p, x[:, :-1], self.cfg)
        return lm_mod.softmax_xent(logits, mb["tokens"][:, 1:])

    # ---- builders ----
    def build(self) -> Callable:
        if self.wave:
            wave = make_wave_pipeline(
                self.pcfg,
                embed_fn=lambda e, mb, aux: self.embed_fn(e, mb),
                enc_stage_fn=self.enc_stage_fn,
                dec_stage_fn=self.dec_stage_fn,
                loss_fn=lambda e, x, mb, aux: self.loss_fn(e, x, mb))
            # LM graphs have no skip tensors: aux rides along empty.
            return lambda enc, dec, edge, mbs: wave(enc, dec, edge, mbs, {})
        fn = make_linear_pipeline(
            self.pcfg, embed_fn=self.embed_fn, stage_fn=self.stage_fn,
            loss_fn=self.loss_fn)
        return fn


# ===========================================================================
# UViT / Hunyuan-DiT (wave with real skip tensors)
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class DiffusionPipelineAdapter:
    """Folded wave pipeline for UViT / Hunyuan-DiT.

    Microbatch inputs (all stacked [M, b, ...]):
      mb:  {"xt", "noise", plus model conditioning ("labels" | nothing)}
      aux: {"t"} for UViT (time token built in embed); Hunyuan additionally
           carries {"temb", "ctx"} to every stage.
    """

    cfg: Any                     # UViTConfig | HunyuanDiTConfig
    pcfg: PipelineConfig | None  # None: callbacks-only (diffusion_model_fns
    kind: str = "uvit"           # borrows embed/loss/_blk_kwargs; build/
                                 # split_params need a real PipelineConfig).
                                 # kind: "uvit" | "hunyuan"

    def init_pipeline_params(self, key) -> tuple:
        init = (diff_mod.init_uvit if self.kind == "uvit"
                else diff_mod.init_hunyuan)
        return self.split_params(init(key, self.cfg))

    def split_params(self, params: Pytree) -> tuple:
        D = self.pcfg.num_devices
        enc = _regroup(params["enc_blocks"], D)
        dec = _regroup(params["dec_blocks"], D, reverse=True)
        edge = {k: v for k, v in params.items()
                if k not in ("enc_blocks", "dec_blocks")}
        return (enc, dec), edge

    def merge_params(self, stacks: tuple, edge: Pytree) -> Pytree:
        stacks = tree_to_host(stacks)   # legacy-JAX shard reassembly fix
        return {**edge,
                "enc_blocks": _ungroup(stacks[0]),
                "dec_blocks": _ungroup(stacks[1], reverse=True)}

    def embed_fn(self, edge_p, mb, aux):
        if self.kind == "uvit":
            return diff_mod.uvit_embed(edge_p, mb["xt"], aux["t"], mb, self.cfg)
        tok = diff_mod._patchify(mb["xt"].astype(self.cfg.dtype),
                                 self.cfg.patch) @ edge_p["patch_embed"].astype(self.cfg.dtype)
        return tok + edge_p["pos_embed"].astype(self.cfg.dtype)[None]

    def _blk_kwargs(self, aux):
        if self.kind == "uvit":
            return {}
        return {"ctx": aux["ctx"], "temb": aux["temb"]}

    def enc_stage_fn(self, stage_p, x, aux):
        kw = self._blk_kwargs(aux)

        def body(x, bp):
            x = diff_mod._apply_vit_block(bp, x, self.cfg, **kw)
            return x, x

        x, skips = jax.lax.scan(body, x, stage_p)
        return x, skips

    def dec_stage_fn(self, stage_p, x, skips, aux):
        kw = self._blk_kwargs(aux)

        def body(x, inp):
            bp, skip = inp
            return diff_mod._apply_vit_block(bp, x, self.cfg, skip=skip, **kw), None

        x, _ = jax.lax.scan(body, x, (stage_p, skips[::-1]))
        return x

    def loss_fn(self, edge_p, x, mb, aux):
        if self.kind == "uvit":
            pred = diff_mod.uvit_output(edge_p, x, self.cfg)
        else:
            from repro.models.layers import rms_norm
            h = rms_norm(x, edge_p["out_norm"], self.cfg.norm_eps)
            pix = h @ edge_p["out_proj"].astype(h.dtype)
            pred = diff_mod._unpatchify(pix, self.cfg.patch,
                                        self.cfg.img_size, self.cfg.in_ch)
        return jnp.mean(jnp.square(pred.astype(jnp.float32)
                                   - mb["noise"].astype(jnp.float32)))

    def build(self) -> Callable:
        return make_wave_pipeline(
            self.pcfg, embed_fn=self.embed_fn,
            enc_stage_fn=self.enc_stage_fn, dec_stage_fn=self.dec_stage_fn,
            loss_fn=self.loss_fn)

    def build_skip_carry_baseline(self) -> Callable:
        """Paper-baseline executor: sequential partition + skip payload."""
        D = self.pcfg.num_devices
        half = self.cfg.half
        assert half % (D // 2) == 0
        k = half // (D // 2)
        return make_skip_carry_pipeline(
            self.pcfg, n_skip_slots=half,
            embed_fn=self.embed_fn,
            enc_stage_fn=self.enc_stage_fn, dec_stage_fn=self.dec_stage_fn,
            loss_fn=self.loss_fn, skips_per_stage=k)

    def split_params_skip_carry(self, params: Pytree) -> tuple:
        """Sequential layout for the baseline: devices 0..D/2-1 hold enc
        stages, D/2..D-1 hold dec stages; stacks are padded to D rows."""
        D = self.pcfg.num_devices
        enc = _regroup(params["enc_blocks"], D // 2)
        dec = _regroup(params["dec_blocks"], D // 2)
        pad = lambda t: jax.tree.map(
            lambda x: jnp.concatenate([x, jnp.zeros_like(x)], 0), t)
        enc_padded = pad(enc)                       # rows D/2.. unused
        dec_padded = jax.tree.map(
            lambda x: jnp.concatenate([jnp.zeros_like(x), x], 0), dec)
        edge = {k: v for k, v in params.items()
                if k not in ("enc_blocks", "dec_blocks")}
        return (enc_padded, dec_padded), edge


def make_diffusion_microbatches(batch: dict, rng, M: int, cfg,
                                kind: str = "uvit",
                                params: Pytree | None = None
                                ) -> tuple[dict, dict]:
    """Sample DDPM (t, noise) per microbatch and reshape [B,...] ->
    [M, B/M, ...] stacked microbatches + aux conditioning.

    For Hunyuan the per-stage adaLN conditioning ``temb`` is computed once
    here from the (replicated) ``time_mlp`` params and broadcast down the
    pipeline as aux; its gradient psums across stages via the shard_map
    transpose."""
    B = batch["latents"].shape[0]
    b = B // M
    rt, rn = jax.random.split(rng)
    t = jax.random.uniform(rt, (B,))
    ab = diff_mod.cosine_alpha_bar(t)[:, None, None, None]
    noise = jax.random.normal(rn, batch["latents"].shape,
                              batch["latents"].dtype)
    xt = jnp.sqrt(ab) * batch["latents"] + jnp.sqrt(1 - ab) * noise
    split = lambda x: x.reshape(M, b, *x.shape[1:])
    mb = {"xt": split(xt), "noise": split(noise)}
    aux = {"t": split(t)}
    if kind == "uvit":
        mb["labels"] = split(batch["labels"])
    else:
        from repro.models.layers import apply_gelu_mlp
        temb = apply_gelu_mlp(
            params["time_mlp"],
            diff_mod.timestep_embedding(t, cfg.d_model).astype(cfg.dtype))
        aux["ctx"] = split(batch["text_embeds"].astype(cfg.dtype))
        aux["temb"] = split(temb)
    return mb, aux


# ===========================================================================
# Block-level model fns for the auto-compile path (runtime.compile)
# ===========================================================================

def lm_model_fns(cfg: LMConfig):
    """Unified-LM family as block-level compile-path callables.

    Pairs with :func:`repro.models.lm.lm_pipeline_graph` (skip-free; the
    compiler emits a linear S=D pipeline, or a folded S=2D wave under
    ``force_wave``).
    """
    from repro.runtime.compile import PipelineModelFns

    def embed_fn(edge_p, mb, aux):
        return lm_mod.embed_tokens(edge_p, mb["tokens"], cfg)

    def block_fn(lp, x, aux):
        positions = jnp.arange(x.shape[1])[None, :]
        x, _, _ = lm_mod.apply_layer(lp, x, cfg, dense_ffn=False,
                                     positions=positions)
        return x

    def loss_fn(edge_p, x, mb, aux):
        logits = lm_mod.unembed(edge_p, x[:, :-1], cfg)
        return lm_mod.softmax_xent(logits, mb["tokens"][:, 1:])

    def split_blocks(params):
        edge = {k: v for k, v in params.items() if k != "layers"}
        return (params["layers"],), edge

    def merge_blocks(stacks, edge):
        return {**edge, "layers": stacks[0]}

    return PipelineModelFns(
        init_fn=lambda key: lm_mod.init_lm(key, cfg),
        embed_fn=embed_fn, loss_fn=loss_fn, block_fn=block_fn,
        split_blocks=split_blocks, merge_blocks=merge_blocks)


def diffusion_model_fns(cfg: Any, kind: str = "uvit"):
    """UViT / Hunyuan-DiT as block-level compile-path callables.

    Pairs with :func:`repro.models.diffusion.uvit_pipeline_graph`: every
    encoder block emits its output as a skip; the mirror decoder block
    consumes it (fully-paired graph -> mirror-symmetric folded partitions).
    """
    from repro.runtime.compile import PipelineModelFns

    ad = DiffusionPipelineAdapter(cfg, None, kind)   # callbacks only

    def enc_block_fn(bp, x, aux):
        y = diff_mod._apply_vit_block(bp, x, cfg, **ad._blk_kwargs(aux))
        return y, y

    def dec_block_fn(bp, x, skip, aux):
        return diff_mod._apply_vit_block(bp, x, cfg, skip=skip,
                                         **ad._blk_kwargs(aux))

    def split_blocks(params):
        edge = {k: v for k, v in params.items()
                if k not in ("enc_blocks", "dec_blocks")}
        return (params["enc_blocks"], params["dec_blocks"]), edge

    def merge_blocks(stacks, edge):
        return {**edge, "enc_blocks": stacks[0], "dec_blocks": stacks[1]}

    init = diff_mod.init_uvit if kind == "uvit" else diff_mod.init_hunyuan
    return PipelineModelFns(
        init_fn=lambda key: init(key, cfg),
        embed_fn=ad.embed_fn, loss_fn=ad.loss_fn,
        enc_block_fn=enc_block_fn, dec_block_fn=dec_block_fn,
        split_blocks=split_blocks, merge_blocks=merge_blocks,
        num_param_stacks=2)


def skipvit_model_fns(cfg):
    """SkipViT (homogeneous stack, arbitrary skip topology) as compile-path
    callables.

    Pairs with :func:`repro.models.diffusion.skipvit_pipeline_graph`.  One
    parameter stack covers emitters, bottleneck blocks and consumers: every
    encoder-half block emits its output to the stash, every decoder-half
    block consumes additively (``x + skip @ skip_in``) — rows the layout's
    skip pairing marks skip-less receive zeros and reduce to plain blocks.
    This is the model family whose partitions exercise asymmetric folds
    (the fold's turnaround cut may land anywhere, including inside the
    bottleneck run).
    """
    from repro.runtime.compile import PipelineModelFns

    def embed_fn(edge_p, mb, aux):
        return diff_mod.uvit_embed(edge_p, mb["xt"], aux["t"], mb, cfg)

    def enc_block_fn(bp, x, aux):
        y = diff_mod._apply_vit_block(bp, x, cfg)
        return y, y

    def dec_block_fn(bp, x, skip, aux):
        x = x + skip @ bp["skip_in"].astype(x.dtype)
        return diff_mod._apply_vit_block(bp, x, cfg)

    def loss_fn(edge_p, x, mb, aux):
        pred = diff_mod.uvit_output(edge_p, x, cfg)
        return jnp.mean(jnp.square(pred.astype(jnp.float32)
                                   - mb["noise"].astype(jnp.float32)))

    def split_blocks(params):
        edge = {k: v for k, v in params.items() if k != "blocks"}
        return (params["blocks"],), edge

    def merge_blocks(stacks, edge):
        return {**edge, "blocks": stacks[0]}

    return PipelineModelFns(
        init_fn=lambda key: diff_mod.init_skipvit(key, cfg),
        embed_fn=embed_fn, loss_fn=loss_fn,
        enc_block_fn=enc_block_fn, dec_block_fn=dec_block_fn,
        split_blocks=split_blocks, merge_blocks=merge_blocks,
        num_param_stacks=1)
