"""Sharding rules: logical param/activation layouts -> mesh PartitionSpecs.

Megatron-style TP over the 'model' axis, ZeRO/FSDP over 'data' (+'pod'),
expert parallelism for MoE over 'model'.  Rules are right-aligned: a rule
``("fsdp", "tp")`` on a leaf of ndim 3 becomes ``P(None, fsdp_axes, tp)`` —
stacked-layer leading dims stay unsharded (they are scanned over).

``build_param_specs`` walks a params pytree by key-path and applies the
first matching rule (match = last path component, or ``parent/leaf``).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
from jax.sharding import PartitionSpec as P, NamedSharding

Pytree = Any

# rule tables: name -> tuple of logical axes for the *trailing* dims.
# logical axes: "tp" (tensor parallel), "fsdp" (param sharding over data),
# "ep" (expert parallel), None (replicated).

LM_RULES: dict[str, tuple] = {
    "embed": ("fsdp", "tp"),
    "head": ("fsdp", "tp"),
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "w_gate": ("fsdp", "tp"),
    "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    # MoE expert tensors (E, d, f) / (E, f, d): experts over 'ep'
    "ffn/w_gate": ("ep", "fsdp", None),
    "ffn/w_up": ("ep", "fsdp", None),
    "ffn/w_down": ("ep", None, "fsdp"),
    "shared/w_gate": ("fsdp", "tp"),
    "shared/w_up": ("fsdp", "tp"),
    "shared/w_down": ("tp", "fsdp"),
    "router": (None, None),
    # MLA
    "wq_a": ("fsdp", None),
    "wq_b": (None, "tp"),
    "wkv_a": ("fsdp", None),
    "wkv_b": (None, "tp"),
    # conv / misc
    "conv": (None, None),
    "proj": ("fsdp", None),
}

DENSE_ONLY_KEYS = {"dense_layers"}   # deepseek prelude uses dense ffn rules


def _is_moe_leaf(path: tuple[str, ...]) -> bool:
    # expert tensors live under layers/ffn with ndim 3 handled by rule table
    return len(path) >= 2 and path[-2] == "ffn"


def _axes_product(entry, axis_sizes: dict) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return axis_sizes.get(entry, 1)
    out = 1
    for a in entry:
        out *= axis_sizes.get(a, 1)
    return out


def fit_spec(spec: P, shape, axis_sizes: dict | None) -> P:
    """Drop sharding on any dim the mesh axes do not divide evenly."""
    if axis_sizes is None:
        return spec
    fitted = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        n = _axes_product(entry, axis_sizes)
        if entry is None or n <= 1:
            fitted.append(None)
        elif dim % n == 0:
            fitted.append(entry)
        else:
            fitted.append(None)
    return P(*fitted)


def build_param_specs(
    params: Pytree,
    *,
    tp_axis: str | None = "model",
    fsdp_axes: tuple[str, ...] | str | None = ("data",),
    ep_axis: str | None = None,
    rules: dict[str, tuple] | None = None,
    min_fsdp_size: int = 2 ** 12,
    axis_sizes: dict | None = None,
) -> Pytree:
    """PartitionSpec pytree matching ``params``.

    ``ep_axis`` switches *stacked* expert tensors (ndim >= 4 leaves under
    ``ffn``) to expert parallelism.  Small leaves (< min_fsdp_size elems)
    stay replicated.  With ``axis_sizes`` every spec is divisibility-checked
    against the mesh and non-dividing entries fall back to replication.
    """
    rules = dict(LM_RULES, **(rules or {}))
    if isinstance(fsdp_axes, str):
        fsdp_axes = (fsdp_axes,)

    def logical_to_mesh(name):
        if name == "tp":
            return tp_axis
        if name == "fsdp":
            return fsdp_axes if fsdp_axes else None
        if name == "ep":
            return ep_axis if ep_axis else tp_axis
        if isinstance(name, (tuple, list)) or (
                isinstance(name, str) and name not in ()):
            return name          # literal mesh axis (or tuple of axes)
        return None

    def spec_for(path: tuple[str, ...], leaf) -> P:
        if leaf.ndim == 0 or leaf.size < min_fsdp_size:
            return P()
        key2 = "/".join(path[-2:])
        key1 = path[-1]
        rule = None
        if ep_axis is not None and key2 in rules and _is_moe_leaf(path) \
                and leaf.ndim >= 4:
            rule = rules[key2]          # stacked (L, E, d, f) expert tensors
        elif key1 in rules:
            rule = rules[key1]
        if rule is None:
            # default: FSDP over the trailing dim
            rule = ("fsdp",) if leaf.ndim >= 1 else ()
        axes = [logical_to_mesh(r) for r in rule]
        pad = leaf.ndim - len(axes)
        if pad < 0:
            axes = axes[-leaf.ndim:]
            pad = 0
        return fit_spec(P(*([None] * pad), *axes), leaf.shape, axis_sizes)

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v, path + (str(i),)) for i, v in enumerate(node)]
            return type(node)(t)
        return spec_for(path, node)

    return walk(params, ())


def zero_stack_specs(
    stacks: Pytree,
    *,
    dp: int,
    axis: str = "model",
    data_axes: tuple[str, ...] = ("data",),
    rules: dict[str, tuple] | None = None,
    min_shard_size: int = 2 ** 8,
) -> tuple[Pytree, Pytree]:
    """ZeRO rest-sharding for ``[D, V, pad, ...]`` stage parameter stacks.

    Returns ``(specs, gather_dims)``.  ``specs`` mirrors the stack pytree
    with ``P(axis, None, None, ...)`` leaves: the leading device dim
    shards over the pipeline axis as always, and one trailing (block)
    dim additionally shards over ``data_axes`` — the same right-aligned
    ``LM_RULES`` fsdp placement ``build_param_specs`` applies to
    unstacked params, with tp/ep disabled (the stage axis *is* the
    pipeline).  ``gather_dims`` holds, per leaf, the dim index within
    the per-slot ``[pad, ...]`` view (what ``tree_index(tree_local(
    stack), vslot)`` yields inside the scan body) the executor must
    all-gather on use; ``-1`` = replicated, no gather.  A leaf stays
    replicated when its per-block size is under ``min_shard_size``
    (smaller than ``build_param_specs``'s ``min_fsdp_size`` — stacked
    stage blocks amortize the gather over the whole slot row) or when
    no eligible dim divides ``dp``.

    Optimizer state mirrors the param tree leaf-wise (see
    ``optim/adamw.py``), so these specs shard ZeRO-1 optimizer state for
    the stacks too — apply them to the ``m``/``v`` leaves unchanged.
    """
    rules = dict(LM_RULES, **(rules or {}))

    def spec_for(path: tuple[str, ...], leaf) -> tuple[P, int]:
        rep = (P(axis), -1)
        nblock = leaf.ndim - 3
        if dp <= 1 or nblock < 1:
            return rep
        block_size = 1
        for d in leaf.shape[3:]:
            block_size *= d
        if block_size < min_shard_size:
            return rep
        rule = rules.get("/".join(path[-2:])) or rules.get(path[-1]) \
            or ("fsdp",)
        # right-align the rule against the block dims; tp/ep entries
        # are disabled here, only "fsdp" maps to the data axes
        entries = [r if r == "fsdp" else None for r in rule][-nblock:]
        entries = [None] * (nblock - len(entries)) + list(entries)
        j = next((k for k, e in enumerate(entries)
                  if e == "fsdp" and leaf.shape[3 + k] % dp == 0), None)
        if j is None:
            # fallback: largest block dim dp divides (ZeRO does not care
            # which dim is scattered, only that the bytes are)
            divisible = [k for k in range(nblock)
                         if leaf.shape[3 + k] % dp == 0]
            if not divisible:
                return rep
            j = max(divisible, key=lambda k: leaf.shape[3 + k])
        trailing = [None] * nblock
        trailing[j] = data_axes
        return P(axis, None, None, *trailing), 1 + j

    def walk(node, path):
        if isinstance(node, dict):
            out = {k: walk(v, path + (k,)) for k, v in node.items()}
            return ({k: v[0] for k, v in out.items()},
                    {k: v[1] for k, v in out.items()})
        if isinstance(node, (list, tuple)):
            t = [walk(v, path + (str(i),)) for i, v in enumerate(node)]
            return (type(node)(x[0] for x in t),
                    type(node)(x[1] for x in t))
        return spec_for(path, node)

    return walk(stacks, ())


def batch_specs(batch: Pytree, dp_axes: Sequence[str] = ("pod", "data"),
                mesh=None) -> Pytree:
    """Shard the leading batch dim of every leaf over the DP axes present
    in the mesh (divisibility-checked)."""
    axes = tuple(a for a in dp_axes if mesh is None or a in mesh.axis_names)
    sizes = (dict(zip(mesh.axis_names, mesh.devices.shape))
             if mesh is not None else None)

    def f(x):
        if x.ndim < 1 or not axes:
            return P()
        return fit_spec(P(axes, *([None] * (x.ndim - 1))), x.shape, sizes)

    return jax.tree.map(f, batch)


def cache_specs(caches: Pytree, *, dp_axes=("pod", "data"),
                tp_axis: str | None = "model",
                seq_shard_axis: str | None = None, mesh=None) -> Pytree:
    """Decode-state sharding, name-aware and right-aligned.

    - GQA "k"/"v" [..., B, S, H, Dh]: batch over DP, heads over TP; with
      ``seq_shard_axis`` the sequence dim shards instead of batch (the
      LSE-merge long-context decode layout for batch=1 cells).
    - MLA "kv" [..., B, S, r] / "k_rope" [..., B, S, 1, dr]: batch/seq only.
    - Mamba "ssm" [B, H, N, P] and mLSTM "C" [B, H, D, D]: batch over DP,
      heads over TP.  "conv"/"h"/"c"/"n"/"m": batch over DP.
    """
    axes = tuple(a for a in dp_axes if mesh is None or a in mesh.axis_names)
    bspec = axes if axes else None   # fit_spec drops it when B is indivisible
    sizes = (dict(zip(mesh.axis_names, mesh.devices.shape))
             if mesh is not None else None)

    def ralign(x, trailing):
        pad = x.ndim - len(trailing)
        return fit_spec(P(*([None] * pad), *trailing), x.shape, sizes)

    def f(path, x):
        name = ""
        for e in reversed(path):
            if hasattr(e, "key"):
                name = str(e.key)
                break
        if name in ("k", "v") and x.ndim >= 4:
            return ralign(x, (bspec, seq_shard_axis, tp_axis, None))
        if name == "kv" and x.ndim >= 3:
            return ralign(x, (bspec, seq_shard_axis, None))
        if name == "k_rope" and x.ndim >= 4:
            return ralign(x, (bspec, seq_shard_axis, None, None))
        if name in ("ssm", "C") and x.ndim >= 4:
            return ralign(x, (axes, tp_axis, None, None))
        if name in ("conv", "h", "c", "n", "m") and x.ndim >= 2:
            return ralign(x, (axes,) + (None,) * (min(x.ndim, 3) - 1))
        if name == "pos" or x.ndim == 0:
            return P()
        return ralign(x, (axes,) + (None,) * max(x.ndim - 1, 0)) \
            if x.ndim >= 1 else P()

    return jax.tree_util.tree_map_with_path(f, caches)


def to_shardings(specs: Pytree, mesh) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
