"""Elastic fault tolerance for the compiled pipeline.

Three pieces make crash/kill/shrink recovery a first-class property of
the ``auto_pipeline`` path:

1. **Plan state-specs + fingerprints.**  :func:`compiled_state_spec`
   serializes everything that determines how a
   :class:`~repro.runtime.compile.CompiledPipeline`'s training state is
   laid out at rest — partition cuts, stage->device map, the
   :class:`~repro.runtime.compile.StageLayout` slot/count/pad tables —
   and :func:`plan_fingerprint` hashes the layout-relevant subset.  The
   spec rides in every checkpoint manifest (``checkpoint.store``), so a
   restore knows exactly which plan wrote the bytes it is reading.
   ``M``/``wire_dtype``/``dp``/``zero_stage`` are recorded for
   observability but excluded from the fingerprint: ``jax.device_get``
   reassembles ZeRO-sharded stacks into full logical arrays before the
   write, so the at-rest format only depends on the stacking layout.

2. **Elastic restore.**  When the restore-time plan differs (fewer
   devices after a node loss, a different P/V from a re-run of the
   tuner), :func:`state_to_logical` de-stacks the saved ``[D, V, pad,
   ...]`` stage stacks through the *saved* layout spec back to the
   model's flat block stacks (pure numpy — no jax mesh needed for the
   old plan), and :func:`logical_to_state` re-stacks them onto the new
   plan via its own ``StageLayout.split``.  AdamW state mirrors params
   leaf-wise, so the same mapping applies to ``m``/``v``.
   :func:`restore_training_state` orchestrates: fast path when
   fingerprints match, destack/restack when they don't.

3. **Fault injection + a NaN guard.**  :class:`FaultPlan` parses an
   env/flag-driven fault script (``kill@K``, ``stop@K``, ``nan@K``,
   ``corrupt@K[:shard]``, ``truncate@K[:shard]``, ``iofail@K:N``, plus
   the multi-host verbs ``hostdown@K:h``, ``hang@K[:h]`` and
   ``slow@K:factor[:h]``) that the training driver (``launch/train.py``)
   consults each step, and :class:`GradGuard` is the skip-and-log guard
   for non-finite grads with a bounded consecutive-skip budget and a
   configurable escalation (abort, or roll back to last-good).

4. **Supervisor detection primitives.**  Workers emit file-based
   :class:`Heartbeat` records (:func:`write_heartbeat` /
   :func:`read_heartbeats`); the training supervisor
   (``launch/supervisor.py``) monitors them with a :class:`Watchdog`
   (stalled progress -> suspect -> hung) and a :class:`StragglerDetector`
   (per-step timing percentiles flag hosts persistently slower than the
   cluster median).  These are pure host-side primitives — no jax.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import sys
import time
from typing import Any

import numpy as np

Pytree = Any

STATE_SPEC_SCHEMA = "repro.state-spec/v1"

#: spec keys that determine the at-rest array layout (and hence whether a
#: saved checkpoint can be loaded directly or must be de-/re-stacked).
_FINGERPRINT_FIELDS = ("P", "V", "folded", "cuts", "devices",
                       "num_param_stacks", "enc_slots", "dec_slots",
                       "enc_counts", "dec_counts", "enc_pad", "dec_pad")


def plan_fingerprint(spec: dict) -> str:
    """Stable 16-hex-digit digest of a state spec's layout fields.

    Computed over the canonical JSON of :data:`_FINGERPRINT_FIELDS` only,
    so it is identical whether the spec came fresh off a plan (tuples)
    or round-tripped through a manifest (lists).
    """
    doc = {k: spec[k] for k in _FINGERPRINT_FIELDS}
    blob = json.dumps(doc, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def compiled_state_spec(plan) -> dict:
    """JSON-serializable layout spec for a CompiledPipeline's state."""
    part, lay, pcfg = plan.partition, plan.layout, plan.pcfg
    spec = {
        "schema": STATE_SPEC_SCHEMA,
        "P": int(part.num_devices),
        "S": int(part.num_stages),
        "V": int(lay.V),
        "folded": bool(part.folded),
        "cuts": [int(c) for c in part.cuts],
        "devices": [int(d) for d in part.devices],
        "dp": int(pcfg.dp_size),
        "zero_stage": int(pcfg.zero_stage),
        "M": int(pcfg.num_microbatches),
        "wire_dtype": str(pcfg.wire_dtype),
        "num_param_stacks": int(plan.model_fns.num_param_stacks),
        "enc_slots": [[int(s) for s in ss] for ss in lay.enc_slots],
        "dec_slots": [[int(s) for s in ss] for ss in lay.dec_slots],
        "enc_counts": [[int(c) for c in cc] for cc in lay.enc_counts],
        "dec_counts": [[int(c) for c in cc] for cc in lay.dec_counts],
        "enc_pad": int(lay.enc_pad),
        "dec_pad": int(lay.dec_pad),
    }
    spec["fingerprint"] = plan_fingerprint(spec)
    return spec


# ===========================================================================
# Elastic de-stack / re-stack
# ===========================================================================

def _spec_enc_ranges(spec: dict) -> list:
    cuts = spec["cuts"]
    return [[(cuts[s], cuts[s + 1]) for s in ss]
            for ss in spec["enc_slots"]]


def _spec_dec_ranges(spec: dict) -> list:
    cuts = spec["cuts"]
    mid = cuts[(len(cuts) - 1) // 2]
    return [[(cuts[s] - mid, cuts[s + 1] - mid) for s in ss]
            for ss in spec["dec_slots"]]


def _destack(stacked: Pytree, ranges: list) -> Pytree:
    """Numpy port of ``StageLayout._unstack`` driven by a serialized spec:
    ``[D, V, pad, ...]`` stage stacks -> flat block stack in graph order."""
    import jax

    order = sorted(((d, v) for d in range(len(ranges))
                    for v in range(len(ranges[d]))),
                   key=lambda dv: ranges[dv[0]][dv[1]][0])

    def f(x):
        x = np.asarray(x)
        parts = [x[d, v, : ranges[d][v][1] - ranges[d][v][0]]
                 for d, v in order]
        return np.concatenate(parts, 0)

    return jax.tree.map(f, stacked)


def destack_stage_stacks(stage_stacks: tuple, spec: dict) -> tuple:
    """Saved per-(device, slot) stage stacks -> the model's logical block
    stacks, through the *saved* plan's layout spec."""
    import jax

    if not spec["folded"]:
        return (_destack(stage_stacks[0], _spec_enc_ranges(spec)),)
    enc_b = _destack(stage_stacks[0], _spec_enc_ranges(spec))
    dec_b = _destack(stage_stacks[1], _spec_dec_ranges(spec))
    if spec["num_param_stacks"] == 1:
        return (jax.tree.map(lambda a, b: np.concatenate([a, b], 0),
                             enc_b, dec_b),)
    return (enc_b, dec_b)


def state_to_logical(state: dict, spec: dict) -> dict:
    """Training state saved under ``spec`` -> plan-independent logical view.

    ``state`` is the tree ``launch/train.py`` checkpoints: ``{"params":
    (stage_stacks, edge), "opt": {"m": ..., "v": ..., "step": ...}}``
    where AdamW's ``m``/``v`` mirror ``params`` leaf-wise.
    """
    def conv(pt):
        stacks, edge = pt
        return {"stacks": destack_stage_stacks(tuple(stacks), spec),
                "edge": edge}

    out = {"params": conv(state["params"])}
    if state.get("opt") is not None:
        o = state["opt"]
        out["opt"] = {"m": conv(o["m"]), "v": conv(o["v"]), "step": o["step"]}
    return out


def logical_to_state(logical: dict, plan) -> dict:
    """Inverse of :func:`state_to_logical`, onto the *new* plan."""
    def conv(d):
        return (plan.layout.split(tuple(d["stacks"])), d["edge"])

    state = {"params": conv(logical["params"])}
    if logical.get("opt") is not None:
        o = logical["opt"]
        state["opt"] = {"m": conv(o["m"]), "v": conv(o["v"]),
                        "step": o["step"]}
    return state


@dataclasses.dataclass(frozen=True)
class RestoreInfo:
    """What :func:`restore_training_state` did."""
    step: int                       # checkpoint step restored
    elastic: bool                   # True when saved plan != current plan
    saved_fingerprint: str | None
    fingerprint: str


def restore_training_state(directory: str, plan, like_state: dict, *,
                           step: int | None = None,
                           strict: bool = True) -> tuple[dict, RestoreInfo]:
    """Restore training state for ``plan``, elastically if needed.

    Loads the newest fully-verified checkpoint (``strict=False`` falls
    back past corrupt/partial steps), then compares the manifest's saved
    state spec against ``plan``'s: identical fingerprints load directly
    (the pytree topology is plan-invariant — only leaf shapes differ);
    different fingerprints route through the logical view
    (:func:`state_to_logical` with the *saved* spec, then
    :func:`logical_to_state` onto ``plan``).
    """
    from repro.checkpoint.store import (CheckpointError, read_manifest,
                                        restore_checkpoint)

    state, got = restore_checkpoint(directory, like_state, step=step,
                                    strict=strict, expect_shapes=False)
    man = read_manifest(directory, got)
    saved = man.get("plan")
    if saved is None:
        raise CheckpointError(
            "checkpoint carries no plan state-spec; cannot verify it "
            "matches the compiled pipeline (save through "
            "CheckpointManager(..., plan=compiled.state_spec()))",
            step=got, reason="no-plan-spec")
    cur = compiled_state_spec(plan)
    if saved["fingerprint"] == cur["fingerprint"]:
        return state, RestoreInfo(got, False, saved["fingerprint"],
                                  cur["fingerprint"])
    print(f"[resilience] plan changed since step {got} "
          f"({saved['fingerprint']} -> {cur['fingerprint']}): de-stacking "
          f"P={saved['P']} V={saved['V']} dp={saved['dp']} "
          f"zero={saved['zero_stage']} state onto P={cur['P']} V={cur['V']} "
          f"dp={cur['dp']} zero={cur['zero_stage']}")
    logical = state_to_logical(state, saved)
    return logical_to_state(logical, plan), RestoreInfo(
        got, True, saved["fingerprint"], cur["fingerprint"])


# ===========================================================================
# Fault injection
# ===========================================================================

#: seconds a ``hang@K`` fault sleeps — long enough that any reasonable
#: watchdog declares the host hung first (SIGTERM interrupts the sleep).
HANG_SECONDS = 3600.0

#: process exit codes the supervisor branches on.
EXIT_KILLED = 42      # kill@K / hostdown@K:h — a node died
EXIT_ESCALATE = 43    # GradGuard skip budget exhausted, rollback requested

_FAULT_KINDS = ("kill", "stop", "nan", "corrupt", "truncate", "iofail",
                "hostdown", "hang", "slow")
_FAULT_RE = re.compile(r"([a-z]+)@(-?\d+)(?::([\w.\-:]+))?")


class FaultPlanError(ValueError):
    """Structured fault-spec failure naming the offending token.

    Raised by :meth:`FaultPlan.parse` / :meth:`FaultPlan.for_host` so a
    malformed ``--faults`` spec fails at startup with the bad token in
    hand, instead of deep inside the training loop.  ``token``/``reason``
    survive as fields; subclasses ``ValueError`` for legacy callers.
    """

    def __init__(self, message: str, *, token: str | None = None,
                 reason: str | None = None):
        self.token = token
        self.reason = reason
        ctx = ", ".join(f"{k}={v!r}" for k, v in
                        (("token", token), ("reason", reason))
                        if v is not None)
        super().__init__(f"[faultplan{'; ' + ctx if ctx else ''}] {message}")


@dataclasses.dataclass(frozen=True)
class FaultAction:
    kind: str            # kill | stop | nan | corrupt | truncate | iofail
    #                      | hostdown | hang | slow
    step: int
    arg: str | None = None   # corrupt/truncate: shard name
    count: int = 1           # iofail: number of injected IO failures
    host: int | None = None  # hostdown/hang/slow: target host rank
    factor: float = 1.0      # slow: per-step slowdown factor
    token: str = ""          # the spec token this action parsed from


class FaultPlan:
    """Env/flag-driven fault script for the training driver.

    Comma-separated tokens, each ``kind@step`` with an optional arg:

    - ``kill@K``      — hard-kill the process (``os._exit``) after step K,
      flushing any in-flight checkpoint first (a node dies between steps);
    - ``stop@K``      — abrupt in-process stop after step K, *without* a
      final save (same recovery surface as kill, usable by in-process
      drills);
    - ``nan@K``       — poison step K's batch with NaNs, so the step's
      grads go non-finite and the :class:`GradGuard` path runs;
    - ``corrupt@K[:shard]``  — after step K, flip one byte in the named
      (default: first) shard of the newest complete checkpoint;
    - ``truncate@K[:shard]`` — same, but truncate the shard to half;
    - ``iofail@K:N``  — the next N checkpoint-save attempts at/after
      step K raise a transient ``OSError`` (exercises the manager's
      retry/backoff path);
    - ``hostdown@K:h`` — host ``h`` hard-exits after step K (the
      multi-host ``kill``; other hosts keep running so the supervisor's
      watchdog/exit monitoring must notice);
    - ``hang@K[:h]``   — host ``h`` (default 0) stalls before step K for
      :data:`HANG_SECONDS` — a hung collective: the process stays alive
      but its heartbeat step stops advancing;
    - ``slow@K:factor[:h]`` — from step K on, host ``h`` (default 0)
      runs each step ``factor``x slower (a straggler, for the
      :class:`StragglerDetector`).

    Malformed specs raise :class:`FaultPlanError` naming the offending
    token: unknown kinds, negative steps, duplicate ``kind@step`` pairs,
    and (once the host count is known — :meth:`for_host`) host indices
    outside ``[0, num_hosts)``.

    Source: the ``--faults`` flag, else the ``REPRO_FAULTS`` env var.
    """

    def __init__(self, actions=(), exit_code: int = EXIT_KILLED):
        self.actions: tuple[FaultAction, ...] = tuple(actions)
        self.exit_code = exit_code
        self._io_left = {i: a.count for i, a in enumerate(self.actions)
                         if a.kind == "iofail"}

    @classmethod
    def parse(cls, spec: str | None = None, *,
              env: str = "REPRO_FAULTS") -> "FaultPlan":
        if spec is None:
            spec = os.environ.get(env, "")
        actions: list[FaultAction] = []
        seen: set[tuple[str, int]] = set()
        for tok in filter(None, (t.strip() for t in spec.split(","))):
            m = _FAULT_RE.fullmatch(tok)
            if not m:
                raise FaultPlanError(
                    f"unparseable fault token {tok!r}; expected "
                    f"kind@step[:arg] with kind in {'|'.join(_FAULT_KINDS)}",
                    token=tok, reason="syntax")
            kind, step, arg = m.group(1), int(m.group(2)), m.group(3)
            if kind not in _FAULT_KINDS:
                raise FaultPlanError(
                    f"unparseable fault token {tok!r}: unknown kind "
                    f"{kind!r} (known: {'|'.join(_FAULT_KINDS)})",
                    token=tok, reason="unknown-kind")
            if step < 0:
                raise FaultPlanError(
                    f"negative step in token {tok!r}: faults fire at "
                    "step indices >= 0", token=tok, reason="negative-step")
            if (kind, step) in seen:
                raise FaultPlanError(
                    f"duplicate {kind}@{step} (token {tok!r}): each verb "
                    "may fire at most once per step",
                    token=tok, reason="duplicate")
            seen.add((kind, step))
            actions.append(cls._parse_action(kind, step, arg, tok))
        return cls(actions)

    @staticmethod
    def _parse_action(kind: str, step: int, arg: str | None,
                      tok: str) -> FaultAction:
        def bad(msg, reason="bad-arg"):
            return FaultPlanError(f"{msg} (token {tok!r})", token=tok,
                                  reason=reason)

        count, host, factor = 1, None, 1.0
        if kind in ("kill", "stop", "nan"):
            if arg is not None:
                raise bad(f"{kind}@K takes no argument")
        elif kind == "iofail":
            try:
                count = int(arg) if arg else 1
            except ValueError:
                raise bad("iofail@K:N needs an integer failure count, "
                          f"got {arg!r}") from None
            if count < 1:
                raise bad(f"iofail@K:N needs N >= 1, got {count}")
            arg = None
        elif kind == "hostdown":
            if arg is None:
                raise bad("hostdown@K:h needs a host index",
                          reason="missing-host")
            try:
                host = int(arg)
            except ValueError:
                raise bad("hostdown@K:h needs an integer host index, "
                          f"got {arg!r}") from None
            arg = None
        elif kind == "hang":
            try:
                host = int(arg) if arg is not None else 0
            except ValueError:
                raise bad("hang@K[:h] needs an integer host index, "
                          f"got {arg!r}") from None
            arg = None
        elif kind == "slow":
            if arg is None:
                raise bad("slow@K:factor[:h] needs a slowdown factor",
                          reason="missing-factor")
            head, _, tail = arg.partition(":")
            try:
                factor = float(head)
                host = int(tail) if tail else 0
            except ValueError:
                raise bad("slow@K:factor[:h] needs a float factor and an "
                          f"optional integer host, got {arg!r}") from None
            if factor < 1.0:
                raise bad(f"slow factor must be >= 1.0, got {factor}")
            arg = None
        return FaultAction(kind, step, arg, count, host, factor, tok)

    def for_host(self, host_id: int, num_hosts: int) -> "FaultPlan":
        """The sub-plan host ``host_id`` of ``num_hosts`` executes.

        Validates every host-scoped token against the real host count
        (:class:`FaultPlanError` on out-of-range indices — the "unknown
        host" class of malformed spec that previously surfaced as a
        silent no-op) and keeps host-less actions (they apply to every
        host) plus the host-scoped ones targeting ``host_id``.
        """
        for a in self.actions:
            if a.host is not None and not (0 <= a.host < num_hosts):
                raise FaultPlanError(
                    f"host index {a.host} out of range for num_hosts="
                    f"{num_hosts} (token {a.token!r})", token=a.token,
                    reason="unknown-host")
        keep = tuple(a for a in self.actions
                     if a.host is None or a.host == host_id)
        return FaultPlan(keep, self.exit_code)

    def with_kill(self, step: int) -> "FaultPlan":
        """Legacy ``--simulate-failure K`` alias."""
        return FaultPlan(self.actions + (FaultAction("kill", step),),
                         self.exit_code)

    # ---- hooks the driver calls --------------------------------------
    def wants_nan(self, step: int) -> bool:
        return any(a.kind == "nan" and a.step == step for a in self.actions)

    def hang_before(self, step: int, *, sleep=time.sleep,
                    seconds: float = HANG_SECONDS) -> bool:
        """``hang@K`` hook, called at the TOP of step K (before compute):
        sleeps ``seconds`` so the process stays alive while its heartbeat
        step stops advancing — the hung-collective signature the
        supervisor's watchdog must detect.  Returns whether it fired."""
        if not any(a.kind == "hang" and a.step == step
                   for a in self.actions):
            return False
        print(f"[resilience] fault plan: hanging before step {step} "
              f"(sleep {seconds:.0f}s — simulated stuck collective)")
        sys.stdout.flush()
        sleep(seconds)
        return True

    def slow_factor(self, step: int) -> float:
        """Largest active ``slow@K:factor`` slowdown at ``step`` (1.0 =
        none).  The driver sleeps ``(factor - 1) * step_time`` after each
        step so the host becomes a measurable straggler."""
        return max((a.factor for a in self.actions
                    if a.kind == "slow" and step >= a.step), default=1.0)

    def poison_batch(self, batch: Pytree, step: int) -> Pytree:
        """NaN every float leaf of ``batch`` when a ``nan@step`` fires."""
        if not self.wants_nan(step):
            return batch
        import jax
        import jax.numpy as jnp

        print(f"[resilience] fault plan: poisoning step {step}'s batch "
              "with NaNs")
        return jax.tree.map(
            lambda x: jnp.full_like(x, jnp.nan)
            if jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact) else x,
            batch)

    def io_fault(self, step: int) -> None:
        """Checkpoint-save hook (``CheckpointManager(io_fault=...)``):
        raises a transient OSError while an ``iofail`` budget remains."""
        for i, a in enumerate(self.actions):
            if a.kind == "iofail" and step >= a.step \
                    and self._io_left.get(i, 0) > 0:
                self._io_left[i] -= 1
                raise OSError(
                    f"[faultplan] injected transient IO failure at step "
                    f"{step} ({self._io_left[i]} more to come)")

    def post_step(self, step: int, *, ckpt_dir: str | None = None,
                  flush=None) -> str | None:
        """Fire end-of-step actions; returns ``"stop"`` on a stop fault."""
        stop = False
        for a in self.actions:
            if a.step != step:
                continue
            if a.kind in ("corrupt", "truncate"):
                if flush is not None:
                    flush()
                if ckpt_dir:
                    what = corrupt_checkpoint(
                        ckpt_dir, shard=a.arg,
                        truncate=(a.kind == "truncate"))
                    print(f"[resilience] fault plan: {a.kind}d {what}")
            elif a.kind in ("kill", "hostdown"):
                if flush is not None:
                    flush()
                who = (f"host {a.host} down" if a.kind == "hostdown"
                       else "hard node failure")
                print(f"[resilience] fault plan: {who} after "
                      f"step {step} (os._exit({self.exit_code}))")
                sys.stdout.flush()
                os._exit(self.exit_code)
            elif a.kind == "stop":
                # like kill, a stop "dies" only between checkpoint writes:
                # flush the in-flight save so the drill's recovery point
                # is deterministic
                if flush is not None:
                    flush()
                stop = True
        return "stop" if stop else None


def corrupt_checkpoint(directory: str, *, step: int | None = None,
                       shard: str | None = None,
                       truncate: bool = False) -> str:
    """Flip one byte in (or truncate) a shard of the newest complete
    checkpoint — the mutation the SHA-256 verification must catch."""
    from repro.checkpoint.store import complete_steps, read_manifest

    if step is None:
        steps = complete_steps(directory)
        if not steps:
            raise FileNotFoundError(
                f"no complete checkpoint under {directory} to corrupt")
        step = steps[-1]
    man = read_manifest(directory, step)
    names = man["shards"]
    name = shard if shard is not None else names[0]
    if not name.endswith(".npz"):
        name += ".npz"
    if name not in names:
        raise ValueError(f"shard {name!r} not in step {step}'s manifest "
                         f"({names})")
    path = os.path.join(directory, f"step_{step:09d}", name)
    if truncate:
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size // 2)
        return f"{path} (truncated {size} -> {size // 2} bytes)"
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.seek(size // 2)
        b = f.read(1)
        f.seek(size // 2)
        f.write(bytes([b[0] ^ 0xFF]))
    return f"{path} (flipped byte {size // 2})"


# ===========================================================================
# Non-finite gradient guard
# ===========================================================================

def all_finite(*trees) -> Any:
    """Scalar bool: every inexact leaf of every tree is finite (traceable)."""
    import jax
    import jax.numpy as jnp

    ok = jnp.bool_(True)
    for leaf in jax.tree.leaves(trees):
        leaf = jnp.asarray(leaf)
        if jnp.issubdtype(leaf.dtype, jnp.inexact):
            ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(leaf)))
    return ok


class GradGuardEscalation(RuntimeError):
    """Raised when :class:`GradGuard`'s consecutive-skip budget is
    exhausted.  Subclasses ``RuntimeError`` so legacy callers that
    treated the exhausted budget as an abort keep working; drivers that
    opt into escalation (``launch/train.py --escalation rollback``)
    catch it and exit :data:`EXIT_ESCALATE`, which the supervisor turns
    into a rollback to the last verified-complete checkpoint."""

    def __init__(self, message: str, *, step: int, consecutive: int,
                 budget: int):
        self.step = step
        self.consecutive = consecutive
        self.budget = budget
        super().__init__(message)


class GradGuard:
    """Skip-and-log guard for non-finite updates.

    The step function skips the optimizer update when loss/grads contain
    non-finite values (``lax.cond`` on :func:`all_finite`); the host-side
    guard counts *consecutive* skipped steps and raises
    :class:`GradGuardEscalation` once they exceed ``budget`` — a single
    poisoned batch is survivable, a divergence or persistently bad data
    pipeline is not.  What happens next is the driver's escalation
    policy: abort (the default, and all the standalone driver can do) or
    roll back to the last-good checkpoint under a supervisor (the state
    that produced the NaN streak is *discarded*, not just frozen).
    """

    def __init__(self, budget: int = 3):
        self.budget = budget
        self.consecutive = 0
        self.skipped_total = 0

    def observe(self, finite: bool, step: int) -> bool:
        """Record one step's finite flag; returns whether it applied."""
        if finite:
            self.consecutive = 0
            return True
        self.consecutive += 1
        self.skipped_total += 1
        print(f"[resilience] non-finite loss/grads at step {step}: update "
              f"skipped ({self.consecutive}/{self.budget} consecutive)")
        if self.consecutive > self.budget:
            raise GradGuardEscalation(
                f"{self.consecutive} consecutive non-finite steps exceed "
                f"the skip budget ({self.budget}): aborting — bad data "
                "stream or diverged optimizer state",
                step=step, consecutive=self.consecutive,
                budget=self.budget)
        return False


# ===========================================================================
# Supervisor detection primitives: heartbeats, watchdog, stragglers
# ===========================================================================

@dataclasses.dataclass
class Heartbeat:
    """One worker's liveness/progress record, written atomically per step.

    ``step`` is the last COMPLETED step (-1 before the first), ``phase``
    one of ``init`` (process up, building/compiling), ``train`` (step
    loop running), ``ckpt`` (blocking checkpoint commit in progress),
    ``done`` (clean exit).  ``gen`` is the supervisor
    generation that launched the worker, so a monitor never confuses a
    stale file from a torn-down generation with a live worker.
    """
    host_id: int
    step: int
    phase: str = "init"             # init | train | ckpt | done
    t: float = 0.0                  # wall-clock at write (time.time())
    loss: float | None = None
    grad_norm: float | None = None
    step_s: float | None = None     # worker-measured duration of `step`
    pid: int | None = None
    gen: int = 0


def _heartbeat_path(directory: str, host_id: int) -> str:
    return os.path.join(directory, f"hb_h{host_id:05d}.json")


def write_heartbeat(directory: str, hb: Heartbeat) -> None:
    """Atomic (tmp + ``os.replace``) write — monitors never read a torn
    record.  Fills ``t``/``pid`` when unset."""
    os.makedirs(directory, exist_ok=True)
    if not hb.t:
        hb.t = time.time()
    if hb.pid is None:
        hb.pid = os.getpid()
    path = _heartbeat_path(directory, hb.host_id)
    tmp = os.path.join(directory, f".hb_h{hb.host_id:05d}.tmp{os.getpid()}")
    with open(tmp, "w") as f:
        json.dump(dataclasses.asdict(hb), f)
    os.replace(tmp, path)


def read_heartbeats(directory: str, *, gen: int | None = None
                    ) -> dict[int, Heartbeat]:
    """All readable heartbeats under ``directory`` keyed by host id.

    Unreadable/torn files are skipped (the next poll sees the replaced
    record); ``gen`` filters out stale records from earlier supervisor
    generations."""
    out: dict[int, Heartbeat] = {}
    if not os.path.isdir(directory):
        return out
    for name in os.listdir(directory):
        m = re.fullmatch(r"hb_h(\d+)\.json", name)
        if not m:
            continue
        try:
            with open(os.path.join(directory, name)) as f:
                doc = json.load(f)
            hb = Heartbeat(**doc)
        except (OSError, json.JSONDecodeError, TypeError):
            continue
        if gen is not None and hb.gen != gen:
            continue
        out[hb.host_id] = hb
    return out


class Watchdog:
    """Progress watchdog over per-host heartbeats.

    A host is judged on the age of its last *progress* (a heartbeat whose
    ``(phase, step)`` advanced), not of its last write — a hung collective
    leaves the process alive (and able to write) but its step frozen:

    - age > deadline                 -> ``suspect`` (a missed heartbeat);
    - age > deadline * miss_budget   -> ``hung`` (persistent stall).

    The deadline is ``startup_timeout`` until the host advances *past*
    its first ``train`` heartbeat and the tight ``stall_timeout``
    afterwards: jit compiles arbitrarily long before step 0, and the
    step in flight right after the first beat still carries residual
    warmup (lazy secondary compiles, persistent-cache serialisation), so
    both are judged leniently.  Hosts expected but never seen at all are
    judged from the watchdog's construction time against
    ``startup_timeout``.  Poll-rate independent: thresholds are wall
    -clock ages, not poll counts.
    """

    def __init__(self, hosts, *, stall_timeout: float = 10.0,
                 startup_timeout: float = 300.0, miss_budget: int = 3,
                 now: float | None = None):
        self.hosts = tuple(hosts)
        self.stall_timeout = float(stall_timeout)
        self.startup_timeout = float(startup_timeout)
        self.miss_budget = int(miss_budget)
        t0 = time.time() if now is None else now
        self._last: dict[int, tuple[str, int, float]] = {
            h: ("unseen", -2, t0) for h in self.hosts}
        self._first_train: dict[int, int] = {}

    def observe(self, heartbeats: dict[int, "Heartbeat"],
                now: float | None = None) -> None:
        now = time.time() if now is None else now
        for h, hb in heartbeats.items():
            if h not in self._last:
                continue
            if hb.phase == "train":
                self._first_train.setdefault(h, hb.step)
            phase, step, _ = self._last[h]
            if (hb.phase, hb.step) != (phase, step):
                self._last[h] = (hb.phase, hb.step, now)

    def _deadline(self, host: int) -> float:
        phase, step, _ = self._last[host]
        if phase in ("ckpt", "done"):
            return self.stall_timeout
        if phase == "train" and step != self._first_train.get(host):
            return self.stall_timeout
        # init / unseen, or sitting on the first train step (the next
        # step still pays jit warmup): lenient
        return self.startup_timeout

    def age(self, host: int, now: float | None = None) -> float:
        now = time.time() if now is None else now
        return now - self._last[host][2]

    def progress(self, host: int) -> tuple[str, int]:
        """Last observed (phase, step) progress point for ``host`` —
        what a supervisor uses to tell a ROOT hung host (least progress:
        it wedged the ring) from victims blocked on it further along."""
        phase, step, _ = self._last[host]
        return phase, step

    def check(self, now: float | None = None) -> dict[int, str]:
        """Per-host verdict: ``ok`` | ``suspect`` | ``hung`` (``done``
        once a clean final heartbeat landed)."""
        now = time.time() if now is None else now
        out: dict[int, str] = {}
        for h in self.hosts:
            phase, _, _ = self._last[h]
            if phase == "done":
                out[h] = "done"
                continue
            age, deadline = self.age(h, now), self._deadline(h)
            if age > deadline * self.miss_budget:
                out[h] = "hung"
            elif age > deadline:
                out[h] = "suspect"
            else:
                out[h] = "ok"
        return out


class StragglerDetector:
    """Flag hosts persistently slower than the cluster median step time.

    Duration samples prefer the worker-measured ``Heartbeat.step_s`` (a
    monitor starved of poll slots on a contended box observes beats in
    multi-step jumps — time-derived averages would wash a slowdown out
    against jit warmup), falling back to successive ``(step, t)`` pair
    deltas for writers that don't report it.  Each host keeps a rolling
    window and its median (p50) duration is compared against the median
    of the *other* hosts' medians: ratio >= ``factor`` sustained over
    ``patience`` completed steps flags the host (streaks are counted in
    steps advanced, not in observations, for the same sparse-poll
    reason).  Needs >= 2 hosts (a cluster of one has no peers to
    straggle behind).
    """

    def __init__(self, *, factor: float = 2.0, patience: int = 3,
                 window: int = 16):
        self.factor = float(factor)
        self.patience = int(patience)
        self.window = int(window)
        self._prev: dict[int, tuple[int, float]] = {}   # host -> (step, t)
        self._durs: dict[int, list[float]] = {}
        self._streak: dict[int, int] = {}

    def observe(self, heartbeats: dict[int, "Heartbeat"]) -> None:
        for h, hb in heartbeats.items():
            if hb.phase != "train" or hb.step < 0:
                continue
            prev = self._prev.get(h)
            self._prev[h] = (hb.step, hb.t)
            if prev is None or hb.step <= prev[0]:
                continue
            advanced = hb.step - prev[0]
            dur = (hb.step_s if hb.step_s is not None
                   else (hb.t - prev[1]) / advanced)
            durs = self._durs.setdefault(h, [])
            durs.append(dur)
            del durs[:-self.window]
            ratio = self._ratio(h)
            self._streak[h] = (self._streak.get(h, 0) + advanced
                               if ratio >= self.factor else 0)

    def _ratio(self, host: int) -> float:
        mine = self._durs.get(host)
        peers = [float(np.median(d)) for h, d in self._durs.items()
                 if h != host and d]
        if not mine or not peers:
            return 0.0
        p50 = float(np.median(peers))
        return float(np.median(mine)) / p50 if p50 > 0 else 0.0

    def stragglers(self) -> dict[int, float]:
        """Hosts flagged ``patience`` consecutive steps -> slowdown ratio."""
        return {h: self._ratio(h) for h, n in self._streak.items()
                if n >= self.patience}
