"""Elastic fault tolerance for the compiled pipeline.

Three pieces make crash/kill/shrink recovery a first-class property of
the ``auto_pipeline`` path:

1. **Plan state-specs + fingerprints.**  :func:`compiled_state_spec`
   serializes everything that determines how a
   :class:`~repro.runtime.compile.CompiledPipeline`'s training state is
   laid out at rest — partition cuts, stage->device map, the
   :class:`~repro.runtime.compile.StageLayout` slot/count/pad tables —
   and :func:`plan_fingerprint` hashes the layout-relevant subset.  The
   spec rides in every checkpoint manifest (``checkpoint.store``), so a
   restore knows exactly which plan wrote the bytes it is reading.
   ``M``/``wire_dtype``/``dp``/``zero_stage`` are recorded for
   observability but excluded from the fingerprint: ``jax.device_get``
   reassembles ZeRO-sharded stacks into full logical arrays before the
   write, so the at-rest format only depends on the stacking layout.

2. **Elastic restore.**  When the restore-time plan differs (fewer
   devices after a node loss, a different P/V from a re-run of the
   tuner), :func:`state_to_logical` de-stacks the saved ``[D, V, pad,
   ...]`` stage stacks through the *saved* layout spec back to the
   model's flat block stacks (pure numpy — no jax mesh needed for the
   old plan), and :func:`logical_to_state` re-stacks them onto the new
   plan via its own ``StageLayout.split``.  AdamW state mirrors params
   leaf-wise, so the same mapping applies to ``m``/``v``.
   :func:`restore_training_state` orchestrates: fast path when
   fingerprints match, destack/restack when they don't.

3. **Fault injection + a NaN guard.**  :class:`FaultPlan` parses an
   env/flag-driven fault script (``kill@K``, ``stop@K``, ``nan@K``,
   ``corrupt@K[:shard]``, ``truncate@K[:shard]``, ``iofail@K:N``) that
   the training driver (``launch/train.py``) consults each step, and
   :class:`GradGuard` is the skip-and-log guard for non-finite
   grads with a bounded consecutive-skip budget.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import sys
from typing import Any

import numpy as np

Pytree = Any

STATE_SPEC_SCHEMA = "repro.state-spec/v1"

#: spec keys that determine the at-rest array layout (and hence whether a
#: saved checkpoint can be loaded directly or must be de-/re-stacked).
_FINGERPRINT_FIELDS = ("P", "V", "folded", "cuts", "devices",
                       "num_param_stacks", "enc_slots", "dec_slots",
                       "enc_counts", "dec_counts", "enc_pad", "dec_pad")


def plan_fingerprint(spec: dict) -> str:
    """Stable 16-hex-digit digest of a state spec's layout fields.

    Computed over the canonical JSON of :data:`_FINGERPRINT_FIELDS` only,
    so it is identical whether the spec came fresh off a plan (tuples)
    or round-tripped through a manifest (lists).
    """
    doc = {k: spec[k] for k in _FINGERPRINT_FIELDS}
    blob = json.dumps(doc, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def compiled_state_spec(plan) -> dict:
    """JSON-serializable layout spec for a CompiledPipeline's state."""
    part, lay, pcfg = plan.partition, plan.layout, plan.pcfg
    spec = {
        "schema": STATE_SPEC_SCHEMA,
        "P": int(part.num_devices),
        "S": int(part.num_stages),
        "V": int(lay.V),
        "folded": bool(part.folded),
        "cuts": [int(c) for c in part.cuts],
        "devices": [int(d) for d in part.devices],
        "dp": int(pcfg.dp_size),
        "zero_stage": int(pcfg.zero_stage),
        "M": int(pcfg.num_microbatches),
        "wire_dtype": str(pcfg.wire_dtype),
        "num_param_stacks": int(plan.model_fns.num_param_stacks),
        "enc_slots": [[int(s) for s in ss] for ss in lay.enc_slots],
        "dec_slots": [[int(s) for s in ss] for ss in lay.dec_slots],
        "enc_counts": [[int(c) for c in cc] for cc in lay.enc_counts],
        "dec_counts": [[int(c) for c in cc] for cc in lay.dec_counts],
        "enc_pad": int(lay.enc_pad),
        "dec_pad": int(lay.dec_pad),
    }
    spec["fingerprint"] = plan_fingerprint(spec)
    return spec


# ===========================================================================
# Elastic de-stack / re-stack
# ===========================================================================

def _spec_enc_ranges(spec: dict) -> list:
    cuts = spec["cuts"]
    return [[(cuts[s], cuts[s + 1]) for s in ss]
            for ss in spec["enc_slots"]]


def _spec_dec_ranges(spec: dict) -> list:
    cuts = spec["cuts"]
    mid = cuts[(len(cuts) - 1) // 2]
    return [[(cuts[s] - mid, cuts[s + 1] - mid) for s in ss]
            for ss in spec["dec_slots"]]


def _destack(stacked: Pytree, ranges: list) -> Pytree:
    """Numpy port of ``StageLayout._unstack`` driven by a serialized spec:
    ``[D, V, pad, ...]`` stage stacks -> flat block stack in graph order."""
    import jax

    order = sorted(((d, v) for d in range(len(ranges))
                    for v in range(len(ranges[d]))),
                   key=lambda dv: ranges[dv[0]][dv[1]][0])

    def f(x):
        x = np.asarray(x)
        parts = [x[d, v, : ranges[d][v][1] - ranges[d][v][0]]
                 for d, v in order]
        return np.concatenate(parts, 0)

    return jax.tree.map(f, stacked)


def destack_stage_stacks(stage_stacks: tuple, spec: dict) -> tuple:
    """Saved per-(device, slot) stage stacks -> the model's logical block
    stacks, through the *saved* plan's layout spec."""
    import jax

    if not spec["folded"]:
        return (_destack(stage_stacks[0], _spec_enc_ranges(spec)),)
    enc_b = _destack(stage_stacks[0], _spec_enc_ranges(spec))
    dec_b = _destack(stage_stacks[1], _spec_dec_ranges(spec))
    if spec["num_param_stacks"] == 1:
        return (jax.tree.map(lambda a, b: np.concatenate([a, b], 0),
                             enc_b, dec_b),)
    return (enc_b, dec_b)


def state_to_logical(state: dict, spec: dict) -> dict:
    """Training state saved under ``spec`` -> plan-independent logical view.

    ``state`` is the tree ``launch/train.py`` checkpoints: ``{"params":
    (stage_stacks, edge), "opt": {"m": ..., "v": ..., "step": ...}}``
    where AdamW's ``m``/``v`` mirror ``params`` leaf-wise.
    """
    def conv(pt):
        stacks, edge = pt
        return {"stacks": destack_stage_stacks(tuple(stacks), spec),
                "edge": edge}

    out = {"params": conv(state["params"])}
    if state.get("opt") is not None:
        o = state["opt"]
        out["opt"] = {"m": conv(o["m"]), "v": conv(o["v"]), "step": o["step"]}
    return out


def logical_to_state(logical: dict, plan) -> dict:
    """Inverse of :func:`state_to_logical`, onto the *new* plan."""
    def conv(d):
        return (plan.layout.split(tuple(d["stacks"])), d["edge"])

    state = {"params": conv(logical["params"])}
    if logical.get("opt") is not None:
        o = logical["opt"]
        state["opt"] = {"m": conv(o["m"]), "v": conv(o["v"]),
                        "step": o["step"]}
    return state


@dataclasses.dataclass(frozen=True)
class RestoreInfo:
    """What :func:`restore_training_state` did."""
    step: int                       # checkpoint step restored
    elastic: bool                   # True when saved plan != current plan
    saved_fingerprint: str | None
    fingerprint: str


def restore_training_state(directory: str, plan, like_state: dict, *,
                           step: int | None = None,
                           strict: bool = True) -> tuple[dict, RestoreInfo]:
    """Restore training state for ``plan``, elastically if needed.

    Loads the newest fully-verified checkpoint (``strict=False`` falls
    back past corrupt/partial steps), then compares the manifest's saved
    state spec against ``plan``'s: identical fingerprints load directly
    (the pytree topology is plan-invariant — only leaf shapes differ);
    different fingerprints route through the logical view
    (:func:`state_to_logical` with the *saved* spec, then
    :func:`logical_to_state` onto ``plan``).
    """
    from repro.checkpoint.store import (CheckpointError, read_manifest,
                                        restore_checkpoint)

    state, got = restore_checkpoint(directory, like_state, step=step,
                                    strict=strict, expect_shapes=False)
    man = read_manifest(directory, got)
    saved = man.get("plan")
    if saved is None:
        raise CheckpointError(
            "checkpoint carries no plan state-spec; cannot verify it "
            "matches the compiled pipeline (save through "
            "CheckpointManager(..., plan=compiled.state_spec()))",
            step=got, reason="no-plan-spec")
    cur = compiled_state_spec(plan)
    if saved["fingerprint"] == cur["fingerprint"]:
        return state, RestoreInfo(got, False, saved["fingerprint"],
                                  cur["fingerprint"])
    print(f"[resilience] plan changed since step {got} "
          f"({saved['fingerprint']} -> {cur['fingerprint']}): de-stacking "
          f"P={saved['P']} V={saved['V']} dp={saved['dp']} "
          f"zero={saved['zero_stage']} state onto P={cur['P']} V={cur['V']} "
          f"dp={cur['dp']} zero={cur['zero_stage']}")
    logical = state_to_logical(state, saved)
    return logical_to_state(logical, plan), RestoreInfo(
        got, True, saved["fingerprint"], cur["fingerprint"])


# ===========================================================================
# Fault injection
# ===========================================================================

_FAULT_RE = re.compile(
    r"(kill|stop|nan|corrupt|truncate|iofail)@(\d+)(?::([\w.\-]+))?")


@dataclasses.dataclass(frozen=True)
class FaultAction:
    kind: str            # kill | stop | nan | corrupt | truncate | iofail
    step: int
    arg: str | None = None   # corrupt/truncate: shard name
    count: int = 1           # iofail: number of injected IO failures


class FaultPlan:
    """Env/flag-driven fault script for the training driver.

    Comma-separated tokens, each ``kind@step`` with an optional arg:

    - ``kill@K``      — hard-kill the process (``os._exit``) after step K,
      flushing any in-flight checkpoint first (a node dies between steps);
    - ``stop@K``      — abrupt in-process stop after step K, *without* a
      final save (same recovery surface as kill, usable by in-process
      drills);
    - ``nan@K``       — poison step K's batch with NaNs, so the step's
      grads go non-finite and the :class:`GradGuard` path runs;
    - ``corrupt@K[:shard]``  — after step K, flip one byte in the named
      (default: first) shard of the newest complete checkpoint;
    - ``truncate@K[:shard]`` — same, but truncate the shard to half;
    - ``iofail@K:N``  — the next N checkpoint-save attempts at/after
      step K raise a transient ``OSError`` (exercises the manager's
      retry/backoff path).

    Source: the ``--faults`` flag, else the ``REPRO_FAULTS`` env var.
    """

    def __init__(self, actions=(), exit_code: int = 42):
        self.actions: tuple[FaultAction, ...] = tuple(actions)
        self.exit_code = exit_code
        self._io_left = {i: a.count for i, a in enumerate(self.actions)
                         if a.kind == "iofail"}

    @classmethod
    def parse(cls, spec: str | None = None, *,
              env: str = "REPRO_FAULTS") -> "FaultPlan":
        if spec is None:
            spec = os.environ.get(env, "")
        actions = []
        for tok in filter(None, (t.strip() for t in spec.split(","))):
            m = _FAULT_RE.fullmatch(tok)
            if not m:
                raise ValueError(
                    f"unparseable fault token {tok!r}; expected "
                    "kind@step[:arg] with kind in kill|stop|nan|corrupt|"
                    "truncate|iofail")
            kind, step, arg = m.group(1), int(m.group(2)), m.group(3)
            count = 1
            if kind == "iofail":
                count, arg = (int(arg) if arg else 1), None
            actions.append(FaultAction(kind, step, arg, count))
        return cls(actions)

    def with_kill(self, step: int) -> "FaultPlan":
        """Legacy ``--simulate-failure K`` alias."""
        return FaultPlan(self.actions + (FaultAction("kill", step),),
                         self.exit_code)

    # ---- hooks the driver calls --------------------------------------
    def wants_nan(self, step: int) -> bool:
        return any(a.kind == "nan" and a.step == step for a in self.actions)

    def poison_batch(self, batch: Pytree, step: int) -> Pytree:
        """NaN every float leaf of ``batch`` when a ``nan@step`` fires."""
        if not self.wants_nan(step):
            return batch
        import jax
        import jax.numpy as jnp

        print(f"[resilience] fault plan: poisoning step {step}'s batch "
              "with NaNs")
        return jax.tree.map(
            lambda x: jnp.full_like(x, jnp.nan)
            if jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact) else x,
            batch)

    def io_fault(self, step: int) -> None:
        """Checkpoint-save hook (``CheckpointManager(io_fault=...)``):
        raises a transient OSError while an ``iofail`` budget remains."""
        for i, a in enumerate(self.actions):
            if a.kind == "iofail" and step >= a.step \
                    and self._io_left.get(i, 0) > 0:
                self._io_left[i] -= 1
                raise OSError(
                    f"[faultplan] injected transient IO failure at step "
                    f"{step} ({self._io_left[i]} more to come)")

    def post_step(self, step: int, *, ckpt_dir: str | None = None,
                  flush=None) -> str | None:
        """Fire end-of-step actions; returns ``"stop"`` on a stop fault."""
        stop = False
        for a in self.actions:
            if a.step != step:
                continue
            if a.kind in ("corrupt", "truncate"):
                if flush is not None:
                    flush()
                if ckpt_dir:
                    what = corrupt_checkpoint(
                        ckpt_dir, shard=a.arg,
                        truncate=(a.kind == "truncate"))
                    print(f"[resilience] fault plan: {a.kind}d {what}")
            elif a.kind == "kill":
                if flush is not None:
                    flush()
                print(f"[resilience] fault plan: hard node failure after "
                      f"step {step} (os._exit({self.exit_code}))")
                sys.stdout.flush()
                os._exit(self.exit_code)
            elif a.kind == "stop":
                # like kill, a stop "dies" only between checkpoint writes:
                # flush the in-flight save so the drill's recovery point
                # is deterministic
                if flush is not None:
                    flush()
                stop = True
        return "stop" if stop else None


def corrupt_checkpoint(directory: str, *, step: int | None = None,
                       shard: str | None = None,
                       truncate: bool = False) -> str:
    """Flip one byte in (or truncate) a shard of the newest complete
    checkpoint — the mutation the SHA-256 verification must catch."""
    from repro.checkpoint.store import complete_steps, read_manifest

    if step is None:
        steps = complete_steps(directory)
        if not steps:
            raise FileNotFoundError(
                f"no complete checkpoint under {directory} to corrupt")
        step = steps[-1]
    man = read_manifest(directory, step)
    names = man["shards"]
    name = shard if shard is not None else names[0]
    if not name.endswith(".npz"):
        name += ".npz"
    if name not in names:
        raise ValueError(f"shard {name!r} not in step {step}'s manifest "
                         f"({names})")
    path = os.path.join(directory, f"step_{step:09d}", name)
    if truncate:
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size // 2)
        return f"{path} (truncated {size} -> {size // 2} bytes)"
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.seek(size // 2)
        b = f.read(1)
        f.seek(size // 2)
        f.write(bytes([b[0] ^ 0xFF]))
    return f"{path} (flipped byte {size // 2})"


# ===========================================================================
# Non-finite gradient guard
# ===========================================================================

def all_finite(*trees) -> Any:
    """Scalar bool: every inexact leaf of every tree is finite (traceable)."""
    import jax
    import jax.numpy as jnp

    ok = jnp.bool_(True)
    for leaf in jax.tree.leaves(trees):
        leaf = jnp.asarray(leaf)
        if jnp.issubdtype(leaf.dtype, jnp.inexact):
            ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(leaf)))
    return ok


class GradGuard:
    """Skip-and-log guard for non-finite updates.

    The step function skips the optimizer update when loss/grads contain
    non-finite values (``lax.cond`` on :func:`all_finite`); the host-side
    guard counts *consecutive* skipped steps and aborts once they exceed
    ``budget`` — a single poisoned batch is survivable, a divergence or
    persistently bad data pipeline is not.
    """

    def __init__(self, budget: int = 3):
        self.budget = budget
        self.consecutive = 0
        self.skipped_total = 0

    def observe(self, finite: bool, step: int) -> bool:
        """Record one step's finite flag; returns whether it applied."""
        if finite:
            self.consecutive = 0
            return True
        self.consecutive += 1
        self.skipped_total += 1
        print(f"[resilience] non-finite loss/grads at step {step}: update "
              f"skipped ({self.consecutive}/{self.budget} consecutive)")
        if self.consecutive > self.budget:
            raise RuntimeError(
                f"{self.consecutive} consecutive non-finite steps exceed "
                f"the skip budget ({self.budget}): aborting — bad data "
                "stream or diverged optimizer state")
        return False
