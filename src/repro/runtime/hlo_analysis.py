"""Compiled-HLO analysis: collective bytes, per-op breakdowns, roofline terms.

``collective_bytes`` parses an HLO module's text (from ``lowered.as_text()``
or ``compiled.as_text()``; both the classic HLO and StableHLO syntaxes are
recognized) and sums the output-shape bytes of every collective op, grouped
by kind.  Notes:

- Ops inside ``while`` bodies are counted ONCE (XLA emits the body once);
  callers that know the trip structure (pipeline ticks, layer scans) must
  scale accordingly — the roofline harness reconstructs totals by compiling
  probe configs with trip counts {1, 2} and extrapolating linearly, which is
  exact for loop-invariant bodies.
- For all-reduce, bytes are counted once (output size); ring implementations
  move ~2x(N-1)/N of that per device — the roofline model applies the ring
  factor separately.
- Wire-format measurements (the bf16 boundary hops of the table executors)
  must parse the *lowered* module: XLA's CPU float-normalization pass
  legalizes sub-fp32 collectives by upcasting them, so ``compiled.as_text()``
  on host-simulated devices reports fp32 shapes that a real TPU/GPU (whose
  collectives move bf16 natively) never pays.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.:  %cp.1 = bf16[1,16,128]{2,1,0} collective-permute(%x), ...
#        ROOT %tuple = (f32[4], f32[4]) all-to-all(...)
_OP_RE = re.compile(
    r"=\s*(?P<shape>\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(?P<kind>all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")

_SHAPE_RE = re.compile(r"(?P<dt>[a-z][a-z0-9]*)\[(?P<dims>[0-9,]*)\]")

# StableHLO:  %71 = "stablehlo.collective_permute"(%70) <{...}>
#             : (tensor<1x18x32xbf16>) -> tensor<1x18x32xbf16>
# Region-bearing collectives (all_reduce, reduce_scatter) carry their
# reduction computation in a `({ ... })` block, so the op name and the
# result type sit on DIFFERENT lines — and the region body's own ops have
# `->` type signatures that must not be mistaken for the collective's.
# _iter_stablehlo_collectives therefore scans line-wise and, for a
# region-bearing op, takes the type signature from the region's closing
# `}) : (...) -> ...` line.
_STABLEHLO_NAME_RE = re.compile(
    r"\"stablehlo\.(?P<kind>all_gather|all_reduce|reduce_scatter|"
    r"all_to_all|collective_permute)\"")

_STABLEHLO_TENSOR_RE = re.compile(
    r"tensor<(?P<dims>(?:[0-9]+x)*)(?P<dt>[a-z][a-z0-9]*)>")


def _iter_stablehlo_collectives(hlo_text: str):
    """Yield (kind, result-type string) for every StableHLO collective."""
    lines = hlo_text.splitlines()
    for i, line in enumerate(lines):
        m = _STABLEHLO_NAME_RE.search(line)
        if m is None:
            continue
        sig = line if "->" in line else None
        if sig is None:
            for j in range(i + 1, len(lines)):
                if lines[j].lstrip().startswith("})") and "->" in lines[j]:
                    sig = lines[j]
                    break
        if sig is not None:
            yield m.group("kind"), sig.rsplit("->", 1)[1]


def _stablehlo_shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _STABLEHLO_TENSOR_RE.finditer(shape_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group("dims").split("x"):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def __str__(self):
        parts = [f"{k}: {v/1e6:.2f}MB x{self.count_by_kind[k]}"
                 for k, v in sorted(self.bytes_by_kind.items())]
        return "; ".join(parts) or "none"


def collective_bytes(hlo_text: str) -> CollectiveStats:
    by_kind: dict = defaultdict(int)
    cnt: dict = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        kind = m.group("kind").replace("-start", "")
        b = _shape_bytes(m.group("shape"))
        by_kind[kind] += b
        cnt[kind] += 1
    for kind, shape in _iter_stablehlo_collectives(hlo_text):
        by_kind[kind.replace("_", "-")] += _stablehlo_shape_bytes(shape)
        cnt[kind.replace("_", "-")] += 1
    return CollectiveStats(dict(by_kind), dict(cnt))


def cost_summary(compiled) -> dict:
    """flops / bytes accessed from compiled.cost_analysis() (may be
    per-partition depending on backend; treat relatively)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
    }


def memory_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        out[k] = getattr(ma, k, None)
    return out
