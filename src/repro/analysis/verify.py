"""``python -m repro.analysis.verify`` — certify plans offline.

Re-synthesizes the tier-1 example plans (or loads saved plan snapshots)
and runs the full static dataflow proof on each lowering, printing one
summary line per certificate and exiting non-zero if any plan fails:

    python -m repro.analysis.verify                    # all tier-1 configs
    python -m repro.analysis.verify hunyuan32          # one config
    python -m repro.analysis.verify --plan plan.json   # saved snapshot
    python -m repro.analysis.verify --use-ilp          # + ILP plans (slow)

Per config the matrix covers every synthesis path ``auto_pipeline`` can
ship — the unit-slot greedy, the duration-aware timed greedy in every
priority orientation, and the portfolio pick — for V in {1, 2, 4}
(infeasible interleave degrees are skipped) and both hop lowerings
(``overlap`` on/off).  ``--use-ilp`` adds the exact ILP synthesis at
V = 1, where HiGHS stays tractable — the nightly job passes it.

Plan construction needs the scheduler (and the jax-backed lowering), so
those imports are deferred; re-certifying a ``--plan`` snapshot stays
numpy-only end to end.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.certificate import (PlanCertificate, certify_tables,
                                        export_plan, load_plan)

TIER1_CONFIGS = ("sdv2unet29", "skipvit26", "hunyuan32")
INTERLEAVE_DEGREES = (1, 2, 4)


def tier1_graph(name: str):
    """(BlockGraph, pipeline device count) for a tier-1 config name.

    Mirrors the benchmark harness (``benchmarks/auto_pipeline.py``) so CI
    certifies exactly the plans the paper-metric tables report.
    """
    if name == "sdv2unet29":
        from repro.configs import sdv2_unet
        from repro.models.diffusion import unet_block_graph
        return unet_block_graph(sdv2_unet.CFG, batch=1), 4
    if name == "skipvit26":
        import random
        from repro.models.diffusion import (SkipViTConfig,
                                            skipvit_pipeline_graph)
        rnd = random.Random(0)
        cfg = SkipViTConfig("b", n_enc=12, n_mid=2, n_dec=12)
        return skipvit_pipeline_graph(
            cfg, fwd_times=[rnd.uniform(0.5, 3.0) for _ in range(26)]), 4
    if name == "hunyuan32":
        from repro.configs import hunyuan_dit
        return hunyuan_dit.pipeline_graph(), 4
    raise ValueError(
        f"unknown config {name!r}; expected one of {TIER1_CONFIGS} "
        "(or pass --plan for a saved snapshot)")


def _synthesize(part, M: int, *, use_ilp: bool, time_limit: float):
    """name -> validated Schedule, every synthesis path we ship."""
    from repro.core.schedule import (TIMED_PRIORITIES, greedy_schedule,
                                     greedy_schedule_timed, ilp_schedule,
                                     schedule_for_partition,
                                     validate_schedule)
    S, D = part.num_stages, part.num_devices
    times = getattr(part, "stage_costs", None) or (1.0,) * S
    scheds = {"greedy": greedy_schedule(S, M, part.device_of_stage, D)}
    for prio in TIMED_PRIORITIES:
        scheds[f"timed-{prio}"] = greedy_schedule_timed(
            S, M, part.device_of_stage, D, times, priority=prio)
    scheds["portfolio"] = schedule_for_partition(part, M)
    if use_ilp and S <= 2 * D:      # V = 1: where HiGHS stays tractable
        scheds["ilp"] = schedule_for_partition(part, M, use_ilp=True,
                                               time_limit=time_limit)
    for name, sched in scheds.items():
        errors = validate_schedule(sched, part.device_of_stage,
                                   collocated=part.collocated_pairs(),
                                   folded=getattr(part, "folded", False))
        if errors:
            raise ValueError(f"{name} synthesis produced an invalid "
                             f"schedule: {errors[:3]}")
    return scheds


def certify_config(name: str, *, use_ilp: bool = False,
                   time_limit: float = 120.0, export_dir=None,
                   zero: bool = False) -> list[PlanCertificate]:
    """Certify every (synthesis, V, overlap) plan for one tier-1 config.

    Every run also certifies at least one hybrid (dp=2) plan per graph —
    the per-replica dataflow proof is unchanged, but the certificate
    records the (dp, zero_stage) dimensions the executor would run with.
    ``zero`` (nightly) adds the ZeRO-2 rest-sharded variant.
    """
    from repro.core.partition import partition
    from repro.runtime.compile import StageLayout
    from repro.runtime.schedule_exec import StepTables
    graph, D = tier1_graph(name)
    M = 2 * D
    certs: list[PlanCertificate] = []
    for V in INTERLEAVE_DEGREES:
        try:
            part = partition(graph, D, lam=0.0, interleave=V)
        except ValueError as e:
            print(f"skip {name} V={V}: {e}", file=sys.stderr)
            continue
        consumers = (StageLayout.from_partition(part, graph)
                     .skip_consumers() if part.folded else None)
        for synth, sched in _synthesize(part, M, use_ilp=use_ilp,
                                        time_limit=time_limit).items():
            tabs = StepTables.from_schedule(
                sched, folded=part.folded, devices=part.devices,
                skip_consumers=consumers)
            for overlap in (True, False):
                tag = (f"{name}/v{V}/{synth}/"
                       f"{'overlap' if overlap else 'sync'}")
                certs.append(certify_tables(
                    tabs, skip_consumers=consumers, overlap=overlap,
                    name=tag))
            if synth == "portfolio" and V == 1:
                for z in ((1, 2) if zero else (1,)):
                    certs.append(certify_tables(
                        tabs, skip_consumers=consumers, overlap=True,
                        dp=2, zero_stage=z,
                        name=f"{name}/v1/portfolio/dp2-zero{z}"))
                if export_dir is not None:
                    export_plan(tabs,
                                export_dir / f"{name}_v1_portfolio_dp2.json",
                                skip_consumers=consumers, dp=2,
                                zero_stage=2 if zero else 1,
                                name=f"{name}/v1/portfolio/dp2")
            if export_dir is not None:
                path = export_dir / f"{name}_v{V}_{synth}.json"
                export_plan(tabs, path, skip_consumers=consumers,
                            name=f"{name}/v{V}/{synth}")
    return certs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.verify",
        description="statically certify lowered pipeline plans")
    ap.add_argument("configs", nargs="*",
                    help=f"tier-1 config names (default: all of "
                         f"{', '.join(TIER1_CONFIGS)})")
    ap.add_argument("--plan", action="append", default=[],
                    metavar="FILE",
                    help="certify a saved plan snapshot (export_plan "
                         "JSON) instead of re-synthesizing")
    ap.add_argument("--use-ilp", action="store_true",
                    help="additionally certify exact-ILP plans (V=1)")
    ap.add_argument("--zero", action="store_true",
                    help="additionally certify ZeRO-2 hybrid (dp=2) "
                         "plan variants (nightly)")
    ap.add_argument("--time-limit", type=float, default=120.0,
                    help="ILP solver time limit in seconds")
    ap.add_argument("--export-dir", metavar="DIR",
                    help="also snapshot each lowered plan to DIR")
    ap.add_argument("--json", metavar="FILE", dest="json_out",
                    help="write all certificates to FILE as JSON")
    args = ap.parse_args(argv)

    export_dir = None
    if args.export_dir:
        import pathlib
        export_dir = pathlib.Path(args.export_dir)
        export_dir.mkdir(parents=True, exist_ok=True)

    certs: list[PlanCertificate] = []
    for path in args.plan:
        saved = load_plan(path)
        cert = saved.certify()
        certs.append(cert if cert.name else
                     PlanCertificate(**{**cert.__dict__, "name": path}))
    if not args.plan or args.configs:
        for name in (args.configs or TIER1_CONFIGS):
            certs.extend(certify_config(
                name, use_ilp=args.use_ilp, time_limit=args.time_limit,
                export_dir=export_dir, zero=args.zero))

    for cert in certs:
        print(cert.summary())
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump([c.to_dict() for c in certs], fh, indent=2,
                      sort_keys=True)
    bad = [c for c in certs if not c.ok]
    print(f"{len(certs) - len(bad)}/{len(certs)} plans certified clean")
    return 1 if bad or not certs else 0


if __name__ == "__main__":
    sys.exit(main())
