"""Abstract interpretation of lowered step tables (race/deadlock proofs).

The table executors (``runtime.schedule_exec``) are scan bodies driven
entirely by host-precomputed per-device arrays: which task runs each step,
which rotating-buffer slot each arrival is stored into, which slot each
task reads, which hops carry a message.  That makes them *statically
verifiable*: this module replays the device programs symbolically — no
jax, no execution — moving abstract tokens through the ring registers and
the rotating ``W_down``/``W_up``/``W_turn``/``W_skip`` buffers in exactly
the executor's phase order (arrivals stored at the top of the body, the
running task's reads next, turn/skip/wire writes last), and checks:

- **no-live-overwrite** — no store lands in a slot whose current entry
  still has an unconsumed reader: arrivals into the rx buffers, turnaround
  writes, and skip-stash writes all prove their target slot dead first.
  This is the race certificate: it holds under the overlapped
  (``PipelineConfig.overlap``) lowering too, where step t's send is
  *issued* one scan iteration later but its arrival still lands at the
  top of step t+1's body, before that step's reads.
- **matched-store-read** — every buffer read is preceded by exactly one
  matching store: the slot is live, and the stored token's microbatch
  (and, for skip reads, encoder slot) equals what the consumer expects.
  Uninitialized-slot reads and stale-entry reads fail here.
- **send-recv-pairing** — ring hops pair across devices every step: a
  stored arrival on device d at step k requires the matching ring
  neighbour to have sent at step k-1, every sent message is stored by its
  receiver one hop later, and nothing is still in flight when the scan
  ends.  Together with device programs being loop-free per step this
  proves the hop ordering deadlock-free: messages only flow forward in
  step order, so a cyclic wait cannot form.
- **wire-dtype-flow** — values that cross a ring are wire-dtype tokens
  (cast-on-send) and every consumer of a ring slot upcasts on read, while
  device-local turnaround / skip-stash traffic stays in the compute dtype;
  a wire-dtype token reaching a compute-dtype read site (or vice versa)
  fails here.
- **buffer-bounds** — every store/read slot index lies inside the
  declared window, and the replayed peak occupancy per channel never
  exceeds it (the windows really are upper bounds on simultaneously-live
  entries — the memory-safety half of the proof).
- **no-lost-message** — every stored entry is eventually read (an unread
  arrival or stash entry means the liveness analysis kept a dead store —
  or a corrupted table dropped a consumer).
- **overlap-accounting** — the interpreter's own exposed/hidden hop
  counts (a hop is exposed when its consumer reads on the arrival step)
  equal the counts the lowering declared, holding the executor tables to
  the same split the planner's ``core.schedule.comm_stats`` mirrors.
- **program-shape** — structural sanity: table shapes agree, selector /
  microbatch / slot values are in range, each microbatch emits its loss
  exactly once, sends and buffer writes are attached to running tasks.

``interpret_tables`` runs the replay in BOTH hop lowerings (synchronous
send-at-bottom and overlapped send-at-top-of-next-body) and requires the
resulting store/read event streams to be identical — the overlapped
double-buffering may restructure *when* collectives are issued, never what
arrives where.

Everything here is deliberately independent of the lowering's own
interval-coloring machinery (the windows are *recomputed* by brute-force
occupancy counting, the pairing by actually carrying tokens around the
ring) so a bug in ``StepTables.from_schedule`` cannot certify itself.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Selector codes — must mirror runtime.schedule_exec (tested for equality
# in tests/test_plan_verify.py; redefined here so this module stays
# importable without jax).
IDLE, RUN_ENC, RUN_DEC = 0, 1, 2

#: Every check the interpreter runs, in report order.  A clean certificate
#: lists all of them with zero violations.
CHECKS = (
    "program-shape",
    "buffer-bounds",
    "send-recv-pairing",
    "no-live-overwrite",
    "matched-store-read",
    "wire-dtype-flow",
    "no-lost-message",
    "overlap-accounting",
    "overlap-equivalence",
)

# Abstract value dtypes riding the dataflow: ring payloads are cast to the
# wire dtype on send; turnaround and skip-stash entries stay in the
# compute dtype.  The interpreter tracks which kind each token is.
WIRE, COMPUTE = "wire", "compute"


@dataclasses.dataclass(frozen=True)
class Violation:
    """One failed static check, with enough context to locate it."""

    check: str                    # one of CHECKS
    detail: str
    device: int | None = None
    step: int | None = None      # compressed forward step index
    slot: int | None = None      # buffer slot / ring channel context

    def __str__(self) -> str:
        where = ", ".join(
            f"{k}={v}" for k, v in (("device", self.device),
                                    ("step", self.step),
                                    ("slot", self.slot)) if v is not None)
        return f"[{self.check}] {self.detail}" + (f" ({where})" if where
                                                  else "")


@dataclasses.dataclass(frozen=True)
class _Token:
    """Abstract value: who produced it, when, for which microbatch."""

    src_device: int
    src_step: int
    microbatch: int
    kind: str                    # WIRE | COMPUTE
    enc_slot: int = -1           # skip-stash entries only


class _Slot:
    """One rotating-buffer slot: empty, or holding a token with a
    remaining-reader count (rx/turn entries have exactly one reader; a
    skip entry may be read several times and dies at its last read)."""

    __slots__ = ("token", "reads", "stored_at")

    def __init__(self):
        self.token: _Token | None = None
        self.reads = 0
        self.stored_at = -1


@dataclasses.dataclass(frozen=True)
class DataflowReport:
    """Everything ``interpret_tables`` proved (or failed to prove)."""

    violations: tuple[Violation, ...]
    # replayed peak occupancy per channel (max simultaneously-live entries
    # across devices) — the independent proof behind the declared windows
    peak_down: int
    peak_up: int
    peak_turn: int
    peak_skip: int
    # independently recounted hop classification
    exposed_down: int
    exposed_up: int
    live_down: int
    live_up: int

    @property
    def ok(self) -> bool:
        return not self.violations

    def by_check(self) -> dict[str, list[Violation]]:
        out: dict[str, list[Violation]] = {name: [] for name in CHECKS}
        for v in self.violations:
            out.setdefault(v.check, []).append(v)
        return out

    def failed_checks(self) -> tuple[str, ...]:
        return tuple(name for name, vs in self.by_check().items() if vs)


def _shape_check(tabs, errs: list[Violation]) -> bool:
    """Structural sanity; returns False when the replay cannot proceed."""
    D = int(tabs.D)
    arrays_2d = ("sel", "slot", "mb", "down_mb", "down_valid", "up_mb",
                 "up_valid", "loss", "embed", "turn_rd", "turn_wr",
                 "down_send", "up_send", "down_slot", "up_slot", "rx_slot",
                 "turn_wr_slot", "turn_rd_slot", "skip_wr", "skip_wr_slot")
    shapes = {name: np.asarray(getattr(tabs, name)).shape
              for name in arrays_2d}
    T = shapes["sel"][1] if len(shapes["sel"]) == 2 else -1
    for name, shape in shapes.items():
        if shape != (D, T):
            errs.append(Violation(
                "program-shape",
                f"table {name!r} has shape {shape}, expected ({D}, {T})"))
    skip_rd = np.asarray(tabs.skip_rd_slot)
    if skip_rd.shape != (D, T, int(tabs.V)):
        errs.append(Violation(
            "program-shape",
            f"skip_rd_slot has shape {skip_rd.shape}, expected "
            f"({D}, {T}, {tabs.V})"))
    if errs:
        return False
    sel = np.asarray(tabs.sel)
    bad = ~np.isin(sel, (IDLE, RUN_ENC, RUN_DEC))
    for d, k in zip(*np.nonzero(bad)):
        errs.append(Violation("program-shape",
                              f"selector {sel[d, k]} is not IDLE/ENC/DEC",
                              device=int(d), step=int(k)))
    mb = np.asarray(tabs.mb)
    run = sel != IDLE
    bad_mb = run & ((mb < 0) | (mb >= int(tabs.M)))
    for d, k in zip(*np.nonzero(bad_mb)):
        errs.append(Violation(
            "program-shape",
            f"microbatch {mb[d, k]} out of range [0, {tabs.M})",
            device=int(d), step=int(k)))
    vslot = np.asarray(tabs.slot)
    bad_v = run & ((vslot < 0) | (vslot >= int(tabs.V)))
    for d, k in zip(*np.nonzero(bad_v)):
        errs.append(Violation(
            "program-shape",
            f"stage slot {vslot[d, k]} out of range [0, {tabs.V})",
            device=int(d), step=int(k)))
    if int(tabs.rings) not in (1, 2):
        errs.append(Violation("program-shape",
                              f"rings={tabs.rings}, expected 1 or 2"))
    # a send / buffer write must be attached to a running task (the
    # executors would put an all-zeros "message" on the wire otherwise)
    for name, tab in (("down_send", tabs.down_send),
                      ("up_send", tabs.up_send),
                      ("turn_wr", tabs.turn_wr),
                      ("skip_wr", tabs.skip_wr)):
        orphan = np.asarray(tab) & ~run
        for d, k in zip(*np.nonzero(orphan)):
            errs.append(Violation(
                "program-shape", f"{name} set on an idle step",
                device=int(d), step=int(k)))
    # each microbatch's loss is emitted exactly once, by a running task
    loss = np.asarray(tabs.loss)
    loss_mbs = [int(m) for m in mb[loss & run]] + \
        [-1 for _ in range(int((loss & ~run).sum()))]
    for d, k in zip(*np.nonzero(loss & ~run)):
        errs.append(Violation("program-shape", "loss emitted on idle step",
                              device=int(d), step=int(k)))
    counts = np.bincount([m for m in loss_mbs if m >= 0],
                         minlength=int(tabs.M))
    for m, c in enumerate(counts):
        if c != 1:
            errs.append(Violation(
                "program-shape",
                f"microbatch {m} emits its loss {c} times (expected 1)"))
    return not errs


def _interpret_once(tabs, *, overlap: bool, skip_consumers,
                    errs: list[Violation]):
    """One full symbolic replay.  Returns (events, peaks, hop counts).

    ``events`` is the ordered log of (phase, device, step, channel, slot,
    token) tuples — the observable dataflow — used to prove the overlapped
    and synchronous lowerings equivalent.
    """
    D, T, V = int(tabs.D), int(tabs.num_steps), int(tabs.V)
    folded = int(tabs.rings) == 2
    W = {"down": int(tabs.W_down), "up": int(tabs.W_up),
         "turn": int(tabs.W_turn), "skip": int(tabs.W_skip)}
    sel = np.asarray(tabs.sel)
    slot = np.asarray(tabs.slot)
    mb = np.asarray(tabs.mb)
    down_valid = np.asarray(tabs.down_valid)
    up_valid = np.asarray(tabs.up_valid)
    down_mb = np.asarray(tabs.down_mb)
    up_mb = np.asarray(tabs.up_mb)
    down_send = np.asarray(tabs.down_send)
    up_send = np.asarray(tabs.up_send)
    down_slot = np.asarray(tabs.down_slot)
    up_slot = np.asarray(tabs.up_slot)
    rx_slot = np.asarray(tabs.rx_slot)
    embed = np.asarray(tabs.embed)
    turn_rd = np.asarray(tabs.turn_rd)
    turn_wr = np.asarray(tabs.turn_wr)
    turn_wr_slot = np.asarray(tabs.turn_wr_slot)
    turn_rd_slot = np.asarray(tabs.turn_rd_slot)
    skip_wr = np.asarray(tabs.skip_wr)
    skip_wr_slot = np.asarray(tabs.skip_wr_slot)
    skip_rd_slot = np.asarray(tabs.skip_rd_slot)

    bufs = {chan: [[_Slot() for _ in range(W[chan])] for _ in range(D)]
            for chan in ("down", "up", "turn", "skip")}
    peaks = {chan: 0 for chan in bufs}
    exposed = {"down": 0, "up": 0}
    live = {"down": 0, "up": 0}
    # one in-flight register per ring per device; overlapped lowering also
    # needs the not-yet-issued pending payload (the double buffer)
    in_flight: dict[str, list[_Token | None]] = {
        "down": [None] * D, "up": [None] * D}
    pending: dict[str, list[_Token | None]] = {
        "down": [None] * D, "up": [None] * D}
    events: list[tuple] = []

    def slot_ok(chan: str, d: int, k: int, w: int) -> bool:
        if not 0 <= w < W[chan]:
            errs.append(Violation(
                "buffer-bounds",
                f"{chan} slot {w} outside the declared window "
                f"W_{chan}={W[chan]}", device=d, step=k, slot=int(w)))
            return False
        return True

    def store(chan: str, d: int, k: int, w: int, tok: _Token):
        if not slot_ok(chan, d, k, w):
            return
        s = bufs[chan][d][w]
        if s.token is not None and s.reads == 0:
            errs.append(Violation(
                "no-live-overwrite",
                f"store into {chan} slot {w} clobbers the live entry for "
                f"microbatch {s.token.microbatch} (stored at step "
                f"{s.stored_at}, not yet read)", device=d, step=k,
                slot=int(w)))
        s.token, s.reads, s.stored_at = tok, 0, k
        events.append(("store", chan, d, k, int(w), tok))

    def read(chan: str, d: int, k: int, w: int, want_mb: int,
             want_kind: str, want_enc_slot: int | None = None
             ) -> _Token | None:
        if not slot_ok(chan, d, k, w):
            return None
        s = bufs[chan][d][w]
        if s.token is None:
            errs.append(Violation(
                "matched-store-read",
                f"read of {chan} slot {w} with no preceding store "
                "(uninitialized-slot read)", device=d, step=k,
                slot=int(w)))
            return None
        tok = s.token
        if tok.microbatch != want_mb or (
                want_enc_slot is not None
                and tok.enc_slot != want_enc_slot):
            errs.append(Violation(
                "matched-store-read",
                f"read of {chan} slot {w} expected microbatch {want_mb}"
                + (f" enc slot {want_enc_slot}"
                   if want_enc_slot is not None else "")
                + f" but the slot holds microbatch {tok.microbatch}"
                + (f" enc slot {tok.enc_slot}"
                   if want_enc_slot is not None else "")
                + f" (stored at step {s.stored_at})",
                device=d, step=k, slot=int(w)))
        if tok.kind != want_kind:
            errs.append(Violation(
                "wire-dtype-flow",
                f"{chan} slot {w} holds a {tok.kind}-dtype value but the "
                f"consumer reads it as {want_kind} (cast-on-send must "
                "meet upcast-on-read)", device=d, step=k, slot=int(w)))
        s.reads += 1
        events.append(("read", chan, d, k, int(w), tok))
        return tok

    def occupancy(chan: str) -> int:
        return max(sum(1 for s in dev if s.token is not None
                       and s.reads == 0) for dev in bufs[chan]) \
            if bufs[chan] and W[chan] else 0

    for k in range(T):
        # ---- hop + arrival phase (top of the scan body) ----------------
        # overlapped: step k-1's payload was parked in `pending` and its
        # ppermute is issued now; synchronous: it already moved to
        # `in_flight` at the bottom of step k-1.  Either way the token is
        # stored before this step's reads — same arrival step, which is
        # exactly the equivalence the overlap lowering claims.
        if overlap:
            for ring in ("down", "up"):
                in_flight[ring] = pending[ring]
                pending[ring] = [None] * D
        for ring, valid, mb_tab, slot_tab, shift in (
                ("down", down_valid, down_mb, down_slot, +1),
                ("up", up_valid, up_mb, up_slot, -1)):
            arrived = [None] * D
            for src in range(D):
                if in_flight[ring][src] is not None:
                    arrived[(src + shift) % D] = in_flight[ring][src]
            in_flight[ring] = [None] * D
            for d in range(D):
                tok = arrived[d]
                if valid[d, k]:
                    if tok is None:
                        errs.append(Violation(
                            "send-recv-pairing",
                            f"{ring}-ring arrival stored at step {k} but "
                            "the ring neighbour sent nothing at step "
                            f"{k - 1}", device=d, step=k))
                        continue
                    if tok.microbatch != mb_tab[d, k]:
                        errs.append(Violation(
                            "send-recv-pairing",
                            f"{ring}-ring arrival carries microbatch "
                            f"{tok.microbatch} but the table expects "
                            f"{mb_tab[d, k]}", device=d, step=k))
                    live[ring] += 1
                    store(ring, d, k, int(slot_tab[d, k]), tok)
                elif tok is not None:
                    errs.append(Violation(
                        "send-recv-pairing",
                        f"{ring}-ring message sent by device "
                        f"{tok.src_device} at step {tok.src_step} is "
                        "dropped (receiver stores nothing this step)",
                        device=d, step=k))
        for chan in peaks:
            peaks[chan] = max(peaks[chan], occupancy(chan))

        # ---- compute phase: the selected task's reads ------------------
        for d in range(D):
            s, m = int(sel[d, k]), int(mb[d, k])
            if s == RUN_ENC and not embed[d, k]:
                tok = read("down", d, k, int(rx_slot[d, k]), m, WIRE)
                if tok is not None and tok.src_step + 1 == k:
                    exposed["down"] += 1
            elif s == RUN_DEC:
                if turn_rd[d, k]:
                    read("turn", d, k, int(turn_rd_slot[d, k]), m, COMPUTE)
                else:
                    tok = read("up", d, k, int(rx_slot[d, k]), m, WIRE)
                    if tok is not None and tok.src_step + 1 == k:
                        exposed["up"] += 1
                consumers = (range(V) if skip_consumers is None
                             else skip_consumers[d][int(slot[d, k])])
                for ev in consumers:
                    read("skip", d, k, int(skip_rd_slot[d, k, ev]), m,
                         COMPUTE, want_enc_slot=int(ev))

        # ---- write phase: turn / skip stores + this step's sends -------
        for d in range(D):
            s, m = int(sel[d, k]), int(mb[d, k])
            out = _Token(d, k, m, COMPUTE) if s != IDLE else None
            if turn_wr[d, k] and out is not None:
                store("turn", d, k, int(turn_wr_slot[d, k]), out)
            if skip_wr[d, k] and out is not None:
                store("skip", d, k, int(skip_wr_slot[d, k]),
                      dataclasses.replace(out, enc_slot=int(slot[d, k])))
            for ring, send in (("down", down_send), ("up", up_send)):
                if send[d, k] and out is not None:
                    wire_tok = dataclasses.replace(out, kind=WIRE)
                    (pending if overlap else in_flight)[ring][d] = wire_tok
        for chan in ("turn", "skip"):
            peaks[chan] = max(peaks[chan], occupancy(chan))

    # ---- end of scan: nothing may still be in flight or unread ---------
    for ring in ("down", "up"):
        for regs in (in_flight[ring], pending[ring]):
            for d in range(D):
                tok = regs[d]
                if tok is not None:
                    errs.append(Violation(
                        "send-recv-pairing",
                        f"{ring}-ring message sent at step {tok.src_step} "
                        "is still in flight when the scan ends (no "
                        "consumer step)", device=d, step=tok.src_step))
    for chan, dev_bufs in bufs.items():
        for d, dev in enumerate(dev_bufs):
            for w, s in enumerate(dev):
                if s.token is not None and s.reads == 0:
                    errs.append(Violation(
                        "no-lost-message",
                        f"{chan} slot {w} entry for microbatch "
                        f"{s.token.microbatch} (stored at step "
                        f"{s.stored_at}) is never read", device=d,
                        slot=w, step=s.stored_at))
    if not folded and (turn_wr.any() or skip_wr.any() or up_send.any()):
        errs.append(Violation(
            "program-shape",
            "linear (single-ring) tables carry turnaround/skip/up-ring "
            "activity"))
    return events, peaks, exposed, live


def interpret_tables(tabs, *, overlap: bool = True,
                     skip_consumers=None) -> DataflowReport:
    """Statically verify a lowered :class:`StepTables` device program.

    ``tabs`` is duck-typed (any object with the StepTables fields), so
    corrupted/mutated tables — ``dataclasses.replace`` products in the
    mutation-soundness suite — flow through the same proof.

    ``skip_consumers`` must be the SAME per-(device, dec-slot) consumer
    lists the lowering was given (``StageLayout.skip_consumers()``), or
    None for the conservative every-slot analysis; the interpreter reads
    exactly the stash entries the executors' pairing tables consume.

    ``overlap`` selects which hop lowering is primary (it decides nothing
    about arrival steps — that is the point); the interpreter ALWAYS
    replays both lowerings and appends an ``overlap-equivalence``
    violation if their observable store/read event streams differ.
    """
    errs: list[Violation] = []
    if not _shape_check(tabs, errs):
        return DataflowReport(tuple(errs), 0, 0, 0, 0, 0, 0, 0, 0)
    events, peaks, exposed, live = _interpret_once(
        tabs, overlap=overlap, skip_consumers=skip_consumers, errs=errs)
    other_errs: list[Violation] = []
    other_events, *_ = _interpret_once(
        tabs, overlap=not overlap, skip_consumers=skip_consumers,
        errs=other_errs)
    if events != other_events:
        diff = next((i for i, (a, b) in enumerate(
            zip(events, other_events)) if a != b),
            min(len(events), len(other_events)))
        errs.append(Violation(
            "overlap-equivalence",
            "synchronous and double-buffered hop lowerings diverge at "
            f"dataflow event {diff} of {len(events)}"))

    # declared windows really bound the replayed occupancy
    for chan, declared in (("down", tabs.W_down), ("up", tabs.W_up),
                           ("turn", tabs.W_turn), ("skip", tabs.W_skip)):
        if peaks[chan] > int(declared):
            errs.append(Violation(
                "buffer-bounds",
                f"replayed peak {chan} occupancy {peaks[chan]} exceeds "
                f"the declared window W_{chan}={declared}"))
    # the lowering's exposed/hidden split matches the replay's own count
    for ring, declared in (("down", tabs.exposed_down),
                           ("up", tabs.exposed_up)):
        if exposed[ring] != int(declared):
            errs.append(Violation(
                "overlap-accounting",
                f"replay counts {exposed[ring]} exposed {ring}-ring hops "
                f"but the lowering declared {declared}"))
    declared_live = tuple(int(x) for x in tabs.live_hops)
    if (live["down"], live["up"]) != declared_live:
        errs.append(Violation(
            "overlap-accounting",
            f"replay carried {(live['down'], live['up'])} (down, up) "
            f"messages but the send masks declare {declared_live}"))
    return DataflowReport(
        tuple(errs), peak_down=peaks["down"], peak_up=peaks["up"],
        peak_turn=peaks["turn"], peak_skip=peaks["skip"],
        exposed_down=exposed["down"], exposed_up=exposed["up"],
        live_down=live["down"], live_up=live["up"])
